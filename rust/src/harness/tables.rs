//! Regeneration of every table in the paper's evaluation section
//! (DESIGN.md §6 maps each to its modules). Tables that the paper measured
//! on the Xeon Phi testbed are regenerated through `phisim`; accuracy
//! tables run the real CHAOS trainer on this host.

use super::report::{fnum, fpct, Table};
use crate::chaos::{ChaosPolicy, RunResult, SequentialPolicy, Trainer};
use crate::config::{ArchSpec, LayerSpec, TrainConfig, PAPER_ARCHS};
use crate::data;
use crate::nn::{compute_dims, Network};
use crate::perfmodel::{
    arch_constants, contention_measured, paper_predicted, ContentionModel, PerfModel, Scenario,
    CLOCK_HZ, MEASURED_THREADS, OPERATION_FACTOR,
};
use crate::phisim::{simulate, SimConfig, PAPER_THREAD_COUNTS};
use crate::util::timer::LayerClass;

/// Scale knobs for the tables that run real training.
#[derive(Debug, Clone, Copy)]
pub struct RealRunScale {
    pub train_images: usize,
    pub test_images: usize,
    pub epochs: usize,
    pub eta0: f64,
}

impl RealRunScale {
    pub fn quick() -> RealRunScale {
        RealRunScale { train_images: 400, test_images: 200, epochs: 3, eta0: 0.01 }
    }

    pub fn full() -> RealRunScale {
        RealRunScale { train_images: 2_000, test_images: 800, epochs: 8, eta0: 0.01 }
    }
}

/// Table 1: execution time at each layer type for the sequential version
/// (small architecture). The paper measured a Xeon E5; we measure this
/// host, and the shape claim — convolution dominating with ~94% — is what
/// must reproduce.
pub fn table1(scale: RealRunScale) -> anyhow::Result<Table> {
    let net = Network::new(ArchSpec::small());
    let (train, test) = data::load_or_generate("data/mnist", scale.train_images, scale.test_images, 7);
    let cfg = TrainConfig {
        epochs: 1,
        threads: 1,
        eta0: scale.eta0,
        eta_decay: 0.9,
        seed: 1,
        validation_fraction: 0.0,
        eval_batch: 32,
        ..TrainConfig::default()
    };
    let run = Trainer::new()
        .network(net)
        .config(cfg)
        .policy(SequentialPolicy)
        .run(&train, &test)?;
    let t = &run.layer_times;
    let total = t.total_secs();
    let mut tab = Table::new(
        "Table 1 — sequential per-layer-type times (small arch, this host)",
        &["Layer type", "Forward propagation", "Back-propagation", "% of total"],
    );
    let get = |c: LayerClass| t.get_secs(c);
    let rows = [
        (
            "Fully connected (+output)",
            get(LayerClass::FcForward) + get(LayerClass::OutputForward),
            get(LayerClass::FcBackward) + get(LayerClass::OutputBackward),
        ),
        ("Convolutional", get(LayerClass::ConvForward), get(LayerClass::ConvBackward)),
        ("Max pooling", get(LayerClass::PoolForward), get(LayerClass::PoolBackward)),
    ];
    for (name, f, b) in rows {
        tab.row(vec![
            name.into(),
            format!("{:.2} s", f),
            format!("{:.2} s", b),
            fpct((f + b) / total),
        ]);
    }
    tab.note(format!(
        "{} train images, 1 epoch, sequential. Paper: conv layers take 93.7% on a Xeon E5.",
        train.len()
    ));
    Ok(tab)
}

/// Table 2: the three CNN architectures, regenerated from the config
/// structs (maps, map sizes, neurons, kernels, weights per layer).
pub fn table2() -> Table {
    let mut tab = Table::new(
        "Table 2 — CNN architectures",
        &["Arch", "Layer type", "Maps", "Map size", "Neurons", "Kernel", "Weights"],
    );
    for name in PAPER_ARCHS {
        let arch = ArchSpec::by_name(name).unwrap();
        let dims = compute_dims(&arch);
        for d in &dims {
            let (ty, maps, kernel): (&str, String, String) = match &d.spec {
                LayerSpec::Input { .. } => ("Input", "-".into(), "-".into()),
                LayerSpec::Conv { maps, kernel, .. } => {
                    ("Convolutional", maps.to_string(), format!("{kernel}x{kernel}"))
                }
                LayerSpec::MaxPool { kernel } => {
                    ("Max-pooling", d.out_maps.to_string(), format!("{kernel}x{kernel}"))
                }
                LayerSpec::AvgPool { kernel } => {
                    ("Avg-pooling", d.out_maps.to_string(), format!("{kernel}x{kernel}"))
                }
                LayerSpec::FullyConnected { .. } => ("Fully connected", "-".into(), "-".into()),
                LayerSpec::Dropout { .. } => ("Dropout", "-".into(), "-".into()),
                LayerSpec::Output { .. } => ("Output", "-".into(), "-".into()),
                LayerSpec::Custom { kind, .. } => (kind.as_str(), "-".into(), "-".into()),
            };
            tab.row(vec![
                name.into(),
                ty.into(),
                maps,
                format!("{0}x{0}", d.out_side),
                d.out_len().to_string(),
                kernel,
                if d.param_count() > 0 { d.param_count().to_string() } else { "-".into() },
            ]);
        }
    }
    tab.note("Large pool-3 kernel is 2x2 (3x3 output): the only reading consistent with the paper's 135,150 FC weights — see DESIGN.md §5.");
    tab
}

/// Table 3: performance-model variables.
pub fn table3() -> Table {
    let mut tab = Table::new(
        "Table 3 — performance model variables",
        &["Variable", "Small", "Medium", "Large"],
    );
    let c: Vec<_> = ["small", "medium", "large"]
        .iter()
        .map(|a| arch_constants(a).unwrap())
        .collect();
    tab.row(vec![
        "FProp ops/image".into(),
        fnum(c[0].fprop_ops),
        fnum(c[1].fprop_ops),
        fnum(c[2].fprop_ops),
    ]);
    tab.row(vec![
        "BProp ops/image".into(),
        fnum(c[0].bprop_ops),
        fnum(c[1].bprop_ops),
        fnum(c[2].bprop_ops),
    ]);
    tab.row(vec![
        "Prep ops".into(),
        format!("{:.0e}", c[0].prep_ops),
        format!("{:.0e}", c[1].prep_ops),
        format!("{:.0e}", c[2].prep_ops),
    ]);
    tab.row(vec![
        "T_Fprop / image (ms)".into(),
        fnum(c[0].t_fprop_ms),
        fnum(c[1].t_fprop_ms),
        fnum(c[2].t_fprop_ms),
    ]);
    tab.row(vec![
        "T_Bprop / image (ms)".into(),
        fnum(c[0].t_bprop_ms),
        fnum(c[1].t_bprop_ms),
        fnum(c[2].t_bprop_ms),
    ]);
    tab.row(vec![
        "Epochs".into(),
        c[0].epochs.to_string(),
        c[1].epochs.to_string(),
        c[2].epochs.to_string(),
    ]);
    tab.row(vec![
        "Clock s (GHz) / OperationFactor".into(),
        format!("{:.3} / {}", CLOCK_HZ / 1e9, OPERATION_FACTOR),
        "—".into(),
        "—".into(),
    ]);
    tab
}

/// Table 4: measured and extrapolated memory contention.
pub fn table4() -> Table {
    let mut tab = Table::new(
        "Table 4 — memory contention (s/image): measured + extrapolated",
        &["# Threads", "Small", "Medium", "Large", "Source"],
    );
    let models: Vec<_> = ["small", "medium", "large"]
        .iter()
        .map(|a| ContentionModel::for_arch(a).unwrap())
        .collect();
    for (i, &p) in MEASURED_THREADS.iter().enumerate() {
        let m: Vec<f64> = ["small", "medium", "large"]
            .iter()
            .map(|a| contention_measured(a).unwrap()[i])
            .collect();
        tab.row(vec![
            p.to_string(),
            format!("{:.2e}", m[0]),
            format!("{:.2e}", m[1]),
            format!("{:.2e}", m[2]),
            "paper (measured)".into(),
        ]);
    }
    for p in [480usize, 960, 1920, 3840] {
        tab.row(vec![
            format!("{p}*"),
            format!("{:.2e}", models[0].contention(p)),
            format!("{:.2e}", models[1].contention(p)),
            format!("{:.2e}", models[2].contention(p)),
            "extrapolated".into(),
        ]);
    }
    // Regression note vs the paper's own starred rows.
    let mut worst: f64 = 0.0;
    for (ai, a) in ["small", "medium", "large"].iter().enumerate() {
        for (p, expect) in paper_predicted(a).unwrap() {
            let got = models[ai].contention(p);
            worst = worst.max((got - expect).abs() / expect);
        }
    }
    tab.note(format!(
        "Extrapolation vs the paper's starred rows: worst deviation {:.1}%.",
        worst * 100.0
    ));
    tab
}

/// Table 5: average time per layer class, large architecture, per network
/// instance per epoch (simulated testbed).
pub fn table5() -> anyhow::Result<Table> {
    let mut tab = Table::new(
        "Table 5 — time per layer class, large arch (per instance/epoch, phisim)",
        &["Config", "BPF (s)", "BPF %", "BPC (s)", "BPC %", "FPC (s)", "FPC %", "FPF (s)", "FPF %"],
    );
    for &p in PAPER_THREAD_COUNTS.iter().rev() {
        let r = simulate(&SimConfig::paper("large", p))?;
        let c = r.layer_class_secs();
        let total = c.total();
        tab.row(vec![
            format!("Phi Par. {p} T"),
            fnum(c.bpf),
            fpct(c.bpf / total),
            fnum(c.bpc),
            fpct(c.bpc / total),
            fnum(c.fpc),
            fpct(c.fpc / total),
            fnum(c.fpf),
            fpct(c.fpf / total),
        ]);
    }
    tab.note("Paper (244T): BPC 88.5%, FPC 9.6%, BPF 1.4%, FPF 0.04%.");
    Ok(tab)
}

/// Table 6: per-layer speedup of the convolutional layers vs Phi 1T.
pub fn table6() -> anyhow::Result<Table> {
    let mut tab = Table::new(
        "Table 6 — conv-layer speedup vs Phi 1T (phisim)",
        &["Config", "BPC-S", "BPC-M", "BPC-L", "FPC-S", "FPC-M", "FPC-L"],
    );
    // per-arch: per-instance conv times at 1T and pT
    let mut results = Vec::new();
    for arch in ["small", "medium", "large"] {
        let base = simulate(&SimConfig::paper(arch, 1))?.layer_class_secs();
        let rows: Vec<(usize, f64, f64)> = PAPER_THREAD_COUNTS[1..]
            .iter()
            .map(|&p| {
                let c = simulate(&SimConfig::paper(arch, p)).unwrap().layer_class_secs();
                (p, base.bpc / c.bpc, base.fpc / c.fpc)
            })
            .collect();
        results.push(rows);
    }
    for (i, &p) in PAPER_THREAD_COUNTS[1..].iter().enumerate().rev() {
        tab.row(vec![
            format!("Phi Par. {p} T"),
            fnum(results[0][i].1),
            fnum(results[1][i].1),
            fnum(results[2][i].1),
            fnum(results[0][i].2),
            fnum(results[1][i].2),
            fnum(results[2][i].2),
        ]);
    }
    tab.note("Paper (244T): BPC 102.0/99.3/103.5, FPC 122.3/124.2/125.4.");
    Ok(tab)
}

/// Run the real accuracy-parity experiment behind Table 7 / Fig 10:
/// a sequential baseline plus CHAOS at several thread counts, identical
/// seeds and data. Returns (baseline, parallel runs).
pub fn parity_runs(
    arch: &str,
    threads: &[usize],
    scale: RealRunScale,
) -> anyhow::Result<(RunResult, Vec<RunResult>)> {
    let spec = ArchSpec::by_name(arch)
        .ok_or_else(|| anyhow::anyhow!("unknown arch '{arch}'"))?;
    let net = Network::new(spec);
    let (train, test) =
        data::load_or_generate("data/mnist", scale.train_images, scale.test_images, 7);
    let cfg = TrainConfig {
        epochs: scale.epochs,
        threads: 1,
        eta0: scale.eta0,
        eta_decay: 0.9,
        seed: 0xC4A05,
        validation_fraction: 0.25,
        eval_batch: 32,
        ..TrainConfig::default()
    };
    let baseline = Trainer::new()
        .network(net.clone())
        .config(cfg.clone())
        .policy(SequentialPolicy)
        .run(&train, &test)?;
    let mut runs = Vec::new();
    for &t in threads {
        let cfg_t = TrainConfig { threads: t, ..cfg.clone() };
        runs.push(
            Trainer::new()
                .network(net.clone())
                .config(cfg_t)
                .policy(ChaosPolicy)
                .run(&train, &test)?,
        );
    }
    Ok((baseline, runs))
}

/// Table 7: incorrectly classified images, parallel vs sequential.
/// Thread counts are scaled to this host (the semantics — shared weights,
/// asynchronous updates — are identical at any thread count; DESIGN.md §2).
pub fn table7(arch: &str, threads: &[usize], scale: RealRunScale) -> anyhow::Result<Table> {
    let (baseline, runs) = parity_runs(arch, threads, scale)?;
    let b_val = baseline.final_epoch().validation.errors as i64;
    let b_test = baseline.final_epoch().test.errors as i64;
    let mut tab = Table::new(
        format!("Table 7 — incorrectly classified images ({arch}, real training)"),
        &["# threads", "Validation Tot", "Validation Diff", "Test Tot", "Test Diff"],
    );
    tab.row(vec![
        "1 (seq baseline)".into(),
        b_val.to_string(),
        "0".into(),
        b_test.to_string(),
        "0".into(),
    ]);
    for r in &runs {
        let e = r.final_epoch();
        tab.row(vec![
            r.threads.to_string(),
            e.validation.errors.to_string(),
            (e.validation.errors as i64 - b_val).to_string(),
            e.test.errors.to_string(),
            (e.test.errors as i64 - b_test).to_string(),
        ]);
    }
    tab.note(format!(
        "{} train / {} test images, {} epochs, eta0 {}. Paper finds deviations of tens of images out of 60k/10k.",
        scale.train_images, scale.test_images, scale.epochs, scale.eta0
    ));
    Ok(tab)
}

/// Table 8: predicted execution times (minutes) for 480–3840 threads.
pub fn table8() -> anyhow::Result<Table> {
    let mut tab = Table::new(
        "Table 8 — predicted minutes for future thread counts (Listing-2 model)",
        &["# Threads", "480", "960", "1920", "3840"],
    );
    let paper = [
        ("Small CNN", [6.6, 5.4, 4.9, 4.6]),
        ("Medium CNN", [36.8, 23.9, 17.4, 14.2]),
        ("Large CNN", [92.9, 60.8, 44.8, 36.8]),
    ];
    for (row, (label, paper_vals)) in ["small", "medium", "large"].iter().zip(paper) {
        let m = PerfModel::for_arch(row)?;
        let mins: Vec<f64> = [480usize, 960, 1920, 3840]
            .iter()
            .map(|&p| m.predict_minutes(&Scenario::paper_default(row, p)))
            .collect();
        tab.row(vec![
            label.to_string(),
            format!("{:.1} ({:.1})", mins[0], paper_vals[0]),
            format!("{:.1} ({:.1})", mins[1], paper_vals[1]),
            format!("{:.1} ({:.1})", mins[2], paper_vals[2]),
            format!("{:.1} ({:.1})", mins[3], paper_vals[3]),
        ]);
    }
    tab.note("Cell format: ours (paper).");
    Ok(tab)
}

/// Table 9: predicted minutes scaling images/epochs at 240/480 threads.
pub fn table9() -> anyhow::Result<Table> {
    let m = PerfModel::for_arch("small")?;
    let mut tab = Table::new(
        "Table 9 — predicted minutes scaling images and epochs (small CNN)",
        &["i/it", "p", "70 ep", "140 ep", "280 ep", "560 ep"],
    );
    for (i, it) in [(60_000, 10_000), (120_000, 20_000), (240_000, 40_000)] {
        for p in [240usize, 480] {
            let mins: Vec<String> = [70usize, 140, 280, 560]
                .iter()
                .map(|&ep| {
                    fnum(m.predict_minutes(&Scenario {
                        images: i,
                        test_images: it,
                        epochs: ep,
                        threads: p,
                    }))
                })
                .collect();
            tab.row(vec![
                format!("{}k/{}k", i / 1000, it / 1000),
                p.to_string(),
                mins[0].clone(),
                mins[1].clone(),
                mins[2].clone(),
                mins[3].clone(),
            ]);
        }
    }
    tab.note("Paper anchors: 60k/10k, 240T, 70 ep → 8.9 min; 480T → 6.6 min.");
    Ok(tab)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_matches_paper_weight_counts() {
        let t = table2();
        let md = t.to_markdown();
        for w in ["340", "30060", "216100", "135150", "1510", "85", "1260", "4550", "510", "20040", "54150"] {
            assert!(md.contains(w), "missing weight count {w}");
        }
    }

    #[test]
    fn table3_and_4_render() {
        assert!(table3().to_markdown().contains("5349000"));
        let t4 = table4().to_markdown();
        assert!(t4.contains("3840*"));
        assert!(t4.contains("1.40e-2") || t4.contains("1.40e-02"), "{t4}");
    }

    #[test]
    fn table5_dominated_by_bpc() {
        let t = table5().unwrap();
        let md = t.to_markdown();
        assert!(t.n_rows() == 8);
        // 244T row: BPC share must be in the high-80s%.
        let row244 = md.lines().find(|l| l.contains("244 T")).unwrap();
        assert!(row244.contains("8") && row244.contains("%"), "{row244}");
    }

    #[test]
    fn table6_shape() {
        let t = table6().unwrap();
        assert_eq!(t.n_rows(), 7);
    }

    #[test]
    fn table8_and_9_render() {
        assert!(table8().unwrap().to_markdown().contains("(92.9)"));
        assert!(table9().unwrap().n_rows() == 6);
    }
}
