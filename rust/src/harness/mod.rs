//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation section (the per-experiment index lives in DESIGN.md §6).
//!
//! Tables/figures measured on the Xeon Phi testbed come from the
//! [`crate::phisim`] simulator and the [`crate::perfmodel`] analytic model;
//! accuracy experiments (Table 7, Fig 10, Table 1) run the real CHAOS
//! trainer on this host.

mod figures;
mod report;
mod tables;

pub use figures::{fig10, fig5, fig6, fig_pred_vs_measured, fig_speedups, EPOCHS_TO_TARGET};
pub use report::{fnum, fpct, Table};
pub use tables::{
    parity_runs, table1, table2, table3, table4, table5, table6, table7, table8, table9,
    RealRunScale,
};
