//! Regeneration of every figure's data series (Figs 5–13). Figures are
//! emitted as markdown tables of the same series the paper plots.

use super::report::{fnum, Table};
use super::tables::{parity_runs, RealRunScale};
use crate::perfmodel::{PerfModel, Scenario};
use crate::phisim::{simulate, speedup_table, xeon_e5_seq_secs, SimConfig, PAPER_THREAD_COUNTS};
use crate::util::stats::relative_deviation;

/// Fig 5: total execution time (hours) vs threads for all architectures,
/// with the sequential Xeon E5 reference.
pub fn fig5() -> anyhow::Result<Table> {
    let mut tab = Table::new(
        "Fig 5 — total execution time (hours), Phi parallel vs Xeon E5 sequential",
        &["Config", "Small", "Medium", "Large"],
    );
    let totals = |f: &dyn Fn(&str) -> anyhow::Result<f64>| -> anyhow::Result<Vec<f64>> {
        ["small", "medium", "large"].iter().map(|a| f(a)).collect()
    };
    let e5 = totals(&|a| xeon_e5_seq_secs(a))?;
    tab.row(vec![
        "Xeon E5 Seq.".into(),
        fnum(e5[0] / 3600.0),
        fnum(e5[1] / 3600.0),
        fnum(e5[2] / 3600.0),
    ]);
    for &p in &PAPER_THREAD_COUNTS {
        let t = totals(&|a| Ok(simulate(&SimConfig::paper(a, p))?.total_secs()))?;
        tab.row(vec![
            format!("Phi Par. {p} T"),
            fnum(t[0] / 3600.0),
            fnum(t[1] / 3600.0),
            fnum(t[2] / 3600.0),
        ]);
    }
    tab.note("Paper anchors: large 1T = 295.5 h, 244T = 2.9 h, E5 seq = 31.1 h.");
    Ok(tab)
}

/// Epochs each architecture needs to reach the paper's 1.54% stop
/// criterion. The small network defines the target (its own ending error
/// after its full 70 epochs); bigger networks hit it in far fewer epochs.
/// The paper does not tabulate the counts, only the resulting ordering
/// (Fig 6: medium fastest to the target, large slowest despite fewest
/// epochs); these constants are chosen to satisfy that ordering and are
/// documented as assumptions in EXPERIMENTS.md. The real-training
/// convergence complement is Fig 10 / Table 7.
pub const EPOCHS_TO_TARGET: [(&str, usize); 3] = [("small", 70), ("medium", 5), ("large", 3)];

/// Fig 6: total execution time until test error ≤ 1.54%.
pub fn fig6() -> anyhow::Result<Table> {
    let mut tab = Table::new(
        "Fig 6 — hours until test error ≤ 1.54% (phisim × epochs-to-target)",
        &["Config", "Small (70 ep)", "Medium (5 ep)", "Large (3 ep)"],
    );
    for &p in &PAPER_THREAD_COUNTS[1..] {
        let mut cells = vec![format!("Phi Par. {p} T")];
        for (arch, epochs) in EPOCHS_TO_TARGET {
            let mut cfg = SimConfig::paper(arch, p);
            cfg.epochs = epochs;
            cells.push(fnum(simulate(&cfg)?.total_secs() / 3600.0));
        }
        tab.row(cells);
    }
    tab.note("Paper: medium reaches the target faster than small; large takes longest despite fewest epochs.");
    Ok(tab)
}

/// Figs 7/8/9: speedups vs Xeon E5 seq / Phi 1T / Core i5 seq.
pub fn fig_speedups(which: u8) -> anyhow::Result<Table> {
    let (title, pick): (&str, fn(&crate::phisim::SpeedupRow) -> f64) = match which {
        7 => ("Fig 7 — speedup vs sequential Xeon E5", |r| r.vs_xeon_e5),
        8 => ("Fig 8 — speedup vs one Phi thread", |r| r.vs_phi_1t),
        9 => ("Fig 9 — speedup vs sequential Core i5", |r| r.vs_core_i5),
        _ => anyhow::bail!("fig_speedups expects 7, 8 or 9"),
    };
    let mut tab = Table::new(title, &["Threads", "Small", "Medium", "Large"]);
    let tables: Vec<_> = ["small", "medium", "large"]
        .iter()
        .map(|a| speedup_table(a))
        .collect::<anyhow::Result<Vec<_>>>()?;
    for (i, &p) in PAPER_THREAD_COUNTS.iter().enumerate() {
        if p == 1 {
            continue;
        }
        tab.row(vec![
            p.to_string(),
            fnum(pick(&tables[0][i])),
            fnum(pick(&tables[1][i])),
            fnum(pick(&tables[2][i])),
        ]);
    }
    match which {
        7 => tab.note("Paper: up to 14.07× at 244 threads."),
        8 => tab.note("Paper: up to 103× at 244 threads; near-linear to 60."),
        _ => tab.note("Paper: up to 65.3× at 244 threads (58× headline at 240)."),
    };
    Ok(tab)
}

/// Fig 10: relative cumulative error (loss) of parallel runs vs the
/// sequential baseline, validation and test sets — real training.
pub fn fig10(arch: &str, threads: &[usize], scale: RealRunScale) -> anyhow::Result<Table> {
    let (baseline, runs) = parity_runs(arch, threads, scale)?;
    let b = baseline.final_epoch();
    let mut tab = Table::new(
        format!("Fig 10 — relative cumulative error vs sequential ({arch}, real training)"),
        &["# threads", "Validation loss ratio", "Test loss ratio"],
    );
    for r in &runs {
        let e = r.final_epoch();
        tab.row(vec![
            r.threads.to_string(),
            fnum(e.validation.loss / b.validation.loss),
            fnum(e.test.loss / b.test.loss),
        ]);
    }
    tab.note("1.0 = identical to sequential; paper's worst deviation is ~0.05% above baseline.");
    Ok(tab)
}

/// Figs 11–13: predicted (analytic model) vs simulated-measured execution
/// time for one architecture, with the paper's deviation metric.
pub fn fig_pred_vs_measured(arch: &str) -> anyhow::Result<Table> {
    let fig_no = match arch {
        "small" => 11,
        "medium" => 12,
        "large" => 13,
        _ => anyhow::bail!("paper archs only"),
    };
    let model = PerfModel::for_arch(arch)?;
    let mut tab = Table::new(
        format!("Fig {fig_no} — predicted vs measured execution time ({arch})"),
        &["Threads", "Predicted (min)", "Measured/sim (min)", "Deviation"],
    );
    let mut devs = Vec::new();
    for &p in &PAPER_THREAD_COUNTS {
        let predicted = model.predict_secs(&Scenario::paper_default(arch, p));
        let measured = simulate(&SimConfig::paper(arch, p))?.total_secs();
        let dev = relative_deviation(measured, predicted);
        devs.push(dev);
        tab.row(vec![
            p.to_string(),
            fnum(predicted / 60.0),
            fnum(measured / 60.0),
            format!("{:.1}%", dev * 100.0),
        ]);
    }
    let avg = devs.iter().sum::<f64>() / devs.len() as f64;
    tab.note(format!(
        "Average deviation {:.1}% (paper: 14.57% small / 14.76% medium / 15.36% large).",
        avg * 100.0
    ));
    Ok(tab)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_renders_with_e5_row() {
        let t = fig5().unwrap();
        let md = t.to_markdown();
        assert!(md.contains("Xeon E5 Seq."));
        assert_eq!(t.n_rows(), 9);
    }

    #[test]
    fn fig6_medium_faster_than_small_and_large_slowest() {
        let t = fig6().unwrap();
        let md = t.to_markdown();
        // 244T row: medium < small < large (paper's qualitative finding).
        let row = md.lines().find(|l| l.starts_with("| 244") || l.contains("244 T")).unwrap();
        let cells: Vec<f64> = row
            .split('|')
            .filter_map(|c| c.trim().parse::<f64>().ok())
            .collect();
        assert_eq!(cells.len(), 3, "{row}");
        assert!(cells[1] < cells[0], "medium should beat small: {row}");
        assert!(cells[2] > cells[0], "large slowest: {row}");
    }

    #[test]
    fn speedup_figs_render() {
        for which in [7u8, 8, 9] {
            let t = fig_speedups(which).unwrap();
            assert_eq!(t.n_rows(), 7);
        }
        assert!(fig_speedups(4).is_err());
    }

    #[test]
    fn fig11_13_deviation_reasonable() {
        for arch in ["small", "medium", "large"] {
            let t = fig_pred_vs_measured(arch).unwrap();
            let md = t.to_markdown();
            // The model and simulator must agree within the paper's own
            // error regime (avg ≤ 25%).
            let avg: f64 = md
                .lines()
                .find(|l| l.contains("Average deviation"))
                .and_then(|l| l.split("deviation ").nth(1))
                .and_then(|s| s.split('%').next())
                .and_then(|s| s.trim().parse().ok())
                .unwrap();
            assert!(avg <= 25.0, "{arch}: avg deviation {avg}%");
        }
    }
}
