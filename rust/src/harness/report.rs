//! Markdown table builder for the experiment harness.

/// A simple aligned markdown table.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    notes: Vec<String>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Table {
        Table {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    pub fn note(&mut self, s: impl Into<String>) -> &mut Self {
        self.notes.push(s.into());
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn to_markdown(&self) -> String {
        let mut out = format!("### {}\n\n", self.title);
        out.push_str(&format!("| {} |\n", self.header.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            self.header.iter().map(|_| "---|").collect::<String>()
        ));
        for r in &self.rows {
            out.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        for n in &self.notes {
            out.push_str(&format!("\n> {n}\n"));
        }
        out
    }
}

/// Format a float with sensible precision for table cells.
pub fn fnum(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 1000.0 {
        format!("{v:.0}")
    } else if v.abs() >= 10.0 {
        format!("{v:.1}")
    } else if v.abs() >= 0.01 {
        format!("{v:.3}")
    } else {
        format!("{v:.3e}")
    }
}

/// Percent with one decimal.
pub fn fpct(frac: f64) -> String {
    format!("{:.1}%", frac * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_markdown() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.note("hello");
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | 2 |"));
        assert!(md.contains("> hello"));
        assert_eq!(t.n_rows(), 1);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        Table::new("x", &["a"]).row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn number_formats() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(12345.6), "12346");
        assert_eq!(fnum(42.33), "42.3");
        assert_eq!(fnum(1.234), "1.234");
        assert_eq!(fnum(0.00042), "4.200e-4");
        assert_eq!(fpct(0.937), "93.7%");
    }
}
