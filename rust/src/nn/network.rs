//! The network orchestrator: forward/backward over an architecture's
//! compiled op pipeline, with pluggable parameter sources so the same code
//! path serves the sequential engine (plain `Vec<f32>`) and the CHAOS
//! workers (shared atomic store, read on demand — §4.1 "reads are performed
//! on demand").
//!
//! [`Network::new`] compiles the [`ArchSpec`] into a `Vec<Box<dyn
//! LayerOp>>` through the layer-kind registry ([`super::layer`]); the
//! orchestrator itself is layer-type-blind — it loads each op's parameter
//! span on demand, drives the op's kernels, and emits each layer's
//! gradients through a callback **as soon as that layer's computation
//! finishes** — the hook CHAOS uses to publish non-instant, per-layer
//! updates without waiting for the whole sample (§4.1 "Controlled
//! HogWild").

use super::activation::cross_entropy;
use super::dims::{total_params, try_compute_dims, LayerDims};
use super::layer::{Acts, LayerOp, OpScratch};
use super::simd::MathPolicy;
use crate::config::ArchSpec;
use crate::util::timer::LayerTimes;
use crate::util::Pcg32;
use std::time::Instant;

/// Read access to the flat parameter vector. Implementations copy the
/// requested span into a caller-provided buffer ("read on demand").
pub trait ParamSource {
    fn load(&self, range: std::ops::Range<usize>, buf: &mut [f32]);
}

/// Plain flat vector (sequential engine, tests).
impl ParamSource for &[f32] {
    fn load(&self, range: std::ops::Range<usize>, buf: &mut [f32]) {
        buf.copy_from_slice(&self[range]);
    }
}

impl ParamSource for Vec<f32> {
    fn load(&self, range: std::ops::Range<usize>, buf: &mut [f32]) {
        buf.copy_from_slice(&self[range]);
    }
}

/// A compiled network: architecture, derived geometry, and the executable
/// op pipeline.
#[derive(Debug)]
pub struct Network {
    pub arch: ArchSpec,
    pub dims: Vec<LayerDims>,
    /// Compiled ops, parallel to `dims` (`ops[0]` is the inert input op).
    pub ops: Vec<Box<dyn LayerOp>>,
    pub total_params: usize,
}

impl Clone for Network {
    fn clone(&self) -> Network {
        // Ops are stateless (all mutable state lives in `Scratch`), so a
        // recompile of the same spec is an exact clone.
        Network::compile(self.arch.clone()).expect("previously compiled architecture")
    }
}

impl Network {
    /// Compile an architecture into an executable network, resolving every
    /// layer through the kind registry. Debug builds additionally run the
    /// static span verifier ([`crate::chaos::analysis::verify_network`])
    /// over the compiled op table, so a kind that mis-declares its
    /// parameter span fails at compile time, not as a training-time race.
    pub fn compile(arch: ArchSpec) -> anyhow::Result<Network> {
        let dims = try_compute_dims(&arch)?;
        let mut ops: Vec<Box<dyn LayerOp>> = Vec::with_capacity(dims.len());
        for d in &dims {
            ops.push(super::layer::kind_for(&d.spec)?.compile(&d.spec, d)?);
        }
        let total_params = total_params(&dims);
        let net = Network { arch, dims, ops, total_params };
        #[cfg(debug_assertions)]
        {
            let report = crate::chaos::analysis::verify_network(&net);
            anyhow::ensure!(
                report.is_clean(),
                "span verifier rejected '{}': {}",
                net.arch.name,
                report.to_text()
            );
            // Second static pass: prove the shape chain coherent and the
            // batch arenas exactly-sized, non-overlapping, and on distinct
            // PRNG streams (see [`super::audit`]).
            let flow = super::audit::audit_dataflow(&net);
            anyhow::ensure!(
                flow.is_clean(),
                "dataflow audit rejected '{}': {}",
                net.arch.name,
                flow.to_text()
            );
        }
        Ok(net)
    }

    /// Compile, panicking on an invalid architecture (use
    /// [`Network::compile`] for fallible construction).
    pub fn new(arch: ArchSpec) -> Network {
        Network::compile(arch).expect("invalid architecture")
    }

    pub fn from_name(name: &str) -> anyhow::Result<Network> {
        ArchSpec::by_name(name)
            .map(Network::new)
            .ok_or_else(|| anyhow::anyhow!("unknown architecture '{name}'"))
    }

    /// Deterministic initial parameters.
    pub fn init_params(&self, seed: u64) -> Vec<f32> {
        super::init::init_params(&self.dims, seed)
    }

    pub fn num_classes(&self) -> usize {
        self.dims.last().unwrap().out_maps
    }

    /// Allocate per-worker scratch buffers for this network (PRNG stream 0;
    /// see [`Network::scratch_seeded`]).
    pub fn scratch(&self) -> Scratch {
        self.scratch_seeded(0)
    }

    /// Per-worker scratch with an explicit PRNG seed. Ops that draw
    /// randomness (dropout masks) draw from these thread-private streams,
    /// so every CHAOS worker passes a distinct seed and masks
    /// independently with no shared state.
    pub fn scratch_seeded(&self, seed: u64) -> Scratch {
        let acts: Vec<Vec<f32>> = self.dims.iter().map(|d| vec![0.0; d.out_len()]).collect();
        let aux: Vec<Vec<u32>> = self.ops.iter().map(|op| vec![0u32; op.aux_len()]).collect();
        let rngs: Vec<Pcg32> =
            (0..self.ops.len()).map(|l| Pcg32::new(seed, l as u64)).collect();
        let max_act = self.dims.iter().map(|d| d.out_len()).max().unwrap_or(0);
        let max_params = self.dims.iter().map(|d| d.param_count()).max().unwrap_or(0);
        Scratch {
            acts,
            aux,
            rngs,
            train_mode: false,
            delta_a: vec![0.0; max_act],
            delta_b: vec![0.0; max_act],
            param_buf: vec![0.0; max_params],
            grad_buf: vec![0.0; max_params],
        }
    }

    /// A batched-forward plan over this network (see
    /// [`super::batch::BatchPlan`]): parameters load once per layer per
    /// batch instead of once per image.
    pub fn batch_plan(&self, cap: usize) -> anyhow::Result<super::batch::BatchPlan<'_>> {
        super::batch::BatchPlan::new(self, cap)
    }

    /// Forward-propagate one image; returns the softmax probabilities
    /// (stored in the scratch's last activation buffer).
    pub fn forward<'s, P: ParamSource>(
        &self,
        params: &P,
        image: &[f32],
        scratch: &'s mut Scratch,
        timers: Option<&LayerTimes>,
    ) -> &'s [f32] {
        let n_layers = self.dims.len();
        debug_assert_eq!(image.len(), self.dims[0].out_len(), "input size mismatch");
        scratch.acts[0].copy_from_slice(image);

        for l in 1..n_layers {
            let d = &self.dims[l];
            let op = &self.ops[l];
            let t0 = timers.map(|_| Instant::now());
            let pc = d.param_count();
            let pbuf = &mut scratch.param_buf[..pc];
            if pc > 0 {
                params.load(d.params.clone(), pbuf);
            }
            // Split so we can borrow acts[l-1] and acts[l] simultaneously.
            let (prev_acts, rest) = scratch.acts.split_at_mut(l);
            op.forward(
                &scratch.param_buf[..pc],
                &prev_acts[l - 1],
                &mut rest[0],
                &mut OpScratch {
                    aux: &mut scratch.aux[l],
                    rng: &mut scratch.rngs[l],
                    train: scratch.train_mode,
                    // Per-sample kernels are the exact reference order and
                    // never stage through an im2col panel.
                    math: MathPolicy::Exact,
                    col: &mut [],
                },
            );
            if let (Some(t), Some(start)) = (timers, t0) {
                t.add(op.class(false), start.elapsed().as_nanos() as u64);
            }
        }
        &scratch.acts[n_layers - 1]
    }

    /// Cross-entropy loss of the last forward pass.
    pub fn loss(&self, scratch: &Scratch, label: usize) -> f32 {
        cross_entropy(scratch.acts.last().unwrap(), label)
    }

    /// Predicted class of the last forward pass.
    pub fn prediction(&self, scratch: &Scratch) -> usize {
        crate::tensor::argmax(scratch.acts.last().unwrap())
    }

    /// Back-propagate from the last forward pass. For each parameterized
    /// layer, `on_grads(layer_index, dims, grads)` is invoked right after
    /// that layer's gradients are complete (back-to-front order) — grads is
    /// the flat `[weights..., biases...]` gradient of this sample.
    /// (The batched equivalent over whole chunks is
    /// [`super::batch::BatchPlan::backward`], bit-identical to accumulating
    /// per-sample calls.)
    pub fn backward<P: ParamSource>(
        &self,
        params: &P,
        label: usize,
        scratch: &mut Scratch,
        timers: Option<&LayerTimes>,
        mut on_grads: impl FnMut(usize, &LayerDims, &[f32]),
    ) {
        let n_layers = self.dims.len();
        debug_assert!(label < self.num_classes());

        // Delta at the output layer: softmax + cross-entropy ⇒ p − onehot
        // (already the pre-activation delta — the output op's contract).
        {
            let probs = scratch.acts.last().unwrap();
            let delta = &mut scratch.delta_a[..probs.len()];
            delta.copy_from_slice(probs);
            delta[label] -= 1.0;
        }

        // Walking back: on entry to layer l, `delta_a[..d.out_len()]` holds
        // ∂L/∂(output of layer l); the op converts to its pre-activation
        // delta itself and writes ∂L/∂(its input) into `delta_b`.
        for l in (1..n_layers).rev() {
            let d = &self.dims[l];
            let op = &self.ops[l];
            let t0 = timers.map(|_| Instant::now());
            let is_first = l == 1; // layer below is the input layer
            let pc = d.param_count();
            let pbuf = &mut scratch.param_buf[..pc];
            if pc > 0 {
                params.load(d.params.clone(), pbuf);
            }
            scratch.grad_buf[..pc].fill(0.0);
            let delta_in: &mut [f32] =
                if is_first { &mut [] } else { &mut scratch.delta_b[..d.in_len()] };
            op.backward(
                &scratch.param_buf[..pc],
                Acts { input: &scratch.acts[l - 1], output: &scratch.acts[l] },
                &mut scratch.delta_a[..d.out_len()],
                delta_in,
                &mut scratch.grad_buf[..pc],
                &mut OpScratch {
                    aux: &mut scratch.aux[l],
                    rng: &mut scratch.rngs[l],
                    train: scratch.train_mode,
                    math: MathPolicy::Exact,
                    col: &mut [],
                },
            );
            if pc > 0 {
                on_grads(l, d, &scratch.grad_buf[..pc]);
            }
            if !is_first {
                std::mem::swap(&mut scratch.delta_a, &mut scratch.delta_b);
            }

            if let (Some(t), Some(start)) = (timers, t0) {
                t.add(op.class(true), start.elapsed().as_nanos() as u64);
            }
        }
    }

    /// Convenience: forward + backward one labelled image against a plain
    /// parameter vector, applying the SGD update in place. Returns
    /// (loss, correct). This is the sequential per-sample step.
    pub fn sgd_step(
        &self,
        params: &mut Vec<f32>,
        image: &[f32],
        label: usize,
        eta: f32,
        scratch: &mut Scratch,
        timers: Option<&LayerTimes>,
    ) -> (f32, bool) {
        // Reads (layer loads) and writes (per-layer SGD updates) interleave
        // during backward — exactly the paper's scheme, where local weights
        // are updated instantly. Both go through one raw pointer so the
        // aliasing provenance is shared; single-threaded, and within a layer
        // the load always happens before the callback's write.
        let ptr = params.as_mut_ptr();
        let len = params.len();
        let src = ParamsPtr(ptr, len);
        let was_training = scratch.train_mode;
        scratch.train_mode = true;
        let probs = self.forward(&src, image, scratch, timers);
        let loss = cross_entropy(probs, label);
        let correct = crate::tensor::argmax(probs) == label;
        self.backward(&src, label, scratch, timers, |_, d, grads| {
            debug_assert!(d.params.end <= len);
            // SAFETY: `ptr` points at `params`, a Vec<f32> exclusively
            // borrowed for the whole call, and `sgd_step` is
            // single-threaded, so no other reference is live while this
            // slice exists: the only reads through the same provenance
            // (`ParamsPtr::load`) happen between callbacks, never during
            // one. `d.params` is in bounds: spans are verified at compile
            // (`analysis::verify_spans`) and `d.params.end <= len` is
            // asserted above.
            let dst = unsafe {
                std::slice::from_raw_parts_mut(ptr.add(d.params.start), d.params.len())
            };
            for (w, g) in dst.iter_mut().zip(grads) {
                *w -= eta * g;
            }
        });
        scratch.train_mode = was_training;
        (loss, correct)
    }
}

/// Raw-pointer parameter source used by `sgd_step` to allow in-place
/// updates between layer computations (mirrors the paper's instant local
/// updates). Safe because `sgd_step` is single-threaded and the network
/// loads each layer's parameters before its callback runs.
struct ParamsPtr(*mut f32, usize);

impl ParamSource for ParamsPtr {
    fn load(&self, range: std::ops::Range<usize>, buf: &mut [f32]) {
        debug_assert!(range.end <= self.1);
        // SAFETY: `self.0` points at the parameter Vec exclusively
        // borrowed by `sgd_step` (single-threaded), and no mutable slice
        // from the update callback is live while this load runs — loads
        // happen between callbacks. `range` is a verified layer span with
        // `range.end <= self.1`, the Vec's length, so the read stays in
        // bounds. The shared slice is dropped before this function
        // returns.
        let src = unsafe { std::slice::from_raw_parts(self.0.add(range.start), range.len()) };
        buf.copy_from_slice(src);
    }
}

/// Per-worker mutable state: activations, per-op auxiliary words (pool
/// switches, dropout masks), per-op PRNG streams, delta ping-pong buffers,
/// and staging buffers for on-demand parameter reads and per-layer gradient
/// accumulation. Everything here is thread-private in CHAOS (§4.2(5):
/// "most of the variables thread private to achieve data locality").
#[derive(Debug, Clone)]
pub struct Scratch {
    pub acts: Vec<Vec<f32>>,
    /// Auxiliary per-op `u32` words (see [`LayerOp::aux_len`]).
    pub aux: Vec<Vec<u32>>,
    /// Per-op thread-private PRNG streams (dropout masks).
    pub rngs: Vec<Pcg32>,
    /// Whether forward/backward run as a training pass (dropout masks
    /// active). `sgd_step` and the trainer's workers set this; evaluation
    /// leaves it false.
    pub train_mode: bool,
    delta_a: Vec<f32>,
    delta_b: Vec<f32>,
    param_buf: Vec<f32>,
    grad_buf: Vec<f32>,
}

impl Scratch {
    /// Probabilities of the last forward pass.
    pub fn probs(&self) -> &[f32] {
        self.acts.last().unwrap()
    }

    /// Reset every per-op PRNG stream to a fixed seed — a fixed-mask knob
    /// for tests (gradient checks reseed before every forward so dropout
    /// draws the same mask).
    pub fn reseed(&mut self, seed: u64) {
        for (l, rng) in self.rngs.iter_mut().enumerate() {
            *rng = Pcg32::new(seed, l as u64);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Act, ArchSpec, LayerSpec};
    use crate::util::Pcg32;

    fn tiny_arch() -> ArchSpec {
        ArchSpec::tiny()
    }

    fn rand_image(rng: &mut Pcg32, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.uniform(-1.0, 1.0)).collect()
    }

    #[test]
    fn forward_produces_distribution() {
        let net = Network::new(tiny_arch());
        let params = net.init_params(3);
        let mut scratch = net.scratch();
        let mut rng = Pcg32::seeded(4);
        let img = rand_image(&mut rng, 13 * 13);
        let probs = net.forward(&params.as_slice(), &img, &mut scratch, None);
        assert_eq!(probs.len(), 10);
        let sum: f32 = probs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-5, "softmax sums to 1, got {sum}");
        assert!(probs.iter().all(|&p| p >= 0.0));
    }

    #[test]
    fn compiled_ops_mirror_dims() {
        for name in ["tiny", "small", "medium", "large"] {
            let net = Network::from_name(name).unwrap();
            assert_eq!(net.ops.len(), net.dims.len());
            for (op, d) in net.ops.iter().zip(&net.dims).skip(1) {
                assert_eq!(op.param_range(), d.params, "{name}: {}", op.kind());
                assert_eq!(op.out_shape().len(), d.out_len(), "{name}: {}", op.kind());
            }
        }
    }

    /// Smallest network that drives both raw-pointer sites in this file —
    /// `ParamsPtr::load` and the in-place update slice in [`Network::sgd_step`]
    /// — through a complete forward/backward step. Sized for Miri (the CI
    /// aliasing job runs exactly this test), where the paper architectures
    /// would take minutes.
    #[test]
    fn sgd_step_aliasing_smoke() {
        let arch = ArchSpec {
            name: "micro".into(),
            layers: vec![
                LayerSpec::Input { side: 4 },
                LayerSpec::fc(3),
                LayerSpec::Output { classes: 2 },
            ],
            paper_epochs: 1,
        };
        let net = Network::new(arch);
        let mut params = net.init_params(11);
        let mut scratch = net.scratch();
        let mut rng = Pcg32::seeded(7);
        let img = rand_image(&mut rng, 16);
        let (loss1, _) = net.sgd_step(&mut params, &img, 1, 0.5, &mut scratch, None);
        let (loss2, _) = net.sgd_step(&mut params, &img, 1, 0.5, &mut scratch, None);
        assert!(loss1.is_finite() && loss2.is_finite());
        assert!(loss2 < loss1, "repeated step on one sample reduces its loss");
    }

    #[test]
    fn full_network_gradcheck() {
        // The decisive correctness test: analytic gradients of the complete
        // stack (conv/pool/tanh/fc/softmax-CE) against central differences.
        let net = Network::new(tiny_arch());
        let mut params = net.init_params(7);
        let mut scratch = net.scratch();
        let mut rng = Pcg32::seeded(8);
        let img = rand_image(&mut rng, 13 * 13);
        let label = 3usize;

        net.forward(&params.as_slice(), &img, &mut scratch, None);
        let mut analytic = vec![0.0f32; net.total_params];
        net.backward(&params.as_slice(), label, &mut scratch, None, |_, d, grads| {
            analytic[d.params.clone()].copy_from_slice(grads);
        });

        let loss_of = |p: &[f32], scratch: &mut Scratch| -> f64 {
            net.forward(&p, &img, scratch, None);
            net.loss(scratch, label) as f64
        };
        let h = 1e-3f32;
        let mut rng2 = Pcg32::seeded(99);
        let mut checked = 0;
        // Sample parameters from every parameterized layer.
        for d in net.dims.clone() {
            if d.param_count() == 0 {
                continue;
            }
            for _ in 0..6 {
                let idx = d.params.start + rng2.range(0, d.param_count());
                let orig = params[idx];
                params[idx] = orig + h;
                let lp = loss_of(params.as_slice(), &mut scratch);
                params[idx] = orig - h;
                let lm = loss_of(params.as_slice(), &mut scratch);
                params[idx] = orig;
                let fd = ((lp - lm) / (2.0 * h as f64)) as f32;
                let an = analytic[idx];
                // Pool argmax ties can make FD noisy; tolerance is loose
                // but catches sign/structure errors decisively.
                assert!(
                    (fd - an).abs() < 5e-3 + 0.05 * fd.abs().max(an.abs()),
                    "param {idx} (layer {:?}): fd={fd} analytic={an}",
                    d.spec
                );
                checked += 1;
            }
        }
        assert!(checked >= 24);
    }

    #[test]
    fn full_network_gradcheck_mixed_new_ops() {
        // Gradcheck over an architecture exercising every op the open API
        // shipped with: padded + strided conv, ReLU activations (conv and
        // fc), average pooling, and dropout with a fixed mask.
        let arch = ArchSpec {
            name: "mixed".into(),
            layers: vec![
                LayerSpec::Input { side: 13 },
                LayerSpec::conv_ex(5, 4, 1, 1, Act::Relu), // (13+2-4)+1 = 12
                LayerSpec::AvgPool { kernel: 2 },          // 6
                LayerSpec::conv_ex(6, 2, 2, 0, Act::ScaledTanh), // (6-2)/2+1 = 3
                LayerSpec::Dropout { rate: 0.3 },          // 3
                LayerSpec::fc_act(12, Act::Relu),
                LayerSpec::Output { classes: 10 },
            ],
            paper_epochs: 1,
        };
        let net = Network::new(arch);
        let mut params = net.init_params(11);
        let mut scratch = net.scratch();
        // Train mode with a reseed before every pass → the dropout mask is
        // fixed across the analytic and both finite-difference passes.
        scratch.train_mode = true;
        let mut rng = Pcg32::seeded(12);
        let img = rand_image(&mut rng, 13 * 13);
        let label = 6usize;

        scratch.reseed(0xA5);
        net.forward(&params.as_slice(), &img, &mut scratch, None);
        let mut analytic = vec![0.0f32; net.total_params];
        net.backward(&params.as_slice(), label, &mut scratch, None, |_, d, grads| {
            analytic[d.params.clone()].copy_from_slice(grads);
        });

        let h = 1e-3f32;
        let mut rng2 = Pcg32::seeded(77);
        let mut checked = 0;
        for d in net.dims.clone() {
            if d.param_count() == 0 {
                continue;
            }
            for _ in 0..8 {
                let idx = d.params.start + rng2.range(0, d.param_count());
                let orig = params[idx];
                params[idx] = orig + h;
                scratch.reseed(0xA5);
                net.forward(&params.as_slice(), &img, &mut scratch, None);
                let lp = net.loss(&scratch, label) as f64;
                params[idx] = orig - h;
                scratch.reseed(0xA5);
                net.forward(&params.as_slice(), &img, &mut scratch, None);
                let lm = net.loss(&scratch, label) as f64;
                params[idx] = orig;
                let fd = ((lp - lm) / (2.0 * h as f64)) as f32;
                let an = analytic[idx];
                // ReLU kinks near zero make FD noisier than the tanh net.
                assert!(
                    (fd - an).abs() < 6e-3 + 0.06 * fd.abs().max(an.abs()),
                    "param {idx} (layer {:?}): fd={fd} analytic={an}",
                    d.spec
                );
                checked += 1;
            }
        }
        assert!(checked >= 32);
    }

    #[test]
    fn dropout_is_identity_outside_training() {
        let arch = ArchSpec {
            name: "drop".into(),
            layers: vec![
                LayerSpec::Input { side: 6 },
                LayerSpec::conv(2, 3), // 4x4
                LayerSpec::Dropout { rate: 0.5 },
                LayerSpec::Output { classes: 10 },
            ],
            paper_epochs: 1,
        };
        let net = Network::new(arch);
        let params = net.init_params(1);
        let mut scratch = net.scratch();
        let mut rng = Pcg32::seeded(2);
        let img = rand_image(&mut rng, 36);
        // Eval mode: two passes agree bitwise (no stochastic masking) and
        // dropout passes activations through unchanged.
        let p1 = net.forward(&params.as_slice(), &img, &mut scratch, None).to_vec();
        assert_eq!(scratch.acts[1], scratch.acts[2], "eval dropout must be identity");
        let p2 = net.forward(&params.as_slice(), &img, &mut scratch, None).to_vec();
        assert_eq!(p1, p2);
        // Train mode: some activations are dropped, survivors are scaled.
        scratch.train_mode = true;
        net.forward(&params.as_slice(), &img, &mut scratch, None);
        let dropped = scratch.acts[2].iter().filter(|&&v| v == 0.0).count();
        assert!(dropped > 0, "rate-0.5 dropout should zero something over 16 values");
        for (y, x) in scratch.acts[2].iter().zip(&scratch.acts[1]) {
            assert!(*y == 0.0 || (*y - x * 2.0).abs() < 1e-6, "survivor not scaled by 1/(1-p)");
        }
    }

    #[test]
    fn worker_seeds_give_independent_dropout_masks() {
        let arch = ArchSpec {
            name: "drop".into(),
            layers: vec![
                LayerSpec::Input { side: 6 },
                LayerSpec::conv(3, 3), // 4x4 x3 maps
                LayerSpec::Dropout { rate: 0.5 },
                LayerSpec::Output { classes: 10 },
            ],
            paper_epochs: 1,
        };
        let net = Network::new(arch);
        let params = net.init_params(1);
        let mut rng = Pcg32::seeded(9);
        let img = rand_image(&mut rng, 36);
        let mask_of = |seed: u64| {
            let mut s = net.scratch_seeded(seed);
            s.train_mode = true;
            net.forward(&params.as_slice(), &img, &mut s, None);
            s.aux[2].clone()
        };
        assert_eq!(mask_of(1), mask_of(1), "same seed → same mask");
        assert_ne!(mask_of(1), mask_of(2), "different workers → different masks");
    }

    #[test]
    fn sgd_step_reduces_loss_on_repeated_sample() {
        let net = Network::new(tiny_arch());
        let mut params = net.init_params(5);
        let mut scratch = net.scratch();
        let mut rng = Pcg32::seeded(10);
        let img = rand_image(&mut rng, 13 * 13);
        let label = 7usize;
        let (first_loss, _) = net.sgd_step(&mut params, &img, label, 0.05, &mut scratch, None);
        let mut last = first_loss;
        for _ in 0..30 {
            let (l, _) = net.sgd_step(&mut params, &img, label, 0.05, &mut scratch, None);
            last = l;
        }
        assert!(
            last < first_loss * 0.5,
            "loss should collapse when overfitting one sample: {first_loss} -> {last}"
        );
    }

    #[test]
    fn grads_emitted_back_to_front_for_all_param_layers() {
        let net = Network::new(tiny_arch());
        let params = net.init_params(2);
        let mut scratch = net.scratch();
        let mut rng = Pcg32::seeded(1);
        let img = rand_image(&mut rng, 13 * 13);
        net.forward(&params.as_slice(), &img, &mut scratch, None);
        let mut order = Vec::new();
        net.backward(&params.as_slice(), 0, &mut scratch, None, |l, _, _| order.push(l));
        assert_eq!(order, vec![6, 5, 3, 1], "output, fc, conv2, conv1");
    }

    #[test]
    fn timers_populate_all_classes() {
        let net = Network::new(tiny_arch());
        let params = net.init_params(2);
        let mut scratch = net.scratch();
        let timers = LayerTimes::new();
        let mut rng = Pcg32::seeded(1);
        let img = rand_image(&mut rng, 13 * 13);
        net.forward(&params.as_slice(), &img, &mut scratch, Some(&timers));
        net.backward(&params.as_slice(), 1, &mut scratch, Some(&timers), |_, _, _| {});
        use crate::util::timer::LayerClass as LC;
        for c in [
            LC::ConvForward,
            LC::ConvBackward,
            LC::PoolForward,
            LC::PoolBackward,
            LC::FcForward,
            LC::FcBackward,
            LC::OutputForward,
            LC::OutputBackward,
        ] {
            assert!(timers.get_secs(c) > 0.0, "no time recorded for {:?}", c);
        }
    }

    #[test]
    fn paper_architectures_run_end_to_end() {
        let mut rng = Pcg32::seeded(6);
        let img = rand_image(&mut rng, 29 * 29);
        for name in crate::config::PAPER_ARCHS {
            let net = Network::from_name(name).unwrap();
            let mut params = net.init_params(1);
            let mut scratch = net.scratch();
            let (loss, _) = net.sgd_step(&mut params, &img, 4, 0.001, &mut scratch, None);
            assert!(loss.is_finite(), "{name}: non-finite loss");
            assert!(loss > 0.0);
        }
    }
}
