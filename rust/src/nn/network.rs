//! The network orchestrator: forward/backward over an architecture's layer
//! stack, with pluggable parameter sources so the same code path serves
//! the sequential engine (plain `Vec<f32>`) and the CHAOS workers (shared
//! atomic store, read on demand — §4.1 "reads are performed on demand").
//!
//! Backward emits each layer's gradients through a callback **as soon as
//! that layer's computation finishes** — the hook CHAOS uses to publish
//! non-instant, per-layer updates without waiting for the whole sample
//! (§4.1 "Controlled HogWild").

use super::activation::{
    apply_scaled_tanh, cross_entropy, scaled_tanh_deriv_from_y, softmax,
};
use super::conv::{conv_backward, conv_forward, ConvShape};
use super::dims::{compute_dims, total_params, LayerDims};
use super::fc::{fc_backward, fc_forward, FcShape};
use super::pool::{pool_backward, pool_forward, PoolShape};
use crate::config::{ArchSpec, LayerSpec};
use crate::util::timer::{LayerClass, LayerTimes};
use std::time::Instant;

/// Read access to the flat parameter vector. Implementations copy the
/// requested span into a caller-provided buffer ("read on demand").
pub trait ParamSource {
    fn load(&self, range: std::ops::Range<usize>, buf: &mut [f32]);
}

/// Plain flat vector (sequential engine, tests).
impl ParamSource for &[f32] {
    fn load(&self, range: std::ops::Range<usize>, buf: &mut [f32]) {
        buf.copy_from_slice(&self[range]);
    }
}

impl ParamSource for Vec<f32> {
    fn load(&self, range: std::ops::Range<usize>, buf: &mut [f32]) {
        buf.copy_from_slice(&self[range]);
    }
}

/// A compiled network: architecture plus derived geometry.
#[derive(Debug, Clone)]
pub struct Network {
    pub arch: ArchSpec,
    pub dims: Vec<LayerDims>,
    pub total_params: usize,
}

impl Network {
    pub fn new(arch: ArchSpec) -> Network {
        let dims = compute_dims(&arch);
        let total_params = total_params(&dims);
        Network { arch, dims, total_params }
    }

    pub fn from_name(name: &str) -> anyhow::Result<Network> {
        ArchSpec::by_name(name)
            .map(Network::new)
            .ok_or_else(|| anyhow::anyhow!("unknown architecture '{name}'"))
    }

    /// Deterministic initial parameters.
    pub fn init_params(&self, seed: u64) -> Vec<f32> {
        super::init::init_params(&self.dims, seed)
    }

    pub fn num_classes(&self) -> usize {
        self.dims.last().unwrap().out_maps
    }

    /// Allocate per-worker scratch buffers for this network.
    pub fn scratch(&self) -> Scratch {
        let acts: Vec<Vec<f32>> = self.dims.iter().map(|d| vec![0.0; d.out_len()]).collect();
        let switches: Vec<Vec<u32>> = self
            .dims
            .iter()
            .map(|d| match d.spec {
                LayerSpec::MaxPool { .. } => vec![0u32; d.out_len()],
                _ => Vec::new(),
            })
            .collect();
        let max_act = self.dims.iter().map(|d| d.out_len()).max().unwrap_or(0);
        let max_params = self.dims.iter().map(|d| d.param_count()).max().unwrap_or(0);
        Scratch {
            acts,
            switches,
            delta_a: vec![0.0; max_act],
            delta_b: vec![0.0; max_act],
            param_buf: vec![0.0; max_params],
            grad_buf: vec![0.0; max_params],
        }
    }

    /// Forward-propagate one image; returns the softmax probabilities
    /// (stored in the scratch's last activation buffer).
    pub fn forward<'s, P: ParamSource>(
        &self,
        params: &P,
        image: &[f32],
        scratch: &'s mut Scratch,
        timers: Option<&LayerTimes>,
    ) -> &'s [f32] {
        let n_layers = self.dims.len();
        debug_assert_eq!(image.len(), self.dims[0].out_len(), "input size mismatch");
        scratch.acts[0].copy_from_slice(image);

        for l in 1..n_layers {
            let d = &self.dims[l];
            let t0 = timers.map(|_| Instant::now());
            // Split so we can borrow acts[l-1] and acts[l] simultaneously.
            let (prev_acts, rest) = scratch.acts.split_at_mut(l);
            let input = &prev_acts[l - 1];
            let out = &mut rest[0];
            let class = match d.spec {
                LayerSpec::Input { .. } => unreachable!("input after layer 0"),
                LayerSpec::Conv { maps, kernel } => {
                    let shape = ConvShape {
                        in_maps: d.in_maps,
                        in_side: d.in_side,
                        out_maps: maps,
                        out_side: d.out_side,
                        kernel,
                    };
                    let pbuf = &mut scratch.param_buf[..d.param_count()];
                    params.load(d.params.clone(), pbuf);
                    let (w, b) = pbuf.split_at(d.weights);
                    conv_forward(&shape, input, w, b, out);
                    apply_scaled_tanh(out);
                    LayerClass::ConvForward
                }
                LayerSpec::MaxPool { kernel } => {
                    let shape = PoolShape {
                        maps: d.in_maps,
                        in_side: d.in_side,
                        out_side: d.out_side,
                        kernel,
                    };
                    pool_forward(&shape, input, out, &mut scratch.switches[l]);
                    LayerClass::PoolForward
                }
                LayerSpec::FullyConnected { neurons } => {
                    let shape = FcShape { inputs: d.in_maps, outputs: neurons };
                    let pbuf = &mut scratch.param_buf[..d.param_count()];
                    params.load(d.params.clone(), pbuf);
                    let (w, b) = pbuf.split_at(d.weights);
                    fc_forward(&shape, input, w, b, out);
                    apply_scaled_tanh(out);
                    LayerClass::FcForward
                }
                LayerSpec::Output { classes } => {
                    let shape = FcShape { inputs: d.in_maps, outputs: classes };
                    let pbuf = &mut scratch.param_buf[..d.param_count()];
                    params.load(d.params.clone(), pbuf);
                    let (w, b) = pbuf.split_at(d.weights);
                    fc_forward(&shape, input, w, b, out);
                    softmax(out);
                    LayerClass::OutputForward
                }
            };
            if let (Some(t), Some(start)) = (timers, t0) {
                t.add(class, start.elapsed().as_nanos() as u64);
            }
        }
        &scratch.acts[n_layers - 1]
    }

    /// Cross-entropy loss of the last forward pass.
    pub fn loss(&self, scratch: &Scratch, label: usize) -> f32 {
        cross_entropy(scratch.acts.last().unwrap(), label)
    }

    /// Predicted class of the last forward pass.
    pub fn prediction(&self, scratch: &Scratch) -> usize {
        crate::tensor::argmax(scratch.acts.last().unwrap())
    }

    /// Back-propagate from the last forward pass. For each parameterized
    /// layer, `on_grads(layer_index, dims, grads)` is invoked right after
    /// that layer's gradients are complete (back-to-front order) — grads is
    /// the flat `[weights..., biases...]` gradient of this sample.
    pub fn backward<P: ParamSource>(
        &self,
        params: &P,
        label: usize,
        scratch: &mut Scratch,
        timers: Option<&LayerTimes>,
        mut on_grads: impl FnMut(usize, &LayerDims, &[f32]),
    ) {
        let n_layers = self.dims.len();
        debug_assert!(label < self.num_classes());

        // delta at the output layer: softmax + cross-entropy ⇒ p − onehot
        {
            let probs = scratch.acts.last().unwrap();
            let delta = &mut scratch.delta_a[..probs.len()];
            delta.copy_from_slice(probs);
            delta[label] -= 1.0;
        }

        // Walking back: `delta_a[..d.out_len()]` holds ∂L/∂(pre-activation)
        // for conv/fc/output layers and ∂L/∂(output) for pool layers.
        for l in (1..n_layers).rev() {
            let d = self.dims[l].clone();
            let t0 = timers.map(|_| Instant::now());
            let is_first = l == 1; // layer below is the input layer
            let input_len = d.in_len();

            let class = match d.spec {
                LayerSpec::Input { .. } => unreachable!(),
                LayerSpec::Conv { maps, kernel } => {
                    let shape = ConvShape {
                        in_maps: d.in_maps,
                        in_side: d.in_side,
                        out_maps: maps,
                        out_side: d.out_side,
                        kernel,
                    };
                    let pbuf = &mut scratch.param_buf[..d.param_count()];
                    params.load(d.params.clone(), pbuf);
                    let (w, _b) = pbuf.split_at(d.weights);
                    let gbuf = &mut scratch.grad_buf[..d.param_count()];
                    gbuf.fill(0.0);
                    let (wg, bg) = gbuf.split_at_mut(d.weights);
                    let delta = &scratch.delta_a[..d.out_len()];
                    let dinput: &mut [f32] = if is_first {
                        &mut []
                    } else {
                        &mut scratch.delta_b[..input_len]
                    };
                    conv_backward(&shape, &scratch.acts[l - 1], w, delta, wg, bg, dinput);
                    on_grads(l, &d, &scratch.grad_buf[..d.param_count()]);
                    LayerClass::ConvBackward
                }
                LayerSpec::MaxPool { kernel } => {
                    let shape = PoolShape {
                        maps: d.in_maps,
                        in_side: d.in_side,
                        out_side: d.out_side,
                        kernel,
                    };
                    let delta = &scratch.delta_a[..d.out_len()];
                    pool_backward(
                        &shape,
                        delta,
                        &scratch.switches[l],
                        &mut scratch.delta_b[..input_len],
                    );
                    LayerClass::PoolBackward
                }
                LayerSpec::FullyConnected { neurons } | LayerSpec::Output { classes: neurons } => {
                    let shape = FcShape { inputs: d.in_maps, outputs: neurons };
                    let pbuf = &mut scratch.param_buf[..d.param_count()];
                    params.load(d.params.clone(), pbuf);
                    let (w, _b) = pbuf.split_at(d.weights);
                    let gbuf = &mut scratch.grad_buf[..d.param_count()];
                    gbuf.fill(0.0);
                    let (wg, bg) = gbuf.split_at_mut(d.weights);
                    let delta = &scratch.delta_a[..d.out_len()];
                    let dinput: &mut [f32] = if is_first {
                        &mut []
                    } else {
                        &mut scratch.delta_b[..input_len]
                    };
                    fc_backward(&shape, &scratch.acts[l - 1], w, delta, wg, bg, dinput);
                    on_grads(l, &d, &scratch.grad_buf[..d.param_count()]);
                    if matches!(d.spec, LayerSpec::Output { .. }) {
                        LayerClass::OutputBackward
                    } else {
                        LayerClass::FcBackward
                    }
                }
            };

            // Convert ∂L/∂(output of layer l−1) into ∂L/∂(pre-activation)
            // when layer l−1 owns a tanh; pools pass through unchanged.
            if !is_first {
                let prev_spec = self.dims[l - 1].spec;
                let prev_has_tanh = matches!(
                    prev_spec,
                    LayerSpec::Conv { .. } | LayerSpec::FullyConnected { .. }
                );
                if prev_has_tanh {
                    let prev_acts = &scratch.acts[l - 1];
                    let din = &mut scratch.delta_b[..input_len];
                    for (dv, &y) in din.iter_mut().zip(prev_acts.iter()) {
                        *dv *= scaled_tanh_deriv_from_y(y);
                    }
                }
                std::mem::swap(&mut scratch.delta_a, &mut scratch.delta_b);
            }

            if let (Some(t), Some(start)) = (timers, t0) {
                t.add(class, start.elapsed().as_nanos() as u64);
            }
        }
    }

    /// Convenience: forward + backward one labelled image against a plain
    /// parameter vector, applying the SGD update in place. Returns
    /// (loss, correct). This is the sequential per-sample step.
    pub fn sgd_step(
        &self,
        params: &mut Vec<f32>,
        image: &[f32],
        label: usize,
        eta: f32,
        scratch: &mut Scratch,
        timers: Option<&LayerTimes>,
    ) -> (f32, bool) {
        // Reads (layer loads) and writes (per-layer SGD updates) interleave
        // during backward — exactly the paper's scheme, where local weights
        // are updated instantly. Both go through one raw pointer so the
        // aliasing provenance is shared; single-threaded, and within a layer
        // the load always happens before the callback's write.
        let ptr = params.as_mut_ptr();
        let len = params.len();
        let src = ParamsPtr(ptr, len);
        let probs = self.forward(&src, image, scratch, timers);
        let loss = cross_entropy(probs, label);
        let correct = crate::tensor::argmax(probs) == label;
        self.backward(&src, label, scratch, timers, |_, d, grads| {
            debug_assert!(d.params.end <= len);
            // Safety: see above — exclusive single-threaded access.
            let dst = unsafe {
                std::slice::from_raw_parts_mut(ptr.add(d.params.start), d.params.len())
            };
            for (w, g) in dst.iter_mut().zip(grads) {
                *w -= eta * g;
            }
        });
        (loss, correct)
    }
}

/// Raw-pointer parameter source used by `sgd_step` to allow in-place
/// updates between layer computations (mirrors the paper's instant local
/// updates). Safe because `sgd_step` is single-threaded and the network
/// loads each layer's parameters before its callback runs.
struct ParamsPtr(*mut f32, usize);

impl ParamSource for ParamsPtr {
    fn load(&self, range: std::ops::Range<usize>, buf: &mut [f32]) {
        debug_assert!(range.end <= self.1);
        let src = unsafe { std::slice::from_raw_parts(self.0.add(range.start), range.len()) };
        buf.copy_from_slice(src);
    }
}

/// Per-worker mutable state: activations, pool switches, delta ping-pong
/// buffers, and staging buffers for on-demand parameter reads and per-layer
/// gradient accumulation. Everything here is thread-private in CHAOS
/// (§4.2(5): "most of the variables thread private to achieve data
/// locality").
#[derive(Debug, Clone)]
pub struct Scratch {
    pub acts: Vec<Vec<f32>>,
    pub switches: Vec<Vec<u32>>,
    delta_a: Vec<f32>,
    delta_b: Vec<f32>,
    param_buf: Vec<f32>,
    grad_buf: Vec<f32>,
}

impl Scratch {
    /// Probabilities of the last forward pass.
    pub fn probs(&self) -> &[f32] {
        self.acts.last().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchSpec;
    use crate::util::Pcg32;

    fn tiny_arch() -> ArchSpec {
        ArchSpec::tiny()
    }

    fn rand_image(rng: &mut Pcg32, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.uniform(-1.0, 1.0)).collect()
    }

    #[test]
    fn forward_produces_distribution() {
        let net = Network::new(tiny_arch());
        let params = net.init_params(3);
        let mut scratch = net.scratch();
        let mut rng = Pcg32::seeded(4);
        let img = rand_image(&mut rng, 13 * 13);
        let probs = net.forward(&params.as_slice(), &img, &mut scratch, None);
        assert_eq!(probs.len(), 10);
        let sum: f32 = probs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-5, "softmax sums to 1, got {sum}");
        assert!(probs.iter().all(|&p| p >= 0.0));
    }

    #[test]
    fn full_network_gradcheck() {
        // The decisive correctness test: analytic gradients of the complete
        // stack (conv/pool/tanh/fc/softmax-CE) against central differences.
        let net = Network::new(tiny_arch());
        let mut params = net.init_params(7);
        let mut scratch = net.scratch();
        let mut rng = Pcg32::seeded(8);
        let img = rand_image(&mut rng, 13 * 13);
        let label = 3usize;

        net.forward(&params.as_slice(), &img, &mut scratch, None);
        let mut analytic = vec![0.0f32; net.total_params];
        net.backward(&params.as_slice(), label, &mut scratch, None, |_, d, grads| {
            analytic[d.params.clone()].copy_from_slice(grads);
        });

        let loss_of = |p: &[f32], scratch: &mut Scratch| -> f64 {
            net.forward(&p, &img, scratch, None);
            net.loss(scratch, label) as f64
        };
        let h = 1e-3f32;
        let mut rng2 = Pcg32::seeded(99);
        let mut checked = 0;
        // Sample parameters from every parameterized layer.
        for d in net.dims.clone() {
            if d.param_count() == 0 {
                continue;
            }
            for _ in 0..6 {
                let idx = d.params.start + rng2.range(0, d.param_count());
                let orig = params[idx];
                params[idx] = orig + h;
                let lp = loss_of(params.as_slice(), &mut scratch);
                params[idx] = orig - h;
                let lm = loss_of(params.as_slice(), &mut scratch);
                params[idx] = orig;
                let fd = ((lp - lm) / (2.0 * h as f64)) as f32;
                let an = analytic[idx];
                // Pool argmax ties can make FD noisy; tolerance is loose
                // but catches sign/structure errors decisively.
                assert!(
                    (fd - an).abs() < 5e-3 + 0.05 * fd.abs().max(an.abs()),
                    "param {idx} (layer {:?}): fd={fd} analytic={an}",
                    d.spec
                );
                checked += 1;
            }
        }
        assert!(checked >= 24);
    }

    #[test]
    fn sgd_step_reduces_loss_on_repeated_sample() {
        let net = Network::new(tiny_arch());
        let mut params = net.init_params(5);
        let mut scratch = net.scratch();
        let mut rng = Pcg32::seeded(10);
        let img = rand_image(&mut rng, 13 * 13);
        let label = 7usize;
        let (first_loss, _) = net.sgd_step(&mut params, &img, label, 0.05, &mut scratch, None);
        let mut last = first_loss;
        for _ in 0..30 {
            let (l, _) = net.sgd_step(&mut params, &img, label, 0.05, &mut scratch, None);
            last = l;
        }
        assert!(
            last < first_loss * 0.5,
            "loss should collapse when overfitting one sample: {first_loss} -> {last}"
        );
    }

    #[test]
    fn grads_emitted_back_to_front_for_all_param_layers() {
        let net = Network::new(tiny_arch());
        let params = net.init_params(2);
        let mut scratch = net.scratch();
        let mut rng = Pcg32::seeded(1);
        let img = rand_image(&mut rng, 13 * 13);
        net.forward(&params.as_slice(), &img, &mut scratch, None);
        let mut order = Vec::new();
        net.backward(&params.as_slice(), 0, &mut scratch, None, |l, _, _| order.push(l));
        assert_eq!(order, vec![6, 5, 3, 1], "output, fc, conv2, conv1");
    }

    #[test]
    fn timers_populate_all_classes() {
        let net = Network::new(tiny_arch());
        let params = net.init_params(2);
        let mut scratch = net.scratch();
        let timers = LayerTimes::new();
        let mut rng = Pcg32::seeded(1);
        let img = rand_image(&mut rng, 13 * 13);
        net.forward(&params.as_slice(), &img, &mut scratch, Some(&timers));
        net.backward(&params.as_slice(), 1, &mut scratch, Some(&timers), |_, _, _| {});
        use crate::util::timer::LayerClass as LC;
        for c in [
            LC::ConvForward,
            LC::ConvBackward,
            LC::PoolForward,
            LC::PoolBackward,
            LC::FcForward,
            LC::FcBackward,
            LC::OutputForward,
            LC::OutputBackward,
        ] {
            assert!(timers.get_secs(c) > 0.0, "no time recorded for {:?}", c);
        }
    }

    #[test]
    fn paper_architectures_run_end_to_end() {
        let mut rng = Pcg32::seeded(6);
        let img = rand_image(&mut rng, 29 * 29);
        for name in crate::config::PAPER_ARCHS {
            let net = Network::from_name(name).unwrap();
            let mut params = net.init_params(1);
            let mut scratch = net.scratch();
            let (loss, _) = net.sgd_step(&mut params, &img, 4, 0.001, &mut scratch, None);
            assert!(loss.is_finite(), "{name}: non-finite loss");
            assert!(loss > 0.0);
        }
    }
}
