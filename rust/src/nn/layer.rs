//! The open layer API: kinds, compiled ops, and the kind registry.
//!
//! A **kind** ([`LayerKind`]) is everything the system knows about one layer
//! vocabulary entry — how to parse/serialize its JSON body, how its output
//! geometry and parameter counts derive from the input geometry, and how to
//! compile a [`LayerSpec`] into an executable op. Kinds live in a string
//! registry ([`register`]/[`lookup`], mirroring `chaos::policy`), so
//! `ArchSpec::from_json`, `validate` and `to_json` are open-ended: a kind
//! registered at runtime is immediately loadable, validatable and trainable
//! through `chaos::Trainer` under every update policy.
//!
//! An **op** ([`LayerOp`]) is one compiled layer of one network: it owns its
//! geometry ([`LayerOp::in_shape`]/[`LayerOp::out_shape`]), its span in the
//! flat parameter vector ([`LayerOp::param_range`] — the contiguous block
//! CHAOS publishes per layer), and the forward/backward kernels. The
//! orchestrator ([`super::Network`]) is a loop over ops — it loads each
//! op's parameter span on demand through `ParamSource`, hands finished
//! gradient blocks to `on_grads` back-to-front (the CHAOS publication
//! hook), and never matches on layer types.
//!
//! ### Backward contract
//!
//! The delta handed to [`LayerOp::backward`] is ∂L/∂(this op's *output*,
//! post-activation); an op that owns an activation first converts it to the
//! pre-activation delta in place using its stored outputs
//! ([`Act::scale_delta`]). The op writes ∂L/∂(its *input*) — again w.r.t.
//! the previous op's post-activation output — into `delta_in`, unless
//! `delta_in` is empty (first layer above the input: nobody consumes it).
//! The one exception is the softmax output op, whose incoming delta is
//! already the pre-activation `p − onehot` because softmax and
//! cross-entropy fuse in the loss.

use super::audit::{Dispatch, KernelPath, OpCost};
use super::conv::{conv_backward, conv_backward_general, conv_forward, conv_forward_general, ConvGeom};
use super::dims::LayerDims;
use super::fc::{fc_backward, fc_forward, FcShape};
use super::pool::{avg_pool_backward, avg_pool_forward, pool_backward, pool_forward, PoolShape};
use super::simd::MathPolicy;
use crate::config::{Act, ArchSpec, LayerSpec};
use crate::util::timer::LayerClass;
use crate::util::{Json, Pcg32};
use std::collections::BTreeMap;
use std::ops::Range;
use std::sync::{Arc, Mutex, OnceLock};

/// Activation geometry flowing between layers: `maps` square feature maps
/// of side `side`. `flat` marks the post-flatten (fully-connected) stage —
/// feature-map layers (conv/pool) reject flat input.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shape {
    pub maps: usize,
    pub side: usize,
    pub flat: bool,
}

impl Shape {
    /// The input layer's shape: one map of side `side`.
    pub fn input(side: usize) -> Shape {
        Shape { maps: 1, side, flat: false }
    }

    /// A flattened vector of `n` neurons.
    pub fn vector(n: usize) -> Shape {
        Shape { maps: n, side: 1, flat: true }
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.maps * self.side * self.side
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Context handed to [`LayerKind::out_shape`] during validation/compilation.
pub struct LayerCtx<'a> {
    /// The architecture being validated (name and full layer list).
    pub arch: &'a ArchSpec,
    /// Index of the layer under consideration.
    pub index: usize,
}

/// Per-op view of the per-worker scratch: this layer's auxiliary `u32`
/// words (pool switches, dropout masks — sized by [`LayerOp::aux_len`]),
/// this layer's thread-private PRNG, and whether the pass is a training
/// pass (dropout is identity outside training).
///
/// Batched passes additionally carry the accumulation policy ([`MathPolicy`]
/// — per-sample kernels are always exact and ignore it) and the shared
/// im2col scratch panel `col`, sized by the plan to the largest
/// [`LayerOp::im2col_len`] in the stack (empty when no op asks for one).
pub struct OpScratch<'a> {
    pub aux: &'a mut [u32],
    pub rng: &'a mut Pcg32,
    pub train: bool,
    pub math: MathPolicy,
    pub col: &'a mut [f32],
}

/// The stored activations an op may consult during backward: its forward
/// input (the previous op's output) and its own forward output.
pub struct Acts<'a> {
    pub input: &'a [f32],
    pub output: &'a [f32],
}

/// Batched stored activations for [`LayerOp::backward_batch`]: `inputs` is
/// `[batch][in_len]` flat, `outputs` `[batch][out_len]` flat — the arenas a
/// [`super::batch::BatchPlan`] forward pass left behind.
pub struct BatchActs<'a> {
    pub inputs: &'a [f32],
    pub outputs: &'a [f32],
}

/// How an op's parameter span may be divided across model-parallel shards —
/// the static contract behind [`crate::chaos::analysis::shard`]. A span is
/// either an indivisible block (it must live whole on every shard that
/// computes the layer) or a sequence of `units` equally-sized output units
/// that may be cut *only* at unit boundaries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SplitSpec {
    /// No legal interior cut. The conservative truth for parameter-free
    /// ops and the default for runtime-registered kinds — a kind that has
    /// not declared its split geometry can never be silently model-split.
    Unsplittable,
    /// The span divides along `units` output units laid out unit-major:
    /// unit `u` owns weight row `u * weights_per_unit ..
    /// (u + 1) * weights_per_unit`, and bias element
    /// `units * weights_per_unit + u`. Legal cuts fall on unit boundaries
    /// only — a shard owning unit `u` owns both its weight row and its
    /// bias element.
    OutputUnits { units: usize, weights_per_unit: usize },
}

impl SplitSpec {
    /// Total parameter count implied by the declared geometry (weights +
    /// biases for [`SplitSpec::OutputUnits`]; `None` for unsplittable
    /// spans, whose length is whatever [`LayerOp::param_range`] says).
    pub fn declared_len(&self) -> Option<usize> {
        match *self {
            SplitSpec::Unsplittable => None,
            SplitSpec::OutputUnits { units, weights_per_unit } => {
                Some(units * weights_per_unit + units)
            }
        }
    }
}

/// One compiled layer of one network. Implementations are stateless between
/// calls — all mutable per-sample state lives in the worker's scratch, so a
/// single op is shared by every CHAOS worker thread.
pub trait LayerOp: Send + Sync + std::fmt::Debug {
    /// Registry name of the kind this op was compiled from.
    fn kind(&self) -> &'static str;

    fn in_shape(&self) -> Shape;

    fn out_shape(&self) -> Shape;

    /// This op's span in the flat parameter vector (empty for
    /// parameter-free ops). Weights come first, then biases.
    ///
    /// **Span contract.** The returned range must equal the compiler's
    /// declared span for the layer (`LayerDims::params`) — same start, same
    /// end — or be empty when the op holds no parameters. Across the stack,
    /// spans must lie in bounds, be pairwise disjoint, and exactly cover the
    /// flat vector; [`crate::chaos::analysis::verify_network`] proves all of
    /// this for every compiled network (debug builds enforce it at
    /// `Network::new`, `chaos analyze` reports it from the CLI). The CHAOS
    /// publication locks key off these spans, so an op that mis-declares its
    /// range turns controlled updates into silent races.
    fn param_range(&self) -> Range<usize>;

    /// Auxiliary `u32` words this op needs in the per-worker scratch.
    fn aux_len(&self) -> usize {
        0
    }

    /// `f32` elements of im2col panel scratch this op's batched kernels
    /// want under [`MathPolicy::Fast`] (zero for ops without an im2col
    /// route). The batch plan allocates one shared panel sized to the
    /// stack-wide maximum and hands it to every op through
    /// [`OpScratch::col`]; the arena is accounted for in
    /// `BatchScratch::layout()` so the dataflow audit covers it.
    fn im2col_len(&self) -> usize {
        0
    }

    /// Timer class for the forward (`backward == false`) or backward pass.
    /// Custom kinds default to the generic `Other` pair.
    fn class(&self, backward: bool) -> LayerClass {
        if backward {
            LayerClass::OtherBackward
        } else {
            LayerClass::OtherForward
        }
    }

    /// Forward one sample: read `input`, write `out` (this op's
    /// post-activation output). `params` is this op's already-loaded
    /// parameter span.
    fn forward(&self, params: &[f32], input: &[f32], out: &mut [f32], scratch: &mut OpScratch<'_>);

    /// Forward `batch` samples at once: `inputs` is `[batch][in_len]` flat,
    /// `outs` is `[batch][out_len]` flat, and `scratch.aux` holds
    /// `batch · aux_len()` words sliced `[batch][aux_len]`. `params` is
    /// still this op's single already-loaded span — the whole point of the
    /// batched path is that the caller loads it **once per batch** (see
    /// [`super::batch::BatchPlan`]).
    ///
    /// Contract: the result must be bit-identical to `batch` successive
    /// [`LayerOp::forward`] calls sharing `scratch.rng` (enforced for every
    /// registered kind by `rust/tests/batch_forward.rs`). The default impl
    /// guarantees this by looping the per-sample kernel; the built-in
    /// conv/fc ops override it with weight-stationary kernels that keep the
    /// per-element accumulation order.
    fn forward_batch(
        &self,
        params: &[f32],
        inputs: &[f32],
        outs: &mut [f32],
        batch: usize,
        scratch: &mut OpScratch<'_>,
    ) {
        let il = self.in_shape().len();
        let ol = self.out_shape().len();
        let al = self.aux_len();
        debug_assert_eq!(inputs.len(), batch * il);
        debug_assert_eq!(outs.len(), batch * ol);
        debug_assert_eq!(scratch.aux.len(), batch * al);
        for b in 0..batch {
            let mut per = OpScratch {
                aux: &mut scratch.aux[b * al..(b + 1) * al],
                rng: &mut *scratch.rng,
                train: scratch.train,
                math: scratch.math,
                col: &mut *scratch.col,
            };
            self.forward(params, &inputs[b * il..(b + 1) * il], &mut outs[b * ol..(b + 1) * ol], &mut per);
        }
    }

    /// Backward one sample — see the module docs for the delta contract.
    /// `grads` is this op's gradient span (zeroed by the driver;
    /// accumulate into it as `[weights..., biases...]`).
    fn backward(
        &self,
        params: &[f32],
        acts: Acts<'_>,
        delta_out: &mut [f32],
        delta_in: &mut [f32],
        grads: &mut [f32],
        scratch: &mut OpScratch<'_>,
    );

    /// Backward `batch` samples at once: `deltas_out` is `[batch][out_len]`
    /// flat (∂L/∂output per sample, converted to pre-activation deltas in
    /// place, like the per-sample contract), `deltas_in` `[batch][in_len]`
    /// flat (or empty for the layer above the input), and `grads` is this
    /// op's **single** gradient span receiving the **batch-summed**
    /// `[weights..., biases...]` gradient (zeroed by the driver). `params`
    /// is the op's single already-loaded span — loaded once per batch by
    /// [`super::batch::BatchPlan::backward`], the backward half of the
    /// weight-stationary story.
    ///
    /// Contract: gradients and input deltas must be bit-identical to
    /// `batch` successive [`LayerOp::backward`] calls sharing `grads` and
    /// `scratch.rng` — every gradient element accumulates its per-sample
    /// contributions in ascending sample order (enforced for every
    /// registered kind by `rust/tests/batch_backward.rs`). The default
    /// impl loops the per-sample kernel; the built-in conv/fc ops override
    /// it with weight-stationary kernels that keep the per-element
    /// accumulation order.
    fn backward_batch(
        &self,
        params: &[f32],
        acts: BatchActs<'_>,
        deltas_out: &mut [f32],
        deltas_in: &mut [f32],
        grads: &mut [f32],
        batch: usize,
        scratch: &mut OpScratch<'_>,
    ) {
        let il = self.in_shape().len();
        let ol = self.out_shape().len();
        let al = self.aux_len();
        debug_assert_eq!(acts.inputs.len(), batch * il);
        debug_assert_eq!(acts.outputs.len(), batch * ol);
        debug_assert_eq!(deltas_out.len(), batch * ol);
        debug_assert!(deltas_in.is_empty() || deltas_in.len() == batch * il);
        debug_assert_eq!(scratch.aux.len(), batch * al);
        let skip_din = deltas_in.is_empty();
        for b in 0..batch {
            let din: &mut [f32] =
                if skip_din { &mut [] } else { &mut deltas_in[b * il..(b + 1) * il] };
            let mut per = OpScratch {
                aux: &mut scratch.aux[b * al..(b + 1) * al],
                rng: &mut *scratch.rng,
                train: scratch.train,
                math: scratch.math,
                col: &mut *scratch.col,
            };
            self.backward(
                params,
                Acts {
                    input: &acts.inputs[b * il..(b + 1) * il],
                    output: &acts.outputs[b * ol..(b + 1) * ol],
                },
                &mut deltas_out[b * ol..(b + 1) * ol],
                din,
                grads,
                &mut per,
            );
        }
    }

    /// Which kernel path each pass of this op compiles to, for the static
    /// dispatch classifier ([`crate::nn::audit::audit_dispatch`]). The
    /// conservative default says "per-sample loop" — the slowest truthful
    /// answer for an op that has not overridden the batched kernels — so
    /// runtime-registered kinds show up on the audit work-list rather than
    /// silently passing as fast.
    fn dispatch(&self) -> Dispatch {
        Dispatch::per_sample()
    }

    /// Static per-sample cost estimate (FLOPs and bytes moved) for the
    /// analytic model ([`crate::nn::audit::audit_cost`]). The conservative
    /// default charges one flop per touched element forward, two backward,
    /// and counts every activation and parameter byte — an upper-ish bound
    /// that keeps unregistered kinds visible in the roofline table. Built-in
    /// ops override this with exact kernel arithmetic.
    fn cost(&self) -> OpCost {
        OpCost::generic(
            self.in_shape().len(),
            self.out_shape().len(),
            self.param_range().len(),
        )
    }

    /// Legal model-parallel cuts of this op's parameter span, for the
    /// static shard planner/verifier ([`crate::chaos::analysis::shard`]).
    /// The conservative default declares the span unsplittable, so a
    /// runtime-registered kind is replicated (data-parallel) until it
    /// opts in; the built-in fully-connected ops override with their
    /// output-unit geometry.
    fn split_points(&self) -> SplitSpec {
        SplitSpec::Unsplittable
    }
}

/// A registered layer kind — the parse/validate/compile behaviour behind
/// one `LayerSpec` vocabulary entry. See the module docs.
pub trait LayerKind: Send + Sync {
    /// Registry name (the JSON key selecting this kind).
    fn name(&self) -> &'static str;

    /// Parse this kind's JSON body (the value under the kind key).
    fn from_json(&self, body: &Json) -> anyhow::Result<LayerSpec>;

    /// Serialize a spec of this kind back to its JSON body.
    fn to_json(&self, spec: &LayerSpec) -> Json;

    /// Validate the spec against the input geometry and derive the output
    /// geometry. All structural errors ("pool does not divide", "conv
    /// after fully-connected", …) surface here.
    fn out_shape(&self, spec: &LayerSpec, input: Shape, ctx: &LayerCtx<'_>)
        -> anyhow::Result<Shape>;

    /// (weights, biases) this layer owns in the flat parameter vector.
    fn param_counts(&self, _spec: &LayerSpec, _input: Shape) -> (usize, usize) {
        (0, 0)
    }

    /// Whether this kind consumes its input as a flattened vector (its
    /// `LayerDims` then reports `in_maps = input.len(), in_side = 1`, the
    /// layout convention of the fully-connected kernels).
    fn flattens_input(&self) -> bool {
        false
    }

    /// The (single, leading) input kind.
    fn is_input(&self) -> bool {
        false
    }

    /// A terminal kind (must be — and only be — the last layer).
    fn is_terminal(&self) -> bool {
        false
    }

    /// Compile a spec of this kind into an executable op for the given
    /// geometry/parameter layout.
    fn compile(&self, spec: &LayerSpec, dims: &LayerDims) -> anyhow::Result<Box<dyn LayerOp>>;
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

fn registry() -> &'static Mutex<BTreeMap<String, Arc<dyn LayerKind>>> {
    static REGISTRY: OnceLock<Mutex<BTreeMap<String, Arc<dyn LayerKind>>>> = OnceLock::new();
    REGISTRY.get_or_init(|| {
        let mut map: BTreeMap<String, Arc<dyn LayerKind>> = BTreeMap::new();
        let builtins: [Arc<dyn LayerKind>; 7] = [
            Arc::new(InputKind),
            Arc::new(ConvKind),
            Arc::new(MaxPoolKind),
            Arc::new(AvgPoolKind),
            Arc::new(FcKind),
            Arc::new(DropoutKind),
            Arc::new(OutputKind),
        ];
        for kind in builtins {
            map.insert(kind.name().to_string(), kind);
        }
        Mutex::new(map)
    })
}

/// Register a custom layer kind, making it selectable from architecture
/// JSON ([`ArchSpec::from_json`]) and compilable into trainable networks —
/// without touching the orchestrator. Fails on duplicate or empty names.
pub fn register(kind: Arc<dyn LayerKind>) -> anyhow::Result<()> {
    let name = kind.name();
    anyhow::ensure!(!name.is_empty(), "layer kind name must be non-empty");
    let mut reg = registry().lock().unwrap();
    anyhow::ensure!(!reg.contains_key(name), "layer kind '{name}' is already registered");
    reg.insert(name.to_string(), kind);
    Ok(())
}

/// Resolve a kind by registry name.
pub fn lookup(name: &str) -> anyhow::Result<Arc<dyn LayerKind>> {
    let reg = registry().lock().unwrap();
    reg.get(name).cloned().ok_or_else(|| {
        let known: Vec<&str> = reg.keys().map(|k| k.as_str()).collect();
        anyhow::anyhow!("unknown layer kind '{name}' (available: {})", known.join("|"))
    })
}

/// The registered kind names (built-ins plus [`register`]ed customs),
/// sorted.
pub fn names() -> Vec<String> {
    registry().lock().unwrap().keys().cloned().collect()
}

/// Parse one layer from its JSON key/body pair — the entry point
/// `ArchSpec::from_json` delegates to.
pub fn from_json(key: &str, body: &Json) -> anyhow::Result<LayerSpec> {
    lookup(key)?.from_json(body)
}

/// Registry name of the kind a spec belongs to.
pub fn kind_of(spec: &LayerSpec) -> &str {
    match spec {
        LayerSpec::Input { .. } => "input",
        LayerSpec::Conv { .. } => "conv",
        LayerSpec::MaxPool { .. } => "pool",
        LayerSpec::AvgPool { .. } => "avgpool",
        LayerSpec::FullyConnected { .. } => "fc",
        LayerSpec::Dropout { .. } => "dropout",
        LayerSpec::Output { .. } => "output",
        LayerSpec::Custom { kind, .. } => kind.as_str(),
    }
}

/// Resolve the registered kind handling a spec.
pub fn kind_for(spec: &LayerSpec) -> anyhow::Result<Arc<dyn LayerKind>> {
    lookup(kind_of(spec))
}

/// Helpers for custom kinds carrying numeric (key, value) arguments.
pub fn args_from_json(body: &Json) -> anyhow::Result<Vec<(String, f64)>> {
    match body.as_obj() {
        Some(obj) => obj
            .iter()
            .map(|(k, v)| {
                v.as_f64()
                    .map(|x| (k.clone(), x))
                    .ok_or_else(|| anyhow::anyhow!("argument '{k}' must be a number"))
            })
            .collect(),
        None => Ok(Vec::new()),
    }
}

pub fn args_to_json(args: &[(String, f64)]) -> Json {
    Json::obj(args.iter().map(|(k, v)| (k.as_str(), Json::num(*v))).collect())
}

// ---------------------------------------------------------------------------
// Built-in kinds and their ops
// ---------------------------------------------------------------------------

fn expect_usize(body: &Json, what: &str) -> anyhow::Result<usize> {
    body.as_usize().ok_or_else(|| anyhow::anyhow!("{what} must be a non-negative integer"))
}

fn parse_act(body: &Json) -> anyhow::Result<Act> {
    match body.get("act") {
        None => Ok(Act::ScaledTanh),
        Some(a) => {
            Act::parse(a.as_str().ok_or_else(|| anyhow::anyhow!("act must be a string"))?)
        }
    }
}

fn no_flat_input(kind: &str, input: Shape, ctx: &LayerCtx<'_>) -> anyhow::Result<()> {
    anyhow::ensure!(
        !input.flat,
        "layer {}: {kind} after fully-connected",
        ctx.index
    );
    Ok(())
}

// ----- input ----------------------------------------------------------------

struct InputKind;

impl LayerKind for InputKind {
    fn name(&self) -> &'static str {
        "input"
    }

    fn is_input(&self) -> bool {
        true
    }

    fn from_json(&self, body: &Json) -> anyhow::Result<LayerSpec> {
        Ok(LayerSpec::Input { side: expect_usize(body, "input side")? })
    }

    fn to_json(&self, spec: &LayerSpec) -> Json {
        let LayerSpec::Input { side } = spec else { unreachable!() };
        Json::num(*side as f64)
    }

    fn out_shape(
        &self,
        spec: &LayerSpec,
        _input: Shape,
        ctx: &LayerCtx<'_>,
    ) -> anyhow::Result<Shape> {
        let LayerSpec::Input { side } = spec else { unreachable!() };
        anyhow::ensure!(*side > 0, "layer {}: input side must be positive", ctx.index);
        Ok(Shape::input(*side))
    }

    fn compile(&self, _spec: &LayerSpec, dims: &LayerDims) -> anyhow::Result<Box<dyn LayerOp>> {
        Ok(Box::new(InputOp { shape: Shape::input(dims.out_side) }))
    }
}

/// Placeholder op for the input layer — the orchestrator's loops start at
/// layer 1, so its kernels are never driven.
#[derive(Debug)]
struct InputOp {
    shape: Shape,
}

impl LayerOp for InputOp {
    fn kind(&self) -> &'static str {
        "input"
    }

    fn in_shape(&self) -> Shape {
        self.shape
    }

    fn out_shape(&self) -> Shape {
        self.shape
    }

    fn param_range(&self) -> Range<usize> {
        0..0
    }

    fn forward(&self, _: &[f32], _: &[f32], _: &mut [f32], _: &mut OpScratch<'_>) {
        unreachable!("input layer is never forwarded");
    }

    fn backward(
        &self,
        _: &[f32],
        _: Acts<'_>,
        _: &mut [f32],
        _: &mut [f32],
        _: &mut [f32],
        _: &mut OpScratch<'_>,
    ) {
        unreachable!("input layer is never backpropagated");
    }

    fn dispatch(&self) -> Dispatch {
        Dispatch::inert()
    }

    fn cost(&self) -> OpCost {
        OpCost::zero()
    }

    fn split_points(&self) -> SplitSpec {
        // Parameter-free: there is nothing to split.
        SplitSpec::Unsplittable
    }
}

// ----- conv ------------------------------------------------------------------

struct ConvKind;

impl LayerKind for ConvKind {
    fn name(&self) -> &'static str {
        "conv"
    }

    fn from_json(&self, body: &Json) -> anyhow::Result<LayerSpec> {
        let maps = body.req("maps")?.as_usize().ok_or_else(|| anyhow::anyhow!("conv maps"))?;
        let kernel =
            body.req("kernel")?.as_usize().ok_or_else(|| anyhow::anyhow!("conv kernel"))?;
        let stride = match body.get("stride") {
            None => 1,
            Some(s) => s.as_usize().ok_or_else(|| anyhow::anyhow!("conv stride"))?,
        };
        let pad = match body.get("pad") {
            None => 0,
            Some(p) => p.as_usize().ok_or_else(|| anyhow::anyhow!("conv pad"))?,
        };
        Ok(LayerSpec::Conv { maps, kernel, stride, pad, act: parse_act(body)? })
    }

    fn to_json(&self, spec: &LayerSpec) -> Json {
        let LayerSpec::Conv { maps, kernel, stride, pad, act } = spec else { unreachable!() };
        let mut fields = vec![
            ("maps", Json::num(*maps as f64)),
            ("kernel", Json::num(*kernel as f64)),
        ];
        if *stride != 1 {
            fields.push(("stride", Json::num(*stride as f64)));
        }
        if *pad != 0 {
            fields.push(("pad", Json::num(*pad as f64)));
        }
        if *act != Act::ScaledTanh {
            fields.push(("act", Json::str(act.name().to_string())));
        }
        Json::obj(fields)
    }

    fn out_shape(
        &self,
        spec: &LayerSpec,
        input: Shape,
        ctx: &LayerCtx<'_>,
    ) -> anyhow::Result<Shape> {
        let LayerSpec::Conv { maps, kernel, stride, pad, .. } = spec else { unreachable!() };
        no_flat_input("conv", input, ctx)?;
        let i = ctx.index;
        anyhow::ensure!(*maps > 0, "layer {i}: conv with zero maps");
        anyhow::ensure!(*stride > 0, "layer {i}: conv stride must be ≥ 1");
        anyhow::ensure!(
            *kernel == 0 || *pad < *kernel,
            "layer {i}: conv pad {pad} must be smaller than kernel {kernel}"
        );
        let out_side = ConvGeom::out_side(input.side, *kernel, *stride, *pad).ok_or_else(|| {
            anyhow::anyhow!(
                "layer {i}: conv kernel {kernel} invalid for side {} (stride {stride}, pad {pad})",
                input.side
            )
        })?;
        Ok(Shape { maps: *maps, side: out_side, flat: false })
    }

    fn param_counts(&self, spec: &LayerSpec, input: Shape) -> (usize, usize) {
        let LayerSpec::Conv { maps, kernel, .. } = spec else { unreachable!() };
        (maps * input.maps * kernel * kernel, *maps)
    }

    fn compile(&self, spec: &LayerSpec, dims: &LayerDims) -> anyhow::Result<Box<dyn LayerOp>> {
        let LayerSpec::Conv { maps, kernel, stride, pad, act } = spec else { unreachable!() };
        let geom = ConvGeom::new(dims.in_maps, dims.in_side, *maps, *kernel, *stride, *pad)
            .ok_or_else(|| anyhow::anyhow!("conv geometry does not fit"))?;
        debug_assert_eq!(geom.out_side, dims.out_side);
        debug_assert_eq!(geom.weight_len(), dims.weights);
        Ok(Box::new(ConvOp { geom, act: *act, weights: dims.weights, params: dims.params.clone() }))
    }
}

#[derive(Debug)]
struct ConvOp {
    geom: ConvGeom,
    act: Act,
    weights: usize,
    params: Range<usize>,
}

impl LayerOp for ConvOp {
    fn kind(&self) -> &'static str {
        "conv"
    }

    fn in_shape(&self) -> Shape {
        Shape { maps: self.geom.in_maps, side: self.geom.in_side, flat: false }
    }

    fn out_shape(&self) -> Shape {
        Shape { maps: self.geom.out_maps, side: self.geom.out_side, flat: false }
    }

    fn param_range(&self) -> Range<usize> {
        self.params.clone()
    }

    fn class(&self, backward: bool) -> LayerClass {
        if backward {
            LayerClass::ConvBackward
        } else {
            LayerClass::ConvForward
        }
    }

    fn forward(&self, params: &[f32], input: &[f32], out: &mut [f32], _: &mut OpScratch<'_>) {
        let (w, b) = params.split_at(self.weights);
        if self.geom.is_plain() {
            conv_forward(&self.geom.as_plain(), input, w, b, out);
        } else {
            conv_forward_general(&self.geom, input, w, b, out);
        }
        self.act.apply(out);
    }

    fn forward_batch(
        &self,
        params: &[f32],
        inputs: &[f32],
        outs: &mut [f32],
        batch: usize,
        scratch: &mut OpScratch<'_>,
    ) {
        let (w, b) = params.split_at(self.weights);
        if self.geom.is_plain() {
            super::conv::conv_forward_batch(&self.geom.as_plain(), inputs, w, b, outs, batch);
        } else {
            // Padded/strided path: tap-stationary batched kernel; under
            // MathPolicy::Fast it stages each sample through the shared
            // im2col panel in scratch.col.
            super::conv::conv_forward_general_batch(
                &self.geom,
                inputs,
                w,
                b,
                outs,
                batch,
                scratch.math,
                scratch.col,
            );
        }
        // Elementwise activation over the whole [batch][out_len] block.
        self.act.apply(outs);
    }

    fn backward(
        &self,
        params: &[f32],
        acts: Acts<'_>,
        delta_out: &mut [f32],
        delta_in: &mut [f32],
        grads: &mut [f32],
        _: &mut OpScratch<'_>,
    ) {
        self.act.scale_delta(delta_out, acts.output);
        let (w, _b) = params.split_at(self.weights);
        let (wg, bg) = grads.split_at_mut(self.weights);
        if self.geom.is_plain() {
            conv_backward(&self.geom.as_plain(), acts.input, w, delta_out, wg, bg, delta_in);
        } else {
            conv_backward_general(&self.geom, acts.input, w, delta_out, wg, bg, delta_in);
        }
    }

    fn backward_batch(
        &self,
        params: &[f32],
        acts: BatchActs<'_>,
        deltas_out: &mut [f32],
        deltas_in: &mut [f32],
        grads: &mut [f32],
        batch: usize,
        _: &mut OpScratch<'_>,
    ) {
        // Block-wise pre-activation conversion (elementwise, so one sweep
        // over the whole [batch][out_len] block matches per-sample bits).
        self.act.scale_delta(deltas_out, acts.outputs);
        let (w, _b) = params.split_at(self.weights);
        let (wg, bg) = grads.split_at_mut(self.weights);
        if self.geom.is_plain() {
            super::conv::conv_backward_batch(
                &self.geom.as_plain(),
                acts.inputs,
                w,
                deltas_out,
                wg,
                bg,
                deltas_in,
                batch,
            );
        } else {
            // Padded/strided path: tap-stationary batched kernel
            // (policy-independent — backward is exact under every policy).
            super::conv::conv_backward_general_batch(
                &self.geom,
                acts.inputs,
                w,
                deltas_out,
                wg,
                bg,
                deltas_in,
                batch,
            );
        }
    }

    fn im2col_len(&self) -> usize {
        if self.geom.is_plain() {
            0
        } else {
            self.geom.im2col_len()
        }
    }

    fn dispatch(&self) -> Dispatch {
        if self.geom.is_plain() {
            // Plain geometry takes the vectorized weight-stationary batch
            // kernels (conv_forward_batch / conv_backward_batch).
            Dispatch::uniform(KernelPath::VectorizedPlain)
        } else {
            // Padded/strided geometry runs the tap-stationary batched
            // kernels, with the im2col+GEMM staging route under fast math.
            Dispatch::uniform(KernelPath::Im2colGemm)
        }
    }

    fn cost(&self) -> OpCost {
        let macs = self.geom.macs() as f64;
        let out = self.geom.out_len() as f64;
        let touched = (self.geom.in_len() + self.geom.out_len()) as f64;
        OpCost {
            // 2 flops per MAC, plus bias add and activation per output.
            fwd_flops: 2.0 * macs + out * (1.0 + self.act.flops_per_elem()),
            // Backward runs the MAC volume twice (input deltas + weight
            // grads), plus the delta pre-activation scaling.
            bwd_flops: 4.0 * macs + out * (1.0 + self.act.flops_per_elem()),
            param_bytes: 4.0 * self.params.len() as f64,
            fwd_act_bytes: 4.0 * touched,
            bwd_act_bytes: 8.0 * touched,
        }
    }

    fn split_points(&self) -> SplitSpec {
        // Conv is the data-parallel class of the hybrid scheme
        // (Krizhevsky, arXiv:1404.5997): compute-heavy, parameter-light,
        // so its span is replicated on every shard rather than cut.
        // Declaring it unsplittable lets the verifier reject any plan
        // that tries to model-parallelize the conv stage.
        SplitSpec::Unsplittable
    }
}

// ----- max pool --------------------------------------------------------------

struct MaxPoolKind;

fn pool_out_shape(
    kind: &str,
    kernel: usize,
    input: Shape,
    ctx: &LayerCtx<'_>,
) -> anyhow::Result<Shape> {
    no_flat_input(kind, input, ctx)?;
    let i = ctx.index;
    let side = input.side;
    anyhow::ensure!(
        kernel > 0 && kernel <= side,
        "layer {i}: pool kernel {kernel} invalid for side {side}"
    );
    // Identity pools are almost always a config mistake; the paper's
    // "large" network legitimately uses P1 (Table 2), so that exact layer
    // stack — whatever the arch is called — is carved out.
    anyhow::ensure!(
        kernel != 1 || ctx.arch.layers == ArchSpec::large().layers,
        "layer {i}: pool kernel 1 is an identity pool (only the paper's 'large' network uses P1)"
    );
    // Stride = kernel; the window grid must tile the input exactly.
    anyhow::ensure!(
        side % kernel == 0,
        "layer {i}: pool kernel {kernel} does not evenly divide side {side}"
    );
    Ok(Shape { maps: input.maps, side: side / kernel, flat: false })
}

impl LayerKind for MaxPoolKind {
    fn name(&self) -> &'static str {
        "pool"
    }

    fn from_json(&self, body: &Json) -> anyhow::Result<LayerSpec> {
        Ok(LayerSpec::MaxPool { kernel: expect_usize(body, "pool kernel")? })
    }

    fn to_json(&self, spec: &LayerSpec) -> Json {
        let LayerSpec::MaxPool { kernel } = spec else { unreachable!() };
        Json::num(*kernel as f64)
    }

    fn out_shape(
        &self,
        spec: &LayerSpec,
        input: Shape,
        ctx: &LayerCtx<'_>,
    ) -> anyhow::Result<Shape> {
        let LayerSpec::MaxPool { kernel } = spec else { unreachable!() };
        pool_out_shape("pool", *kernel, input, ctx)
    }

    fn compile(&self, spec: &LayerSpec, dims: &LayerDims) -> anyhow::Result<Box<dyn LayerOp>> {
        let LayerSpec::MaxPool { kernel } = spec else { unreachable!() };
        Ok(Box::new(MaxPoolOp {
            shape: PoolShape {
                maps: dims.in_maps,
                in_side: dims.in_side,
                out_side: dims.out_side,
                kernel: *kernel,
            },
        }))
    }
}

#[derive(Debug)]
struct MaxPoolOp {
    shape: PoolShape,
}

impl LayerOp for MaxPoolOp {
    fn kind(&self) -> &'static str {
        "pool"
    }

    fn in_shape(&self) -> Shape {
        Shape { maps: self.shape.maps, side: self.shape.in_side, flat: false }
    }

    fn out_shape(&self) -> Shape {
        Shape { maps: self.shape.maps, side: self.shape.out_side, flat: false }
    }

    fn param_range(&self) -> Range<usize> {
        0..0
    }

    fn aux_len(&self) -> usize {
        self.shape.out_len()
    }

    fn class(&self, backward: bool) -> LayerClass {
        if backward {
            LayerClass::PoolBackward
        } else {
            LayerClass::PoolForward
        }
    }

    fn forward(&self, _: &[f32], input: &[f32], out: &mut [f32], scratch: &mut OpScratch<'_>) {
        pool_forward(&self.shape, input, out, scratch.aux);
    }

    fn forward_batch(
        &self,
        _: &[f32],
        inputs: &[f32],
        outs: &mut [f32],
        batch: usize,
        scratch: &mut OpScratch<'_>,
    ) {
        super::pool::pool_forward_batch(&self.shape, inputs, outs, scratch.aux, batch);
    }

    fn backward(
        &self,
        _: &[f32],
        _acts: Acts<'_>,
        delta_out: &mut [f32],
        delta_in: &mut [f32],
        _: &mut [f32],
        scratch: &mut OpScratch<'_>,
    ) {
        if delta_in.is_empty() {
            return; // pool directly above the input: nobody consumes deltas
        }
        pool_backward(&self.shape, delta_out, scratch.aux, delta_in);
    }

    fn backward_batch(
        &self,
        _: &[f32],
        _acts: BatchActs<'_>,
        deltas_out: &mut [f32],
        deltas_in: &mut [f32],
        _: &mut [f32],
        batch: usize,
        scratch: &mut OpScratch<'_>,
    ) {
        if deltas_in.is_empty() {
            return;
        }
        super::pool::pool_backward_batch(&self.shape, deltas_out, scratch.aux, deltas_in, batch);
    }

    fn dispatch(&self) -> Dispatch {
        // Window-stationary batch kernels: each pool window's geometry is
        // computed once and swept across the batch lanes (parameter-free,
        // so there is no weight-stationarity to exploit).
        Dispatch::uniform(KernelPath::BatchLane)
    }

    fn cost(&self) -> OpCost {
        let touched = (self.shape.in_len() + self.shape.out_len()) as f64;
        OpCost {
            // One compare per window tap forward; backward scatters one
            // add per output through the argmax switch.
            fwd_flops: self.shape.window_ops() as f64,
            bwd_flops: self.shape.out_len() as f64,
            param_bytes: 0.0,
            fwd_act_bytes: 4.0 * touched,
            bwd_act_bytes: 8.0 * touched,
        }
    }

    fn split_points(&self) -> SplitSpec {
        // Parameter-free: there is nothing to split.
        SplitSpec::Unsplittable
    }
}

// ----- avg pool --------------------------------------------------------------

struct AvgPoolKind;

impl LayerKind for AvgPoolKind {
    fn name(&self) -> &'static str {
        "avgpool"
    }

    fn from_json(&self, body: &Json) -> anyhow::Result<LayerSpec> {
        Ok(LayerSpec::AvgPool { kernel: expect_usize(body, "avgpool kernel")? })
    }

    fn to_json(&self, spec: &LayerSpec) -> Json {
        let LayerSpec::AvgPool { kernel } = spec else { unreachable!() };
        Json::num(*kernel as f64)
    }

    fn out_shape(
        &self,
        spec: &LayerSpec,
        input: Shape,
        ctx: &LayerCtx<'_>,
    ) -> anyhow::Result<Shape> {
        let LayerSpec::AvgPool { kernel } = spec else { unreachable!() };
        pool_out_shape("avgpool", *kernel, input, ctx)
    }

    fn compile(&self, spec: &LayerSpec, dims: &LayerDims) -> anyhow::Result<Box<dyn LayerOp>> {
        let LayerSpec::AvgPool { kernel } = spec else { unreachable!() };
        Ok(Box::new(AvgPoolOp {
            shape: PoolShape {
                maps: dims.in_maps,
                in_side: dims.in_side,
                out_side: dims.out_side,
                kernel: *kernel,
            },
        }))
    }
}

#[derive(Debug)]
struct AvgPoolOp {
    shape: PoolShape,
}

impl LayerOp for AvgPoolOp {
    fn kind(&self) -> &'static str {
        "avgpool"
    }

    fn in_shape(&self) -> Shape {
        Shape { maps: self.shape.maps, side: self.shape.in_side, flat: false }
    }

    fn out_shape(&self) -> Shape {
        Shape { maps: self.shape.maps, side: self.shape.out_side, flat: false }
    }

    fn param_range(&self) -> Range<usize> {
        0..0
    }

    fn class(&self, backward: bool) -> LayerClass {
        if backward {
            LayerClass::PoolBackward
        } else {
            LayerClass::PoolForward
        }
    }

    fn forward(&self, _: &[f32], input: &[f32], out: &mut [f32], _: &mut OpScratch<'_>) {
        avg_pool_forward(&self.shape, input, out);
    }

    fn forward_batch(
        &self,
        _: &[f32],
        inputs: &[f32],
        outs: &mut [f32],
        batch: usize,
        _: &mut OpScratch<'_>,
    ) {
        super::pool::avg_pool_forward_batch(&self.shape, inputs, outs, batch);
    }

    fn backward(
        &self,
        _: &[f32],
        _acts: Acts<'_>,
        delta_out: &mut [f32],
        delta_in: &mut [f32],
        _: &mut [f32],
        _: &mut OpScratch<'_>,
    ) {
        if delta_in.is_empty() {
            return;
        }
        avg_pool_backward(&self.shape, delta_out, delta_in);
    }

    fn backward_batch(
        &self,
        _: &[f32],
        _acts: BatchActs<'_>,
        deltas_out: &mut [f32],
        deltas_in: &mut [f32],
        _: &mut [f32],
        batch: usize,
        _: &mut OpScratch<'_>,
    ) {
        if deltas_in.is_empty() {
            return;
        }
        super::pool::avg_pool_backward_batch(&self.shape, deltas_out, deltas_in, batch);
    }

    fn dispatch(&self) -> Dispatch {
        Dispatch::uniform(KernelPath::BatchLane)
    }

    fn cost(&self) -> OpCost {
        let touched = (self.shape.in_len() + self.shape.out_len()) as f64;
        OpCost {
            // One add per window tap plus the 1/k² scale per output;
            // backward fans the scaled delta back over each window.
            fwd_flops: (self.shape.window_ops() + self.shape.out_len()) as f64,
            bwd_flops: self.shape.in_len() as f64,
            param_bytes: 0.0,
            fwd_act_bytes: 4.0 * touched,
            bwd_act_bytes: 8.0 * touched,
        }
    }

    fn split_points(&self) -> SplitSpec {
        // Parameter-free: there is nothing to split.
        SplitSpec::Unsplittable
    }
}

// ----- fully connected -------------------------------------------------------

struct FcKind;

impl LayerKind for FcKind {
    fn name(&self) -> &'static str {
        "fc"
    }

    fn flattens_input(&self) -> bool {
        true
    }

    fn from_json(&self, body: &Json) -> anyhow::Result<LayerSpec> {
        // Shorthand `{"fc": 50}` or object `{"fc": {"neurons": 50, "act": "relu"}}`.
        if let Some(n) = body.as_usize() {
            return Ok(LayerSpec::fc(n));
        }
        let neurons =
            body.req("neurons")?.as_usize().ok_or_else(|| anyhow::anyhow!("fc neurons"))?;
        Ok(LayerSpec::FullyConnected { neurons, act: parse_act(body)? })
    }

    fn to_json(&self, spec: &LayerSpec) -> Json {
        let LayerSpec::FullyConnected { neurons, act } = spec else { unreachable!() };
        if *act == Act::ScaledTanh {
            Json::num(*neurons as f64)
        } else {
            Json::obj(vec![
                ("neurons", Json::num(*neurons as f64)),
                ("act", Json::str(act.name().to_string())),
            ])
        }
    }

    fn out_shape(
        &self,
        spec: &LayerSpec,
        input: Shape,
        ctx: &LayerCtx<'_>,
    ) -> anyhow::Result<Shape> {
        let LayerSpec::FullyConnected { neurons, .. } = spec else { unreachable!() };
        anyhow::ensure!(*neurons > 0, "layer {}: fc with zero neurons", ctx.index);
        anyhow::ensure!(!input.is_empty(), "layer {}: fc on empty input", ctx.index);
        Ok(Shape::vector(*neurons))
    }

    fn param_counts(&self, spec: &LayerSpec, input: Shape) -> (usize, usize) {
        let LayerSpec::FullyConnected { neurons, .. } = spec else { unreachable!() };
        (neurons * input.len(), *neurons)
    }

    fn compile(&self, spec: &LayerSpec, dims: &LayerDims) -> anyhow::Result<Box<dyn LayerOp>> {
        let LayerSpec::FullyConnected { neurons, act } = spec else { unreachable!() };
        Ok(Box::new(FcOp {
            shape: FcShape { inputs: dims.in_maps, outputs: *neurons },
            act: *act,
            output_softmax: false,
            weights: dims.weights,
            params: dims.params.clone(),
        }))
    }
}

// ----- output ----------------------------------------------------------------

struct OutputKind;

impl LayerKind for OutputKind {
    fn name(&self) -> &'static str {
        "output"
    }

    fn flattens_input(&self) -> bool {
        true
    }

    fn is_terminal(&self) -> bool {
        true
    }

    fn from_json(&self, body: &Json) -> anyhow::Result<LayerSpec> {
        Ok(LayerSpec::Output { classes: expect_usize(body, "output classes")? })
    }

    fn to_json(&self, spec: &LayerSpec) -> Json {
        let LayerSpec::Output { classes } = spec else { unreachable!() };
        Json::num(*classes as f64)
    }

    fn out_shape(
        &self,
        spec: &LayerSpec,
        input: Shape,
        ctx: &LayerCtx<'_>,
    ) -> anyhow::Result<Shape> {
        let LayerSpec::Output { classes } = spec else { unreachable!() };
        anyhow::ensure!(*classes > 0, "layer {}: output with zero classes", ctx.index);
        anyhow::ensure!(!input.is_empty(), "layer {}: output on empty input", ctx.index);
        Ok(Shape::vector(*classes))
    }

    fn param_counts(&self, spec: &LayerSpec, input: Shape) -> (usize, usize) {
        let LayerSpec::Output { classes } = spec else { unreachable!() };
        (classes * input.len(), *classes)
    }

    fn compile(&self, spec: &LayerSpec, dims: &LayerDims) -> anyhow::Result<Box<dyn LayerOp>> {
        let LayerSpec::Output { classes } = spec else { unreachable!() };
        Ok(Box::new(FcOp {
            shape: FcShape { inputs: dims.in_maps, outputs: *classes },
            act: Act::Identity,
            output_softmax: true,
            weights: dims.weights,
            params: dims.params.clone(),
        }))
    }
}

/// Fully-connected op, shared by the hidden `fc` kind and the softmax
/// `output` kind. With `output_softmax`, forward applies softmax and
/// backward consumes the already-fused softmax/cross-entropy delta
/// `p − onehot` without any activation-derivative scaling.
#[derive(Debug)]
struct FcOp {
    shape: FcShape,
    act: Act,
    output_softmax: bool,
    weights: usize,
    params: Range<usize>,
}

impl LayerOp for FcOp {
    fn kind(&self) -> &'static str {
        if self.output_softmax {
            "output"
        } else {
            "fc"
        }
    }

    fn in_shape(&self) -> Shape {
        Shape::vector(self.shape.inputs)
    }

    fn out_shape(&self) -> Shape {
        Shape::vector(self.shape.outputs)
    }

    fn param_range(&self) -> Range<usize> {
        self.params.clone()
    }

    fn class(&self, backward: bool) -> LayerClass {
        match (self.output_softmax, backward) {
            (false, false) => LayerClass::FcForward,
            (false, true) => LayerClass::FcBackward,
            (true, false) => LayerClass::OutputForward,
            (true, true) => LayerClass::OutputBackward,
        }
    }

    fn forward(&self, params: &[f32], input: &[f32], out: &mut [f32], _: &mut OpScratch<'_>) {
        let (w, b) = params.split_at(self.weights);
        fc_forward(&self.shape, input, w, b, out);
        if self.output_softmax {
            super::activation::softmax(out);
        } else {
            self.act.apply(out);
        }
    }

    fn forward_batch(
        &self,
        params: &[f32],
        inputs: &[f32],
        outs: &mut [f32],
        batch: usize,
        scratch: &mut OpScratch<'_>,
    ) {
        let (w, b) = params.split_at(self.weights);
        match scratch.math {
            MathPolicy::Exact => {
                super::fc::fc_forward_batch(&self.shape, inputs, w, b, outs, batch)
            }
            MathPolicy::Fast => {
                super::fc::fc_forward_batch_blocked(&self.shape, inputs, w, b, outs, batch)
            }
        }
        if self.output_softmax {
            // Softmax normalizes per sample, never across the batch.
            for row in outs.chunks_exact_mut(self.shape.outputs) {
                super::activation::softmax(row);
            }
        } else {
            self.act.apply(outs);
        }
    }

    fn backward(
        &self,
        params: &[f32],
        acts: Acts<'_>,
        delta_out: &mut [f32],
        delta_in: &mut [f32],
        grads: &mut [f32],
        _: &mut OpScratch<'_>,
    ) {
        if !self.output_softmax {
            self.act.scale_delta(delta_out, acts.output);
        }
        let (w, _b) = params.split_at(self.weights);
        let (wg, bg) = grads.split_at_mut(self.weights);
        fc_backward(&self.shape, acts.input, w, delta_out, wg, bg, delta_in);
    }

    fn backward_batch(
        &self,
        params: &[f32],
        acts: BatchActs<'_>,
        deltas_out: &mut [f32],
        deltas_in: &mut [f32],
        grads: &mut [f32],
        batch: usize,
        _: &mut OpScratch<'_>,
    ) {
        if !self.output_softmax {
            // Elementwise over the whole [batch][outputs] block; the output
            // op's incoming delta is already pre-activation (fused
            // softmax/cross-entropy), per sample as per row.
            self.act.scale_delta(deltas_out, acts.outputs);
        }
        let (w, _b) = params.split_at(self.weights);
        let (wg, bg) = grads.split_at_mut(self.weights);
        super::fc::fc_backward_batch(
            &self.shape,
            acts.inputs,
            w,
            deltas_out,
            wg,
            bg,
            deltas_in,
            batch,
        );
    }

    fn dispatch(&self) -> Dispatch {
        // Both passes run weight-stationary GEMM-shaped batch kernels:
        // forward is batch-lane dotted (exact) or KC/MR cache-blocked
        // (fast), backward is k-panel blocked unconditionally (bit-exact
        // either way — each gradient element has a single owner).
        Dispatch::uniform(KernelPath::BlockedGemm)
    }

    fn cost(&self) -> OpCost {
        let macs = self.shape.macs() as f64;
        let out = self.shape.outputs as f64;
        let touched = (self.shape.inputs + self.shape.outputs) as f64;
        // Softmax costs a handful of flops per class (exp, subtract-max,
        // normalize); hidden fc pays bias + activation per output.
        let per_out =
            if self.output_softmax { 5.0 } else { 1.0 + self.act.flops_per_elem() };
        OpCost {
            fwd_flops: 2.0 * macs + out * per_out,
            bwd_flops: 4.0 * macs + out * per_out,
            param_bytes: 4.0 * self.params.len() as f64,
            fwd_act_bytes: 4.0 * touched,
            bwd_act_bytes: 8.0 * touched,
        }
    }

    fn split_points(&self) -> SplitSpec {
        // The model-parallel class: weights are [neuron][input] row-major
        // followed by [outputs] biases, so each output unit owns one
        // weight row plus one bias element and the span cuts cleanly at
        // unit boundaries. Serves both the hidden "fc" and softmax
        // "output" kinds (FcOp compiles both).
        SplitSpec::OutputUnits {
            units: self.shape.outputs,
            weights_per_unit: self.shape.inputs,
        }
    }
}

// ----- dropout ---------------------------------------------------------------

struct DropoutKind;

impl LayerKind for DropoutKind {
    fn name(&self) -> &'static str {
        "dropout"
    }

    fn from_json(&self, body: &Json) -> anyhow::Result<LayerSpec> {
        let rate =
            body.as_f64().ok_or_else(|| anyhow::anyhow!("dropout rate must be a number"))?;
        Ok(LayerSpec::Dropout { rate: rate as f32 })
    }

    fn to_json(&self, spec: &LayerSpec) -> Json {
        let LayerSpec::Dropout { rate } = spec else { unreachable!() };
        Json::num(*rate as f64)
    }

    fn out_shape(
        &self,
        spec: &LayerSpec,
        input: Shape,
        ctx: &LayerCtx<'_>,
    ) -> anyhow::Result<Shape> {
        let LayerSpec::Dropout { rate } = spec else { unreachable!() };
        anyhow::ensure!(
            (0.0..1.0).contains(rate),
            "layer {}: dropout rate {rate} must be in [0, 1)",
            ctx.index
        );
        Ok(input)
    }

    fn compile(&self, spec: &LayerSpec, dims: &LayerDims) -> anyhow::Result<Box<dyn LayerOp>> {
        let LayerSpec::Dropout { rate } = spec else { unreachable!() };
        Ok(Box::new(DropoutOp {
            shape: Shape { maps: dims.out_maps, side: dims.out_side, flat: dims.flat },
            rate: *rate,
            keep_scale: 1.0 / (1.0 - rate),
        }))
    }
}

/// Inverted dropout (identity at `rate == 0` or outside training passes).
/// Every worker draws masks from its own scratch PRNG, so CHAOS workers
/// mask independently without any shared state.
#[derive(Debug)]
struct DropoutOp {
    shape: Shape,
    rate: f32,
    keep_scale: f32,
}

impl LayerOp for DropoutOp {
    fn kind(&self) -> &'static str {
        "dropout"
    }

    fn in_shape(&self) -> Shape {
        self.shape
    }

    fn out_shape(&self) -> Shape {
        self.shape
    }

    fn param_range(&self) -> Range<usize> {
        0..0
    }

    fn aux_len(&self) -> usize {
        self.shape.len()
    }

    fn class(&self, backward: bool) -> LayerClass {
        if backward {
            LayerClass::DropoutBackward
        } else {
            LayerClass::DropoutForward
        }
    }

    fn forward(&self, _: &[f32], input: &[f32], out: &mut [f32], scratch: &mut OpScratch<'_>) {
        if !scratch.train || self.rate == 0.0 {
            // Identity pass-through; the mask is not written because the
            // eval-mode backward path never reads it.
            out.copy_from_slice(input);
            return;
        }
        for ((o, &x), m) in out.iter_mut().zip(input).zip(scratch.aux.iter_mut()) {
            let keep = scratch.rng.next_f32() >= self.rate;
            *m = keep as u32;
            *o = if keep { x * self.keep_scale } else { 0.0 };
        }
    }

    fn forward_batch(
        &self,
        _: &[f32],
        inputs: &[f32],
        outs: &mut [f32],
        batch: usize,
        scratch: &mut OpScratch<'_>,
    ) {
        if !scratch.train || self.rate == 0.0 {
            // Eval-mode fast path: one block copy instead of B pass-throughs.
            outs.copy_from_slice(inputs);
            return;
        }
        // Train mode: one flat sweep over the [batch][len] block. The
        // per-sample kernel draws one uniform per element in b-major
        // elementwise order — exactly this sweep's order — so the mask
        // stream (and therefore the output) is bit-identical to `batch`
        // successive per-sample forwards sharing the PRNG.
        debug_assert_eq!(inputs.len(), batch * self.shape.len());
        for ((o, &x), m) in outs.iter_mut().zip(inputs).zip(scratch.aux.iter_mut()) {
            let keep = scratch.rng.next_f32() >= self.rate;
            *m = keep as u32;
            *o = if keep { x * self.keep_scale } else { 0.0 };
        }
    }

    fn backward(
        &self,
        _: &[f32],
        _acts: Acts<'_>,
        delta_out: &mut [f32],
        delta_in: &mut [f32],
        _: &mut [f32],
        scratch: &mut OpScratch<'_>,
    ) {
        if delta_in.is_empty() {
            return;
        }
        if !scratch.train || self.rate == 0.0 {
            delta_in.copy_from_slice(delta_out);
            return;
        }
        for ((di, &d), &m) in delta_in.iter_mut().zip(delta_out.iter()).zip(scratch.aux.iter()) {
            *di = if m != 0 { d * self.keep_scale } else { 0.0 };
        }
    }

    fn backward_batch(
        &self,
        _: &[f32],
        _acts: BatchActs<'_>,
        deltas_out: &mut [f32],
        deltas_in: &mut [f32],
        _: &mut [f32],
        _batch: usize,
        scratch: &mut OpScratch<'_>,
    ) {
        if deltas_in.is_empty() {
            return;
        }
        if !scratch.train || self.rate == 0.0 {
            // Eval-mode fast path: one block copy.
            deltas_in.copy_from_slice(deltas_out);
            return;
        }
        // Block-wise: the [batch][len] mask words align elementwise with
        // the [batch][len] delta planes, so one flat sweep covers the batch.
        for ((di, &d), &m) in
            deltas_in.iter_mut().zip(deltas_out.iter()).zip(scratch.aux.iter())
        {
            *di = if m != 0 { d * self.keep_scale } else { 0.0 };
        }
    }

    fn dispatch(&self) -> Dispatch {
        // Both passes are one flat elementwise sweep over the
        // [batch][len] block; forward's b-major mask draws match the
        // per-sample PRNG order, so the sweep keeps bit-parity.
        Dispatch::uniform(KernelPath::BlockElementwise)
    }

    fn cost(&self) -> OpCost {
        let n = self.shape.len() as f64;
        OpCost {
            // Forward: one uniform draw + one scale per element; backward:
            // one masked scale per element.
            fwd_flops: 2.0 * n,
            bwd_flops: n,
            param_bytes: 0.0,
            // Forward also writes the u32 mask plane.
            fwd_act_bytes: 8.0 * n,
            bwd_act_bytes: 16.0 * n,
        }
    }

    fn split_points(&self) -> SplitSpec {
        // Parameter-free: there is nothing to split.
        SplitSpec::Unsplittable
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_kinds_are_registered() {
        let names = names();
        for n in ["input", "conv", "pool", "avgpool", "fc", "dropout", "output"] {
            assert!(names.iter().any(|x| x == n), "missing builtin kind {n}");
        }
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted);
    }

    #[test]
    fn lookup_unknown_kind_lists_registry() {
        let e = lookup("bogus").unwrap_err().to_string();
        assert!(e.contains("unknown layer kind 'bogus'") && e.contains("pool"), "{e}");
    }

    #[test]
    fn kind_of_covers_every_builtin_spec() {
        for (spec, want) in [
            (LayerSpec::Input { side: 9 }, "input"),
            (LayerSpec::conv(2, 3), "conv"),
            (LayerSpec::MaxPool { kernel: 2 }, "pool"),
            (LayerSpec::AvgPool { kernel: 2 }, "avgpool"),
            (LayerSpec::fc(4), "fc"),
            (LayerSpec::Dropout { rate: 0.5 }, "dropout"),
            (LayerSpec::Output { classes: 10 }, "output"),
            (LayerSpec::custom("warp", vec![]), "warp"),
        ] {
            assert_eq!(kind_of(&spec), want);
        }
    }

    #[test]
    fn custom_args_json_roundtrip() {
        let args = vec![("alpha".to_string(), 0.5), ("beta".to_string(), 2.0)];
        let j = args_to_json(&args);
        assert_eq!(args_from_json(&j).unwrap(), args);
    }

    #[test]
    fn shape_helpers() {
        let s = Shape::input(29);
        assert_eq!(s.len(), 841);
        assert!(!s.flat);
        let v = Shape::vector(50);
        assert_eq!(v.len(), 50);
        assert!(v.flat);
    }
}
