//! Fully-connected layer kernels (also used by the output layer, which is a
//! fully-connected layer followed by softmax).
//!
//! Weights are `[neuron][input]` row-major, so the forward pass is a
//! sequence of contiguous dot products and the backward input-gradient is a
//! saxpy over the weight rows — both auto-vectorizable.

use super::simd::{GEMM_KC, GEMM_MR};

/// Geometry for one fully-connected layer.
#[derive(Debug, Clone, Copy)]
pub struct FcShape {
    pub inputs: usize,
    pub outputs: usize,
}

impl FcShape {
    pub fn new(inputs: usize, outputs: usize) -> FcShape {
        assert!(inputs > 0 && outputs > 0);
        FcShape { inputs, outputs }
    }

    pub fn weight_len(&self) -> usize {
        self.inputs * self.outputs
    }

    /// Multiply-accumulates of one forward sample (one per weight).
    pub fn macs(&self) -> usize {
        self.inputs * self.outputs
    }
}

/// Forward: `out[n] = b[n] + Σ_i w[n][i]·in[i]` (pre-activations).
pub fn fc_forward(s: &FcShape, input: &[f32], weights: &[f32], biases: &[f32], out: &mut [f32]) {
    debug_assert_eq!(input.len(), s.inputs);
    debug_assert_eq!(weights.len(), s.weight_len());
    debug_assert_eq!(biases.len(), s.outputs);
    debug_assert_eq!(out.len(), s.outputs);
    for n in 0..s.outputs {
        let row = &weights[n * s.inputs..(n + 1) * s.inputs];
        out[n] = super::simd::dot(row, input) + biases[n];
    }
}

/// Batched forward over `batch` samples laid out `[b][inputs]` →
/// `[b][outputs]` — the weight-stationary variant of [`fc_forward`] with
/// the batch as the SIMD lane axis ([`super::simd::lane_dot`]): each
/// weight row is loaded once per batch and dotted against every sample
/// (row-stationary GEMV → GEMM), instead of streaming the whole weight
/// matrix through the cache once per sample.
///
/// Bit-identical to `batch` independent [`fc_forward`] calls: each output
/// element is the same `dot(row, input) + bias` expression.
pub fn fc_forward_batch(
    s: &FcShape,
    inputs: &[f32],
    weights: &[f32],
    biases: &[f32],
    outs: &mut [f32],
    batch: usize,
) {
    debug_assert_eq!(inputs.len(), batch * s.inputs);
    debug_assert_eq!(weights.len(), s.weight_len());
    debug_assert_eq!(biases.len(), s.outputs);
    debug_assert_eq!(outs.len(), batch * s.outputs);
    for n in 0..s.outputs {
        let row = &weights[n * s.inputs..(n + 1) * s.inputs];
        super::simd::lane_dot(row, inputs, s.inputs, batch, &mut outs[n..], s.outputs, biases[n]);
    }
}

/// Cache-blocked batched forward ([`super::simd::MathPolicy::Fast`] route):
/// the reduction axis is chunked into [`GEMM_KC`]-long panels and the
/// weight rows register-blocked [`GEMM_MR`] at a time, so one k-panel of
/// `MR` weight rows stays L1-resident while the batch streams past.
/// Reassociates the reduction (bias hoisted out of the dot chain, panel
/// partial sums added panel-by-panel), so results agree with
/// [`fc_forward_batch`] only to rounding.
pub fn fc_forward_batch_blocked(
    s: &FcShape,
    inputs: &[f32],
    weights: &[f32],
    biases: &[f32],
    outs: &mut [f32],
    batch: usize,
) {
    debug_assert_eq!(inputs.len(), batch * s.inputs);
    debug_assert_eq!(weights.len(), s.weight_len());
    debug_assert_eq!(biases.len(), s.outputs);
    debug_assert_eq!(outs.len(), batch * s.outputs);
    for b in 0..batch {
        outs[b * s.outputs..(b + 1) * s.outputs].copy_from_slice(biases);
    }
    let mut k0 = 0;
    while k0 < s.inputs {
        let kc = GEMM_KC.min(s.inputs - k0);
        let mut n0 = 0;
        while n0 < s.outputs {
            let mr = GEMM_MR.min(s.outputs - n0);
            for b in 0..batch {
                let x = &inputs[b * s.inputs + k0..b * s.inputs + k0 + kc];
                let out = &mut outs[b * s.outputs + n0..b * s.outputs + n0 + mr];
                for (r, o) in out.iter_mut().enumerate() {
                    let n = n0 + r;
                    let row = &weights[n * s.inputs + k0..n * s.inputs + k0 + kc];
                    *o += super::simd::dot(row, x);
                }
            }
            n0 += mr;
        }
        k0 += kc;
    }
}

/// Backward: accumulate `wgrads[n][i] += delta[n]·in[i]`,
/// `bgrads[n] += delta[n]`, and compute `dinput[i] = Σ_n w[n][i]·delta[n]`
/// (w.r.t. this layer's input; caller applies the previous activation's
/// derivative). Pass an empty `dinput` to skip.
pub fn fc_backward(
    s: &FcShape,
    input: &[f32],
    weights: &[f32],
    delta: &[f32],
    wgrads: &mut [f32],
    bgrads: &mut [f32],
    dinput: &mut [f32],
) {
    debug_assert_eq!(input.len(), s.inputs);
    debug_assert_eq!(weights.len(), s.weight_len());
    debug_assert_eq!(delta.len(), s.outputs);
    debug_assert_eq!(wgrads.len(), s.weight_len());
    debug_assert_eq!(bgrads.len(), s.outputs);
    let want_dinput = !dinput.is_empty();
    if want_dinput {
        debug_assert_eq!(dinput.len(), s.inputs);
        dinput.fill(0.0);
    }
    for n in 0..s.outputs {
        let d = delta[n];
        bgrads[n] += d;
        let wrow = &weights[n * s.inputs..(n + 1) * s.inputs];
        let grow = &mut wgrads[n * s.inputs..(n + 1) * s.inputs];
        for i in 0..s.inputs {
            grow[i] += d * input[i];
        }
        if want_dinput {
            for i in 0..s.inputs {
                dinput[i] += d * wrow[i];
            }
        }
    }
}

/// Batched backward over `batch` samples (`inputs`/`dinputs` laid out
/// `[b][inputs]`, `deltas` `[b][outputs]`) — the GEMM-shaped variant of
/// [`fc_backward`]: the weight-gradient matrix accumulates the sum of
/// per-sample outer products `Σ_b δ_b ⊗ x_b` row by row, cache-blocked
/// along the input axis in [`GEMM_KC`]-long panels so each weight-row /
/// gradient-row panel stays L1-resident while the batch streams past.
/// `wgrads`/`bgrads` receive the **batch-summed** gradients; `dinputs` is
/// overwritten per sample (empty slice to skip).
///
/// Bit-identical to `batch` successive [`fc_backward`] calls sharing the
/// gradient buffers under **every** math policy: each gradient element
/// belongs to exactly one `(n, i)` pair, so k-blocking only reorders
/// writes to *different* elements — every element still receives its
/// per-sample contributions in ascending sample order.
pub fn fc_backward_batch(
    s: &FcShape,
    inputs: &[f32],
    weights: &[f32],
    deltas: &[f32],
    wgrads: &mut [f32],
    bgrads: &mut [f32],
    dinputs: &mut [f32],
    batch: usize,
) {
    debug_assert_eq!(inputs.len(), batch * s.inputs);
    debug_assert_eq!(weights.len(), s.weight_len());
    debug_assert_eq!(deltas.len(), batch * s.outputs);
    debug_assert_eq!(wgrads.len(), s.weight_len());
    debug_assert_eq!(bgrads.len(), s.outputs);
    let want_dinput = !dinputs.is_empty();
    if want_dinput {
        debug_assert_eq!(dinputs.len(), batch * s.inputs);
        dinputs.fill(0.0);
    }
    let mut k0 = 0;
    while k0 < s.inputs {
        let kc = GEMM_KC.min(s.inputs - k0);
        for n in 0..s.outputs {
            let wrow = &weights[n * s.inputs + k0..n * s.inputs + k0 + kc];
            let grow = &mut wgrads[n * s.inputs + k0..n * s.inputs + k0 + kc];
            for b in 0..batch {
                let d = deltas[b * s.outputs + n];
                // The bias gradient has no k axis: charge it on the first
                // panel only (still ascending sample order per n).
                if k0 == 0 {
                    bgrads[n] += d;
                }
                let input = &inputs[b * s.inputs + k0..b * s.inputs + k0 + kc];
                for i in 0..kc {
                    grow[i] += d * input[i];
                }
                if want_dinput {
                    let dinp = &mut dinputs[b * s.inputs + k0..b * s.inputs + k0 + kc];
                    for i in 0..kc {
                        dinp[i] += d * wrow[i];
                    }
                }
            }
        }
        k0 += kc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn forward_known_values() {
        let s = FcShape::new(3, 2);
        let input = [1.0, 2.0, 3.0];
        let weights = [1.0, 0.0, 0.0, 0.0, 1.0, 1.0]; // n0 = in0, n1 = in1+in2
        let biases = [0.5, -0.5];
        let mut out = [0.0; 2];
        fc_forward(&s, &input, &weights, &biases, &mut out);
        assert_eq!(out, [1.5, 4.5]);
    }

    #[test]
    fn backward_grads_match_finite_difference() {
        let mut rng = Pcg32::seeded(5);
        let s = FcShape::new(7, 4);
        let input: Vec<f32> = (0..s.inputs).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut weights: Vec<f32> = (0..s.weight_len()).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let biases: Vec<f32> = (0..s.outputs).map(|_| rng.uniform(-1.0, 1.0)).collect();
        // Loss = Σ c_n·out_n with random coefficients → delta = c.
        let coeff: Vec<f32> = (0..s.outputs).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut wg = vec![0.0; s.weight_len()];
        let mut bg = vec![0.0; s.outputs];
        let mut din = vec![0.0; s.inputs];
        fc_backward(&s, &input, &weights, &coeff, &mut wg, &mut bg, &mut din);

        let loss = |w: &[f32], inp: &[f32]| -> f32 {
            let mut out = vec![0.0; s.outputs];
            fc_forward(&s, inp, w, &biases, &mut out);
            out.iter().zip(&coeff).map(|(o, c)| o * c).sum()
        };
        let h = 1e-3;
        for idx in [0, 3, 11, s.weight_len() - 1] {
            let orig = weights[idx];
            weights[idx] = orig + h;
            let lp = loss(&weights, &input);
            weights[idx] = orig - h;
            let lm = loss(&weights, &input);
            weights[idx] = orig;
            let fd = (lp - lm) / (2.0 * h);
            assert!((fd - wg[idx]).abs() < 1e-2, "w[{idx}] fd={fd} vs {}", wg[idx]);
        }
        let mut input2 = input.clone();
        for idx in [0, 4, s.inputs - 1] {
            let orig = input2[idx];
            input2[idx] = orig + h;
            let lp = loss(&weights, &input2);
            input2[idx] = orig - h;
            let lm = loss(&weights, &input2);
            input2[idx] = orig;
            let fd = (lp - lm) / (2.0 * h);
            assert!((fd - din[idx]).abs() < 1e-2, "din[{idx}] fd={fd} vs {}", din[idx]);
        }
        for (b, c) in bg.iter().zip(&coeff) {
            assert!((b - c).abs() < 1e-6, "bias grad equals delta");
        }
    }

    #[test]
    fn batched_forward_bit_identical_to_per_sample() {
        let mut rng = Pcg32::seeded(21);
        let s = FcShape::new(13, 5);
        let batch = 4;
        let inputs: Vec<f32> =
            (0..batch * s.inputs).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let weights: Vec<f32> = (0..s.weight_len()).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let biases: Vec<f32> = (0..s.outputs).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut batched = vec![0.0; batch * s.outputs];
        fc_forward_batch(&s, &inputs, &weights, &biases, &mut batched, batch);
        for b in 0..batch {
            let mut single = vec![0.0; s.outputs];
            fc_forward(&s, &inputs[b * s.inputs..(b + 1) * s.inputs], &weights, &biases, &mut single);
            assert_eq!(&batched[b * s.outputs..(b + 1) * s.outputs], single.as_slice());
        }
    }

    #[test]
    fn batched_backward_bit_identical_to_per_sample() {
        let mut rng = Pcg32::seeded(23);
        let s = FcShape::new(11, 6);
        let batch = 5;
        let inputs: Vec<f32> = (0..batch * s.inputs).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let weights: Vec<f32> = (0..s.weight_len()).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let deltas: Vec<f32> = (0..batch * s.outputs).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut wg_b = vec![0.0; s.weight_len()];
        let mut bg_b = vec![0.0; s.outputs];
        let mut din_b = vec![0.0; batch * s.inputs];
        fc_backward_batch(&s, &inputs, &weights, &deltas, &mut wg_b, &mut bg_b, &mut din_b, batch);
        let mut wg = vec![0.0; s.weight_len()];
        let mut bg = vec![0.0; s.outputs];
        let mut din = vec![0.0; batch * s.inputs];
        for b in 0..batch {
            fc_backward(
                &s,
                &inputs[b * s.inputs..(b + 1) * s.inputs],
                &weights,
                &deltas[b * s.outputs..(b + 1) * s.outputs],
                &mut wg,
                &mut bg,
                &mut din[b * s.inputs..(b + 1) * s.inputs],
            );
        }
        assert_eq!(wg_b, wg);
        assert_eq!(bg_b, bg);
        assert_eq!(din_b, din);
    }

    #[test]
    fn blocked_forward_matches_exact_to_rounding() {
        let mut rng = Pcg32::seeded(29);
        // inputs > GEMM_KC so the k-panel loop actually splits the
        // reduction; outputs not a multiple of GEMM_MR for the edge block.
        let s = FcShape::new(GEMM_KC + 45, 2 * GEMM_MR + 1);
        let batch = 6;
        let inputs: Vec<f32> = (0..batch * s.inputs).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let weights: Vec<f32> = (0..s.weight_len()).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let biases: Vec<f32> = (0..s.outputs).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut exact = vec![0.0; batch * s.outputs];
        fc_forward_batch(&s, &inputs, &weights, &biases, &mut exact, batch);
        let mut blocked = vec![0.0; batch * s.outputs];
        fc_forward_batch_blocked(&s, &inputs, &weights, &biases, &mut blocked, batch);
        for (i, (e, f)) in exact.iter().zip(&blocked).enumerate() {
            assert!(
                (e - f).abs() < 1e-4 * (1.0 + e.abs()),
                "out[{i}]: exact {e} vs blocked {f}"
            );
        }
    }

    #[test]
    fn backward_k_blocking_bit_identical_across_panel_boundary() {
        let mut rng = Pcg32::seeded(31);
        // inputs > GEMM_KC: the per-element sample order must survive the
        // panel split bitwise.
        let s = FcShape::new(GEMM_KC + 13, 3);
        let batch = 4;
        let inputs: Vec<f32> = (0..batch * s.inputs).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let weights: Vec<f32> = (0..s.weight_len()).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let deltas: Vec<f32> = (0..batch * s.outputs).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut wg_b = vec![0.0; s.weight_len()];
        let mut bg_b = vec![0.0; s.outputs];
        let mut din_b = vec![0.0; batch * s.inputs];
        fc_backward_batch(&s, &inputs, &weights, &deltas, &mut wg_b, &mut bg_b, &mut din_b, batch);
        let mut wg = vec![0.0; s.weight_len()];
        let mut bg = vec![0.0; s.outputs];
        let mut din = vec![0.0; batch * s.inputs];
        for b in 0..batch {
            fc_backward(
                &s,
                &inputs[b * s.inputs..(b + 1) * s.inputs],
                &weights,
                &deltas[b * s.outputs..(b + 1) * s.outputs],
                &mut wg,
                &mut bg,
                &mut din[b * s.inputs..(b + 1) * s.inputs],
            );
        }
        assert_eq!(wg_b, wg);
        assert_eq!(bg_b, bg);
        assert_eq!(din_b, din);
    }

    #[test]
    fn backward_accumulates() {
        let s = FcShape::new(2, 2);
        let input = [1.0, 2.0];
        let weights = [0.1, 0.2, 0.3, 0.4];
        let delta = [1.0, 1.0];
        let mut wg = vec![0.0; 4];
        let mut bg = vec![0.0; 2];
        fc_backward(&s, &input, &weights, &delta, &mut wg, &mut bg, &mut []);
        fc_backward(&s, &input, &weights, &delta, &mut wg, &mut bg, &mut []);
        assert_eq!(wg, vec![2.0, 4.0, 2.0, 4.0]);
        assert_eq!(bg, vec![2.0, 2.0]);
    }
}
