//! Derived per-layer dimensions and the flat parameter layout.
//!
//! From an [`ArchSpec`](crate::config::ArchSpec) we compute, per layer, the
//! input/output geometry and the range this layer's parameters occupy in the
//! single flat parameter vector. The flat layout is what makes CHAOS's
//! per-layer publication cheap: a layer's weights are one contiguous span,
//! shared between workers, updated with one pass.

use crate::config::{ArchSpec, LayerSpec};
use std::ops::Range;

/// Geometry + parameter layout for one layer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerDims {
    pub spec: LayerSpec,
    /// Input feature maps (1 for the input layer itself).
    pub in_maps: usize,
    /// Input side length (square maps). For FC/Output this is 1 and
    /// `in_maps` carries the flattened neuron count.
    pub in_side: usize,
    /// Output feature maps.
    pub out_maps: usize,
    /// Output side length.
    pub out_side: usize,
    /// Number of weights (excluding biases).
    pub weights: usize,
    /// Number of biases.
    pub biases: usize,
    /// Range of this layer's parameters in the flat parameter vector
    /// (weights first, then biases).
    pub params: Range<usize>,
}

impl LayerDims {
    /// Output activation element count.
    pub fn out_len(&self) -> usize {
        self.out_maps * self.out_side * self.out_side
    }

    /// Input activation element count.
    pub fn in_len(&self) -> usize {
        self.in_maps * self.in_side * self.in_side
    }

    /// Total parameter count (weights + biases).
    pub fn param_count(&self) -> usize {
        self.weights + self.biases
    }

    /// Split a flat layer-parameter slice into (weights, biases).
    pub fn split_params<'a>(&self, layer_params: &'a [f32]) -> (&'a [f32], &'a [f32]) {
        debug_assert_eq!(layer_params.len(), self.param_count());
        layer_params.split_at(self.weights)
    }

    /// Mutable variant of [`Self::split_params`].
    pub fn split_params_mut<'a>(
        &self,
        layer_params: &'a mut [f32],
    ) -> (&'a mut [f32], &'a mut [f32]) {
        debug_assert_eq!(layer_params.len(), self.param_count());
        layer_params.split_at_mut(self.weights)
    }
}

/// Compute dims for every layer of an architecture. The returned vector is
/// parallel to `arch.layers`.
pub fn compute_dims(arch: &ArchSpec) -> Vec<LayerDims> {
    arch.validate().expect("invalid architecture");
    let mut dims = Vec::with_capacity(arch.layers.len());
    let mut maps = 1usize;
    let mut side = 0usize;
    let mut offset = 0usize;
    for spec in &arch.layers {
        let d = match *spec {
            LayerSpec::Input { side: s } => {
                side = s;
                LayerDims {
                    spec: *spec,
                    in_maps: 1,
                    in_side: s,
                    out_maps: 1,
                    out_side: s,
                    weights: 0,
                    biases: 0,
                    params: offset..offset,
                }
            }
            LayerSpec::Conv { maps: m, kernel } => {
                let out_side = side - kernel + 1;
                let weights = m * maps * kernel * kernel;
                let d = LayerDims {
                    spec: *spec,
                    in_maps: maps,
                    in_side: side,
                    out_maps: m,
                    out_side,
                    weights,
                    biases: m,
                    params: offset..offset + weights + m,
                };
                maps = m;
                side = out_side;
                d
            }
            LayerSpec::MaxPool { kernel } => {
                let out_side = side / kernel;
                let d = LayerDims {
                    spec: *spec,
                    in_maps: maps,
                    in_side: side,
                    out_maps: maps,
                    out_side,
                    weights: 0,
                    biases: 0,
                    params: offset..offset,
                };
                side = out_side;
                d
            }
            LayerSpec::FullyConnected { neurons } => {
                let inputs = maps * side * side;
                let weights = neurons * inputs;
                let d = LayerDims {
                    spec: *spec,
                    in_maps: inputs,
                    in_side: 1,
                    out_maps: neurons,
                    out_side: 1,
                    weights,
                    biases: neurons,
                    params: offset..offset + weights + neurons,
                };
                maps = neurons;
                side = 1;
                d
            }
            LayerSpec::Output { classes } => {
                let inputs = maps * side * side;
                let weights = classes * inputs;
                let d = LayerDims {
                    spec: *spec,
                    in_maps: inputs,
                    in_side: 1,
                    out_maps: classes,
                    out_side: 1,
                    weights,
                    biases: classes,
                    params: offset..offset + weights + classes,
                };
                maps = classes;
                side = 1;
                d
            }
        };
        offset = d.params.end;
        dims.push(d);
    }
    dims
}

/// Total parameter count of an architecture.
pub fn total_params(dims: &[LayerDims]) -> usize {
    dims.last().map(|d| d.params.end).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchSpec;

    /// Paper Table 2 weight counts, per parameterized layer.
    #[test]
    fn small_matches_table2() {
        let dims = compute_dims(&ArchSpec::small());
        // conv1: 85, conv2: 1260, fc: 4550, out: 510
        let params: Vec<usize> =
            dims.iter().filter(|d| d.param_count() > 0).map(|d| d.param_count()).collect();
        assert_eq!(params, vec![85, 1260, 4550, 510]);
        assert_eq!(total_params(&dims), 85 + 1260 + 4550 + 510);
    }

    #[test]
    fn medium_matches_table2() {
        let dims = compute_dims(&ArchSpec::medium());
        let params: Vec<usize> =
            dims.iter().filter(|d| d.param_count() > 0).map(|d| d.param_count()).collect();
        assert_eq!(params, vec![340, 20040, 54150, 1510]);
    }

    #[test]
    fn large_matches_table2() {
        let dims = compute_dims(&ArchSpec::large());
        let params: Vec<usize> =
            dims.iter().filter(|d| d.param_count() > 0).map(|d| d.param_count()).collect();
        assert_eq!(params, vec![340, 30060, 216100, 135150, 1510]);
    }

    #[test]
    fn small_neuron_counts_match_table2() {
        let dims = compute_dims(&ArchSpec::small());
        let neurons: Vec<usize> = dims.iter().map(|d| d.out_len()).collect();
        // input 841, conv 3380, pool 845, conv 810, pool 90, fc 50, out 10
        assert_eq!(neurons, vec![841, 3380, 845, 810, 90, 50, 10]);
    }

    #[test]
    fn large_neuron_counts_match_table2() {
        let dims = compute_dims(&ArchSpec::large());
        let neurons: Vec<usize> = dims.iter().map(|d| d.out_len()).collect();
        // Table 2 (with the documented pool-3 fix -> 3x3x100 = 900)
        assert_eq!(neurons, vec![841, 13520, 13520, 29040, 7260, 3600, 900, 150, 10]);
    }

    #[test]
    fn ranges_are_contiguous_and_disjoint() {
        for name in crate::config::PAPER_ARCHS {
            let dims = compute_dims(&ArchSpec::by_name(name).unwrap());
            let mut expected_start = 0;
            for d in &dims {
                assert_eq!(d.params.start, expected_start, "{name}: gap in layout");
                assert_eq!(d.params.len(), d.param_count());
                expected_start = d.params.end;
            }
        }
    }

    #[test]
    fn split_params_partition() {
        let dims = compute_dims(&ArchSpec::small());
        let conv1 = &dims[1];
        let buf = vec![0.0f32; conv1.param_count()];
        let (w, b) = conv1.split_params(&buf);
        assert_eq!(w.len(), 80);
        assert_eq!(b.len(), 5);
    }
}
