//! Derived per-layer dimensions and the flat parameter layout.
//!
//! From an [`ArchSpec`](crate::config::ArchSpec) we compute, per layer, the
//! input/output geometry and the range this layer's parameters occupy in the
//! single flat parameter vector. The flat layout is what makes CHAOS's
//! per-layer publication cheap: a layer's weights are one contiguous span,
//! shared between workers, updated with one pass.
//!
//! Geometry and parameter counts are *not* hard-coded per layer type: every
//! layer is folded through its registered kind
//! ([`crate::nn::layer::LayerKind`]), so a kind registered at runtime lays
//! out exactly like a built-in one.

use super::layer::{self, LayerCtx, Shape};
use crate::config::{ArchSpec, LayerSpec};
use std::ops::Range;

/// Geometry + parameter layout for one layer.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerDims {
    pub spec: LayerSpec,
    /// Input feature maps (1 for the input layer itself). For kinds that
    /// flatten their input (fc/output), this is the flattened neuron count.
    pub in_maps: usize,
    /// Input side length (square maps; 1 for flattened input).
    pub in_side: usize,
    /// Output feature maps (the neuron count for flat outputs).
    pub out_maps: usize,
    /// Output side length.
    pub out_side: usize,
    /// Whether the output is a flattened vector (post-fc stage) — lets
    /// pass-through kinds compile a faithful [`Shape`] without guessing.
    pub flat: bool,
    /// Number of weights (excluding biases).
    pub weights: usize,
    /// Number of biases.
    pub biases: usize,
    /// Range of this layer's parameters in the flat parameter vector
    /// (weights first, then biases).
    pub params: Range<usize>,
}

impl LayerDims {
    /// Output activation element count.
    pub fn out_len(&self) -> usize {
        self.out_maps * self.out_side * self.out_side
    }

    /// Input activation element count.
    pub fn in_len(&self) -> usize {
        self.in_maps * self.in_side * self.in_side
    }

    /// Total parameter count (weights + biases).
    pub fn param_count(&self) -> usize {
        self.weights + self.biases
    }

    /// Split a flat layer-parameter slice into (weights, biases).
    pub fn split_params<'a>(&self, layer_params: &'a [f32]) -> (&'a [f32], &'a [f32]) {
        debug_assert_eq!(layer_params.len(), self.param_count());
        layer_params.split_at(self.weights)
    }

    /// Mutable variant of [`Self::split_params`].
    pub fn split_params_mut<'a>(
        &self,
        layer_params: &'a mut [f32],
    ) -> (&'a mut [f32], &'a mut [f32]) {
        debug_assert_eq!(layer_params.len(), self.param_count());
        layer_params.split_at_mut(self.weights)
    }
}

/// Compute dims for every layer of an architecture, or the first structural
/// error. The returned vector is parallel to `arch.layers`. This is also
/// the engine behind [`ArchSpec::validate`].
pub fn try_compute_dims(arch: &ArchSpec) -> anyhow::Result<Vec<LayerDims>> {
    let n = arch.layers.len();
    anyhow::ensure!(n > 0, "architecture must start with an input layer");
    let mut dims = Vec::with_capacity(n);
    let mut shape = Shape::input(0);
    let mut offset = 0usize;
    let mut last_terminal = false;
    for (i, spec) in arch.layers.iter().enumerate() {
        let kind = layer::kind_for(spec)?;
        if i == 0 {
            anyhow::ensure!(kind.is_input(), "architecture must start with an input layer");
        } else {
            anyhow::ensure!(!kind.is_input(), "layer {i}: input after start");
        }
        if kind.is_terminal() && i != n - 1 {
            anyhow::bail!("layer {i}: output before the end");
        }
        last_terminal = kind.is_terminal();
        let ctx = LayerCtx { arch, index: i };
        let out = kind.out_shape(spec, shape, &ctx)?;
        // Kinds that flatten see their input through the fully-connected
        // layout convention (in_maps = element count, side 1).
        let input = if kind.flattens_input() { Shape::vector(shape.len()) } else { shape };
        let (weights, biases) = kind.param_counts(spec, shape);
        let d = LayerDims {
            spec: spec.clone(),
            in_maps: if i == 0 { 1 } else { input.maps },
            in_side: if i == 0 { out.side } else { input.side },
            out_maps: out.maps,
            out_side: out.side,
            flat: out.flat,
            weights,
            biases,
            params: offset..offset + weights + biases,
        };
        offset = d.params.end;
        dims.push(d);
        shape = out;
    }
    anyhow::ensure!(last_terminal, "architecture must end with an output layer");
    Ok(dims)
}

/// Compute dims for every layer of an architecture. The returned vector is
/// parallel to `arch.layers`. Panics on an invalid architecture (use
/// [`try_compute_dims`] or [`ArchSpec::validate`] for fallible checking).
pub fn compute_dims(arch: &ArchSpec) -> Vec<LayerDims> {
    try_compute_dims(arch).expect("invalid architecture")
}

/// Total parameter count of an architecture.
pub fn total_params(dims: &[LayerDims]) -> usize {
    dims.last().map(|d| d.params.end).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchSpec;

    /// Paper Table 2 weight counts, per parameterized layer.
    #[test]
    fn small_matches_table2() {
        let dims = compute_dims(&ArchSpec::small());
        // conv1: 85, conv2: 1260, fc: 4550, out: 510
        let params: Vec<usize> =
            dims.iter().filter(|d| d.param_count() > 0).map(|d| d.param_count()).collect();
        assert_eq!(params, vec![85, 1260, 4550, 510]);
        assert_eq!(total_params(&dims), 85 + 1260 + 4550 + 510);
    }

    #[test]
    fn medium_matches_table2() {
        let dims = compute_dims(&ArchSpec::medium());
        let params: Vec<usize> =
            dims.iter().filter(|d| d.param_count() > 0).map(|d| d.param_count()).collect();
        assert_eq!(params, vec![340, 20040, 54150, 1510]);
    }

    #[test]
    fn large_matches_table2() {
        let dims = compute_dims(&ArchSpec::large());
        let params: Vec<usize> =
            dims.iter().filter(|d| d.param_count() > 0).map(|d| d.param_count()).collect();
        assert_eq!(params, vec![340, 30060, 216100, 135150, 1510]);
    }

    #[test]
    fn small_neuron_counts_match_table2() {
        let dims = compute_dims(&ArchSpec::small());
        let neurons: Vec<usize> = dims.iter().map(|d| d.out_len()).collect();
        // input 841, conv 3380, pool 845, conv 810, pool 90, fc 50, out 10
        assert_eq!(neurons, vec![841, 3380, 845, 810, 90, 50, 10]);
    }

    #[test]
    fn large_neuron_counts_match_table2() {
        let dims = compute_dims(&ArchSpec::large());
        let neurons: Vec<usize> = dims.iter().map(|d| d.out_len()).collect();
        // Table 2 (with the documented pool-3 fix -> 3x3x100 = 900)
        assert_eq!(neurons, vec![841, 13520, 13520, 29040, 7260, 3600, 900, 150, 10]);
    }

    #[test]
    fn ranges_are_contiguous_and_disjoint() {
        for name in crate::config::PAPER_ARCHS {
            let dims = compute_dims(&ArchSpec::by_name(name).unwrap());
            let mut expected_start = 0;
            for d in &dims {
                assert_eq!(d.params.start, expected_start, "{name}: gap in layout");
                assert_eq!(d.params.len(), d.param_count());
                expected_start = d.params.end;
            }
        }
    }

    #[test]
    fn split_params_partition() {
        let dims = compute_dims(&ArchSpec::small());
        let conv1 = &dims[1];
        let buf = vec![0.0f32; conv1.param_count()];
        let (w, b) = conv1.split_params(&buf);
        assert_eq!(w.len(), 80);
        assert_eq!(b.len(), 5);
    }

    #[test]
    fn padded_strided_conv_dims() {
        use crate::config::{Act, LayerSpec};
        let arch = ArchSpec {
            name: "padded".into(),
            layers: vec![
                LayerSpec::Input { side: 29 },
                LayerSpec::conv_ex(8, 5, 2, 2, Act::Relu), // (29+4-5)/2+1 = 15
                LayerSpec::AvgPool { kernel: 3 },          // 5
                LayerSpec::Dropout { rate: 0.5 },          // 5
                LayerSpec::fc(20),
                LayerSpec::Output { classes: 10 },
            ],
            paper_epochs: 1,
        };
        let dims = try_compute_dims(&arch).unwrap();
        assert_eq!(dims[1].out_side, 15);
        assert_eq!(dims[1].weights, 8 * 1 * 5 * 5);
        assert_eq!(dims[2].out_side, 5);
        assert_eq!(dims[3].out_len(), 8 * 5 * 5);
        assert_eq!(dims[3].param_count(), 0);
        assert_eq!(dims[4].in_maps, 8 * 5 * 5);
        assert_eq!(dims[4].weights, 20 * 8 * 5 * 5);
    }
}
