//! From-scratch CNN substrate (the analogue of the Cireşan C++ network the
//! paper parallelizes): convolution, max-pooling, fully-connected and
//! softmax-output layers over flat f32 buffers, with per-layer gradient
//! emission hooks that the CHAOS coordinator uses for its controlled
//! Hogwild updates.

pub mod activation;
pub mod conv;
pub mod dims;
pub mod fc;
pub mod init;
pub mod network;
pub mod pool;
pub mod simd;

pub use dims::{compute_dims, total_params, LayerDims};
pub use network::{Network, ParamSource, Scratch};
