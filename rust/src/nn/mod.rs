//! From-scratch CNN substrate (the analogue of the Cireşan C++ network the
//! paper parallelizes): an open, registry-driven layer vocabulary
//! ([`layer`] — convolution with optional zero padding/stride, max and
//! average pooling, fully-connected with selectable activations, dropout,
//! softmax output, plus anything registered at runtime) compiled into flat
//! f32 op pipelines, with per-layer gradient emission hooks that the CHAOS
//! coordinator uses for its controlled Hogwild updates. Forward-only
//! consumers (evaluation phases, the native serving engine) run the same
//! pipeline over whole batches through [`batch::BatchPlan`], amortizing
//! parameter loads across `[B][len]` activation arenas.

pub mod activation;
pub mod audit;
pub mod batch;
pub mod conv;
pub mod dims;
pub mod fc;
pub mod init;
pub mod layer;
pub mod network;
pub mod pool;
pub mod simd;

pub use audit::{
    audit_cost, audit_dataflow, audit_dispatch, boundary_act_elems, ArenaExtent, ArenaLayout,
    CostReport, DataflowDefect, DataflowReport, Dispatch, KernelPath, KernelReport, OpCost,
};
pub use batch::{BatchPlan, BatchScratch};
pub use dims::{compute_dims, total_params, LayerDims};
pub use layer::{Acts, BatchActs, LayerCtx, LayerKind, LayerOp, OpScratch, Shape, SplitSpec};
pub use network::{Network, ParamSource, Scratch};
pub use simd::MathPolicy;
