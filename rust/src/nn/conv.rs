//! Convolutional layer kernels — the application hot-spot.
//!
//! The paper measures ~94% (small net) to ~99% (large net) of training time
//! in these loops (Table 1), so they are written for the auto-vectorizer:
//! the innermost loop always walks contiguous `out_side`-long rows of both
//! operands with a constant scalar weight — a saxpy/dot shape that LLVM
//! turns into packed FMA, the same structure the paper obtained with
//! `#pragma omp simd` on the Phi's 512-bit VPU (Listing 1 reports a 3.98×
//! estimated vector speedup; our `simd_conv` bench reproduces the
//! scalar-vs-vector comparison).
//!
//! Layout: input/output activations are `[maps][side][side]` flat;
//! weights are `[out_map][in_map][ky][kx]` flat, then `[out_map]` biases.

use super::simd::MathPolicy;

/// Geometry for one convolution.
#[derive(Debug, Clone, Copy)]
pub struct ConvShape {
    pub in_maps: usize,
    pub in_side: usize,
    pub out_maps: usize,
    pub out_side: usize,
    pub kernel: usize,
}

impl ConvShape {
    pub fn valid(in_maps: usize, in_side: usize, out_maps: usize, kernel: usize) -> ConvShape {
        assert!(kernel <= in_side && kernel > 0);
        ConvShape { in_maps, in_side, out_maps, out_side: in_side - kernel + 1, kernel }
    }

    pub fn in_len(&self) -> usize {
        self.in_maps * self.in_side * self.in_side
    }

    pub fn out_len(&self) -> usize {
        self.out_maps * self.out_side * self.out_side
    }

    pub fn weight_len(&self) -> usize {
        self.out_maps * self.in_maps * self.kernel * self.kernel
    }
}

/// Forward convolution producing **pre-activations**:
/// `out[m][y][x] = b[m] + Σ_j Σ_ky Σ_kx w[m][j][ky][kx] · in[j][y+ky][x+kx]`.
///
/// The caller applies the activation afterwards (the network keeps
/// post-activation values for the backward pass).
pub fn conv_forward(
    s: &ConvShape,
    input: &[f32],
    weights: &[f32],
    biases: &[f32],
    out: &mut [f32],
) {
    debug_assert_eq!(input.len(), s.in_len());
    debug_assert_eq!(weights.len(), s.weight_len());
    debug_assert_eq!(biases.len(), s.out_maps);
    debug_assert_eq!(out.len(), s.out_len());

    let os = s.out_side;
    let is = s.in_side;
    let k = s.kernel;
    let omap_len = os * os;
    let imap_len = is * is;

    for m in 0..s.out_maps {
        let out_map = &mut out[m * omap_len..(m + 1) * omap_len];
        out_map.fill(biases[m]);
        let wm = &weights[m * s.in_maps * k * k..];
        for j in 0..s.in_maps {
            let in_map = &input[j * imap_len..(j + 1) * imap_len];
            let wj = &wm[j * k * k..(j + 1) * k * k];
            for ky in 0..k {
                for kx in 0..k {
                    let w = wj[ky * k + kx];
                    for y in 0..os {
                        let in_row = &in_map[(y + ky) * is + kx..(y + ky) * is + kx + os];
                        let out_row = &mut out_map[y * os..y * os + os];
                        // saxpy: vectorizes (constant w, contiguous rows)
                        for x in 0..os {
                            out_row[x] += w * in_row[x];
                        }
                    }
                }
            }
        }
    }
}

/// Batched forward convolution over `batch` samples laid out `[b][in_len]`
/// → `[b][out_len]` — the weight-stationary variant of [`conv_forward`]
/// with the **batch as the SIMD lane axis**: each kernel tap is loaded once
/// per batch and broadcast across every sample's rows via
/// [`super::simd::lane_axpy`] (lane stride = one sample plane), so at
/// batch ≥ 8 the weight traffic amortizes away and every lane's row stays
/// contiguous for the auto-vectorizer.
///
/// Bit-identity contract: every output element receives its additions in
/// exactly the order of the per-sample kernel (bias, then `j → ky → kx`
/// taps), so the result equals `batch` independent [`conv_forward`] calls
/// bitwise (enforced by `rust/tests/batch_forward.rs`).
pub fn conv_forward_batch(
    s: &ConvShape,
    inputs: &[f32],
    weights: &[f32],
    biases: &[f32],
    outs: &mut [f32],
    batch: usize,
) {
    let in_len = s.in_len();
    let out_len = s.out_len();
    debug_assert_eq!(inputs.len(), batch * in_len);
    debug_assert_eq!(weights.len(), s.weight_len());
    debug_assert_eq!(biases.len(), s.out_maps);
    debug_assert_eq!(outs.len(), batch * out_len);

    let os = s.out_side;
    let is = s.in_side;
    let k = s.kernel;
    let omap_len = os * os;
    let imap_len = is * is;

    for m in 0..s.out_maps {
        for b in 0..batch {
            outs[b * out_len + m * omap_len..b * out_len + (m + 1) * omap_len].fill(biases[m]);
        }
        let wm = &weights[m * s.in_maps * k * k..];
        for j in 0..s.in_maps {
            let wj = &wm[j * k * k..(j + 1) * k * k];
            for ky in 0..k {
                for kx in 0..k {
                    // One scalar weight, stationary across the whole batch:
                    // each output row (y) is updated in every sample lane.
                    let w = wj[ky * k + kx];
                    for y in 0..os {
                        let src = j * imap_len + (y + ky) * is + kx;
                        let dst = m * omap_len + y * os;
                        super::simd::lane_axpy(
                            &mut outs[dst..],
                            out_len,
                            &inputs[src..],
                            in_len,
                            os,
                            batch,
                            w,
                        );
                    }
                }
            }
        }
    }
}

/// Backward convolution: accumulates weight/bias gradients and computes the
/// gradient w.r.t. the layer input.
///
/// * `delta` — ∂L/∂(pre-activation) of this layer, `[out_maps][os][os]`.
/// * `input` — the forward input (post-activation of the previous layer).
/// * `wgrads`/`bgrads` — **accumulated into** (callers zero them first; the
///   CHAOS worker reuses one buffer per layer across publications).
/// * `dinput` — overwritten with ∂L/∂input (w.r.t. the previous layer's
///   *output*; the caller then multiplies by the previous activation's
///   derivative). Pass an empty slice to skip (first conv layer).
pub fn conv_backward(
    s: &ConvShape,
    input: &[f32],
    weights: &[f32],
    delta: &[f32],
    wgrads: &mut [f32],
    bgrads: &mut [f32],
    dinput: &mut [f32],
) {
    debug_assert_eq!(input.len(), s.in_len());
    debug_assert_eq!(weights.len(), s.weight_len());
    debug_assert_eq!(delta.len(), s.out_len());
    debug_assert_eq!(wgrads.len(), s.weight_len());
    debug_assert_eq!(bgrads.len(), s.out_maps);
    let want_dinput = !dinput.is_empty();
    if want_dinput {
        debug_assert_eq!(dinput.len(), s.in_len());
        dinput.fill(0.0);
    }

    let os = s.out_side;
    let is = s.in_side;
    let k = s.kernel;
    let omap_len = os * os;
    let imap_len = is * is;

    for m in 0..s.out_maps {
        let d_map = &delta[m * omap_len..(m + 1) * omap_len];
        // bias gradient: Σ delta
        let mut bsum = 0.0f32;
        for &d in d_map {
            bsum += d;
        }
        bgrads[m] += bsum;

        let wm_base = m * s.in_maps * k * k;
        for j in 0..s.in_maps {
            let in_map = &input[j * imap_len..(j + 1) * imap_len];
            let wj = &weights[wm_base + j * k * k..wm_base + (j + 1) * k * k];
            let gj = &mut wgrads[wm_base + j * k * k..wm_base + (j + 1) * k * k];
            if want_dinput {
                // Fused pass: for each kernel tap, one walk over the delta
                // rows computes both the weight-gradient dot and the
                // input-delta saxpy (halves delta-row traffic vs two
                // separate (ky,kx) sweeps).
                let din_map = &mut dinput[j * imap_len..(j + 1) * imap_len];
                for ky in 0..k {
                    for kx in 0..k {
                        let w = wj[ky * k + kx];
                        let mut acc = 0.0f32;
                        for y in 0..os {
                            let base = (y + ky) * is + kx;
                            let in_row = &in_map[base..base + os];
                            let d_row = &d_map[y * os..y * os + os];
                            acc += super::simd::dot(in_row, d_row);
                            let din_row = &mut din_map[base..base + os];
                            super::simd::saxpy(din_row, d_row, w);
                        }
                        gj[ky * k + kx] += acc;
                    }
                }
            } else {
                for ky in 0..k {
                    for kx in 0..k {
                        // Row dot products through the multi-accumulator
                        // primitive (a plain reduction would stay scalar —
                        // see nn::simd).
                        let mut acc = 0.0f32;
                        for y in 0..os {
                            let base = (y + ky) * is + kx;
                            let in_row = &in_map[base..base + os];
                            let d_row = &d_map[y * os..y * os + os];
                            acc += super::simd::dot(in_row, d_row);
                        }
                        gj[ky * k + kx] += acc;
                    }
                }
            }
        }
    }
}

/// Batched backward convolution over `batch` samples (`inputs`/`dinputs`
/// laid out `[b][in_len]`, `deltas` `[b][out_len]`) — the weight-stationary
/// variant of [`conv_backward`]: each kernel tap's weight and its gradient
/// accumulator stay resident while every sample's rows stream past, so
/// weight/gradient traffic amortizes across the batch exactly like the
/// forward path. `wgrads`/`bgrads` receive the **batch-summed** gradients
/// (accumulated into, as in the per-sample kernel); `dinputs` is
/// overwritten per sample (pass an empty slice to skip).
///
/// Bit-identity contract: every gradient element receives its per-sample
/// contributions in ascending sample order, each computed by the same
/// row-dot sequence as [`conv_backward`], so the result equals `batch`
/// successive per-sample calls sharing the gradient buffers bitwise
/// (enforced by `rust/tests/batch_backward.rs`).
pub fn conv_backward_batch(
    s: &ConvShape,
    inputs: &[f32],
    weights: &[f32],
    deltas: &[f32],
    wgrads: &mut [f32],
    bgrads: &mut [f32],
    dinputs: &mut [f32],
    batch: usize,
) {
    let in_len = s.in_len();
    let out_len = s.out_len();
    debug_assert_eq!(inputs.len(), batch * in_len);
    debug_assert_eq!(weights.len(), s.weight_len());
    debug_assert_eq!(deltas.len(), batch * out_len);
    debug_assert_eq!(wgrads.len(), s.weight_len());
    debug_assert_eq!(bgrads.len(), s.out_maps);
    let want_dinput = !dinputs.is_empty();
    if want_dinput {
        debug_assert_eq!(dinputs.len(), batch * in_len);
        dinputs.fill(0.0);
    }

    let os = s.out_side;
    let is = s.in_side;
    let k = s.kernel;
    let omap_len = os * os;
    let imap_len = is * is;

    for m in 0..s.out_maps {
        // Bias gradient: per-sample delta sums, added in sample order.
        for b in 0..batch {
            let d_map = &deltas[b * out_len + m * omap_len..b * out_len + (m + 1) * omap_len];
            let mut bsum = 0.0f32;
            for &d in d_map {
                bsum += d;
            }
            bgrads[m] += bsum;
        }

        let wm_base = m * s.in_maps * k * k;
        for j in 0..s.in_maps {
            for ky in 0..k {
                for kx in 0..k {
                    let tap = wm_base + j * k * k + ky * k + kx;
                    // One scalar weight and one gradient accumulator,
                    // stationary across the whole batch.
                    let w = weights[tap];
                    let mut gacc = wgrads[tap];
                    for b in 0..batch {
                        let in_map =
                            &inputs[b * in_len + j * imap_len..b * in_len + (j + 1) * imap_len];
                        let d_map = &deltas
                            [b * out_len + m * omap_len..b * out_len + (m + 1) * omap_len];
                        let mut acc = 0.0f32;
                        if want_dinput {
                            let din_map = &mut dinputs
                                [b * in_len + j * imap_len..b * in_len + (j + 1) * imap_len];
                            for y in 0..os {
                                let base = (y + ky) * is + kx;
                                let in_row = &in_map[base..base + os];
                                let d_row = &d_map[y * os..y * os + os];
                                acc += super::simd::dot(in_row, d_row);
                                let din_row = &mut din_map[base..base + os];
                                super::simd::saxpy(din_row, d_row, w);
                            }
                        } else {
                            for y in 0..os {
                                let base = (y + ky) * is + kx;
                                let in_row = &in_map[base..base + os];
                                let d_row = &d_map[y * os..y * os + os];
                                acc += super::simd::dot(in_row, d_row);
                            }
                        }
                        gacc += acc;
                    }
                    wgrads[tap] = gacc;
                }
            }
        }
    }
}

/// Geometry for a general convolution: zero padding `pad` on every border
/// and stride `stride`. `stride == 1 && pad == 0` degenerates to the
/// "valid" convolution above ([`ConvGeom::is_plain`]); the compiled conv op
/// dispatches to the vectorized [`conv_forward`]/[`conv_backward`] pair on
/// that fast path and to the general (bounds-checked) loops below
/// otherwise.
#[derive(Debug, Clone, Copy)]
pub struct ConvGeom {
    pub in_maps: usize,
    pub in_side: usize,
    pub out_maps: usize,
    pub out_side: usize,
    pub kernel: usize,
    pub stride: usize,
    pub pad: usize,
}

impl ConvGeom {
    /// Output side of a `kernel`/`stride`/`pad` convolution over `in_side`,
    /// or `None` when the window does not fit.
    pub fn out_side(in_side: usize, kernel: usize, stride: usize, pad: usize) -> Option<usize> {
        if kernel == 0 || stride == 0 || in_side + 2 * pad < kernel {
            return None;
        }
        Some((in_side + 2 * pad - kernel) / stride + 1)
    }

    pub fn new(
        in_maps: usize,
        in_side: usize,
        out_maps: usize,
        kernel: usize,
        stride: usize,
        pad: usize,
    ) -> Option<ConvGeom> {
        let out_side = Self::out_side(in_side, kernel, stride, pad)?;
        Some(ConvGeom { in_maps, in_side, out_maps, out_side, kernel, stride, pad })
    }

    /// Plain "valid" stride-1 convolution (the paper's only kind).
    pub fn is_plain(&self) -> bool {
        self.stride == 1 && self.pad == 0
    }

    /// View as the stride-1 valid-conv shape (callers check `is_plain`).
    pub fn as_plain(&self) -> ConvShape {
        debug_assert!(self.is_plain());
        ConvShape {
            in_maps: self.in_maps,
            in_side: self.in_side,
            out_maps: self.out_maps,
            out_side: self.out_side,
            kernel: self.kernel,
        }
    }

    pub fn in_len(&self) -> usize {
        self.in_maps * self.in_side * self.in_side
    }

    pub fn out_len(&self) -> usize {
        self.out_maps * self.out_side * self.out_side
    }

    pub fn weight_len(&self) -> usize {
        self.out_maps * self.in_maps * self.kernel * self.kernel
    }

    /// Multiply-accumulates of one forward sample: every output element
    /// reads a full `in_maps · k²` receptive column (padding contributes
    /// zeros but still occupies a tap in the general kernel).
    pub fn macs(&self) -> usize {
        self.out_len() * self.in_maps * self.kernel * self.kernel
    }

    /// Scratch elements of one sample's im2col panel: one `out_side²`-long
    /// row per receptive-column tap (`in_maps · k²` rows). The fast-math
    /// general forward materializes this panel so the accumulation becomes
    /// a contiguous saxpy per tap (GEMM-shaped); the `BatchScratch` arena
    /// sized from this is accounted for in the dataflow audit.
    pub fn im2col_len(&self) -> usize {
        self.in_maps * self.kernel * self.kernel * self.out_side * self.out_side
    }
}

/// Output positions `o` with a valid (non-padding) input under tap offset
/// `kk`: `0 ≤ o·stride + kk − pad < in_side`, clamped to `0..out_side`.
/// Returns `(lo, hi)` with `lo ≥ hi` meaning no valid position.
#[inline]
fn valid_range(kk: usize, pad: usize, stride: usize, in_side: usize, out_side: usize) -> (usize, usize) {
    let lo = if kk >= pad { 0 } else { (pad - kk).div_ceil(stride) };
    let hi = if in_side + pad < kk + 1 {
        0
    } else {
        ((in_side + pad - kk - 1) / stride + 1).min(out_side)
    };
    (lo, hi)
}

/// General forward convolution (zero padding, arbitrary stride), producing
/// pre-activations. Same weight layout as [`conv_forward`].
pub fn conv_forward_general(
    g: &ConvGeom,
    input: &[f32],
    weights: &[f32],
    biases: &[f32],
    out: &mut [f32],
) {
    debug_assert_eq!(input.len(), g.in_len());
    debug_assert_eq!(weights.len(), g.weight_len());
    debug_assert_eq!(biases.len(), g.out_maps);
    debug_assert_eq!(out.len(), g.out_len());

    let k = g.kernel;
    let is = g.in_side;
    let os = g.out_side;
    let imap_len = is * is;
    let omap_len = os * os;

    for m in 0..g.out_maps {
        let out_map = &mut out[m * omap_len..(m + 1) * omap_len];
        let wm = &weights[m * g.in_maps * k * k..];
        for oy in 0..os {
            for ox in 0..os {
                let mut acc = biases[m];
                for j in 0..g.in_maps {
                    let in_map = &input[j * imap_len..(j + 1) * imap_len];
                    let wj = &wm[j * k * k..(j + 1) * k * k];
                    for ky in 0..k {
                        // Zero padding: out-of-range taps contribute 0.
                        let iy = (oy * g.stride + ky).wrapping_sub(g.pad);
                        if iy >= is {
                            continue;
                        }
                        for kx in 0..k {
                            let ix = (ox * g.stride + kx).wrapping_sub(g.pad);
                            if ix >= is {
                                continue;
                            }
                            acc += wj[ky * k + kx] * in_map[iy * is + ix];
                        }
                    }
                }
                out_map[oy * os + ox] = acc;
            }
        }
    }
}

/// General backward convolution: accumulates weight/bias gradients and
/// (unless `dinput` is empty) overwrites `dinput` with ∂L/∂input. Same
/// contract as [`conv_backward`].
pub fn conv_backward_general(
    g: &ConvGeom,
    input: &[f32],
    weights: &[f32],
    delta: &[f32],
    wgrads: &mut [f32],
    bgrads: &mut [f32],
    dinput: &mut [f32],
) {
    debug_assert_eq!(input.len(), g.in_len());
    debug_assert_eq!(weights.len(), g.weight_len());
    debug_assert_eq!(delta.len(), g.out_len());
    debug_assert_eq!(wgrads.len(), g.weight_len());
    debug_assert_eq!(bgrads.len(), g.out_maps);
    let want_dinput = !dinput.is_empty();
    if want_dinput {
        debug_assert_eq!(dinput.len(), g.in_len());
        dinput.fill(0.0);
    }

    let k = g.kernel;
    let is = g.in_side;
    let os = g.out_side;
    let imap_len = is * is;
    let omap_len = os * os;

    for m in 0..g.out_maps {
        let d_map = &delta[m * omap_len..(m + 1) * omap_len];
        let mut bsum = 0.0f32;
        for &d in d_map {
            bsum += d;
        }
        bgrads[m] += bsum;

        let wm_base = m * g.in_maps * k * k;
        for j in 0..g.in_maps {
            let in_map = &input[j * imap_len..(j + 1) * imap_len];
            let wj = &weights[wm_base + j * k * k..wm_base + (j + 1) * k * k];
            let gj = &mut wgrads[wm_base + j * k * k..wm_base + (j + 1) * k * k];
            for ky in 0..k {
                for kx in 0..k {
                    let w = wj[ky * k + kx];
                    let mut acc = 0.0f32;
                    for oy in 0..os {
                        let iy = (oy * g.stride + ky).wrapping_sub(g.pad);
                        if iy >= is {
                            continue;
                        }
                        for ox in 0..os {
                            let ix = (ox * g.stride + kx).wrapping_sub(g.pad);
                            if ix >= is {
                                continue;
                            }
                            let d = d_map[oy * os + ox];
                            acc += in_map[iy * is + ix] * d;
                            if want_dinput {
                                dinput[j * imap_len + iy * is + ix] += w * d;
                            }
                        }
                    }
                    gj[ky * k + kx] += acc;
                }
            }
        }
    }
}

/// Batched general forward convolution over `batch` samples — the
/// tap-stationary replacement for a per-sample [`conv_forward_general`]
/// loop. Two accumulation routes, selected by `math`:
///
/// * [`MathPolicy::Exact`]: interval-precomputed valid ranges replace the
///   per-tap bounds checks, and every output element receives its taps in
///   the per-sample order (`bias`, then `j → ky → kx`, padding skipped) —
///   **bit-identical** to `batch` independent [`conv_forward_general`]
///   calls.
/// * [`MathPolicy::Fast`]: per sample, a zero-padded im2col panel is
///   materialized in `col` (layout `[j·k² tap rows][out_side²]`, sized by
///   [`ConvGeom::im2col_len`]) and each output map accumulates one
///   contiguous saxpy per tap — a GEMM shape. Padding taps contribute
///   explicit `w · 0.0` terms, so results agree with exact mode only to
///   rounding (and `-0.0` sign bits may differ).
pub fn conv_forward_general_batch(
    g: &ConvGeom,
    inputs: &[f32],
    weights: &[f32],
    biases: &[f32],
    outs: &mut [f32],
    batch: usize,
    math: MathPolicy,
    col: &mut [f32],
) {
    let in_len = g.in_len();
    let out_len = g.out_len();
    debug_assert_eq!(inputs.len(), batch * in_len);
    debug_assert_eq!(weights.len(), g.weight_len());
    debug_assert_eq!(biases.len(), g.out_maps);
    debug_assert_eq!(outs.len(), batch * out_len);

    let k = g.kernel;
    let is = g.in_side;
    let os = g.out_side;
    let imap_len = is * is;
    let omap_len = os * os;

    if math == MathPolicy::Fast {
        debug_assert!(col.len() >= g.im2col_len());
        let taps = g.in_maps * k * k;
        let col = &mut col[..taps * omap_len];
        for b in 0..batch {
            // Build this sample's panel. A shared col arena may hold another
            // layer's (or sample's) stale values at this layer's padding
            // positions, so the zero fill is not optional.
            col.fill(0.0);
            let input = &inputs[b * in_len..(b + 1) * in_len];
            for j in 0..g.in_maps {
                let in_map = &input[j * imap_len..(j + 1) * imap_len];
                for ky in 0..k {
                    let (oy_lo, oy_hi) = valid_range(ky, g.pad, g.stride, is, os);
                    for kx in 0..k {
                        let (ox_lo, ox_hi) = valid_range(kx, g.pad, g.stride, is, os);
                        let c = (j * k + ky) * k + kx;
                        let col_row = &mut col[c * omap_len..(c + 1) * omap_len];
                        for oy in oy_lo..oy_hi {
                            let iy = oy * g.stride + ky - g.pad;
                            for ox in ox_lo..ox_hi {
                                let ix = ox * g.stride + kx - g.pad;
                                col_row[oy * os + ox] = in_map[iy * is + ix];
                            }
                        }
                    }
                }
            }
            // GEMM: out[m] = bias[m] + Σ_c w[m][c] · col[c].
            let out = &mut outs[b * out_len..(b + 1) * out_len];
            for m in 0..g.out_maps {
                let out_map = &mut out[m * omap_len..(m + 1) * omap_len];
                out_map.fill(biases[m]);
                let wm = &weights[m * taps..(m + 1) * taps];
                for (c, &w) in wm.iter().enumerate() {
                    super::simd::saxpy(out_map, &col[c * omap_len..(c + 1) * omap_len], w);
                }
            }
        }
        return;
    }

    // Exact: tap-stationary sweep; the valid-output intervals skip exactly
    // the padding taps the per-sample kernel's bounds checks skip, so the
    // per-element addition chain is unchanged.
    for m in 0..g.out_maps {
        for b in 0..batch {
            outs[b * out_len + m * omap_len..b * out_len + (m + 1) * omap_len].fill(biases[m]);
        }
        let wm = &weights[m * g.in_maps * k * k..];
        for j in 0..g.in_maps {
            let wj = &wm[j * k * k..(j + 1) * k * k];
            for ky in 0..k {
                let (oy_lo, oy_hi) = valid_range(ky, g.pad, g.stride, is, os);
                for kx in 0..k {
                    let (ox_lo, ox_hi) = valid_range(kx, g.pad, g.stride, is, os);
                    let w = wj[ky * k + kx];
                    for b in 0..batch {
                        let in_map =
                            &inputs[b * in_len + j * imap_len..b * in_len + (j + 1) * imap_len];
                        let out_map = &mut outs
                            [b * out_len + m * omap_len..b * out_len + (m + 1) * omap_len];
                        for oy in oy_lo..oy_hi {
                            let iy = oy * g.stride + ky - g.pad;
                            let out_row = &mut out_map[oy * os..(oy + 1) * os];
                            for ox in ox_lo..ox_hi {
                                let ix = ox * g.stride + kx - g.pad;
                                out_row[ox] += w * in_map[iy * is + ix];
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Batched general backward convolution — the tap-stationary variant of
/// [`conv_backward_general`], policy-independent (always exact): every
/// gradient element receives its per-sample contributions in ascending
/// sample order, each computed by the same scalar `(oy, ox)` chain as the
/// per-sample kernel, so the result equals `batch` successive
/// [`conv_backward_general`] calls sharing the gradient buffers bitwise.
pub fn conv_backward_general_batch(
    g: &ConvGeom,
    inputs: &[f32],
    weights: &[f32],
    deltas: &[f32],
    wgrads: &mut [f32],
    bgrads: &mut [f32],
    dinputs: &mut [f32],
    batch: usize,
) {
    let in_len = g.in_len();
    let out_len = g.out_len();
    debug_assert_eq!(inputs.len(), batch * in_len);
    debug_assert_eq!(weights.len(), g.weight_len());
    debug_assert_eq!(deltas.len(), batch * out_len);
    debug_assert_eq!(wgrads.len(), g.weight_len());
    debug_assert_eq!(bgrads.len(), g.out_maps);
    let want_dinput = !dinputs.is_empty();
    if want_dinput {
        debug_assert_eq!(dinputs.len(), batch * in_len);
        dinputs.fill(0.0);
    }

    let k = g.kernel;
    let is = g.in_side;
    let os = g.out_side;
    let imap_len = is * is;
    let omap_len = os * os;

    for m in 0..g.out_maps {
        // Bias gradient: per-sample delta sums, added in sample order.
        for b in 0..batch {
            let d_map = &deltas[b * out_len + m * omap_len..b * out_len + (m + 1) * omap_len];
            let mut bsum = 0.0f32;
            for &d in d_map {
                bsum += d;
            }
            bgrads[m] += bsum;
        }

        let wm_base = m * g.in_maps * k * k;
        for j in 0..g.in_maps {
            for ky in 0..k {
                let (oy_lo, oy_hi) = valid_range(ky, g.pad, g.stride, is, os);
                for kx in 0..k {
                    let (ox_lo, ox_hi) = valid_range(kx, g.pad, g.stride, is, os);
                    let tap = wm_base + j * k * k + ky * k + kx;
                    // One scalar weight and one gradient accumulator,
                    // stationary across the whole batch.
                    let w = weights[tap];
                    let mut gacc = wgrads[tap];
                    for b in 0..batch {
                        let in_map =
                            &inputs[b * in_len + j * imap_len..b * in_len + (j + 1) * imap_len];
                        let d_map = &deltas
                            [b * out_len + m * omap_len..b * out_len + (m + 1) * omap_len];
                        let mut acc = 0.0f32;
                        if want_dinput {
                            let din_map = &mut dinputs
                                [b * in_len + j * imap_len..b * in_len + (j + 1) * imap_len];
                            for oy in oy_lo..oy_hi {
                                let iy = oy * g.stride + ky - g.pad;
                                for ox in ox_lo..ox_hi {
                                    let ix = ox * g.stride + kx - g.pad;
                                    let d = d_map[oy * os + ox];
                                    acc += in_map[iy * is + ix] * d;
                                    din_map[iy * is + ix] += w * d;
                                }
                            }
                        } else {
                            for oy in oy_lo..oy_hi {
                                let iy = oy * g.stride + ky - g.pad;
                                for ox in ox_lo..ox_hi {
                                    let ix = ox * g.stride + kx - g.pad;
                                    acc += in_map[iy * is + ix] * d_map[oy * os + ox];
                                }
                            }
                        }
                        gacc += acc;
                    }
                    wgrads[tap] = gacc;
                }
            }
        }
    }
}

/// Reference (naive, index-arithmetic) forward used only by tests to pin the
/// optimized loops down.
#[cfg(test)]
pub fn conv_forward_naive(
    s: &ConvShape,
    input: &[f32],
    weights: &[f32],
    biases: &[f32],
    out: &mut [f32],
) {
    for m in 0..s.out_maps {
        for y in 0..s.out_side {
            for x in 0..s.out_side {
                let mut acc = biases[m];
                for j in 0..s.in_maps {
                    for ky in 0..s.kernel {
                        for kx in 0..s.kernel {
                            let w = weights[((m * s.in_maps + j) * s.kernel + ky) * s.kernel + kx];
                            let iv = input[j * s.in_side * s.in_side
                                + (y + ky) * s.in_side
                                + (x + kx)];
                            acc += w * iv;
                        }
                    }
                }
                out[m * s.out_side * s.out_side + y * s.out_side + x] = acc;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{proptest, Pcg32};

    fn rand_vec(rng: &mut Pcg32, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect()
    }

    #[test]
    fn forward_matches_naive() {
        proptest::run(
            proptest::Config { cases: 40, max_size: 8, ..Default::default() },
            |rng, size| {
                let in_maps = rng.range(1, 4);
                let out_maps = rng.range(1, 4);
                let kernel = rng.range(1, 4.min(size + 1) + 1);
                let in_side = kernel + rng.range(0, size + 1);
                let s = ConvShape::valid(in_maps, in_side, out_maps, kernel);
                let input = rand_vec(rng, s.in_len());
                let weights = rand_vec(rng, s.weight_len());
                let biases = rand_vec(rng, s.out_maps);
                (s, input, weights, biases)
            },
            |(s, input, weights, biases)| {
                let mut fast = vec![0.0; s.out_len()];
                let mut naive = vec![0.0; s.out_len()];
                conv_forward(s, input, weights, biases, &mut fast);
                conv_forward_naive(s, input, weights, biases, &mut naive);
                proptest::check_close(&fast, &naive, 1e-5, 1e-5)
            },
        );
    }

    #[test]
    fn forward_known_values() {
        // 1 input map 3x3, 1 output map, kernel 2, identity-ish weights.
        let s = ConvShape::valid(1, 3, 1, 2);
        let input = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0];
        let weights = [1.0, 0.0, 0.0, 1.0]; // picks in[y][x] + in[y+1][x+1]
        let biases = [10.0];
        let mut out = [0.0; 4];
        conv_forward(&s, &input, &weights, &biases, &mut out);
        assert_eq!(out, [1.0 + 5.0 + 10.0, 2.0 + 6.0 + 10.0, 4.0 + 8.0 + 10.0, 5.0 + 9.0 + 10.0]);
    }

    #[test]
    fn backward_weight_grads_match_finite_difference() {
        let mut rng = Pcg32::seeded(11);
        let s = ConvShape::valid(2, 6, 3, 3);
        let input = rand_vec(&mut rng, s.in_len());
        let mut weights = rand_vec(&mut rng, s.weight_len());
        let biases = rand_vec(&mut rng, s.out_maps);
        // Loss = sum(out) so that dL/d(pre-act) = 1 everywhere.
        let delta = vec![1.0f32; s.out_len()];
        let mut wg = vec![0.0; s.weight_len()];
        let mut bg = vec![0.0; s.out_maps];
        let mut din = vec![0.0; s.in_len()];
        conv_backward(&s, &input, &weights, &delta, &mut wg, &mut bg, &mut din);

        let loss = |w: &[f32]| -> f32 {
            let mut out = vec![0.0; s.out_len()];
            conv_forward(&s, &input, w, &biases, &mut out);
            out.iter().sum()
        };
        let h = 1e-3;
        for idx in [0, 5, s.weight_len() / 2, s.weight_len() - 1] {
            let orig = weights[idx];
            weights[idx] = orig + h;
            let lp = loss(&weights);
            weights[idx] = orig - h;
            let lm = loss(&weights);
            weights[idx] = orig;
            let fd = (lp - lm) / (2.0 * h);
            assert!(
                (fd - wg[idx]).abs() < 2e-2 * (1.0 + fd.abs()),
                "w[{idx}]: fd={fd} analytic={}",
                wg[idx]
            );
        }
        // Bias gradient with delta=1 is the number of output pixels per map.
        for m in 0..s.out_maps {
            assert!((bg[m] - (s.out_side * s.out_side) as f32).abs() < 1e-4);
        }
    }

    #[test]
    fn backward_dinput_matches_finite_difference() {
        let mut rng = Pcg32::seeded(13);
        let s = ConvShape::valid(2, 5, 2, 2);
        let mut input = rand_vec(&mut rng, s.in_len());
        let weights = rand_vec(&mut rng, s.weight_len());
        let biases = rand_vec(&mut rng, s.out_maps);
        let delta = vec![1.0f32; s.out_len()];
        let mut wg = vec![0.0; s.weight_len()];
        let mut bg = vec![0.0; s.out_maps];
        let mut din = vec![0.0; s.in_len()];
        conv_backward(&s, &input, &weights, &delta, &mut wg, &mut bg, &mut din);

        let loss = |inp: &[f32]| -> f32 {
            let mut out = vec![0.0; s.out_len()];
            conv_forward(&s, inp, &weights, &biases, &mut out);
            out.iter().sum()
        };
        let h = 1e-3;
        for idx in [0, 7, s.in_len() / 2, s.in_len() - 1] {
            let orig = input[idx];
            input[idx] = orig + h;
            let lp = loss(&input);
            input[idx] = orig - h;
            let lm = loss(&input);
            input[idx] = orig;
            let fd = (lp - lm) / (2.0 * h);
            assert!(
                (fd - din[idx]).abs() < 2e-2 * (1.0 + fd.abs()),
                "din[{idx}]: fd={fd} analytic={}",
                din[idx]
            );
        }
    }

    #[test]
    fn general_matches_plain_when_unpadded_unit_stride() {
        proptest::run(
            proptest::Config { cases: 30, max_size: 6, ..Default::default() },
            |rng, size| {
                let in_maps = rng.range(1, 3);
                let out_maps = rng.range(1, 3);
                let kernel = rng.range(1, 4.min(size + 1) + 1);
                let in_side = kernel + rng.range(0, size + 1);
                let s = ConvShape::valid(in_maps, in_side, out_maps, kernel);
                let input = rand_vec(rng, s.in_len());
                let weights = rand_vec(rng, s.weight_len());
                let biases = rand_vec(rng, s.out_maps);
                (s, input, weights, biases)
            },
            |(s, input, weights, biases)| {
                let g = ConvGeom::new(s.in_maps, s.in_side, s.out_maps, s.kernel, 1, 0).unwrap();
                assert!(g.is_plain());
                assert_eq!(g.out_side, s.out_side);
                let mut plain = vec![0.0; s.out_len()];
                let mut general = vec![0.0; s.out_len()];
                conv_forward(s, input, weights, biases, &mut plain);
                conv_forward_general(&g, input, weights, biases, &mut general);
                proptest::check_close(&general, &plain, 1e-5, 1e-5)
            },
        );
    }

    #[test]
    fn general_backward_matches_finite_difference() {
        // Padded (pad=1) strided (stride=2) convolution, FD on weights and
        // inputs with loss = Σ out.
        let mut rng = Pcg32::seeded(17);
        let g = ConvGeom::new(2, 7, 3, 3, 2, 1).unwrap();
        assert_eq!(g.out_side, (7 + 2 - 3) / 2 + 1);
        let mut input = rand_vec(&mut rng, g.in_len());
        let mut weights = rand_vec(&mut rng, g.weight_len());
        let biases = rand_vec(&mut rng, g.out_maps);
        let delta = vec![1.0f32; g.out_len()];
        let mut wg = vec![0.0; g.weight_len()];
        let mut bg = vec![0.0; g.out_maps];
        let mut din = vec![0.0; g.in_len()];
        conv_backward_general(&g, &input, &weights, &delta, &mut wg, &mut bg, &mut din);

        let loss = |w: &[f32], inp: &[f32]| -> f32 {
            let mut out = vec![0.0; g.out_len()];
            conv_forward_general(&g, inp, w, &biases, &mut out);
            out.iter().sum()
        };
        let h = 1e-3;
        for idx in [0, 4, g.weight_len() / 2, g.weight_len() - 1] {
            let orig = weights[idx];
            weights[idx] = orig + h;
            let lp = loss(&weights, &input);
            weights[idx] = orig - h;
            let lm = loss(&weights, &input);
            weights[idx] = orig;
            let fd = (lp - lm) / (2.0 * h);
            assert!(
                (fd - wg[idx]).abs() < 2e-2 * (1.0 + fd.abs()),
                "w[{idx}]: fd={fd} analytic={}",
                wg[idx]
            );
        }
        for idx in [0, 5, g.in_len() / 2, g.in_len() - 1] {
            let orig = input[idx];
            input[idx] = orig + h;
            let lp = loss(&weights, &input);
            input[idx] = orig - h;
            let lm = loss(&weights, &input);
            input[idx] = orig;
            let fd = (lp - lm) / (2.0 * h);
            assert!(
                (fd - din[idx]).abs() < 2e-2 * (1.0 + fd.abs()),
                "din[{idx}]: fd={fd} analytic={}",
                din[idx]
            );
        }
        // With delta = 1, bias grads count output pixels per map.
        for m in 0..g.out_maps {
            assert!((bg[m] - (g.out_side * g.out_side) as f32).abs() < 1e-4);
        }
    }

    #[test]
    fn batched_forward_bit_identical_to_per_sample() {
        proptest::run(
            proptest::Config { cases: 30, max_size: 6, ..Default::default() },
            |rng, size| {
                let in_maps = rng.range(1, 4);
                let out_maps = rng.range(1, 4);
                let kernel = rng.range(1, 4.min(size + 1) + 1);
                let in_side = kernel + rng.range(0, size + 1);
                let batch = rng.range(1, 6);
                let s = ConvShape::valid(in_maps, in_side, out_maps, kernel);
                let inputs = rand_vec(rng, batch * s.in_len());
                let weights = rand_vec(rng, s.weight_len());
                let biases = rand_vec(rng, s.out_maps);
                (s, inputs, weights, biases, batch)
            },
            |(s, inputs, weights, biases, batch)| {
                let mut batched = vec![0.0; batch * s.out_len()];
                conv_forward_batch(s, inputs, weights, biases, &mut batched, *batch);
                for b in 0..*batch {
                    let mut single = vec![0.0; s.out_len()];
                    let input = &inputs[b * s.in_len()..(b + 1) * s.in_len()];
                    conv_forward(s, input, weights, biases, &mut single);
                    let row = &batched[b * s.out_len()..(b + 1) * s.out_len()];
                    if row != single.as_slice() {
                        return Err(format!("sample {b} not bit-identical"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn batched_backward_bit_identical_to_per_sample() {
        proptest::run(
            proptest::Config { cases: 30, max_size: 6, ..Default::default() },
            |rng, size| {
                let in_maps = rng.range(1, 4);
                let out_maps = rng.range(1, 4);
                let kernel = rng.range(1, 4.min(size + 1) + 1);
                let in_side = kernel + rng.range(0, size + 1);
                let batch = rng.range(1, 6);
                let s = ConvShape::valid(in_maps, in_side, out_maps, kernel);
                let inputs = rand_vec(rng, batch * s.in_len());
                let weights = rand_vec(rng, s.weight_len());
                let deltas = rand_vec(rng, batch * s.out_len());
                (s, inputs, weights, deltas, batch)
            },
            |(s, inputs, weights, deltas, batch)| {
                let mut wg_b = vec![0.0; s.weight_len()];
                let mut bg_b = vec![0.0; s.out_maps];
                let mut din_b = vec![0.0; batch * s.in_len()];
                conv_backward_batch(
                    s, inputs, weights, deltas, &mut wg_b, &mut bg_b, &mut din_b, *batch,
                );
                // Reference: per-sample calls sharing the gradient buffers.
                let mut wg = vec![0.0; s.weight_len()];
                let mut bg = vec![0.0; s.out_maps];
                let mut din = vec![0.0; batch * s.in_len()];
                for b in 0..*batch {
                    conv_backward(
                        s,
                        &inputs[b * s.in_len()..(b + 1) * s.in_len()],
                        weights,
                        &deltas[b * s.out_len()..(b + 1) * s.out_len()],
                        &mut wg,
                        &mut bg,
                        &mut din[b * s.in_len()..(b + 1) * s.in_len()],
                    );
                }
                if wg_b != wg {
                    return Err("weight grads not bit-identical".to_string());
                }
                if bg_b != bg {
                    return Err("bias grads not bit-identical".to_string());
                }
                if din_b != din {
                    return Err("input deltas not bit-identical".to_string());
                }
                // The dinput-skipping path accumulates the same grads.
                let mut wg_s = vec![0.0; s.weight_len()];
                let mut bg_s = vec![0.0; s.out_maps];
                conv_backward_batch(
                    s, inputs, weights, deltas, &mut wg_s, &mut bg_s, &mut [], *batch,
                );
                if wg_s != wg || bg_s != bg {
                    return Err("grads diverge without dinput".to_string());
                }
                Ok(())
            },
        );
    }

    /// Random general (padded/strided) geometry + operands for the batched
    /// general-kernel property tests.
    fn rand_general_case(
        rng: &mut Pcg32,
        size: usize,
    ) -> (ConvGeom, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, usize) {
        loop {
            let in_maps = rng.range(1, 3);
            let out_maps = rng.range(1, 3);
            let kernel = rng.range(1, 4.min(size + 1) + 1);
            let in_side = 1 + rng.range(0, size + 3);
            let stride = rng.range(1, 3);
            let pad = rng.range(0, kernel);
            if let Some(g) = ConvGeom::new(in_maps, in_side, out_maps, kernel, stride, pad) {
                let batch = rng.range(1, 5);
                let inputs = rand_vec(rng, batch * g.in_len());
                let weights = rand_vec(rng, g.weight_len());
                let biases = rand_vec(rng, g.out_maps);
                let deltas = rand_vec(rng, batch * g.out_len());
                return (g, inputs, weights, biases, deltas, batch);
            }
        }
    }

    #[test]
    fn general_batched_forward_exact_bit_identical_to_per_sample() {
        proptest::run(
            proptest::Config { cases: 30, max_size: 6, ..Default::default() },
            |rng, size| rand_general_case(rng, size),
            |(g, inputs, weights, biases, _deltas, batch)| {
                let mut batched = vec![0.0; batch * g.out_len()];
                conv_forward_general_batch(
                    g,
                    inputs,
                    weights,
                    biases,
                    &mut batched,
                    *batch,
                    MathPolicy::Exact,
                    &mut [],
                );
                for b in 0..*batch {
                    let mut single = vec![0.0; g.out_len()];
                    let input = &inputs[b * g.in_len()..(b + 1) * g.in_len()];
                    conv_forward_general(g, input, weights, biases, &mut single);
                    if &batched[b * g.out_len()..(b + 1) * g.out_len()] != single.as_slice() {
                        return Err(format!("sample {b} not bit-identical (geom {g:?})"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn general_batched_forward_fast_matches_exact_to_rounding() {
        proptest::run(
            proptest::Config { cases: 30, max_size: 6, ..Default::default() },
            |rng, size| rand_general_case(rng, size),
            |(g, inputs, weights, biases, _deltas, batch)| {
                let mut exact = vec![0.0; batch * g.out_len()];
                conv_forward_general_batch(
                    g,
                    inputs,
                    weights,
                    biases,
                    &mut exact,
                    *batch,
                    MathPolicy::Exact,
                    &mut [],
                );
                // Poison the panel to prove the zero-fill handles reuse.
                let mut col = vec![f32::NAN; g.im2col_len()];
                let mut fast = vec![0.0; batch * g.out_len()];
                conv_forward_general_batch(
                    g,
                    inputs,
                    weights,
                    biases,
                    &mut fast,
                    *batch,
                    MathPolicy::Fast,
                    &mut col,
                );
                proptest::check_close(&fast, &exact, 1e-5, 1e-5)
            },
        );
    }

    #[test]
    fn general_batched_backward_bit_identical_to_per_sample() {
        proptest::run(
            proptest::Config { cases: 30, max_size: 6, ..Default::default() },
            |rng, size| rand_general_case(rng, size),
            |(g, inputs, weights, _biases, deltas, batch)| {
                let mut wg_b = vec![0.0; g.weight_len()];
                let mut bg_b = vec![0.0; g.out_maps];
                let mut din_b = vec![0.0; batch * g.in_len()];
                conv_backward_general_batch(
                    g, inputs, weights, deltas, &mut wg_b, &mut bg_b, &mut din_b, *batch,
                );
                let mut wg = vec![0.0; g.weight_len()];
                let mut bg = vec![0.0; g.out_maps];
                let mut din = vec![0.0; batch * g.in_len()];
                for b in 0..*batch {
                    conv_backward_general(
                        g,
                        &inputs[b * g.in_len()..(b + 1) * g.in_len()],
                        weights,
                        &deltas[b * g.out_len()..(b + 1) * g.out_len()],
                        &mut wg,
                        &mut bg,
                        &mut din[b * g.in_len()..(b + 1) * g.in_len()],
                    );
                }
                if wg_b != wg {
                    return Err("weight grads not bit-identical".to_string());
                }
                if bg_b != bg {
                    return Err("bias grads not bit-identical".to_string());
                }
                if din_b != din {
                    return Err("input deltas not bit-identical".to_string());
                }
                // The dinput-skipping path accumulates the same grads.
                let mut wg_s = vec![0.0; g.weight_len()];
                let mut bg_s = vec![0.0; g.out_maps];
                conv_backward_general_batch(
                    g, inputs, weights, deltas, &mut wg_s, &mut bg_s, &mut [], *batch,
                );
                if wg_s != wg || bg_s != bg {
                    return Err("grads diverge without dinput".to_string());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn geom_rejects_impossible_windows() {
        assert!(ConvGeom::new(1, 3, 1, 5, 1, 0).is_none(), "kernel larger than padded input");
        assert!(ConvGeom::new(1, 3, 1, 2, 0, 0).is_none(), "zero stride");
        assert!(ConvGeom::new(1, 3, 1, 0, 1, 0).is_none(), "zero kernel");
        // Padding rescues an otherwise too-large kernel.
        assert_eq!(ConvGeom::new(1, 3, 1, 5, 1, 1).unwrap().out_side, 1);
    }

    #[test]
    fn backward_accumulates_grads() {
        let s = ConvShape::valid(1, 3, 1, 2);
        let input = vec![1.0; s.in_len()];
        let weights = vec![0.5; s.weight_len()];
        let delta = vec![1.0; s.out_len()];
        let mut wg = vec![0.0; s.weight_len()];
        let mut bg = vec![0.0; 1];
        conv_backward(&s, &input, &weights, &delta, &mut wg, &mut bg, &mut []);
        let first = wg.clone();
        conv_backward(&s, &input, &weights, &delta, &mut wg, &mut bg, &mut []);
        for (a, b) in wg.iter().zip(&first) {
            assert!((a - 2.0 * b).abs() < 1e-6, "second call must accumulate");
        }
    }
}
