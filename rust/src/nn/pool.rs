//! Pooling layers (kernel k, stride k — LeNet-style non-overlapping
//! windows; the large network's 1×1 max pooling degenerates to identity).
//!
//! Max-pool forward records the argmax position of every window so backward
//! can route deltas to the winning input ("switches", as in the original
//! LeNet/Cireşan code). Average pooling needs no switches: backward spreads
//! each delta uniformly over its window.

/// Geometry for one pooling layer.
#[derive(Debug, Clone, Copy)]
pub struct PoolShape {
    pub maps: usize,
    pub in_side: usize,
    pub out_side: usize,
    pub kernel: usize,
}

impl PoolShape {
    pub fn new(maps: usize, in_side: usize, kernel: usize) -> PoolShape {
        assert!(kernel > 0 && kernel <= in_side);
        PoolShape { maps, in_side, out_side: in_side / kernel, kernel }
    }

    pub fn in_len(&self) -> usize {
        self.maps * self.in_side * self.in_side
    }

    pub fn out_len(&self) -> usize {
        self.maps * self.out_side * self.out_side
    }

    /// Window element reads of one forward sample: every output element
    /// scans its full k² window (windows tile the input exactly).
    pub fn window_ops(&self) -> usize {
        self.out_len() * self.kernel * self.kernel
    }
}

/// Forward max-pool. `switches[o]` receives the flat input index of the
/// maximum for output element `o`.
pub fn pool_forward(s: &PoolShape, input: &[f32], out: &mut [f32], switches: &mut [u32]) {
    debug_assert_eq!(input.len(), s.in_len());
    debug_assert_eq!(out.len(), s.out_len());
    debug_assert_eq!(switches.len(), s.out_len());

    let k = s.kernel;
    let is = s.in_side;
    let os = s.out_side;
    let imap = is * is;
    let omap = os * os;

    for m in 0..s.maps {
        let in_map = &input[m * imap..(m + 1) * imap];
        for oy in 0..os {
            for ox in 0..os {
                let mut best = f32::NEG_INFINITY;
                let mut best_idx = 0u32;
                for ky in 0..k {
                    let row = (oy * k + ky) * is + ox * k;
                    for kx in 0..k {
                        let idx = row + kx;
                        let v = in_map[idx];
                        if v > best {
                            best = v;
                            best_idx = (m * imap + idx) as u32;
                        }
                    }
                }
                let o = m * omap + oy * os + ox;
                out[o] = best;
                switches[o] = best_idx;
            }
        }
    }
}

/// Batched forward max-pool over samples laid out `[b][in_len]` →
/// `[b][out_len]`, `switches` laid out `[b][out_len]`. Each sample's
/// switches hold flat indices into *that sample's* input (the per-sample
/// convention), so backward routing per sample is unchanged.
///
/// Batch-lane sweep: the window geometry (indices, bounds) is computed
/// once per output element and reused across every sample lane, instead of
/// re-deriving it per sample. Samples are independent and each window is
/// scanned in the per-sample `ky → kx` order, so outputs and argmax ties
/// are bit-identical to tiled per-sample calls.
pub fn pool_forward_batch(
    s: &PoolShape,
    inputs: &[f32],
    outs: &mut [f32],
    switches: &mut [u32],
    batch: usize,
) {
    let in_len = s.in_len();
    let out_len = s.out_len();
    debug_assert_eq!(inputs.len(), batch * in_len);
    debug_assert_eq!(outs.len(), batch * out_len);
    debug_assert_eq!(switches.len(), batch * out_len);

    let k = s.kernel;
    let is = s.in_side;
    let os = s.out_side;
    let imap = is * is;
    let omap = os * os;

    for m in 0..s.maps {
        for oy in 0..os {
            for ox in 0..os {
                let o = m * omap + oy * os + ox;
                let win = (oy * k) * is + ox * k;
                for b in 0..batch {
                    let in_map = &inputs[b * in_len + m * imap..b * in_len + (m + 1) * imap];
                    let mut best = f32::NEG_INFINITY;
                    let mut best_idx = 0u32;
                    for ky in 0..k {
                        let row = win + ky * is;
                        for kx in 0..k {
                            let idx = row + kx;
                            let v = in_map[idx];
                            if v > best {
                                best = v;
                                best_idx = (m * imap + idx) as u32;
                            }
                        }
                    }
                    outs[b * out_len + o] = best;
                    switches[b * out_len + o] = best_idx;
                }
            }
        }
    }
}

/// Backward max-pool: route each output delta to the recorded argmax input.
/// `dinput` is overwritten.
pub fn pool_backward(s: &PoolShape, delta: &[f32], switches: &[u32], dinput: &mut [f32]) {
    debug_assert_eq!(delta.len(), s.out_len());
    debug_assert_eq!(switches.len(), s.out_len());
    debug_assert_eq!(dinput.len(), s.in_len());
    dinput.fill(0.0);
    for (o, &d) in delta.iter().enumerate() {
        dinput[switches[o] as usize] += d;
    }
}

/// Batched backward max-pool (`deltas`/`switches` laid out `[b][out_len]`,
/// `dinputs` `[b][in_len]`, each sample's switches indexing into its own
/// input — see [`pool_forward_batch`]). Output-element-outer, sample-inner
/// sweep; windows tile the input disjointly (≤ 1 delta per input element),
/// so the routing order cannot change the result and the batch stays
/// bit-identical to tiled per-sample calls.
pub fn pool_backward_batch(
    s: &PoolShape,
    deltas: &[f32],
    switches: &[u32],
    dinputs: &mut [f32],
    batch: usize,
) {
    let in_len = s.in_len();
    let out_len = s.out_len();
    debug_assert_eq!(deltas.len(), batch * out_len);
    debug_assert_eq!(switches.len(), batch * out_len);
    debug_assert_eq!(dinputs.len(), batch * in_len);
    dinputs.fill(0.0);
    for o in 0..out_len {
        for b in 0..batch {
            let d = deltas[b * out_len + o];
            dinputs[b * in_len + switches[b * out_len + o] as usize] += d;
        }
    }
}

/// Forward average-pool: each output is the mean of its window.
pub fn avg_pool_forward(s: &PoolShape, input: &[f32], out: &mut [f32]) {
    debug_assert_eq!(input.len(), s.in_len());
    debug_assert_eq!(out.len(), s.out_len());

    let k = s.kernel;
    let is = s.in_side;
    let os = s.out_side;
    let imap = is * is;
    let omap = os * os;
    let inv = 1.0 / (k * k) as f32;

    for m in 0..s.maps {
        let in_map = &input[m * imap..(m + 1) * imap];
        for oy in 0..os {
            for ox in 0..os {
                let mut sum = 0.0f32;
                for ky in 0..k {
                    let row = (oy * k + ky) * is + ox * k;
                    for kx in 0..k {
                        sum += in_map[row + kx];
                    }
                }
                out[m * omap + oy * os + ox] = sum * inv;
            }
        }
    }
}

/// Batched forward average-pool (`[b][in_len]` → `[b][out_len]`); see
/// [`pool_forward_batch`] for the layout and batch-lane conventions. Each
/// window sum uses the per-sample `ky → kx` order → bit-identical to tiled
/// per-sample calls.
pub fn avg_pool_forward_batch(s: &PoolShape, inputs: &[f32], outs: &mut [f32], batch: usize) {
    let in_len = s.in_len();
    let out_len = s.out_len();
    debug_assert_eq!(inputs.len(), batch * in_len);
    debug_assert_eq!(outs.len(), batch * out_len);

    let k = s.kernel;
    let is = s.in_side;
    let os = s.out_side;
    let imap = is * is;
    let omap = os * os;
    let inv = 1.0 / (k * k) as f32;

    for m in 0..s.maps {
        for oy in 0..os {
            for ox in 0..os {
                let o = m * omap + oy * os + ox;
                let win = (oy * k) * is + ox * k;
                for b in 0..batch {
                    let in_map = &inputs[b * in_len + m * imap..b * in_len + (m + 1) * imap];
                    let mut sum = 0.0f32;
                    for ky in 0..k {
                        let row = win + ky * is;
                        for kx in 0..k {
                            sum += in_map[row + kx];
                        }
                    }
                    outs[b * out_len + o] = sum * inv;
                }
            }
        }
    }
}

/// Backward average-pool: spread each output delta uniformly over its
/// window. `dinput` is overwritten.
pub fn avg_pool_backward(s: &PoolShape, delta: &[f32], dinput: &mut [f32]) {
    debug_assert_eq!(delta.len(), s.out_len());
    debug_assert_eq!(dinput.len(), s.in_len());

    let k = s.kernel;
    let is = s.in_side;
    let os = s.out_side;
    let imap = is * is;
    let omap = os * os;
    let inv = 1.0 / (k * k) as f32;

    dinput.fill(0.0);
    for m in 0..s.maps {
        let din_map = &mut dinput[m * imap..(m + 1) * imap];
        for oy in 0..os {
            for ox in 0..os {
                let d = delta[m * omap + oy * os + ox] * inv;
                for ky in 0..k {
                    let row = (oy * k + ky) * is + ox * k;
                    for kx in 0..k {
                        din_map[row + kx] += d;
                    }
                }
            }
        }
    }
}

/// Batched backward average-pool (`deltas` `[b][out_len]` → `dinputs`
/// `[b][in_len]`); window-stationary, sample-inner like
/// [`pool_backward_batch`] — disjoint windows keep it bit-identical to
/// tiled per-sample calls.
pub fn avg_pool_backward_batch(s: &PoolShape, deltas: &[f32], dinputs: &mut [f32], batch: usize) {
    let in_len = s.in_len();
    let out_len = s.out_len();
    debug_assert_eq!(deltas.len(), batch * out_len);
    debug_assert_eq!(dinputs.len(), batch * in_len);

    let k = s.kernel;
    let is = s.in_side;
    let os = s.out_side;
    let imap = is * is;
    let omap = os * os;
    let inv = 1.0 / (k * k) as f32;

    dinputs.fill(0.0);
    for m in 0..s.maps {
        for oy in 0..os {
            for ox in 0..os {
                let o = m * omap + oy * os + ox;
                let win = m * imap + (oy * k) * is + ox * k;
                for b in 0..batch {
                    let d = deltas[b * out_len + o] * inv;
                    let base = b * in_len + win;
                    for ky in 0..k {
                        let row = base + ky * is;
                        for kx in 0..k {
                            dinputs[row + kx] += d;
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{proptest, Pcg32};

    #[test]
    fn forward_picks_window_max() {
        // 1 map, 4x4 -> 2x2 with kernel 2.
        let s = PoolShape::new(1, 4, 2);
        #[rustfmt::skip]
        let input = [
            1.0, 2.0,   5.0, 1.0,
            3.0, 4.0,   0.0, 2.0,
            9.0, 0.0,   1.0, 1.0,
            0.0, 0.0,   1.0, 8.0,
        ];
        let mut out = [0.0; 4];
        let mut sw = [0u32; 4];
        pool_forward(&s, &input, &mut out, &mut sw);
        assert_eq!(out, [4.0, 5.0, 9.0, 8.0]);
        assert_eq!(sw, [5, 2, 8, 15]);
    }

    #[test]
    fn identity_pool_is_identity() {
        let s = PoolShape::new(2, 3, 1);
        let input: Vec<f32> = (0..18).map(|i| i as f32).collect();
        let mut out = vec![0.0; 18];
        let mut sw = vec![0u32; 18];
        pool_forward(&s, &input, &mut out, &mut sw);
        assert_eq!(out, input);
        for (i, &x) in sw.iter().enumerate() {
            assert_eq!(x as usize, i);
        }
    }

    #[test]
    fn backward_routes_to_argmax() {
        let s = PoolShape::new(1, 4, 2);
        #[rustfmt::skip]
        let input = [
            1.0, 2.0,   5.0, 1.0,
            3.0, 4.0,   0.0, 2.0,
            9.0, 0.0,   1.0, 1.0,
            0.0, 0.0,   1.0, 8.0,
        ];
        let mut out = [0.0; 4];
        let mut sw = [0u32; 4];
        pool_forward(&s, &input, &mut out, &mut sw);
        let delta = [10.0, 20.0, 30.0, 40.0];
        let mut din = [0.0; 16];
        pool_backward(&s, &delta, &sw, &mut din);
        assert_eq!(din[5], 10.0);
        assert_eq!(din[2], 20.0);
        assert_eq!(din[8], 30.0);
        assert_eq!(din[15], 40.0);
        assert_eq!(din.iter().filter(|&&v| v != 0.0).count(), 4);
    }

    #[test]
    fn switch_always_within_window() {
        proptest::run(
            proptest::Config { cases: 40, max_size: 6, ..Default::default() },
            |rng, size| {
                let maps = rng.range(1, 4);
                let kernel = rng.range(1, size.min(4) + 1);
                let out_side = rng.range(1, 5);
                let in_side = kernel * out_side;
                let s = PoolShape::new(maps, in_side, kernel);
                let input: Vec<f32> =
                    (0..s.in_len()).map(|_| rng.uniform(-1.0, 1.0)).collect();
                (s, input)
            },
            |(s, input)| {
                let mut out = vec![0.0; s.out_len()];
                let mut sw = vec![0u32; s.out_len()];
                pool_forward(s, input, &mut out, &mut sw);
                let imap = s.in_side * s.in_side;
                let omap = s.out_side * s.out_side;
                for m in 0..s.maps {
                    for oy in 0..s.out_side {
                        for ox in 0..s.out_side {
                            let o = m * omap + oy * s.out_side + ox;
                            let idx = sw[o] as usize;
                            // window membership
                            let mi = idx / imap;
                            let rem = idx % imap;
                            let y = rem / s.in_side;
                            let x = rem % s.in_side;
                            if mi != m
                                || y / s.kernel != oy
                                || x / s.kernel != ox
                                || input[idx] != out[o]
                            {
                                return Err(format!(
                                    "switch {idx} outside window for out {o}"
                                ));
                            }
                            // maximality
                            for ky in 0..s.kernel {
                                for kx in 0..s.kernel {
                                    let cand = m * imap
                                        + (oy * s.kernel + ky) * s.in_side
                                        + ox * s.kernel
                                        + kx;
                                    if input[cand] > out[o] {
                                        return Err(format!(
                                            "out {o} not the max of its window"
                                        ));
                                    }
                                }
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn avg_forward_known_values() {
        let s = PoolShape::new(1, 4, 2);
        #[rustfmt::skip]
        let input = [
            1.0, 2.0,   5.0, 1.0,
            3.0, 4.0,   0.0, 2.0,
            9.0, 0.0,   1.0, 1.0,
            0.0, 0.0,   1.0, 8.0,
        ];
        let mut out = [0.0; 4];
        avg_pool_forward(&s, &input, &mut out);
        assert_eq!(out, [2.5, 2.0, 2.25, 2.75]);
    }

    #[test]
    fn avg_backward_spreads_uniformly_and_conserves_mass() {
        let mut rng = Pcg32::seeded(4);
        let s = PoolShape::new(2, 6, 3);
        let delta: Vec<f32> = (0..s.out_len()).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut din = vec![0.0; s.in_len()];
        avg_pool_backward(&s, &delta, &mut din);
        let sum_d: f32 = delta.iter().sum();
        let sum_i: f32 = din.iter().sum();
        assert!((sum_d - sum_i).abs() < 1e-4, "delta mass must be conserved");
        // Every input in one window gets delta/k².
        assert!((din[0] - delta[0] / 9.0).abs() < 1e-6);
        assert!((din[2 * 6 + 1] - delta[0] / 9.0).abs() < 1e-6);
    }

    #[test]
    fn avg_identity_pool_is_identity() {
        let s = PoolShape::new(2, 3, 1);
        let input: Vec<f32> = (0..18).map(|i| i as f32).collect();
        let mut out = vec![0.0; 18];
        avg_pool_forward(&s, &input, &mut out);
        assert_eq!(out, input);
    }

    #[test]
    fn batched_pools_bit_identical_to_per_sample() {
        let mut rng = Pcg32::seeded(7);
        for (maps, in_side, kernel) in [(3, 6, 2), (2, 9, 3), (1, 4, 1)] {
            let s = PoolShape::new(maps, in_side, kernel);
            let batch = 4;
            let inputs: Vec<f32> =
                (0..batch * s.in_len()).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let deltas: Vec<f32> =
                (0..batch * s.out_len()).map(|_| rng.uniform(-1.0, 1.0)).collect();

            let mut outs_b = vec![0.0; batch * s.out_len()];
            let mut sw_b = vec![0u32; batch * s.out_len()];
            pool_forward_batch(&s, &inputs, &mut outs_b, &mut sw_b, batch);
            let mut din_b = vec![0.0; batch * s.in_len()];
            pool_backward_batch(&s, &deltas, &sw_b, &mut din_b, batch);
            let mut avg_b = vec![0.0; batch * s.out_len()];
            avg_pool_forward_batch(&s, &inputs, &mut avg_b, batch);
            let mut avg_din_b = vec![0.0; batch * s.in_len()];
            avg_pool_backward_batch(&s, &deltas, &mut avg_din_b, batch);

            for b in 0..batch {
                let input = &inputs[b * s.in_len()..(b + 1) * s.in_len()];
                let delta = &deltas[b * s.out_len()..(b + 1) * s.out_len()];
                let mut out = vec![0.0; s.out_len()];
                let mut sw = vec![0u32; s.out_len()];
                pool_forward(&s, input, &mut out, &mut sw);
                assert_eq!(&outs_b[b * s.out_len()..(b + 1) * s.out_len()], out.as_slice());
                assert_eq!(&sw_b[b * s.out_len()..(b + 1) * s.out_len()], sw.as_slice());
                let mut din = vec![0.0; s.in_len()];
                pool_backward(&s, delta, &sw, &mut din);
                assert_eq!(&din_b[b * s.in_len()..(b + 1) * s.in_len()], din.as_slice());
                let mut avg = vec![0.0; s.out_len()];
                avg_pool_forward(&s, input, &mut avg);
                assert_eq!(&avg_b[b * s.out_len()..(b + 1) * s.out_len()], avg.as_slice());
                let mut avg_din = vec![0.0; s.in_len()];
                avg_pool_backward(&s, delta, &mut avg_din);
                assert_eq!(
                    &avg_din_b[b * s.in_len()..(b + 1) * s.in_len()],
                    avg_din.as_slice()
                );
            }
        }
    }

    #[test]
    fn backward_conserves_delta_mass() {
        let mut rng = Pcg32::seeded(3);
        let s = PoolShape::new(3, 6, 2);
        let input: Vec<f32> = (0..s.in_len()).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut out = vec![0.0; s.out_len()];
        let mut sw = vec![0u32; s.out_len()];
        pool_forward(&s, &input, &mut out, &mut sw);
        let delta: Vec<f32> = (0..s.out_len()).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut din = vec![0.0; s.in_len()];
        pool_backward(&s, &delta, &sw, &mut din);
        let sum_d: f32 = delta.iter().sum();
        let sum_i: f32 = din.iter().sum();
        assert!((sum_d - sum_i).abs() < 1e-4, "delta mass must be conserved");
    }
}
