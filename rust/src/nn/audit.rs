//! `nn::audit` — static analysis over compiled networks.
//!
//! Three layers, all pure functions over plain data so defective inputs
//! can be hand-built in tests:
//!
//! 1. **Dataflow/aliasing verifier** ([`audit_dataflow`]): proves the
//!    `in_shape`/`out_shape` chain coherent end-to-end (every op consumes
//!    exactly what its upstream produces, and agrees with the compiler's
//!    [`LayerDims`](super::dims::LayerDims) table), and that a
//!    [`BatchScratch`](super::batch::BatchScratch)'s arenas are sized
//!    exactly to their planes with no byte overlap between the ping-pong
//!    delta planes, the live activation planes, and the staging buffers —
//!    and that the per-layer dropout PRNG streams are pairwise distinct.
//!    Debug builds run it at `Network::compile` right after the span
//!    verifier; `chaos analyze` runs it from the CLI.
//! 2. **Kernel-dispatch classifier** ([`audit_dispatch`]): every
//!    [`LayerOp`](super::layer::LayerOp) names the kernel path its
//!    forward/backward batch kernels compile to ([`KernelPath`], via
//!    `LayerOp::dispatch` — conservative `PerSampleLoop` default for
//!    runtime-registered kinds), and the [`KernelReport`] flags every op
//!    off the vectorized fast paths: the exact work-list for the SIMD /
//!    cache-blocking pass.
//! 3. **Static cost model** ([`audit_cost`]): per-op FLOPs and bytes
//!    moved under the weight-stationary execution model (parameter spans
//!    are loaded **once per batch**, so their traffic amortizes over the
//!    batch), with arithmetic intensity per op and whole-net roofline
//!    totals. `perfmodel::LayerCosts::derived` consumes these instead of
//!    the hand-fit Table-3 constants; `benches/layer_ops.rs` is the
//!    measured cross-check.
//!
//! JSON views carry a `schema` version field (`chaos.analyze.*/v1`),
//! matching the self-checked `BENCH_*.json` convention.

use super::network::Network;
use crate::util::Json;
use std::fmt;

/// Batch capacity used by the compile-time dataflow audit: 2 is the
/// smallest capacity that exercises per-sample plane strides.
pub const AUDIT_CAP: usize = 2;

// ---------------------------------------------------------------------------
// Defects
// ---------------------------------------------------------------------------

/// One dataflow/aliasing defect found by the verifier.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DataflowDefect {
    /// An op's input element count disagrees with its upstream op's
    /// output element count.
    BrokenChain { layer: usize, got: usize, expected: usize },
    /// An op's own shape disagrees with the compiler's `LayerDims` row.
    OpShapeMismatch { layer: usize, kind: String, side: &'static str, op: usize, dims: usize },
    /// An expected arena is absent from the scratch layout.
    ArenaMissing { name: String },
    /// An arena is not sized exactly to its plane.
    ArenaMisSized { name: String, expected: usize, got: usize },
    /// Two live arenas overlap in memory (aliased planes).
    ArenaOverlap { a: String, b: String },
    /// Two per-layer PRNG streams coincide (dropout masks would repeat).
    DuplicateRngStream { a: usize, b: usize, stream: u64 },
}

impl DataflowDefect {
    /// Stable machine-readable class tag (mirrors `SpanDefect::class`).
    pub fn class(&self) -> &'static str {
        match self {
            DataflowDefect::BrokenChain { .. } => "shape-chain",
            DataflowDefect::OpShapeMismatch { .. } => "op-shape-mismatch",
            DataflowDefect::ArenaMissing { .. } => "arena-missing",
            DataflowDefect::ArenaMisSized { .. } => "arena-size",
            DataflowDefect::ArenaOverlap { .. } => "arena-overlap",
            DataflowDefect::DuplicateRngStream { .. } => "dup-rng-stream",
        }
    }
}

impl fmt::Display for DataflowDefect {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DataflowDefect::BrokenChain { layer, got, expected } => write!(
                f,
                "layer {layer}: input length {got} does not match upstream output {expected}"
            ),
            DataflowDefect::OpShapeMismatch { layer, kind, side, op, dims } => write!(
                f,
                "layer {layer} ({kind}): op {side} length {op} disagrees with compiled dims {dims}"
            ),
            DataflowDefect::ArenaMissing { name } => {
                write!(f, "arena '{name}' missing from the scratch layout")
            }
            DataflowDefect::ArenaMisSized { name, expected, got } => {
                write!(f, "arena '{name}' holds {got} elements, plane needs exactly {expected}")
            }
            DataflowDefect::ArenaOverlap { a, b } => {
                write!(f, "arenas '{a}' and '{b}' overlap in memory")
            }
            DataflowDefect::DuplicateRngStream { a, b, stream } => {
                write!(f, "layers {a} and {b} share PRNG stream {stream}")
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Shape-chain verification
// ---------------------------------------------------------------------------

/// One row of the shape chain: what the op itself declares vs. what the
/// compiler's dims table recorded, as element counts (flattening between
/// feature maps and fc vectors preserves the count, so counts are the
/// invariant the chain can be checked on).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShapeRow {
    pub layer: usize,
    pub kind: String,
    pub op_in: usize,
    pub op_out: usize,
    pub dims_in: usize,
    pub dims_out: usize,
}

/// Extract the shape chain from a compiled network.
pub fn shape_rows(net: &Network) -> Vec<ShapeRow> {
    net.ops
        .iter()
        .zip(&net.dims)
        .enumerate()
        .map(|(layer, (op, d))| ShapeRow {
            layer,
            kind: op.kind().to_string(),
            op_in: op.in_shape().len(),
            op_out: op.out_shape().len(),
            dims_in: d.in_len(),
            dims_out: d.out_len(),
        })
        .collect()
}

/// Activation element counts at each layer boundary, from the audited
/// dims chain: entry `l` is the tensor a sample presents *to* layer `l`
/// (so for `l ≥ 1` it is exactly what crosses the boundary between layer
/// `l − 1` and layer `l`, and entry 0 is the network input). The shard
/// verifier and comm cost model ([`crate::chaos::analysis::shard`],
/// [`crate::perfmodel::score_plan`]) price cross-shard traffic in these
/// units — the boundary tensor is the audited activation and nothing
/// else, which is what makes "only activations cross shard boundaries"
/// a checkable statement rather than a convention.
pub fn boundary_act_elems(net: &Network) -> Vec<usize> {
    net.dims.iter().map(|d| d.in_len()).collect()
}

/// Verify a shape chain: per-row op/dims agreement, and end-to-end
/// coherence (each row consumes exactly what the previous row produced).
pub fn verify_shape_rows(rows: &[ShapeRow]) -> Vec<DataflowDefect> {
    let mut defects = Vec::new();
    for row in rows {
        if row.op_in != row.dims_in {
            defects.push(DataflowDefect::OpShapeMismatch {
                layer: row.layer,
                kind: row.kind.clone(),
                side: "in",
                op: row.op_in,
                dims: row.dims_in,
            });
        }
        if row.op_out != row.dims_out {
            defects.push(DataflowDefect::OpShapeMismatch {
                layer: row.layer,
                kind: row.kind.clone(),
                side: "out",
                op: row.op_out,
                dims: row.dims_out,
            });
        }
    }
    for pair in rows.windows(2) {
        let (up, down) = (&pair[0], &pair[1]);
        if down.dims_in != up.dims_out {
            defects.push(DataflowDefect::BrokenChain {
                layer: down.layer,
                got: down.dims_in,
                expected: up.dims_out,
            });
        }
    }
    defects
}

// ---------------------------------------------------------------------------
// Arena-layout verification
// ---------------------------------------------------------------------------

/// One arena of a `BatchScratch`, reduced to its memory extent:
/// `addr` is the base byte address, `len` the element count (all arenas
/// hold 4-byte elements — `f32` planes or `u32` aux words).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArenaExtent {
    pub name: String,
    pub addr: usize,
    pub len: usize,
}

impl ArenaExtent {
    /// Half-open byte range of this extent.
    fn bytes(&self) -> (usize, usize) {
        (self.addr, self.addr + 4 * self.len)
    }
}

/// The full arena layout of one `BatchScratch` (see
/// [`super::batch::BatchScratch::layout`]), plus the per-layer PRNG
/// stream identifiers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArenaLayout {
    pub cap: usize,
    pub extents: Vec<ArenaExtent>,
    pub rng_streams: Vec<u64>,
}

/// Verify an arena layout against the expected `(name, element count)`
/// plane sizes: every expected arena present and sized exactly, no two
/// non-empty arenas overlapping in memory, all PRNG streams distinct.
pub fn verify_arena_layout(
    layout: &ArenaLayout,
    expected: &[(String, usize)],
) -> Vec<DataflowDefect> {
    let mut defects = Vec::new();
    for (name, want) in expected {
        match layout.extents.iter().find(|e| &e.name == name) {
            None => defects.push(DataflowDefect::ArenaMissing { name: name.clone() }),
            Some(e) if e.len != *want => defects.push(DataflowDefect::ArenaMisSized {
                name: name.clone(),
                expected: *want,
                got: e.len,
            }),
            Some(_) => {}
        }
    }
    for i in 0..layout.extents.len() {
        for j in i + 1..layout.extents.len() {
            let (a, b) = (&layout.extents[i], &layout.extents[j]);
            if a.len == 0 || b.len == 0 {
                // Empty arenas have dangling (possibly shared) base
                // pointers and no live bytes — nothing to alias.
                continue;
            }
            let ((a0, a1), (b0, b1)) = (a.bytes(), b.bytes());
            if a0 < b1 && b0 < a1 {
                defects.push(DataflowDefect::ArenaOverlap {
                    a: a.name.clone(),
                    b: b.name.clone(),
                });
            }
        }
    }
    for i in 0..layout.rng_streams.len() {
        for j in i + 1..layout.rng_streams.len() {
            if layout.rng_streams[i] == layout.rng_streams[j] {
                defects.push(DataflowDefect::DuplicateRngStream {
                    a: i,
                    b: j,
                    stream: layout.rng_streams[i],
                });
            }
        }
    }
    defects
}

/// The exact arena sizes a `BatchScratch` of capacity `cap` must expose
/// for `net` once the backward arenas are materialized: per-layer
/// activation planes, per-layer aux words, the param staging buffer and
/// grad staging buffer (both max plane over the stack), and the two
/// ping-pong delta planes (capacity × max activation plane).
pub fn expected_extents(net: &Network, cap: usize) -> Vec<(String, usize)> {
    let mut v = Vec::new();
    for (l, d) in net.dims.iter().enumerate() {
        v.push((format!("acts[{l}]"), cap * d.out_len()));
    }
    for (l, op) in net.ops.iter().enumerate() {
        v.push((format!("aux[{l}]"), cap * op.aux_len()));
    }
    let max_params = net.dims.iter().map(|d| d.param_count()).max().unwrap_or(0);
    let max_act = net.dims.iter().map(|d| d.out_len()).max().unwrap_or(0);
    let max_col = net.ops.iter().map(|op| op.im2col_len()).max().unwrap_or(0);
    v.push(("param_buf".to_string(), max_params));
    v.push(("delta_a".to_string(), cap * max_act));
    v.push(("delta_b".to_string(), cap * max_act));
    v.push(("grad_buf".to_string(), max_params));
    // One shared im2col staging panel (per sample, reused across the
    // batch), zero-length when no op wants the im2col route.
    v.push(("im2col".to_string(), max_col));
    v
}

// ---------------------------------------------------------------------------
// Dataflow report
// ---------------------------------------------------------------------------

/// Outcome of the dataflow/aliasing audit over one compiled network.
#[derive(Debug, Clone)]
pub struct DataflowReport {
    pub arch: String,
    pub layers: usize,
    /// Batch capacity the arena layout was audited at.
    pub cap: usize,
    pub defects: Vec<DataflowDefect>,
}

impl DataflowReport {
    pub fn is_clean(&self) -> bool {
        self.defects.is_empty()
    }

    pub fn to_text(&self) -> String {
        let mut s = format!(
            "{}: dataflow audit over {} layers (arena cap {}) — {}\n",
            self.arch,
            self.layers,
            self.cap,
            if self.is_clean() {
                "clean".to_string()
            } else {
                format!("{} defect(s)", self.defects.len())
            }
        );
        for d in &self.defects {
            s.push_str(&format!("  [{}] {d}\n", d.class()));
        }
        s
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::str("chaos.analyze.dataflow/v1")),
            ("arch", Json::str(self.arch.clone())),
            ("layers", Json::num(self.layers as f64)),
            ("cap", Json::num(self.cap as f64)),
            ("clean", Json::Bool(self.is_clean())),
            (
                "defects",
                Json::arr(
                    self.defects
                        .iter()
                        .map(|d| {
                            Json::obj(vec![
                                ("class", Json::str(d.class())),
                                ("detail", Json::str(d.to_string())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Run the full dataflow/aliasing audit over a compiled network: shape
/// chain, arena layout of a real `BatchScratch` (backward arenas
/// materialized), and PRNG stream distinctness.
pub fn audit_dataflow(net: &Network) -> DataflowReport {
    let rows = shape_rows(net);
    let mut defects = verify_shape_rows(&rows);
    let plan = net.batch_plan(AUDIT_CAP).expect("audit batch capacity is ≥ 1");
    let mut scratch = plan.scratch_seeded(0);
    scratch.ensure_backward_arenas(net);
    let layout = scratch.layout();
    defects.extend(verify_arena_layout(&layout, &expected_extents(net, AUDIT_CAP)));
    DataflowReport { arch: net.arch.name.clone(), layers: net.ops.len(), cap: AUDIT_CAP, defects }
}

// ---------------------------------------------------------------------------
// Kernel-dispatch classification
// ---------------------------------------------------------------------------

/// The kernel path a batched op compiles to. `fast()` paths keep the
/// whole batch in one vectorizable kernel invocation; the rest are the
/// SIMD/cache-blocking work-list.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelPath {
    /// Stride-1, pad-0 conv kernels with the batch loop hoisted inside
    /// the kernel-tap loop.
    VectorizedPlain,
    /// GEMM-shaped fc kernels: weights stationary while the batch streams.
    WeightStationary,
    /// Padded/strided conv via tap-stationary batched kernels with an
    /// im2col+GEMM staging route under fast math.
    Im2colGemm,
    /// Parameter-free window kernels swept with the batch as the lane
    /// axis (window geometry computed once, applied across samples).
    BatchLane,
    /// Cache-blocked GEMM-shaped fc kernels: `GEMM_KC`-long k-panels ×
    /// `GEMM_MR`-row register blocks (see `nn::simd`).
    BlockedGemm,
    /// One flat elementwise sweep over the whole `[batch][len]` block.
    BlockElementwise,
    /// Batched driver tiles the per-sample kernel sample-by-sample
    /// (amortizes the param load only).
    TiledPerSample,
    /// General padded/strided fallback kernel — gather-heavy, off every
    /// vectorized path.
    GeneralFallback,
    /// Trait-default loop over the per-sample kernel (sequential RNG
    /// draws or an un-overridden custom kind).
    PerSampleLoop,
    /// Never executed (the input placeholder).
    Inert,
}

impl KernelPath {
    pub fn name(self) -> &'static str {
        match self {
            KernelPath::VectorizedPlain => "vectorized-plain",
            KernelPath::WeightStationary => "weight-stationary",
            KernelPath::Im2colGemm => "im2col-gemm",
            KernelPath::BatchLane => "batch-lane",
            KernelPath::BlockedGemm => "blocked-gemm",
            KernelPath::BlockElementwise => "block-elementwise",
            KernelPath::TiledPerSample => "tiled-per-sample",
            KernelPath::GeneralFallback => "general-fallback",
            KernelPath::PerSampleLoop => "per-sample-loop",
            KernelPath::Inert => "inert",
        }
    }

    /// Whether this path is one of the vectorized fast paths.
    pub fn fast(self) -> bool {
        matches!(
            self,
            KernelPath::VectorizedPlain
                | KernelPath::WeightStationary
                | KernelPath::Im2colGemm
                | KernelPath::BatchLane
                | KernelPath::BlockedGemm
                | KernelPath::BlockElementwise
                | KernelPath::Inert
        )
    }
}

impl fmt::Display for KernelPath {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// The kernel paths one op's forward and backward batch kernels take.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Dispatch {
    pub forward: KernelPath,
    pub backward: KernelPath,
}

impl Dispatch {
    /// Same path both directions.
    pub fn uniform(path: KernelPath) -> Dispatch {
        Dispatch { forward: path, backward: path }
    }

    /// The conservative trait default: un-overridden batch kernels loop
    /// the per-sample kernel.
    pub fn per_sample() -> Dispatch {
        Dispatch::uniform(KernelPath::PerSampleLoop)
    }

    /// The input placeholder: never driven.
    pub fn inert() -> Dispatch {
        Dispatch::uniform(KernelPath::Inert)
    }

    /// On the fast path in both directions.
    pub fn fast(self) -> bool {
        self.forward.fast() && self.backward.fast()
    }
}

/// One layer's dispatch classification.
#[derive(Debug, Clone)]
pub struct KernelRow {
    pub layer: usize,
    pub kind: String,
    pub dispatch: Dispatch,
}

/// Dispatch classification of every op in a compiled network.
#[derive(Debug, Clone)]
pub struct KernelReport {
    pub arch: String,
    pub rows: Vec<KernelRow>,
}

impl KernelReport {
    /// The SIMD work-list: every op off a vectorized fast path.
    pub fn off_fast_path(&self) -> Vec<&KernelRow> {
        self.rows.iter().filter(|r| !r.dispatch.fast()).collect()
    }

    pub fn to_text(&self) -> String {
        let off = self.off_fast_path().len();
        let mut s = format!(
            "{}: kernel dispatch — {} of {} op(s) off the vectorized fast path\n",
            self.arch,
            off,
            self.rows.len()
        );
        s.push_str("  layer  kind      forward            backward\n");
        for r in &self.rows {
            s.push_str(&format!(
                "  {:>5}  {:<8}  {:<17}  {:<17}{}\n",
                r.layer,
                r.kind,
                r.dispatch.forward.name(),
                r.dispatch.backward.name(),
                if r.dispatch.fast() { "" } else { "  !" }
            ));
        }
        s
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            // /v2: adds the im2col-gemm / batch-lane / blocked-gemm
            // classes and the GEMM tile constants.
            ("schema", Json::str("chaos.analyze.kernel/v2")),
            ("arch", Json::str(self.arch.clone())),
            ("off_fast_path", Json::num(self.off_fast_path().len() as f64)),
            (
                "tiles",
                Json::obj(vec![
                    ("gemm_kc", Json::num(crate::nn::simd::GEMM_KC as f64)),
                    ("gemm_mr", Json::num(crate::nn::simd::GEMM_MR as f64)),
                ]),
            ),
            (
                "layers",
                Json::arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("layer", Json::num(r.layer as f64)),
                                ("kind", Json::str(r.kind.clone())),
                                ("forward", Json::str(r.dispatch.forward.name())),
                                ("backward", Json::str(r.dispatch.backward.name())),
                                ("fast", Json::Bool(r.dispatch.fast())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Classify every op's kernel dispatch (via `LayerOp::dispatch`, which
/// runtime-registered kinds inherit conservatively).
pub fn audit_dispatch(net: &Network) -> KernelReport {
    let rows = net
        .ops
        .iter()
        .enumerate()
        .map(|(layer, op)| KernelRow {
            layer,
            kind: op.kind().to_string(),
            dispatch: op.dispatch(),
        })
        .collect();
    KernelReport { arch: net.arch.name.clone(), rows }
}

// ---------------------------------------------------------------------------
// Static cost model
// ---------------------------------------------------------------------------

/// Per-sample static cost of one op under the weight-stationary execution
/// model. FLOPs and activation bytes are per sample; `param_bytes` is the
/// parameter span traffic charged **once per batch** (the whole point of
/// the batched drivers), so byte totals amortize it by the batch size.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpCost {
    pub fwd_flops: f64,
    pub bwd_flops: f64,
    /// 4 · parameter span length — loaded once per batch per direction.
    pub param_bytes: f64,
    /// Activation traffic of one forward sample (read input + write output).
    pub fwd_act_bytes: f64,
    /// Activation traffic of one backward sample (deltas both directions
    /// plus the stored activations).
    pub bwd_act_bytes: f64,
}

impl OpCost {
    pub fn zero() -> OpCost {
        OpCost {
            fwd_flops: 0.0,
            bwd_flops: 0.0,
            param_bytes: 0.0,
            fwd_act_bytes: 0.0,
            bwd_act_bytes: 0.0,
        }
    }

    /// The conservative trait default for kinds without a cost override:
    /// one touch per input/output element forward, twice that backward,
    /// the parameter span counted once per batch.
    pub fn generic(in_len: usize, out_len: usize, param_len: usize) -> OpCost {
        let touched = (in_len + out_len) as f64;
        OpCost {
            fwd_flops: touched,
            bwd_flops: 2.0 * touched,
            param_bytes: 4.0 * param_len as f64,
            fwd_act_bytes: 4.0 * touched,
            bwd_act_bytes: 8.0 * touched,
        }
    }

    /// Forward bytes per sample at batch size `batch` (weight traffic
    /// amortized over the batch).
    pub fn fwd_bytes(&self, batch: usize) -> f64 {
        self.fwd_act_bytes + self.param_bytes / batch as f64
    }

    /// Backward bytes per sample at batch size `batch`.
    pub fn bwd_bytes(&self, batch: usize) -> f64 {
        self.bwd_act_bytes + self.param_bytes / batch as f64
    }

    /// Forward arithmetic intensity (FLOPs per byte) at batch size `batch`.
    pub fn fwd_intensity(&self, batch: usize) -> f64 {
        intensity(self.fwd_flops, self.fwd_bytes(batch))
    }

    /// Backward arithmetic intensity at batch size `batch`.
    pub fn bwd_intensity(&self, batch: usize) -> f64 {
        intensity(self.bwd_flops, self.bwd_bytes(batch))
    }
}

fn intensity(flops: f64, bytes: f64) -> f64 {
    if bytes > 0.0 {
        flops / bytes
    } else {
        0.0
    }
}

/// One layer's static cost plus its dispatch classification — a row of
/// the `chaos analyze --cost` table.
#[derive(Debug, Clone)]
pub struct CostRow {
    pub layer: usize,
    pub kind: String,
    pub dispatch: Dispatch,
    pub cost: OpCost,
}

/// The whole-net static cost model at one batch size.
#[derive(Debug, Clone)]
pub struct CostReport {
    pub arch: String,
    pub batch: usize,
    pub rows: Vec<CostRow>,
}

impl CostReport {
    pub fn total_fwd_flops(&self) -> f64 {
        self.rows.iter().map(|r| r.cost.fwd_flops).sum()
    }

    pub fn total_bwd_flops(&self) -> f64 {
        self.rows.iter().map(|r| r.cost.bwd_flops).sum()
    }

    pub fn total_fwd_bytes(&self) -> f64 {
        self.rows.iter().map(|r| r.cost.fwd_bytes(self.batch)).sum()
    }

    pub fn total_bwd_bytes(&self) -> f64 {
        self.rows.iter().map(|r| r.cost.bwd_bytes(self.batch)).sum()
    }

    /// Whole-net forward arithmetic intensity.
    pub fn fwd_intensity(&self) -> f64 {
        intensity(self.total_fwd_flops(), self.total_fwd_bytes())
    }

    /// Whole-net backward arithmetic intensity.
    pub fn bwd_intensity(&self) -> f64 {
        intensity(self.total_bwd_flops(), self.total_bwd_bytes())
    }

    pub fn to_text(&self) -> String {
        let mut s = format!(
            "{}: static cost model, per sample at batch {} (weights amortized per batch)\n",
            self.arch, self.batch
        );
        s.push_str(
            "  layer  kind      forward            backward           \
             fwd flops   bwd flops   fwd bytes   fwd ai\n",
        );
        for r in &self.rows {
            s.push_str(&format!(
                "  {:>5}  {:<8}  {:<17}  {:<17}  {:>10.3e}  {:>10.3e}  {:>10.3e}  {:>7.2}{}\n",
                r.layer,
                r.kind,
                r.dispatch.forward.name(),
                r.dispatch.backward.name(),
                r.cost.fwd_flops,
                r.cost.bwd_flops,
                r.cost.fwd_bytes(self.batch),
                r.cost.fwd_intensity(self.batch),
                if r.dispatch.fast() { "" } else { "  !" }
            ));
        }
        s.push_str(&format!(
            "  total  fwd {:.3e} flop / {:.3e} B (ai {:.2})   bwd {:.3e} flop / {:.3e} B (ai {:.2})\n",
            self.total_fwd_flops(),
            self.total_fwd_bytes(),
            self.fwd_intensity(),
            self.total_bwd_flops(),
            self.total_bwd_bytes(),
            self.bwd_intensity(),
        ));
        s
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::str("chaos.analyze.cost/v1")),
            ("arch", Json::str(self.arch.clone())),
            ("batch", Json::num(self.batch as f64)),
            (
                "layers",
                Json::arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("layer", Json::num(r.layer as f64)),
                                ("kind", Json::str(r.kind.clone())),
                                ("forward", Json::str(r.dispatch.forward.name())),
                                ("backward", Json::str(r.dispatch.backward.name())),
                                ("fast", Json::Bool(r.dispatch.fast())),
                                ("fwd_flops", Json::num(r.cost.fwd_flops)),
                                ("bwd_flops", Json::num(r.cost.bwd_flops)),
                                ("param_bytes", Json::num(r.cost.param_bytes)),
                                ("fwd_bytes", Json::num(r.cost.fwd_bytes(self.batch))),
                                ("bwd_bytes", Json::num(r.cost.bwd_bytes(self.batch))),
                                ("fwd_intensity", Json::num(r.cost.fwd_intensity(self.batch))),
                                ("bwd_intensity", Json::num(r.cost.bwd_intensity(self.batch))),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "totals",
                Json::obj(vec![
                    ("fwd_flops", Json::num(self.total_fwd_flops())),
                    ("bwd_flops", Json::num(self.total_bwd_flops())),
                    ("fwd_bytes", Json::num(self.total_fwd_bytes())),
                    ("bwd_bytes", Json::num(self.total_bwd_bytes())),
                    ("fwd_intensity", Json::num(self.fwd_intensity())),
                    ("bwd_intensity", Json::num(self.bwd_intensity())),
                ]),
            ),
        ])
    }
}

/// Build the static cost model for a compiled network at one batch size
/// (via `LayerOp::cost`, which runtime-registered kinds inherit
/// conservatively).
pub fn audit_cost(net: &Network, batch: usize) -> CostReport {
    assert!(batch >= 1, "cost model batch size must be ≥ 1");
    let rows = net
        .ops
        .iter()
        .enumerate()
        .map(|(layer, op)| CostRow {
            layer,
            kind: op.kind().to_string(),
            dispatch: op.dispatch(),
            cost: op.cost(),
        })
        .collect();
    CostReport { arch: net.arch.name.clone(), batch, rows }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchSpec;

    fn row(layer: usize, inn: usize, out: usize) -> ShapeRow {
        ShapeRow {
            layer,
            kind: "conv".to_string(),
            op_in: inn,
            op_out: out,
            dims_in: inn,
            dims_out: out,
        }
    }

    #[test]
    fn clean_shape_chain_has_no_defects() {
        let rows = vec![row(0, 9, 9), row(1, 9, 4), row(2, 4, 10)];
        assert!(verify_shape_rows(&rows).is_empty());
    }

    #[test]
    fn broken_chain_and_op_mismatch_are_detected() {
        // Layer 2 consumes 5 elements where layer 1 produced 4.
        let rows = vec![row(0, 9, 9), row(1, 9, 4), row(2, 5, 10)];
        let classes: Vec<_> = verify_shape_rows(&rows).iter().map(|d| d.class()).collect();
        assert!(classes.contains(&"shape-chain"), "{classes:?}");

        // Op disagrees with the compiled dims table.
        let mut bad = row(1, 9, 4);
        bad.op_out = 7;
        let defects = verify_shape_rows(&[row(0, 9, 9), bad]);
        assert!(
            defects.iter().any(|d| matches!(
                d,
                DataflowDefect::OpShapeMismatch { side: "out", op: 7, dims: 4, .. }
            )),
            "{defects:?}"
        );
    }

    fn extent(name: &str, addr: usize, len: usize) -> ArenaExtent {
        ArenaExtent { name: name.to_string(), addr, len }
    }

    #[test]
    fn arena_layout_defects_are_detected() {
        let expected =
            vec![("delta_a".to_string(), 8), ("delta_b".to_string(), 8), ("acts[0]".to_string(), 4)];
        // Clean: disjoint byte ranges, exact sizes, distinct streams.
        let clean = ArenaLayout {
            cap: 2,
            extents: vec![
                extent("delta_a", 0, 8),
                extent("delta_b", 64, 8),
                extent("acts[0]", 128, 4),
            ],
            rng_streams: vec![0, 1, 2],
        };
        assert!(verify_arena_layout(&clean, &expected).is_empty());

        // Aliased ping-pong delta planes: delta_b starts inside delta_a.
        let aliased = ArenaLayout {
            cap: 2,
            extents: vec![
                extent("delta_a", 0, 8),
                extent("delta_b", 16, 8),
                extent("acts[0]", 128, 4),
            ],
            rng_streams: vec![0, 1, 2],
        };
        let classes: Vec<_> =
            verify_arena_layout(&aliased, &expected).iter().map(|d| d.class()).collect();
        assert_eq!(classes, vec!["arena-overlap"]);

        // Missing + mis-sized arenas.
        let short = ArenaLayout {
            cap: 2,
            extents: vec![extent("delta_a", 0, 6), extent("delta_b", 64, 8)],
            rng_streams: vec![0, 1],
        };
        let classes: Vec<_> =
            verify_arena_layout(&short, &expected).iter().map(|d| d.class()).collect();
        assert!(classes.contains(&"arena-size"), "{classes:?}");
        assert!(classes.contains(&"arena-missing"), "{classes:?}");

        // Duplicate PRNG streams.
        let dup = ArenaLayout {
            cap: 2,
            extents: vec![
                extent("delta_a", 0, 8),
                extent("delta_b", 64, 8),
                extent("acts[0]", 128, 4),
            ],
            rng_streams: vec![3, 5, 3],
        };
        let defects = verify_arena_layout(&dup, &expected);
        assert!(
            defects
                .iter()
                .any(|d| matches!(d, DataflowDefect::DuplicateRngStream { a: 0, b: 2, stream: 3 })),
            "{defects:?}"
        );
    }

    #[test]
    fn empty_extents_never_alias() {
        // Two zero-length arenas sharing a dangling base pointer are fine.
        let layout = ArenaLayout {
            cap: 1,
            extents: vec![extent("aux[1]", 4, 0), extent("aux[2]", 4, 0)],
            rng_streams: vec![],
        };
        assert!(verify_arena_layout(&layout, &[]).is_empty());
    }

    #[test]
    fn fast_path_classification() {
        assert!(KernelPath::VectorizedPlain.fast());
        assert!(KernelPath::WeightStationary.fast());
        assert!(KernelPath::Im2colGemm.fast());
        assert!(KernelPath::BatchLane.fast());
        assert!(KernelPath::BlockedGemm.fast());
        assert!(KernelPath::BlockElementwise.fast());
        assert!(KernelPath::Inert.fast());
        assert!(!KernelPath::TiledPerSample.fast());
        assert!(!KernelPath::GeneralFallback.fast());
        assert!(!KernelPath::PerSampleLoop.fast());
        assert_eq!(KernelPath::Im2colGemm.name(), "im2col-gemm");
        assert_eq!(KernelPath::BatchLane.name(), "batch-lane");
        assert_eq!(KernelPath::BlockedGemm.name(), "blocked-gemm");
        let d = Dispatch { forward: KernelPath::PerSampleLoop, backward: KernelPath::BlockElementwise };
        assert!(!d.fast(), "one slow direction keeps the op on the work-list");
        assert!(Dispatch::uniform(KernelPath::WeightStationary).fast());
    }

    #[test]
    fn op_cost_amortizes_weights_over_the_batch() {
        let c = OpCost {
            fwd_flops: 100.0,
            bwd_flops: 200.0,
            param_bytes: 400.0,
            fwd_act_bytes: 40.0,
            bwd_act_bytes: 80.0,
        };
        assert_eq!(c.fwd_bytes(1), 440.0);
        assert_eq!(c.fwd_bytes(10), 80.0);
        assert!(c.fwd_intensity(10) > c.fwd_intensity(1), "batching raises intensity");
        assert_eq!(OpCost::zero().fwd_intensity(4), 0.0, "zero bytes must not divide by zero");
    }

    #[test]
    fn tiny_network_audits_clean() {
        let net = Network::new(ArchSpec::tiny());
        let report = audit_dataflow(&net);
        assert!(report.is_clean(), "{}", report.to_text());
        assert_eq!(report.layers, net.ops.len());
        // JSON carries the schema tag and round-trips.
        let json = Json::parse(&report.to_json().pretty()).unwrap();
        assert_eq!(
            json.get("schema").and_then(Json::as_str),
            Some("chaos.analyze.dataflow/v1")
        );
        assert_eq!(json.get("clean").and_then(Json::as_bool), Some(true));
    }

    #[test]
    fn tiny_cost_report_is_positive_and_tagged() {
        let net = Network::new(ArchSpec::tiny());
        let cost = audit_cost(&net, 32);
        assert!(cost.total_fwd_flops() > 0.0);
        assert!(
            cost.total_bwd_flops() > cost.total_fwd_flops(),
            "backward does strictly more arithmetic than forward"
        );
        let json = Json::parse(&cost.to_json().pretty()).unwrap();
        assert_eq!(json.get("schema").and_then(Json::as_str), Some("chaos.analyze.cost/v1"));
        let kernel = audit_dispatch(&net);
        let kjson = Json::parse(&kernel.to_json().pretty()).unwrap();
        assert_eq!(kjson.get("schema").and_then(Json::as_str), Some("chaos.analyze.kernel/v2"));
        let tiles = kjson.get("tiles").expect("v2 carries the GEMM tile constants");
        assert_eq!(
            tiles.get("gemm_kc").and_then(Json::as_usize),
            Some(crate::nn::simd::GEMM_KC)
        );
        assert_eq!(
            tiles.get("gemm_mr").and_then(Json::as_usize),
            Some(crate::nn::simd::GEMM_MR)
        );
    }
}
