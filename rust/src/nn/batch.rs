//! Batched execution: [`BatchPlan`] + [`BatchScratch`].
//!
//! The per-sample orchestrator ([`super::Network::forward`]) re-loads every
//! layer's parameter span through [`ParamSource`] once **per image**. A
//! [`BatchPlan`] drives the same compiled op pipeline over `[B][len]`
//! flat activation arenas and loads each layer's span exactly **once per
//! batch**, handing the ops their weight-stationary
//! [`LayerOp::forward_batch`]/[`LayerOp::backward_batch`] kernels. This is
//! the data-parallel batching of Krizhevsky's "one weird trick"
//! (arXiv:1404.5997) applied to the paper's SIMD story: contiguous
//! activation rows across the batch keep the inner loops
//! auto-vectorizer-friendly while weight traffic amortizes.
//!
//! Arenas live in 64-byte-aligned buffers ([`crate::tensor::AlignedBuf`],
//! the paper's `_mm_malloc(…, 64)` discipline). Consumers:
//! [`crate::runtime::NativeBatchEngine`] (serving), the trainer's
//! validation/testing phases, and the minibatch update policies'
//! training phases (`chaos::trainer` / `chaos::policy`). The backward
//! arenas (delta ping-pong planes + the gradient staging buffer) allocate
//! lazily on the first [`BatchPlan::backward`] call, so forward-only
//! consumers pay nothing for them.
//!
//! Bit-identity: `plan.forward(params, images, n, …)` produces, row for
//! row, exactly the bits of `n` independent [`super::Network::forward`]
//! calls (enforced by `rust/tests/batch_forward.rs`), and
//! `plan.backward(params, labels, n, …)` emits per-layer batch-summed
//! gradients bitwise equal to accumulating `n` per-sample
//! [`super::Network::backward`] calls (`rust/tests/batch_backward.rs`).

use super::layer::{BatchActs, LayerOp, OpScratch};
use super::network::{Network, ParamSource};
use super::simd::MathPolicy;
use crate::tensor::AlignedBuf;
use crate::util::timer::LayerTimes;
use crate::util::Pcg32;
use std::time::Instant;

/// A batched-forward execution plan over a compiled network: just the
/// network reference plus the batch capacity. Cheap to construct — all
/// heavy state lives in the [`BatchScratch`] it allocates.
pub struct BatchPlan<'n> {
    net: &'n Network,
    cap: usize,
    math: MathPolicy,
}

impl<'n> BatchPlan<'n> {
    /// Plan batches of up to `cap` samples. `cap == 0` is rejected — it
    /// would make every downstream buffer zero-length and turn the serve
    /// loop into a busy spin. Accumulation defaults to
    /// [`MathPolicy::Exact`] (bit-identical to per-sample execution); see
    /// [`BatchPlan::with_math`].
    pub fn new(net: &'n Network, cap: usize) -> anyhow::Result<BatchPlan<'n>> {
        anyhow::ensure!(cap > 0, "batch capacity must be ≥ 1");
        Ok(BatchPlan { net, cap, math: MathPolicy::Exact })
    }

    /// Select the accumulation policy the batched kernels run under (see
    /// the `nn::simd` reassociation contract).
    pub fn with_math(mut self, math: MathPolicy) -> BatchPlan<'n> {
        self.math = math;
        self
    }

    /// The accumulation policy this plan executes under.
    pub fn math(&self) -> MathPolicy {
        self.math
    }

    /// Batch capacity.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// The network this plan executes.
    pub fn network(&self) -> &Network {
        self.net
    }

    /// Flat length of one input image.
    pub fn image_len(&self) -> usize {
        self.net.dims[0].out_len()
    }

    /// Allocate the activation/aux arenas (PRNG stream 0, eval mode).
    pub fn scratch(&self) -> BatchScratch {
        self.scratch_seeded(0)
    }

    /// Arenas with an explicit PRNG seed for ops that draw randomness
    /// (train-mode dropout masks) — mirrors
    /// [`Network::scratch_seeded`].
    pub fn scratch_seeded(&self, seed: u64) -> BatchScratch {
        let acts: Vec<AlignedBuf> =
            self.net.dims.iter().map(|d| AlignedBuf::zeroed(self.cap * d.out_len())).collect();
        let aux: Vec<Vec<u32>> =
            self.net.ops.iter().map(|op| vec![0u32; self.cap * op.aux_len()]).collect();
        let rngs: Vec<Pcg32> =
            (0..self.net.ops.len()).map(|l| Pcg32::new(seed, l as u64)).collect();
        let max_params = self.net.dims.iter().map(|d| d.param_count()).max().unwrap_or(0);
        // One shared im2col staging panel sized to the largest requester
        // (eager, unlike the backward arenas: the forward pass uses it).
        let max_col = self.net.ops.iter().map(|op| op.im2col_len()).max().unwrap_or(0);
        let scratch = BatchScratch {
            cap: self.cap,
            acts,
            aux,
            rngs,
            train_mode: false,
            math: self.math,
            param_buf: AlignedBuf::zeroed(max_params),
            col: AlignedBuf::zeroed(max_col),
            // Backward arenas allocate lazily on the first backward() —
            // forward-only consumers (serving, eval) never pay for them.
            delta_a: AlignedBuf::zeroed(0),
            delta_b: AlignedBuf::zeroed(0),
            grad_buf: AlignedBuf::zeroed(0),
        };
        // The batch-lane kernels assume 64-byte arena bases (the paper's
        // `_mm_malloc(…, 64)` discipline); mid-arena lane slices inherit
        // whatever the plane stride gives them, so the assert belongs
        // here, at allocation, not in the primitives.
        #[cfg(debug_assertions)]
        for (buf, what) in scratch
            .acts
            .iter()
            .map(|a| (a, "acts"))
            .chain([(&scratch.param_buf, "param_buf"), (&scratch.col, "im2col")])
        {
            debug_assert!(buf.is_aligned(), "{what} arena base must be 64-byte aligned");
        }
        scratch
    }

    /// Stage one image into batch slot `slot` (for callers gathering
    /// non-contiguous images, e.g. dataset evaluation); run with
    /// [`BatchPlan::forward_staged`].
    pub fn stage_image(&self, scratch: &mut BatchScratch, slot: usize, image: &[f32]) {
        let il = self.image_len();
        debug_assert!(slot < self.cap, "slot {slot} out of range (cap {})", self.cap);
        debug_assert_eq!(image.len(), il, "input size mismatch");
        scratch.acts[0][slot * il..(slot + 1) * il].copy_from_slice(image);
    }

    /// Forward `n ≤ cap` contiguous images (`[n][image_len]` flat);
    /// returns the `[n][classes]` flat probability block.
    pub fn forward<'s, P: ParamSource>(
        &self,
        params: &P,
        images: &[f32],
        n: usize,
        scratch: &'s mut BatchScratch,
        timers: Option<&LayerTimes>,
    ) -> &'s [f32] {
        let il = self.image_len();
        debug_assert_eq!(images.len(), n * il, "input size mismatch");
        scratch.acts[0][..n * il].copy_from_slice(images);
        self.forward_staged(params, n, scratch, timers)
    }

    /// Forward the first `n` already-staged slots (see
    /// [`BatchPlan::stage_image`]); returns the `[n][classes]` flat
    /// probability block. Each layer's parameter span is loaded **once**
    /// for the whole batch.
    pub fn forward_staged<'s, P: ParamSource>(
        &self,
        params: &P,
        n: usize,
        scratch: &'s mut BatchScratch,
        timers: Option<&LayerTimes>,
    ) -> &'s [f32] {
        assert!(n <= self.cap, "batch {n} exceeds plan capacity {}", self.cap);
        let n_layers = self.net.dims.len();
        for l in 1..n_layers {
            let d = &self.net.dims[l];
            let op: &dyn LayerOp = self.net.ops[l].as_ref();
            let t0 = timers.map(|_| Instant::now());
            let pc = d.param_count();
            if pc > 0 {
                // The batched path's defining property: one on-demand load
                // per layer per batch, not per image.
                params.load(d.params.clone(), &mut scratch.param_buf[..pc]);
            }
            let al = op.aux_len();
            let (prev_acts, rest) = scratch.acts.split_at_mut(l);
            op.forward_batch(
                &scratch.param_buf[..pc],
                &prev_acts[l - 1][..n * d.in_len()],
                &mut rest[0][..n * d.out_len()],
                n,
                &mut OpScratch {
                    aux: &mut scratch.aux[l][..n * al],
                    rng: &mut scratch.rngs[l],
                    train: scratch.train_mode,
                    math: scratch.math,
                    col: &mut scratch.col[..],
                },
            );
            if let (Some(t), Some(start)) = (timers, t0) {
                t.add(op.class(false), start.elapsed().as_nanos() as u64);
            }
        }
        let classes = self.net.num_classes();
        &scratch.acts[n_layers - 1][..n * classes]
    }

    /// Back-propagate the last forward pass of the first `n` slots against
    /// per-sample `labels`, emitting each parameterized layer's
    /// **batch-summed** `[weights..., biases...]` gradient through
    /// `on_grads(layer_index, dims, grads)` right after that layer
    /// completes (back-to-front, mirroring [`Network::backward`]'s
    /// per-layer publication hook). Each layer's parameter span is loaded
    /// **once** for the whole batch, the backward half of the
    /// weight-stationary story.
    ///
    /// The caller must have run [`BatchPlan::forward`]/
    /// [`BatchPlan::forward_staged`] on the same scratch with the same `n`
    /// (training passes set `scratch.train_mode` so dropout masks are drawn
    /// and replayed); the stored `[n][len]` activation arenas are consumed
    /// here. Gradients are bit-identical to accumulating `n` per-sample
    /// [`Network::backward`] calls (`rust/tests/batch_backward.rs`).
    pub fn backward<P: ParamSource>(
        &self,
        params: &P,
        labels: &[usize],
        n: usize,
        scratch: &mut BatchScratch,
        timers: Option<&LayerTimes>,
        mut on_grads: impl FnMut(usize, &super::dims::LayerDims, &[f32]),
    ) {
        assert!(n <= self.cap, "batch {n} exceeds plan capacity {}", self.cap);
        // A hard assert: a short `labels` in release mode would silently
        // backpropagate raw softmax rows for the unlabelled slots.
        assert_eq!(labels.len(), n, "one label per batch slot");
        scratch.ensure_backward_arenas(self.net);
        let n_layers = self.net.dims.len();
        let classes = self.net.num_classes();

        // Output delta per row: softmax + cross-entropy ⇒ p − onehot
        // (already the pre-activation delta — the output op's contract).
        {
            let probs = scratch.acts.last().unwrap();
            let delta = &mut scratch.delta_a[..n * classes];
            delta.copy_from_slice(&probs[..n * classes]);
            for (s, &label) in labels.iter().enumerate() {
                debug_assert!(label < classes);
                delta[s * classes + label] -= 1.0;
            }
        }

        // Walking back: on entry to layer l, `delta_a[..n·out_len]` holds
        // every sample's ∂L/∂(output of layer l); the op converts to its
        // pre-activation deltas itself and writes each sample's
        // ∂L/∂(input) into `delta_b`.
        for l in (1..n_layers).rev() {
            let d = &self.net.dims[l];
            let op: &dyn LayerOp = self.net.ops[l].as_ref();
            let t0 = timers.map(|_| Instant::now());
            let is_first = l == 1; // layer below is the input layer
            let pc = d.param_count();
            if pc > 0 {
                // One on-demand load per layer per batch, as in forward.
                params.load(d.params.clone(), &mut scratch.param_buf[..pc]);
            }
            scratch.grad_buf[..pc].fill(0.0);
            let al = op.aux_len();
            let (prev_acts, rest) = scratch.acts.split_at(l);
            let deltas_in: &mut [f32] =
                if is_first { &mut [] } else { &mut scratch.delta_b[..n * d.in_len()] };
            op.backward_batch(
                &scratch.param_buf[..pc],
                BatchActs {
                    inputs: &prev_acts[l - 1][..n * d.in_len()],
                    outputs: &rest[0][..n * d.out_len()],
                },
                &mut scratch.delta_a[..n * d.out_len()],
                deltas_in,
                &mut scratch.grad_buf[..pc],
                n,
                &mut OpScratch {
                    aux: &mut scratch.aux[l][..n * al],
                    rng: &mut scratch.rngs[l],
                    train: scratch.train_mode,
                    math: scratch.math,
                    col: &mut scratch.col[..],
                },
            );
            if pc > 0 {
                on_grads(l, d, &scratch.grad_buf[..pc]);
            }
            if !is_first {
                std::mem::swap(&mut scratch.delta_a, &mut scratch.delta_b);
            }
            if let (Some(t), Some(start)) = (timers, t0) {
                t.add(op.class(true), start.elapsed().as_nanos() as u64);
            }
        }
    }
}

/// Arenas for one batched worker: per-layer `[cap][out_len]` activation
/// blocks, per-op `[cap][aux_len]` auxiliary words, per-op PRNG streams,
/// the single staging buffer for on-demand parameter loads, and (allocated
/// lazily by [`BatchPlan::backward`]) the `[cap][max_len]` delta ping-pong
/// planes plus the per-layer batch-summed gradient staging buffer.
/// Thread-private, like the per-sample [`super::Scratch`].
pub struct BatchScratch {
    cap: usize,
    /// `acts[l]` holds layer `l`'s outputs for every batch slot.
    acts: Vec<AlignedBuf>,
    aux: Vec<Vec<u32>>,
    rngs: Vec<Pcg32>,
    /// Whether forward/backward run as a training pass (dropout masks
    /// active).
    pub train_mode: bool,
    /// Accumulation policy, copied from the plan that allocated this
    /// scratch (the plan passes it to every op through `OpScratch`).
    math: MathPolicy,
    param_buf: AlignedBuf,
    /// Shared im2col staging panel (one sample, reused across the batch),
    /// sized to the largest `LayerOp::im2col_len` in the stack.
    col: AlignedBuf,
    delta_a: AlignedBuf,
    delta_b: AlignedBuf,
    grad_buf: AlignedBuf,
}

impl BatchScratch {
    /// Batch capacity these arenas were sized for.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Allocate the backward arenas on first use (forward-only consumers
    /// never reach this). Public so the dataflow auditor
    /// ([`crate::nn::audit`]) — and out-of-crate audit harnesses — can
    /// materialize and then verify them; idempotent once sized.
    pub fn ensure_backward_arenas(&mut self, net: &Network) {
        let max_act = net.dims.iter().map(|d| d.out_len()).max().unwrap_or(0);
        let need = self.cap * max_act;
        if self.delta_a.len() < need {
            self.delta_a = AlignedBuf::zeroed(need);
            self.delta_b = AlignedBuf::zeroed(need);
        }
        let max_params = net.dims.iter().map(|d| d.param_count()).max().unwrap_or(0);
        if self.grad_buf.len() < max_params {
            self.grad_buf = AlignedBuf::zeroed(max_params);
        }
    }

    /// Reduce the arenas to their memory extents plus the per-op PRNG
    /// stream identifiers — the plain-data view the dataflow/aliasing
    /// verifier ([`crate::nn::audit::verify_arena_layout`]) reasons about.
    pub fn layout(&self) -> crate::nn::audit::ArenaLayout {
        use crate::nn::audit::{ArenaExtent, ArenaLayout};
        let mut extents = Vec::new();
        for (l, a) in self.acts.iter().enumerate() {
            extents.push(ArenaExtent {
                name: format!("acts[{l}]"),
                addr: a.as_ptr() as usize,
                len: a.len(),
            });
        }
        for (l, a) in self.aux.iter().enumerate() {
            extents.push(ArenaExtent {
                name: format!("aux[{l}]"),
                addr: a.as_ptr() as usize,
                len: a.len(),
            });
        }
        for (name, buf) in [
            ("param_buf", &self.param_buf),
            ("delta_a", &self.delta_a),
            ("delta_b", &self.delta_b),
            ("grad_buf", &self.grad_buf),
            ("im2col", &self.col),
        ] {
            extents.push(ArenaExtent {
                name: name.to_string(),
                addr: buf.as_ptr() as usize,
                len: buf.len(),
            });
        }
        ArenaLayout {
            cap: self.cap,
            extents,
            rng_streams: self.rngs.iter().map(|r| r.stream()).collect(),
        }
    }

    /// Reset every per-op PRNG stream (fixed-mask knob for tests, mirrors
    /// [`super::Scratch::reseed`]).
    pub fn reseed(&mut self, seed: u64) {
        for (l, rng) in self.rngs.iter_mut().enumerate() {
            *rng = Pcg32::new(seed, l as u64);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchSpec;

    #[test]
    fn zero_capacity_is_rejected() {
        let net = Network::new(ArchSpec::tiny());
        let e = BatchPlan::new(&net, 0).unwrap_err().to_string();
        assert!(e.contains("batch capacity"), "{e}");
    }

    #[test]
    fn batched_probs_are_distributions() {
        let net = Network::new(ArchSpec::tiny());
        let params = net.init_params(3);
        let plan = BatchPlan::new(&net, 4).unwrap();
        let mut scratch = plan.scratch();
        let mut rng = Pcg32::seeded(9);
        let il = plan.image_len();
        let images: Vec<f32> = (0..3 * il).map(|_| rng.uniform(-1.0, 1.0)).collect();
        // Partial batch (3 of 4 slots).
        let probs = plan.forward(&params, &images, 3, &mut scratch, None);
        assert_eq!(probs.len(), 3 * net.num_classes());
        for row in probs.chunks_exact(net.num_classes()) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "softmax row sums to 1, got {sum}");
        }
    }

    #[test]
    #[should_panic(expected = "exceeds plan capacity")]
    fn oversized_batch_panics() {
        let net = Network::new(ArchSpec::tiny());
        let plan = BatchPlan::new(&net, 2).unwrap();
        let mut scratch = plan.scratch();
        let params = net.init_params(1);
        plan.forward_staged(&params, 3, &mut scratch, None);
    }

    #[test]
    fn batched_backward_matches_accumulated_per_sample() {
        let net = Network::new(ArchSpec::tiny());
        let params = net.init_params(13);
        let n = 3;
        let il = net.dims[0].out_len();
        let mut rng = Pcg32::seeded(21);
        let images: Vec<f32> = (0..n * il).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let labels = [1usize, 7, 3];

        // Reference: per-sample backward, grads summed in sample order.
        let mut scratch = net.scratch();
        scratch.train_mode = true;
        let mut acc = vec![0.0f32; net.total_params];
        for s in 0..n {
            net.forward(&params.as_slice(), &images[s * il..(s + 1) * il], &mut scratch, None);
            net.backward(&params.as_slice(), labels[s], &mut scratch, None, |_, d, g| {
                for (a, &v) in acc[d.params.clone()].iter_mut().zip(g) {
                    *a += v;
                }
            });
        }

        let plan = BatchPlan::new(&net, 4).unwrap();
        let mut bs = plan.scratch();
        bs.train_mode = true;
        plan.forward(&params, &images, n, &mut bs, None);
        let mut batched = vec![0.0f32; net.total_params];
        let mut order = Vec::new();
        plan.backward(&params, &labels, n, &mut bs, None, |l, d, g| {
            order.push(l);
            batched[d.params.clone()].copy_from_slice(g);
        });
        assert_eq!(order, vec![6, 5, 3, 1], "back-to-front over parameterized layers");
        assert_eq!(batched, acc, "batch-summed gradients must match per-sample bits");
    }

    #[test]
    fn arena_layout_matches_expected_extents() {
        // Miri-sized (fc-only micro arch, batch 2): the arena layout the
        // aliasing verifier reasons about must describe real, disjoint,
        // exactly-sized planes once the backward arenas materialize.
        let arch = ArchSpec {
            name: "micro".into(),
            layers: vec![
                crate::config::LayerSpec::Input { side: 4 },
                crate::config::LayerSpec::fc(3),
                crate::config::LayerSpec::Output { classes: 2 },
            ],
            paper_epochs: 1,
        };
        let net = Network::new(arch);
        let plan = BatchPlan::new(&net, 2).unwrap();
        let mut scratch = plan.scratch_seeded(7);
        scratch.ensure_backward_arenas(&net);
        let layout = scratch.layout();
        assert_eq!(layout.cap, 2);
        let expected = crate::nn::audit::expected_extents(&net, 2);
        let defects = crate::nn::audit::verify_arena_layout(&layout, &expected);
        assert!(defects.is_empty(), "{defects:?}");
        // Per-op PRNG streams are the layer indices — pairwise distinct.
        assert_eq!(layout.rng_streams, vec![0, 1, 2]);
    }

    #[test]
    fn im2col_arena_layout_matches_expected_extents() {
        // Miri-sized: a strided/padded conv makes the plan allocate the
        // shared im2col panel eagerly; the layout the aliasing verifier
        // sees must size it exactly and keep it disjoint from every other
        // arena. Geometry: side 4, k=3, stride 2, pad 1 → out_side 2, so
        // the panel holds 1·3·3·2·2 = 36 elements.
        let arch = ArchSpec {
            name: "micro-general".into(),
            layers: vec![
                crate::config::LayerSpec::Input { side: 4 },
                crate::config::LayerSpec::conv_ex(1, 3, 2, 1, crate::config::Act::Relu),
                crate::config::LayerSpec::Output { classes: 2 },
            ],
            paper_epochs: 1,
        };
        let net = Network::new(arch);
        let plan = BatchPlan::new(&net, 2).unwrap();
        let mut scratch = plan.scratch_seeded(3);
        scratch.ensure_backward_arenas(&net);
        let layout = scratch.layout();
        let col = layout.extents.iter().find(|e| e.name == "im2col").unwrap();
        assert_eq!(col.len, 36);
        let expected = crate::nn::audit::expected_extents(&net, 2);
        let defects = crate::nn::audit::verify_arena_layout(&layout, &expected);
        assert!(defects.is_empty(), "{defects:?}");
    }

    #[test]
    fn fast_math_forward_stays_close_to_exact() {
        // Same plan, both policies: fast math may reassociate, so outputs
        // agree only to rounding — but must stay within a tight relative
        // bound on softmax probabilities.
        let net = Network::new(ArchSpec::tiny());
        let params = net.init_params(17);
        let mut rng = Pcg32::seeded(29);
        let il = net.dims[0].out_len();
        let images: Vec<f32> = (0..4 * il).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let exact_plan = BatchPlan::new(&net, 4).unwrap();
        let mut se = exact_plan.scratch();
        let exact = exact_plan.forward(&params, &images, 4, &mut se, None).to_vec();
        let fast_plan = BatchPlan::new(&net, 4).unwrap().with_math(MathPolicy::Fast);
        assert_eq!(fast_plan.math(), MathPolicy::Fast);
        let mut sf = fast_plan.scratch();
        let fast = fast_plan.forward(&params, &images, 4, &mut sf, None);
        for (i, (&e, &f)) in exact.iter().zip(fast.iter()).enumerate() {
            assert!(
                (e - f).abs() <= 1e-5 * (1.0 + e.abs()),
                "probability {i} diverged: exact {e} vs fast {f}"
            );
        }
    }

    #[test]
    fn staged_equals_contiguous() {
        let net = Network::new(ArchSpec::tiny());
        let params = net.init_params(5);
        let plan = BatchPlan::new(&net, 3).unwrap();
        let mut rng = Pcg32::seeded(11);
        let il = plan.image_len();
        let images: Vec<f32> = (0..3 * il).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut s1 = plan.scratch();
        let contiguous = plan.forward(&params, &images, 3, &mut s1, None).to_vec();
        let mut s2 = plan.scratch();
        for slot in 0..3 {
            plan.stage_image(&mut s2, slot, &images[slot * il..(slot + 1) * il]);
        }
        let staged = plan.forward_staged(&params, 3, &mut s2, None);
        assert_eq!(contiguous, staged);
    }
}
