//! Weight initialization.
//!
//! Glorot/Xavier-uniform per parameterized layer (biases start at zero).
//! Initialization is fully determined by the seed, so sequential and
//! parallel runs start from identical weights — the precondition for the
//! paper's accuracy-parity comparison (Table 7).

use super::dims::LayerDims;
use crate::util::Pcg32;

/// Per-layer fan-in/fan-out used for the init scale, derived from the
/// parameter layout alone so runtime-registered layer kinds initialize
/// like built-ins. For a conv layer `weights = out_maps·in_maps·k²`, so
/// `weights/out_maps = in_maps·k²` (fan-in) and `weights/in_maps =
/// out_maps·k²` (fan-out); for a fully-connected layer the same quotients
/// give `inputs` and `neurons` — both identical to the classic per-type
/// formulas.
fn fans(d: &LayerDims) -> (usize, usize) {
    if d.weights == 0 || d.in_maps == 0 || d.out_maps == 0 {
        return (1, 1);
    }
    (d.weights / d.out_maps, d.weights / d.in_maps)
}

/// Initialize a flat parameter vector for the given layer dims.
pub fn init_params(dims: &[LayerDims], seed: u64) -> Vec<f32> {
    let total = super::dims::total_params(dims);
    let mut params = vec![0.0f32; total];
    // One PRNG stream per layer: init of layer k does not depend on the
    // sizes of earlier layers.
    for (l, d) in dims.iter().enumerate() {
        if d.param_count() == 0 {
            continue;
        }
        let mut rng = Pcg32::new(seed, l as u64);
        let (fan_in, fan_out) = fans(d);
        let r = (6.0f32 / (fan_in + fan_out) as f32).sqrt();
        let slice = &mut params[d.params.clone()];
        let (w, b) = slice.split_at_mut(d.weights);
        rng.fill_uniform(w, -r, r);
        b.fill(0.0);
    }
    params
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchSpec;
    use crate::nn::dims::compute_dims;

    #[test]
    fn deterministic() {
        let dims = compute_dims(&ArchSpec::small());
        let a = init_params(&dims, 42);
        let b = init_params(&dims, 42);
        assert_eq!(a, b);
        let c = init_params(&dims, 43);
        assert_ne!(a, c);
    }

    #[test]
    fn biases_zero_weights_bounded() {
        let dims = compute_dims(&ArchSpec::medium());
        let p = init_params(&dims, 1);
        for d in &dims {
            if d.param_count() == 0 {
                continue;
            }
            let slice = &p[d.params.clone()];
            let (w, b) = d.split_params(slice);
            assert!(b.iter().all(|&x| x == 0.0));
            let max = w.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
            assert!(max > 0.0 && max < 1.0, "weights look unscaled: {max}");
        }
    }

    #[test]
    fn nonzero_everywhere_weights() {
        let dims = compute_dims(&ArchSpec::small());
        let p = init_params(&dims, 7);
        // Not a rigorous check, but all-zero weight blocks would break
        // symmetry-sensitive training.
        for d in &dims {
            if d.weights > 0 {
                let w = &p[d.params.start..d.params.start + d.weights];
                assert!(w.iter().any(|&x| x != 0.0));
            }
        }
    }
}
