//! Activation functions.
//!
//! Hidden layers default to the LeCun-scaled tanh `f(x) = 1.7159·tanh(2x/3)`
//! (the activation of the Cireşan reference implementation the paper builds
//! on); conv and fully-connected layers can select ReLU or identity through
//! their `act` field ([`Act`]); the output layer applies softmax, trained
//! with cross-entropy.

use crate::config::Act;

/// Scale A of the LeCun tanh.
pub const TANH_A: f32 = 1.7159;
/// Slope B of the LeCun tanh.
pub const TANH_B: f32 = 2.0 / 3.0;

/// f(x) = A·tanh(B·x).
#[inline]
pub fn scaled_tanh(x: f32) -> f32 {
    TANH_A * (TANH_B * x).tanh()
}

/// f'(x) expressed through the *output* y = f(x):
/// f'(x) = A·B·(1 − tanh²(Bx)) = (B/A)·(A² − y²).
/// Formulating the derivative in terms of y lets backward reuse the stored
/// activations instead of the pre-activations.
#[inline]
pub fn scaled_tanh_deriv_from_y(y: f32) -> f32 {
    (TANH_B / TANH_A) * (TANH_A * TANH_A - y * y)
}

/// Apply the scaled tanh elementwise.
#[inline]
pub fn apply_scaled_tanh(xs: &mut [f32]) {
    for v in xs.iter_mut() {
        *v = scaled_tanh(*v);
    }
}

impl Act {
    /// Apply the activation elementwise to pre-activations.
    #[inline]
    pub fn apply(self, xs: &mut [f32]) {
        match self {
            Act::ScaledTanh => apply_scaled_tanh(xs),
            Act::Relu => {
                for v in xs.iter_mut() {
                    *v = v.max(0.0);
                }
            }
            Act::Identity => {}
        }
    }

    /// Convert ∂L/∂(output) into ∂L/∂(pre-activation) in place, using the
    /// stored *outputs* `ys` (every provided activation's derivative is
    /// expressible through its output, so backward never needs the
    /// pre-activations). Length-generic and elementwise, so the batched
    /// backward kernels apply it block-wise over whole `[batch][len]`
    /// delta planes with per-sample-identical bits.
    #[inline]
    pub fn scale_delta(self, delta: &mut [f32], ys: &[f32]) {
        debug_assert_eq!(delta.len(), ys.len());
        match self {
            Act::ScaledTanh => {
                for (dv, &y) in delta.iter_mut().zip(ys.iter()) {
                    *dv *= scaled_tanh_deriv_from_y(y);
                }
            }
            Act::Relu => {
                for (dv, &y) in delta.iter_mut().zip(ys.iter()) {
                    if y <= 0.0 {
                        *dv = 0.0;
                    }
                }
            }
            Act::Identity => {}
        }
    }

    /// Static FLOP estimate of applying this activation to one element,
    /// for the `nn::audit` cost model (transcendentals counted as a
    /// handful of flops, the usual roofline convention).
    pub fn flops_per_elem(self) -> f64 {
        match self {
            Act::ScaledTanh => 8.0,
            Act::Relu => 1.0,
            Act::Identity => 0.0,
        }
    }
}

/// In-place numerically-stable softmax.
pub fn softmax(xs: &mut [f32]) {
    let max = xs.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut sum = 0.0f32;
    for v in xs.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in xs.iter_mut() {
        *v *= inv;
    }
}

/// Cross-entropy loss −ln p[label] with clamping for numerical safety.
#[inline]
pub fn cross_entropy(probs: &[f32], label: usize) -> f32 {
    -probs[label].max(1e-12).ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tanh_shape() {
        assert_eq!(scaled_tanh(0.0), 0.0);
        assert!((scaled_tanh(1e9) - TANH_A).abs() < 1e-4, "saturates at A");
        assert!((scaled_tanh(-1e9) + TANH_A).abs() < 1e-4);
        // f(1) = 1.7159 * tanh(2/3) ≈ 1.7159 * 0.58278
        assert!((scaled_tanh(1.0) - 1.0).abs() < 0.01, "f(1) ≈ 1 by design");
    }

    #[test]
    fn derivative_matches_finite_difference() {
        for &x in &[-2.0f32, -0.5, 0.0, 0.3, 1.7] {
            let h = 1e-3f32;
            let fd = (scaled_tanh(x + h) - scaled_tanh(x - h)) / (2.0 * h);
            let y = scaled_tanh(x);
            let an = scaled_tanh_deriv_from_y(y);
            assert!((fd - an).abs() < 1e-3, "x={x}: fd={fd} analytic={an}");
        }
    }

    #[test]
    fn softmax_normalizes() {
        let mut v = [1.0f32, 2.0, 3.0];
        softmax(&mut v);
        let s: f32 = v.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(v[2] > v[1] && v[1] > v[0]);
    }

    #[test]
    fn softmax_stable_for_large_inputs() {
        let mut v = [1000.0f32, 1001.0, 999.0];
        softmax(&mut v);
        assert!(v.iter().all(|p| p.is_finite()));
        let s: f32 = v.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
    }

    #[test]
    fn cross_entropy_basics() {
        let p = [0.1f32, 0.7, 0.2];
        assert!((cross_entropy(&p, 1) - (-0.7f32.ln())).abs() < 1e-6);
        // Zero probability does not produce inf.
        assert!(cross_entropy(&[0.0, 1.0], 0).is_finite());
    }
}
