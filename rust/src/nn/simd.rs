//! Vector-friendly primitives for the hot loops.
//!
//! Rust/LLVM will not reassociate floating-point reductions, so a naive
//! `acc += a[i] * b[i]` dot product is a *scalar* dependency chain even at
//! opt-level 3. Splitting the accumulator into 8 independent lanes lets
//! the auto-vectorizer emit packed mul/add — the same transformation the
//! paper's `#pragma omp simd` performed on the Phi's 512-bit VPU
//! (§Perf iteration 3 in EXPERIMENTS.md measures the win).

/// Dot product with 8 independent accumulator lanes (4-lane pass over the
/// remainder, scalar only for the last ≤3 elements — the large network's
/// 6-wide map rows would otherwise fall back to a scalar chain).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let mut acc = [0.0f32; 8];
    // Exact-size slices help LLVM drop bounds checks.
    let (a8, a_rest) = a.split_at(chunks * 8);
    let (b8, b_rest) = b.split_at(chunks * 8);
    for (ca, cb) in a8.chunks_exact(8).zip(b8.chunks_exact(8)) {
        for l in 0..8 {
            acc[l] += ca[l] * cb[l];
        }
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    let quads = a_rest.len() / 4;
    let (a4, a_tail) = a_rest.split_at(quads * 4);
    let (b4, b_tail) = b_rest.split_at(quads * 4);
    if quads > 0 {
        let mut q = [0.0f32; 4];
        for (ca, cb) in a4.chunks_exact(4).zip(b4.chunks_exact(4)) {
            for l in 0..4 {
                q[l] += ca[l] * cb[l];
            }
        }
        s += (q[0] + q[1]) + (q[2] + q[3]);
    }
    for (x, y) in a_tail.iter().zip(b_tail) {
        s += x * y;
    }
    s
}

/// `dst += w * src` over equal-length slices (saxpy). No reduction, so the
/// plain loop already vectorizes; kept as a named primitive for clarity.
#[inline]
pub fn saxpy(dst: &mut [f32], src: &[f32], w: f32) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d += w * s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn dot_matches_naive() {
        let mut rng = Pcg32::seeded(1);
        for n in [0, 1, 7, 8, 9, 16, 31, 100, 841] {
            let a: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let fast = dot(&a, &b);
            assert!(
                (naive - fast).abs() < 1e-4 * (1.0 + naive.abs()),
                "n={n}: {naive} vs {fast}"
            );
        }
    }

    #[test]
    fn saxpy_matches_naive() {
        let mut rng = Pcg32::seeded(2);
        let src: Vec<f32> = (0..50).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut dst = vec![1.0f32; 50];
        let mut expect = dst.clone();
        saxpy(&mut dst, &src, 0.5);
        for (e, &s) in expect.iter_mut().zip(&src) {
            *e += 0.5 * s;
        }
        assert_eq!(dst, expect);
    }
}
