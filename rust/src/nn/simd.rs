//! Vector-friendly primitives for the hot loops.
//!
//! # Scalar-lane reductions
//!
//! Rust/LLVM will not reassociate floating-point reductions, so a naive
//! `acc += a[i] * b[i]` dot product is a *scalar* dependency chain even at
//! opt-level 3. Splitting the accumulator into 8 independent lanes lets
//! the auto-vectorizer emit packed mul/add — the same transformation the
//! paper's `#pragma omp simd` performed on the Phi's 512-bit VPU
//! (§Perf iteration 3 in EXPERIMENTS.md measures the win).
//!
//! # Batch-lane layout
//!
//! Batched activations live in `[b][plane]` arenas (`AlignedBuf`, 64-byte
//! base alignment, debug-asserted where the arenas are allocated in
//! `BatchPlan::scratch_seeded`): sample `b`'s plane starts `b * plane_len`
//! elements into the arena. The batch-lane primitives [`lane_axpy`] and
//! [`lane_dot`] treat the **batch dimension as the SIMD lane axis**: one
//! weight tap (or one weight row) is loaded once and broadcast against
//! `lanes` samples sitting at a fixed element stride, so the weight traffic
//! is amortized over the whole batch while each lane's row stays a
//! contiguous, unit-stride — and therefore vectorizable — span.
//!
//! # Reassociation contract ([`MathPolicy`])
//!
//! f32 addition is not associative, so kernel blocking is a semantic
//! choice, not just a perf one:
//!
//! - [`MathPolicy::Exact`] (the default): every batched kernel preserves
//!   the per-sample, per-element accumulation order of the scalar
//!   reference kernels. Batched results are **bit-identical** to
//!   successive per-sample calls — the property the batch bit-identity
//!   suites pin (`rust/tests/batch_forward.rs`, `batch_backward.rs`).
//! - [`MathPolicy::Fast`]: kernels may reassociate — chunk the reduction
//!   axis into [`GEMM_KC`]-long blocks, hoist biases out of the dot chain,
//!   or materialize zero-padded im2col panels whose padding taps
//!   contribute exact-zero terms. Results agree with exact mode only to
//!   rounding (the `MathPolicy` property tests bound the per-element
//!   relative error), in exchange for cache-blocked GEMM shapes.
//!
//! The tile constants [`GEMM_KC`] / [`GEMM_MR`] are `pub` so the static
//! cost model in `nn::audit` can report the blocking it prices.

/// GEMM cache block along the reduction (k) axis: 256 f32 = 1 KiB per
/// panel row, so an MR-row weight panel plus one sample row stay resident
/// in a 32 KiB L1 while the batch streams past.
pub const GEMM_KC: usize = 256;

/// Register-block height of the fc micro-kernel: weight rows processed
/// per k-panel, each holding an independent accumulator (fits the 16
/// logical registers of x86-64 without spilling).
pub const GEMM_MR: usize = 4;

/// Accumulation-order policy for the batched kernels (see the module docs
/// for the full contract).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MathPolicy {
    /// Preserve the per-sample accumulation order: batched results are
    /// bit-identical to successive per-sample kernel calls.
    #[default]
    Exact,
    /// Allow reassociation (k-blocking, bias hoisting, zero-padded im2col
    /// panels) for better cache behaviour; results agree with `Exact`
    /// only to rounding.
    Fast,
}

impl MathPolicy {
    /// Parse a CLI-facing policy name (`exact` | `fast`).
    pub fn parse(s: &str) -> anyhow::Result<MathPolicy> {
        match s {
            "exact" => Ok(MathPolicy::Exact),
            "fast" => Ok(MathPolicy::Fast),
            other => anyhow::bail!("unknown math policy '{other}' (expected exact|fast)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            MathPolicy::Exact => "exact",
            MathPolicy::Fast => "fast",
        }
    }
}

/// Dot product with 8 independent accumulator lanes (4-lane pass over the
/// remainder, scalar only for the last ≤3 elements — the large network's
/// 6-wide map rows would otherwise fall back to a scalar chain).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let mut acc = [0.0f32; 8];
    // Exact-size slices help LLVM drop bounds checks.
    let (a8, a_rest) = a.split_at(chunks * 8);
    let (b8, b_rest) = b.split_at(chunks * 8);
    for (ca, cb) in a8.chunks_exact(8).zip(b8.chunks_exact(8)) {
        for l in 0..8 {
            acc[l] += ca[l] * cb[l];
        }
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    let quads = a_rest.len() / 4;
    let (a4, a_tail) = a_rest.split_at(quads * 4);
    let (b4, b_tail) = b_rest.split_at(quads * 4);
    if quads > 0 {
        let mut q = [0.0f32; 4];
        for (ca, cb) in a4.chunks_exact(4).zip(b4.chunks_exact(4)) {
            for l in 0..4 {
                q[l] += ca[l] * cb[l];
            }
        }
        s += (q[0] + q[1]) + (q[2] + q[3]);
    }
    for (x, y) in a_tail.iter().zip(b_tail) {
        s += x * y;
    }
    s
}

/// `dst += w * src` over equal-length slices (saxpy). No reduction, so the
/// plain loop already vectorizes; kept as a named primitive for clarity.
#[inline]
pub fn saxpy(dst: &mut [f32], src: &[f32], w: f32) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d += w * s;
    }
}

/// Batch-lane saxpy: `dst[l·dst_stride..][..row] += w · src[l·src_stride..][..row]`
/// for each lane `l < lanes`. One weight tap, broadcast against `lanes`
/// samples; each lane's row is a contiguous unit-stride span, so the inner
/// loop vectorizes while the tap load is amortized over the batch.
///
/// Element-disjoint across lanes, so lane order does not affect the
/// result: bit-identical to per-lane [`saxpy`] calls in any order.
#[inline]
pub fn lane_axpy(
    dst: &mut [f32],
    dst_stride: usize,
    src: &[f32],
    src_stride: usize,
    row: usize,
    lanes: usize,
    w: f32,
) {
    debug_assert!(lanes > 0 && row > 0);
    debug_assert!(dst.len() >= (lanes - 1) * dst_stride + row);
    debug_assert!(src.len() >= (lanes - 1) * src_stride + row);
    for l in 0..lanes {
        let d = &mut dst[l * dst_stride..l * dst_stride + row];
        let s = &src[l * src_stride..l * src_stride + row];
        for (di, &si) in d.iter_mut().zip(s) {
            *di += w * si;
        }
    }
}

/// Batch-lane dot: `outs[l·out_stride] = dot(row, xs[l·x_stride..][..row.len()]) + bias`
/// for each lane `l < lanes`. One weight row, dotted against `lanes`
/// samples — the weight-stationary fc forward with the batch as the lane
/// axis. Uses the same [`dot`] reduction per lane, so each output element
/// is bit-identical to the per-sample kernel's.
#[inline]
pub fn lane_dot(
    row: &[f32],
    xs: &[f32],
    x_stride: usize,
    lanes: usize,
    outs: &mut [f32],
    out_stride: usize,
    bias: f32,
) {
    debug_assert!(lanes > 0);
    debug_assert!(xs.len() >= (lanes - 1) * x_stride + row.len());
    debug_assert!(outs.len() >= (lanes - 1) * out_stride + 1);
    for l in 0..lanes {
        let x = &xs[l * x_stride..l * x_stride + row.len()];
        outs[l * out_stride] = dot(row, x) + bias;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn dot_matches_naive() {
        let mut rng = Pcg32::seeded(1);
        for n in [0, 1, 7, 8, 9, 16, 31, 100, 841] {
            let a: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let fast = dot(&a, &b);
            assert!(
                (naive - fast).abs() < 1e-4 * (1.0 + naive.abs()),
                "n={n}: {naive} vs {fast}"
            );
        }
    }

    #[test]
    fn saxpy_matches_naive() {
        let mut rng = Pcg32::seeded(2);
        let src: Vec<f32> = (0..50).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut dst = vec![1.0f32; 50];
        let mut expect = dst.clone();
        saxpy(&mut dst, &src, 0.5);
        for (e, &s) in expect.iter_mut().zip(&src) {
            *e += 0.5 * s;
        }
        assert_eq!(dst, expect);
    }

    #[test]
    fn lane_axpy_bit_identical_to_per_lane_saxpy() {
        let mut rng = Pcg32::seeded(3);
        let (lanes, row, stride) = (5usize, 9usize, 14usize);
        let src: Vec<f32> =
            (0..(lanes - 1) * stride + row).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut dst: Vec<f32> =
            (0..(lanes - 1) * stride + row).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut expect = dst.clone();
        lane_axpy(&mut dst, stride, &src, stride, row, lanes, 0.75);
        for l in 0..lanes {
            saxpy(&mut expect[l * stride..l * stride + row], &src[l * stride..l * stride + row], 0.75);
        }
        assert_eq!(dst, expect);
    }

    #[test]
    fn lane_dot_bit_identical_to_per_lane_dot() {
        let mut rng = Pcg32::seeded(4);
        let (lanes, n, x_stride, out_stride) = (4usize, 23usize, 30usize, 3usize);
        let row: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let xs: Vec<f32> =
            (0..(lanes - 1) * x_stride + n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut outs = vec![0.0f32; (lanes - 1) * out_stride + 1];
        lane_dot(&row, &xs, x_stride, lanes, &mut outs, out_stride, 0.25);
        for l in 0..lanes {
            let expect = dot(&row, &xs[l * x_stride..l * x_stride + n]) + 0.25;
            assert_eq!(outs[l * out_stride].to_bits(), expect.to_bits(), "lane {l}");
        }
    }

    #[test]
    fn math_policy_parses_and_names() {
        assert_eq!(MathPolicy::parse("exact").unwrap(), MathPolicy::Exact);
        assert_eq!(MathPolicy::parse("fast").unwrap(), MathPolicy::Fast);
        assert!(MathPolicy::parse("sloppy").is_err());
        assert_eq!(MathPolicy::default(), MathPolicy::Exact);
        assert_eq!(MathPolicy::Exact.name(), "exact");
        assert_eq!(MathPolicy::Fast.name(), "fast");
    }
}
