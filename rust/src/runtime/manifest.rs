//! `artifacts/manifest.json` — the contract between the AOT pipeline
//! (python/compile/aot.py) and this runtime. The manifest pins parameter
//! order, shapes and artifact I/O signatures so the rust side never guesses
//! about the HLO entry layout.

use crate::util::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One named parameter of an architecture (e.g. `l1_conv_w`, shape
/// `[5,1,4,4]`). Order in `ArchManifest::params` is the flat-vector order
/// shared with `nn::dims`.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub count: usize,
}

/// One lowered artifact (forward / forward_bN / train).
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    /// File name relative to the artifact directory.
    pub file: String,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
}

/// Everything the runtime needs to drive one architecture.
#[derive(Debug, Clone)]
pub struct ArchManifest {
    pub name: String,
    pub input_side: usize,
    pub batch: usize,
    pub param_count: usize,
    pub params: Vec<ParamSpec>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl ArchManifest {
    /// Total flat parameter count (must equal `nn::Network::total_params`).
    pub fn flat_len(&self) -> usize {
        self.params.iter().map(|p| p.count).sum()
    }

    /// The artifact spec by kind (`forward`, `train`, `forward_b{N}`).
    pub fn artifact(&self, kind: &str) -> anyhow::Result<&ArtifactSpec> {
        self.artifacts
            .get(kind)
            .ok_or_else(|| anyhow::anyhow!("arch '{}' has no artifact '{kind}'", self.name))
    }

    /// Kind string of the batched-forward artifact.
    pub fn batched_forward_kind(&self) -> String {
        format!("forward_b{}", self.batch)
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub archs: BTreeMap<String, ArchManifest>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e} (run `make artifacts`)", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest JSON text (exposed for tests).
    pub fn parse(text: &str, dir: PathBuf) -> anyhow::Result<Manifest> {
        let j = Json::parse(text)?;
        let mut archs = BTreeMap::new();
        for (name, aj) in j
            .req("archs")?
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("archs must be an object"))?
        {
            let params = aj
                .req("params")?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("params must be an array"))?
                .iter()
                .map(|p| -> anyhow::Result<ParamSpec> {
                    let shape = p.req("shape")?.usize_vec()?;
                    let count = p
                        .req("count")?
                        .as_usize()
                        .ok_or_else(|| anyhow::anyhow!("param count"))?;
                    anyhow::ensure!(
                        shape.iter().product::<usize>() == count,
                        "param count mismatch in manifest"
                    );
                    Ok(ParamSpec {
                        name: p
                            .req("name")?
                            .as_str()
                            .ok_or_else(|| anyhow::anyhow!("param name"))?
                            .to_string(),
                        shape,
                        count,
                    })
                })
                .collect::<anyhow::Result<Vec<_>>>()?;

            let mut artifacts = BTreeMap::new();
            for (kind, art) in aj
                .req("artifacts")?
                .as_obj()
                .ok_or_else(|| anyhow::anyhow!("artifacts must be an object"))?
            {
                let strings = |key: &str| -> anyhow::Result<Vec<String>> {
                    art.req(key)?
                        .as_arr()
                        .ok_or_else(|| anyhow::anyhow!("{key} must be an array"))?
                        .iter()
                        .map(|s| {
                            s.as_str()
                                .map(str::to_string)
                                .ok_or_else(|| anyhow::anyhow!("{key} entries must be strings"))
                        })
                        .collect()
                };
                artifacts.insert(
                    kind.clone(),
                    ArtifactSpec {
                        file: art
                            .req("file")?
                            .as_str()
                            .ok_or_else(|| anyhow::anyhow!("artifact file"))?
                            .to_string(),
                        inputs: strings("inputs")?,
                        outputs: strings("outputs")?,
                    },
                );
            }

            let am = ArchManifest {
                name: name.clone(),
                input_side: aj
                    .req("input_side")?
                    .as_usize()
                    .ok_or_else(|| anyhow::anyhow!("input_side"))?,
                batch: aj.get("batch").and_then(|b| b.as_usize()).unwrap_or(16),
                param_count: aj
                    .req("param_count")?
                    .as_usize()
                    .ok_or_else(|| anyhow::anyhow!("param_count"))?,
                params,
                artifacts,
            };
            anyhow::ensure!(
                am.flat_len() == am.param_count,
                "arch '{name}': param shapes sum to {} but param_count says {}",
                am.flat_len(),
                am.param_count
            );
            archs.insert(name.clone(), am);
        }
        Ok(Manifest { dir, archs })
    }

    pub fn arch(&self, name: &str) -> anyhow::Result<&ArchManifest> {
        self.archs
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("manifest has no arch '{name}' (have: {:?})", self.archs.keys().collect::<Vec<_>>()))
    }

    /// Absolute path of an artifact file.
    pub fn path_of(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "batch": 4,
      "archs": {
        "tiny": {
          "input_side": 13, "batch": 4, "param_count": 329,
          "params": [
            {"name": "l1_conv_w", "shape": [3,1,4,4], "count": 48},
            {"name": "l1_conv_b", "shape": [3], "count": 3},
            {"name": "l3_conv_w", "shape": [4,3,2,2], "count": 48},
            {"name": "l3_conv_b", "shape": [4], "count": 4},
            {"name": "l5_fc_w", "shape": [8,16], "count": 128},
            {"name": "l5_fc_b", "shape": [8], "count": 8},
            {"name": "l6_out_w", "shape": [10,8], "count": 80},
            {"name": "l6_out_b", "shape": [10], "count": 10}
          ],
          "artifacts": {
            "forward": {"file": "tiny_forward.hlo.txt", "inputs": ["l1_conv_w", "image"], "outputs": ["probs"]},
            "train": {"file": "tiny_train.hlo.txt", "inputs": ["l1_conv_w", "image", "label"], "outputs": ["loss", "probs", "grad_l1_conv_w"]}
          }
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let t = m.arch("tiny").unwrap();
        assert_eq!(t.input_side, 13);
        assert_eq!(t.params.len(), 8);
        assert_eq!(t.flat_len(), 48 + 3 + 48 + 4 + 128 + 8 + 80 + 10);
        assert_eq!(t.artifact("forward").unwrap().file, "tiny_forward.hlo.txt");
        assert!(t.artifact("missing").is_err());
        assert!(m.arch("big").is_err());
        assert_eq!(
            m.path_of(t.artifact("train").unwrap()),
            PathBuf::from("/tmp/a/tiny_train.hlo.txt")
        );
        assert_eq!(t.batched_forward_kind(), "forward_b4");
    }

    #[test]
    fn rejects_count_mismatch() {
        let bad = SAMPLE.replace(r#""count": 48}"#, r#""count": 49}"#);
        assert!(Manifest::parse(&bad, PathBuf::from(".")).is_err());
    }

    #[test]
    fn rejects_param_count_mismatch() {
        let bad = SAMPLE.replace(r#""param_count": 329"#, r#""param_count": 700"#);
        assert!(Manifest::parse(&bad, PathBuf::from(".")).is_err());
    }

    #[test]
    fn real_manifest_if_built() {
        // When `make artifacts` has run, the real manifest must parse and
        // agree with the rust dims for every arch it carries.
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        for (name, am) in &m.archs {
            if let Some(spec) = crate::config::ArchSpec::by_name(name) {
                let net = crate::nn::Network::new(spec);
                assert_eq!(am.param_count, net.total_params, "{name} param count");
            }
        }
    }
}
