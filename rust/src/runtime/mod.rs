//! Runtime layer: the execution engines behind serving and
//! cross-validation.
//!
//! Two engine families live here, selected at the serving layer through
//! `serve::Engine`:
//!
//! * **Native** ([`NativeBatchEngine`]) — drives the compiled
//!   [`crate::nn::Network`] op pipeline through a batched forward plan
//!   ([`crate::nn::BatchPlan`]). No artifacts, no external crates, works
//!   in every build, accepts partial batches, and serves weights straight
//!   from a CHAOS training run. This is the default serving path. Its
//!   sibling [`SharedStoreEngine`] serves **live** from a
//!   [`crate::chaos::SharedParams`] training store, snapshotting weights
//!   per batch.
//! * **PJRT** ([`ForwardEngine`]/[`BatchForwardEngine`]/[`TrainEngine`]) —
//!   loads the AOT-lowered HLO artifacts (`make artifacts`) and executes
//!   them on the PJRT CPU client. The interchange format is HLO **text** —
//!   xla_extension 0.5.1 rejects jax ≥ 0.5 serialized protos (64-bit
//!   instruction ids), while the text parser reassigns ids (see DESIGN.md
//!   §4 and /opt/xla-example/README.md). Requires the `xla-runtime`
//!   feature; the default build substitutes a stub whose loaders error.
//!
//! Cross-validation between the two paths lives in
//! `rust/tests/runtime_roundtrip.rs`: both implement the same math, so
//! probabilities and gradients must agree to float tolerance.

// The real executor needs the external `xla` bindings crate; the default
// build substitutes an API-compatible stub whose loaders return an error
// (see Cargo.toml `[features]`).
#[cfg(feature = "xla-runtime")]
mod executor;
#[cfg(not(feature = "xla-runtime"))]
#[path = "executor_stub.rs"]
mod executor;
mod manifest;
mod native;

pub use executor::{
    BatchForwardEngine, Executable, ForwardEngine, Runtime, TrainEngine, TrainStepOut,
};
pub use manifest::{ArchManifest, ArtifactSpec, Manifest, ParamSpec};
pub use native::{NativeBatchEngine, SharedStoreEngine};

/// Default artifact directory (relative to the repo root).
pub const ARTIFACT_DIR: &str = "artifacts";

/// True when the AOT artifacts have been built.
pub fn artifacts_available(dir: &str) -> bool {
    std::path::Path::new(dir).join("manifest.json").exists()
}
