//! API-compatible stand-in for the PJRT executor, used when the crate is
//! built without the `xla-runtime` feature (the default — the external
//! `xla` bindings crate is not available in the offline build environment).
//!
//! Every loader returns a descriptive error, so the serving and runtime
//! paths degrade gracefully at run time while the rest of the crate (CHAOS
//! trainer, harness, simulator) is fully functional. The integration tests
//! and benches that need artifacts skip before touching this module.

use super::manifest::{ArchManifest, Manifest};

fn unavailable() -> anyhow::Error {
    anyhow::anyhow!(
        "PJRT runtime unavailable: built without the `xla-runtime` feature \
         (rebuild with `--features xla-runtime` in an environment that \
         provides the `xla` bindings crate)"
    )
}

/// Stub PJRT client; construction always fails.
pub struct Runtime {
    _private: (),
}

impl Runtime {
    pub fn cpu() -> anyhow::Result<Runtime> {
        Err(unavailable())
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    pub fn load_artifact(&self, _path: &std::path::Path) -> anyhow::Result<Executable> {
        Err(unavailable())
    }
}

/// Stub compiled artifact (never constructed).
pub struct Executable {
    /// Wall-clock seconds spent compiling (reported by examples/benches).
    pub compile_secs: f64,
}

/// Stub single-image forward engine.
pub struct ForwardEngine {
    pub arch: ArchManifest,
}

impl ForwardEngine {
    pub fn load(_rt: &Runtime, _manifest: &Manifest, _arch: &str) -> anyhow::Result<ForwardEngine> {
        Err(unavailable())
    }

    pub fn run(&self, _flat_params: &[f32], _image: &[f32]) -> anyhow::Result<Vec<f32>> {
        Err(unavailable())
    }
}

/// Stub batched forward engine (serving path).
pub struct BatchForwardEngine {
    pub arch: ArchManifest,
    pub batch: usize,
}

impl BatchForwardEngine {
    pub fn load(
        _rt: &Runtime,
        _manifest: &Manifest,
        _arch: &str,
    ) -> anyhow::Result<BatchForwardEngine> {
        Err(unavailable())
    }

    pub fn run(&self, _flat_params: &[f32], _images: &[f32]) -> anyhow::Result<Vec<Vec<f32>>> {
        Err(unavailable())
    }
}

/// Stub train-step engine.
pub struct TrainEngine {
    pub arch: ArchManifest,
}

/// Result of one AOT train step.
#[derive(Debug)]
pub struct TrainStepOut {
    pub loss: f32,
    pub probs: Vec<f32>,
    /// Flat gradient vector in the shared parameter order.
    pub grads: Vec<f32>,
}

impl TrainEngine {
    pub fn load(_rt: &Runtime, _manifest: &Manifest, _arch: &str) -> anyhow::Result<TrainEngine> {
        Err(unavailable())
    }

    pub fn run(
        &self,
        _flat_params: &[f32],
        _image: &[f32],
        _label: i32,
    ) -> anyhow::Result<TrainStepOut> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let e = Runtime::cpu().unwrap_err();
        assert!(e.to_string().contains("xla-runtime"), "{e}");
    }
}
