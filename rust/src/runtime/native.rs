//! The native batched inference engine: serve predictions from any
//! compiled [`Network`] + parameter snapshot, **no PJRT artifacts
//! required**.
//!
//! This is the in-process counterpart of the AOT
//! [`super::BatchForwardEngine`]: where the PJRT engine executes a
//! statically-batched HLO artifact (and therefore must pad every batch to
//! the compiled `B`), the native engine drives the
//! [`crate::nn::BatchPlan`] pipeline directly, so it accepts partial
//! batches, works in the default (stub) build, and serves weights straight
//! out of a CHAOS training run (`RunResult::final_params`) with no
//! artifact round-trip. `serve::Engine::{Native, Pjrt}` selects between
//! the two.

use crate::chaos::SharedParams;
use crate::nn::{BatchScratch, Network};
use std::sync::Arc;

/// Batched forward execution over the native op pipeline. Owns the
/// network, a parameter snapshot, and the reusable batch arenas — one
/// engine per serving thread (the arenas are thread-private).
pub struct NativeBatchEngine {
    net: Network,
    params: Vec<f32>,
    batch: usize,
    scratch: BatchScratch,
}

impl NativeBatchEngine {
    /// Build an engine serving `params` through `net` in batches of up to
    /// `batch`. Rejects a zero batch size and a parameter snapshot that
    /// does not match the network's layout.
    pub fn new(net: Network, params: Vec<f32>, batch: usize) -> anyhow::Result<NativeBatchEngine> {
        anyhow::ensure!(batch > 0, "native engine: batch size must be ≥ 1");
        anyhow::ensure!(
            params.len() == net.total_params,
            "native engine: parameter snapshot has {} values, network '{}' needs {}",
            params.len(),
            net.arch.name,
            net.total_params
        );
        let scratch = net.batch_plan(batch)?.scratch();
        Ok(NativeBatchEngine { net, params, batch, scratch })
    }

    /// Maximum samples per [`NativeBatchEngine::run`] call.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Flat length of one input image.
    pub fn image_len(&self) -> usize {
        let side = self.net.arch.input_side();
        side * side
    }

    /// Number of output classes per prediction row.
    pub fn num_classes(&self) -> usize {
        self.net.num_classes()
    }

    /// Run the first `n` images of a `[≥n][image_len]` flat buffer and
    /// return one probability row per image. Unlike the PJRT engine there
    /// is no padding requirement: a partial batch costs only the rows it
    /// contains.
    pub fn run(&mut self, images: &[f32], n: usize) -> anyhow::Result<Vec<Vec<f32>>> {
        anyhow::ensure!(n > 0, "native engine: empty batch");
        anyhow::ensure!(
            n <= self.batch,
            "native engine: batch {n} exceeds capacity {}",
            self.batch
        );
        let il = self.image_len();
        anyhow::ensure!(images.len() >= n * il, "native engine: image buffer too short");
        let plan = self.net.batch_plan(self.batch)?;
        let probs = plan.forward(&self.params, &images[..n * il], n, &mut self.scratch, None);
        let classes = self.net.num_classes();
        Ok(probs.chunks_exact(classes).map(|row| row.to_vec()).collect())
    }
}

/// Batched forward execution **live from a CHAOS training store**: every
/// batch snapshots the current weights out of a [`SharedParams`] before
/// running, so predictions track training mid-epoch with no checkpoint
/// round-trip.
///
/// The per-batch snapshot uses [`SharedParams::snapshot_into`] — relaxed
/// atomic loads into a reusable engine-private buffer. Under the CHAOS
/// per-layer lock contract reads never block publishers and never
/// constitute defects (only publications are contract-checked), so a
/// serving thread is just another reader: the same tolerance argument
/// that lets heterogeneous training workers observe non-instant updates
/// lets an inference batch observe a mid-publication weight vector. One
/// engine per serving thread, like [`NativeBatchEngine`].
pub struct SharedStoreEngine {
    net: Network,
    store: Arc<SharedParams>,
    /// Per-batch weight snapshot, reused across runs.
    params: Vec<f32>,
    batch: usize,
    scratch: BatchScratch,
}

impl SharedStoreEngine {
    /// Build an engine serving live from `store` through `net` in batches
    /// of up to `batch`. Rejects a zero batch size and a store whose
    /// length does not match the network's layout.
    pub fn new(
        net: Network,
        store: Arc<SharedParams>,
        batch: usize,
    ) -> anyhow::Result<SharedStoreEngine> {
        anyhow::ensure!(batch > 0, "shared-store engine: batch size must be ≥ 1");
        anyhow::ensure!(
            store.len() == net.total_params,
            "shared-store engine: store holds {} values, network '{}' needs {}",
            store.len(),
            net.arch.name,
            net.total_params
        );
        let scratch = net.batch_plan(batch)?.scratch();
        let params = vec![0.0; net.total_params];
        Ok(SharedStoreEngine { net, store, params, batch, scratch })
    }

    /// Maximum samples per [`SharedStoreEngine::run`] call.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Flat length of one input image.
    pub fn image_len(&self) -> usize {
        let side = self.net.arch.input_side();
        side * side
    }

    /// Number of output classes per prediction row.
    pub fn num_classes(&self) -> usize {
        self.net.num_classes()
    }

    /// Snapshot the store, then run the first `n` images of a
    /// `[≥n][image_len]` flat buffer — every row of one batch sees the
    /// *same* weight snapshot, taken at batch start.
    pub fn run(&mut self, images: &[f32], n: usize) -> anyhow::Result<Vec<Vec<f32>>> {
        anyhow::ensure!(n > 0, "shared-store engine: empty batch");
        anyhow::ensure!(
            n <= self.batch,
            "shared-store engine: batch {n} exceeds capacity {}",
            self.batch
        );
        let il = self.image_len();
        anyhow::ensure!(images.len() >= n * il, "shared-store engine: image buffer too short");
        self.store.snapshot_into(&mut self.params);
        let plan = self.net.batch_plan(self.batch)?;
        let probs = plan.forward(&self.params, &images[..n * il], n, &mut self.scratch, None);
        let classes = self.net.num_classes();
        Ok(probs.chunks_exact(classes).map(|row| row.to_vec()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchSpec;
    use crate::util::Pcg32;

    #[test]
    fn rejects_bad_construction() {
        let net = Network::new(ArchSpec::tiny());
        let params = net.init_params(1);
        let e = NativeBatchEngine::new(net.clone(), params.clone(), 0).unwrap_err().to_string();
        assert!(e.contains("batch size"), "{e}");
        let e = NativeBatchEngine::new(net, vec![0.0; 3], 4).unwrap_err().to_string();
        assert!(e.contains("parameter snapshot"), "{e}");
    }

    #[test]
    fn partial_batch_matches_per_sample_forward() {
        let net = Network::new(ArchSpec::tiny());
        let params = net.init_params(7);
        let mut engine = NativeBatchEngine::new(net.clone(), params.clone(), 8).unwrap();
        let il = engine.image_len();
        let mut rng = Pcg32::seeded(2);
        let images: Vec<f32> = (0..3 * il).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let rows = engine.run(&images, 3).unwrap();
        assert_eq!(rows.len(), 3);
        let mut scratch = net.scratch();
        for (i, row) in rows.iter().enumerate() {
            let expect =
                net.forward(&params.as_slice(), &images[i * il..(i + 1) * il], &mut scratch, None);
            assert_eq!(row.as_slice(), expect, "row {i} not bit-identical");
        }
    }

    #[test]
    fn shared_store_engine_rejects_bad_construction() {
        let net = Network::new(ArchSpec::tiny());
        let params = net.init_params(1);
        let store = Arc::new(SharedParams::new(&params, &net.dims));
        let e = SharedStoreEngine::new(net.clone(), store, 0).unwrap_err().to_string();
        assert!(e.contains("batch size"), "{e}");
        let short = Arc::new(SharedParams::new(&[0.0; 3], &net.dims));
        let e = SharedStoreEngine::new(net, short, 4).unwrap_err().to_string();
        assert!(e.contains("store holds"), "{e}");
    }

    #[test]
    fn shared_store_engine_matches_native_on_frozen_store() {
        // With no publications between runs, the live engine must be
        // bit-identical to the snapshot engine on the same weights.
        let net = Network::new(ArchSpec::tiny());
        let params = net.init_params(11);
        let store = Arc::new(SharedParams::new(&params, &net.dims));
        let mut live = SharedStoreEngine::new(net.clone(), store, 4).unwrap();
        let mut frozen = NativeBatchEngine::new(net, params, 4).unwrap();
        let il = live.image_len();
        let mut rng = Pcg32::seeded(5);
        let images: Vec<f32> = (0..3 * il).map(|_| rng.uniform(-1.0, 1.0)).collect();
        assert_eq!(live.run(&images, 3).unwrap(), frozen.run(&images, 3).unwrap());
    }

    #[test]
    fn shared_store_engine_sees_published_updates() {
        let net = Network::new(ArchSpec::tiny());
        let params = net.init_params(11);
        let dims = net.dims.clone();
        let store = Arc::new(SharedParams::new(&params, &net.dims));
        let mut engine = SharedStoreEngine::new(net, store.clone(), 2).unwrap();
        let il = engine.image_len();
        let mut rng = Pcg32::seeded(6);
        let images: Vec<f32> = (0..il).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let before = engine.run(&images, 1).unwrap();
        // Publish a large update to a parameterized layer: the next batch's
        // snapshot must reflect it.
        let range = dims[1].params.clone();
        store.publish_scaled(1, range.clone(), &vec![1.0; range.len()], 5.0);
        let after = engine.run(&images, 1).unwrap();
        assert_ne!(before, after, "live engine must pick up published weights");
    }

    #[test]
    fn oversized_batch_is_an_error() {
        let net = Network::new(ArchSpec::tiny());
        let params = net.init_params(1);
        let il = 13 * 13;
        let mut engine = NativeBatchEngine::new(net, params, 2).unwrap();
        let images = vec![0.0; 3 * il];
        assert!(engine.run(&images, 3).is_err());
        assert!(engine.run(&images, 0).is_err());
    }
}
