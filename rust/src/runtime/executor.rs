//! PJRT execution of the AOT artifacts.
//!
//! `HloModuleProto::from_text_file` → `XlaComputation` → `client.compile`
//! → `execute` (see /opt/xla-example/load_hlo). One compiled executable per
//! artifact; compilation happens once at load, execution is the request
//! path. Python is never involved here.

use super::manifest::{ArchManifest, Manifest};
use crate::util::Stopwatch;
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

/// A shared PJRT CPU client (compile + execute context).
pub struct Runtime {
    client: PjRtClient,
}

impl Runtime {
    pub fn cpu() -> anyhow::Result<Runtime> {
        let client = PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu client: {e}"))?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one artifact file into an executable.
    pub fn load_artifact(&self, path: &std::path::Path) -> anyhow::Result<Executable> {
        let sw = Stopwatch::start();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e}", path.display()))?;
        Ok(Executable { exe, compile_secs: sw.elapsed_secs() })
    }
}

/// A compiled artifact.
pub struct Executable {
    exe: PjRtLoadedExecutable,
    /// Wall-clock seconds spent compiling (reported by examples/benches).
    pub compile_secs: f64,
}

impl Executable {
    /// Execute with the given literals; returns the decomposed result tuple
    /// (the AOT pipeline lowers everything with `return_tuple=True`).
    pub fn run(&self, inputs: &[Literal]) -> anyhow::Result<Vec<Literal>> {
        let result = self
            .exe
            .execute::<Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("execute: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e}"))?;
        lit.to_tuple().map_err(|e| anyhow::anyhow!("untuple result: {e}"))
    }
}

/// Build the input literals for an architecture: one literal per parameter
/// (sliced out of the flat vector in manifest order) plus trailing inputs.
fn param_literals(am: &ArchManifest, flat: &[f32]) -> anyhow::Result<Vec<Literal>> {
    anyhow::ensure!(
        flat.len() == am.param_count,
        "flat params {} != manifest {}",
        flat.len(),
        am.param_count
    );
    let mut lits = Vec::with_capacity(am.params.len() + 2);
    let mut off = 0;
    for p in &am.params {
        let span = &flat[off..off + p.count];
        let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
        let lit = Literal::vec1(span)
            .reshape(&dims)
            .map_err(|e| anyhow::anyhow!("reshape {}: {e}", p.name))?;
        lits.push(lit);
        off += p.count;
    }
    Ok(lits)
}

/// The single-image forward artifact, loaded and ready.
pub struct ForwardEngine {
    pub arch: ArchManifest,
    exe: Executable,
}

impl ForwardEngine {
    pub fn load(rt: &Runtime, manifest: &Manifest, arch: &str) -> anyhow::Result<ForwardEngine> {
        let am = manifest.arch(arch)?.clone();
        let spec = am.artifact("forward")?;
        let exe = rt.load_artifact(&manifest.path_of(spec))?;
        Ok(ForwardEngine { arch: am, exe })
    }

    /// probs = forward(params, image).
    pub fn run(&self, flat_params: &[f32], image: &[f32]) -> anyhow::Result<Vec<f32>> {
        let side = self.arch.input_side;
        anyhow::ensure!(image.len() == side * side, "image size mismatch");
        let mut inputs = param_literals(&self.arch, flat_params)?;
        inputs.push(
            Literal::vec1(image)
                .reshape(&[side as i64, side as i64])
                .map_err(|e| anyhow::anyhow!("image literal: {e}"))?,
        );
        let out = self.exe.run(&inputs)?;
        anyhow::ensure!(out.len() == 1, "forward returned {} outputs", out.len());
        out[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("probs to_vec: {e}"))
    }
}

/// The batched forward artifact (serving path).
pub struct BatchForwardEngine {
    pub arch: ArchManifest,
    pub batch: usize,
    exe: Executable,
}

impl BatchForwardEngine {
    pub fn load(rt: &Runtime, manifest: &Manifest, arch: &str) -> anyhow::Result<BatchForwardEngine> {
        let am = manifest.arch(arch)?.clone();
        let kind = am.batched_forward_kind();
        let spec = am.artifact(&kind)?;
        let exe = rt.load_artifact(&manifest.path_of(spec))?;
        let batch = am.batch;
        Ok(BatchForwardEngine { arch: am, batch, exe })
    }

    /// probs[B][classes] = forward(params, images[B]); `images` is
    /// `B * side²` long (callers pad short batches).
    pub fn run(&self, flat_params: &[f32], images: &[f32]) -> anyhow::Result<Vec<Vec<f32>>> {
        let side = self.arch.input_side;
        anyhow::ensure!(
            images.len() == self.batch * side * side,
            "batch images size mismatch: {} != {}",
            images.len(),
            self.batch * side * side
        );
        let mut inputs = param_literals(&self.arch, flat_params)?;
        inputs.push(
            Literal::vec1(images)
                .reshape(&[self.batch as i64, side as i64, side as i64])
                .map_err(|e| anyhow::anyhow!("images literal: {e}"))?,
        );
        let out = self.exe.run(&inputs)?;
        anyhow::ensure!(out.len() == 1, "batched forward returned {} outputs", out.len());
        let flat = out[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("probs to_vec: {e}"))?;
        let classes = flat.len() / self.batch;
        Ok(flat.chunks(classes).map(|c| c.to_vec()).collect())
    }
}

/// The train-step artifact: one sample's (loss, probs, grads).
pub struct TrainEngine {
    pub arch: ArchManifest,
    exe: Executable,
}

/// Result of one AOT train step.
#[derive(Debug)]
pub struct TrainStepOut {
    pub loss: f32,
    pub probs: Vec<f32>,
    /// Flat gradient vector in the shared parameter order.
    pub grads: Vec<f32>,
}

impl TrainEngine {
    pub fn load(rt: &Runtime, manifest: &Manifest, arch: &str) -> anyhow::Result<TrainEngine> {
        let am = manifest.arch(arch)?.clone();
        let spec = am.artifact("train")?;
        let exe = rt.load_artifact(&manifest.path_of(spec))?;
        Ok(TrainEngine { arch: am, exe })
    }

    pub fn run(&self, flat_params: &[f32], image: &[f32], label: i32) -> anyhow::Result<TrainStepOut> {
        let side = self.arch.input_side;
        anyhow::ensure!(image.len() == side * side, "image size mismatch");
        let mut inputs = param_literals(&self.arch, flat_params)?;
        inputs.push(
            Literal::vec1(image)
                .reshape(&[side as i64, side as i64])
                .map_err(|e| anyhow::anyhow!("image literal: {e}"))?,
        );
        inputs.push(Literal::scalar(label));
        let out = self.exe.run(&inputs)?;
        anyhow::ensure!(
            out.len() == 2 + self.arch.params.len(),
            "train returned {} outputs, expected {}",
            out.len(),
            2 + self.arch.params.len()
        );
        let loss = out[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("loss: {e}"))?[0];
        let probs = out[1]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("probs: {e}"))?;
        let mut grads = Vec::with_capacity(self.arch.param_count);
        for (i, p) in self.arch.params.iter().enumerate() {
            let g = out[2 + i]
                .to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("grad {}: {e}", p.name))?;
            anyhow::ensure!(g.len() == p.count, "grad {} wrong length", p.name);
            grads.extend_from_slice(&g);
        }
        Ok(TrainStepOut { loss, probs, grads })
    }
}
