//! Bounded multi-producer / multi-consumer request queue.
//!
//! The serving tier's admission point: producers ([`super::ServerHandle`])
//! push with one of three disciplines — non-blocking
//! ([`Bounded::try_push`], the `try_predict` path), blocking until space
//! ([`Bounded::push_deadline`] with no deadline, the classic `predict`
//! path) or blocking at most until a deadline (`predict_deadline`) — and
//! the worker pool pops from the shared tail. `std::sync::mpsc` cannot
//! express this shape (its receiver is single-consumer and `SyncSender`
//! has no deadline-bounded send), so this is a small
//! `Mutex<VecDeque> + Condvar` queue, the textbook construction.
//!
//! Closing ([`Bounded::close`]) is one-way: further pushes fail with
//! [`PushError::Closed`], while pops drain the remaining items and then
//! return `None` — the same drain-then-disconnect semantics as dropping
//! every `mpsc` sender.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// Why a push was refused. The rejected item is handed back so the caller
/// can reply to it.
#[derive(Debug)]
pub(crate) enum PushError<T> {
    /// The queue was at capacity (for the whole wait, if one was allowed).
    Full(T),
    /// The queue is closed — the server is shutting down.
    Closed(T),
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// A bounded MPMC queue with deadline-aware blocking pushes and pops.
pub(crate) struct Bounded<T> {
    cap: usize,
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
}

impl<T> Bounded<T> {
    /// A queue admitting at most `cap` items (`cap ≥ 1`).
    pub fn new(cap: usize) -> Bounded<T> {
        assert!(cap > 0, "queue capacity must be ≥ 1");
        Bounded {
            cap,
            inner: Mutex::new(Inner { items: VecDeque::with_capacity(cap), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }

    /// Current depth (a gauge — racy by nature, exact at the instant read).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    /// Non-blocking push: fails immediately when full or closed.
    pub fn try_push(&self, item: T) -> Result<(), PushError<T>> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(PushError::Closed(item));
        }
        if g.items.len() >= self.cap {
            return Err(PushError::Full(item));
        }
        g.items.push_back(item);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Push, waiting for space until `deadline` (forever when `None`).
    /// Returns [`PushError::Full`] if the deadline passes first and
    /// [`PushError::Closed`] if the queue closes while waiting.
    pub fn push_deadline(&self, item: T, deadline: Option<Instant>) -> Result<(), PushError<T>> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return Err(PushError::Closed(item));
            }
            if g.items.len() < self.cap {
                g.items.push_back(item);
                drop(g);
                self.not_empty.notify_one();
                return Ok(());
            }
            match deadline {
                None => g = self.not_full.wait(g).unwrap(),
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return Err(PushError::Full(item));
                    }
                    g = self.not_full.wait_timeout(g, d - now).unwrap().0;
                }
            }
        }
    }

    /// Pop, blocking until an item arrives. Returns `None` only once the
    /// queue is closed **and** drained.
    pub fn pop_wait(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Pop, blocking at most until `deadline`. `None` means timeout (or
    /// closed-and-drained) — the batch collector's straggler wait.
    pub fn pop_before(&self, deadline: Instant) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            g = self.not_empty.wait_timeout(g, deadline - now).unwrap().0;
        }
    }

    /// Close the queue: pushes start failing, pops drain what remains.
    /// Wakes every waiter on both sides.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        drop(g);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    #[cfg(test)]
    fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn fifo_order_and_capacity() {
        let q: Bounded<u32> = Bounded::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        match q.try_push(3) {
            Err(PushError::Full(3)) => {}
            other => panic!("expected Full(3), got {other:?}"),
        }
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop_wait(), Some(1));
        assert_eq!(q.pop_wait(), Some(2));
    }

    #[test]
    fn push_deadline_times_out_when_full() {
        let q: Bounded<u32> = Bounded::new(1);
        q.try_push(1).unwrap();
        let start = Instant::now();
        let deadline = start + Duration::from_millis(30);
        match q.push_deadline(2, Some(deadline)) {
            Err(PushError::Full(2)) => {}
            other => panic!("expected Full(2), got {other:?}"),
        }
        assert!(start.elapsed() >= Duration::from_millis(25), "must wait out the deadline");
    }

    #[test]
    fn push_deadline_unblocks_when_space_frees() {
        let q: Arc<Bounded<u32>> = Arc::new(Bounded::new(1));
        q.try_push(1).unwrap();
        let q2 = q.clone();
        let t = std::thread::spawn(move || {
            q2.push_deadline(2, Some(Instant::now() + Duration::from_secs(10)))
        });
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(q.pop_wait(), Some(1));
        assert!(t.join().unwrap().is_ok(), "freed slot must admit the waiter");
        assert_eq!(q.pop_wait(), Some(2));
    }

    #[test]
    fn close_drains_then_disconnects() {
        let q: Bounded<u32> = Bounded::new(4);
        q.try_push(7).unwrap();
        q.close();
        assert!(q.is_closed());
        match q.try_push(8) {
            Err(PushError::Closed(8)) => {}
            other => panic!("expected Closed(8), got {other:?}"),
        }
        // Remaining items drain, then the disconnect surfaces.
        assert_eq!(q.pop_wait(), Some(7));
        assert_eq!(q.pop_wait(), None);
        assert_eq!(q.pop_before(Instant::now() + Duration::from_millis(5)), None);
    }

    #[test]
    fn close_wakes_blocked_consumers_and_producers() {
        let q: Arc<Bounded<u32>> = Arc::new(Bounded::new(1));
        q.try_push(1).unwrap();
        let consumer = {
            let q = q.clone();
            std::thread::spawn(move || {
                // Drain the item, then block on an empty queue.
                assert_eq!(q.pop_wait(), Some(1));
                q.pop_wait()
            })
        };
        let producer = {
            let q = q.clone();
            std::thread::spawn(move || q.push_deadline(9, None))
        };
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        // The producer either got its item in before close (consumer pops
        // it) or was woken with Closed; the consumer must return either
        // way rather than hang.
        let popped = consumer.join().unwrap();
        match producer.join().unwrap() {
            Ok(()) => assert!(popped == Some(9) || popped.is_none()),
            Err(PushError::Closed(9)) => {}
            Err(other) => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn pop_before_times_out_on_empty_queue() {
        let q: Bounded<u32> = Bounded::new(1);
        let start = Instant::now();
        assert_eq!(q.pop_before(start + Duration::from_millis(20)), None);
        assert!(start.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    #[should_panic(expected = "capacity")]
    fn zero_capacity_is_rejected() {
        let _ = Bounded::<u32>::new(0);
    }
}
