//! Serving metrics: bounded-memory latency/execution histograms, queue
//! and in-flight gauges, and admission-control counters.
//!
//! The first cut of this module pushed every latency into an unbounded
//! `Vec` under a `Mutex` — sustained traffic grew memory without bound and
//! snapshots sorted the whole history. Everything is now fixed-size and
//! lock-free: distributions live in log-spaced fixed-bucket
//! [`Histogram`]s (atomic counters; percentile estimates are exact to one
//! bucket width, regression-tested), counters and gauges are plain
//! atomics. Recording costs a handful of relaxed atomic ops regardless of
//! how long the server has been up.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Number of histogram buckets per decade of latency. The geometric
/// bucket ratio is `10^(1/PER_DECADE)` ≈ 1.33, which bounds the relative
/// error of every percentile estimate.
const PER_DECADE: usize = 8;
/// Histogram span: `10^DECADES` × the 1 µs base bucket (≈ 10 s). Slower
/// samples land in the overflow bucket and report the observed max.
const DECADES: usize = 7;

/// A fixed-bucket histogram over microsecond samples. Log-spaced bucket
/// edges from 1 µs to ~10 s plus an overflow bucket; all state is atomic,
/// so recording never blocks and memory is constant for the lifetime of
/// the server.
#[derive(Debug)]
pub struct Histogram {
    /// Ascending bucket upper edges (µs); samples beyond the last edge go
    /// to the overflow bucket.
    bounds_us: Vec<f64>,
    /// One counter per bucket, `bounds_us.len() + 1` with the overflow.
    counts: Vec<AtomicU64>,
    total: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        let n = DECADES * PER_DECADE;
        let ratio = 10f64.powf(1.0 / PER_DECADE as f64);
        let mut bounds_us = Vec::with_capacity(n);
        let mut edge = 1.0f64;
        for _ in 0..n {
            bounds_us.push(edge);
            edge *= ratio;
        }
        let counts = (0..=n).map(|_| AtomicU64::new(0)).collect();
        Histogram {
            bounds_us,
            counts,
            total: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }

    /// The geometric ratio between adjacent bucket edges — the bound on
    /// the relative error of [`Histogram::percentile_us`].
    pub fn bucket_ratio() -> f64 {
        10f64.powf(1.0 / PER_DECADE as f64)
    }

    /// Record one sample (µs). Negative samples clamp to zero.
    pub fn record_us(&self, us: f64) {
        let us = if us.is_finite() { us.max(0.0) } else { 0.0 };
        let idx = self.bounds_us.partition_point(|&edge| edge < us);
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
        let ns = (us * 1e3) as u64;
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// Largest recorded sample (µs); 0 when empty.
    pub fn max_us(&self) -> f64 {
        self.max_ns.load(Ordering::Relaxed) as f64 / 1e3
    }

    /// Mean of all recorded samples (µs); 0 when empty.
    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_ns.load(Ordering::Relaxed) as f64 / 1e3 / n as f64
    }

    /// Percentile estimate (p in [0, 100]): the upper edge of the bucket
    /// holding the rank-p sample, i.e. within one bucket width
    /// ([`Histogram::bucket_ratio`]) above the true value. Returns a
    /// well-defined 0 (never NaN) on an empty histogram.
    pub fn percentile_us(&self, p: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let rank = ((p.clamp(0.0, 100.0) / 100.0) * total as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            cum += c.load(Ordering::Relaxed);
            if cum >= rank {
                return if i < self.bounds_us.len() {
                    self.bounds_us[i]
                } else {
                    // Overflow bucket: the best bound we have is the max.
                    self.max_us()
                };
            }
        }
        self.max_us()
    }
}

/// Thread-safe serving metrics: request-latency and per-batch
/// execution-time histograms, batch fill, deadline/admission counters,
/// queue-depth and in-flight gauges. All recording paths are lock-free
/// and memory is bounded.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    /// Enqueue → reply latency of served requests.
    latency: Histogram,
    /// Engine execution time per batch (the `serve_loop` measurement that
    /// used to be discarded).
    exec: Histogram,
    batches: AtomicU64,
    /// Sum of batch sizes (mean fill = filled / batches).
    filled: AtomicU64,
    expired: AtomicU64,
    overloaded: AtomicU64,
    exec_failures: AtomicU64,
    queue_depth: AtomicUsize,
    in_flight: AtomicUsize,
    workers: AtomicUsize,
}

/// A point-in-time snapshot of the metrics for reporting. Every field is
/// well-defined (zero, never NaN) on a server that has served nothing.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    /// Requests answered successfully.
    pub requests: usize,
    /// Batches executed.
    pub batches: usize,
    pub p50_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
    /// Per-batch engine execution time percentiles/mean (µs).
    pub exec_p50_us: f64,
    pub exec_p99_us: f64,
    pub exec_mean_us: f64,
    pub mean_batch_fill: f64,
    /// Requests cancelled because their deadline passed before execution.
    pub expired: usize,
    /// Requests rejected at admission because the queue was full.
    pub overloaded: usize,
    /// Batches whose engine execution failed.
    pub exec_failures: usize,
    /// Queue depth at the last enqueue/dequeue (gauge).
    pub queue_depth: usize,
    /// Requests currently staged in an executing batch (gauge).
    pub in_flight: usize,
    /// Worker threads in the pool.
    pub workers: usize,
}

impl ServeMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one served request's enqueue→reply latency.
    pub fn record_latency_us(&self, us: f64) {
        self.latency.record_us(us);
    }

    /// Record one executed batch's size.
    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.filled.fetch_add(size as u64, Ordering::Relaxed);
    }

    /// Record one batch's engine execution time.
    pub fn record_exec_us(&self, us: f64) {
        self.exec.record_us(us);
    }

    /// Count a request cancelled on deadline expiry.
    pub fn record_expired(&self) {
        self.expired.fetch_add(1, Ordering::Relaxed);
    }

    /// Count a request rejected at admission (queue full).
    pub fn record_overloaded(&self) {
        self.overloaded.fetch_add(1, Ordering::Relaxed);
    }

    /// Count a batch whose engine execution failed.
    pub fn record_exec_failure(&self) {
        self.exec_failures.fetch_add(1, Ordering::Relaxed);
    }

    /// Update the queue-depth gauge (called from both enqueue and
    /// dequeue sides with the queue's current length).
    pub fn set_queue_depth(&self, depth: usize) {
        self.queue_depth.store(depth, Ordering::Relaxed);
    }

    pub fn set_workers(&self, n: usize) {
        self.workers.store(n, Ordering::Relaxed);
    }

    /// Raise the in-flight gauge as a batch enters the engine.
    pub fn inflight_add(&self, n: usize) {
        self.in_flight.fetch_add(n, Ordering::Relaxed);
    }

    /// Lower the in-flight gauge as a batch leaves the engine.
    pub fn inflight_sub(&self, n: usize) {
        self.in_flight.fetch_sub(n, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let batches = self.batches.load(Ordering::Relaxed);
        let filled = self.filled.load(Ordering::Relaxed);
        MetricsSnapshot {
            requests: self.latency.count() as usize,
            batches: batches as usize,
            p50_us: self.latency.percentile_us(50.0),
            p99_us: self.latency.percentile_us(99.0),
            max_us: self.latency.max_us(),
            exec_p50_us: self.exec.percentile_us(50.0),
            exec_p99_us: self.exec.percentile_us(99.0),
            exec_mean_us: self.exec.mean_us(),
            mean_batch_fill: if batches == 0 { 0.0 } else { filled as f64 / batches as f64 },
            expired: self.expired.load(Ordering::Relaxed) as usize,
            overloaded: self.overloaded.load(Ordering::Relaxed) as usize,
            exec_failures: self.exec_failures.load(Ordering::Relaxed) as usize,
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            in_flight: self.in_flight.load(Ordering::Relaxed),
            workers: self.workers.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn empty_snapshot_is_all_zeros_never_nan() {
        // Regression: `MetricsSnapshot` on a zero-request server used to
        // run percentiles over empty data; every field must now be a
        // well-defined zero.
        let s = ServeMetrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.batches, 0);
        for v in [
            s.p50_us,
            s.p99_us,
            s.max_us,
            s.exec_p50_us,
            s.exec_p99_us,
            s.exec_mean_us,
            s.mean_batch_fill,
        ] {
            assert!(v.is_finite(), "snapshot field must never be NaN/inf: {v}");
            assert_eq!(v, 0.0);
        }
        assert_eq!(s.expired, 0);
        assert_eq!(s.overloaded, 0);
        assert_eq!(s.queue_depth, 0);
        assert_eq!(s.in_flight, 0);
    }

    #[test]
    fn percentiles_stay_within_one_bucket_width() {
        // The histogram contract: against an exact reference percentile
        // over the same samples, the estimate is never below the true
        // sample and at most one geometric bucket above it.
        let m = ServeMetrics::new();
        let samples: Vec<f64> = (1..=1000).map(|i| i as f64).collect();
        for &s in &samples {
            m.record_latency_us(s);
        }
        let snap = m.snapshot();
        let ratio = Histogram::bucket_ratio();
        for (p, est) in [(50.0, snap.p50_us), (99.0, snap.p99_us)] {
            let exact = stats::percentile(&samples, p);
            assert!(
                est >= exact * 0.999 && est <= exact * ratio * 1.001,
                "p{p}: histogram estimate {est} vs exact {exact} (ratio bound {ratio})"
            );
        }
        assert_eq!(snap.requests, 1000);
        assert_eq!(snap.max_us, 1000.0);
    }

    #[test]
    fn memory_is_bounded_under_sustained_traffic() {
        // 100k samples land in the same fixed bucket array that 10
        // samples do — nothing grows with traffic.
        let m = ServeMetrics::new();
        for i in 0..100_000u64 {
            m.record_latency_us((i % 7_000) as f64);
            if i % 8 == 0 {
                m.record_exec_us((i % 900) as f64);
            }
        }
        let s = m.snapshot();
        assert_eq!(s.requests, 100_000);
        assert!(s.p50_us > 0.0 && s.p99_us >= s.p50_us);
        assert!(s.exec_p99_us >= s.exec_p50_us);
    }

    #[test]
    fn overflow_bucket_reports_observed_max() {
        let h = Histogram::new();
        h.record_us(1e12); // far past the last edge
        assert_eq!(h.count(), 1);
        assert!((h.percentile_us(50.0) - 1e12).abs() / 1e12 < 1e-6);
        assert!((h.max_us() - 1e12).abs() / 1e12 < 1e-6);
    }

    #[test]
    fn batch_and_gauge_accounting() {
        let m = ServeMetrics::new();
        m.record_batch(4);
        m.record_batch(8);
        m.record_exec_us(100.0);
        m.record_expired();
        m.record_overloaded();
        m.set_queue_depth(3);
        m.set_workers(2);
        m.inflight_add(8);
        m.inflight_sub(8);
        m.inflight_add(4);
        let s = m.snapshot();
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch_fill - 6.0).abs() < 1e-9);
        assert_eq!(s.expired, 1);
        assert_eq!(s.overloaded, 1);
        assert_eq!(s.queue_depth, 3);
        assert_eq!(s.workers, 2);
        assert_eq!(s.in_flight, 4);
        assert!(s.exec_mean_us > 0.0);
    }

    #[test]
    fn concurrent_recording() {
        let m = std::sync::Arc::new(ServeMetrics::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let m = m.clone();
                s.spawn(move || {
                    for _ in 0..250 {
                        m.record_latency_us(1.0);
                    }
                });
            }
        });
        assert_eq!(m.snapshot().requests, 1000);
    }
}
