//! Serving metrics: request latency distribution and batch fill —
//! the numbers the `serve_infer` example reports.

use crate::util::stats;
use std::sync::Mutex;

/// Thread-safe latency/batch accounting.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    latencies_us: Vec<f64>,
    batch_sizes: Vec<usize>,
}

/// A snapshot of the metrics for reporting.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub requests: usize,
    pub batches: usize,
    pub p50_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
    pub mean_batch_fill: f64,
}

impl ServeMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_latency_us(&self, us: f64) {
        self.inner.lock().unwrap().latencies_us.push(us);
    }

    pub fn record_batch(&self, size: usize) {
        self.inner.lock().unwrap().batch_sizes.push(size);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let inner = self.inner.lock().unwrap();
        let l = &inner.latencies_us;
        MetricsSnapshot {
            requests: l.len(),
            batches: inner.batch_sizes.len(),
            p50_us: stats::percentile(l, 50.0),
            p99_us: stats::percentile(l, 99.0),
            max_us: l.iter().copied().fold(0.0, f64::max),
            mean_batch_fill: if inner.batch_sizes.is_empty() {
                0.0
            } else {
                inner.batch_sizes.iter().sum::<usize>() as f64 / inner.batch_sizes.len() as f64
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = ServeMetrics::new();
        for i in 1..=100 {
            m.record_latency_us(i as f64);
        }
        m.record_batch(4);
        m.record_batch(8);
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert_eq!(s.batches, 2);
        assert!((s.p50_us - 50.5).abs() < 1.0);
        assert!(s.p99_us >= 99.0);
        assert_eq!(s.max_us, 100.0);
        assert!((s.mean_batch_fill - 6.0).abs() < 1e-9);
    }

    #[test]
    fn concurrent_recording() {
        let m = std::sync::Arc::new(ServeMetrics::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let m = m.clone();
                s.spawn(move || {
                    for _ in 0..250 {
                        m.record_latency_us(1.0);
                    }
                });
            }
        });
        assert_eq!(m.snapshot().requests, 1000);
    }
}
