//! Dynamic batcher + multi-worker serving loop.
//!
//! Requests enter a shared bounded queue ([`super::queue::Bounded`]) at an
//! admission point with three disciplines ([`ServerHandle::predict`] /
//! [`ServerHandle::try_predict`] / [`ServerHandle::predict_deadline`]); a
//! pool of `N` worker threads — each owning its own engine and batch
//! arenas — drains the queue, groups up to `B` requests (waiting at most
//! `max_delay` for stragglers), drops expired requests *before* they
//! occupy a batch slot, executes the batch, and replies per-request. This
//! is the standard router/worker-pool shape of serving systems
//! (vLLM-style), sized down to the paper's models.
//!
//! Three execution engines ([`Engine`]):
//! * `Native` — [`crate::runtime::NativeBatchEngine`] over any compiled
//!   network + parameter snapshot; partial batches run at their actual
//!   size. Replicated per worker.
//! * `Shared` — [`crate::runtime::SharedStoreEngine`] serving **live**
//!   from a [`crate::chaos::SharedParams`] training store: each batch
//!   reads a fresh per-batch snapshot under the CHAOS per-layer read
//!   contract, so a model is servable mid-epoch with no checkpoint
//!   round-trip.
//! * `Pjrt` — the AOT artifact path; the compiled HLO has a static batch
//!   dimension, so partial batches are zero-padded to `B`.

use super::error::ServeError;
use super::metrics::ServeMetrics;
use super::queue::{Bounded, PushError};
use crate::chaos::SharedParams;
use crate::nn::Network;
use crate::runtime::{BatchForwardEngine, NativeBatchEngine, SharedStoreEngine};
use crate::util::Stopwatch;
use std::sync::mpsc::{self, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Server tuning knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Max time a request may wait for batch-mates.
    pub max_delay: Duration,
    /// Request-queue capacity — the admission-control bound: a full queue
    /// rejects [`ServerHandle::try_predict`] /
    /// [`ServerHandle::predict_deadline`] with
    /// [`ServeError::Overloaded`].
    pub queue_depth: usize,
    /// Worker threads draining the queue, each with its own engine and
    /// batch arenas.
    pub workers: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { max_delay: Duration::from_millis(2), queue_depth: 1024, workers: 1 }
    }
}

/// Which execution engine a [`Server`] runs — the serving-side analogue of
/// the runtime's native/PJRT split (see [`crate::runtime`]), plus the
/// live-from-training shared-store path.
pub enum Engine {
    /// In-process batched execution of a compiled network; no artifacts
    /// required. `batch` is each worker's batch cap.
    Native { net: Network, params: Vec<f32>, batch: usize },
    /// Serve directly from a live [`SharedParams`] training store: every
    /// batch snapshots the current weights (per-batch, under the CHAOS
    /// read contract), so predictions track training mid-epoch.
    Shared { net: Network, store: Arc<SharedParams>, batch: usize },
    /// AOT-compiled PJRT artifact (requires `make artifacts` and the
    /// `xla-runtime` feature). The batch cap is the artifact's compiled
    /// batch dimension.
    Pjrt { artifact_dir: String, arch: String, params: Vec<f32> },
}

impl Engine {
    /// One engine spec per worker: native/shared replicate by cloning the
    /// (stateless) network and sharing/cloning the weights; PJRT workers
    /// each load the artifact themselves (the handles are not `Send`).
    fn replicate(self, n: usize) -> Vec<Engine> {
        match self {
            Engine::Native { net, params, batch } => (0..n)
                .map(|_| Engine::Native { net: net.clone(), params: params.clone(), batch })
                .collect(),
            Engine::Shared { net, store, batch } => (0..n)
                .map(|_| Engine::Shared { net: net.clone(), store: store.clone(), batch })
                .collect(),
            Engine::Pjrt { artifact_dir, arch, params } => (0..n)
                .map(|_| Engine::Pjrt {
                    artifact_dir: artifact_dir.clone(),
                    arch: arch.clone(),
                    params: params.clone(),
                })
                .collect(),
        }
    }
}

/// What the serve loop needs from any engine. `images` is the worker's
/// `[cap][image_len]` zero-padded staging buffer; `n` is how many leading
/// rows are real.
trait ServeEngine {
    fn batch_cap(&self) -> usize;
    fn image_len(&self) -> usize;
    fn run(&mut self, images: &[f32], n: usize) -> anyhow::Result<Vec<Vec<f32>>>;
}

impl ServeEngine for NativeBatchEngine {
    fn batch_cap(&self) -> usize {
        self.batch()
    }

    fn image_len(&self) -> usize {
        NativeBatchEngine::image_len(self)
    }

    fn run(&mut self, images: &[f32], n: usize) -> anyhow::Result<Vec<Vec<f32>>> {
        NativeBatchEngine::run(self, images, n)
    }
}

impl ServeEngine for SharedStoreEngine {
    fn batch_cap(&self) -> usize {
        self.batch()
    }

    fn image_len(&self) -> usize {
        SharedStoreEngine::image_len(self)
    }

    fn run(&mut self, images: &[f32], n: usize) -> anyhow::Result<Vec<Vec<f32>>> {
        SharedStoreEngine::run(self, images, n)
    }
}

/// PJRT engine + the parameter snapshot its `run` signature expects.
struct PjrtServe {
    engine: BatchForwardEngine,
    params: Vec<f32>,
}

impl ServeEngine for PjrtServe {
    fn batch_cap(&self) -> usize {
        self.engine.batch
    }

    fn image_len(&self) -> usize {
        let side = self.engine.arch.input_side;
        side * side
    }

    fn run(&mut self, images: &[f32], _n: usize) -> anyhow::Result<Vec<Vec<f32>>> {
        // The compiled HLO batch dimension is static: always execute the
        // full padded buffer; the caller uses the first `n` rows.
        self.engine.run(&self.params, images)
    }
}

/// Build one worker's engine from its spec. Runs *inside* the worker
/// thread (the xla crate's PJRT handles are not `Send`).
fn build_engine(spec: Engine) -> anyhow::Result<Box<dyn ServeEngine>> {
    let built: Box<dyn ServeEngine> = match spec {
        Engine::Native { net, params, batch } => {
            Box::new(NativeBatchEngine::new(net, params, batch)?)
        }
        Engine::Shared { net, store, batch } => {
            Box::new(SharedStoreEngine::new(net, store, batch)?)
        }
        Engine::Pjrt { artifact_dir, arch, params } => {
            let manifest = crate::runtime::Manifest::load(&artifact_dir)?;
            let rt = crate::runtime::Runtime::cpu()?;
            let engine = BatchForwardEngine::load(&rt, &manifest, &arch)?;
            Box::new(PjrtServe { engine, params })
        }
    };
    anyhow::ensure!(built.batch_cap() > 0, "serve: engine reports a zero batch capacity");
    Ok(built)
}

struct Request {
    image: Vec<f32>,
    enqueued: Instant,
    /// Cancellation point: once passed, the request must not occupy a
    /// batch slot — workers reply [`ServeError::Expired`] instead.
    deadline: Option<Instant>,
    reply: Sender<Result<Vec<f32>, ServeError>>,
}

impl Request {
    fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// Closes the request queue when the last [`ServerHandle`] clone
/// (including the [`Server`]'s own) drops, so idle workers drain and
/// exit — the queue-level analogue of every `mpsc` sender disconnecting.
struct ProducerGuard {
    queue: Arc<Bounded<Request>>,
}

impl Drop for ProducerGuard {
    fn drop(&mut self) {
        self.queue.close();
    }
}

/// Handle used by client threads. Cloning is cheap; every clone is a
/// liveness token keeping the worker pool serving.
#[derive(Clone)]
pub struct ServerHandle {
    queue: Arc<Bounded<Request>>,
    image_len: usize,
    pub metrics: Arc<ServeMetrics>,
    /// Producer liveness: closes the queue when the last clone drops, and
    /// `Server::drop` counts strong references to decide between joining
    /// the pool (no external handles) and detaching.
    shared: Arc<ProducerGuard>,
}

impl ServerHandle {
    /// Submit one image and block for its probability vector. Blocks
    /// while the queue is full (classic backpressure); for load-shedding
    /// admission control use [`ServerHandle::try_predict`] or
    /// [`ServerHandle::predict_deadline`].
    pub fn predict(&self, image: &[f32]) -> Result<Vec<f32>, ServeError> {
        self.submit(image, None, false)
    }

    /// Like [`ServerHandle::predict`], but refuses immediately with
    /// [`ServeError::Overloaded`] when the queue is full instead of
    /// blocking.
    pub fn try_predict(&self, image: &[f32]) -> Result<Vec<f32>, ServeError> {
        self.submit(image, None, true)
    }

    /// Submit with a deadline `budget` from now. Admission waits at most
    /// until the deadline ([`ServeError::Overloaded`] on a full queue);
    /// once admitted, the request is cancelled — before it occupies a
    /// batch slot — if the deadline passes before execution, and the call
    /// returns [`ServeError::Expired`].
    pub fn predict_deadline(
        &self,
        image: &[f32],
        budget: Duration,
    ) -> Result<Vec<f32>, ServeError> {
        self.submit(image, Some(Instant::now() + budget), false)
    }

    fn submit(
        &self,
        image: &[f32],
        deadline: Option<Instant>,
        nonblocking: bool,
    ) -> Result<Vec<f32>, ServeError> {
        if image.len() != self.image_len {
            return Err(ServeError::InvalidRequest(format!(
                "image size mismatch: got {}, engine expects {}",
                image.len(),
                self.image_len
            )));
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        let req =
            Request { image: image.to_vec(), enqueued: Instant::now(), deadline, reply: reply_tx };
        let admission = if nonblocking {
            self.queue.try_push(req)
        } else {
            self.queue.push_deadline(req, deadline)
        };
        match admission {
            Ok(()) => {}
            Err(PushError::Full(_)) => {
                self.metrics.record_overloaded();
                return Err(ServeError::Overloaded);
            }
            Err(PushError::Closed(_)) => return Err(ServeError::Stopped),
        }
        self.metrics.set_queue_depth(self.queue.len());
        match deadline {
            None => reply_rx.recv().unwrap_or(Err(ServeError::Stopped)),
            Some(d) => {
                let timeout = d.saturating_duration_since(Instant::now());
                match reply_rx.recv_timeout(timeout) {
                    Ok(reply) => reply,
                    // The worker discovers the expiry independently (and
                    // counts it) when it reaches the request.
                    Err(mpsc::RecvTimeoutError::Timeout) => Err(ServeError::Expired),
                    Err(mpsc::RecvTimeoutError::Disconnected) => Err(ServeError::Stopped),
                }
            }
        }
    }
}

/// The serving-pool owner. Dropping `Server` drops its own handle: with no
/// outstanding [`ServerHandle`]s the queue closes and every worker is
/// joined; with handles still alive the pool is **detached** and keeps
/// serving them, exiting on its own once the last handle disconnects.
pub struct Server {
    handle: Option<ServerHandle>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Validate the config and spawn the worker pool. Each engine is
    /// built *inside* its worker (the xla crate's PJRT handles are not
    /// `Send`); build errors — including a zero batch cap from the engine
    /// — are reported back before this returns.
    pub fn spawn(engine: Engine, cfg: ServerConfig) -> anyhow::Result<Server> {
        anyhow::ensure!(
            cfg.queue_depth > 0,
            "serve: queue_depth must be ≥ 1 (a zero-capacity queue rejects every request)"
        );
        anyhow::ensure!(cfg.workers > 0, "serve: the worker pool needs ≥ 1 worker");
        if let Engine::Native { batch, .. } | Engine::Shared { batch, .. } = &engine {
            anyhow::ensure!(*batch > 0, "serve: engine batch size must be ≥ 1");
        }
        let metrics = Arc::new(ServeMetrics::new());
        metrics.set_workers(cfg.workers);
        let queue: Arc<Bounded<Request>> = Arc::new(Bounded::new(cfg.queue_depth));
        let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<usize>>();
        let mut workers = Vec::with_capacity(cfg.workers);
        for spec in engine.replicate(cfg.workers) {
            let queue = queue.clone();
            let metrics = metrics.clone();
            let cfg = cfg.clone();
            let ready = ready_tx.clone();
            workers.push(std::thread::spawn(move || match build_engine(spec) {
                Ok(engine) => {
                    let _ = ready.send(Ok(engine.image_len()));
                    worker_loop(engine, &cfg, &queue, &metrics);
                }
                Err(e) => {
                    let _ = ready.send(Err(e));
                }
            }));
        }
        drop(ready_tx);

        // Collect every worker's load report; any failure tears the pool
        // down (close + join) and surfaces the first error.
        let mut image_len = None;
        let mut first_err = None;
        for _ in 0..cfg.workers {
            match ready_rx.recv() {
                Ok(Ok(il)) => {
                    debug_assert!(image_len.is_none_or(|prev: usize| prev == il));
                    image_len = Some(il);
                }
                Ok(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some(anyhow::anyhow!("server worker died during load"));
                    }
                }
            }
        }
        if let Some(e) = first_err {
            queue.close();
            for w in workers {
                let _ = w.join();
            }
            return Err(e);
        }
        let image_len = image_len.expect("workers > 0 all reported ready");
        let handle = ServerHandle {
            queue: queue.clone(),
            image_len,
            metrics,
            shared: Arc::new(ProducerGuard { queue }),
        };
        Ok(Server { handle: Some(handle), workers })
    }

    /// Convenience: spawn on the native engine.
    pub fn spawn_native(
        net: Network,
        params: Vec<f32>,
        batch: usize,
        cfg: ServerConfig,
    ) -> anyhow::Result<Server> {
        Server::spawn(Engine::Native { net, params, batch }, cfg)
    }

    /// Convenience: spawn serving live from a shared training store.
    pub fn spawn_shared(
        net: Network,
        store: Arc<SharedParams>,
        batch: usize,
        cfg: ServerConfig,
    ) -> anyhow::Result<Server> {
        Server::spawn(Engine::Shared { net, store, batch }, cfg)
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.as_ref().expect("handle lives until drop").clone()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let Some(handle) = self.handle.take() else { return };
        // Join only when no external handle can feed the pool any more;
        // otherwise detach — joining here would block until every
        // outstanding clone is dropped (possibly forever). A handle
        // dropped between the count and the join only makes the join
        // return sooner; no new handle can appear because cloning
        // requires an existing one.
        let external = Arc::strong_count(&handle.shared) > 1;
        drop(handle); // last ProducerGuard ref ⇒ queue closes
        if !external {
            for w in self.workers.drain(..) {
                let _ = w.join();
            }
        }
    }
}

/// Reply `Expired` (and count it) if the request's deadline has passed;
/// otherwise hand it back for batching. The expiry gate every request
/// passes **before** occupying a batch slot.
fn admit(req: Request, metrics: &ServeMetrics) -> Option<Request> {
    if req.expired(Instant::now()) {
        metrics.record_expired();
        let _ = req.reply.send(Err(ServeError::Expired));
        None
    } else {
        Some(req)
    }
}

/// One worker: pop a request, collect batch-mates until the cap or the
/// first request's delay budget runs out, sweep expired requests out,
/// execute, reply. Exits when the queue is closed and drained.
fn worker_loop(
    mut engine: Box<dyn ServeEngine>,
    cfg: &ServerConfig,
    queue: &Bounded<Request>,
    metrics: &ServeMetrics,
) {
    let image_len = engine.image_len();
    let batch_cap = engine.batch_cap();
    let mut batch: Vec<Request> = Vec::with_capacity(batch_cap);
    let mut images = vec![0.0f32; batch_cap * image_len];

    loop {
        // Block for the first live request of a batch.
        let first = loop {
            match queue.pop_wait() {
                Some(r) => {
                    metrics.set_queue_depth(queue.len());
                    if let Some(r) = admit(r, metrics) {
                        break r;
                    }
                }
                None => return, // closed and drained
            }
        };
        // Collect batch-mates until full or the delay budget of the
        // *first* request runs out.
        let flush_at = first.enqueued + cfg.max_delay;
        batch.clear();
        batch.push(first);
        while batch.len() < batch_cap {
            if Instant::now() >= flush_at {
                break;
            }
            match queue.pop_before(flush_at) {
                Some(r) => {
                    metrics.set_queue_depth(queue.len());
                    if let Some(r) = admit(r, metrics) {
                        batch.push(r);
                    }
                }
                None => break,
            }
        }
        // Final expiry sweep: time spent waiting for stragglers must not
        // let an expired request into the engine.
        let now = Instant::now();
        let mut i = 0;
        while i < batch.len() {
            if batch[i].expired(now) {
                let r = batch.swap_remove(i);
                metrics.record_expired();
                let _ = r.reply.send(Err(ServeError::Expired));
            } else {
                i += 1;
            }
        }
        if batch.is_empty() {
            continue;
        }

        // Stage (zero-padding the tail for the static-batch engine) and
        // execute, timing the engine for the per-batch exec metric.
        images.fill(0.0);
        for (i, r) in batch.iter().enumerate() {
            images[i * image_len..(i + 1) * image_len].copy_from_slice(&r.image);
        }
        metrics.record_batch(batch.len());
        metrics.inflight_add(batch.len());
        let sw = Stopwatch::start();
        let result = engine.run(&images, batch.len());
        metrics.record_exec_us(sw.elapsed_secs() * 1e6);
        metrics.inflight_sub(batch.len());

        match result {
            Ok(rows) if rows.len() >= batch.len() => {
                for (i, r) in batch.drain(..).enumerate() {
                    metrics.record_latency_us(r.enqueued.elapsed().as_secs_f64() * 1e6);
                    let _ = r.reply.send(Ok(rows[i].clone()));
                }
            }
            Ok(rows) => {
                let msg =
                    format!("engine returned {} rows for a batch of {}", rows.len(), batch.len());
                metrics.record_exec_failure();
                for r in batch.drain(..) {
                    let _ = r.reply.send(Err(ServeError::Exec(msg.clone())));
                }
            }
            Err(e) => {
                let msg = format!("batch execution failed: {e:#}");
                metrics.record_exec_failure();
                for r in batch.drain(..) {
                    let _ = r.reply.send(Err(ServeError::Exec(msg.clone())));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    // Engine-driven integration coverage (multi-worker pools, deadline
    // expiry, admission control, drop semantics, live shared-store
    // serving) lives in rust/tests/serving.rs and the serving examples.
    // Unit tests here cover config defaults and spawn-time validation.
    use super::*;
    use crate::config::ArchSpec;

    #[test]
    fn config_defaults_sane() {
        let c = ServerConfig::default();
        assert!(c.max_delay >= Duration::from_micros(100));
        assert!(c.queue_depth >= 16);
        assert!(c.workers >= 1);
    }

    #[test]
    fn spawn_rejects_zero_queue_depth() {
        let net = Network::new(ArchSpec::tiny());
        let params = net.init_params(1);
        let e = Server::spawn_native(
            net,
            params,
            4,
            ServerConfig { queue_depth: 0, ..Default::default() },
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("queue_depth"), "{e}");
    }

    #[test]
    fn spawn_rejects_zero_batch() {
        let net = Network::new(ArchSpec::tiny());
        let params = net.init_params(1);
        let e = Server::spawn_native(net, params, 0, ServerConfig::default())
            .unwrap_err()
            .to_string();
        assert!(e.contains("batch size"), "{e}");
    }

    #[test]
    fn spawn_rejects_zero_workers() {
        let net = Network::new(ArchSpec::tiny());
        let params = net.init_params(1);
        let e = Server::spawn_native(
            net,
            params,
            4,
            ServerConfig { workers: 0, ..Default::default() },
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("worker"), "{e}");
    }

    #[test]
    fn spawn_rejects_zero_batch_on_shared_engine() {
        let net = Network::new(ArchSpec::tiny());
        let store = Arc::new(SharedParams::new(&net.init_params(1), &net.dims));
        let e = Server::spawn_shared(net, store, 0, ServerConfig::default())
            .unwrap_err()
            .to_string();
        assert!(e.contains("batch size"), "{e}");
    }
}
