//! Dynamic batcher + serving loop.
//!
//! Requests arrive on an mpsc channel; the collector drains up to `B`
//! requests, waiting at most `max_delay` for stragglers, pads the batch to
//! `B` with zeros (the compiled HLO has a static batch dimension), executes,
//! and replies per-request. This is the standard router/batcher shape of
//! serving systems (vLLM-style), sized down to the paper's models.

use super::metrics::ServeMetrics;
use crate::runtime::BatchForwardEngine;
use crate::util::Stopwatch;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Server tuning knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Max time a request may wait for batch-mates.
    pub max_delay: Duration,
    /// Channel capacity (back-pressure bound).
    pub queue_depth: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { max_delay: Duration::from_millis(2), queue_depth: 1024 }
    }
}

struct Request {
    image: Vec<f32>,
    enqueued: Instant,
    reply: Sender<anyhow::Result<Vec<f32>>>,
}

/// Handle used by client threads.
#[derive(Clone)]
pub struct ServerHandle {
    tx: SyncSender<Request>,
    image_len: usize,
    pub metrics: Arc<ServeMetrics>,
}

impl ServerHandle {
    /// Submit one image and block for its probability vector.
    pub fn predict(&self, image: &[f32]) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(image.len() == self.image_len, "image size mismatch");
        let (reply_tx, reply_rx) = mpsc::channel();
        let req = Request { image: image.to_vec(), enqueued: Instant::now(), reply: reply_tx };
        self.tx
            .send(req)
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        reply_rx.recv().map_err(|_| anyhow::anyhow!("server dropped request"))?
    }
}

/// The serving loop owner. Dropping `Server` (after all handles are gone)
/// stops the worker thread.
pub struct Server {
    handle: ServerHandle,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Spawn the serving thread. The PJRT client and executable are
    /// created *inside* the worker (the xla crate's handles are not
    /// `Send`); load errors are reported back before this returns.
    pub fn spawn(
        artifact_dir: String,
        arch: String,
        params: Vec<f32>,
        cfg: ServerConfig,
    ) -> anyhow::Result<Server> {
        let metrics = Arc::new(ServeMetrics::new());
        let (tx, rx) = mpsc::sync_channel::<Request>(cfg.queue_depth);
        let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<usize>>();
        let m2 = metrics.clone();
        let worker = std::thread::spawn(move || {
            let load = (|| -> anyhow::Result<BatchForwardEngine> {
                let manifest = crate::runtime::Manifest::load(&artifact_dir)?;
                let rt = crate::runtime::Runtime::cpu()?;
                BatchForwardEngine::load(&rt, &manifest, &arch)
            })();
            match load {
                Ok(engine) => {
                    let side = engine.arch.input_side;
                    let _ = ready_tx.send(Ok(side * side));
                    serve_loop(engine, params, cfg, rx, m2);
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                }
            }
        });
        let image_len = ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("server thread died during load"))??;
        Ok(Server { handle: ServerHandle { tx, image_len, metrics }, worker: Some(worker) })
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // Close our handle's sender by replacing it with a dummy channel,
        // then join once all external handles are dropped. We cannot force
        // external handles closed; join only if the channel is already
        // disconnected, otherwise detach.
        if let Some(w) = self.worker.take() {
            let (dummy_tx, _) = mpsc::sync_channel(1);
            self.handle.tx = dummy_tx;
            // If no other handles exist the loop will exit promptly.
            let _ = w.join();
        }
    }
}

fn serve_loop(
    engine: BatchForwardEngine,
    params: Vec<f32>,
    cfg: ServerConfig,
    rx: Receiver<Request>,
    metrics: Arc<ServeMetrics>,
) {
    let image_len = engine.arch.input_side * engine.arch.input_side;
    let batch_cap = engine.batch;
    let mut batch: Vec<Request> = Vec::with_capacity(batch_cap);
    let mut images = vec![0.0f32; batch_cap * image_len];

    loop {
        batch.clear();
        // Block for the first request of a batch.
        match rx.recv() {
            Ok(r) => batch.push(r),
            Err(_) => return, // all senders dropped
        }
        // Then collect batch-mates until full or the delay budget of the
        // *first* request runs out.
        let deadline = batch[0].enqueued + cfg.max_delay;
        while batch.len() < batch_cap {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }

        // Pad and execute.
        images.fill(0.0);
        for (i, r) in batch.iter().enumerate() {
            images[i * image_len..(i + 1) * image_len].copy_from_slice(&r.image);
        }
        metrics.record_batch(batch.len());
        let sw = Stopwatch::start();
        let result = engine.run(&params, &images);
        let _exec_secs = sw.elapsed_secs();

        match result {
            Ok(rows) => {
                for (i, r) in batch.drain(..).enumerate() {
                    metrics
                        .record_latency_us(r.enqueued.elapsed().as_secs_f64() * 1e6);
                    let _ = r.reply.send(Ok(rows[i].clone()));
                }
            }
            Err(e) => {
                let msg = format!("batch execution failed: {e}");
                for r in batch.drain(..) {
                    let _ = r.reply.send(Err(anyhow::anyhow!("{msg}")));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    // The full server path needs compiled artifacts; integration coverage
    // lives in rust/tests/serving.rs and examples/serve_infer.rs. Unit
    // tests here cover config defaults.
    use super::*;

    #[test]
    fn config_defaults_sane() {
        let c = ServerConfig::default();
        assert!(c.max_delay >= Duration::from_micros(100));
        assert!(c.queue_depth >= 16);
    }
}
