//! Dynamic batcher + serving loop.
//!
//! Requests arrive on an mpsc channel; the collector drains up to `B`
//! requests, waiting at most `max_delay` for stragglers, executes the
//! batch on the selected [`Engine`], and replies per-request. This is the
//! standard router/batcher shape of serving systems (vLLM-style), sized
//! down to the paper's models.
//!
//! Two execution engines ([`Engine`]):
//! * `Native` — [`crate::runtime::NativeBatchEngine`] over any compiled
//!   network + parameter snapshot; partial batches run at their actual
//!   size.
//! * `Pjrt` — the AOT artifact path; the compiled HLO has a static batch
//!   dimension, so partial batches are zero-padded to `B`.

use super::metrics::ServeMetrics;
use crate::nn::Network;
use crate::runtime::{BatchForwardEngine, NativeBatchEngine};
use crate::util::Stopwatch;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Server tuning knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Max time a request may wait for batch-mates.
    pub max_delay: Duration,
    /// Channel capacity (back-pressure bound).
    pub queue_depth: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { max_delay: Duration::from_millis(2), queue_depth: 1024 }
    }
}

/// Which execution engine a [`Server`] runs — the serving-side analogue of
/// the runtime's native/PJRT split (see [`crate::runtime`]).
pub enum Engine {
    /// In-process batched execution of a compiled network; no artifacts
    /// required. `batch` is the collector's batch cap.
    Native { net: Network, params: Vec<f32>, batch: usize },
    /// AOT-compiled PJRT artifact (requires `make artifacts` and the
    /// `xla-runtime` feature). The batch cap is the artifact's compiled
    /// batch dimension.
    Pjrt { artifact_dir: String, arch: String, params: Vec<f32> },
}

/// What the serve loop needs from either engine. `images` is the
/// collector's `[cap][image_len]` zero-padded staging buffer; `n` is how
/// many leading rows are real.
trait ServeEngine {
    fn batch_cap(&self) -> usize;
    fn image_len(&self) -> usize;
    fn run(&mut self, images: &[f32], n: usize) -> anyhow::Result<Vec<Vec<f32>>>;
}

impl ServeEngine for NativeBatchEngine {
    fn batch_cap(&self) -> usize {
        self.batch()
    }

    fn image_len(&self) -> usize {
        NativeBatchEngine::image_len(self)
    }

    fn run(&mut self, images: &[f32], n: usize) -> anyhow::Result<Vec<Vec<f32>>> {
        NativeBatchEngine::run(self, images, n)
    }
}

/// PJRT engine + the parameter snapshot its `run` signature expects.
struct PjrtServe {
    engine: BatchForwardEngine,
    params: Vec<f32>,
}

impl ServeEngine for PjrtServe {
    fn batch_cap(&self) -> usize {
        self.engine.batch
    }

    fn image_len(&self) -> usize {
        let side = self.engine.arch.input_side;
        side * side
    }

    fn run(&mut self, images: &[f32], _n: usize) -> anyhow::Result<Vec<Vec<f32>>> {
        // The compiled HLO batch dimension is static: always execute the
        // full padded buffer; the caller uses the first `n` rows.
        self.engine.run(&self.params, images)
    }
}

struct Request {
    image: Vec<f32>,
    enqueued: Instant,
    reply: Sender<anyhow::Result<Vec<f32>>>,
}

/// Handle used by client threads.
#[derive(Clone)]
pub struct ServerHandle {
    tx: SyncSender<Request>,
    image_len: usize,
    pub metrics: Arc<ServeMetrics>,
    /// Liveness token: `Server::drop` counts strong references to decide
    /// between joining the worker (no external handles) and detaching.
    alive: Arc<()>,
}

impl ServerHandle {
    /// Submit one image and block for its probability vector.
    pub fn predict(&self, image: &[f32]) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(image.len() == self.image_len, "image size mismatch");
        let (reply_tx, reply_rx) = mpsc::channel();
        let req = Request { image: image.to_vec(), enqueued: Instant::now(), reply: reply_tx };
        self.tx
            .send(req)
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        reply_rx.recv().map_err(|_| anyhow::anyhow!("server dropped request"))?
    }
}

/// The serving loop owner. Dropping `Server` closes its own sender: with
/// no outstanding [`ServerHandle`]s the worker exits and is joined; with
/// handles still alive the worker is **detached** and keeps serving them,
/// exiting on its own once the last handle disconnects.
pub struct Server {
    handle: ServerHandle,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Validate the config and spawn the serving thread. The engine is
    /// built *inside* the worker (the xla crate's PJRT handles are not
    /// `Send`); build errors — including a zero batch cap from the engine
    /// — are reported back before this returns.
    pub fn spawn(engine: Engine, cfg: ServerConfig) -> anyhow::Result<Server> {
        anyhow::ensure!(
            cfg.queue_depth > 0,
            "serve: queue_depth must be ≥ 1 (a zero-capacity channel deadlocks every sender)"
        );
        if let Engine::Native { batch, .. } = &engine {
            anyhow::ensure!(*batch > 0, "serve: native engine batch size must be ≥ 1");
        }
        let metrics = Arc::new(ServeMetrics::new());
        let (tx, rx) = mpsc::sync_channel::<Request>(cfg.queue_depth);
        let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<usize>>();
        let m2 = metrics.clone();
        let worker = std::thread::spawn(move || {
            let built = (|| -> anyhow::Result<Box<dyn ServeEngine>> {
                let built: Box<dyn ServeEngine> = match engine {
                    Engine::Native { net, params, batch } => {
                        Box::new(NativeBatchEngine::new(net, params, batch)?)
                    }
                    Engine::Pjrt { artifact_dir, arch, params } => {
                        let manifest = crate::runtime::Manifest::load(&artifact_dir)?;
                        let rt = crate::runtime::Runtime::cpu()?;
                        let engine = BatchForwardEngine::load(&rt, &manifest, &arch)?;
                        Box::new(PjrtServe { engine, params })
                    }
                };
                anyhow::ensure!(
                    built.batch_cap() > 0,
                    "serve: engine reports a zero batch capacity"
                );
                Ok(built)
            })();
            match built {
                Ok(engine) => {
                    let _ = ready_tx.send(Ok(engine.image_len()));
                    serve_loop(engine, cfg, rx, m2);
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                }
            }
        });
        let image_len = ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("server thread died during load"))??;
        Ok(Server {
            handle: ServerHandle { tx, image_len, metrics, alive: Arc::new(()) },
            worker: Some(worker),
        })
    }

    /// Convenience: spawn on the native engine.
    pub fn spawn_native(
        net: Network,
        params: Vec<f32>,
        batch: usize,
        cfg: ServerConfig,
    ) -> anyhow::Result<Server> {
        Server::spawn(Engine::Native { net, params, batch }, cfg)
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if let Some(w) = self.worker.take() {
            // Close our own sender by replacing it with a dummy channel.
            let (dummy_tx, _) = mpsc::sync_channel(1);
            self.handle.tx = dummy_tx;
            // Join only when no external handle can feed the loop any
            // more; otherwise detach — joining here would block until
            // every outstanding clone is dropped (possibly forever).
            // A handle dropped between the count and the join only makes
            // the join return sooner; no new handle can appear because
            // cloning requires an existing one.
            if Arc::strong_count(&self.handle.alive) == 1 {
                let _ = w.join();
            }
        }
    }
}

fn serve_loop(
    mut engine: Box<dyn ServeEngine>,
    cfg: ServerConfig,
    rx: Receiver<Request>,
    metrics: Arc<ServeMetrics>,
) {
    let image_len = engine.image_len();
    let batch_cap = engine.batch_cap();
    let mut batch: Vec<Request> = Vec::with_capacity(batch_cap);
    let mut images = vec![0.0f32; batch_cap * image_len];

    loop {
        batch.clear();
        // Block for the first request of a batch.
        match rx.recv() {
            Ok(r) => batch.push(r),
            Err(_) => return, // all senders dropped
        }
        // Then collect batch-mates until full or the delay budget of the
        // *first* request runs out.
        let deadline = batch[0].enqueued + cfg.max_delay;
        while batch.len() < batch_cap {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }

        // Stage (zero-padding the tail for the static-batch engine) and
        // execute.
        images.fill(0.0);
        for (i, r) in batch.iter().enumerate() {
            images[i * image_len..(i + 1) * image_len].copy_from_slice(&r.image);
        }
        metrics.record_batch(batch.len());
        let sw = Stopwatch::start();
        let result = engine.run(&images, batch.len());
        let _exec_secs = sw.elapsed_secs();

        match result {
            Ok(rows) => {
                if rows.len() < batch.len() {
                    let msg = format!(
                        "engine returned {} rows for a batch of {}",
                        rows.len(),
                        batch.len()
                    );
                    for r in batch.drain(..) {
                        let _ = r.reply.send(Err(anyhow::anyhow!("{msg}")));
                    }
                    continue;
                }
                for (i, r) in batch.drain(..).enumerate() {
                    metrics
                        .record_latency_us(r.enqueued.elapsed().as_secs_f64() * 1e6);
                    let _ = r.reply.send(Ok(rows[i].clone()));
                }
            }
            Err(e) => {
                let msg = format!("batch execution failed: {e}");
                for r in batch.drain(..) {
                    let _ = r.reply.send(Err(anyhow::anyhow!("{msg}")));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    // Engine-driven integration coverage (native partial batches,
    // straggler flushes, drop semantics) lives in rust/tests/serving.rs
    // and examples/serve_infer.rs. Unit tests here cover config defaults
    // and spawn-time validation.
    use super::*;
    use crate::config::ArchSpec;

    #[test]
    fn config_defaults_sane() {
        let c = ServerConfig::default();
        assert!(c.max_delay >= Duration::from_micros(100));
        assert!(c.queue_depth >= 16);
    }

    #[test]
    fn spawn_rejects_zero_queue_depth() {
        let net = Network::new(ArchSpec::tiny());
        let params = net.init_params(1);
        let e = Server::spawn_native(
            net,
            params,
            4,
            ServerConfig { queue_depth: 0, ..Default::default() },
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("queue_depth"), "{e}");
    }

    #[test]
    fn spawn_rejects_zero_batch() {
        let net = Network::new(ArchSpec::tiny());
        let params = net.init_params(1);
        let e = Server::spawn_native(net, params, 0, ServerConfig::default())
            .unwrap_err()
            .to_string();
        assert!(e.contains("batch size"), "{e}");
    }
}
