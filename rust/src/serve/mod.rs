//! Batched inference service over the AOT executable — the deployment-side
//! complement of the trainer: once CHAOS has produced weights, this module
//! serves predictions from the PJRT path with dynamic batching.
//!
//! Architecture (std threads + channels; tokio is not in the vendored
//! registry): callers submit images through [`ServerHandle::predict`]; a
//! collector thread groups them into batches of up to `B` (the artifact's
//! compiled batch size), flushing on size or on `max_delay`; the executor
//! runs the batched HLO and routes each row back through the caller's
//! oneshot channel.

mod batcher;
mod metrics;

pub use batcher::{Server, ServerConfig, ServerHandle};
pub use metrics::ServeMetrics;
