//! Batched inference service — the deployment-side complement of the
//! trainer: once CHAOS has produced weights (or *while* it is producing
//! them), this module serves predictions with dynamic batching.
//!
//! Architecture (std threads; tokio is not in the vendored registry):
//! callers submit images through a [`ServerHandle`] — blocking
//! ([`ServerHandle::predict`]), load-shedding
//! ([`ServerHandle::try_predict`]) or deadline-bounded
//! ([`ServerHandle::predict_deadline`]) — into a shared bounded queue; a
//! pool of `N` worker threads, each owning its own engine and batch
//! arenas, drains the queue, groups requests into batches of up to `B`
//! (flushing on size or on `max_delay`), drops expired requests before
//! they occupy a batch slot, and routes each probability row back through
//! the caller's oneshot channel. Failures are typed ([`ServeError`]):
//! `Overloaded` (full queue), `Expired` (deadline passed), `Stopped`
//! (shutdown), plus request-validation and execution errors.
//!
//! ## Engine choice ([`Engine`])
//!
//! * **`Engine::Native`** (default choice) — executes the compiled
//!   [`crate::nn::Network`] through the batched forward plan
//!   ([`crate::nn::BatchPlan`]) via
//!   [`crate::runtime::NativeBatchEngine`]. Works in every build, needs no
//!   artifacts, runs partial batches at their actual size, and serves
//!   weights straight from a training run.
//! * **`Engine::Shared`** — serves **live from a training run**: each
//!   batch snapshots the current weights out of a
//!   [`crate::chaos::SharedParams`] store
//!   ([`crate::runtime::SharedStoreEngine`]) under the CHAOS per-layer
//!   read contract, so predictions track training mid-epoch with no
//!   checkpoint round-trip.
//! * **`Engine::Pjrt`** — executes the AOT-compiled batched-forward HLO
//!   artifact on the PJRT CPU client (requires `make artifacts` and the
//!   `xla-runtime` feature). The artifact's batch dimension is static, so
//!   partial batches are zero-padded to the compiled `B`.
//!
//! Observability: [`ServerHandle::metrics`] exposes [`ServeMetrics`] —
//! fixed-bucket latency and exec-time histograms (bounded memory under
//! sustained traffic) plus queue-depth / in-flight / worker gauges and
//! expiry / overload / failure counters, snapshotted via
//! [`ServeMetrics::snapshot`] into a [`MetricsSnapshot`].

mod batcher;
mod error;
mod metrics;
mod queue;

pub use batcher::{Engine, Server, ServerConfig, ServerHandle};
pub use error::ServeError;
pub use metrics::{Histogram, MetricsSnapshot, ServeMetrics};
