//! Batched inference service — the deployment-side complement of the
//! trainer: once CHAOS has produced weights, this module serves
//! predictions with dynamic batching.
//!
//! Architecture (std threads + channels; tokio is not in the vendored
//! registry): callers submit images through [`ServerHandle::predict`]; a
//! collector thread groups them into batches of up to `B`, flushing on
//! size or on `max_delay`; the engine runs the batch and routes each row
//! back through the caller's oneshot channel.
//!
//! ## Engine choice ([`Engine`])
//!
//! * **`Engine::Native`** (default choice) — executes the compiled
//!   [`crate::nn::Network`] through the batched forward plan
//!   ([`crate::nn::BatchPlan`]) via
//!   [`crate::runtime::NativeBatchEngine`]. Works in every build, needs no
//!   artifacts, runs partial batches at their actual size, and serves
//!   weights straight from a training run.
//! * **`Engine::Pjrt`** — executes the AOT-compiled batched-forward HLO
//!   artifact on the PJRT CPU client (requires `make artifacts` and the
//!   `xla-runtime` feature). The artifact's batch dimension is static, so
//!   partial batches are zero-padded to the compiled `B`.

mod batcher;
mod metrics;

pub use batcher::{Engine, Server, ServerConfig, ServerHandle};
pub use metrics::ServeMetrics;
