//! Typed serving errors — the admission-control and deadline contract of
//! the serving tier.
//!
//! Clients used to get one opaque "server stopped" string for every
//! failure mode; the worker-pool rewrite distinguishes the cases a real
//! load balancer must tell apart: a full queue ([`ServeError::Overloaded`],
//! retry elsewhere / shed load), a missed deadline ([`ServeError::Expired`],
//! the answer is worthless now), and an actual shutdown
//! ([`ServeError::Stopped`]).

/// Why a prediction request failed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The bounded request queue was full and the request was not admitted
    /// (within its deadline, if it had one). The server is shedding load —
    /// back off and retry.
    Overloaded,
    /// The request's deadline passed before a reply was produced. Expired
    /// requests are cancelled before they occupy a batch slot; the engine
    /// never runs them.
    Expired,
    /// The server has shut down (or a worker died) — no reply will ever
    /// come.
    Stopped,
    /// The request was malformed (e.g. an image size mismatch) and was
    /// rejected before it was enqueued.
    InvalidRequest(String),
    /// The engine failed while executing the batch containing this
    /// request.
    Exec(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded => {
                write!(f, "server overloaded: request queue is full")
            }
            ServeError::Expired => {
                write!(f, "request expired: deadline passed before execution")
            }
            ServeError::Stopped => write!(f, "server stopped"),
            ServeError::InvalidRequest(msg) => write!(f, "invalid request: {msg}"),
            ServeError::Exec(msg) => write!(f, "execution failed: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variants_are_distinguishable_in_display() {
        let msgs = [
            ServeError::Overloaded.to_string(),
            ServeError::Expired.to_string(),
            ServeError::Stopped.to_string(),
            ServeError::InvalidRequest("image size mismatch".into()).to_string(),
            ServeError::Exec("boom".into()).to_string(),
        ];
        for (i, a) in msgs.iter().enumerate() {
            for (j, b) in msgs.iter().enumerate() {
                if i != j {
                    assert_ne!(a, b);
                }
            }
        }
        assert!(msgs[0].contains("overloaded"));
        assert!(msgs[1].contains("expired"));
        assert!(msgs[3].contains("size"));
    }

    #[test]
    fn converts_into_anyhow() {
        fn fails() -> anyhow::Result<()> {
            Err(ServeError::Overloaded)?;
            Ok(())
        }
        assert!(fails().unwrap_err().to_string().contains("overloaded"));
    }
}
