//! The open update-policy API — the seam the paper's §4.1 identifies as
//! the *interchangeable* part of the trainer.
//!
//! A policy decides how each worker's gradients reach the shared weights:
//! instantly or delayed, locked or racy, per layer or per sample, with or
//! without barriers. The epoch driver ([`super::Trainer`]) is policy-blind;
//! it drives forward/backward passes and hands every layer's finished
//! gradient block to the policy's per-worker hooks. New schemes (e.g. the
//! hybrid data/model parallelism of Krizhevsky's "one weird trick",
//! arXiv:1404.5997, or heterogeneous-device scheduling, arXiv:1712.02546)
//! are new [`UpdatePolicy`] impls plus a [`register`] call — no changes to
//! the driver.
//!
//! The five paper strategies ship as provided impls, resolvable by name
//! through [`from_name`] (e.g. `"chaos"`, `"averaged:64"`):
//!
//! * [`SequentialPolicy`] — plain on-line SGD on one thread (baseline A);
//! * [`AveragedPolicy`] — barrier-synchronized averaged gradients
//!   (strategy B, De Grazia et al.);
//! * [`DelayedRoundRobinPolicy`] — whole-sample publications serialized in
//!   ticket order (strategy C, Zinkevich et al.);
//! * [`HogwildPolicy`] — instant, lock-free, racy updates (strategy D,
//!   Recht et al.);
//! * [`ChaosPolicy`] — controlled HogWild: per-layer publication under a
//!   per-layer lock, arbitrary order of implicit synchronization (the
//!   paper's contribution).
//!
//! Two **minibatch** policies train on B-sample chunks through the batched
//! kernels (the paper's per-sample SGD was a Phi-era constraint; minibatch
//! data parallelism amortizes every weight load across the chunk,
//! arXiv:1404.5997). Their workers claim whole chunks from the sampler and
//! drive one `nn::BatchPlan` forward/backward per chunk — see
//! [`UpdatePolicy::minibatch`] and [`WorkerHooks::publish_batch`]:
//!
//! * [`MinibatchPolicy`] (`"minibatch:B"`) — true averaged minibatch
//!   gradients: one publication per layer per chunk under the per-layer
//!   locks, scaled by η/n where n is the *actual* chunk size (the epoch's
//!   final chunk may be smaller than B);
//! * [`HogwildBatchPolicy`] (`"hogwild-batch:B"`) — per-layer delayed
//!   publication of **batch-summed** gradients under the CHAOS-style
//!   per-layer locks: equivalent to B per-sample CHAOS steps computed from
//!   one weight snapshot, published together.

use super::analysis::SyncContract;
use super::shared::SharedParams;
use super::strategies::Turnstile;
use crate::nn::{LayerDims, MathPolicy, Network};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Barrier, Mutex, OnceLock};

/// Everything a policy may consult while running one epoch's training
/// phase. Borrowed by the driver for the duration of the epoch.
pub struct EpochCtx<'a> {
    /// The network being trained (geometry, layer table).
    pub net: &'a Network,
    /// The shared weight store all workers read from and publish to.
    pub store: &'a SharedParams,
    /// Number of worker threads in this run.
    pub threads: usize,
    /// Learning rate η for this epoch.
    pub eta: f32,
    /// 0-based epoch index.
    pub epoch: usize,
    /// The run's PRNG seed (`TrainConfig::seed`) — mixed into per-worker
    /// scratch streams so stochastic ops (dropout masks) differ across
    /// differently-seeded runs.
    pub seed: u64,
    /// Accumulation policy for the minibatch training kernels
    /// (`TrainConfig::math`); per-sample workers are inherently exact.
    pub math: MathPolicy,
}

/// An update policy: how worker gradients reach the shared weights.
///
/// A policy is long-lived (one per run); per-epoch shared state (barriers,
/// accumulators, turnstiles) is created by [`UpdatePolicy::epoch_state`]
/// and per-worker state by [`EpochState::worker`].
pub trait UpdatePolicy: Send + Sync {
    /// Stable name recorded in [`super::RunResult::strategy`] (and used by
    /// the registry), e.g. `"chaos"`.
    fn name(&self) -> String;

    /// Sequential policies run the in-place single-thread engine; the
    /// driver also takes that path whenever `threads == 1`.
    fn is_sequential(&self) -> bool {
        false
    }

    /// Reject invalid parameterizations before any thread spawns.
    fn validate(&self) -> anyhow::Result<()> {
        Ok(())
    }

    /// Minibatch-capable policies return `Some(B)`: the epoch driver then
    /// claims B-sample chunks from the sampler and drives forward/backward
    /// through one `nn::BatchPlan` per worker, handing each layer's
    /// batch-summed gradients to [`WorkerHooks::publish_batch`]. `None`
    /// (the default) trains per-sample through the per-worker
    /// [`WorkerHooks::publish`] hook.
    fn minibatch(&self) -> Option<usize> {
        None
    }

    /// The synchronization discipline this policy's publications promise
    /// to follow, enforced by the race checker when the crate is built
    /// with `--features race-check` (see [`crate::chaos::analysis`]). The
    /// default claims [`SyncContract::Controlled`] — writes never
    /// temporally overlap; a deliberately racy policy must override this
    /// to [`SyncContract::HogwildTolerated`] to opt into its races.
    fn sync_contract(&self) -> SyncContract {
        SyncContract::Controlled
    }

    /// Per-epoch shared state; called once per epoch before workers start.
    fn epoch_state(&self, ctx: &EpochCtx<'_>) -> Box<dyn EpochState>;
}

/// Shared state for one epoch's training phase; hands out per-worker hooks
/// (worker setup). Shared by reference across all worker threads.
pub trait EpochState: Send + Sync {
    /// Per-worker setup: build this worker's hook object. Called once per
    /// worker thread, inside that thread.
    fn worker(&self, ctx: &EpochCtx<'_>, worker_id: usize) -> Box<dyn WorkerHooks + '_>;
}

/// Per-worker policy hooks, driven by the epoch driver.
pub trait WorkerHooks {
    /// Layer `layer`'s gradients for the current sample are complete
    /// (called back-to-front during back-propagation — the per-layer
    /// publication point).
    fn publish(&mut self, ctx: &EpochCtx<'_>, layer: usize, dims: &LayerDims, grads: &[f32]);

    /// The current sample's backward pass finished (sample-boundary sync
    /// point — turnstiles, chunk counting, barriers).
    fn end_sample(&mut self, _ctx: &EpochCtx<'_>) {}

    /// Layer `layer`'s **batch-summed** gradients over `n` samples are
    /// complete (back-to-front during the chunk's batched back-propagation
    /// — only driven for policies whose [`UpdatePolicy::minibatch`] is
    /// `Some`). `n` is the *actual* chunk size: the epoch's final chunk may
    /// be smaller than the configured B, and averaging policies must
    /// divide by `n`, not B.
    fn publish_batch(
        &mut self,
        _ctx: &EpochCtx<'_>,
        _layer: usize,
        _dims: &LayerDims,
        _grads: &[f32],
        _n: usize,
    ) {
        unreachable!(
            "publish_batch driven on a policy without minibatch support \
             (override publish_batch alongside UpdatePolicy::minibatch)"
        );
    }

    /// The sampler drained; flush remaining state and join any collective
    /// shutdown (worker teardown). Called once, before the thread exits.
    fn finish(&mut self, _ctx: &EpochCtx<'_>) {}
}

// ---------------------------------------------------------------------------
// Baseline A: sequential
// ---------------------------------------------------------------------------

/// Plain on-line SGD on one thread (the paper's baseline).
#[derive(Debug, Clone, Copy, Default)]
pub struct SequentialPolicy;

impl UpdatePolicy for SequentialPolicy {
    fn name(&self) -> String {
        "sequential".to_string()
    }

    fn is_sequential(&self) -> bool {
        true
    }

    fn epoch_state(&self, _ctx: &EpochCtx<'_>) -> Box<dyn EpochState> {
        // Never reached through the driver (sequential policies run the
        // in-place engine); behaves like CHAOS if driven directly.
        Box::new(LockedState)
    }
}

// ---------------------------------------------------------------------------
// CHAOS (controlled HogWild) and strategy D (pure HogWild!)
// ---------------------------------------------------------------------------

/// CHAOS: per-layer delayed publication under a per-layer lock.
#[derive(Debug, Clone, Copy, Default)]
pub struct ChaosPolicy;

impl UpdatePolicy for ChaosPolicy {
    fn name(&self) -> String {
        "chaos".to_string()
    }

    fn epoch_state(&self, _ctx: &EpochCtx<'_>) -> Box<dyn EpochState> {
        Box::new(LockedState)
    }
}

struct LockedState;

impl EpochState for LockedState {
    fn worker(&self, _ctx: &EpochCtx<'_>, _worker_id: usize) -> Box<dyn WorkerHooks + '_> {
        Box::new(LockedHooks)
    }
}

struct LockedHooks;

impl WorkerHooks for LockedHooks {
    fn publish(&mut self, ctx: &EpochCtx<'_>, layer: usize, dims: &LayerDims, grads: &[f32]) {
        ctx.store.publish_scaled(layer, dims.params.clone(), grads, -ctx.eta);
    }
}

/// Strategy D: per-layer publication without locks; racing publishers may
/// lose updates — exactly the race the original HogWild! tolerates.
#[derive(Debug, Clone, Copy, Default)]
pub struct HogwildPolicy;

impl UpdatePolicy for HogwildPolicy {
    fn name(&self) -> String {
        "hogwild".to_string()
    }

    /// HogWild! opts into its races: concurrent unlocked writes to the
    /// same range are the design, not a defect.
    fn sync_contract(&self) -> SyncContract {
        SyncContract::HogwildTolerated
    }

    fn epoch_state(&self, _ctx: &EpochCtx<'_>) -> Box<dyn EpochState> {
        Box::new(UnlockedState)
    }
}

struct UnlockedState;

impl EpochState for UnlockedState {
    fn worker(&self, _ctx: &EpochCtx<'_>, _worker_id: usize) -> Box<dyn WorkerHooks + '_> {
        Box::new(UnlockedHooks)
    }
}

struct UnlockedHooks;

impl WorkerHooks for UnlockedHooks {
    fn publish(&mut self, ctx: &EpochCtx<'_>, _layer: usize, dims: &LayerDims, grads: &[f32]) {
        ctx.store.publish_scaled_unlocked(dims.params.clone(), grads, -ctx.eta);
    }
}

// ---------------------------------------------------------------------------
// Strategy C: delayed round-robin
// ---------------------------------------------------------------------------

/// Strategy C: gradients of the whole sample are gathered locally, then
/// published one worker at a time in strict ticket order.
#[derive(Debug, Clone, Copy, Default)]
pub struct DelayedRoundRobinPolicy;

impl UpdatePolicy for DelayedRoundRobinPolicy {
    fn name(&self) -> String {
        "delayed-rr".to_string()
    }

    fn epoch_state(&self, ctx: &EpochCtx<'_>) -> Box<dyn EpochState> {
        let param_layers: Vec<usize> = ctx
            .net
            .dims
            .iter()
            .enumerate()
            .filter(|(_, d)| d.param_count() > 0)
            .map(|(i, _)| i)
            .collect();
        Box::new(RoundRobinState {
            turnstile: Turnstile::new(),
            param_layers,
            total_params: ctx.net.total_params,
        })
    }
}

struct RoundRobinState {
    turnstile: Turnstile,
    param_layers: Vec<usize>,
    total_params: usize,
}

impl EpochState for RoundRobinState {
    fn worker(&self, _ctx: &EpochCtx<'_>, _worker_id: usize) -> Box<dyn WorkerHooks + '_> {
        Box::new(RoundRobinWorker { state: self, grads: vec![0.0; self.total_params] })
    }
}

struct RoundRobinWorker<'a> {
    state: &'a RoundRobinState,
    grads: Vec<f32>,
}

impl WorkerHooks for RoundRobinWorker<'_> {
    fn publish(&mut self, _ctx: &EpochCtx<'_>, _layer: usize, dims: &LayerDims, grads: &[f32]) {
        self.grads[dims.params.clone()].copy_from_slice(grads);
    }

    fn end_sample(&mut self, ctx: &EpochCtx<'_>) {
        self.state.turnstile.enter();
        for &l in &self.state.param_layers {
            let range = ctx.net.dims[l].params.clone();
            // The turnstile already serializes all publishers.
            ctx.store.publish_scaled_unlocked(range.clone(), &self.grads[range], -ctx.eta);
        }
        self.state.turnstile.leave();
    }
}

// ---------------------------------------------------------------------------
// Strategy B: averaged (synchronous) SGD
// ---------------------------------------------------------------------------

/// Strategy B: workers accumulate gradients over up to `sync_every`
/// samples, a barrier synchronizes, the leader averages across workers and
/// applies one master step, and the round repeats until the epoch's sample
/// pool drains.
#[derive(Debug, Clone, Copy)]
pub struct AveragedPolicy {
    /// Samples accumulated per worker between synchronization rounds.
    pub sync_every: usize,
}

impl AveragedPolicy {
    pub fn new(sync_every: usize) -> AveragedPolicy {
        AveragedPolicy { sync_every }
    }
}

impl Default for AveragedPolicy {
    fn default() -> AveragedPolicy {
        AveragedPolicy { sync_every: 32 }
    }
}

impl UpdatePolicy for AveragedPolicy {
    fn name(&self) -> String {
        "averaged".to_string()
    }

    fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.sync_every > 0,
            "averaged: sync_every must be ≥ 1 (0 would deadlock the barrier rounds)"
        );
        Ok(())
    }

    /// The leader overwrites the whole store between barrier rounds.
    fn sync_contract(&self) -> SyncContract {
        SyncContract::StoreAll
    }

    fn epoch_state(&self, ctx: &EpochCtx<'_>) -> Box<dyn EpochState> {
        Box::new(AveragedState {
            sync_every: self.sync_every.max(1),
            accum: Mutex::new(vec![0.0f32; ctx.net.total_params]),
            round_samples: AtomicUsize::new(0),
            barrier: Barrier::new(ctx.threads),
            done: AtomicBool::new(false),
        })
    }
}

struct AveragedState {
    sync_every: usize,
    accum: Mutex<Vec<f32>>,
    round_samples: AtomicUsize,
    barrier: Barrier,
    done: AtomicBool,
}

impl EpochState for AveragedState {
    fn worker(&self, ctx: &EpochCtx<'_>, _worker_id: usize) -> Box<dyn WorkerHooks + '_> {
        Box::new(AveragedWorker {
            state: self,
            local: vec![0.0; ctx.net.total_params],
            n_local: 0,
        })
    }
}

struct AveragedWorker<'a> {
    state: &'a AveragedState,
    local: Vec<f32>,
    n_local: usize,
}

impl AveragedWorker<'_> {
    /// One synchronization round: merge the local chunk, barrier, leader
    /// applies the averaged master step (or flags the epoch done when the
    /// round gathered nothing), barrier, reset.
    fn round(&mut self, ctx: &EpochCtx<'_>) {
        let st = self.state;
        if self.n_local > 0 {
            let mut acc = st.accum.lock().unwrap();
            for (a, &l) in acc.iter_mut().zip(&self.local) {
                *a += l;
            }
            st.round_samples.fetch_add(self.n_local, Ordering::Relaxed);
        }
        let wait = st.barrier.wait();
        if wait.is_leader() {
            let n = st.round_samples.swap(0, Ordering::Relaxed);
            if n == 0 {
                st.done.store(true, Ordering::Release);
            } else {
                let mut acc = st.accum.lock().unwrap();
                // Averaged master step (strategy B): each learner's
                // contribution is the gradient *sum* over its batch; the
                // master averages across learners and applies one step:
                // w -= η · (Σ_batches g) / workers. Note n counts samples;
                // workers ≈ ceil(n / sync_every).
                let workers = n.div_ceil(st.sync_every).max(1);
                let mut new_params = ctx.store.snapshot();
                let scale = ctx.eta / workers as f32;
                for (w, g) in new_params.iter_mut().zip(acc.iter()) {
                    *w -= scale * g;
                }
                ctx.store.store_all(&new_params);
                acc.fill(0.0);
            }
        }
        st.barrier.wait();
        self.local.fill(0.0);
        self.n_local = 0;
    }
}

impl WorkerHooks for AveragedWorker<'_> {
    fn publish(&mut self, _ctx: &EpochCtx<'_>, _layer: usize, dims: &LayerDims, grads: &[f32]) {
        for (a, &g) in self.local[dims.params.clone()].iter_mut().zip(grads) {
            *a += g;
        }
    }

    fn end_sample(&mut self, ctx: &EpochCtx<'_>) {
        self.n_local += 1;
        if self.n_local >= self.state.sync_every {
            self.round(ctx);
        }
    }

    fn finish(&mut self, ctx: &EpochCtx<'_>) {
        // Flush the partial chunk, then keep joining rounds until every
        // worker has drained: the round that gathers zero samples globally
        // ends the epoch for everyone.
        loop {
            self.round(ctx);
            if self.state.done.load(Ordering::Acquire) {
                break;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Minibatch policies (batched kernels, B-sample chunks)
// ---------------------------------------------------------------------------

/// True minibatch SGD over the batched kernels: each worker claims
/// B-sample chunks, computes batch-summed gradients through one
/// `nn::BatchPlan`, and publishes every layer **once per chunk** under the
/// per-layer locks, scaled by η/n — averaged minibatch gradients, the
/// data-parallel variant of Krizhevsky's "one weird trick"
/// (arXiv:1404.5997). `n` is the actual chunk size, so the epoch's final
/// partial chunk still takes an exactly-averaged step.
#[derive(Debug, Clone, Copy)]
pub struct MinibatchPolicy {
    /// Samples per chunk (the minibatch size B).
    pub batch: usize,
}

impl MinibatchPolicy {
    pub fn new(batch: usize) -> MinibatchPolicy {
        MinibatchPolicy { batch }
    }
}

impl Default for MinibatchPolicy {
    fn default() -> MinibatchPolicy {
        MinibatchPolicy { batch: 32 }
    }
}

impl UpdatePolicy for MinibatchPolicy {
    fn name(&self) -> String {
        "minibatch".to_string()
    }

    fn minibatch(&self) -> Option<usize> {
        Some(self.batch)
    }

    fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.batch > 0, "minibatch: batch size must be ≥ 1");
        Ok(())
    }

    fn epoch_state(&self, _ctx: &EpochCtx<'_>) -> Box<dyn EpochState> {
        Box::new(MinibatchState { average: true })
    }
}

/// Batched CHAOS: batch-summed gradients published per layer under the
/// per-layer locks at chunk boundaries ("delayed" by up to B samples),
/// **without** averaging — equivalent to B per-sample CHAOS steps computed
/// from one weight snapshot and published together, trading update
/// freshness for amortized weight loads.
#[derive(Debug, Clone, Copy)]
pub struct HogwildBatchPolicy {
    /// Samples per chunk (the minibatch size B).
    pub batch: usize,
}

impl HogwildBatchPolicy {
    pub fn new(batch: usize) -> HogwildBatchPolicy {
        HogwildBatchPolicy { batch }
    }
}

impl Default for HogwildBatchPolicy {
    fn default() -> HogwildBatchPolicy {
        HogwildBatchPolicy { batch: 32 }
    }
}

impl UpdatePolicy for HogwildBatchPolicy {
    fn name(&self) -> String {
        "hogwild-batch".to_string()
    }

    fn minibatch(&self) -> Option<usize> {
        Some(self.batch)
    }

    fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.batch > 0, "hogwild-batch: batch size must be ≥ 1");
        Ok(())
    }

    fn epoch_state(&self, _ctx: &EpochCtx<'_>) -> Box<dyn EpochState> {
        Box::new(MinibatchState { average: false })
    }
}

struct MinibatchState {
    /// Divide the batch-summed gradient by the chunk size (`minibatch`)
    /// or publish the raw sum (`hogwild-batch`).
    average: bool,
}

impl EpochState for MinibatchState {
    fn worker(&self, _ctx: &EpochCtx<'_>, _worker_id: usize) -> Box<dyn WorkerHooks + '_> {
        Box::new(MinibatchHooks { average: self.average })
    }
}

struct MinibatchHooks {
    average: bool,
}

impl WorkerHooks for MinibatchHooks {
    fn publish(&mut self, ctx: &EpochCtx<'_>, layer: usize, dims: &LayerDims, grads: &[f32]) {
        // Per-sample driving degenerates to a chunk of one: η/1 = η.
        ctx.store.publish_scaled(layer, dims.params.clone(), grads, -ctx.eta);
    }

    fn publish_batch(
        &mut self,
        ctx: &EpochCtx<'_>,
        layer: usize,
        dims: &LayerDims,
        grads: &[f32],
        n: usize,
    ) {
        debug_assert!(n > 0, "empty chunks are never backpropagated");
        // Averaging divides by the actual chunk size n — the epoch's final
        // chunk may be smaller than the configured B.
        let scale = if self.average { -(ctx.eta / n as f32) } else { -ctx.eta };
        ctx.store.publish_scaled(layer, dims.params.clone(), grads, scale);
    }
}

// ---------------------------------------------------------------------------
// Name registry
// ---------------------------------------------------------------------------

type Factory = Arc<dyn Fn(Option<&str>) -> anyhow::Result<Box<dyn UpdatePolicy>> + Send + Sync>;

fn make_sequential(arg: Option<&str>) -> anyhow::Result<Box<dyn UpdatePolicy>> {
    no_arg("sequential", arg)?;
    Ok(Box::new(SequentialPolicy))
}

fn make_chaos(arg: Option<&str>) -> anyhow::Result<Box<dyn UpdatePolicy>> {
    no_arg("chaos", arg)?;
    Ok(Box::new(ChaosPolicy))
}

fn make_hogwild(arg: Option<&str>) -> anyhow::Result<Box<dyn UpdatePolicy>> {
    no_arg("hogwild", arg)?;
    Ok(Box::new(HogwildPolicy))
}

fn make_delayed_rr(arg: Option<&str>) -> anyhow::Result<Box<dyn UpdatePolicy>> {
    no_arg("delayed-rr", arg)?;
    Ok(Box::new(DelayedRoundRobinPolicy))
}

fn make_averaged(arg: Option<&str>) -> anyhow::Result<Box<dyn UpdatePolicy>> {
    Ok(Box::new(AveragedPolicy { sync_every: parse_sync_every(arg)? }))
}

fn make_minibatch(arg: Option<&str>) -> anyhow::Result<Box<dyn UpdatePolicy>> {
    Ok(Box::new(MinibatchPolicy { batch: parse_batch("minibatch", arg)? }))
}

fn make_hogwild_batch(arg: Option<&str>) -> anyhow::Result<Box<dyn UpdatePolicy>> {
    Ok(Box::new(HogwildBatchPolicy { batch: parse_batch("hogwild-batch", arg)? }))
}

/// Parse a `<policy>:<batch>` argument (`None` = the default 32).
pub(crate) fn parse_batch(name: &str, arg: Option<&str>) -> anyhow::Result<usize> {
    parse_positive_arg(&format!("{name}:<batch>"), arg, "")
}

/// Parse an optional positive-integer `:` argument (`None` = the default
/// 32). `what` labels the flag in errors; `zero_note` explains why zero is
/// rejected, if there is more to say.
fn parse_positive_arg(what: &str, arg: Option<&str>, zero_note: &str) -> anyhow::Result<usize> {
    let v: usize = match arg {
        None => 32,
        Some(a) => a.parse().map_err(|_| anyhow::anyhow!("{what} — bad integer '{a}'"))?,
    };
    anyhow::ensure!(v > 0, "{what} must be ≥ 1{zero_note}");
    Ok(v)
}

/// Parse the `averaged:<sync_every>` argument (`None` = the default 32).
pub(crate) fn parse_sync_every(arg: Option<&str>) -> anyhow::Result<usize> {
    parse_positive_arg("averaged:<sync_every>", arg, " (0 would deadlock the barrier rounds)")
}

fn no_arg(name: &str, arg: Option<&str>) -> anyhow::Result<()> {
    match arg {
        None => Ok(()),
        Some(a) => anyhow::bail!("policy '{name}' takes no ':' argument (got '{a}')"),
    }
}

fn registry() -> &'static Mutex<BTreeMap<String, Factory>> {
    static REGISTRY: OnceLock<Mutex<BTreeMap<String, Factory>>> = OnceLock::new();
    REGISTRY.get_or_init(|| {
        let mut map: BTreeMap<String, Factory> = BTreeMap::new();
        map.insert("sequential".to_string(), Arc::new(make_sequential));
        map.insert("chaos".to_string(), Arc::new(make_chaos));
        map.insert("hogwild".to_string(), Arc::new(make_hogwild));
        map.insert("delayed-rr".to_string(), Arc::new(make_delayed_rr));
        map.insert("averaged".to_string(), Arc::new(make_averaged));
        map.insert("minibatch".to_string(), Arc::new(make_minibatch));
        map.insert("hogwild-batch".to_string(), Arc::new(make_hogwild_batch));
        Mutex::new(map)
    })
}

/// Short aliases accepted by [`from_name`] (CLI back-compat).
fn canonical(head: &str) -> &str {
    match head {
        "seq" => "sequential",
        "delayed" => "delayed-rr",
        "avg" => "averaged",
        "mb" => "minibatch",
        other => other,
    }
}

/// Resolve a policy by name, e.g. `"chaos"` or `"averaged:64"`. Text after
/// the first `:` is handed to the policy's factory as its argument. The
/// returned policy has already passed [`UpdatePolicy::validate`].
pub fn from_name(text: &str) -> anyhow::Result<Box<dyn UpdatePolicy>> {
    let (head, arg) = match text.split_once(':') {
        Some((h, a)) => (h, Some(a)),
        None => (text, None),
    };
    let head = canonical(head);
    // Clone the factory out and drop the guard before calling it, so a
    // factory may itself consult the registry (delegating/wrapper
    // policies) and a panicking factory cannot poison the lock.
    let factory = {
        let reg = registry().lock().unwrap();
        reg.get(head)
            .cloned()
            .ok_or_else(|| {
                let known: Vec<&str> = reg.keys().map(|k| k.as_str()).collect();
                anyhow::anyhow!("unknown policy '{text}' (available: {})", known.join("|"))
            })?
    };
    let policy = factory(arg)?;
    policy.validate()?;
    Ok(policy)
}

/// The registered policy names (built-ins plus [`register`]ed customs),
/// sorted. Benches and examples iterate this so new policies are covered
/// automatically.
pub fn names() -> Vec<String> {
    registry().lock().unwrap().keys().cloned().collect()
}

/// Register a custom policy factory under `name`, making it selectable via
/// [`from_name`] (and therefore the CLI and every registry-driven bench)
/// without touching the trainer. The factory receives the text after the
/// first `:`, if any. Fails on duplicate or malformed names.
pub fn register<F>(name: &str, factory: F) -> anyhow::Result<()>
where
    F: Fn(Option<&str>) -> anyhow::Result<Box<dyn UpdatePolicy>> + Send + Sync + 'static,
{
    anyhow::ensure!(
        !name.is_empty() && !name.contains(':'),
        "policy name '{name}' must be non-empty and ':'-free"
    );
    // Alias heads are rewritten before lookup, so a policy registered
    // under one would be silently unreachable.
    anyhow::ensure!(
        canonical(name) == name,
        "policy name '{name}' is a reserved alias of '{}'",
        canonical(name)
    );
    let mut reg = registry().lock().unwrap();
    anyhow::ensure!(!reg.contains_key(name), "policy '{name}' is already registered");
    reg.insert(name.to_string(), Arc::new(factory));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchSpec;

    #[test]
    fn builtin_names_resolve() {
        for (text, want) in [
            ("sequential", "sequential"),
            ("seq", "sequential"),
            ("chaos", "chaos"),
            ("hogwild", "hogwild"),
            ("delayed-rr", "delayed-rr"),
            ("delayed", "delayed-rr"),
            ("averaged", "averaged"),
            ("avg:8", "averaged"),
            ("averaged:64", "averaged"),
            ("minibatch", "minibatch"),
            ("minibatch:32", "minibatch"),
            ("mb:8", "minibatch"),
            ("hogwild-batch:16", "hogwild-batch"),
        ] {
            assert_eq!(from_name(text).unwrap().name(), want, "{text}");
        }
    }

    #[test]
    fn minibatch_names_carry_batch_size() {
        assert_eq!(from_name("minibatch:8").unwrap().minibatch(), Some(8));
        assert_eq!(from_name("minibatch").unwrap().minibatch(), Some(32), "default B");
        assert_eq!(from_name("hogwild-batch:64").unwrap().minibatch(), Some(64));
        // Per-sample policies stay per-sample.
        assert_eq!(from_name("chaos").unwrap().minibatch(), None);
        assert_eq!(from_name("averaged:16").unwrap().minibatch(), None);
    }

    #[test]
    fn minibatch_arg_error_branches() {
        let e = from_name("minibatch:x").unwrap_err().to_string();
        assert!(e.contains("bad integer 'x'"), "{e}");
        let e = from_name("minibatch:0").unwrap_err().to_string();
        assert!(e.contains("must be ≥ 1"), "{e}");
        let e = from_name("hogwild-batch:0").unwrap_err().to_string();
        assert!(e.contains("must be ≥ 1"), "{e}");
        assert!(MinibatchPolicy { batch: 0 }.validate().is_err());
        assert!(HogwildBatchPolicy { batch: 0 }.validate().is_err());
    }

    #[test]
    fn minibatch_publish_scales_by_actual_chunk_size() {
        // The eta-scaling audit: a partial final chunk (n < configured B)
        // must divide by n, not B — and hogwild-batch must not divide at
        // all.
        let net = crate::nn::Network::new(ArchSpec::tiny());
        let params = net.init_params(3);
        let store = SharedParams::new(&params, &net.dims);
        let eta = 0.01f32;
        let ctx = EpochCtx {
            net: &net,
            store: &store,
            threads: 1,
            eta,
            epoch: 0,
            seed: 0,
            math: MathPolicy::Exact,
        };
        let layer = 1;
        let dims = &net.dims[layer];
        let grads = vec![1.0f32; dims.param_count()];
        let i = dims.params.start;

        let state = MinibatchPolicy::new(32).epoch_state(&ctx);
        let mut hooks = state.worker(&ctx, 0);
        let before = store.get(i);
        hooks.publish_batch(&ctx, layer, dims, &grads, 5);
        let after = store.get(i);
        assert!(
            (before - after - eta / 5.0).abs() < 1e-7,
            "minibatch must scale by η/n (n=5): {before} -> {after}"
        );

        let state = HogwildBatchPolicy::new(32).epoch_state(&ctx);
        let mut hooks = state.worker(&ctx, 0);
        let before = store.get(i);
        hooks.publish_batch(&ctx, layer, dims, &grads, 5);
        let after = store.get(i);
        assert!(
            (before - after - eta).abs() < 1e-7,
            "hogwild-batch publishes the raw sum: {before} -> {after}"
        );
        assert_eq!(store.publication_count(), 2, "one publication per layer per chunk");
    }

    #[test]
    fn from_name_error_branches() {
        // Unknown name lists the registry.
        let e = from_name("bogus").unwrap_err().to_string();
        assert!(e.contains("unknown policy 'bogus'") && e.contains("chaos"), "{e}");
        // Bad integer argument.
        let e = from_name("averaged:x").unwrap_err().to_string();
        assert!(e.contains("bad integer 'x'"), "{e}");
        // Zero sync_every would deadlock the barrier rounds.
        let e = from_name("averaged:0").unwrap_err().to_string();
        assert!(e.contains("deadlock"), "{e}");
        // Stray argument on an argument-free policy.
        let e = from_name("chaos:7").unwrap_err().to_string();
        assert!(e.contains("takes no ':' argument"), "{e}");
    }

    #[test]
    fn names_lists_builtins_sorted() {
        let names = names();
        for n in [
            "averaged",
            "chaos",
            "delayed-rr",
            "hogwild",
            "hogwild-batch",
            "minibatch",
            "sequential",
        ] {
            assert!(names.iter().any(|x| x == n), "missing {n}");
        }
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted);
    }

    #[test]
    fn register_rejects_duplicates_and_bad_names() {
        assert!(register("chaos", make_chaos).is_err());
        assert!(register("", make_chaos).is_err());
        assert!(register("a:b", make_chaos).is_err());
        // Alias heads are canonicalized before lookup, so registering one
        // would create an unreachable policy.
        for alias in ["seq", "avg", "delayed", "mb"] {
            let e = register(alias, make_chaos).unwrap_err().to_string();
            assert!(e.contains("reserved alias"), "{alias}: {e}");
        }
    }

    #[test]
    fn averaged_validate_rejects_zero() {
        assert!(AveragedPolicy { sync_every: 0 }.validate().is_err());
        assert!(AveragedPolicy::new(16).validate().is_ok());
    }

    #[test]
    fn sequential_flag_only_on_sequential() {
        assert!(SequentialPolicy.is_sequential());
        assert!(!ChaosPolicy.is_sequential());
        assert!(!HogwildPolicy.is_sequential());
        assert!(!DelayedRoundRobinPolicy.is_sequential());
        assert!(!AveragedPolicy::default().is_sequential());
        assert!(!MinibatchPolicy::default().is_sequential());
        assert!(!HogwildBatchPolicy::default().is_sequential());
    }

    #[test]
    fn builtin_policies_declare_their_contracts() {
        use SyncContract as C;
        for (name, want) in [
            ("sequential", C::Controlled),
            ("chaos", C::Controlled),
            ("hogwild", C::HogwildTolerated),
            // The turnstile serializes delayed-rr's unlocked publishes —
            // temporally disjoint writes satisfy the controlled contract.
            ("delayed-rr", C::Controlled),
            ("averaged", C::StoreAll),
            ("minibatch", C::Controlled),
            // Despite the name, hogwild-batch publishes under the
            // per-layer locks; only per-sample hogwild races.
            ("hogwild-batch", C::Controlled),
        ] {
            assert_eq!(from_name(name).unwrap().sync_contract(), want, "{name}");
        }
    }

    #[test]
    fn delayed_rr_state_finds_param_layers() {
        let net = crate::nn::Network::new(ArchSpec::tiny());
        let params = net.init_params(1);
        let store = SharedParams::new(&params, &net.dims);
        let ctx = EpochCtx {
            net: &net,
            store: &store,
            threads: 2,
            eta: 0.01,
            epoch: 0,
            seed: 0,
            math: MathPolicy::Exact,
        };
        let state = DelayedRoundRobinPolicy.epoch_state(&ctx);
        // Drive one worker through a fake sample: publish into every
        // parameterized layer, then end_sample must push it to the store.
        let mut hooks = state.worker(&ctx, 0);
        for (l, d) in net.dims.iter().enumerate() {
            if d.param_count() > 0 {
                let grads = vec![1.0f32; d.param_count()];
                hooks.publish(&ctx, l, d, &grads);
            }
        }
        let before = store.get(net.dims.last().unwrap().params.start);
        hooks.end_sample(&ctx);
        let after = store.get(net.dims.last().unwrap().params.start);
        assert!((before - after - 0.01).abs() < 1e-6, "w -= η·g must apply: {before} -> {after}");
        assert!(store.publication_count() > 0);
    }
}
