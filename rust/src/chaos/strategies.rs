//! The update strategies of §4.1: CHAOS itself plus the four published
//! schemes it draws from, implemented as selectable policies so the
//! `update_policies` bench can ablate them head-to-head:
//!
//! * **Sequential** — plain on-line SGD, one thread (the paper's baseline).
//! * **Strategy B, Averaged** — workers accumulate gradients over a chunk,
//!   a barrier synchronizes, the master averages and broadcasts
//!   (De Grazia et al.).
//! * **Strategy C, Delayed round-robin** — workers train on the shared
//!   weights but publish whole-sample updates one at a time in ticket
//!   (first-come round-robin) order (Zinkevich et al., "slow learners").
//! * **Strategy D, HogWild!** — instant, lock-free, racy updates
//!   (Recht et al.).
//! * **CHAOS** — controlled HogWild: local instant gradient accumulation,
//!   per-layer publication under a per-layer lock, arbitrary order of
//!   implicit synchronization.

use super::policy::{
    self, AveragedPolicy, ChaosPolicy, DelayedRoundRobinPolicy, HogwildPolicy, SequentialPolicy,
    UpdatePolicy,
};
use std::sync::{Condvar, Mutex};

/// The closed strategy enum of the original API, kept as a convenience for
/// naming the five paper schemes. The open, extensible surface is
/// [`UpdatePolicy`] (see [`super::policy`]); [`Strategy::into_policy`]
/// bridges the two.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// On-line SGD on one thread.
    Sequential,
    /// CHAOS: per-layer delayed publication under per-layer locks.
    Chaos,
    /// Strategy D: per-layer publication without locks.
    Hogwild,
    /// Strategy C: whole-sample publications serialized in ticket order.
    DelayedRoundRobin,
    /// Strategy B: barrier-synchronized averaged gradients every
    /// `sync_every` samples per worker.
    Averaged { sync_every: usize },
}

impl Strategy {
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Sequential => "sequential",
            Strategy::Chaos => "chaos",
            Strategy::Hogwild => "hogwild",
            Strategy::DelayedRoundRobin => "delayed-rr",
            Strategy::Averaged { .. } => "averaged",
        }
    }

    /// Parse from CLI text, e.g. `chaos`, `averaged:64`. Rejects a zero
    /// `sync_every` (it would deadlock the averaged barrier rounds) and
    /// stray `:` arguments on strategies that take none.
    pub fn parse(text: &str) -> anyhow::Result<Strategy> {
        let (head, arg) = match text.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (text, None),
        };
        let strategy = match head {
            "sequential" | "seq" => Strategy::Sequential,
            "chaos" => Strategy::Chaos,
            "hogwild" => Strategy::Hogwild,
            "delayed-rr" | "delayed" => Strategy::DelayedRoundRobin,
            "averaged" | "avg" => {
                return Ok(Strategy::Averaged { sync_every: policy::parse_sync_every(arg)? });
            }
            _ => anyhow::bail!(
                "unknown strategy '{text}' (sequential|chaos|hogwild|delayed-rr|averaged[:n])"
            ),
        };
        if let Some(a) = arg {
            anyhow::bail!("strategy '{head}' takes no ':' argument (got '{a}')");
        }
        Ok(strategy)
    }

    /// Bridge into the open policy API: the equivalent [`UpdatePolicy`].
    pub fn into_policy(self) -> Box<dyn UpdatePolicy> {
        match self {
            Strategy::Sequential => Box::new(SequentialPolicy),
            Strategy::Chaos => Box::new(ChaosPolicy),
            Strategy::Hogwild => Box::new(HogwildPolicy),
            Strategy::DelayedRoundRobin => Box::new(DelayedRoundRobinPolicy),
            // Hand-built zero values are clamped like the old worker did;
            // `parse` already rejects `averaged:0`.
            Strategy::Averaged { sync_every } => {
                Box::new(AveragedPolicy { sync_every: sync_every.max(1) })
            }
        }
    }
}

/// FIFO ticket turnstile used by the delayed round-robin strategy: each
/// publication takes a ticket and is admitted strictly in ticket order, so
/// updates are serialized and delayed — Zinkevich et al.'s round-robin
/// discipline with first-come ordering.
#[derive(Debug, Default)]
pub struct Turnstile {
    state: Mutex<TurnstileState>,
    cv: Condvar,
}

#[derive(Debug, Default)]
struct TurnstileState {
    next_ticket: u64,
    serving: u64,
}

impl Turnstile {
    pub fn new() -> Turnstile {
        Turnstile::default()
    }

    /// Block until it is this caller's turn; returns the ticket number.
    pub fn enter(&self) -> u64 {
        let mut st = self.state.lock().unwrap();
        let ticket = st.next_ticket;
        st.next_ticket += 1;
        while st.serving != ticket {
            st = self.cv.wait(st).unwrap();
        }
        ticket
    }

    /// Release the turnstile for the next ticket holder.
    pub fn leave(&self) {
        let mut st = self.state.lock().unwrap();
        st.serving += 1;
        drop(st);
        self.cv.notify_all();
    }

    /// Tickets served so far.
    pub fn served(&self) -> u64 {
        self.state.lock().unwrap().serving
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;

    #[test]
    fn parse_all() {
        assert_eq!(Strategy::parse("chaos").unwrap(), Strategy::Chaos);
        assert_eq!(Strategy::parse("seq").unwrap(), Strategy::Sequential);
        assert_eq!(Strategy::parse("hogwild").unwrap(), Strategy::Hogwild);
        assert_eq!(Strategy::parse("delayed-rr").unwrap(), Strategy::DelayedRoundRobin);
        assert_eq!(Strategy::parse("delayed").unwrap(), Strategy::DelayedRoundRobin);
        assert_eq!(
            Strategy::parse("averaged:16").unwrap(),
            Strategy::Averaged { sync_every: 16 }
        );
        assert_eq!(
            Strategy::parse("averaged").unwrap(),
            Strategy::Averaged { sync_every: 32 }
        );
        assert_eq!(Strategy::parse("avg:8").unwrap(), Strategy::Averaged { sync_every: 8 });
    }

    #[test]
    fn parse_error_branches() {
        // Unknown strategy name.
        let e = Strategy::parse("bogus").unwrap_err().to_string();
        assert!(e.contains("unknown strategy 'bogus'"), "{e}");
        // Non-numeric sync_every.
        let e = Strategy::parse("averaged:x").unwrap_err().to_string();
        assert!(e.contains("bad integer 'x'"), "{e}");
        // Zero sync_every would deadlock the averaged barrier rounds.
        let e = Strategy::parse("averaged:0").unwrap_err().to_string();
        assert!(e.contains("deadlock"), "{e}");
        // Stray argument on an argument-free strategy.
        for text in ["chaos:4", "sequential:1", "hogwild:x", "delayed-rr:9"] {
            let e = Strategy::parse(text).unwrap_err().to_string();
            assert!(e.contains("takes no ':' argument"), "{text}: {e}");
        }
    }

    #[test]
    fn into_policy_preserves_names_and_clamps_zero() {
        for (s, n) in [
            (Strategy::Sequential, "sequential"),
            (Strategy::Chaos, "chaos"),
            (Strategy::Hogwild, "hogwild"),
            (Strategy::DelayedRoundRobin, "delayed-rr"),
            (Strategy::Averaged { sync_every: 8 }, "averaged"),
        ] {
            assert_eq!(s.into_policy().name(), n);
        }
        // A hand-built zero clamps instead of deadlocking.
        assert!(Strategy::Averaged { sync_every: 0 }.into_policy().validate().is_ok());
    }

    #[test]
    fn names_stable() {
        for (s, n) in [
            (Strategy::Sequential, "sequential"),
            (Strategy::Chaos, "chaos"),
            (Strategy::Hogwild, "hogwild"),
            (Strategy::DelayedRoundRobin, "delayed-rr"),
            (Strategy::Averaged { sync_every: 8 }, "averaged"),
        ] {
            assert_eq!(s.name(), n);
        }
    }

    #[test]
    fn turnstile_serializes_in_ticket_order() {
        let ts = Arc::new(Turnstile::new());
        let order = Arc::new(Mutex::new(Vec::new()));
        let in_critical = Arc::new(AtomicU64::new(0));
        std::thread::scope(|s| {
            for _ in 0..6 {
                let ts = ts.clone();
                let order = order.clone();
                let in_critical = in_critical.clone();
                s.spawn(move || {
                    for _ in 0..50 {
                        let ticket = ts.enter();
                        // mutual exclusion check
                        assert_eq!(in_critical.fetch_add(1, Ordering::SeqCst), 0);
                        order.lock().unwrap().push(ticket);
                        in_critical.fetch_sub(1, Ordering::SeqCst);
                        ts.leave();
                    }
                });
            }
        });
        let order = order.lock().unwrap();
        assert_eq!(order.len(), 300);
        for (i, &t) in order.iter().enumerate() {
            assert_eq!(t, i as u64, "tickets must be served in order");
        }
        assert_eq!(ts.served(), 300);
    }
}
