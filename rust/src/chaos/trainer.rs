//! The epoch driver and its public face, the [`Trainer`] builder.
//!
//! Fig 3 of the paper: per epoch, a parallel **Training** phase (workers
//! pick images, forward/backward, publish updates according to the selected
//! [`UpdatePolicy`]), then parallel **Validation** and **Testing** phases
//! where every worker participates in forward-only evaluation.
//!
//! One driver serves every policy. Sequential policies (and any run with
//! `threads == 1`) use the in-place single-thread engine — plain `Vec<f32>`
//! weights, no shared store, no publications; parallel policies share one
//! [`SharedParams`] store and drive the policy's per-worker hooks. Epoch
//! records, evaluation order and learning-rate schedule are identical on
//! both paths, so a 1-thread run of any policy is bit-identical to the
//! sequential baseline from the same seed.
//!
//! ```ignore
//! let run = chaos::Trainer::new()
//!     .arch(ArchSpec::small())
//!     .epochs(5)
//!     .threads(4)
//!     .policy_name("averaged:64")?
//!     .observer(chaos::EarlyStop::at_test_error(0.05))
//!     .run(&train_set, &test_set)?;
//! ```

use super::observer::{EpochObserver, ParamsView, RunView, TrainControl};
use super::policy::{self, ChaosPolicy, EpochCtx, UpdatePolicy, WorkerHooks};
use super::reporter::{EpochRecord, EvalMetrics, RunResult};
use super::sampler::Sampler;
use super::shared::SharedParams;
use crate::config::{ArchSpec, TrainConfig};
use crate::data::Dataset;
use crate::nn::{Network, Scratch};
use crate::util::{LayerTimes, Stopwatch};
use std::sync::{mpsc, Arc, Mutex};

/// Builder for a training run — the public entry point of the CHAOS
/// coordinator.
///
/// Configure the network (`.arch(..)` / `.network(..)`), hyper-parameters
/// (`.config(..)` or the fluent setters), the update policy (`.policy(..)`
/// / `.policy_name(..)`) and any observers, then `.run(train, test)`.
/// Everything is validated up front; `.run` fails fast on an incomplete or
/// inconsistent build.
pub struct Trainer {
    net: Option<Network>,
    /// An architecture awaiting compilation — kept as a spec so an invalid
    /// one surfaces as an error from `validate`/`run`, never a panic.
    pending_arch: Option<ArchSpec>,
    cfg: TrainConfig,
    policy: Box<dyn UpdatePolicy>,
    observers: Vec<Box<dyn EpochObserver>>,
    store_export: Option<mpsc::Sender<Arc<SharedParams>>>,
}

impl Default for Trainer {
    fn default() -> Trainer {
        Trainer::new()
    }
}

impl Trainer {
    /// A trainer with the default config and the CHAOS policy; the
    /// architecture must still be set.
    pub fn new() -> Trainer {
        Trainer {
            net: None,
            pending_arch: None,
            cfg: TrainConfig::default(),
            policy: Box::new(ChaosPolicy),
            observers: Vec::new(),
            store_export: None,
        }
    }

    /// Train the given architecture (compiled through the layer-kind
    /// registry when the run starts; an invalid spec errors from
    /// [`Trainer::validate`]/[`Trainer::run`]).
    pub fn arch(mut self, arch: ArchSpec) -> Trainer {
        self.pending_arch = Some(arch);
        self.net = None;
        self
    }

    /// Train an already-compiled network.
    pub fn network(mut self, net: Network) -> Trainer {
        self.net = Some(net);
        self.pending_arch = None;
        self
    }

    /// Replace the whole hyper-parameter block.
    pub fn config(mut self, cfg: TrainConfig) -> Trainer {
        self.cfg = cfg;
        self
    }

    /// Number of epochs.
    pub fn epochs(mut self, epochs: usize) -> Trainer {
        self.cfg = self.cfg.with_epochs(epochs);
        self
    }

    /// Worker/thread count (1 = the sequential engine).
    pub fn threads(mut self, threads: usize) -> Trainer {
        self.cfg = self.cfg.with_threads(threads);
        self
    }

    /// Learning-rate schedule: η₀ and the per-epoch decay factor.
    pub fn eta(mut self, eta0: f64, eta_decay: f64) -> Trainer {
        self.cfg = self.cfg.with_eta(eta0, eta_decay);
        self
    }

    /// PRNG seed for weight init and the per-epoch image shuffle.
    pub fn seed(mut self, seed: u64) -> Trainer {
        self.cfg = self.cfg.with_seed(seed);
        self
    }

    /// Fraction of the training set evaluated as the validation split.
    pub fn validation_fraction(mut self, fraction: f64) -> Trainer {
        self.cfg = self.cfg.with_validation_fraction(fraction);
        self
    }

    /// Select the update policy.
    pub fn policy(mut self, policy: impl UpdatePolicy + 'static) -> Trainer {
        self.policy = Box::new(policy);
        self
    }

    /// Select an already-boxed update policy (e.g. from
    /// [`policy::from_name`] or [`Strategy::into_policy`]).
    pub fn policy_boxed(mut self, policy: Box<dyn UpdatePolicy>) -> Trainer {
        self.policy = policy;
        self
    }

    /// Select the update policy by registry name, e.g. `"averaged:64"`.
    pub fn policy_name(self, name: &str) -> anyhow::Result<Trainer> {
        Ok(self.policy_boxed(policy::from_name(name)?))
    }

    /// Attach an observer ([`EpochObserver`]); repeat to attach several.
    /// The run stops early if *any* observer returns
    /// [`TrainControl::Stop`].
    pub fn observer(mut self, observer: impl EpochObserver + 'static) -> Trainer {
        self.observers.push(Box::new(observer));
        self
    }

    /// Register a channel that receives the run's live [`SharedParams`]
    /// store as soon as a parallel run creates it — the live-serving
    /// hookup: hand the received `Arc` to
    /// [`crate::serve::Server::spawn_shared`] (or
    /// [`crate::runtime::SharedStoreEngine`]) and predictions track
    /// training mid-epoch. Sequential runs (`threads == 1` or a
    /// sequential policy) have no shared store; the sender is dropped
    /// unused, so the receiver observes a disconnect instead of blocking.
    pub fn export_store(mut self, tx: mpsc::Sender<Arc<SharedParams>>) -> Trainer {
        self.store_export = Some(tx);
        self
    }

    /// Check the build without running: architecture present, config sane,
    /// policy parameterization valid.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.net.is_some() || self.pending_arch.is_some(),
            "Trainer: no architecture set (use .arch(..) or .network(..))"
        );
        if let Some(arch) = &self.pending_arch {
            arch.validate()?;
        }
        self.cfg.validate()?;
        self.policy.validate()?;
        Ok(())
    }

    /// Validate, then train on `train_set` (validating on its first
    /// `validation_fraction` portion) and evaluate on `test_set` each
    /// epoch.
    pub fn run(mut self, train_set: &Dataset, test_set: &Dataset) -> anyhow::Result<RunResult> {
        self.validate()?;
        if let Some(arch) = self.pending_arch.take() {
            self.net = Some(Network::compile(arch)?);
        }
        let net = self.net.take().expect("validated above");
        Ok(run_epochs(
            &net,
            train_set,
            test_set,
            &self.cfg,
            self.policy.as_ref(),
            &mut self.observers,
            self.store_export.take(),
        ))
    }
}

/// Number of validation images given the config.
fn validation_len(cfg: &TrainConfig, train_set: &Dataset) -> usize {
    ((train_set.len() as f64) * cfg.validation_fraction).round() as usize
}

/// Engine state: where the weights live for the duration of the run.
enum Engine {
    /// Single-thread in-place SGD (sequential policies or `threads == 1`).
    Seq { params: Vec<f32>, scratch: Scratch },
    /// Shared atomic store driven by a policy's worker hooks. `Arc` so a
    /// live handle can be exported to concurrent readers (the serving
    /// tier) while the run owns it.
    Par { store: Arc<SharedParams> },
}

/// The unified epoch driver behind [`Trainer::run`].
fn run_epochs(
    net: &Network,
    train_set: &Dataset,
    test_set: &Dataset,
    cfg: &TrainConfig,
    policy: &dyn UpdatePolicy,
    observers: &mut [Box<dyn EpochObserver>],
    store_export: Option<mpsc::Sender<Arc<SharedParams>>>,
) -> RunResult {
    // Minibatch policies train through the batched engine even at one
    // thread — the per-sample sequential engine would silently change
    // their update semantics (η/n averaged chunks vs per-sample steps).
    let sequential =
        policy.is_sequential() || (cfg.threads == 1 && policy.minibatch().is_none());
    let threads = if sequential { 1 } else { cfg.threads };
    let policy_name = policy.name();
    let layer_times = LayerTimes::new();
    let val_len = validation_len(cfg, train_set);
    let mut epochs = Vec::with_capacity(cfg.epochs);
    let mut stopped_early = false;
    let run_sw = Stopwatch::start();

    let mut engine = if sequential {
        // Seed the scratch PRNG streams (dropout masks) from the run seed;
        // paper archs draw nothing from them, so this preserves the
        // 1-thread bit-identity guarantee.
        Engine::Seq { params: net.init_params(cfg.seed), scratch: net.scratch_seeded(cfg.seed) }
    } else {
        let init = net.init_params(cfg.seed);
        let store = Arc::new(SharedParams::new(&init, &net.dims));
        // Declare the policy's synchronization discipline to the store so
        // the race checker (`--features race-check`) can enforce it.
        store.set_sync_contract(policy.sync_contract());
        Engine::Par { store }
    };
    // Hand a live store handle to any registered exporter (the serving
    // tier's live-from-training hookup). On the sequential engine there is
    // no store: dropping the sender unread disconnects the receiver.
    if let Some(tx) = store_export {
        if let Engine::Par { store } = &engine {
            let _ = tx.send(store.clone());
        }
    }

    for epoch in 0..cfg.epochs {
        let eta = cfg.eta_at(epoch);
        let epoch_sw = Stopwatch::start();
        // Training phase: both engines consume the same shuffle.
        let sampler = Sampler::shuffled(train_set.len(), cfg.seed, epoch);
        let train_m = match &mut engine {
            Engine::Seq { params, scratch } => {
                let mut m = EvalMetrics::default();
                while let Some(idx) = sampler.next() {
                    let (loss, correct) = net.sgd_step(
                        params,
                        train_set.image(idx),
                        train_set.label(idx),
                        eta,
                        scratch,
                        Some(&layer_times),
                    );
                    m.images += 1;
                    m.loss += loss as f64;
                    m.errors += usize::from(!correct);
                }
                m
            }
            Engine::Par { store } => {
                let ctx = EpochCtx {
                    net,
                    store: &**store,
                    threads,
                    eta,
                    epoch,
                    seed: cfg.seed,
                    math: cfg.math,
                };
                train_phase_parallel(&ctx, train_set, &sampler, policy, &layer_times)
            }
        };
        let train_secs = epoch_sw.elapsed_secs();

        // Publication milestone: cumulative count at the end of this
        // epoch's training phase (parallel engines only).
        if let Engine::Par { store } = &engine {
            if !observers.is_empty() {
                let total = store.publication_count();
                let view = run_view(net, &policy_name, threads, cfg, &engine);
                for obs in observers.iter_mut() {
                    obs.on_publications(total, &view);
                }
            }
        }

        // Validation and testing phases.
        let eb = cfg.eval_batch;
        let (validation, test) = match &mut engine {
            Engine::Seq { params, .. } => (
                eval_seq(net, params, train_set, val_len, eb, Some(&layer_times)),
                eval_seq(net, params, test_set, test_set.len(), eb, Some(&layer_times)),
            ),
            Engine::Par { store } => (
                eval_parallel(net, &**store, train_set, val_len, threads, eb, &layer_times),
                eval_parallel(net, &**store, test_set, test_set.len(), threads, eb, &layer_times),
            ),
        };

        let record = EpochRecord {
            epoch,
            eta,
            train: train_m,
            validation,
            test,
            train_secs,
            total_secs: epoch_sw.elapsed_secs(),
        };
        if !observers.is_empty() {
            let view = run_view(net, &policy_name, threads, cfg, &engine);
            for obs in observers.iter_mut() {
                if obs.on_epoch_end(&record, &view) == TrainControl::Stop {
                    stopped_early = true;
                }
            }
        }
        epochs.push(record);
        if stopped_early {
            break;
        }
    }

    let (final_params, publications) = match engine {
        Engine::Seq { params, .. } => (params, 0),
        Engine::Par { store } => {
            // Under race-check, every parallel run doubles as a clean-run
            // test: any lock-discipline violation recorded during the run
            // fails loudly here instead of vanishing with the store.
            #[cfg(feature = "race-check")]
            {
                let defects = store.race_defects();
                let dropped = store.race_dropped_events();
                assert!(
                    defects.is_empty(),
                    "race-check: {} store defect(s) under the '{}' policy \
                     ({} contract, {} event(s) dropped past the log cap): {:?}",
                    defects.len(),
                    policy_name,
                    policy.sync_contract().as_str(),
                    dropped,
                    defects
                );
                // No silent caps: a clean run with a truncated event log
                // still says so (defect checking never consults the log,
                // but any replay of the event stream would be partial).
                if dropped > 0 {
                    eprintln!(
                        "race-check: event log capped, {dropped} event(s) dropped \
                         (defect detection unaffected)"
                    );
                }
            }
            let count = store.publication_count();
            (store.snapshot(), count)
        }
    };
    RunResult {
        arch: net.arch.name.clone(),
        strategy: policy_name,
        threads,
        epochs,
        final_params,
        layer_times,
        wall_secs: run_sw.elapsed_secs(),
        publications,
        stopped_early,
    }
}

fn run_view<'a>(
    net: &'a Network,
    policy_name: &'a str,
    threads: usize,
    cfg: &TrainConfig,
    engine: &'a Engine,
) -> RunView<'a> {
    let (params, publications) = match engine {
        Engine::Seq { params, .. } => (ParamsView::Seq(params.as_slice()), 0),
        Engine::Par { store } => (ParamsView::Par(&**store), store.publication_count()),
    };
    RunView::new(&net.arch.name, policy_name, threads, cfg.epochs, publications, params)
}

/// One epoch's parallel training phase: every worker picks work from the
/// shared pool, forward/backward-propagates against the shared store, and
/// routes gradients through the policy's hooks. Per-sample policies pick
/// single images; minibatch-capable policies ([`UpdatePolicy::minibatch`])
/// claim whole B-sample chunks and drive one `BatchPlan` per worker.
fn train_phase_parallel(
    ctx: &EpochCtx<'_>,
    data: &Dataset,
    sampler: &Sampler,
    policy: &dyn UpdatePolicy,
    timers: &LayerTimes,
) -> EvalMetrics {
    let state = policy.epoch_state(ctx);
    let minibatch = policy.minibatch();
    let metrics = Mutex::new(EvalMetrics::default());
    std::thread::scope(|s| {
        for worker_id in 0..ctx.threads {
            let state = &state;
            let metrics = &metrics;
            s.spawn(move || {
                let mut hooks = state.worker(ctx, worker_id);
                // Distinct per-worker PRNG streams (dropout masks), mixed
                // with the run seed so differently-seeded runs draw
                // independent masks — a thread-private concern, like the
                // rest of the scratch.
                let seed = ctx.seed ^ (((ctx.epoch as u64) << 32) | worker_id as u64);
                let local = match minibatch {
                    None => worker_per_sample(ctx, data, sampler, &mut *hooks, seed, timers),
                    Some(b) => worker_minibatch(ctx, data, sampler, &mut *hooks, seed, b, timers),
                };
                hooks.finish(ctx);
                merge_metrics(metrics, &local);
            });
        }
    });
    metrics.into_inner().unwrap()
}

/// Per-sample worker loop: pick one image at a time, publish per layer per
/// sample through [`WorkerHooks::publish`].
fn worker_per_sample(
    ctx: &EpochCtx<'_>,
    data: &Dataset,
    sampler: &Sampler,
    hooks: &mut dyn WorkerHooks,
    seed: u64,
    timers: &LayerTimes,
) -> EvalMetrics {
    let mut scratch = ctx.net.scratch_seeded(seed);
    scratch.train_mode = true;
    let mut local = EvalMetrics::default();
    while let Some(idx) = sampler.next() {
        let label = data.label(idx);
        ctx.net.forward(&ctx.store, data.image(idx), &mut scratch, Some(timers));
        local.images += 1;
        local.loss += ctx.net.loss(&scratch, label) as f64;
        local.errors += usize::from(ctx.net.prediction(&scratch) != label);
        ctx.net.backward(&ctx.store, label, &mut scratch, Some(timers), |l, d, g| {
            hooks.publish(ctx, l, d, g)
        });
        hooks.end_sample(ctx);
    }
    local
}

/// Minibatch worker loop: claim up-to-B-sample chunks from the sampler
/// (one atomic op per chunk), forward/backward each chunk through one
/// [`crate::nn::BatchPlan`] — every layer's parameter span reads once per
/// chunk — and hand the batch-summed per-layer gradients to
/// [`WorkerHooks::publish_batch`] with the *actual* chunk size (the
/// epoch's final chunk may be smaller than B).
fn worker_minibatch(
    ctx: &EpochCtx<'_>,
    data: &Dataset,
    sampler: &Sampler,
    hooks: &mut dyn WorkerHooks,
    seed: u64,
    batch: usize,
    timers: &LayerTimes,
) -> EvalMetrics {
    let plan =
        ctx.net.batch_plan(batch).expect("minibatch size validated ≥ 1").with_math(ctx.math);
    let mut scratch = plan.scratch_seeded(seed);
    scratch.train_mode = true;
    let classes = ctx.net.num_classes();
    let mut local = EvalMetrics::default();
    let mut idxs: Vec<usize> = Vec::with_capacity(batch);
    let mut labels: Vec<usize> = Vec::with_capacity(batch);
    loop {
        sampler.next_chunk(batch, &mut idxs);
        if idxs.is_empty() {
            break;
        }
        labels.clear();
        for (slot, &idx) in idxs.iter().enumerate() {
            plan.stage_image(&mut scratch, slot, data.image(idx));
            labels.push(data.label(idx));
        }
        let n = idxs.len();
        {
            let probs = plan.forward_staged(&ctx.store, n, &mut scratch, Some(timers));
            for (row, &label) in probs.chunks_exact(classes).zip(&labels) {
                tally_row(row, label, &mut local);
            }
        }
        plan.backward(&ctx.store, &labels, n, &mut scratch, Some(timers), |l, d, g| {
            hooks.publish_batch(ctx, l, d, g, n)
        });
    }
    local
}

// The evaluation batch size used to be a hardcoded `EVAL_BATCH: usize = 32`
// here; it is now the validated `TrainConfig::eval_batch` field (default 32)
// threaded through `eval_seq`/`eval_parallel`. Each worker forwards chunks
// of up to `eval_batch` images per scratch reuse, so every layer's parameter
// span is read once per chunk instead of once per image (`nn::BatchPlan`).
// The batched path is bit-identical to per-image forwards, so metrics are
// unchanged by the knob.

/// Accumulate metrics for one probability row — the single definition of
/// the evaluation metric, shared by the sequential and parallel phases.
fn tally_row(row: &[f32], label: usize, m: &mut EvalMetrics) {
    m.images += 1;
    m.loss += crate::nn::activation::cross_entropy(row, label) as f64;
    m.errors += usize::from(crate::tensor::argmax(row) != label);
}

fn eval_seq(
    net: &Network,
    params: &[f32],
    data: &Dataset,
    limit: usize,
    eval_batch: usize,
    timers: Option<&LayerTimes>,
) -> EvalMetrics {
    let n = limit.min(data.len());
    let mut m = EvalMetrics::default();
    if n == 0 {
        // Empty validation/test split: `batch_plan(eval_batch.min(0))`
        // would hit the zero-capacity rejection and panic mid-run.
        return m;
    }
    let plan = net.batch_plan(eval_batch.min(n)).expect("non-zero eval batch");
    let mut scratch = plan.scratch();
    let classes = net.num_classes();
    let mut idx = 0;
    while idx < n {
        let b = plan.cap().min(n - idx);
        for slot in 0..b {
            plan.stage_image(&mut scratch, slot, data.image(idx + slot));
        }
        let probs = plan.forward_staged(&params, b, &mut scratch, timers);
        for (s, row) in probs.chunks_exact(classes).enumerate() {
            tally_row(row, data.label(idx + s), &mut m);
        }
        idx += b;
    }
    m
}

fn merge_metrics(metrics: &Mutex<EvalMetrics>, local: &EvalMetrics) {
    let mut m = metrics.lock().unwrap();
    m.images += local.images;
    m.errors += local.errors;
    m.loss += local.loss;
}

/// Parallel forward-only evaluation (validation/testing phases — each
/// worker claims chunks of up to `eval_batch` images
/// ([`TrainConfig::eval_batch`]) from the shared pool and
/// forward-propagates them in one batched pass per chunk, so the shared
/// store is read once per layer per chunk; results are cumulated, paper
/// Fig 4b).
pub fn eval_parallel(
    net: &Network,
    store: &SharedParams,
    data: &Dataset,
    limit: usize,
    threads: usize,
    eval_batch: usize,
    timers: &LayerTimes,
) -> EvalMetrics {
    let n = limit.min(data.len());
    if n == 0 {
        // Empty validation/test split: nothing to evaluate. Returning
        // early also keeps `batch_plan` away from degenerate capacities
        // (mirrors eval_seq; regression-tested by
        // `empty_eval_sets_evaluate_to_empty_stats`).
        return EvalMetrics::default();
    }
    let sampler = Sampler::sequential(n);
    let metrics = Mutex::new(EvalMetrics::default());
    let classes = net.num_classes();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| {
                let plan = net.batch_plan(eval_batch).expect("non-zero eval batch");
                let mut scratch = plan.scratch();
                let mut local = EvalMetrics::default();
                let mut idxs: Vec<usize> = Vec::with_capacity(eval_batch);
                loop {
                    // next_chunk claims a contiguous run in one atomic op,
                    // but staging stays per slot (and tallying per index)
                    // so the loop is agnostic to the claim shape.
                    sampler.next_chunk(eval_batch, &mut idxs);
                    if idxs.is_empty() {
                        break;
                    }
                    for (slot, &idx) in idxs.iter().enumerate() {
                        plan.stage_image(&mut scratch, slot, data.image(idx));
                    }
                    let probs =
                        plan.forward_staged(&store, idxs.len(), &mut scratch, Some(timers));
                    for (row, &idx) in probs.chunks_exact(classes).zip(&idxs) {
                        tally_row(row, data.label(idx), &mut local);
                    }
                }
                merge_metrics(&metrics, &local);
            });
        }
    });
    metrics.into_inner().unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chaos::observer::observer_fn;
    use crate::chaos::policy::{AveragedPolicy, SequentialPolicy};
    use crate::chaos::EarlyStop;
    use crate::config::ArchSpec;
    use crate::data::{generate_synthetic, SynthConfig};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    /// 13×13 resized synthetic digits for the tiny architecture.
    fn tiny_data(n: usize, seed: u64) -> Dataset {
        generate_synthetic(n, seed, &SynthConfig::default()).resize(13)
    }

    fn tiny_cfg(threads: usize, epochs: usize) -> TrainConfig {
        TrainConfig {
            epochs,
            threads,
            // The tiny net wants a larger step than the paper networks.
            eta0: 0.05,
            eta_decay: 0.95,
            seed: 42,
            validation_fraction: 0.25,
            eval_batch: 32,
            ..TrainConfig::default()
        }
    }

    fn tiny_trainer(threads: usize, epochs: usize) -> Trainer {
        Trainer::new().arch(ArchSpec::tiny()).config(tiny_cfg(threads, epochs))
    }

    #[test]
    fn sequential_training_reduces_loss_and_errors() {
        let trn = tiny_data(300, 1);
        let tst = tiny_data(100, 2);
        let r = tiny_trainer(1, 6).policy(SequentialPolicy).run(&trn, &tst).unwrap();
        let first = &r.epochs[0];
        let last = r.final_epoch();
        assert!(last.train.loss < first.train.loss, "training loss must fall");
        assert!(
            last.test.error_rate() < 0.5,
            "test error rate {} should beat chance by a wide margin",
            last.test.error_rate()
        );
        assert_eq!(first.train.images, 300);
        assert_eq!(first.validation.images, 75);
        assert_eq!(first.test.images, 100);
        assert_eq!(r.publications, 0);
        assert!(!r.stopped_early);
    }

    #[test]
    fn chaos_parallel_matches_sequential_accuracy() {
        // The paper's Result 4: parallel CHAOS training reaches accuracy
        // comparable to sequential (Table 7's deviations are tens of
        // images out of 60k). Here: same data/seed, small tolerance.
        let trn = tiny_data(400, 3);
        let tst = tiny_data(150, 4);
        let seq = tiny_trainer(1, 3).policy(SequentialPolicy).run(&trn, &tst).unwrap();
        let par = tiny_trainer(4, 3).policy(ChaosPolicy).run(&trn, &tst).unwrap();
        let seq_err = seq.final_epoch().test.error_rate();
        let par_err = par.final_epoch().test.error_rate();
        assert!(
            (seq_err - par_err).abs() < 0.15,
            "parity violated: sequential {seq_err} vs chaos {par_err}"
        );
        assert!(par.publications > 0, "chaos must publish through the store");
        assert_eq!(par.threads, 4);
    }

    #[test]
    fn all_parallel_policies_run_and_learn() {
        let trn = tiny_data(240, 5);
        let tst = tiny_data(80, 6);
        for name in ["chaos", "hogwild", "delayed-rr", "averaged:16", "hogwild-batch:8"] {
            let r = tiny_trainer(3, 3).policy_name(name).unwrap().run(&trn, &tst).unwrap();
            let first = &r.epochs[0];
            let last = r.final_epoch();
            assert_eq!(first.train.images, 240, "{name}: all images trained");
            assert!(
                last.train.loss < first.train.loss,
                "{name}: loss should fall ({} -> {})",
                first.train.loss,
                last.train.loss
            );
            assert!(last.test.error_rate() < 0.7, "{name}: learns something");
        }
    }

    #[test]
    fn minibatch_policies_train_end_to_end() {
        // Averaged chunks take η-scaled mean-gradient steps, so the
        // minibatch run gets a learning rate sized for averaged updates.
        let trn = tiny_data(240, 5);
        let tst = tiny_data(80, 6);
        for threads in [1usize, 3] {
            let r = tiny_trainer(threads, 5)
                .eta(0.2, 0.95)
                .policy_name("minibatch:4")
                .unwrap()
                .run(&trn, &tst)
                .unwrap();
            let first = &r.epochs[0];
            let last = r.final_epoch();
            assert_eq!(first.train.images, 240, "{threads} threads: every image trained");
            assert!(
                last.train.loss < first.train.loss,
                "{threads} threads: loss should fall ({} -> {})",
                first.train.loss,
                last.train.loss
            );
            assert!(last.test.error_rate() < 0.7, "{threads} threads: learns something");
            assert!(
                r.publications > 0,
                "{threads} threads: minibatch publishes through the store even at one thread"
            );
        }
    }

    #[test]
    fn minibatch_partial_chunk_matches_per_sample_reference() {
        // End-to-end eta-scaling audit on a dataset whose size is NOT a
        // multiple of B: the final chunk of each epoch has n = 50 % 16 = 2
        // samples and must be averaged by 2, not 16. The reference
        // replays the exact chunk schedule with per-sample kernels
        // (bit-identical to the batched path) and applies
        // w += −(η/n)·Σg per chunk.
        let n_images = 50usize;
        let batch = 16usize;
        let epochs = 2usize;
        let trn = tiny_data(n_images, 61);
        let tst = tiny_data(10, 62);
        let cfg = tiny_cfg(1, epochs);
        let r = Trainer::new()
            .arch(ArchSpec::tiny())
            .config(cfg.clone())
            .policy_name(&format!("minibatch:{batch}"))
            .unwrap()
            .run(&trn, &tst)
            .unwrap();

        let net = Network::new(ArchSpec::tiny());
        let mut params = net.init_params(cfg.seed);
        let mut scratch = net.scratch();
        scratch.train_mode = true;
        for epoch in 0..epochs {
            let eta = cfg.eta_at(epoch);
            let sampler = Sampler::shuffled(n_images, cfg.seed, epoch);
            let mut chunk = Vec::new();
            loop {
                sampler.next_chunk(batch, &mut chunk);
                if chunk.is_empty() {
                    break;
                }
                let mut acc = vec![0.0f32; net.total_params];
                for &idx in &chunk {
                    net.forward(&params.as_slice(), trn.image(idx), &mut scratch, None);
                    net.backward(&params.as_slice(), trn.label(idx), &mut scratch, None, |_, d, g| {
                        for (a, &v) in acc[d.params.clone()].iter_mut().zip(g) {
                            *a += v;
                        }
                    });
                }
                let scale = -(eta / chunk.len() as f32);
                for d in &net.dims {
                    if d.param_count() == 0 {
                        continue;
                    }
                    for (w, &g) in
                        params[d.params.clone()].iter_mut().zip(&acc[d.params.clone()])
                    {
                        *w += scale * g;
                    }
                }
            }
        }
        assert_eq!(
            r.final_params, params,
            "trainer minibatch weights must match the per-sample reference bitwise"
        );
    }

    #[test]
    fn empty_eval_sets_evaluate_to_empty_stats() {
        // Regression: an empty validation split (validation_fraction 0) or
        // an empty test set used to panic mid-run in the batched eval
        // phases (`batch_plan(eval_batch.min(0))` rejects zero capacity).
        let trn = tiny_data(40, 71);
        let empty = tiny_data(0, 72);
        // Sequential engine.
        let r = tiny_trainer(1, 1)
            .policy(SequentialPolicy)
            .validation_fraction(0.0)
            .run(&trn, &empty)
            .unwrap();
        assert_eq!(r.final_epoch().validation.images, 0);
        assert_eq!(r.final_epoch().test.images, 0);
        assert_eq!(r.final_epoch().test.errors, 0);
        // Parallel engine.
        let r = tiny_trainer(3, 1)
            .policy(ChaosPolicy)
            .validation_fraction(0.0)
            .run(&trn, &empty)
            .unwrap();
        assert_eq!(r.final_epoch().validation.images, 0);
        assert_eq!(r.final_epoch().test.images, 0);
        // Direct phase-level checks.
        let net = Network::new(ArchSpec::tiny());
        let params = net.init_params(1);
        assert_eq!(eval_seq(&net, &params, &empty, empty.len(), 32, None).images, 0);
        let store = SharedParams::new(&params, &net.dims);
        let timers = LayerTimes::new();
        assert_eq!(eval_parallel(&net, &store, &empty, empty.len(), 2, 32, &timers).images, 0);
        assert_eq!(eval_parallel(&net, &store, &trn, 0, 2, 32, &timers).images, 0);
    }

    #[test]
    fn thread_one_falls_back_to_sequential_engine() {
        let trn = tiny_data(60, 7);
        let tst = tiny_data(30, 8);
        let r = tiny_trainer(1, 1).policy(ChaosPolicy).run(&trn, &tst).unwrap();
        assert_eq!(r.threads, 1);
        assert_eq!(r.publications, 0, "sequential path bypasses the store");
    }

    #[test]
    fn every_policy_is_bit_identical_to_sequential_at_one_thread() {
        // The 1-thread run of any policy routes through the in-place
        // sequential engine, so metrics and final weights must be
        // bit-identical across policies from the same seed.
        let trn = tiny_data(120, 11);
        let tst = tiny_data(40, 12);
        let base = tiny_trainer(1, 2).policy(SequentialPolicy).run(&trn, &tst).unwrap();
        for name in ["chaos", "hogwild", "delayed-rr", "averaged:16"] {
            let r = tiny_trainer(1, 2).policy_name(name).unwrap().run(&trn, &tst).unwrap();
            assert_eq!(r.threads, 1);
            assert_eq!(r.final_params, base.final_params, "{name}: weights diverged");
            for (a, b) in r.epochs.iter().zip(&base.epochs) {
                assert_eq!(a.train, b.train, "{name}");
                assert_eq!(a.validation, b.validation, "{name}");
                assert_eq!(a.test, b.test, "{name}");
            }
        }
    }

    #[test]
    fn builder_validation_errors() {
        let d = tiny_data(10, 1);
        // No architecture.
        let e = Trainer::new().run(&d, &d).unwrap_err().to_string();
        assert!(e.contains("no architecture"), "{e}");
        // Bad config fields.
        let e = tiny_trainer(0, 1).validate().unwrap_err().to_string();
        assert!(e.contains("threads"), "{e}");
        let e = tiny_trainer(1, 0).validate().unwrap_err().to_string();
        assert!(e.contains("epochs"), "{e}");
        assert!(tiny_trainer(1, 1).eta(-1.0, 0.9).validate().is_err());
        assert!(tiny_trainer(1, 1).validation_fraction(2.0).validate().is_err());
        // Invalid policy parameterization caught at build time.
        assert!(tiny_trainer(2, 1).policy(AveragedPolicy { sync_every: 0 }).validate().is_err());
        // Registry errors surface through the builder too.
        assert!(tiny_trainer(2, 1).policy_name("averaged:0").is_err());
        // A valid build passes.
        tiny_trainer(2, 1).validate().unwrap();
    }

    #[test]
    fn observers_are_invoked_and_can_stop_the_run() {
        let trn = tiny_data(80, 21);
        let tst = tiny_data(30, 22);
        let epoch_calls = Arc::new(AtomicUsize::new(0));
        let c = epoch_calls.clone();
        let r = tiny_trainer(1, 3)
            .policy(SequentialPolicy)
            .observer(observer_fn(move |_rec, _run| {
                c.fetch_add(1, Ordering::Relaxed);
                TrainControl::Continue
            }))
            .run(&trn, &tst)
            .unwrap();
        assert_eq!(epoch_calls.load(Ordering::Relaxed), 3);
        assert_eq!(r.epochs.len(), 3);
        assert!(!r.stopped_early);

        // EarlyStop with an always-met target ends the run after epoch 1.
        let r = tiny_trainer(1, 5)
            .policy(SequentialPolicy)
            .observer(EarlyStop::at_test_error(1.0))
            .run(&trn, &tst)
            .unwrap();
        assert_eq!(r.epochs.len(), 1);
        assert!(r.stopped_early);
    }

    #[test]
    fn publication_milestones_fire_on_parallel_runs_only() {
        let trn = tiny_data(60, 31);
        let tst = tiny_data(20, 32);

        struct PubCounter(Arc<AtomicUsize>, Arc<AtomicUsize>);
        impl EpochObserver for PubCounter {
            fn on_publications(&mut self, total: u64, _run: &RunView<'_>) {
                self.0.fetch_add(1, Ordering::Relaxed);
                self.1.store(total as usize, Ordering::Relaxed);
            }
        }

        let calls = Arc::new(AtomicUsize::new(0));
        let last_total = Arc::new(AtomicUsize::new(0));
        let r = tiny_trainer(3, 2)
            .policy(ChaosPolicy)
            .observer(PubCounter(calls.clone(), last_total.clone()))
            .run(&trn, &tst)
            .unwrap();
        assert_eq!(calls.load(Ordering::Relaxed), 2, "one milestone per epoch");
        assert_eq!(last_total.load(Ordering::Relaxed) as u64, r.publications);

        let calls_seq = Arc::new(AtomicUsize::new(0));
        tiny_trainer(1, 2)
            .policy(SequentialPolicy)
            .observer(PubCounter(calls_seq.clone(), Arc::new(AtomicUsize::new(0))))
            .run(&trn, &tst)
            .unwrap();
        assert_eq!(calls_seq.load(Ordering::Relaxed), 0, "sequential engine never publishes");
    }

    #[test]
    fn export_store_delivers_live_store_on_parallel_runs_only() {
        let trn = tiny_data(80, 51);
        let tst = tiny_data(30, 52);
        // Parallel run: the exported handle IS the run's store — after the
        // run it holds the final weights and the publication count.
        let (tx, rx) = mpsc::channel();
        let r = tiny_trainer(3, 1).policy(ChaosPolicy).export_store(tx).run(&trn, &tst).unwrap();
        let store = rx.recv().expect("parallel run must export its store");
        assert_eq!(store.snapshot(), r.final_params);
        assert_eq!(store.publication_count(), r.publications);
        // Sequential run: no store exists; the receiver sees a disconnect
        // rather than blocking forever.
        let (tx, rx) = mpsc::channel();
        tiny_trainer(1, 1).policy(SequentialPolicy).export_store(tx).run(&trn, &tst).unwrap();
        assert!(rx.recv().is_err(), "sequential engine has no store to export");
    }

    #[test]
    fn strategy_into_policy_runs_through_builder() {
        // `Strategy` (the paper's closed strategy enum) remains a thin
        // front-end over the policy registry now that the deprecated
        // `chaos::train` shim is gone.
        let trn = tiny_data(90, 41);
        let tst = tiny_data(30, 42);
        let via_strategy = tiny_trainer(1, 2)
            .policy_boxed(crate::chaos::Strategy::Sequential.into_policy())
            .run(&trn, &tst)
            .unwrap();
        let direct = tiny_trainer(1, 2).policy(SequentialPolicy).run(&trn, &tst).unwrap();
        assert_eq!(via_strategy.final_params, direct.final_params);
        assert_eq!(via_strategy.strategy, direct.strategy);
    }

    #[test]
    fn eval_parallel_counts_every_image_once() {
        let net = Network::new(ArchSpec::tiny());
        let data = tiny_data(123, 9);
        let params = net.init_params(1);
        let store = SharedParams::new(&params, &net.dims);
        let timers = LayerTimes::new();
        let m = eval_parallel(&net, &store, &data, data.len(), 4, 32, &timers);
        assert_eq!(m.images, 123);
        assert!(m.loss > 0.0);
        // limit smaller than the dataset
        let m2 = eval_parallel(&net, &store, &data, 50, 4, 32, &timers);
        assert_eq!(m2.images, 50);
    }

    #[test]
    fn parallel_eval_matches_sequential_eval() {
        let net = Network::new(ArchSpec::tiny());
        let data = tiny_data(100, 10);
        let params = net.init_params(2);
        let store = SharedParams::new(&params, &net.dims);
        let timers = LayerTimes::new();
        let par = eval_parallel(&net, &store, &data, data.len(), 4, 16, &timers);
        let seq = eval_seq(&net, &params, &data, data.len(), 32, None);
        assert_eq!(par.errors, seq.errors, "same weights ⇒ same predictions");
        assert!((par.loss - seq.loss).abs() < 1e-3 * seq.loss.abs().max(1.0));
    }
}
