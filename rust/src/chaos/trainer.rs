//! The epoch driver — Fig 3 of the paper: per epoch, a parallel **Training**
//! phase (workers pick images, forward/backward, publish updates according
//! to the selected strategy), then parallel **Validation** and **Testing**
//! phases where every worker participates in forward-only evaluation.

use super::reporter::{EpochRecord, EvalMetrics, RunResult};
use super::sampler::Sampler;
use super::shared::SharedParams;
use super::strategies::{Strategy, Turnstile};
use crate::config::TrainConfig;
use crate::data::Dataset;
use crate::nn::{Network, Scratch};
use crate::util::{LayerTimes, Stopwatch};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Barrier, Mutex};

/// Train `net` on `train_set` (validating on its first
/// `cfg.validation_fraction` portion) and evaluate on `test_set` each
/// epoch, using the given update strategy. This is the public entry point
/// of the CHAOS coordinator.
pub fn train(
    net: &Network,
    train_set: &Dataset,
    test_set: &Dataset,
    cfg: &TrainConfig,
    strategy: Strategy,
) -> anyhow::Result<RunResult> {
    cfg.validate()?;
    if matches!(strategy, Strategy::Sequential) || cfg.threads == 1 {
        return Ok(train_sequential(net, train_set, test_set, cfg, strategy));
    }
    Ok(train_parallel(net, train_set, test_set, cfg, strategy))
}

/// Number of validation images given the config.
fn validation_len(cfg: &TrainConfig, train_set: &Dataset) -> usize {
    ((train_set.len() as f64) * cfg.validation_fraction).round() as usize
}

// ---------------------------------------------------------------------------
// Sequential baseline
// ---------------------------------------------------------------------------

fn train_sequential(
    net: &Network,
    train_set: &Dataset,
    test_set: &Dataset,
    cfg: &TrainConfig,
    strategy: Strategy,
) -> RunResult {
    let mut params = net.init_params(cfg.seed);
    let mut scratch = net.scratch();
    let layer_times = LayerTimes::new();
    let val_len = validation_len(cfg, train_set);
    let mut epochs = Vec::with_capacity(cfg.epochs);
    let run_sw = Stopwatch::start();

    for epoch in 0..cfg.epochs {
        let eta = cfg.eta_at(epoch);
        let epoch_sw = Stopwatch::start();
        // Training phase: same shuffle the parallel runs use.
        let sampler = Sampler::shuffled(train_set.len(), cfg.seed, epoch);
        let mut train_m = EvalMetrics::default();
        while let Some(idx) = sampler.next() {
            let (loss, correct) = net.sgd_step(
                &mut params,
                train_set.image(idx),
                train_set.label(idx),
                eta,
                &mut scratch,
                Some(&layer_times),
            );
            train_m.images += 1;
            train_m.loss += loss as f64;
            train_m.errors += usize::from(!correct);
        }
        let train_secs = epoch_sw.elapsed_secs();

        let validation =
            eval_seq(net, &params, train_set, val_len, &mut scratch, Some(&layer_times));
        let test =
            eval_seq(net, &params, test_set, test_set.len(), &mut scratch, Some(&layer_times));
        epochs.push(EpochRecord {
            epoch,
            eta,
            train: train_m,
            validation,
            test,
            train_secs,
            total_secs: epoch_sw.elapsed_secs(),
        });
    }

    RunResult {
        arch: net.arch.name.clone(),
        strategy: strategy.name().to_string(),
        threads: 1,
        epochs,
        final_params: params,
        layer_times,
        wall_secs: run_sw.elapsed_secs(),
        publications: 0,
    }
}

fn eval_seq(
    net: &Network,
    params: &Vec<f32>,
    data: &Dataset,
    limit: usize,
    scratch: &mut Scratch,
    timers: Option<&LayerTimes>,
) -> EvalMetrics {
    let mut m = EvalMetrics::default();
    for idx in 0..limit.min(data.len()) {
        net.forward(params, data.image(idx), scratch, timers);
        m.images += 1;
        m.loss += net.loss(scratch, data.label(idx)) as f64;
        m.errors += usize::from(net.prediction(scratch) != data.label(idx));
    }
    m
}

// ---------------------------------------------------------------------------
// Parallel strategies
// ---------------------------------------------------------------------------

fn train_parallel(
    net: &Network,
    train_set: &Dataset,
    test_set: &Dataset,
    cfg: &TrainConfig,
    strategy: Strategy,
) -> RunResult {
    let init = net.init_params(cfg.seed);
    let store = SharedParams::new(&init, &net.dims);
    let layer_times = LayerTimes::new();
    let val_len = validation_len(cfg, train_set);
    let threads = cfg.threads;
    let mut epochs = Vec::with_capacity(cfg.epochs);
    let run_sw = Stopwatch::start();

    for epoch in 0..cfg.epochs {
        let eta = cfg.eta_at(epoch);
        let epoch_sw = Stopwatch::start();
        let sampler = Sampler::shuffled(train_set.len(), cfg.seed, epoch);
        let train_metrics = Mutex::new(EvalMetrics::default());

        match strategy {
            Strategy::Chaos | Strategy::Hogwild => {
                let locked = matches!(strategy, Strategy::Chaos);
                std::thread::scope(|s| {
                    for _ in 0..threads {
                        s.spawn(|| {
                            worker_chaos(
                                net,
                                &store,
                                train_set,
                                &sampler,
                                eta,
                                locked,
                                &layer_times,
                                &train_metrics,
                            )
                        });
                    }
                });
            }
            Strategy::DelayedRoundRobin => {
                let turnstile = Turnstile::new();
                std::thread::scope(|s| {
                    for _ in 0..threads {
                        s.spawn(|| {
                            worker_delayed_rr(
                                net,
                                &store,
                                train_set,
                                &sampler,
                                eta,
                                &turnstile,
                                &layer_times,
                                &train_metrics,
                            )
                        });
                    }
                });
            }
            Strategy::Averaged { sync_every } => {
                let accum = Mutex::new(vec![0.0f32; net.total_params]);
                let round_samples = AtomicUsize::new(0);
                let barrier = Barrier::new(threads);
                let done = AtomicBool::new(false);
                std::thread::scope(|s| {
                    for _ in 0..threads {
                        s.spawn(|| {
                            worker_averaged(
                                net,
                                &store,
                                train_set,
                                &sampler,
                                eta,
                                sync_every.max(1),
                                &accum,
                                &round_samples,
                                &barrier,
                                &done,
                                &layer_times,
                                &train_metrics,
                            )
                        });
                    }
                });
            }
            Strategy::Sequential => unreachable!("handled by train()"),
        }
        let train_secs = epoch_sw.elapsed_secs();

        let validation =
            eval_parallel(net, &store, train_set, val_len, threads, &layer_times);
        let test =
            eval_parallel(net, &store, test_set, test_set.len(), threads, &layer_times);
        epochs.push(EpochRecord {
            epoch,
            eta,
            train: train_metrics.into_inner().unwrap(),
            validation,
            test,
            train_secs,
            total_secs: epoch_sw.elapsed_secs(),
        });
    }

    RunResult {
        arch: net.arch.name.clone(),
        strategy: strategy.name().to_string(),
        threads,
        epochs,
        final_params: store.snapshot(),
        layer_times,
        wall_secs: run_sw.elapsed_secs(),
        publications: store.publication_count(),
    }
}

/// CHAOS / HogWild! worker: forward + backward on the shared weights,
/// publishing each layer's scaled gradients as soon as they are complete
/// (per-layer lock for CHAOS, none for HogWild!).
#[allow(clippy::too_many_arguments)]
fn worker_chaos(
    net: &Network,
    store: &SharedParams,
    data: &Dataset,
    sampler: &Sampler,
    eta: f32,
    locked: bool,
    timers: &LayerTimes,
    metrics: &Mutex<EvalMetrics>,
) {
    let mut scratch = net.scratch();
    let mut local = EvalMetrics::default();
    while let Some(idx) = sampler.next() {
        let label = data.label(idx);
        net.forward(&store, data.image(idx), &mut scratch, Some(timers));
        local.images += 1;
        local.loss += net.loss(&scratch, label) as f64;
        local.errors += usize::from(net.prediction(&scratch) != label);
        net.backward(&store, label, &mut scratch, Some(timers), |l, d, grads| {
            if locked {
                store.publish_scaled(l, d.params.clone(), grads, -eta);
            } else {
                store.publish_scaled_unlocked(d.params.clone(), grads, -eta);
            }
        });
    }
    merge_metrics(metrics, &local);
}

/// Strategy C worker: gradients of the whole sample are gathered locally,
/// then published in strict ticket order through the turnstile.
#[allow(clippy::too_many_arguments)]
fn worker_delayed_rr(
    net: &Network,
    store: &SharedParams,
    data: &Dataset,
    sampler: &Sampler,
    eta: f32,
    turnstile: &Turnstile,
    timers: &LayerTimes,
    metrics: &Mutex<EvalMetrics>,
) {
    let mut scratch = net.scratch();
    let mut local = EvalMetrics::default();
    let mut grads = vec![0.0f32; net.total_params];
    let param_layers: Vec<usize> = net
        .dims
        .iter()
        .enumerate()
        .filter(|(_, d)| d.param_count() > 0)
        .map(|(i, _)| i)
        .collect();
    while let Some(idx) = sampler.next() {
        let label = data.label(idx);
        net.forward(&store, data.image(idx), &mut scratch, Some(timers));
        local.images += 1;
        local.loss += net.loss(&scratch, label) as f64;
        local.errors += usize::from(net.prediction(&scratch) != label);
        net.backward(&store, label, &mut scratch, Some(timers), |_, d, g| {
            grads[d.params.clone()].copy_from_slice(g);
        });
        turnstile.enter();
        for &l in &param_layers {
            let range = net.dims[l].params.clone();
            // The turnstile already serializes all publishers.
            store.publish_scaled_unlocked(range.clone(), &grads[range], -eta);
        }
        turnstile.leave();
    }
    merge_metrics(metrics, &local);
}

/// Strategy B worker: accumulate gradients over up to `sync_every` samples,
/// merge into the round accumulator, barrier, leader applies the averaged
/// update, barrier, repeat until the sampler drains.
#[allow(clippy::too_many_arguments)]
fn worker_averaged(
    net: &Network,
    store: &SharedParams,
    data: &Dataset,
    sampler: &Sampler,
    eta: f32,
    sync_every: usize,
    accum: &Mutex<Vec<f32>>,
    round_samples: &AtomicUsize,
    barrier: &Barrier,
    done: &AtomicBool,
    timers: &LayerTimes,
    metrics: &Mutex<EvalMetrics>,
) {
    let mut scratch = net.scratch();
    let mut local_metrics = EvalMetrics::default();
    let mut local = vec![0.0f32; net.total_params];
    loop {
        local.fill(0.0);
        let mut n_local = 0usize;
        for _ in 0..sync_every {
            let Some(idx) = sampler.next() else { break };
            let label = data.label(idx);
            net.forward(&store, data.image(idx), &mut scratch, Some(timers));
            local_metrics.images += 1;
            local_metrics.loss += net.loss(&scratch, label) as f64;
            local_metrics.errors += usize::from(net.prediction(&scratch) != label);
            net.backward(&store, label, &mut scratch, Some(timers), |_, d, g| {
                for (a, &gv) in local[d.params.clone()].iter_mut().zip(g) {
                    *a += gv;
                }
            });
            n_local += 1;
        }
        if n_local > 0 {
            let mut acc = accum.lock().unwrap();
            for (a, &l) in acc.iter_mut().zip(&local) {
                *a += l;
            }
            round_samples.fetch_add(n_local, Ordering::Relaxed);
        }
        let wait = barrier.wait();
        if wait.is_leader() {
            let n = round_samples.swap(0, Ordering::Relaxed);
            if n == 0 {
                done.store(true, Ordering::Release);
            } else {
                let mut acc = accum.lock().unwrap();
                // Averaged master step (strategy B): each learner's
                // contribution is the gradient *sum* over its batch; the
                // master averages across learners and applies one step:
                // w -= η · (Σ_batches g) / workers. Note n counts samples;
                // workers ≈ ceil(n / sync_every).
                let workers = n.div_ceil(sync_every).max(1);
                let mut new_params = store.snapshot();
                let scale = eta / workers as f32;
                for (w, g) in new_params.iter_mut().zip(acc.iter()) {
                    *w -= scale * g;
                }
                store.store_all(&new_params);
                acc.fill(0.0);
            }
        }
        barrier.wait();
        if done.load(Ordering::Acquire) {
            break;
        }
    }
    merge_metrics(metrics, &local_metrics);
}

fn merge_metrics(metrics: &Mutex<EvalMetrics>, local: &EvalMetrics) {
    let mut m = metrics.lock().unwrap();
    m.images += local.images;
    m.errors += local.errors;
    m.loss += local.loss;
}

/// Parallel forward-only evaluation (validation/testing phases — each
/// worker picks images and forward-propagates, results are cumulated,
/// paper Fig 4b).
pub fn eval_parallel(
    net: &Network,
    store: &SharedParams,
    data: &Dataset,
    limit: usize,
    threads: usize,
    timers: &LayerTimes,
) -> EvalMetrics {
    let sampler = Sampler::sequential(limit.min(data.len()));
    let metrics = Mutex::new(EvalMetrics::default());
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| {
                let mut scratch = net.scratch();
                let mut local = EvalMetrics::default();
                while let Some(idx) = sampler.next() {
                    let label = data.label(idx);
                    net.forward(&store, data.image(idx), &mut scratch, Some(timers));
                    local.images += 1;
                    local.loss += net.loss(&scratch, label) as f64;
                    local.errors += usize::from(net.prediction(&scratch) != label);
                }
                merge_metrics(&metrics, &local);
            });
        }
    });
    metrics.into_inner().unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchSpec;
    use crate::data::{generate_synthetic, SynthConfig};

    /// 13×13 resized synthetic digits for the tiny architecture.
    fn tiny_data(n: usize, seed: u64) -> Dataset {
        generate_synthetic(n, seed, &SynthConfig::default()).resize(13)
    }

    fn tiny_cfg(threads: usize, epochs: usize) -> TrainConfig {
        TrainConfig {
            epochs,
            threads,
            // The tiny net wants a larger step than the paper networks.
            eta0: 0.05,
            eta_decay: 0.95,
            seed: 42,
            validation_fraction: 0.25,
        }
    }

    #[test]
    fn sequential_training_reduces_loss_and_errors() {
        let net = Network::new(ArchSpec::tiny());
        let trn = tiny_data(300, 1);
        let tst = tiny_data(100, 2);
        let r = train_sequential(&net, &trn, &tst, &tiny_cfg(1, 6), Strategy::Sequential);
        let first = &r.epochs[0];
        let last = r.final_epoch();
        assert!(last.train.loss < first.train.loss, "training loss must fall");
        assert!(
            last.test.error_rate() < 0.5,
            "test error rate {} should beat chance by a wide margin",
            last.test.error_rate()
        );
        assert_eq!(first.train.images, 300);
        assert_eq!(first.validation.images, 75);
        assert_eq!(first.test.images, 100);
        assert_eq!(r.publications, 0);
    }

    #[test]
    fn chaos_parallel_matches_sequential_accuracy() {
        // The paper's Result 4: parallel CHAOS training reaches accuracy
        // comparable to sequential (Table 7's deviations are tens of
        // images out of 60k). Here: same data/seed, small tolerance.
        let net = Network::new(ArchSpec::tiny());
        let trn = tiny_data(400, 3);
        let tst = tiny_data(150, 4);
        let seq = train(&net, &trn, &tst, &tiny_cfg(1, 3), Strategy::Sequential).unwrap();
        let par = train(&net, &trn, &tst, &tiny_cfg(4, 3), Strategy::Chaos).unwrap();
        let seq_err = seq.final_epoch().test.error_rate();
        let par_err = par.final_epoch().test.error_rate();
        assert!(
            (seq_err - par_err).abs() < 0.15,
            "parity violated: sequential {seq_err} vs chaos {par_err}"
        );
        assert!(par.publications > 0, "chaos must publish through the store");
        assert_eq!(par.threads, 4);
    }

    #[test]
    fn all_parallel_strategies_run_and_learn() {
        let net = Network::new(ArchSpec::tiny());
        let trn = tiny_data(240, 5);
        let tst = tiny_data(80, 6);
        for strategy in [
            Strategy::Chaos,
            Strategy::Hogwild,
            Strategy::DelayedRoundRobin,
            Strategy::Averaged { sync_every: 16 },
        ] {
            let r = train(&net, &trn, &tst, &tiny_cfg(3, 3), strategy).unwrap();
            assert_eq!(r.strategy, strategy.name());
            let first = &r.epochs[0];
            let last = r.final_epoch();
            assert_eq!(first.train.images, 240, "{}: all images trained", strategy.name());
            assert!(
                last.train.loss < first.train.loss,
                "{}: loss should fall ({} -> {})",
                strategy.name(),
                first.train.loss,
                last.train.loss
            );
            assert!(last.test.error_rate() < 0.7, "{}: learns something", strategy.name());
        }
    }

    #[test]
    fn thread_one_falls_back_to_sequential_engine() {
        let net = Network::new(ArchSpec::tiny());
        let trn = tiny_data(60, 7);
        let tst = tiny_data(30, 8);
        let r = train(&net, &trn, &tst, &tiny_cfg(1, 1), Strategy::Chaos).unwrap();
        assert_eq!(r.threads, 1);
        assert_eq!(r.publications, 0, "sequential path bypasses the store");
    }

    #[test]
    fn eval_parallel_counts_every_image_once() {
        let net = Network::new(ArchSpec::tiny());
        let data = tiny_data(123, 9);
        let params = net.init_params(1);
        let store = SharedParams::new(&params, &net.dims);
        let timers = LayerTimes::new();
        let m = eval_parallel(&net, &store, &data, data.len(), 4, &timers);
        assert_eq!(m.images, 123);
        assert!(m.loss > 0.0);
        // limit smaller than the dataset
        let m2 = eval_parallel(&net, &store, &data, 50, 4, &timers);
        assert_eq!(m2.images, 50);
    }

    #[test]
    fn parallel_eval_matches_sequential_eval() {
        let net = Network::new(ArchSpec::tiny());
        let data = tiny_data(100, 10);
        let params = net.init_params(2);
        let store = SharedParams::new(&params, &net.dims);
        let timers = LayerTimes::new();
        let par = eval_parallel(&net, &store, &data, data.len(), 4, &timers);
        let mut scratch = net.scratch();
        let seq = eval_seq(&net, &params, &data, data.len(), &mut scratch, None);
        assert_eq!(par.errors, seq.errors, "same weights ⇒ same predictions");
        assert!((par.loss - seq.loss).abs() < 1e-3 * seq.loss.abs().max(1.0));
    }
}

