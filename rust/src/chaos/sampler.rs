//! Work distribution: workers *pick* images rather than being assigned
//! static chunks — §4.2(3): "Letting workers pick images instead of
//! assigning images to workers allows for a smaller overhead at the end of
//! a work-sharing construct" (no straggler waits at the tail).
//!
//! The sampler is a shuffled index list with an atomic cursor; `next()` is
//! one `fetch_add`.

use crate::util::Pcg32;
use std::sync::atomic::{AtomicUsize, Ordering};

/// A single-epoch pool of image indices, consumed concurrently.
#[derive(Debug)]
pub struct Sampler {
    order: Vec<u32>,
    cursor: AtomicUsize,
}

impl Sampler {
    /// Sequential order over `n` images.
    pub fn sequential(n: usize) -> Sampler {
        Sampler { order: Self::identity(n), cursor: AtomicUsize::new(0) }
    }

    /// Shuffled order, deterministic in (seed, epoch).
    pub fn shuffled(n: usize, seed: u64, epoch: usize) -> Sampler {
        let mut order = Self::identity(n);
        let mut rng = Pcg32::new(seed, 0x5A17 ^ epoch as u64);
        rng.shuffle(&mut order);
        Sampler { order, cursor: AtomicUsize::new(0) }
    }

    /// Identity permutation `0..n`. Indices are stored as `u32` (half the
    /// footprint of the epoch-sized index list), so a pool beyond
    /// `u32::MAX` images must be rejected — `0..n as u32` would otherwise
    /// silently truncate to an empty (or short) range.
    fn identity(n: usize) -> Vec<u32> {
        assert!(
            u32::try_from(n).is_ok(),
            "sampler pool of {n} images exceeds the u32 index space"
        );
        (0..n as u32).collect()
    }

    /// Claim the next image index, or `None` when the pool is drained.
    #[inline]
    pub fn next(&self) -> Option<usize> {
        let i = self.cursor.fetch_add(1, Ordering::Relaxed);
        self.order.get(i).map(|&v| v as usize)
    }

    /// Claim up to `max` indices in **one** atomic operation, replacing
    /// `out`'s contents. An empty `out` afterwards means the pool is
    /// drained; a partial fill means this claim got the epoch's tail (the
    /// final chunk may be smaller than `max`). Minibatch workers use this
    /// so claiming a B-sample chunk costs one `fetch_add` instead of B.
    pub fn next_chunk(&self, max: usize, out: &mut Vec<usize>) {
        out.clear();
        if max == 0 {
            return;
        }
        let start = self.cursor.fetch_add(max, Ordering::Relaxed);
        if start >= self.order.len() {
            return;
        }
        let end = start.saturating_add(max).min(self.order.len());
        out.extend(self.order[start..end].iter().map(|&v| v as usize));
    }

    /// Number of images in the pool.
    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    /// How many have been claimed so far (may exceed len briefly).
    pub fn claimed(&self) -> usize {
        self.cursor.load(Ordering::Relaxed).min(self.order.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn drains_exactly_once_single_thread() {
        let s = Sampler::shuffled(100, 1, 0);
        let mut seen = HashSet::new();
        while let Some(i) = s.next() {
            assert!(seen.insert(i), "index {i} issued twice");
        }
        assert_eq!(seen.len(), 100);
        assert!(s.next().is_none());
    }

    #[test]
    fn drains_exactly_once_multi_thread() {
        let s = Sampler::shuffled(1000, 2, 5);
        let issued: Vec<Vec<usize>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..4)
                .map(|_| {
                    scope.spawn(|| {
                        let mut mine = Vec::new();
                        while let Some(i) = s.next() {
                            mine.push(i);
                        }
                        mine
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let all: Vec<usize> = issued.into_iter().flatten().collect();
        assert_eq!(all.len(), 1000);
        let set: HashSet<usize> = all.iter().copied().collect();
        assert_eq!(set.len(), 1000, "duplicates issued");
        assert_eq!(s.claimed(), 1000);
    }

    #[test]
    fn shuffle_depends_on_epoch_and_seed() {
        let a: Vec<_> = {
            let s = Sampler::shuffled(50, 1, 0);
            std::iter::from_fn(|| s.next()).collect()
        };
        let b: Vec<_> = {
            let s = Sampler::shuffled(50, 1, 1);
            std::iter::from_fn(|| s.next()).collect()
        };
        let a2: Vec<_> = {
            let s = Sampler::shuffled(50, 1, 0);
            std::iter::from_fn(|| s.next()).collect()
        };
        assert_ne!(a, b, "different epochs must reshuffle");
        assert_eq!(a, a2, "same (seed, epoch) must reproduce");
    }

    #[test]
    fn chunks_drain_exactly_once_multi_thread() {
        let s = Sampler::shuffled(1000, 3, 2);
        let issued: Vec<Vec<usize>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..4)
                .map(|_| {
                    scope.spawn(|| {
                        let mut mine = Vec::new();
                        let mut chunk = Vec::new();
                        loop {
                            s.next_chunk(7, &mut chunk);
                            if chunk.is_empty() {
                                break;
                            }
                            mine.extend_from_slice(&chunk);
                        }
                        mine
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let all: Vec<usize> = issued.into_iter().flatten().collect();
        assert_eq!(all.len(), 1000);
        let set: HashSet<usize> = all.iter().copied().collect();
        assert_eq!(set.len(), 1000, "duplicates issued");
    }

    #[test]
    fn chunk_tail_is_partial_then_empty() {
        let s = Sampler::sequential(10);
        let mut chunk = Vec::new();
        s.next_chunk(8, &mut chunk);
        assert_eq!(chunk, vec![0, 1, 2, 3, 4, 5, 6, 7]);
        s.next_chunk(8, &mut chunk);
        assert_eq!(chunk, vec![8, 9], "tail chunk is partial");
        s.next_chunk(8, &mut chunk);
        assert!(chunk.is_empty(), "drained pool yields empty chunks");
        s.next_chunk(0, &mut chunk);
        assert!(chunk.is_empty());
    }

    #[test]
    fn sequential_in_order() {
        let s = Sampler::sequential(5);
        let got: Vec<_> = std::iter::from_fn(|| s.next()).collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn empty_pool_yields_nothing() {
        for s in [Sampler::sequential(0), Sampler::shuffled(0, 9, 3)] {
            assert!(s.is_empty());
            assert_eq!(s.len(), 0);
            assert!(s.next().is_none());
            let mut chunk = vec![99];
            s.next_chunk(4, &mut chunk);
            assert!(chunk.is_empty(), "chunk from an empty pool must clear out");
            assert_eq!(s.claimed(), 0);
        }
    }

    #[test]
    fn chunk_larger_than_pool_returns_everything() {
        let s = Sampler::sequential(3);
        let mut chunk = Vec::new();
        s.next_chunk(1000, &mut chunk);
        assert_eq!(chunk, vec![0, 1, 2]);
        s.next_chunk(1000, &mut chunk);
        assert!(chunk.is_empty());
        assert_eq!(s.claimed(), 3);
    }

    /// A pool beyond the u32 index space must be rejected loudly, not
    /// truncated by `0..n as u32` into a silently empty sampler. The assert
    /// fires before the index list is allocated, so the test never attempts
    /// a 16 GiB allocation.
    #[test]
    #[should_panic(expected = "exceeds the u32 index space")]
    #[cfg(target_pointer_width = "64")]
    fn pool_beyond_u32_panics() {
        let _ = Sampler::sequential(u32::MAX as usize + 1);
    }
}
