//! The shared weight store — the heart of CHAOS.
//!
//! All workers train against one parameter vector (§4.1: "all workers share
//! weight parameters"). Storage is `AtomicU32` holding f32 bits: on x86 a
//! relaxed atomic load/store compiles to a plain `mov`, so reads in the
//! forward/backward hot path cost the same as the paper's raw C++ shared
//! arrays while staying defined behaviour in Rust.
//!
//! Publication disciplines (§4.1 Design Aspects):
//! * **Controlled** (CHAOS): one publisher per layer at a time, first-come
//!   first-served via a per-layer mutex. A worker finishes a layer's local
//!   gradients, takes the layer lock, applies `w -= η·g` — "non-instant
//!   updates … without significant delay"; other workers keep reading and
//!   never wait on a barrier, which is the "implicit synchronization in
//!   arbitrary order".
//! * **Unlocked** (HogWild!, strategy D): plain load-add-store without the
//!   lock; concurrent publishers may lose updates — exactly the race the
//!   original HogWild! tolerates.
//! * **store_all** (averaged SGD, strategy B): the master overwrites the
//!   whole vector between mini-batches.
//!
//! Built with `--features race-check`, every access is additionally
//! recorded into a [`RaceRecorder`](super::analysis::RaceRecorder) that
//! enforces the policy's declared [`SyncContract`] (see
//! [`super::analysis`]), and publish/load paths carry
//! [`yield_point`](super::analysis::yield_point)s so the deterministic
//! interleaver can replay adversarial orderings. Without the feature the
//! instrumentation compiles out entirely.

use super::analysis::{ShardOwnership, SyncContract};
#[cfg(feature = "race-check")]
use super::analysis::{yield_point, RaceDefect, RaceRecorder, StoreEvent};
use crate::nn::{LayerDims, ParamSource};
use std::ops::Range;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Mutex;

/// Shared parameter vector with per-layer publication locks.
pub struct SharedParams {
    words: Vec<AtomicU32>,
    /// One lock per layer (indexed by layer id; non-parameterized layers
    /// carry an unused lock to keep indexing trivial).
    locks: Vec<Mutex<()>>,
    /// Per-layer declared parameter spans (parallel to `locks`) — the
    /// ownership table behind [`SharedParams::range_owned_by`].
    spans: Vec<Range<usize>>,
    /// Count of published layer-updates (metrics / tests).
    publications: AtomicU64,
    #[cfg(feature = "race-check")]
    race: RaceRecorder,
}

impl SharedParams {
    /// Initialize from a flat parameter vector and the layer table.
    pub fn new(init: &[f32], dims: &[LayerDims]) -> SharedParams {
        SharedParams {
            words: init.iter().map(|&v| AtomicU32::new(v.to_bits())).collect(),
            locks: dims.iter().map(|_| Mutex::new(())).collect(),
            spans: dims.iter().map(|d| d.params.clone()).collect(),
            publications: AtomicU64::new(0),
            #[cfg(feature = "race-check")]
            race: RaceRecorder::new(dims, init.len()),
        }
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// Number of per-layer publications so far.
    pub fn publication_count(&self) -> u64 {
        self.publications.load(Ordering::Relaxed)
    }

    /// Read one value (tests/debug).
    pub fn get(&self, i: usize) -> f32 {
        f32::from_bits(self.words[i].load(Ordering::Relaxed))
    }

    /// Whether `range` lies within layer `layer`'s declared parameter
    /// span — the precondition of [`SharedParams::publish_scaled`]: a
    /// mismatched `(layer, range)` pair would serialize under the wrong
    /// lock and silently race the range's real owner.
    pub fn range_owned_by(&self, layer: usize, range: &Range<usize>) -> bool {
        match self.spans.get(layer) {
            Some(s) => range.start <= range.end && s.start <= range.start && range.end <= s.end,
            None => false,
        }
    }

    /// Declare the synchronization discipline of the running update policy
    /// (see [`super::analysis::SyncContract`]). A no-op unless built with
    /// `--features race-check`.
    pub fn set_sync_contract(&self, contract: SyncContract) {
        #[cfg(feature = "race-check")]
        self.race.set_contract(contract);
        #[cfg(not(feature = "race-check"))]
        let _ = contract;
    }

    /// Install the shard side of the contract (a verified
    /// [`ShardPlan::ownership`](super::analysis::shard::ShardPlan::ownership)
    /// table): under `race-check`, a publish overlapping a split piece
    /// from a worker that has not declared the owning shard (via
    /// [`super::analysis::set_worker_shard`]) is recorded as a
    /// cross-shard-publish defect. A no-op without the feature.
    pub fn set_shard_ownership(&self, ownership: ShardOwnership) {
        #[cfg(feature = "race-check")]
        self.race.set_shard_ownership(ownership);
        #[cfg(not(feature = "race-check"))]
        let _ = ownership;
    }

    /// Copy a span into `buf` — the worker's on-demand read.
    #[inline]
    pub fn load_span(&self, range: Range<usize>, buf: &mut [f32]) {
        debug_assert_eq!(buf.len(), range.len());
        #[cfg(feature = "race-check")]
        {
            self.race.record_load(range.clone());
            yield_point("load");
        }
        for (dst, w) in buf.iter_mut().zip(&self.words[range]) {
            *dst = f32::from_bits(w.load(Ordering::Relaxed));
        }
    }

    /// Controlled publication: `w[range] += scale · grads`, serialized per
    /// layer. `scale` is `-η` for gradient descent. `range` must lie
    /// within layer `layer`'s declared span
    /// ([`SharedParams::range_owned_by`]) — checked in debug builds, and a
    /// hard error under `--features race-check`.
    pub fn publish_scaled(&self, layer: usize, range: Range<usize>, grads: &[f32], scale: f32) {
        debug_assert_eq!(grads.len(), range.len());
        #[cfg(feature = "race-check")]
        assert!(
            self.range_owned_by(layer, &range),
            "publish_scaled: range {}..{} not owned by layer {layer} (span {:?})",
            range.start,
            range.end,
            self.spans.get(layer)
        );
        #[cfg(not(feature = "race-check"))]
        debug_assert!(
            self.range_owned_by(layer, &range),
            "publish_scaled: range {}..{} not owned by layer {layer} (span {:?})",
            range.start,
            range.end,
            self.spans.get(layer)
        );
        // Interleaver discipline: park *before* taking the lock, never
        // inside it — a suspended lock holder could never be resumed.
        #[cfg(feature = "race-check")]
        yield_point("publish:locked");
        let _guard = self.locks[layer].lock().unwrap();
        #[cfg(feature = "race-check")]
        let _write = self.race.locked_publish(layer, range.clone());
        for (w, &g) in self.words[range].iter().zip(grads) {
            let cur = f32::from_bits(w.load(Ordering::Relaxed));
            w.store((cur + scale * g).to_bits(), Ordering::Relaxed);
        }
        self.publications.fetch_add(1, Ordering::Relaxed);
    }

    /// HogWild!-style unlocked publication: same update, no lock; racing
    /// publishers may interleave element-wise and lose increments.
    pub fn publish_scaled_unlocked(&self, range: Range<usize>, grads: &[f32], scale: f32) {
        debug_assert_eq!(grads.len(), range.len());
        #[cfg(feature = "race-check")]
        let _write = self.race.unlocked_publish(range.clone());
        #[cfg(feature = "race-check")]
        let mut first = true;
        for (w, &g) in self.words[range].iter().zip(grads) {
            let cur = f32::from_bits(w.load(Ordering::Relaxed));
            // Park between the read and the write of the first element —
            // the exact window in which a concurrent publisher's update is
            // lost, so the interleaver can force the loss deterministically.
            #[cfg(feature = "race-check")]
            if std::mem::take(&mut first) {
                yield_point("publish:unlocked:rmw");
            }
            w.store((cur + scale * g).to_bits(), Ordering::Relaxed);
        }
        self.publications.fetch_add(1, Ordering::Relaxed);
    }

    /// Overwrite the full vector (averaged-SGD master step).
    pub fn store_all(&self, values: &[f32]) {
        debug_assert_eq!(values.len(), self.words.len());
        #[cfg(feature = "race-check")]
        self.race.record_store_all();
        for (w, &v) in self.words.iter().zip(values) {
            w.store(v.to_bits(), Ordering::Relaxed);
        }
    }

    /// Snapshot the full vector.
    pub fn snapshot(&self) -> Vec<f32> {
        self.words
            .iter()
            .map(|w| f32::from_bits(w.load(Ordering::Relaxed)))
            .collect()
    }

    /// Snapshot the full vector into a caller-owned buffer — the serving
    /// tier's per-batch read ([`crate::runtime::SharedStoreEngine`]):
    /// allocation-free on the hot path, and recorded as a whole-store load
    /// under `--features race-check` so live-serving reads are checked
    /// against the training policy's [`SyncContract`] like any worker
    /// read.
    pub fn snapshot_into(&self, buf: &mut [f32]) {
        assert_eq!(
            buf.len(),
            self.words.len(),
            "snapshot_into: buffer length must match the store"
        );
        #[cfg(feature = "race-check")]
        {
            self.race.record_load(0..self.words.len());
            yield_point("load");
        }
        for (dst, w) in buf.iter_mut().zip(&self.words) {
            *dst = f32::from_bits(w.load(Ordering::Relaxed));
        }
    }
}

/// Race-checker views, available with `--features race-check` (see
/// [`super::analysis::race`]).
#[cfg(feature = "race-check")]
impl SharedParams {
    /// Lock-discipline / race defects recorded so far (empty on a clean
    /// run). The trainer asserts this is empty at the end of every
    /// parallel run.
    pub fn race_defects(&self) -> Vec<RaceDefect> {
        self.race.defects()
    }

    /// The recorded store-access event log.
    pub fn race_events(&self) -> Vec<StoreEvent> {
        self.race.events()
    }

    /// Events dropped past the recorder's log cap. Nonzero means
    /// [`SharedParams::race_events`] is a truncated view (defect checking
    /// is unaffected); the trainer's end-of-run summary names this count
    /// so the truncation is never silent.
    pub fn race_dropped_events(&self) -> usize {
        self.race.dropped_events()
    }

    pub fn race_is_clean(&self) -> bool {
        self.race.is_clean()
    }
}

impl ParamSource for &SharedParams {
    #[inline]
    fn load(&self, range: Range<usize>, buf: &mut [f32]) {
        self.load_span(range, buf);
    }
}

impl std::fmt::Debug for SharedParams {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "SharedParams(len={}, layers={}, publications={})",
            self.words.len(),
            self.locks.len(),
            self.publication_count()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchSpec;
    use crate::nn::compute_dims;

    fn store_for(arch: &ArchSpec, fill: f32) -> (SharedParams, Vec<LayerDims>) {
        let dims = compute_dims(arch);
        let total = crate::nn::total_params(&dims);
        (SharedParams::new(&vec![fill; total], &dims), dims)
    }

    #[test]
    fn roundtrip_snapshot() {
        let (store, _) = store_for(&ArchSpec::tiny(), 0.5);
        let snap = store.snapshot();
        assert!(snap.iter().all(|&v| v == 0.5));
        assert_eq!(snap.len(), store.len());
    }

    #[test]
    fn publish_applies_scaled_update() {
        let (store, dims) = store_for(&ArchSpec::tiny(), 1.0);
        let layer = 1;
        let range = dims[layer].params.clone();
        let grads = vec![2.0f32; range.len()];
        store.publish_scaled(layer, range.clone(), &grads, -0.25);
        // w = 1.0 - 0.25*2.0 = 0.5 inside the layer; untouched elsewhere.
        assert!((store.get(range.start) - 0.5).abs() < 1e-6);
        assert!((store.get(range.end) - 1.0).abs() < 1e-6);
        assert_eq!(store.publication_count(), 1);
    }

    #[test]
    fn load_span_matches_get() {
        let (store, dims) = store_for(&ArchSpec::tiny(), 0.0);
        let range = dims[1].params.clone();
        store.publish_scaled(1, range.clone(), &vec![1.0; range.len()], 3.0);
        let mut buf = vec![0.0; range.len()];
        store.load_span(range.clone(), &mut buf);
        assert!(buf.iter().all(|&v| (v - 3.0).abs() < 1e-6));
    }

    #[test]
    fn locked_publications_never_lose_updates() {
        // The controlled scheme serializes per layer: the sum of N
        // publications must be exact regardless of thread interleaving.
        let (store, dims) = store_for(&ArchSpec::tiny(), 0.0);
        let layer = 1;
        let range = dims[layer].params.clone();
        let store = std::sync::Arc::new(store);
        let per_thread = 200;
        let threads = 8;
        std::thread::scope(|s| {
            for _ in 0..threads {
                let store = store.clone();
                let range = range.clone();
                s.spawn(move || {
                    let grads = vec![1.0f32; range.len()];
                    for _ in 0..per_thread {
                        store.publish_scaled(layer, range.clone(), &grads, 1.0);
                    }
                });
            }
        });
        let expect = (per_thread * threads) as f32;
        for i in range {
            assert_eq!(store.get(i), expect, "lost update at {i}");
        }
        assert_eq!(store.publication_count(), (per_thread * threads) as u64);
    }

    #[test]
    fn hogwild_lost_updates_stay_bounded() {
        // The unlocked path may lose updates but not invent them. Per
        // thread, each read-modify-write reads at least the thread's own
        // last store (coherence), so every thread's stored sequence grows
        // by ≥ 1 per publish and the coherence-final store — some thread's
        // last — is ≥ per_thread. And no store can exceed the race-free
        // sum, since every stored value is (some earlier value) + 1.
        let (store, dims) = store_for(&ArchSpec::tiny(), 0.0);
        let range = dims[1].params.clone();
        let store = std::sync::Arc::new(store);
        let per_thread = 200;
        let threads = 8;
        std::thread::scope(|s| {
            for _ in 0..threads {
                let store = store.clone();
                let range = range.clone();
                s.spawn(move || {
                    let grads = vec![1.0f32; range.len()];
                    for _ in 0..per_thread {
                        store.publish_scaled_unlocked(range.clone(), &grads, 1.0);
                    }
                });
            }
        });
        for i in range {
            let v = store.get(i);
            assert!(v >= per_thread as f32, "below one thread's own updates at {i}: {v}");
            assert!(v <= (per_thread * threads) as f32, "above the race-free sum at {i}: {v}");
        }
        assert_eq!(store.publication_count(), (per_thread * threads) as u64);
    }

    #[test]
    fn range_ownership_is_checked() {
        let (store, dims) = store_for(&ArchSpec::tiny(), 0.0);
        assert!(store.range_owned_by(1, &dims[1].params));
        let sub = dims[1].params.start..dims[1].params.start + 1;
        assert!(store.range_owned_by(1, &sub));
        assert!(!store.range_owned_by(1, &dims[3].params), "another layer's span");
        assert!(!store.range_owned_by(99, &dims[1].params), "layer out of table");
        let inverted = dims[1].params.end..dims[1].params.start;
        assert!(!store.range_owned_by(1, &inverted), "inverted range");
    }

    #[test]
    #[should_panic(expected = "not owned by layer")]
    #[cfg(any(debug_assertions, feature = "race-check"))]
    fn mismatched_publish_panics() {
        // Satellite of the span contract: publishing layer 3's range under
        // layer 1's lock is the wrong-lock hazard — rejected outright.
        let (store, dims) = store_for(&ArchSpec::tiny(), 0.0);
        let range = dims[3].params.clone();
        store.publish_scaled(1, range.clone(), &vec![0.0; range.len()], 1.0);
    }

    #[test]
    fn param_source_impl_reads_layers() {
        let (store, dims) = store_for(&ArchSpec::tiny(), 7.0);
        let src = &store;
        let mut buf = vec![0.0; dims[1].param_count()];
        ParamSource::load(&src, dims[1].params.clone(), &mut buf);
        assert!(buf.iter().all(|&v| v == 7.0));
    }
}
