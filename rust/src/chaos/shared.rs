//! The shared weight store — the heart of CHAOS.
//!
//! All workers train against one parameter vector (§4.1: "all workers share
//! weight parameters"). Storage is `AtomicU32` holding f32 bits: on x86 a
//! relaxed atomic load/store compiles to a plain `mov`, so reads in the
//! forward/backward hot path cost the same as the paper's raw C++ shared
//! arrays while staying defined behaviour in Rust.
//!
//! Publication disciplines (§4.1 Design Aspects):
//! * **Controlled** (CHAOS): one publisher per layer at a time, first-come
//!   first-served via a per-layer mutex. A worker finishes a layer's local
//!   gradients, takes the layer lock, applies `w -= η·g` — "non-instant
//!   updates … without significant delay"; other workers keep reading and
//!   never wait on a barrier, which is the "implicit synchronization in
//!   arbitrary order".
//! * **Unlocked** (HogWild!, strategy D): plain load-add-store without the
//!   lock; concurrent publishers may lose updates — exactly the race the
//!   original HogWild! tolerates.
//! * **store_all** (averaged SGD, strategy B): the master overwrites the
//!   whole vector between mini-batches.

use crate::nn::{LayerDims, ParamSource};
use std::ops::Range;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Mutex;

/// Shared parameter vector with per-layer publication locks.
pub struct SharedParams {
    words: Vec<AtomicU32>,
    /// One lock per layer (indexed by layer id; non-parameterized layers
    /// carry an unused lock to keep indexing trivial).
    locks: Vec<Mutex<()>>,
    /// Count of published layer-updates (metrics / tests).
    publications: AtomicU64,
}

impl SharedParams {
    /// Initialize from a flat parameter vector and the layer table.
    pub fn new(init: &[f32], dims: &[LayerDims]) -> SharedParams {
        SharedParams {
            words: init.iter().map(|&v| AtomicU32::new(v.to_bits())).collect(),
            locks: dims.iter().map(|_| Mutex::new(())).collect(),
            publications: AtomicU64::new(0),
        }
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// Number of per-layer publications so far.
    pub fn publication_count(&self) -> u64 {
        self.publications.load(Ordering::Relaxed)
    }

    /// Read one value (tests/debug).
    pub fn get(&self, i: usize) -> f32 {
        f32::from_bits(self.words[i].load(Ordering::Relaxed))
    }

    /// Copy a span into `buf` — the worker's on-demand read.
    #[inline]
    pub fn load_span(&self, range: Range<usize>, buf: &mut [f32]) {
        debug_assert_eq!(buf.len(), range.len());
        for (dst, w) in buf.iter_mut().zip(&self.words[range]) {
            *dst = f32::from_bits(w.load(Ordering::Relaxed));
        }
    }

    /// Controlled publication: `w[range] += scale · grads`, serialized per
    /// layer. `scale` is `-η` for gradient descent.
    pub fn publish_scaled(&self, layer: usize, range: Range<usize>, grads: &[f32], scale: f32) {
        debug_assert_eq!(grads.len(), range.len());
        let _guard = self.locks[layer].lock().unwrap();
        for (w, &g) in self.words[range].iter().zip(grads) {
            let cur = f32::from_bits(w.load(Ordering::Relaxed));
            w.store((cur + scale * g).to_bits(), Ordering::Relaxed);
        }
        self.publications.fetch_add(1, Ordering::Relaxed);
    }

    /// HogWild!-style unlocked publication: same update, no lock; racing
    /// publishers may interleave element-wise and lose increments.
    pub fn publish_scaled_unlocked(&self, range: Range<usize>, grads: &[f32], scale: f32) {
        debug_assert_eq!(grads.len(), range.len());
        for (w, &g) in self.words[range].iter().zip(grads) {
            let cur = f32::from_bits(w.load(Ordering::Relaxed));
            w.store((cur + scale * g).to_bits(), Ordering::Relaxed);
        }
        self.publications.fetch_add(1, Ordering::Relaxed);
    }

    /// Overwrite the full vector (averaged-SGD master step).
    pub fn store_all(&self, values: &[f32]) {
        debug_assert_eq!(values.len(), self.words.len());
        for (w, &v) in self.words.iter().zip(values) {
            w.store(v.to_bits(), Ordering::Relaxed);
        }
    }

    /// Snapshot the full vector.
    pub fn snapshot(&self) -> Vec<f32> {
        self.words
            .iter()
            .map(|w| f32::from_bits(w.load(Ordering::Relaxed)))
            .collect()
    }
}

impl ParamSource for &SharedParams {
    #[inline]
    fn load(&self, range: Range<usize>, buf: &mut [f32]) {
        self.load_span(range, buf);
    }
}

impl std::fmt::Debug for SharedParams {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "SharedParams(len={}, layers={}, publications={})",
            self.words.len(),
            self.locks.len(),
            self.publication_count()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchSpec;
    use crate::nn::compute_dims;

    fn store_for(arch: &ArchSpec, fill: f32) -> (SharedParams, Vec<LayerDims>) {
        let dims = compute_dims(arch);
        let total = crate::nn::total_params(&dims);
        (SharedParams::new(&vec![fill; total], &dims), dims)
    }

    #[test]
    fn roundtrip_snapshot() {
        let (store, _) = store_for(&ArchSpec::tiny(), 0.5);
        let snap = store.snapshot();
        assert!(snap.iter().all(|&v| v == 0.5));
        assert_eq!(snap.len(), store.len());
    }

    #[test]
    fn publish_applies_scaled_update() {
        let (store, dims) = store_for(&ArchSpec::tiny(), 1.0);
        let layer = 1;
        let range = dims[layer].params.clone();
        let grads = vec![2.0f32; range.len()];
        store.publish_scaled(layer, range.clone(), &grads, -0.25);
        // w = 1.0 - 0.25*2.0 = 0.5 inside the layer; untouched elsewhere.
        assert!((store.get(range.start) - 0.5).abs() < 1e-6);
        assert!((store.get(range.end) - 1.0).abs() < 1e-6);
        assert_eq!(store.publication_count(), 1);
    }

    #[test]
    fn load_span_matches_get() {
        let (store, dims) = store_for(&ArchSpec::tiny(), 0.0);
        let range = dims[1].params.clone();
        store.publish_scaled(1, range.clone(), &vec![1.0; range.len()], 3.0);
        let mut buf = vec![0.0; range.len()];
        store.load_span(range.clone(), &mut buf);
        assert!(buf.iter().all(|&v| (v - 3.0).abs() < 1e-6));
    }

    #[test]
    fn locked_publications_never_lose_updates() {
        // The controlled scheme serializes per layer: the sum of N
        // publications must be exact regardless of thread interleaving.
        let (store, dims) = store_for(&ArchSpec::tiny(), 0.0);
        let layer = 1;
        let range = dims[layer].params.clone();
        let store = std::sync::Arc::new(store);
        let per_thread = 200;
        let threads = 8;
        std::thread::scope(|s| {
            for _ in 0..threads {
                let store = store.clone();
                let range = range.clone();
                s.spawn(move || {
                    let grads = vec![1.0f32; range.len()];
                    for _ in 0..per_thread {
                        store.publish_scaled(layer, range.clone(), &grads, 1.0);
                    }
                });
            }
        });
        let expect = (per_thread * threads) as f32;
        for i in range {
            assert_eq!(store.get(i), expect, "lost update at {i}");
        }
        assert_eq!(store.publication_count(), (per_thread * threads) as u64);
    }

    #[test]
    fn param_source_impl_reads_layers() {
        let (store, dims) = store_for(&ArchSpec::tiny(), 7.0);
        let src = &store;
        let mut buf = vec![0.0; dims[1].param_count()];
        ParamSource::load(&src, dims[1].params.clone(), &mut buf);
        assert!(buf.iter().all(|&v| v == 7.0));
    }
}
