//! Run records — the Rust analogue of the paper's `Reporter` class
//! (§4.2: "we added a Reporter class to serialize execution results").
//!
//! Captures per-epoch errors, error rates and cumulative losses for the
//! training/validation/test phases plus wall-clock and per-layer times;
//! the harness consumes these to regenerate Table 7, Fig 6 and Fig 10.

use crate::util::timer::LAYER_CLASSES;
use crate::util::{Json, LayerTimes};

/// Metrics of one evaluation pass over a dataset split.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EvalMetrics {
    /// Images evaluated.
    pub images: usize,
    /// Incorrectly predicted images (paper Table 7 "Tot").
    pub errors: usize,
    /// Cumulative cross-entropy loss (paper Fig 10 "cumulative error").
    pub loss: f64,
}

impl EvalMetrics {
    /// Fraction of incorrect predictions.
    pub fn error_rate(&self) -> f64 {
        if self.images == 0 {
            0.0
        } else {
            self.errors as f64 / self.images as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("images", Json::num(self.images as f64)),
            ("errors", Json::num(self.errors as f64)),
            ("loss", Json::num(self.loss)),
            ("error_rate", Json::num(self.error_rate())),
        ])
    }
}

/// One epoch of a run: train metrics plus validation/test evaluations,
/// mirroring the paper's epoch structure (Fig 3: Training → Validation →
/// Testing).
#[derive(Debug, Clone)]
pub struct EpochRecord {
    pub epoch: usize,
    pub eta: f32,
    pub train: EvalMetrics,
    pub validation: EvalMetrics,
    pub test: EvalMetrics,
    /// Wall-clock seconds spent in the training phase of this epoch.
    pub train_secs: f64,
    /// Wall-clock seconds for the whole epoch.
    pub total_secs: f64,
}

impl EpochRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("epoch", Json::num(self.epoch as f64)),
            ("eta", Json::num(self.eta as f64)),
            ("train", self.train.to_json()),
            ("validation", self.validation.to_json()),
            ("test", self.test.to_json()),
            ("train_secs", Json::num(self.train_secs)),
            ("total_secs", Json::num(self.total_secs)),
        ])
    }
}

/// Complete result of a training run.
#[derive(Debug)]
pub struct RunResult {
    pub arch: String,
    pub strategy: String,
    pub threads: usize,
    pub epochs: Vec<EpochRecord>,
    /// Final weights (for parity checks and serving).
    pub final_params: Vec<f32>,
    /// Accumulated per-layer-class times across all workers.
    pub layer_times: LayerTimes,
    /// End-to-end wall-clock seconds (excluding setup, like the paper's
    /// "execution time" which excludes initialization).
    pub wall_secs: f64,
    /// Total shared-store publications (parallel strategies).
    pub publications: u64,
    /// True when an [`super::EpochObserver`] ended the run before
    /// `cfg.epochs` (early stopping).
    pub stopped_early: bool,
}

impl RunResult {
    pub fn final_epoch(&self) -> &EpochRecord {
        self.epochs.last().expect("run has no epochs")
    }

    /// First epoch (1-based count) whose test error rate reached `target`,
    /// if any — the paper's Fig 6 stop-criterion analysis.
    pub fn epochs_to_error_rate(&self, target: f64) -> Option<usize> {
        self.epochs
            .iter()
            .position(|e| e.test.error_rate() <= target)
            .map(|p| p + 1)
    }

    pub fn to_json(&self) -> Json {
        let layer_times: Vec<Json> = LAYER_CLASSES
            .iter()
            .map(|&c| {
                Json::obj(vec![
                    ("class", Json::str(c.name())),
                    ("secs", Json::num(self.layer_times.get_secs(c))),
                ])
            })
            .collect();
        Json::obj(vec![
            ("arch", Json::str(self.arch.clone())),
            ("strategy", Json::str(self.strategy.clone())),
            ("threads", Json::num(self.threads as f64)),
            ("epochs", Json::arr(self.epochs.iter().map(|e| e.to_json()).collect())),
            ("layer_times", Json::arr(layer_times)),
            ("wall_secs", Json::num(self.wall_secs)),
            ("publications", Json::num(self.publications as f64)),
            ("stopped_early", Json::Bool(self.stopped_early)),
        ])
    }

    /// Write the JSON record to a file (one run per file).
    pub fn save(&self, path: &str) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(epoch: usize, test_errors: usize) -> EpochRecord {
        EpochRecord {
            epoch,
            eta: 0.001,
            train: EvalMetrics { images: 100, errors: 20, loss: 50.0 },
            validation: EvalMetrics { images: 100, errors: 15, loss: 40.0 },
            test: EvalMetrics { images: 100, errors: test_errors, loss: 30.0 },
            train_secs: 1.0,
            total_secs: 2.0,
        }
    }

    #[test]
    fn error_rate() {
        let m = EvalMetrics { images: 200, errors: 3, loss: 0.0 };
        assert!((m.error_rate() - 0.015).abs() < 1e-12);
        assert_eq!(EvalMetrics::default().error_rate(), 0.0);
    }

    #[test]
    fn epochs_to_error_rate_finds_first() {
        let r = RunResult {
            arch: "small".into(),
            strategy: "chaos".into(),
            threads: 4,
            epochs: vec![record(0, 50), record(1, 10), record(2, 1), record(3, 2)],
            final_params: vec![],
            layer_times: LayerTimes::new(),
            wall_secs: 10.0,
            publications: 0,
            stopped_early: false,
        };
        assert_eq!(r.epochs_to_error_rate(0.10), Some(2));
        assert_eq!(r.epochs_to_error_rate(0.015), Some(3));
        assert_eq!(r.epochs_to_error_rate(0.001), None);
    }

    #[test]
    fn json_roundtrip_shape() {
        let r = RunResult {
            arch: "small".into(),
            strategy: "chaos".into(),
            threads: 2,
            epochs: vec![record(0, 5)],
            final_params: vec![1.0],
            layer_times: LayerTimes::new(),
            wall_secs: 1.0,
            publications: 42,
            stopped_early: true,
        };
        let j = r.to_json();
        assert_eq!(j.get("arch").unwrap().as_str(), Some("small"));
        assert_eq!(j.get("publications").unwrap().as_usize(), Some(42));
        assert_eq!(j.get("stopped_early").unwrap().as_bool(), Some(true));
        let epochs = j.get("epochs").unwrap().as_arr().unwrap();
        assert_eq!(epochs.len(), 1);
        assert_eq!(epochs[0].get("test").unwrap().get("errors").unwrap().as_usize(), Some(5));
        // parses back
        crate::util::Json::parse(&j.pretty()).unwrap();
    }
}
