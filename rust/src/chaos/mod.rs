//! The CHAOS coordinator — the paper's contribution (§4).
//!
//! **C**ontrolled **H**ogwild with **A**rbitrary **O**rder of
//! **S**ynchronization: data-parallel asynchronous SGD where
//!
//! * every worker thread owns a network *instance* (private activations,
//!   deltas and scratch — [`crate::nn::Scratch`]) but all instances share
//!   one weight vector ([`SharedParams`]);
//! * workers *pick* images from a common pool ([`Sampler`]) so nobody waits
//!   on a straggler;
//! * during back-propagation each layer's gradients are first accumulated
//!   locally, then *published* to the shared weights as soon as that layer
//!   finishes — delayed enough to avoid cache-line ping-pong, instant
//!   enough that other workers see fresh weights within a layer's latency;
//! * publication order is arbitrary and first-come-first-served; there is
//!   no barrier anywhere in an epoch's training phase.
//!
//! The coordinator is driven through the [`Trainer`] builder; the update
//! scheme — CHAOS itself, the strategies the paper contrasts with (B:
//! averaged/synchronous SGD, C: delayed round-robin, D: pure HogWild!), or
//! the minibatch policies (`minibatch:B` / `hogwild-batch:B`, training on
//! B-sample chunks through the batched kernels) — is an open
//! [`UpdatePolicy`] trait over one shared worker framework, so new schemes
//! plug in without touching the epoch driver (see [`policy`]). Runs can be
//! observed in flight (early stopping, live checkpointing) through
//! [`EpochObserver`].

pub mod analysis;
mod checkpoint;
mod observer;
pub mod policy;
mod reporter;
mod sampler;
mod shared;
mod strategies;
mod trainer;

pub use analysis::{ShardOwnership, ShardPlan, SyncContract};
pub use checkpoint::Checkpoint;
pub use observer::{
    observer_fn, CheckpointEvery, EarlyStop, EpochObserver, FnObserver, RunView, TrainControl,
};
pub use policy::{
    AveragedPolicy, ChaosPolicy, DelayedRoundRobinPolicy, EpochCtx, EpochState, HogwildBatchPolicy,
    HogwildPolicy, MinibatchPolicy, SequentialPolicy, UpdatePolicy, WorkerHooks,
};
pub use reporter::{EpochRecord, EvalMetrics, RunResult};
pub use sampler::Sampler;
pub use shared::SharedParams;
pub use strategies::{Strategy, Turnstile};
pub use trainer::{eval_parallel, Trainer};
