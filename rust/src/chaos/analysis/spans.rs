//! Static span verification — level 1 of the analysis subsystem.
//!
//! A compiled network declares, per layer, a `Range<usize>` into the flat
//! parameter vector ([`LayerDims::params`]), and each compiled op repeats
//! that declaration through [`LayerOp::param_range`](crate::nn::LayerOp).
//! Everything downstream — per-layer publication locks, on-demand span
//! loads, sharded stores — assumes those spans are in-bounds, pairwise
//! disjoint, and exactly cover `0..total_params`. [`verify_spans`] proves
//! those properties for a layer table (or reports every violation), and
//! [`verify_network`] additionally cross-checks the op pipeline against
//! the layout. The verifier runs at `Network::compile` in debug builds
//! and behind `chaos analyze` on the CLI.

use crate::nn::{LayerDims, Network};
use crate::util::json::Json;
use std::ops::Range;

/// One violation of the span contract.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SpanDefect {
    /// `start > end` — not a meaningful range at all.
    Inverted { layer: usize, range: Range<usize> },
    /// The span reaches past the end of the parameter vector.
    OutOfBounds { layer: usize, range: Range<usize>, total: usize },
    /// Two layers' spans intersect — publications to one would race the
    /// other's lock discipline.
    Overlap { layer_a: usize, layer_b: usize, range_a: Range<usize>, range_b: Range<usize> },
    /// A hole in the coverage of `0..total` — parameters no layer owns.
    Gap { start: usize, end: usize },
    /// The span's length disagrees with the layer's declared
    /// weight + bias count.
    LengthMismatch { layer: usize, span_len: usize, param_count: usize },
    /// A compiled op's `param_range` disagrees with the layout table.
    OpSpanMismatch { layer: usize, op: Range<usize>, declared: Range<usize> },
}

impl std::fmt::Display for SpanDefect {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SpanDefect::Inverted { layer, range } => {
                write!(f, "layer {layer}: inverted span {}..{}", range.start, range.end)
            }
            SpanDefect::OutOfBounds { layer, range, total } => write!(
                f,
                "layer {layer}: span {}..{} exceeds parameter vector length {total}",
                range.start, range.end
            ),
            SpanDefect::Overlap { layer_a, layer_b, range_a, range_b } => write!(
                f,
                "layers {layer_a} and {layer_b}: spans {}..{} and {}..{} overlap",
                range_a.start, range_a.end, range_b.start, range_b.end
            ),
            SpanDefect::Gap { start, end } => {
                write!(f, "parameters {start}..{end} are covered by no layer's span")
            }
            SpanDefect::LengthMismatch { layer, span_len, param_count } => write!(
                f,
                "layer {layer}: span holds {span_len} parameters but the layer declares {param_count}"
            ),
            SpanDefect::OpSpanMismatch { layer, op, declared } => write!(
                f,
                "layer {layer}: compiled op claims span {}..{} but the layout declares {}..{}",
                op.start, op.end, declared.start, declared.end
            ),
        }
    }
}

impl SpanDefect {
    /// Stable machine-readable class name (JSON reports, tests).
    pub fn class(&self) -> &'static str {
        match self {
            SpanDefect::Inverted { .. } => "inverted",
            SpanDefect::OutOfBounds { .. } => "out-of-bounds",
            SpanDefect::Overlap { .. } => "overlap",
            SpanDefect::Gap { .. } => "gap",
            SpanDefect::LengthMismatch { .. } => "length-mismatch",
            SpanDefect::OpSpanMismatch { .. } => "op-span-mismatch",
        }
    }
}

/// The structured result of a span verification pass.
#[derive(Debug, Clone)]
pub struct SpanReport {
    /// Architecture name (empty when verifying a bare layer table).
    pub arch: String,
    pub layers: usize,
    pub total_params: usize,
    pub defects: Vec<SpanDefect>,
}

impl SpanReport {
    pub fn is_clean(&self) -> bool {
        self.defects.is_empty()
    }

    /// Human-readable multi-line report.
    pub fn to_text(&self) -> String {
        let head = format!(
            "{}: {} layers, {} parameters — ",
            if self.arch.is_empty() { "<layer table>" } else { &self.arch },
            self.layers,
            self.total_params
        );
        if self.is_clean() {
            return format!("{head}spans in-bounds, disjoint, exact cover: OK");
        }
        let mut out = format!("{head}{} defect(s)", self.defects.len());
        for d in &self.defects {
            out.push_str("\n  - ");
            out.push_str(&d.to_string());
        }
        out
    }

    /// Structured JSON (the CLI's `--json` output).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::str("chaos.analyze.spans/v1")),
            ("arch", Json::str(self.arch.clone())),
            ("layers", Json::num(self.layers as f64)),
            ("total_params", Json::num(self.total_params as f64)),
            ("clean", Json::Bool(self.is_clean())),
            (
                "defects",
                Json::arr(
                    self.defects
                        .iter()
                        .map(|d| {
                            Json::obj(vec![
                                ("class", Json::str(d.class())),
                                ("detail", Json::str(d.to_string())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Whether `r` is a well-formed, in-bounds, non-inverted range — defects
/// about malformed ranges are reported separately and excluded from the
/// overlap/coverage passes so one broken span doesn't cascade.
fn well_formed(r: &Range<usize>, total: usize) -> bool {
    r.start <= r.end && r.end <= total
}

/// Verify a layer table's parameter spans against a vector of
/// `total_params` parameters: every span in-bounds, spans pairwise
/// disjoint, and their union exactly `0..total_params`. Returns every
/// defect found (empty = contract holds).
pub fn verify_spans(dims: &[LayerDims], total_params: usize) -> Vec<SpanDefect> {
    let mut defects = Vec::new();
    for (i, d) in dims.iter().enumerate() {
        let r = &d.params;
        if r.start > r.end {
            defects.push(SpanDefect::Inverted { layer: i, range: r.clone() });
            continue;
        }
        if r.end > total_params {
            defects.push(SpanDefect::OutOfBounds {
                layer: i,
                range: r.clone(),
                total: total_params,
            });
        }
        if r.len() != d.param_count() {
            defects.push(SpanDefect::LengthMismatch {
                layer: i,
                span_len: r.len(),
                param_count: d.param_count(),
            });
        }
    }

    // Disjointness + exact cover over the well-formed, non-empty spans.
    let mut spans: Vec<(usize, Range<usize>)> = dims
        .iter()
        .enumerate()
        .filter(|(_, d)| !d.params.is_empty() && well_formed(&d.params, total_params))
        .map(|(i, d)| (i, d.params.clone()))
        .collect();
    spans.sort_by_key(|(_, r)| (r.start, r.end));

    let mut covered = 0usize; // everything below this offset is owned
    let mut owner = 0usize; // layer owning the span that ends at `covered`
    for (i, r) in &spans {
        if r.start < covered {
            defects.push(SpanDefect::Overlap {
                layer_a: owner,
                layer_b: *i,
                range_a: dims[owner].params.clone(),
                range_b: r.clone(),
            });
        } else if r.start > covered {
            defects.push(SpanDefect::Gap { start: covered, end: r.start });
        }
        if r.end > covered {
            covered = r.end;
            owner = *i;
        }
    }
    if covered < total_params {
        defects.push(SpanDefect::Gap { start: covered, end: total_params });
    }
    defects
}

/// Verify a compiled network: the layout contract of [`verify_spans`]
/// plus the cross-check that every compiled op's
/// [`param_range`](crate::nn::LayerOp::param_range) agrees with the
/// layout table (parameter-free ops may report any empty range).
pub fn verify_network(net: &Network) -> SpanReport {
    let mut defects = verify_spans(&net.dims, net.total_params);
    for (i, (op, d)) in net.ops.iter().zip(&net.dims).enumerate() {
        let op_range = op.param_range();
        if op_range.is_empty() && d.params.is_empty() {
            continue;
        }
        if op_range != d.params {
            defects.push(SpanDefect::OpSpanMismatch {
                layer: i,
                op: op_range,
                declared: d.params.clone(),
            });
        }
    }
    SpanReport {
        arch: net.arch.name.clone(),
        layers: net.dims.len(),
        total_params: net.total_params,
        defects,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchSpec;
    use crate::nn::compute_dims;

    fn defect_classes(defects: &[SpanDefect]) -> Vec<&'static str> {
        defects.iter().map(|d| d.class()).collect()
    }

    #[test]
    fn paper_archs_are_clean() {
        for name in crate::config::PAPER_ARCHS.into_iter().chain(["tiny"]) {
            let net = Network::from_name(name).unwrap();
            let report = verify_network(&net);
            assert!(report.is_clean(), "{name}: {}", report.to_text());
            assert!(report.to_text().contains("OK"));
        }
    }

    /// Doctored layer tables seed each static defect class; the verifier
    /// must name every one.
    #[test]
    fn seeded_defects_are_detected() {
        let arch = ArchSpec::tiny();
        let clean = compute_dims(&arch);
        let total = crate::nn::total_params(&clean);
        assert!(verify_spans(&clean, total).is_empty(), "baseline must be clean");

        // Overlapping spans: shift layer 3's span down into layer 1's.
        let mut dims = clean.clone();
        let shift = 2usize;
        dims[3].params = dims[3].params.start - shift..dims[3].params.end - shift;
        let defects = verify_spans(&dims, total);
        assert!(
            defect_classes(&defects).contains(&"overlap"),
            "overlap not detected: {defects:?}"
        );

        // Out-of-bounds span: extend the last layer past the vector end.
        let mut dims = clean.clone();
        let last = dims.len() - 1;
        dims[last].params = dims[last].params.start..total + 7;
        let defects = verify_spans(&dims, total);
        assert!(
            defect_classes(&defects).contains(&"out-of-bounds"),
            "out-of-bounds not detected: {defects:?}"
        );

        // Coverage gap: shrink a middle span so parameters go unowned.
        let mut dims = clean.clone();
        dims[1].params = dims[1].params.start..dims[1].params.end - 3;
        dims[1].weights -= 3; // keep length consistent so only the gap fires
        let defects = verify_spans(&dims, total);
        assert!(defect_classes(&defects).contains(&"gap"), "gap not detected: {defects:?}");

        // Length mismatch: span length disagrees with weights + biases.
        let mut dims = clean.clone();
        dims[1].weights += 5;
        let defects = verify_spans(&dims, total);
        assert!(
            defect_classes(&defects).contains(&"length-mismatch"),
            "length mismatch not detected: {defects:?}"
        );

        // Inverted span.
        let mut dims = clean;
        dims[1].params = dims[1].params.end..dims[1].params.start;
        let defects = verify_spans(&dims, total);
        assert!(
            defect_classes(&defects).contains(&"inverted"),
            "inverted span not detected: {defects:?}"
        );
    }

    #[test]
    fn tail_gap_detected_when_no_layer_reaches_the_end() {
        let arch = ArchSpec::tiny();
        let dims = compute_dims(&arch);
        let total = crate::nn::total_params(&dims);
        // Pretend the vector is longer than the layout covers.
        let defects = verify_spans(&dims, total + 10);
        assert_eq!(defect_classes(&defects), vec!["gap"]);
        match &defects[0] {
            SpanDefect::Gap { start, end } => {
                assert_eq!((*start, *end), (total, total + 10));
            }
            other => panic!("expected Gap, got {other:?}"),
        }
    }

    #[test]
    fn report_text_and_json_name_defects() {
        let arch = ArchSpec::tiny();
        let mut dims = compute_dims(&arch);
        let total = crate::nn::total_params(&dims);
        let last = dims.len() - 1;
        dims[last].params = dims[last].params.start..total + 1;
        let report = SpanReport {
            arch: "doctored".into(),
            layers: dims.len(),
            total_params: total,
            defects: verify_spans(&dims, total),
        };
        assert!(!report.is_clean());
        let text = report.to_text();
        assert!(text.contains("doctored") && text.contains("exceeds"), "{text}");
        let json = report.to_json().pretty();
        assert!(json.contains("out-of-bounds"), "{json}");
        // The JSON round-trips through the parser.
        let parsed = Json::parse(&json).unwrap();
        assert_eq!(parsed.get("clean").and_then(|j| j.as_bool()), Some(false));
    }
}
