//! `chaos::analysis` — machine-checking the store's concurrency and
//! aliasing contracts.
//!
//! CHAOS's correctness rests on a contract that was, until this module,
//! entirely unchecked: every layer op declares a span in the flat
//! parameter vector, and [`SharedParams`](super::SharedParams) serializes
//! publications with per-layer locks while the Hogwild paths (§4.1,
//! strategy D) deliberately skip them. The analysis subsystem verifies
//! that discipline at three levels:
//!
//! 1. **Static span verification** ([`spans`]): a pass over a compiled
//!    network's layer table proving the declared parameter spans are
//!    in-bounds, pairwise-disjoint, and exactly cover the parameter
//!    vector, and that each compiled op's
//!    [`param_range`](crate::nn::LayerOp::param_range) agrees with the
//!    layout. Runs at [`Network::compile`](crate::nn::Network::compile) in
//!    debug builds and behind the `chaos analyze` CLI subcommand.
//!    Defect classes: inverted span, out-of-bounds span, overlapping
//!    spans, coverage gap, span/param-count length mismatch, op/layout
//!    span mismatch.
//!
//! 2. **Dynamic race / lock-discipline checking** ([`race`]): behind the
//!    `race-check` cargo feature, [`SharedParams`](super::SharedParams)
//!    records lock acquire/release, `publish_*`, `load_span` and
//!    `store_all` events into a [`race::RaceRecorder`], and every
//!    [`UpdatePolicy`](super::UpdatePolicy) declares a
//!    [`SyncContract`] (via
//!    [`UpdatePolicy::sync_contract`](super::UpdatePolicy::sync_contract)).
//!    The checker flags **wrong-lock publishes** (a `publish_scaled`
//!    range not owned by the locked layer — a hard error under the
//!    feature), **overlapping unlocked writes under a `Controlled`
//!    contract** (a race the policy did not opt into), and **publishes
//!    outside any declared span**. Clean runs are silent; the trainer
//!    asserts a defect-free store at the end of every parallel run.
//!
//! 3. **Deterministic interleaving** ([`interleave`]): a seeded
//!    cooperative scheduler that serializes worker steps at the store's
//!    publish/load yield points, so tests can *replay* adversarial
//!    orderings of the controlled and Hogwild paths reproducibly — e.g.
//!    forcing the exact read-modify-write interleaving in which pure
//!    HogWild! loses an update, and proving the per-layer locks lose
//!    none under any schedule.
//!
//! The three levels compose: the static verifier proves the *declared*
//! layout is sound, the race checker proves runtime accesses respect the
//! declarations, and the interleaver makes the nondeterministic part of
//! that proof replayable.

pub mod interleave;
pub mod race;
pub mod spans;

pub use interleave::{yield_point, Interleaver, Schedule, Trace, TraceStep};
pub use race::{RaceDefect, RaceRecorder, StoreEvent, SyncContract};
pub use spans::{verify_network, verify_spans, SpanDefect, SpanReport};
