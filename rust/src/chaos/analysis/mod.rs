//! `chaos::analysis` — machine-checking the store's concurrency and
//! aliasing contracts.
//!
//! CHAOS's correctness rests on a contract that was, until this module,
//! entirely unchecked: every layer op declares a span in the flat
//! parameter vector, and [`SharedParams`](super::SharedParams) serializes
//! publications with per-layer locks while the Hogwild paths (§4.1,
//! strategy D) deliberately skip them. The analysis subsystem verifies
//! that discipline at three levels:
//!
//! 1. **Static span verification** ([`spans`]): a pass over a compiled
//!    network's layer table proving the declared parameter spans are
//!    in-bounds, pairwise-disjoint, and exactly cover the parameter
//!    vector, and that each compiled op's
//!    [`param_range`](crate::nn::LayerOp::param_range) agrees with the
//!    layout. Runs at [`Network::compile`](crate::nn::Network::compile) in
//!    debug builds and behind the `chaos analyze` CLI subcommand.
//!    Defect classes: inverted span, out-of-bounds span, overlapping
//!    spans, coverage gap, span/param-count length mismatch, op/layout
//!    span mismatch.
//!
//! 2. **Dynamic race / lock-discipline checking** ([`race`]): behind the
//!    `race-check` cargo feature, [`SharedParams`](super::SharedParams)
//!    records lock acquire/release, `publish_*`, `load_span` and
//!    `store_all` events into a [`race::RaceRecorder`], and every
//!    [`UpdatePolicy`](super::UpdatePolicy) declares a
//!    [`SyncContract`] (via
//!    [`UpdatePolicy::sync_contract`](super::UpdatePolicy::sync_contract)).
//!    The checker flags **wrong-lock publishes** (a `publish_scaled`
//!    range not owned by the locked layer — a hard error under the
//!    feature), **overlapping unlocked writes under a `Controlled`
//!    contract** (a race the policy did not opt into), and **publishes
//!    outside any declared span**. Clean runs are silent; the trainer
//!    asserts a defect-free store at the end of every parallel run.
//!
//! 3. **Deterministic interleaving** ([`interleave`]): a seeded
//!    cooperative scheduler that serializes worker steps at the store's
//!    publish/load yield points, so tests can *replay* adversarial
//!    orderings of the controlled and Hogwild paths reproducibly — e.g.
//!    forcing the exact read-modify-write interleaving in which pure
//!    HogWild! loses an update, and proving the per-layer locks lose
//!    none under any schedule.
//!
//! 4. **Static shard planning and verification** ([`shard`]): the
//!    contract for hybrid-parallel training before any sharded runtime
//!    exists. A [`shard::ShardPlan`] partitions the span table across N
//!    (optionally weighted) shards — conv/pool/activation spans
//!    replicated (the data-parallel class), fc spans split along the
//!    output-unit axis declared by
//!    [`LayerOp::split_points`](crate::nn::LayerOp::split_points) —
//!    and [`shard::verify_shards`] proves any plan (planner-produced or
//!    hand-written) in-bounds, disjoint, exact-cover, aligned to the
//!    op-declared split points, and dataflow-clean: only activation
//!    tensors, as audited by the [`crate::nn::audit`] dims chain, cross
//!    shard boundaries. A comm cost model
//!    ([`crate::perfmodel::score_plan`]) prices each plan's predicted
//!    imbalance and cross-shard traffic. The race checker enforces the
//!    plan at runtime: installing a [`race::ShardOwnership`] table turns
//!    any publish outside the worker's declared shard into a
//!    **cross-shard-publish** defect, replayable by the interleaver.
//!
//! The levels compose: the static verifier proves the *declared* layout
//! is sound, the shard pass proves partitions of that layout are sound,
//! the race checker proves runtime accesses respect both, and the
//! interleaver makes the nondeterministic part of that proof replayable.

pub mod interleave;
pub mod race;
pub mod shard;
pub mod spans;

pub use interleave::{yield_point, Interleaver, Schedule, Trace, TraceStep};
pub use race::{
    set_worker_shard, worker_shard, RaceDefect, RaceRecorder, ShardOwnership, StoreEvent,
    SyncContract,
};
pub use shard::{
    plan_shards, plan_shards_weighted, verify_shards, LayerAssignment, ShardDefect, ShardPlan,
    ShardReport,
};
pub use spans::{verify_network, verify_spans, SpanDefect, SpanReport};
