//! Dynamic race / lock-discipline checking — level 2 of the analysis
//! subsystem.
//!
//! [`RaceRecorder`] is the event log and checker that
//! [`SharedParams`](crate::chaos::SharedParams) feeds when the crate is
//! built with `--features race-check`. Every store access — lock
//! acquire/release, locked and unlocked publication, span load, full-store
//! overwrite — is recorded, and writes are checked against the layer span
//! table (the same contract the static verifier proves for the layout)
//! and against the policy's declared [`SyncContract`]:
//!
//! * **wrong-lock publish** — a locked publication whose range is not
//!   owned by the locked layer; it serializes under the wrong mutex, so
//!   the per-layer discipline silently degrades to a race;
//! * **unlocked overlap under `Controlled`** — two temporally overlapping
//!   unlocked writes to intersecting ranges when the policy claimed the
//!   controlled discipline (a policy that wants HogWild! races declares
//!   [`SyncContract::HogwildTolerated`] and opts out of this check);
//! * **outside-span publish** — a write not contained in any single
//!   layer's declared span (crossing a layer boundary or landing in
//!   unowned territory);
//! * **out-of-bounds publish** — a write past the end of the store.
//!
//! The recorder is silent on clean runs: `defects()` stays empty and the
//! trainer's end-of-run assertion passes. Temporal extent of a write is
//! tracked with RAII [`WriteGuard`]s — an active write is one whose guard
//! is still alive, which is exactly the store's element-update loop.

use crate::nn::LayerDims;
use std::ops::Range;
use std::sync::Mutex;

/// Event-log capacity; beyond it events are counted but not stored, so a
/// long training run cannot exhaust memory through instrumentation.
const EVENT_CAP: usize = 16_384;

/// The synchronization discipline an update policy promises to follow.
/// Declared via
/// [`UpdatePolicy::sync_contract`](crate::chaos::UpdatePolicy::sync_contract)
/// and enforced by the [`RaceRecorder`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncContract {
    /// All publications are serialized — per-layer locks, turnstiles, or
    /// any other mechanism that prevents two writers from touching the
    /// same range at the same time. Overlapping unlocked writes are a
    /// defect.
    Controlled,
    /// The policy deliberately races (HogWild!, strategy D): overlapping
    /// unlocked writes are tolerated by design. Span containment is still
    /// enforced.
    HogwildTolerated,
    /// A master thread overwrites the whole vector between barrier rounds
    /// (averaged SGD, strategy B).
    StoreAll,
}

impl SyncContract {
    pub fn as_str(&self) -> &'static str {
        match self {
            SyncContract::Controlled => "controlled",
            SyncContract::HogwildTolerated => "hogwild-tolerated",
            SyncContract::StoreAll => "store-all",
        }
    }
}

/// One recorded store access.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StoreEvent {
    LockAcquired { layer: usize },
    LockReleased { layer: usize },
    PublishLocked { layer: usize, range: Range<usize> },
    PublishUnlocked { range: Range<usize> },
    Load { range: Range<usize> },
    StoreAll,
}

/// One violation of the lock discipline or span contract.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RaceDefect {
    /// A locked publication whose range is not inside the locked layer's
    /// declared span.
    WrongLockPublish { layer: usize, range: Range<usize>, span: Range<usize> },
    /// Two temporally overlapping unlocked writes to intersecting ranges
    /// under a `Controlled` contract.
    UnlockedOverlap { range: Range<usize>, other: Range<usize> },
    /// A publication not contained in any single declared span.
    OutsideSpan { range: Range<usize> },
    /// A publication past the end of the store.
    OutOfBounds { range: Range<usize>, total: usize },
}

impl std::fmt::Display for RaceDefect {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RaceDefect::WrongLockPublish { layer, range, span } => write!(
                f,
                "publish of {}..{} under layer {layer}'s lock, which owns {}..{}",
                range.start, range.end, span.start, span.end
            ),
            RaceDefect::UnlockedOverlap { range, other } => write!(
                f,
                "unlocked write {}..{} overlaps concurrent write {}..{} under a controlled contract",
                range.start, range.end, other.start, other.end
            ),
            RaceDefect::OutsideSpan { range } => write!(
                f,
                "publish of {}..{} is not contained in any declared layer span",
                range.start, range.end
            ),
            RaceDefect::OutOfBounds { range, total } => write!(
                f,
                "publish of {}..{} exceeds store length {total}",
                range.start, range.end
            ),
        }
    }
}

impl RaceDefect {
    /// Stable machine-readable class name (reports, tests).
    pub fn class(&self) -> &'static str {
        match self {
            RaceDefect::WrongLockPublish { .. } => "wrong-lock-publish",
            RaceDefect::UnlockedOverlap { .. } => "unlocked-overlap",
            RaceDefect::OutsideSpan { .. } => "outside-span",
            RaceDefect::OutOfBounds { .. } => "out-of-bounds",
        }
    }
}

/// A write whose [`WriteGuard`] is still alive.
#[derive(Debug, Clone)]
struct ActiveWrite {
    id: u64,
    range: Range<usize>,
    locked: bool,
}

struct RecState {
    contract: SyncContract,
    next_id: u64,
    active: Vec<ActiveWrite>,
    events: Vec<StoreEvent>,
    events_dropped: usize,
    defects: Vec<RaceDefect>,
}

/// The store's event log and lock-discipline checker. One per
/// [`SharedParams`](crate::chaos::SharedParams) under `race-check`; also
/// usable standalone in tests.
pub struct RaceRecorder {
    /// Per-layer declared spans (indexed by layer id, like the store's
    /// lock table).
    spans: Vec<Range<usize>>,
    total: usize,
    state: Mutex<RecState>,
}

impl RaceRecorder {
    /// Build from a layer table (the store's construction path).
    pub fn new(dims: &[LayerDims], total: usize) -> RaceRecorder {
        RaceRecorder::from_spans(dims.iter().map(|d| d.params.clone()).collect(), total)
    }

    /// Build from bare spans (tests).
    pub fn from_spans(spans: Vec<Range<usize>>, total: usize) -> RaceRecorder {
        RaceRecorder {
            spans,
            total,
            state: Mutex::new(RecState {
                contract: SyncContract::Controlled,
                next_id: 0,
                active: Vec::new(),
                events: Vec::new(),
                events_dropped: 0,
                defects: Vec::new(),
            }),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, RecState> {
        // A panicking worker must not hide every later defect behind a
        // poisoned mutex — the recorder's state is a plain log, always
        // safe to read.
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn record(st: &mut RecState, ev: StoreEvent) {
        if st.events.len() < EVENT_CAP {
            st.events.push(ev);
        } else {
            st.events_dropped += 1;
        }
    }

    /// The contract currently enforced (defaults to `Controlled`).
    pub fn contract(&self) -> SyncContract {
        self.lock().contract
    }

    /// Declare the discipline the running policy promises — called by the
    /// trainer before workers spawn.
    pub fn set_contract(&self, contract: SyncContract) {
        self.lock().contract = contract;
    }

    fn check_bounds_and_span(&self, st: &mut RecState, range: &Range<usize>) {
        if range.end > self.total || range.start > range.end {
            st.defects.push(RaceDefect::OutOfBounds { range: range.clone(), total: self.total });
            return;
        }
        let contained = self
            .spans
            .iter()
            .any(|s| !s.is_empty() && s.start <= range.start && range.end <= s.end);
        if !contained && !range.is_empty() {
            st.defects.push(RaceDefect::OutsideSpan { range: range.clone() });
        }
    }

    /// Record a locked publication (the store has just acquired layer
    /// `layer`'s lock). The returned guard spans the element-update loop;
    /// drop it when the write completes.
    pub fn locked_publish(&self, layer: usize, range: Range<usize>) -> WriteGuard<'_> {
        let mut st = self.lock();
        Self::record(&mut st, StoreEvent::LockAcquired { layer });
        Self::record(&mut st, StoreEvent::PublishLocked { layer, range: range.clone() });
        self.check_bounds_and_span(&mut st, &range);
        let span = self.spans.get(layer).cloned().unwrap_or(0..0);
        let owned = span.start <= range.start && range.end <= span.end;
        if !owned && !(range.is_empty() && span.is_empty()) {
            st.defects.push(RaceDefect::WrongLockPublish { layer, range: range.clone(), span });
        }
        // A locked write racing an *unlocked* write is the unlocked side's
        // violation under Controlled; report it against the unlocked range.
        if st.contract == SyncContract::Controlled {
            let hits: Vec<Range<usize>> = st
                .active
                .iter()
                .filter(|a| !a.locked && overlap(&a.range, &range))
                .map(|a| a.range.clone())
                .collect();
            for other in hits {
                st.defects.push(RaceDefect::UnlockedOverlap { range: other, other: range.clone() });
            }
        }
        self.push_active(&mut st, range, true, Some(layer))
    }

    /// Record an unlocked publication. Under a `Controlled` contract, any
    /// temporal overlap with another active write to an intersecting range
    /// is a defect.
    pub fn unlocked_publish(&self, range: Range<usize>) -> WriteGuard<'_> {
        let mut st = self.lock();
        Self::record(&mut st, StoreEvent::PublishUnlocked { range: range.clone() });
        self.check_bounds_and_span(&mut st, &range);
        if st.contract == SyncContract::Controlled {
            let hits: Vec<Range<usize>> = st
                .active
                .iter()
                .filter(|a| overlap(&a.range, &range))
                .map(|a| a.range.clone())
                .collect();
            for other in hits {
                st.defects.push(RaceDefect::UnlockedOverlap { range: range.clone(), other });
            }
        }
        self.push_active(&mut st, range, false, None)
    }

    fn push_active(
        &self,
        st: &mut RecState,
        range: Range<usize>,
        locked: bool,
        layer: Option<usize>,
    ) -> WriteGuard<'_> {
        let id = st.next_id;
        st.next_id += 1;
        st.active.push(ActiveWrite { id, range, locked });
        WriteGuard { rec: self, id, layer }
    }

    /// Record an on-demand span read.
    pub fn record_load(&self, range: Range<usize>) {
        let mut st = self.lock();
        Self::record(&mut st, StoreEvent::Load { range });
    }

    /// Record a full-store overwrite (averaged-SGD master step).
    pub fn record_store_all(&self) {
        let mut st = self.lock();
        Self::record(&mut st, StoreEvent::StoreAll);
    }

    /// All defects found so far (empty on a clean run).
    pub fn defects(&self) -> Vec<RaceDefect> {
        self.lock().defects.clone()
    }

    pub fn is_clean(&self) -> bool {
        self.lock().defects.is_empty()
    }

    /// The recorded event log (capped at [`EVENT_CAP`] entries; see
    /// [`RaceRecorder::events_dropped`]).
    pub fn events(&self) -> Vec<StoreEvent> {
        self.lock().events.clone()
    }

    /// Number of events that arrived after the log filled.
    pub fn events_dropped(&self) -> usize {
        self.lock().events_dropped
    }
}

impl std::fmt::Debug for RaceRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let st = self.lock();
        write!(
            f,
            "RaceRecorder(layers={}, total={}, contract={}, events={}, defects={})",
            self.spans.len(),
            self.total,
            st.contract.as_str(),
            st.events.len(),
            st.defects.len()
        )
    }
}

fn overlap(a: &Range<usize>, b: &Range<usize>) -> bool {
    a.start < b.end && b.start < a.end
}

/// RAII handle marking a write as active; dropping it ends the write's
/// temporal extent (and records the lock release for locked writes).
pub struct WriteGuard<'a> {
    rec: &'a RaceRecorder,
    id: u64,
    layer: Option<usize>,
}

impl Drop for WriteGuard<'_> {
    fn drop(&mut self) {
        let mut st = self.rec.lock();
        st.active.retain(|a| a.id != self.id);
        if let Some(layer) = self.layer {
            RaceRecorder::record(&mut st, StoreEvent::LockReleased { layer });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchSpec;
    use crate::nn::compute_dims;

    fn recorder_for_tiny() -> (RaceRecorder, Vec<Range<usize>>) {
        let dims = compute_dims(&ArchSpec::tiny());
        let total = crate::nn::total_params(&dims);
        let spans: Vec<Range<usize>> = dims.iter().map(|d| d.params.clone()).collect();
        (RaceRecorder::new(&dims, total), spans)
    }

    fn classes(defects: &[RaceDefect]) -> Vec<&'static str> {
        defects.iter().map(|d| d.class()).collect()
    }

    #[test]
    fn clean_controlled_sequence_is_silent() {
        let (rec, spans) = recorder_for_tiny();
        assert_eq!(rec.contract(), SyncContract::Controlled);
        for (layer, span) in spans.iter().enumerate().filter(|(_, s)| !s.is_empty()) {
            rec.record_load(span.clone());
            let g = rec.locked_publish(layer, span.clone());
            drop(g);
        }
        assert!(rec.is_clean(), "{:?}", rec.defects());
        // Lock events bracket every publication.
        let events = rec.events();
        let acquires = events.iter().filter(|e| matches!(e, StoreEvent::LockAcquired { .. }));
        let releases = events.iter().filter(|e| matches!(e, StoreEvent::LockReleased { .. }));
        assert_eq!(acquires.count(), releases.count());
    }

    #[test]
    fn wrong_lock_publish_detected() {
        let (rec, spans) = recorder_for_tiny();
        // Publish layer 3's range while holding layer 1's lock.
        let g = rec.locked_publish(1, spans[3].clone());
        drop(g);
        let defects = rec.defects();
        assert!(
            classes(&defects).contains(&"wrong-lock-publish"),
            "not detected: {defects:?}"
        );
        match &defects[0] {
            RaceDefect::WrongLockPublish { layer, range, span } => {
                assert_eq!(*layer, 1);
                assert_eq!(*range, spans[3]);
                assert_eq!(*span, spans[1]);
            }
            other => panic!("expected WrongLockPublish, got {other:?}"),
        }
    }

    #[test]
    fn unlocked_overlap_flagged_under_controlled_only() {
        let (rec, spans) = recorder_for_tiny();
        let r = spans[1].clone();
        let g1 = rec.unlocked_publish(r.clone());
        let g2 = rec.unlocked_publish(r.clone());
        drop(g2);
        drop(g1);
        let defects = rec.defects();
        assert_eq!(classes(&defects), vec!["unlocked-overlap"], "{defects:?}");

        // The same interleaving is tolerated under a HogWild! contract.
        let (rec, _) = recorder_for_tiny();
        rec.set_contract(SyncContract::HogwildTolerated);
        let g1 = rec.unlocked_publish(r.clone());
        let g2 = rec.unlocked_publish(r.clone());
        drop(g2);
        drop(g1);
        assert!(rec.is_clean(), "{:?}", rec.defects());
    }

    #[test]
    fn sequential_unlocked_writes_are_controlled_clean() {
        // Temporal separation is what Controlled demands — the turnstile
        // policy (delayed-rr) publishes unlocked but never concurrently.
        let (rec, spans) = recorder_for_tiny();
        for _ in 0..3 {
            let g = rec.unlocked_publish(spans[1].clone());
            drop(g);
        }
        assert!(rec.is_clean(), "{:?}", rec.defects());
    }

    #[test]
    fn disjoint_concurrent_unlocked_writes_are_clean() {
        let (rec, spans) = recorder_for_tiny();
        let g1 = rec.unlocked_publish(spans[1].clone());
        let g2 = rec.unlocked_publish(spans[3].clone());
        drop(g1);
        drop(g2);
        assert!(rec.is_clean(), "{:?}", rec.defects());
    }

    #[test]
    fn outside_span_and_out_of_bounds_detected() {
        let (rec, spans) = recorder_for_tiny();
        // A range straddling the layer-1/layer-3 boundary fits no single
        // span (layer 2 is a parameter-free pool).
        let straddle = spans[1].end - 1..spans[3].start + 1;
        let g = rec.unlocked_publish(straddle);
        drop(g);
        assert_eq!(classes(&rec.defects()), vec!["outside-span"]);

        let (rec, _) = recorder_for_tiny();
        let total = rec.total;
        let g = rec.unlocked_publish(total - 1..total + 4);
        drop(g);
        assert_eq!(classes(&rec.defects()), vec!["out-of-bounds"]);
    }

    #[test]
    fn locked_write_racing_unlocked_write_is_flagged() {
        let (rec, spans) = recorder_for_tiny();
        let g1 = rec.unlocked_publish(spans[1].clone());
        let g2 = rec.locked_publish(1, spans[1].clone());
        drop(g2);
        drop(g1);
        assert!(
            classes(&rec.defects()).contains(&"unlocked-overlap"),
            "{:?}",
            rec.defects()
        );
    }

    #[test]
    fn event_log_caps_without_losing_defect_detection() {
        let (rec, spans) = recorder_for_tiny();
        for _ in 0..(EVENT_CAP + 10) {
            rec.record_load(spans[1].clone());
        }
        assert_eq!(rec.events().len(), EVENT_CAP);
        assert_eq!(rec.events_dropped(), 10);
        // Defects are still found after the log fills.
        let g = rec.locked_publish(1, spans[3].clone());
        drop(g);
        assert!(!rec.is_clean());
    }
}
