//! Dynamic race / lock-discipline checking — level 2 of the analysis
//! subsystem.
//!
//! [`RaceRecorder`] is the event log and checker that
//! [`SharedParams`](crate::chaos::SharedParams) feeds when the crate is
//! built with `--features race-check`. Every store access — lock
//! acquire/release, locked and unlocked publication, span load, full-store
//! overwrite — is recorded, and writes are checked against the layer span
//! table (the same contract the static verifier proves for the layout)
//! and against the policy's declared [`SyncContract`]:
//!
//! * **wrong-lock publish** — a locked publication whose range is not
//!   owned by the locked layer; it serializes under the wrong mutex, so
//!   the per-layer discipline silently degrades to a race;
//! * **unlocked overlap under `Controlled`** — two temporally overlapping
//!   unlocked writes to intersecting ranges when the policy claimed the
//!   controlled discipline (a policy that wants HogWild! races declares
//!   [`SyncContract::HogwildTolerated`] and opts out of this check);
//! * **outside-span publish** — a write not contained in any single
//!   layer's declared span (crossing a layer boundary or landing in
//!   unowned territory);
//! * **out-of-bounds publish** — a write past the end of the store;
//! * **cross-shard publish** — when a [`ShardOwnership`] table is
//!   installed (the contract side of [`super::shard`]), a publication
//!   overlapping a parameter piece owned by a shard the publishing
//!   worker did not declare via [`set_worker_shard`].
//!
//! The recorder is silent on clean runs: `defects()` stays empty and the
//! trainer's end-of-run assertion passes. Temporal extent of a write is
//! tracked with RAII [`WriteGuard`]s — an active write is one whose guard
//! is still alive, which is exactly the store's element-update loop.
//!
//! The event log is capped ([`EVENT_CAP`] entries) so instrumentation
//! cannot exhaust memory, and the cap is *loud*: events past it are
//! counted in [`RaceRecorder::dropped_events`], surfaced in the
//! recorder's `Debug` line and the trainer's end-of-run summary, so a
//! truncated log can never masquerade as a short one.

use crate::nn::LayerDims;
use std::cell::Cell;
use std::ops::Range;
use std::sync::Mutex;

/// Event-log capacity; beyond it events are counted (never silently
/// discarded — see [`RaceRecorder::dropped_events`]) but not stored, so a
/// long training run cannot exhaust memory through instrumentation.
pub const EVENT_CAP: usize = 16_384;

/// The synchronization discipline an update policy promises to follow.
/// Declared via
/// [`UpdatePolicy::sync_contract`](crate::chaos::UpdatePolicy::sync_contract)
/// and enforced by the [`RaceRecorder`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncContract {
    /// All publications are serialized — per-layer locks, turnstiles, or
    /// any other mechanism that prevents two writers from touching the
    /// same range at the same time. Overlapping unlocked writes are a
    /// defect.
    Controlled,
    /// The policy deliberately races (HogWild!, strategy D): overlapping
    /// unlocked writes are tolerated by design. Span containment is still
    /// enforced.
    HogwildTolerated,
    /// A master thread overwrites the whole vector between barrier rounds
    /// (averaged SGD, strategy B).
    StoreAll,
}

impl SyncContract {
    pub fn as_str(&self) -> &'static str {
        match self {
            SyncContract::Controlled => "controlled",
            SyncContract::HogwildTolerated => "hogwild-tolerated",
            SyncContract::StoreAll => "store-all",
        }
    }
}

/// The shard side of the installed contract: which shard owns each split
/// parameter piece of the flat vector. Built from a verified
/// [`ShardPlan`](super::shard::ShardPlan) via
/// [`ShardPlan::ownership`](super::shard::ShardPlan::ownership) and
/// installed with [`RaceRecorder::set_shard_ownership`]. Ranges absent
/// from the table are replicated (data-parallel) territory — any worker
/// may publish there under the usual span/lock rules; listed pieces may
/// be published only by workers that declared the owning shard through
/// [`set_worker_shard`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ShardOwnership {
    /// `(absolute parameter range, owning shard)`, sorted by start.
    pieces: Vec<(Range<usize>, usize)>,
}

impl ShardOwnership {
    /// Build from `(range, shard)` pairs; empty ranges are dropped and the
    /// table is kept sorted by range start.
    pub fn new(mut pieces: Vec<(Range<usize>, usize)>) -> ShardOwnership {
        pieces.retain(|(r, _)| !r.is_empty());
        pieces.sort_by_key(|(r, _)| (r.start, r.end));
        ShardOwnership { pieces }
    }

    /// The owned pieces, sorted by range start.
    pub fn pieces(&self) -> &[(Range<usize>, usize)] {
        &self.pieces
    }

    pub fn is_empty(&self) -> bool {
        self.pieces.is_empty()
    }
}

thread_local! {
    /// The shard the current thread publishes for (`None` = not a sharded
    /// worker). A per-thread declaration, not a recorder field, because
    /// shard identity is a property of the worker, exactly like the CHAOS
    /// worker id itself.
    static WORKER_SHARD: Cell<Option<usize>> = const { Cell::new(None) };
}

/// Declare which shard the current thread publishes for (`None` clears
/// the declaration). Consulted by every recorder publish check once a
/// [`ShardOwnership`] table is installed.
pub fn set_worker_shard(shard: Option<usize>) {
    WORKER_SHARD.with(|c| c.set(shard));
}

/// The current thread's declared shard, if any.
pub fn worker_shard() -> Option<usize> {
    WORKER_SHARD.with(|c| c.get())
}

/// One recorded store access.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StoreEvent {
    LockAcquired { layer: usize },
    LockReleased { layer: usize },
    PublishLocked { layer: usize, range: Range<usize> },
    PublishUnlocked { range: Range<usize> },
    Load { range: Range<usize> },
    StoreAll,
}

/// One violation of the lock discipline or span contract.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RaceDefect {
    /// A locked publication whose range is not inside the locked layer's
    /// declared span.
    WrongLockPublish { layer: usize, range: Range<usize>, span: Range<usize> },
    /// Two temporally overlapping unlocked writes to intersecting ranges
    /// under a `Controlled` contract.
    UnlockedOverlap { range: Range<usize>, other: Range<usize> },
    /// A publication not contained in any single declared span.
    OutsideSpan { range: Range<usize> },
    /// A publication past the end of the store.
    OutOfBounds { range: Range<usize>, total: usize },
    /// A publication overlapping a parameter piece owned by another shard
    /// (the publishing worker declared `shard`, or never declared one).
    CrossShardPublish {
        range: Range<usize>,
        piece: Range<usize>,
        owner: usize,
        shard: Option<usize>,
    },
}

impl std::fmt::Display for RaceDefect {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RaceDefect::WrongLockPublish { layer, range, span } => write!(
                f,
                "publish of {}..{} under layer {layer}'s lock, which owns {}..{}",
                range.start, range.end, span.start, span.end
            ),
            RaceDefect::UnlockedOverlap { range, other } => write!(
                f,
                "unlocked write {}..{} overlaps concurrent write {}..{} under a controlled contract",
                range.start, range.end, other.start, other.end
            ),
            RaceDefect::OutsideSpan { range } => write!(
                f,
                "publish of {}..{} is not contained in any declared layer span",
                range.start, range.end
            ),
            RaceDefect::OutOfBounds { range, total } => write!(
                f,
                "publish of {}..{} exceeds store length {total}",
                range.start, range.end
            ),
            RaceDefect::CrossShardPublish { range, piece, owner, shard } => {
                write!(
                    f,
                    "publish of {}..{} overlaps {}..{}, owned by shard {owner}, from a worker ",
                    range.start, range.end, piece.start, piece.end
                )?;
                match shard {
                    Some(s) => write!(f, "on shard {s}"),
                    None => write!(f, "with no declared shard"),
                }
            }
        }
    }
}

impl RaceDefect {
    /// Stable machine-readable class name (reports, tests).
    pub fn class(&self) -> &'static str {
        match self {
            RaceDefect::WrongLockPublish { .. } => "wrong-lock-publish",
            RaceDefect::UnlockedOverlap { .. } => "unlocked-overlap",
            RaceDefect::OutsideSpan { .. } => "outside-span",
            RaceDefect::OutOfBounds { .. } => "out-of-bounds",
            RaceDefect::CrossShardPublish { .. } => "cross-shard-publish",
        }
    }
}

/// A write whose [`WriteGuard`] is still alive.
#[derive(Debug, Clone)]
struct ActiveWrite {
    id: u64,
    range: Range<usize>,
    locked: bool,
}

struct RecState {
    contract: SyncContract,
    shards: Option<ShardOwnership>,
    next_id: u64,
    active: Vec<ActiveWrite>,
    events: Vec<StoreEvent>,
    events_dropped: usize,
    defects: Vec<RaceDefect>,
}

/// The store's event log and lock-discipline checker. One per
/// [`SharedParams`](crate::chaos::SharedParams) under `race-check`; also
/// usable standalone in tests.
pub struct RaceRecorder {
    /// Per-layer declared spans (indexed by layer id, like the store's
    /// lock table).
    spans: Vec<Range<usize>>,
    total: usize,
    state: Mutex<RecState>,
}

impl RaceRecorder {
    /// Build from a layer table (the store's construction path).
    pub fn new(dims: &[LayerDims], total: usize) -> RaceRecorder {
        RaceRecorder::from_spans(dims.iter().map(|d| d.params.clone()).collect(), total)
    }

    /// Build from bare spans (tests).
    pub fn from_spans(spans: Vec<Range<usize>>, total: usize) -> RaceRecorder {
        RaceRecorder {
            spans,
            total,
            state: Mutex::new(RecState {
                contract: SyncContract::Controlled,
                shards: None,
                next_id: 0,
                active: Vec::new(),
                events: Vec::new(),
                events_dropped: 0,
                defects: Vec::new(),
            }),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, RecState> {
        // A panicking worker must not hide every later defect behind a
        // poisoned mutex — the recorder's state is a plain log, always
        // safe to read.
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn record(st: &mut RecState, ev: StoreEvent) {
        if st.events.len() < EVENT_CAP {
            st.events.push(ev);
        } else {
            st.events_dropped += 1;
        }
    }

    /// The contract currently enforced (defaults to `Controlled`).
    pub fn contract(&self) -> SyncContract {
        self.lock().contract
    }

    /// Declare the discipline the running policy promises — called by the
    /// trainer before workers spawn.
    pub fn set_contract(&self, contract: SyncContract) {
        self.lock().contract = contract;
    }

    /// Install the shard side of the contract: from here on, a publish
    /// overlapping an owned piece from a worker that has not declared the
    /// owning shard (via [`set_worker_shard`]) is a
    /// [`RaceDefect::CrossShardPublish`].
    pub fn set_shard_ownership(&self, ownership: ShardOwnership) {
        self.lock().shards = Some(ownership);
    }

    /// The installed shard-ownership table, if any.
    pub fn shard_ownership(&self) -> Option<ShardOwnership> {
        self.lock().shards.clone()
    }

    fn check_shard(st: &mut RecState, range: &Range<usize>) {
        let Some(own) = st.shards.as_ref() else { return };
        if range.is_empty() {
            return;
        }
        let publisher = worker_shard();
        let hits: Vec<(Range<usize>, usize)> = own
            .pieces()
            .iter()
            .filter(|(piece, owner)| overlap(piece, range) && publisher != Some(*owner))
            .cloned()
            .collect();
        for (piece, owner) in hits {
            st.defects.push(RaceDefect::CrossShardPublish {
                range: range.clone(),
                piece,
                owner,
                shard: publisher,
            });
        }
    }

    fn check_bounds_and_span(&self, st: &mut RecState, range: &Range<usize>) {
        if range.end > self.total || range.start > range.end {
            st.defects.push(RaceDefect::OutOfBounds { range: range.clone(), total: self.total });
            return;
        }
        let contained = self
            .spans
            .iter()
            .any(|s| !s.is_empty() && s.start <= range.start && range.end <= s.end);
        if !contained && !range.is_empty() {
            st.defects.push(RaceDefect::OutsideSpan { range: range.clone() });
        }
    }

    /// Record a locked publication (the store has just acquired layer
    /// `layer`'s lock). The returned guard spans the element-update loop;
    /// drop it when the write completes.
    pub fn locked_publish(&self, layer: usize, range: Range<usize>) -> WriteGuard<'_> {
        let mut st = self.lock();
        Self::record(&mut st, StoreEvent::LockAcquired { layer });
        Self::record(&mut st, StoreEvent::PublishLocked { layer, range: range.clone() });
        self.check_bounds_and_span(&mut st, &range);
        Self::check_shard(&mut st, &range);
        let span = self.spans.get(layer).cloned().unwrap_or(0..0);
        let owned = span.start <= range.start && range.end <= span.end;
        if !owned && !(range.is_empty() && span.is_empty()) {
            st.defects.push(RaceDefect::WrongLockPublish { layer, range: range.clone(), span });
        }
        // A locked write racing an *unlocked* write is the unlocked side's
        // violation under Controlled; report it against the unlocked range.
        if st.contract == SyncContract::Controlled {
            let hits: Vec<Range<usize>> = st
                .active
                .iter()
                .filter(|a| !a.locked && overlap(&a.range, &range))
                .map(|a| a.range.clone())
                .collect();
            for other in hits {
                st.defects.push(RaceDefect::UnlockedOverlap { range: other, other: range.clone() });
            }
        }
        self.push_active(&mut st, range, true, Some(layer))
    }

    /// Record an unlocked publication. Under a `Controlled` contract, any
    /// temporal overlap with another active write to an intersecting range
    /// is a defect.
    pub fn unlocked_publish(&self, range: Range<usize>) -> WriteGuard<'_> {
        let mut st = self.lock();
        Self::record(&mut st, StoreEvent::PublishUnlocked { range: range.clone() });
        self.check_bounds_and_span(&mut st, &range);
        Self::check_shard(&mut st, &range);
        if st.contract == SyncContract::Controlled {
            let hits: Vec<Range<usize>> = st
                .active
                .iter()
                .filter(|a| overlap(&a.range, &range))
                .map(|a| a.range.clone())
                .collect();
            for other in hits {
                st.defects.push(RaceDefect::UnlockedOverlap { range: range.clone(), other });
            }
        }
        self.push_active(&mut st, range, false, None)
    }

    fn push_active(
        &self,
        st: &mut RecState,
        range: Range<usize>,
        locked: bool,
        layer: Option<usize>,
    ) -> WriteGuard<'_> {
        let id = st.next_id;
        st.next_id += 1;
        st.active.push(ActiveWrite { id, range, locked });
        WriteGuard { rec: self, id, layer }
    }

    /// Record an on-demand span read.
    pub fn record_load(&self, range: Range<usize>) {
        let mut st = self.lock();
        Self::record(&mut st, StoreEvent::Load { range });
    }

    /// Record a full-store overwrite (averaged-SGD master step).
    pub fn record_store_all(&self) {
        let mut st = self.lock();
        Self::record(&mut st, StoreEvent::StoreAll);
    }

    /// All defects found so far (empty on a clean run).
    pub fn defects(&self) -> Vec<RaceDefect> {
        self.lock().defects.clone()
    }

    pub fn is_clean(&self) -> bool {
        self.lock().defects.is_empty()
    }

    /// The recorded event log (capped at [`EVENT_CAP`] entries; see
    /// [`RaceRecorder::dropped_events`]).
    pub fn events(&self) -> Vec<StoreEvent> {
        self.lock().events.clone()
    }

    /// Number of events that arrived after the log filled. Nonzero means
    /// [`RaceRecorder::events`] is a truncated view — defect *checking*
    /// is unaffected (it never consults the log), but any analysis replay
    /// of the event stream is incomplete and must say so.
    pub fn dropped_events(&self) -> usize {
        self.lock().events_dropped
    }
}

impl std::fmt::Debug for RaceRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let st = self.lock();
        write!(
            f,
            "RaceRecorder(layers={}, total={}, contract={}, events={}, dropped={}, defects={})",
            self.spans.len(),
            self.total,
            st.contract.as_str(),
            st.events.len(),
            st.events_dropped,
            st.defects.len()
        )
    }
}

fn overlap(a: &Range<usize>, b: &Range<usize>) -> bool {
    a.start < b.end && b.start < a.end
}

/// RAII handle marking a write as active; dropping it ends the write's
/// temporal extent (and records the lock release for locked writes).
pub struct WriteGuard<'a> {
    rec: &'a RaceRecorder,
    id: u64,
    layer: Option<usize>,
}

impl Drop for WriteGuard<'_> {
    fn drop(&mut self) {
        let mut st = self.rec.lock();
        st.active.retain(|a| a.id != self.id);
        if let Some(layer) = self.layer {
            RaceRecorder::record(&mut st, StoreEvent::LockReleased { layer });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchSpec;
    use crate::nn::compute_dims;

    fn recorder_for_tiny() -> (RaceRecorder, Vec<Range<usize>>) {
        let dims = compute_dims(&ArchSpec::tiny());
        let total = crate::nn::total_params(&dims);
        let spans: Vec<Range<usize>> = dims.iter().map(|d| d.params.clone()).collect();
        (RaceRecorder::new(&dims, total), spans)
    }

    fn classes(defects: &[RaceDefect]) -> Vec<&'static str> {
        defects.iter().map(|d| d.class()).collect()
    }

    #[test]
    fn clean_controlled_sequence_is_silent() {
        let (rec, spans) = recorder_for_tiny();
        assert_eq!(rec.contract(), SyncContract::Controlled);
        for (layer, span) in spans.iter().enumerate().filter(|(_, s)| !s.is_empty()) {
            rec.record_load(span.clone());
            let g = rec.locked_publish(layer, span.clone());
            drop(g);
        }
        assert!(rec.is_clean(), "{:?}", rec.defects());
        // Lock events bracket every publication.
        let events = rec.events();
        let acquires = events.iter().filter(|e| matches!(e, StoreEvent::LockAcquired { .. }));
        let releases = events.iter().filter(|e| matches!(e, StoreEvent::LockReleased { .. }));
        assert_eq!(acquires.count(), releases.count());
    }

    #[test]
    fn wrong_lock_publish_detected() {
        let (rec, spans) = recorder_for_tiny();
        // Publish layer 3's range while holding layer 1's lock.
        let g = rec.locked_publish(1, spans[3].clone());
        drop(g);
        let defects = rec.defects();
        assert!(
            classes(&defects).contains(&"wrong-lock-publish"),
            "not detected: {defects:?}"
        );
        match &defects[0] {
            RaceDefect::WrongLockPublish { layer, range, span } => {
                assert_eq!(*layer, 1);
                assert_eq!(*range, spans[3]);
                assert_eq!(*span, spans[1]);
            }
            other => panic!("expected WrongLockPublish, got {other:?}"),
        }
    }

    #[test]
    fn unlocked_overlap_flagged_under_controlled_only() {
        let (rec, spans) = recorder_for_tiny();
        let r = spans[1].clone();
        let g1 = rec.unlocked_publish(r.clone());
        let g2 = rec.unlocked_publish(r.clone());
        drop(g2);
        drop(g1);
        let defects = rec.defects();
        assert_eq!(classes(&defects), vec!["unlocked-overlap"], "{defects:?}");

        // The same interleaving is tolerated under a HogWild! contract.
        let (rec, _) = recorder_for_tiny();
        rec.set_contract(SyncContract::HogwildTolerated);
        let g1 = rec.unlocked_publish(r.clone());
        let g2 = rec.unlocked_publish(r.clone());
        drop(g2);
        drop(g1);
        assert!(rec.is_clean(), "{:?}", rec.defects());
    }

    #[test]
    fn sequential_unlocked_writes_are_controlled_clean() {
        // Temporal separation is what Controlled demands — the turnstile
        // policy (delayed-rr) publishes unlocked but never concurrently.
        let (rec, spans) = recorder_for_tiny();
        for _ in 0..3 {
            let g = rec.unlocked_publish(spans[1].clone());
            drop(g);
        }
        assert!(rec.is_clean(), "{:?}", rec.defects());
    }

    #[test]
    fn disjoint_concurrent_unlocked_writes_are_clean() {
        let (rec, spans) = recorder_for_tiny();
        let g1 = rec.unlocked_publish(spans[1].clone());
        let g2 = rec.unlocked_publish(spans[3].clone());
        drop(g1);
        drop(g2);
        assert!(rec.is_clean(), "{:?}", rec.defects());
    }

    #[test]
    fn outside_span_and_out_of_bounds_detected() {
        let (rec, spans) = recorder_for_tiny();
        // A range straddling the layer-1/layer-3 boundary fits no single
        // span (layer 2 is a parameter-free pool).
        let straddle = spans[1].end - 1..spans[3].start + 1;
        let g = rec.unlocked_publish(straddle);
        drop(g);
        assert_eq!(classes(&rec.defects()), vec!["outside-span"]);

        let (rec, _) = recorder_for_tiny();
        let total = rec.total;
        let g = rec.unlocked_publish(total - 1..total + 4);
        drop(g);
        assert_eq!(classes(&rec.defects()), vec!["out-of-bounds"]);
    }

    #[test]
    fn locked_write_racing_unlocked_write_is_flagged() {
        let (rec, spans) = recorder_for_tiny();
        let g1 = rec.unlocked_publish(spans[1].clone());
        let g2 = rec.locked_publish(1, spans[1].clone());
        drop(g2);
        drop(g1);
        assert!(
            classes(&rec.defects()).contains(&"unlocked-overlap"),
            "{:?}",
            rec.defects()
        );
    }

    #[test]
    fn event_log_caps_without_losing_defect_detection() {
        let (rec, spans) = recorder_for_tiny();
        for _ in 0..(EVENT_CAP + 10) {
            rec.record_load(spans[1].clone());
        }
        assert_eq!(rec.events().len(), EVENT_CAP);
        assert_eq!(rec.dropped_events(), 10);
        // The truncation is visible, not silent: the recorder's Debug
        // line (what the trainer summary prints) names the dropped count.
        assert!(format!("{rec:?}").contains("dropped=10"), "{rec:?}");
        // Defects are still found after the log fills.
        let g = rec.locked_publish(1, spans[3].clone());
        drop(g);
        assert!(!rec.is_clean());
        assert_eq!(rec.dropped_events(), 10 + 3); // publish = 3 more events
    }

    #[test]
    fn cross_shard_publish_detected_only_with_ownership_installed() {
        let (rec, spans) = recorder_for_tiny();
        // Without an ownership table, shard identity is irrelevant.
        set_worker_shard(Some(0));
        drop(rec.unlocked_publish(spans[3].clone()));
        assert!(rec.is_clean(), "{:?}", rec.defects());

        // Split layer 3's span between shards 0 and 1; this thread is
        // shard 0, so publishing shard 1's half is a defect.
        let mid = (spans[3].start + spans[3].end) / 2;
        rec.set_shard_ownership(ShardOwnership::new(vec![
            (spans[3].start..mid, 0),
            (mid..spans[3].end, 1),
        ]));
        drop(rec.unlocked_publish(spans[3].start..mid));
        assert!(rec.is_clean(), "{:?}", rec.defects());
        drop(rec.unlocked_publish(mid..spans[3].end));
        let defects = rec.defects();
        assert_eq!(classes(&defects), vec!["cross-shard-publish"], "{defects:?}");
        match &defects[0] {
            RaceDefect::CrossShardPublish { owner, shard, .. } => {
                assert_eq!(*owner, 1);
                assert_eq!(*shard, Some(0));
            }
            other => panic!("expected CrossShardPublish, got {other:?}"),
        }
        set_worker_shard(None);
    }

    #[test]
    fn undeclared_worker_cannot_publish_owned_pieces() {
        let (rec, spans) = recorder_for_tiny();
        set_worker_shard(None);
        rec.set_shard_ownership(ShardOwnership::new(vec![(spans[1].clone(), 0)]));
        // Replicated territory (layer 3 is not in the table) stays open…
        drop(rec.unlocked_publish(spans[3].clone()));
        // …but the owned piece requires a declared shard.
        drop(rec.unlocked_publish(spans[1].clone()));
        let defects = rec.defects();
        assert_eq!(classes(&defects), vec!["cross-shard-publish"], "{defects:?}");
        match &defects[0] {
            RaceDefect::CrossShardPublish { shard, .. } => assert_eq!(*shard, None),
            other => panic!("expected CrossShardPublish, got {other:?}"),
        }
    }
}
