//! Deterministic interleaving — level 3 of the analysis subsystem.
//!
//! [`Interleaver::run`] executes a set of worker closures under a
//! cooperative scheduler: exactly one worker runs at a time, and control
//! only transfers at [`yield_point`] calls (and at worker start/exit). The
//! next worker is chosen either by a scripted order ([`Schedule::Script`])
//! or by a seeded PRNG ([`Schedule::Seeded`]), so any adversarial ordering
//! of the store's publish/load steps can be *replayed* — the
//! nondeterministic half of a race report becomes a reproducible test.
//! Under `--features race-check`, [`SharedParams`](crate::chaos::SharedParams)
//! places yield points before lock acquisition, inside the unlocked
//! read-modify-write, and at span loads; outside an interleaved run those
//! calls are no-ops.
//!
//! **Discipline:** a worker must never yield while holding a lock another
//! worker might take — with one-at-a-time execution, the suspended holder
//! can never be resumed to release it. The store's instrumentation
//! therefore yields *before* acquiring a layer lock, never inside it.

use crate::util::Pcg32;
use std::cell::RefCell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};

/// How the scheduler picks the next worker at each yield.
#[derive(Debug, Clone)]
pub enum Schedule {
    /// Seeded PRNG pick among the runnable workers — reproducible
    /// adversarial fuzzing.
    Seeded(u64),
    /// Explicit worker ids, consumed left to right; entries naming a
    /// finished (or not-yet-yielded) worker are skipped, and when the
    /// script runs dry the lowest runnable id continues. `Script(vec![])`
    /// is round-robin-by-lowest-id.
    Script(Vec<usize>),
}

/// One scheduling decision: worker `worker` was granted the step tagged
/// `tag` (the tag of the yield point it was resumed at, or `"start"` /
/// `"exit"` at its boundaries).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceStep {
    pub worker: usize,
    pub tag: &'static str,
}

/// The full schedule actually executed — compare against an expected
/// ordering, or log it to reproduce a failure.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Trace {
    pub steps: Vec<TraceStep>,
}

impl Trace {
    /// The worker ids in execution order (tags stripped).
    pub fn order(&self) -> Vec<usize> {
        self.steps.iter().map(|s| s.worker).collect()
    }
}

struct State {
    /// The worker currently holding the execution token.
    current: Option<usize>,
    /// Worker is parked at a yield point (or its starting line) and can be
    /// granted the token.
    waiting: Vec<bool>,
    finished: Vec<bool>,
    script: VecDeque<usize>,
    rng: Option<Pcg32>,
    trace: Trace,
}

struct Shared {
    state: Mutex<State>,
    cv: Condvar,
}

impl Shared {
    fn new(n: usize, schedule: Schedule) -> Shared {
        let (script, rng) = match schedule {
            Schedule::Script(s) => (s.into(), None),
            Schedule::Seeded(seed) => (VecDeque::new(), Some(Pcg32::seeded(seed))),
        };
        Shared {
            state: Mutex::new(State {
                current: None,
                waiting: vec![true; n],
                finished: vec![false; n],
                script,
                rng,
                trace: Trace::default(),
            }),
            cv: Condvar::new(),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, State> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Grant the token to the next runnable worker (script first, then
    /// seeded pick, then lowest id). No-op when nothing is runnable.
    fn pick_next(st: &mut State) {
        let runnable: Vec<usize> = (0..st.waiting.len())
            .filter(|&i| st.waiting[i] && !st.finished[i])
            .collect();
        if runnable.is_empty() {
            st.current = None;
            return;
        }
        while let Some(w) = st.script.pop_front() {
            if runnable.contains(&w) {
                st.current = Some(w);
                return;
            }
        }
        st.current = Some(match &mut st.rng {
            Some(rng) => runnable[rng.range(0, runnable.len())],
            None => runnable[0],
        });
    }

    /// Park at a yield point until the scheduler grants this worker the
    /// token again.
    fn yield_at(&self, id: usize, tag: &'static str) {
        let mut st = self.lock();
        st.waiting[id] = true;
        st.current = None;
        Self::pick_next(&mut st);
        self.cv.notify_all();
        while st.current != Some(id) {
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        st.waiting[id] = false;
        st.trace.steps.push(TraceStep { worker: id, tag });
    }

    /// Block until the scheduler grants this worker its first step.
    fn wait_for_start(&self, id: usize) {
        let mut st = self.lock();
        while st.current != Some(id) {
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        st.waiting[id] = false;
        st.trace.steps.push(TraceStep { worker: id, tag: "start" });
    }

    /// Worker exit: release the token and reschedule so the remaining
    /// workers keep running.
    fn finish(&self, id: usize) {
        let mut st = self.lock();
        st.finished[id] = true;
        st.waiting[id] = false;
        st.trace.steps.push(TraceStep { worker: id, tag: "exit" });
        if st.current == Some(id) {
            st.current = None;
        }
        Self::pick_next(&mut st);
        self.cv.notify_all();
    }
}

thread_local! {
    /// The interleaver context of the current thread, if it is an
    /// interleaved worker.
    static WORKER: RefCell<Option<(Arc<Shared>, usize)>> = const { RefCell::new(None) };
}

/// A serialization point: inside an [`Interleaver::run`] worker, parks the
/// worker and lets the schedule pick who runs next; on any other thread
/// (normal training, tests without an interleaver) this is a no-op.
/// `tag` labels the step in the [`Trace`].
pub fn yield_point(tag: &'static str) {
    let ctx = WORKER.with(|w| w.borrow().clone());
    if let Some((shared, id)) = ctx {
        shared.yield_at(id, tag);
    }
}

/// The cooperative scheduler. See the module docs for the execution model.
pub struct Interleaver;

impl Interleaver {
    /// Run `workers` to completion under `schedule`, one at a time,
    /// switching only at [`yield_point`]s and worker boundaries. Returns
    /// the executed [`Trace`]. A panicking worker unwinds out of `run`
    /// after the remaining workers finish.
    pub fn run<'a>(schedule: Schedule, workers: Vec<Box<dyn FnOnce() + Send + 'a>>) -> Trace {
        let shared = Arc::new(Shared::new(workers.len(), schedule));
        let mut first_panic = None;
        std::thread::scope(|s| {
            let handles: Vec<_> = workers
                .into_iter()
                .enumerate()
                .map(|(id, f)| {
                    let sh = Arc::clone(&shared);
                    s.spawn(move || {
                        sh.wait_for_start(id);
                        WORKER.with(|w| *w.borrow_mut() = Some((Arc::clone(&sh), id)));
                        let result = catch_unwind(AssertUnwindSafe(f));
                        WORKER.with(|w| *w.borrow_mut() = None);
                        sh.finish(id);
                        result
                    })
                })
                .collect();
            // Initial grant: every worker starts parked on its start line.
            {
                let mut st = shared.lock();
                Shared::pick_next(&mut st);
            }
            shared.cv.notify_all();
            for h in handles {
                if let Err(payload) = h.join().expect("interleaved worker thread died") {
                    first_panic.get_or_insert(payload);
                }
            }
        });
        if let Some(payload) = first_panic {
            resume_unwind(payload);
        }
        match Arc::try_unwrap(shared) {
            Ok(sh) => sh.state.into_inner().unwrap_or_else(|e| e.into_inner()).trace,
            Err(_) => unreachable!("every worker joined and dropped its scheduler handle"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Two workers each appending their id twice, strictly alternating
    /// under a script — the trace and the data agree with the script.
    #[test]
    fn scripted_schedule_is_exact() {
        let log = Mutex::new(Vec::new());
        let mk = |id: usize| {
            let log = &log;
            Box::new(move || {
                log.lock().unwrap().push(id);
                yield_point("step");
                log.lock().unwrap().push(id);
            }) as Box<dyn FnOnce() + Send>
        };
        let trace = Interleaver::run(Schedule::Script(vec![0, 1, 0, 1]), vec![mk(0), mk(1)]);
        assert_eq!(*log.lock().unwrap(), vec![0, 1, 0, 1]);
        // start0, start1, resume0, exit0 (recorded by the finishing worker
        // before the next grant), resume1, exit1.
        assert_eq!(trace.order(), vec![0, 1, 0, 0, 1, 1]);
        let tags: Vec<&str> = trace.steps.iter().map(|s| s.tag).collect();
        assert_eq!(tags, vec!["start", "start", "step", "exit", "step", "exit"]);
    }

    #[test]
    fn empty_script_runs_lowest_id_to_completion() {
        let log = Mutex::new(Vec::new());
        let mk = |id: usize| {
            let log = &log;
            Box::new(move || {
                log.lock().unwrap().push(id);
                yield_point("step");
                log.lock().unwrap().push(id);
            }) as Box<dyn FnOnce() + Send>
        };
        Interleaver::run(Schedule::Script(vec![]), vec![mk(0), mk(1)]);
        assert_eq!(*log.lock().unwrap(), vec![0, 0, 1, 1]);
    }

    #[test]
    fn seeded_schedule_is_reproducible() {
        let run = |seed: u64| {
            let log = Mutex::new(Vec::new());
            let mk = |id: usize| {
                let log = &log;
                Box::new(move || {
                    for _ in 0..4 {
                        log.lock().unwrap().push(id);
                        yield_point("step");
                    }
                }) as Box<dyn FnOnce() + Send>
            };
            let trace = Interleaver::run(Schedule::Seeded(seed), vec![mk(0), mk(1), mk(2)]);
            (std::mem::take(&mut *log.lock().unwrap()), trace)
        };
        let (log_a, trace_a) = run(42);
        let (log_b, trace_b) = run(42);
        assert_eq!(log_a, log_b, "same seed must replay the same interleaving");
        assert_eq!(trace_a, trace_b);
        // Some seed in a small pool produces a different order (the
        // scheduler is actually randomized, not round-robin in disguise).
        assert!(
            (0..20u64).any(|s| run(s).0 != log_a),
            "20 seeds all gave one interleaving"
        );
    }

    #[test]
    fn yield_point_outside_interleaver_is_noop() {
        yield_point("free-running"); // must not hang or panic
    }

    #[test]
    fn single_worker_runs_through_all_yields() {
        let n = AtomicUsize::new(0);
        let trace = Interleaver::run(
            Schedule::Seeded(7),
            vec![Box::new(|| {
                for _ in 0..3 {
                    n.fetch_add(1, Ordering::Relaxed);
                    yield_point("tick");
                }
            })],
        );
        assert_eq!(n.load(Ordering::Relaxed), 3);
        assert_eq!(trace.order(), vec![0; 5]); // start + 3 ticks + exit
    }

    #[test]
    fn worker_panic_propagates_after_others_finish() {
        let survivor_done = AtomicUsize::new(0);
        let result = catch_unwind(AssertUnwindSafe(|| {
            Interleaver::run(
                Schedule::Script(vec![0, 1]),
                vec![
                    Box::new(|| {
                        yield_point("a");
                        panic!("seeded worker failure");
                    }),
                    Box::new(|| {
                        yield_point("b");
                        survivor_done.fetch_add(1, Ordering::Relaxed);
                    }),
                ],
            );
        }));
        assert!(result.is_err(), "worker panic must unwind out of run()");
        assert_eq!(
            survivor_done.load(Ordering::Relaxed),
            1,
            "the non-panicking worker must still complete"
        );
    }
}
