//! Static shard planning and verification — level 4 of the analysis
//! subsystem, and the contract the hybrid-parallel runtime refactor
//! builds against.
//!
//! The hybrid scheme (Krizhevsky, arXiv:1404.5997) runs the conv stage
//! data-parallel — every shard holds a full copy of the conv/pool spans
//! and processes its own slice of the batch — and the parameter-heavy
//! fully-connected stage model-parallel: each fc span is cut along the
//! output-unit axis declared by
//! [`LayerOp::split_points`](crate::nn::LayerOp::split_points), so each
//! shard owns a block of weight rows plus the matching bias elements and
//! only *activations* cross shard boundaries. Heterogeneous workers get
//! weighted shards (Marques et al., arXiv:1712.02546): the planner
//! apportions both sample share (data-parallel stage) and output units
//! (model-parallel stage) by per-shard weight factors.
//!
//! Three parts:
//!
//! * **Planner** — [`plan_shards`] / [`plan_shards_weighted`] partition a
//!   compiled network's span table into a [`ShardPlan`];
//! * **Verifier** — [`verify_shards`] proves a plan (planner-produced or
//!   hand-written) in-bounds, disjoint, an exact cover of every split
//!   span, aligned to the op-declared split points, and dataflow-clean
//!   against the [`crate::nn::audit`] dims chain. Defects carry stable
//!   class tags mirroring [`super::spans`];
//! * **Cost model** — clean plans are priced by
//!   [`crate::perfmodel::score_plan`]: per-shard FLOP/param totals from
//!   [`LayerOp::cost`](crate::nn::LayerOp::cost), per-boundary activation
//!   bytes, predicted imbalance and a proxy seconds-per-sample, so plans
//!   rank *before* any sharded runtime exists.
//!
//! The CLI face is `chaos analyze --shards N [--weights a,b,..]`
//! (schema `chaos.analyze.shard/v1`, nonzero exit on defects); the
//! runtime face is [`ShardPlan::ownership`] → installed on the race
//! checker, which turns any publish outside the worker's declared shard
//! into a recorded [`CrossShardPublish`](super::race::RaceDefect) defect.

use super::race::ShardOwnership;
use crate::nn::audit::{self, DataflowDefect};
use crate::nn::{Network, SplitSpec};
use crate::perfmodel::{score_plan, ShardScore};
use crate::util::Json;
use std::ops::Range;

/// How one layer's parameter span is laid out across the shards of a plan.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LayerAssignment {
    /// Data-parallel class: every shard holds the full span (conv, pool,
    /// dropout, input — and any kind that declares itself unsplittable).
    Replicated,
    /// A hand-written plan may spell the replicas out, one absolute range
    /// per shard. The verifier requires each copy to equal the full span:
    /// a partial copy means *parameters*, not activations, would have to
    /// cross the shard boundary.
    Copies(Vec<Range<usize>>),
    /// Model-parallel class: `pieces[s]` is the list of absolute
    /// parameter ranges shard `s` owns (for a planner-produced fc split,
    /// one weight-row block plus one bias block per shard).
    Split { pieces: Vec<Vec<Range<usize>>> },
}

impl LayerAssignment {
    /// Stable class tag for reports.
    pub fn class(&self) -> &'static str {
        match self {
            LayerAssignment::Replicated => "replicated",
            LayerAssignment::Copies(_) => "copies",
            LayerAssignment::Split { .. } => "split",
        }
    }
}

/// A partition of a compiled network's span table across `shards` shards.
/// Produced by the planner or written by hand; proven sound (or not) by
/// [`verify_shards`].
#[derive(Debug, Clone, PartialEq)]
pub struct ShardPlan {
    pub arch: String,
    pub shards: usize,
    /// Per-shard capacity share, normalized to sum 1 (uniform unless the
    /// caller passed weight factors).
    pub weights: Vec<f64>,
    /// One assignment per layer, parallel to the network's layer table.
    pub layers: Vec<LayerAssignment>,
}

impl ShardPlan {
    /// The absolute parameter ranges shard `shard` owns in `layer`
    /// (replicated layers: the whole span on every shard).
    pub fn owned_ranges(&self, net: &Network, shard: usize, layer: usize) -> Vec<Range<usize>> {
        let span = net.dims[layer].params.clone();
        match &self.layers[layer] {
            LayerAssignment::Replicated => {
                if span.is_empty() {
                    Vec::new()
                } else {
                    vec![span]
                }
            }
            LayerAssignment::Copies(copies) => match copies.get(shard) {
                Some(c) if !c.is_empty() => vec![c.clone()],
                _ => Vec::new(),
            },
            LayerAssignment::Split { pieces } => pieces.get(shard).cloned().unwrap_or_default(),
        }
    }

    /// Parameters shard `shard` owns in `layer` (an element count).
    pub fn owned_len(&self, net: &Network, shard: usize, layer: usize) -> usize {
        self.owned_ranges(net, shard, layer).iter().map(|r| r.len()).sum()
    }

    /// The runtime face of the plan: every split piece with its owning
    /// shard, ready for
    /// [`RaceRecorder::set_shard_ownership`](super::race::RaceRecorder::set_shard_ownership)
    /// (replicated spans are deliberately absent — any worker may publish
    /// there under the usual span/lock rules).
    pub fn ownership(&self) -> ShardOwnership {
        let mut pieces = Vec::new();
        for assignment in &self.layers {
            if let LayerAssignment::Split { pieces: per_shard } = assignment {
                for (shard, ranges) in per_shard.iter().enumerate() {
                    for r in ranges {
                        pieces.push((r.clone(), shard));
                    }
                }
            }
        }
        ShardOwnership::new(pieces)
    }
}

/// Partition `net` across `shards` equally-weighted shards.
pub fn plan_shards(net: &Network, shards: usize) -> ShardPlan {
    assert!(shards >= 1, "a shard plan needs at least one shard");
    plan_shards_weighted(net, &vec![1.0; shards]).expect("uniform weights are always valid")
}

/// Partition `net` across `weights.len()` shards, apportioning both the
/// data-parallel sample share and the model-parallel output units by the
/// given per-shard weight factors (largest-remainder apportionment, so
/// unit counts are exact and deterministic).
pub fn plan_shards_weighted(net: &Network, weights: &[f64]) -> anyhow::Result<ShardPlan> {
    anyhow::ensure!(!weights.is_empty(), "a shard plan needs at least one shard");
    for &w in weights {
        anyhow::ensure!(
            w.is_finite() && w > 0.0,
            "shard weight factors must be finite and positive, got {w}"
        );
    }
    let total: f64 = weights.iter().sum();
    let weights: Vec<f64> = weights.iter().map(|w| w / total).collect();
    let shards = weights.len();

    let mut layers = Vec::with_capacity(net.dims.len());
    for (op, d) in net.ops.iter().zip(&net.dims) {
        let span = d.params.clone();
        let assignment = match op.split_points() {
            SplitSpec::OutputUnits { units, weights_per_unit }
                if shards > 1 && !span.is_empty() =>
            {
                let unit_ranges = apportion(units, &weights);
                let pieces = unit_ranges
                    .iter()
                    .map(|u| unit_pieces(&span, units, weights_per_unit, u))
                    .collect();
                LayerAssignment::Split { pieces }
            }
            _ => LayerAssignment::Replicated,
        };
        layers.push(assignment);
    }
    Ok(ShardPlan { arch: net.arch.name.clone(), shards, weights, layers })
}

/// Contiguous unit ranges apportioning `units` output units to shards by
/// normalized weight (floor each share, then hand the remainder out by
/// largest fractional part; ties break toward the lower shard index).
fn apportion(units: usize, weights: &[f64]) -> Vec<Range<usize>> {
    let n = weights.len();
    let mut counts = Vec::with_capacity(n);
    let mut fracs = Vec::with_capacity(n);
    for &w in weights {
        let exact = units as f64 * w;
        let floor = exact.floor();
        counts.push(floor as usize);
        fracs.push(exact - floor);
    }
    let assigned: usize = counts.iter().sum();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| fracs[b].total_cmp(&fracs[a]).then(a.cmp(&b)));
    for i in 0..units.saturating_sub(assigned) {
        counts[order[i % n]] += 1;
    }
    let mut start = 0;
    counts
        .into_iter()
        .map(|c| {
            let r = start..start + c;
            start += c;
            r
        })
        .collect()
}

/// Absolute parameter ranges for output units `u` of a span laid out
/// weight-rows-then-biases: one weight-row block and one bias block
/// (empty blocks omitted).
fn unit_pieces(
    span: &Range<usize>,
    units: usize,
    weights_per_unit: usize,
    u: &Range<usize>,
) -> Vec<Range<usize>> {
    let mut out = Vec::with_capacity(2);
    let w = span.start + u.start * weights_per_unit..span.start + u.end * weights_per_unit;
    if !w.is_empty() {
        out.push(w);
    }
    let bias0 = span.start + units * weights_per_unit;
    let b = bias0 + u.start..bias0 + u.end;
    if !b.is_empty() {
        out.push(b);
    }
    out
}

/// One violation of the shard contract. Class tags are stable
/// machine-readable strings (reports, tests, CI), mirroring
/// [`SpanDefect`](super::spans::SpanDefect).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ShardDefect {
    /// The plan has no shards at all.
    EmptyPlan,
    /// The plan's layer table and the network's disagree in length.
    LayerCountMismatch { plan: usize, net: usize },
    /// A layer's per-shard list is not sized to the plan's shard count.
    ShardCountMismatch { layer: usize, got: usize, want: usize },
    /// A split assignment on an op that declares no legal interior cut.
    UnsplittableSplit { layer: usize, kind: String },
    /// The op's declared split geometry does not add up to its span.
    SplitSpecMismatch { layer: usize, declared: usize, span_len: usize },
    /// A piece outside its layer's span (or inverted).
    OutOfBounds { layer: usize, shard: usize, range: Range<usize>, span: Range<usize> },
    /// Two owned pieces intersect (same shard or different shards).
    Overlap { layer: usize, shard_a: usize, shard_b: usize, range: Range<usize> },
    /// Parameters of a split span no shard owns.
    Gap { layer: usize, range: Range<usize> },
    /// An output unit whose weight row / bias element is owned by more
    /// than one shard — a cut off the op-declared split points.
    StraddledSplitPoint { layer: usize, unit: usize, owners: Vec<usize> },
    /// Something other than a whole activation tensor would have to cross
    /// a shard boundary (a partial replica, or a broken activation chain
    /// at the boundary).
    NonActivationCrossing { layer: usize, detail: String },
}

impl std::fmt::Display for ShardDefect {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShardDefect::EmptyPlan => write!(f, "plan declares zero shards"),
            ShardDefect::LayerCountMismatch { plan, net } => {
                write!(f, "plan covers {plan} layers but the network has {net}")
            }
            ShardDefect::ShardCountMismatch { layer, got, want } => {
                write!(f, "layer {layer} assigns {got} shard entries, plan has {want} shards")
            }
            ShardDefect::UnsplittableSplit { layer, kind } => write!(
                f,
                "layer {layer} ({kind}) declares no legal interior cut but the plan splits it"
            ),
            ShardDefect::SplitSpecMismatch { layer, declared, span_len } => write!(
                f,
                "layer {layer} declares split geometry totalling {declared} params, span has {span_len}"
            ),
            ShardDefect::OutOfBounds { layer, shard, range, span } => write!(
                f,
                "layer {layer} shard {shard}: piece {}..{} outside span {}..{}",
                range.start, range.end, span.start, span.end
            ),
            ShardDefect::Overlap { layer, shard_a, shard_b, range } => {
                if shard_a == shard_b {
                    write!(
                        f,
                        "layer {layer}: shard {shard_a} owns {}..{} twice",
                        range.start, range.end
                    )
                } else {
                    write!(
                        f,
                        "layer {layer}: piece {}..{} of shard {shard_b} overlaps shard {shard_a}",
                        range.start, range.end
                    )
                }
            }
            ShardDefect::Gap { layer, range } => write!(
                f,
                "layer {layer}: params {}..{} of a split span are owned by no shard",
                range.start, range.end
            ),
            ShardDefect::StraddledSplitPoint { layer, unit, owners } => write!(
                f,
                "layer {layer}: output unit {unit} is straddled by shards {owners:?} — cuts must fall on unit boundaries"
            ),
            ShardDefect::NonActivationCrossing { layer, detail } => {
                write!(f, "layer {layer}: {detail}")
            }
        }
    }
}

impl ShardDefect {
    /// Stable machine-readable class name (reports, tests).
    pub fn class(&self) -> &'static str {
        match self {
            ShardDefect::EmptyPlan => "empty-plan",
            ShardDefect::LayerCountMismatch { .. } => "layer-count-mismatch",
            ShardDefect::ShardCountMismatch { .. } => "shard-count-mismatch",
            ShardDefect::UnsplittableSplit { .. } => "unsplittable-split",
            ShardDefect::SplitSpecMismatch { .. } => "split-spec-mismatch",
            ShardDefect::OutOfBounds { .. } => "out-of-bounds",
            ShardDefect::Overlap { .. } => "overlap",
            ShardDefect::Gap { .. } => "gap",
            ShardDefect::StraddledSplitPoint { .. } => "straddled-split-point",
            ShardDefect::NonActivationCrossing { .. } => "non-activation-crossing",
        }
    }
}

/// Per-layer summary row of a [`ShardReport`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardLayerRow {
    pub layer: usize,
    pub kind: String,
    /// `"replicated"` / `"copies"` / `"split"`.
    pub class: &'static str,
    /// Parameters each shard owns in this layer.
    pub owned: Vec<usize>,
}

/// The result of verifying (and, when clean, pricing) one plan against
/// one compiled network. Schema `chaos.analyze.shard/v1`.
#[derive(Debug, Clone)]
pub struct ShardReport {
    pub arch: String,
    pub shards: usize,
    pub weights: Vec<f64>,
    pub layers: Vec<ShardLayerRow>,
    pub defects: Vec<ShardDefect>,
    /// Comm/imbalance pricing; present only for clean plans.
    pub score: Option<ShardScore>,
}

impl ShardReport {
    pub fn is_clean(&self) -> bool {
        self.defects.is_empty()
    }

    /// Human-readable report (the CLI's default output).
    pub fn to_text(&self) -> String {
        let weights = self
            .weights
            .iter()
            .map(|w| format!("{w:.3}"))
            .collect::<Vec<_>>()
            .join("/");
        let mut out = format!(
            "{}: shard plan over {} shard(s) (weights {weights}) — ",
            self.arch, self.shards
        );
        if self.is_clean() {
            out.push_str("in-bounds, disjoint, exact cover, unit-aligned: OK\n");
        } else {
            out.push_str(&format!("{} defect(s)\n", self.defects.len()));
            for d in &self.defects {
                out.push_str(&format!("  - {d}\n"));
            }
        }
        out.push_str("  layer  kind      class       owned params/shard\n");
        for row in &self.layers {
            let owned =
                row.owned.iter().map(|n| n.to_string()).collect::<Vec<_>>().join("/");
            out.push_str(&format!(
                "  {:>5}  {:<8}  {:<10}  {owned}\n",
                row.layer, row.kind, row.class
            ));
        }
        if let Some(score) = &self.score {
            for s in &score.shards {
                out.push_str(&format!(
                    "  shard {}: weight {:.3}, {} params, {:.3e} fwd + {:.3e} bwd flops/sample\n",
                    s.shard, s.weight, s.params, s.fwd_flops, s.bwd_flops
                ));
            }
            for b in score.boundaries.iter().filter(|b| b.fwd_bytes > 0.0) {
                out.push_str(&format!(
                    "  boundary →{}: {} acts, {} — {:.3e} B fwd + {:.3e} B bwd per sample\n",
                    b.layer, b.act_elems, b.kind, b.fwd_bytes, b.bwd_bytes
                ));
            }
            out.push_str(&format!(
                "  predicted: imbalance {:.3}, {:.3e} comm B/sample, proxy {:.3e} s/sample\n",
                score.imbalance,
                score.comm_bytes,
                score.proxy_secs()
            ));
        }
        out
    }

    /// Structured JSON (the CLI's `--json` output).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("schema", Json::str("chaos.analyze.shard/v1")),
            ("arch", Json::str(self.arch.clone())),
            ("shards", Json::num(self.shards as f64)),
            ("weights", Json::arr(self.weights.iter().map(|&w| Json::num(w)).collect())),
            ("clean", Json::Bool(self.is_clean())),
            (
                "defects",
                Json::arr(
                    self.defects
                        .iter()
                        .map(|d| {
                            Json::obj(vec![
                                ("class", Json::str(d.class())),
                                ("detail", Json::str(d.to_string())),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "layers",
                Json::arr(
                    self.layers
                        .iter()
                        .map(|row| {
                            Json::obj(vec![
                                ("layer", Json::num(row.layer as f64)),
                                ("kind", Json::str(row.kind.clone())),
                                ("class", Json::str(row.class)),
                                (
                                    "owned",
                                    Json::arr(
                                        row.owned.iter().map(|&n| Json::num(n as f64)).collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ];
        match &self.score {
            None => fields.push(("totals", Json::Null)),
            Some(score) => {
                fields.push((
                    "per_shard",
                    Json::arr(
                        score
                            .shards
                            .iter()
                            .map(|s| {
                                Json::obj(vec![
                                    ("shard", Json::num(s.shard as f64)),
                                    ("weight", Json::num(s.weight)),
                                    ("params", Json::num(s.params as f64)),
                                    ("fwd_flops", Json::num(s.fwd_flops)),
                                    ("bwd_flops", Json::num(s.bwd_flops)),
                                ])
                            })
                            .collect(),
                    ),
                ));
                fields.push((
                    "boundaries",
                    Json::arr(
                        score
                            .boundaries
                            .iter()
                            .map(|b| {
                                Json::obj(vec![
                                    ("layer", Json::num(b.layer as f64)),
                                    ("act_elems", Json::num(b.act_elems as f64)),
                                    ("kind", Json::str(b.kind)),
                                    ("fwd_bytes", Json::num(b.fwd_bytes)),
                                    ("bwd_bytes", Json::num(b.bwd_bytes)),
                                ])
                            })
                            .collect(),
                    ),
                ));
                fields.push((
                    "totals",
                    Json::obj(vec![
                        ("fwd_flops", Json::num(score.total_fwd_flops())),
                        ("bwd_flops", Json::num(score.total_bwd_flops())),
                        ("comm_bytes", Json::num(score.comm_bytes)),
                        ("imbalance", Json::num(score.imbalance)),
                        ("proxy_secs", Json::num(score.proxy_secs())),
                    ]),
                ));
            }
        }
        Json::obj(fields)
    }
}

/// Prove a plan sound against a compiled network: in-bounds, disjoint,
/// exact cover of every split span, aligned to op-declared split points,
/// and nothing but whole activation tensors crossing shard boundaries.
/// Clean plans additionally carry a [`ShardScore`] from
/// [`crate::perfmodel::score_plan`].
pub fn verify_shards(net: &Network, plan: &ShardPlan) -> ShardReport {
    let mut defects = Vec::new();
    if plan.shards == 0 {
        defects.push(ShardDefect::EmptyPlan);
    }
    if plan.weights.len() != plan.shards {
        defects.push(ShardDefect::ShardCountMismatch {
            layer: 0,
            got: plan.weights.len(),
            want: plan.shards,
        });
    }
    if plan.layers.len() != net.dims.len() {
        defects.push(ShardDefect::LayerCountMismatch {
            plan: plan.layers.len(),
            net: net.dims.len(),
        });
        // Nothing below can be indexed sensibly against the wrong table.
        return report_for(net, plan, defects);
    }

    let mut any_split = false;
    for (layer, (op, d)) in net.ops.iter().zip(&net.dims).enumerate() {
        let span = d.params.clone();
        match &plan.layers[layer] {
            // Implicit replication is sound by construction: every shard
            // holds exactly the declared span.
            LayerAssignment::Replicated => {}
            LayerAssignment::Copies(copies) => {
                if copies.len() != plan.shards {
                    defects.push(ShardDefect::ShardCountMismatch {
                        layer,
                        got: copies.len(),
                        want: plan.shards,
                    });
                }
                for (shard, copy) in copies.iter().enumerate() {
                    if copy.start > copy.end
                        || copy.start < span.start
                        || copy.end > span.end
                    {
                        defects.push(ShardDefect::OutOfBounds {
                            layer,
                            shard,
                            range: copy.clone(),
                            span: span.clone(),
                        });
                    } else if *copy != span {
                        defects.push(ShardDefect::NonActivationCrossing {
                            layer,
                            detail: format!(
                                "shard {shard}'s replica covers {}..{} of span {}..{} — the missing parameters would have to cross the shard boundary",
                                copy.start, copy.end, span.start, span.end
                            ),
                        });
                    }
                }
            }
            LayerAssignment::Split { pieces } => {
                any_split = true;
                let spec = op.split_points();
                let SplitSpec::OutputUnits { units, weights_per_unit } = spec else {
                    defects.push(ShardDefect::UnsplittableSplit {
                        layer,
                        kind: op.kind().to_string(),
                    });
                    continue;
                };
                if let Some(declared) = spec.declared_len() {
                    if declared != span.len() {
                        defects.push(ShardDefect::SplitSpecMismatch {
                            layer,
                            declared,
                            span_len: span.len(),
                        });
                        continue;
                    }
                }
                if pieces.len() != plan.shards {
                    defects.push(ShardDefect::ShardCountMismatch {
                        layer,
                        got: pieces.len(),
                        want: plan.shards,
                    });
                }
                verify_split_layer(
                    layer,
                    &span,
                    units,
                    weights_per_unit,
                    pieces,
                    &mut defects,
                );
            }
        }
    }

    // Dataflow cleanliness of the boundaries: the tensors crossing shard
    // boundaries are exactly the audited activation chain, so a broken
    // chain means the boundary traffic of a split plan is ill-defined.
    if any_split {
        for df in audit::verify_shape_rows(&audit::shape_rows(net)) {
            let (layer, detail) = match &df {
                DataflowDefect::BrokenChain { layer, got, expected } => (
                    *layer,
                    format!(
                        "activation chain broken at the boundary (consumes {got}, upstream produces {expected}) — the crossing tensor is not a well-defined activation"
                    ),
                ),
                DataflowDefect::OpShapeMismatch { layer, kind, side, op, dims } => (
                    *layer,
                    format!(
                        "{kind} op/dims {side}-shape mismatch ({op} vs {dims}) at a shard boundary"
                    ),
                ),
                // verify_shape_rows emits only the two variants above;
                // anything else would come from the arena auditor.
                _ => continue,
            };
            defects.push(ShardDefect::NonActivationCrossing { layer, detail });
        }
    }

    report_for(net, plan, defects)
}

/// Ownership/coverage/alignment checks for one split layer, via a
/// span-relative owner array (split spans are fc-sized — at most a few
/// hundred thousand entries).
fn verify_split_layer(
    layer: usize,
    span: &Range<usize>,
    units: usize,
    weights_per_unit: usize,
    pieces: &[Vec<Range<usize>>],
    defects: &mut Vec<ShardDefect>,
) {
    let mut owner: Vec<Option<u32>> = vec![None; span.len()];
    for (shard, ranges) in pieces.iter().enumerate() {
        for r in ranges {
            if r.start > r.end || r.start < span.start || r.end > span.end {
                defects.push(ShardDefect::OutOfBounds {
                    layer,
                    shard,
                    range: r.clone(),
                    span: span.clone(),
                });
                continue;
            }
            // One overlap defect per offending piece, against the first
            // prior owner hit — per-element reporting would flood.
            let mut clash: Option<usize> = None;
            for p in r.clone() {
                let slot = &mut owner[p - span.start];
                match *slot {
                    Some(prior) => {
                        if clash.is_none() {
                            clash = Some(prior as usize);
                        }
                    }
                    None => *slot = Some(shard as u32),
                }
            }
            if let Some(prior) = clash {
                defects.push(ShardDefect::Overlap {
                    layer,
                    shard_a: prior,
                    shard_b: shard,
                    range: r.clone(),
                });
            }
        }
    }

    // Exact cover: maximal unowned runs.
    let mut i = 0;
    while i < owner.len() {
        if owner[i].is_none() {
            let mut j = i;
            while j < owner.len() && owner[j].is_none() {
                j += 1;
            }
            defects.push(ShardDefect::Gap { layer, range: span.start + i..span.start + j });
            i = j;
        } else {
            i += 1;
        }
    }

    // Alignment: each output unit (weight row + bias element) must have a
    // single owner — a second owner means a cut off the declared points.
    for unit in 0..units {
        let mut owners: Vec<usize> = Vec::new();
        let row = unit * weights_per_unit..(unit + 1) * weights_per_unit;
        let bias = units * weights_per_unit + unit;
        for i in row.chain(bias..bias + 1) {
            if let Some(s) = owner[i] {
                if !owners.contains(&(s as usize)) {
                    owners.push(s as usize);
                }
            }
        }
        if owners.len() > 1 {
            defects.push(ShardDefect::StraddledSplitPoint { layer, unit, owners });
        }
    }
}

fn report_for(net: &Network, plan: &ShardPlan, defects: Vec<ShardDefect>) -> ShardReport {
    let aligned = plan.layers.len() == net.dims.len() && plan.shards >= 1;
    let layers = if aligned {
        net.ops
            .iter()
            .enumerate()
            .map(|(layer, op)| ShardLayerRow {
                layer,
                kind: op.kind().to_string(),
                class: plan.layers[layer].class(),
                owned: (0..plan.shards).map(|s| plan.owned_len(net, s, layer)).collect(),
            })
            .collect()
    } else {
        Vec::new()
    };
    let score = if defects.is_empty() && aligned { Some(score_plan(net, plan)) } else { None };
    ShardReport {
        arch: plan.arch.clone(),
        shards: plan.shards,
        weights: plan.weights.clone(),
        layers,
        defects,
        score,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net(name: &str) -> Network {
        Network::from_name(name).unwrap()
    }

    fn classes(report: &ShardReport) -> Vec<&'static str> {
        report.defects.iter().map(|d| d.class()).collect()
    }

    fn split_layers(plan: &ShardPlan) -> Vec<usize> {
        plan.layers
            .iter()
            .enumerate()
            .filter(|(_, a)| matches!(a, LayerAssignment::Split { .. }))
            .map(|(l, _)| l)
            .collect()
    }

    #[test]
    fn planner_splits_fc_replicates_conv() {
        let net = net("small");
        let plan = plan_shards(&net, 2);
        let split = split_layers(&plan);
        assert!(!split.is_empty(), "no fc layer was split");
        for (layer, op) in net.ops.iter().enumerate() {
            let is_fc = matches!(op.split_points(), SplitSpec::OutputUnits { .. });
            assert_eq!(
                split.contains(&layer),
                is_fc && !net.dims[layer].params.is_empty(),
                "layer {layer} ({})",
                op.kind()
            );
        }
        assert!(verify_shards(&net, &plan).is_clean());
    }

    #[test]
    fn single_shard_plan_is_all_replicated() {
        let net = net("small");
        let plan = plan_shards(&net, 1);
        assert!(split_layers(&plan).is_empty());
        let report = verify_shards(&net, &plan);
        assert!(report.is_clean(), "{:?}", report.defects);
        let score = report.score.unwrap();
        assert_eq!(score.comm_bytes, 0.0, "one shard, no boundary traffic");
        assert!((score.imbalance - 1.0).abs() < 1e-9);
    }

    #[test]
    fn weighted_units_follow_weights() {
        let net = net("small");
        let plan = plan_shards_weighted(&net, &[3.0, 1.0]).unwrap();
        let report = verify_shards(&net, &plan);
        assert!(report.is_clean(), "{:?}", report.defects);
        for layer in split_layers(&plan) {
            let heavy = plan.owned_len(&net, 0, layer);
            let light = plan.owned_len(&net, 1, layer);
            assert!(heavy >= light, "layer {layer}: {heavy} vs {light}");
        }
    }

    #[test]
    fn weighted_planner_rejects_bad_weights() {
        let net = net("small");
        assert!(plan_shards_weighted(&net, &[]).is_err());
        assert!(plan_shards_weighted(&net, &[1.0, 0.0]).is_err());
        assert!(plan_shards_weighted(&net, &[1.0, f64::NAN]).is_err());
        assert!(plan_shards_weighted(&net, &[1.0, -2.0]).is_err());
    }

    #[test]
    fn more_shards_than_output_units_leaves_empty_shards_clean() {
        let net = net("tiny");
        // The output layer has 10 units; 12 shards leaves at least two
        // with no units — legal, they still carry replicated work.
        let plan = plan_shards(&net, 12);
        let report = verify_shards(&net, &plan);
        assert!(report.is_clean(), "{:?}", report.defects);
    }

    #[test]
    fn ownership_lists_exactly_the_split_pieces() {
        let net = net("small");
        let plan = plan_shards(&net, 2);
        let own = plan.ownership();
        let expected: usize = split_layers(&plan)
            .iter()
            .map(|&l| {
                (0..plan.shards)
                    .map(|s| plan.owned_ranges(&net, s, l).len())
                    .sum::<usize>()
            })
            .sum();
        assert_eq!(own.pieces().len(), expected);
        assert!(!own.is_empty());
        // Owned pieces partition each split span: lengths add up.
        for &l in &split_layers(&plan) {
            let total: usize =
                (0..plan.shards).map(|s| plan.owned_len(&net, s, l)).sum();
            assert_eq!(total, net.dims[l].params.len());
        }
    }

    #[test]
    fn report_json_carries_schema_and_roundtrips() {
        let net = net("tiny");
        let report = verify_shards(&net, &plan_shards(&net, 2));
        assert!(report.is_clean());
        let text = report.to_json().pretty();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(
            parsed.get("schema").and_then(|s| s.as_str()),
            Some("chaos.analyze.shard/v1")
        );
        assert_eq!(parsed.get("clean"), Some(&Json::Bool(true)));
        assert!(report.to_text().contains("shard plan over 2 shard(s)"));
    }

    #[test]
    fn seeded_straddle_is_detected() {
        let net = net("small");
        let mut plan = plan_shards(&net, 2);
        let layer = split_layers(&plan)[0];
        // Shift one param from shard 1's weight block into shard 0's —
        // the cut no longer falls on a unit boundary.
        if let LayerAssignment::Split { pieces } = &mut plan.layers[layer] {
            pieces[0][0].end += 1;
            pieces[1][0].start += 1;
        }
        let report = verify_shards(&net, &plan);
        assert_eq!(classes(&report), vec!["straddled-split-point"], "{:?}", report.defects);
        assert!(report.score.is_none());
    }

    #[test]
    fn seeded_gap_and_overlap_are_detected() {
        let net = net("small");
        let layer = split_layers(&plan_shards(&net, 2))[0];

        // Gap: shard 1 forgets its bias block.
        let mut plan = plan_shards(&net, 2);
        if let LayerAssignment::Split { pieces } = &mut plan.layers[layer] {
            pieces[1].pop();
        }
        let report = verify_shards(&net, &plan);
        assert!(classes(&report).contains(&"gap"), "{:?}", report.defects);

        // Overlap within one shard: shard 0 lists a sub-range of its own
        // weight block twice.
        let mut plan = plan_shards(&net, 2);
        if let LayerAssignment::Split { pieces } = &mut plan.layers[layer] {
            let w = pieces[0][0].clone();
            pieces[0].push(w.start..w.start + 1);
        }
        let report = verify_shards(&net, &plan);
        let overlaps: Vec<_> = report
            .defects
            .iter()
            .filter(|d| matches!(d, ShardDefect::Overlap { shard_a: 0, shard_b: 0, .. }))
            .collect();
        assert_eq!(overlaps.len(), 1, "{:?}", report.defects);
    }

    #[test]
    fn seeded_partial_replica_is_non_activation_crossing() {
        let net = net("small");
        let mut plan = plan_shards(&net, 2);
        // Find a parameterized replicated layer (conv) and hand-write
        // truncated copies for it.
        let layer = (0..net.dims.len())
            .find(|&l| {
                !net.dims[l].params.is_empty()
                    && matches!(plan.layers[l], LayerAssignment::Replicated)
            })
            .unwrap();
        let span = net.dims[layer].params.clone();
        plan.layers[layer] =
            LayerAssignment::Copies(vec![span.clone(), span.start..span.end - 1]);
        let report = verify_shards(&net, &plan);
        assert_eq!(
            classes(&report),
            vec!["non-activation-crossing"],
            "{:?}",
            report.defects
        );
    }

    #[test]
    fn seeded_unsplittable_split_and_shape_defects() {
        let net = net("small");

        // Splitting a conv span: conv declares no interior cut.
        let mut plan = plan_shards(&net, 2);
        let conv = net
            .ops
            .iter()
            .position(|op| op.kind() == "conv")
            .expect("small has conv layers");
        let span = net.dims[conv].params.clone();
        let mid = (span.start + span.end) / 2;
        plan.layers[conv] = LayerAssignment::Split {
            pieces: vec![vec![span.start..mid], vec![mid..span.end]],
        };
        let report = verify_shards(&net, &plan);
        assert!(classes(&report).contains(&"unsplittable-split"), "{:?}", report.defects);

        // Wrong layer count.
        let mut plan = plan_shards(&net, 2);
        plan.layers.pop();
        assert!(classes(&verify_shards(&net, &plan)).contains(&"layer-count-mismatch"));

        // Zero shards.
        let mut plan = plan_shards(&net, 2);
        plan.shards = 0;
        assert!(classes(&verify_shards(&net, &plan)).contains(&"empty-plan"));
    }

    #[test]
    fn seeded_out_of_bounds_piece_is_detected() {
        let net = net("small");
        let mut plan = plan_shards(&net, 2);
        let layer = split_layers(&plan)[0];
        if let LayerAssignment::Split { pieces } = &mut plan.layers[layer] {
            let end = net.dims[layer].params.end;
            pieces[1].push(end..end + 7);
        }
        let report = verify_shards(&net, &plan);
        assert!(classes(&report).contains(&"out-of-bounds"), "{:?}", report.defects);
    }
}
