//! Weight checkpoints: persist a trained parameter vector so the serving
//! path (`chaos serve --weights`) and later runs can reuse it.
//!
//! Format (little-endian): magic `CHKP1\n`, arch-name length (u32) + UTF-8
//! name, parameter count (u64), raw f32 data, CRC32 of the data. The arch
//! name and count are verified on load so a checkpoint can never be applied
//! to the wrong network.

use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 6] = b"CHKP1\n";

/// A named weight snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub arch: String,
    pub params: Vec<f32>,
}

impl Checkpoint {
    pub fn new(arch: impl Into<String>, params: Vec<f32>) -> Checkpoint {
        Checkpoint { arch: arch.into(), params }
    }

    /// Write to a file. The write goes to a sibling temp file that is
    /// renamed into place, so live mid-run checkpointing (see
    /// [`super::CheckpointEvery`]) can overwrite a previous snapshot
    /// without ever leaving a torn file behind.
    pub fn save(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let path = path.as_ref();
        let file_name = path
            .file_name()
            .ok_or_else(|| anyhow::anyhow!("checkpoint path has no file name: {path:?}"))?;
        let tmp = path.with_file_name(format!("{}.tmp", file_name.to_string_lossy()));
        let write = || -> anyhow::Result<()> {
            let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
            f.write_all(MAGIC)?;
            let name = self.arch.as_bytes();
            f.write_all(&(name.len() as u32).to_le_bytes())?;
            f.write_all(name)?;
            f.write_all(&(self.params.len() as u64).to_le_bytes())?;
            let mut crc = flate2::Crc::new();
            for v in &self.params {
                let b = v.to_le_bytes();
                crc.update(&b);
                f.write_all(&b)?;
            }
            f.write_all(&crc.sum().to_le_bytes())?;
            f.flush()?;
            Ok(())
        };
        if let Err(e) = write() {
            // Don't leave a partial sibling behind (repeated live
            // checkpointing would otherwise accumulate stale .tmp files).
            let _ = std::fs::remove_file(&tmp);
            return Err(e);
        }
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Read from a file, verifying magic and checksum.
    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Checkpoint> {
        let mut f = std::io::BufReader::new(std::fs::File::open(&path)?);
        let mut magic = [0u8; 6];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "not a CHKP1 checkpoint");
        let mut u32b = [0u8; 4];
        f.read_exact(&mut u32b)?;
        let name_len = u32::from_le_bytes(u32b) as usize;
        anyhow::ensure!(name_len <= 256, "arch name too long ({name_len})");
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let arch = String::from_utf8(name).map_err(|_| anyhow::anyhow!("bad arch name"))?;
        let mut u64b = [0u8; 8];
        f.read_exact(&mut u64b)?;
        let count = u64::from_le_bytes(u64b) as usize;
        anyhow::ensure!(count <= 1 << 28, "implausible parameter count {count}");
        let mut params = Vec::with_capacity(count);
        let mut crc = flate2::Crc::new();
        let mut buf = [0u8; 4];
        for _ in 0..count {
            f.read_exact(&mut buf)?;
            crc.update(&buf);
            params.push(f32::from_le_bytes(buf));
        }
        f.read_exact(&mut u32b)?;
        let stored = u32::from_le_bytes(u32b);
        anyhow::ensure!(
            stored == crc.sum(),
            "checkpoint corrupted: crc {stored:#x} != {:#x}",
            crc.sum()
        );
        Ok(Checkpoint { arch, params })
    }

    /// Load and verify against a network (arch name + parameter count).
    pub fn load_for(
        path: impl AsRef<Path>,
        net: &crate::nn::Network,
    ) -> anyhow::Result<Vec<f32>> {
        let ckpt = Self::load(path)?;
        anyhow::ensure!(
            ckpt.arch == net.arch.name,
            "checkpoint is for arch '{}', network is '{}'",
            ckpt.arch,
            net.arch.name
        );
        anyhow::ensure!(
            ckpt.params.len() == net.total_params,
            "checkpoint has {} params, network needs {}",
            ckpt.params.len(),
            net.total_params
        );
        Ok(ckpt.params)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchSpec;
    use crate::nn::Network;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("ckpt_{}_{name}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let net = Network::new(ArchSpec::tiny());
        let params = net.init_params(7);
        let path = tmp("roundtrip.ckpt");
        Checkpoint::new("tiny", params.clone()).save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.arch, "tiny");
        assert_eq!(back.params, params);
        let verified = Checkpoint::load_for(&path, &net).unwrap();
        assert_eq!(verified, params);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn wrong_arch_rejected() {
        let net_small = Network::new(ArchSpec::small());
        let path = tmp("wrong_arch.ckpt");
        Checkpoint::new("tiny", vec![0.0; 329]).save(&path).unwrap();
        assert!(Checkpoint::load_for(&path, &net_small).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn corruption_detected() {
        let path = tmp("corrupt.ckpt");
        Checkpoint::new("tiny", vec![1.0; 64]).save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let e = Checkpoint::load(&path).unwrap_err();
        assert!(e.to_string().contains("corrupted"), "{e}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn garbage_rejected() {
        let path = tmp("garbage.ckpt");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).unwrap();
        assert!(Checkpoint::load("/nonexistent/x.ckpt").is_err());
    }
}
