//! Run observation hooks: callbacks fired by the epoch driver while a
//! training run is in flight, so callers can stop early, checkpoint live
//! (via [`super::Checkpoint`]) or stream progress — instead of only
//! inspecting the [`super::RunResult`] after the fact.

use super::checkpoint::Checkpoint;
use super::reporter::EpochRecord;
use super::shared::SharedParams;
use std::path::PathBuf;

/// What the run should do after an observer callback.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrainControl {
    /// Keep training.
    Continue,
    /// Finish the current epoch's record and end the run
    /// ([`super::RunResult::stopped_early`] is set).
    Stop,
}

/// Where the live parameters currently live (engine-dependent).
pub(crate) enum ParamsView<'a> {
    /// Sequential engine: the plain in-place vector.
    Seq(&'a [f32]),
    /// Parallel engines: the shared atomic store.
    Par(&'a SharedParams),
}

/// A read-only window into the in-flight run, passed to every observer
/// callback.
pub struct RunView<'a> {
    /// Architecture name (e.g. `"small"`).
    pub arch: &'a str,
    /// Active update-policy name (e.g. `"chaos"`).
    pub policy: &'a str,
    /// Worker threads in use (1 for the sequential engine).
    pub threads: usize,
    /// Epochs the run was configured for (early stopping may cut this
    /// short).
    pub epochs_planned: usize,
    /// Cumulative shared-store publications so far (0 on the sequential
    /// engine).
    pub publications: u64,
    pub(crate) params: ParamsView<'a>,
}

impl<'a> RunView<'a> {
    pub(crate) fn new(
        arch: &'a str,
        policy: &'a str,
        threads: usize,
        epochs_planned: usize,
        publications: u64,
        params: ParamsView<'a>,
    ) -> RunView<'a> {
        RunView { arch, policy, threads, epochs_planned, publications, params }
    }

    /// Snapshot the current parameter vector (consistent enough for
    /// checkpointing: on parallel engines concurrent publications may be
    /// torn across layers, exactly like any CHAOS read).
    pub fn params(&self) -> Vec<f32> {
        match &self.params {
            ParamsView::Seq(p) => p.to_vec(),
            ParamsView::Par(store) => store.snapshot(),
        }
    }

    /// Package the current weights as a [`Checkpoint`] (live mid-run
    /// checkpointing — pair with [`Checkpoint::save`]).
    pub fn checkpoint(&self) -> Checkpoint {
        Checkpoint::new(self.arch, self.params())
    }
}

/// Observer of an in-flight training run. All callbacks run on the driver
/// thread, between phases — they never race the workers.
pub trait EpochObserver: Send {
    /// Fired after each epoch's record (train + validation + test) is
    /// complete. Return [`TrainControl::Stop`] to end the run after this
    /// epoch.
    fn on_epoch_end(&mut self, _record: &EpochRecord, _run: &RunView<'_>) -> TrainControl {
        TrainControl::Continue
    }

    /// Publication milestone: fired after each epoch's *training* phase on
    /// parallel engines, with the new cumulative shared-store publication
    /// count. Never fired by the sequential engine (which publishes
    /// nothing).
    fn on_publications(&mut self, _total: u64, _run: &RunView<'_>) {}
}

/// Stop the run once the test error rate reaches a target — the paper's
/// Fig 6 stop-criterion, applied live.
#[derive(Debug, Clone, Copy)]
pub struct EarlyStop {
    /// Stop when `test.error_rate() <= target_test_error`.
    pub target_test_error: f64,
}

impl EarlyStop {
    pub fn at_test_error(target_test_error: f64) -> EarlyStop {
        EarlyStop { target_test_error }
    }
}

impl EpochObserver for EarlyStop {
    fn on_epoch_end(&mut self, record: &EpochRecord, _run: &RunView<'_>) -> TrainControl {
        if record.test.error_rate() <= self.target_test_error {
            TrainControl::Stop
        } else {
            TrainControl::Continue
        }
    }
}

/// Save a [`Checkpoint`] of the live weights every `every` epochs, so a
/// long run can be resumed or served before it finishes.
#[derive(Debug)]
pub struct CheckpointEvery {
    every: usize,
    path: PathBuf,
    /// Successful saves so far.
    pub saves: usize,
    /// The last save error, if any (the run continues regardless).
    pub last_error: Option<String>,
}

impl CheckpointEvery {
    pub fn new(every: usize, path: impl Into<PathBuf>) -> CheckpointEvery {
        CheckpointEvery { every: every.max(1), path: path.into(), saves: 0, last_error: None }
    }
}

impl EpochObserver for CheckpointEvery {
    fn on_epoch_end(&mut self, record: &EpochRecord, run: &RunView<'_>) -> TrainControl {
        if (record.epoch + 1) % self.every == 0 {
            match run.checkpoint().save(&self.path) {
                Ok(()) => self.saves += 1,
                Err(e) => {
                    // The observer is consumed by the run, so surface the
                    // failure immediately rather than only in the field.
                    eprintln!(
                        "warning: live checkpoint to {} failed at epoch {}: {e}",
                        self.path.display(),
                        record.epoch
                    );
                    self.last_error = Some(e.to_string());
                }
            }
        }
        TrainControl::Continue
    }
}

/// Adapter turning a closure into an [`EpochObserver`].
pub struct FnObserver<F>(pub F);

impl<F> EpochObserver for FnObserver<F>
where
    F: FnMut(&EpochRecord, &RunView<'_>) -> TrainControl + Send,
{
    fn on_epoch_end(&mut self, record: &EpochRecord, run: &RunView<'_>) -> TrainControl {
        (self.0)(record, run)
    }
}

/// Convenience constructor for [`FnObserver`].
pub fn observer_fn<F>(f: F) -> FnObserver<F>
where
    F: FnMut(&EpochRecord, &RunView<'_>) -> TrainControl + Send,
{
    FnObserver(f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chaos::reporter::EvalMetrics;

    fn record(epoch: usize, test_errors: usize) -> EpochRecord {
        EpochRecord {
            epoch,
            eta: 0.001,
            train: EvalMetrics { images: 100, errors: 30, loss: 60.0 },
            validation: EvalMetrics { images: 100, errors: 20, loss: 50.0 },
            test: EvalMetrics { images: 100, errors: test_errors, loss: 40.0 },
            train_secs: 1.0,
            total_secs: 2.0,
        }
    }

    fn view(params: &[f32]) -> RunView<'_> {
        RunView::new("tiny", "chaos", 1, 5, 0, ParamsView::Seq(params))
    }

    #[test]
    fn early_stop_triggers_at_target() {
        let params = vec![0.0f32; 4];
        let mut obs = EarlyStop::at_test_error(0.10);
        assert_eq!(obs.on_epoch_end(&record(0, 50), &view(&params)), TrainControl::Continue);
        assert_eq!(obs.on_epoch_end(&record(1, 10), &view(&params)), TrainControl::Stop);
        assert_eq!(obs.on_epoch_end(&record(2, 0), &view(&params)), TrainControl::Stop);
    }

    #[test]
    fn run_view_snapshots_params_and_checkpoints() {
        let params = vec![1.0f32, 2.0, 3.0];
        let v = view(&params);
        assert_eq!(v.params(), params);
        let ckpt = v.checkpoint();
        assert_eq!(ckpt.arch, "tiny");
        assert_eq!(ckpt.params, params);
    }

    #[test]
    fn checkpoint_every_saves_on_schedule() {
        let params = vec![0.5f32; 8];
        let path = std::env::temp_dir().join(format!("obs_ckpt_{}.ckpt", std::process::id()));
        let mut obs = CheckpointEvery::new(2, &path);
        obs.on_epoch_end(&record(0, 50), &view(&params)); // epoch 1: no save
        assert_eq!(obs.saves, 0);
        obs.on_epoch_end(&record(1, 50), &view(&params)); // epoch 2: save
        assert_eq!(obs.saves, 1);
        assert!(obs.last_error.is_none(), "{:?}", obs.last_error);
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.params, params);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn fn_observer_invokes_closure() {
        let params = vec![0.0f32; 2];
        let mut calls = 0;
        {
            let mut obs = observer_fn(|rec: &EpochRecord, _run: &RunView<'_>| {
                calls += 1;
                if rec.epoch >= 1 {
                    TrainControl::Stop
                } else {
                    TrainControl::Continue
                }
            });
            assert_eq!(obs.on_epoch_end(&record(0, 9), &view(&params)), TrainControl::Continue);
            assert_eq!(obs.on_epoch_end(&record(1, 9), &view(&params)), TrainControl::Stop);
        }
        assert_eq!(calls, 2);
    }
}
