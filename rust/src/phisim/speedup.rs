//! Speedup sweeps over the paper's thread counts — the series behind
//! Figs 5, 7, 8, 9 and Tables 5/6.

use super::sim::{simulate, SimConfig, SimResult};
use crate::perfmodel::{CORE_I5_SPEED_VS_PHI1T, XEON_E5_SPEED_VS_PHI1T};

/// The thread counts evaluated in the paper (§5.1).
pub const PAPER_THREAD_COUNTS: [usize; 8] = [1, 15, 30, 60, 120, 180, 240, 244];

/// One row of the speedup tables.
#[derive(Debug, Clone)]
pub struct SpeedupRow {
    pub threads: usize,
    pub total_secs: f64,
    /// Speedup vs one Phi thread (Fig 8).
    pub vs_phi_1t: f64,
    /// Speedup vs sequential Xeon E5 (Fig 7).
    pub vs_xeon_e5: f64,
    /// Speedup vs sequential Core i5 (Fig 9).
    pub vs_core_i5: f64,
    /// Full simulation result (layer tables etc.).
    pub result: SimResult,
}

/// Simulate every paper thread count for an architecture.
pub fn speedup_table(arch: &str) -> anyhow::Result<Vec<SpeedupRow>> {
    let base = simulate(&SimConfig::paper(arch, 1))?.total_secs();
    let e5 = base / XEON_E5_SPEED_VS_PHI1T;
    let i5 = base / CORE_I5_SPEED_VS_PHI1T;
    PAPER_THREAD_COUNTS
        .iter()
        .map(|&p| {
            let result = simulate(&SimConfig::paper(arch, p))?;
            let total = result.total_secs();
            Ok(SpeedupRow {
                threads: p,
                total_secs: total,
                vs_phi_1t: base / total,
                vs_xeon_e5: e5 / total,
                vs_core_i5: i5 / total,
                result,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simulation_is_deterministic() {
        let a = simulate(&SimConfig::paper("small", 60)).unwrap();
        let b = simulate(&SimConfig::paper("small", 60)).unwrap();
        assert_eq!(a.total_secs(), b.total_secs());
        assert_eq!(a.layer_class_secs(), b.layer_class_secs());
    }

    #[test]
    fn near_linear_scaling_up_to_60_threads() {
        // Paper Result 3: "doubling the number of threads from 15 to 30,
        // and from 30 to 60 almost doubles the speedup".
        let rows = speedup_table("medium").unwrap();
        let at = |p: usize| rows.iter().find(|r| r.threads == p).unwrap();
        let s15 = at(15).vs_phi_1t;
        let s30 = at(30).vs_phi_1t;
        let s60 = at(60).vs_phi_1t;
        assert!((13.0..=15.2).contains(&s15), "s15={s15}");
        assert!((s30 / s15 - 2.0).abs() < 0.25, "30/15 ratio {}", s30 / s15);
        assert!((s60 / s30 - 2.0).abs() < 0.25, "60/30 ratio {}", s60 / s30);
    }

    #[test]
    fn trend_bends_past_two_threads_per_core() {
        // Fig 8: the double-speedup trend breaks at 120 threads (2/core)
        // and flattens further at 180/240.
        let rows = speedup_table("large").unwrap();
        let at = |p: usize| rows.iter().find(|r| r.threads == p).unwrap();
        let r120 = at(120).vs_phi_1t / at(60).vs_phi_1t;
        let r240 = at(240).vs_phi_1t / at(120).vs_phi_1t;
        assert!(r120 < 1.8, "120/60 ratio should bend: {r120}");
        assert!(r240 < 1.45, "240/120 ratio should flatten: {r240}");
        // but still improve
        assert!(at(240).vs_phi_1t > at(120).vs_phi_1t);
    }

    #[test]
    fn headline_speedups_in_paper_regime() {
        // Paper Result 3: up to 103× vs Phi 1T, 14× vs Xeon E5, 58× vs
        // Core i5 (best over architectures, 244 threads). Shape target:
        // within ±25%.
        let rows = speedup_table("large").unwrap();
        let last = rows.iter().find(|r| r.threads == 244).unwrap();
        assert!(
            (77.0..=129.0).contains(&last.vs_phi_1t),
            "vs Phi 1T: {}",
            last.vs_phi_1t
        );
        assert!(
            (10.5..=17.5).contains(&last.vs_xeon_e5),
            "vs E5: {}",
            last.vs_xeon_e5
        );
        assert!(
            (43.0..=73.0).contains(&last.vs_core_i5),
            "vs i5: {}",
            last.vs_core_i5
        );
    }

    #[test]
    fn conv_backward_dominates_large_at_high_threads() {
        // Paper Table 5: at 240T on the large net, ~88% of layer time is
        // backward conv, ~10% forward conv.
        let r = simulate(&SimConfig::paper("large", 240)).unwrap();
        let c = r.layer_class_secs();
        let bpc_frac = c.bpc / c.total();
        let fpc_frac = c.fpc / c.total();
        assert!((0.80..=0.93).contains(&bpc_frac), "bpc fraction {bpc_frac}");
        assert!((0.05..=0.16).contains(&fpc_frac), "fpc fraction {fpc_frac}");
        assert!(c.bpf < c.bpc * 0.05, "fully-connected backward is tiny");
    }

    #[test]
    fn more_threads_never_slower() {
        let rows = speedup_table("small").unwrap();
        for pair in rows.windows(2) {
            assert!(
                pair[1].total_secs <= pair[0].total_secs * 1.02,
                "slower at {} threads than {}",
                pair[1].threads,
                pair[0].threads
            );
        }
    }

    #[test]
    fn large_one_thread_total_matches_paper_magnitude() {
        // Paper: large net, 1 Phi thread ≈ 295.5 h; 244 threads ≈ 2.9 h.
        let t1 = simulate(&SimConfig::paper("large", 1)).unwrap().total_secs() / 3600.0;
        let t244 = simulate(&SimConfig::paper("large", 244)).unwrap().total_secs() / 3600.0;
        assert!((200.0..400.0).contains(&t1), "1T: {t1} h");
        assert!((1.9..4.4).contains(&t244), "244T: {t244} h");
    }
}
