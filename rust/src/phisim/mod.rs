//! `phisim` — a discrete-event simulator of CHAOS training on the Intel
//! Xeon Phi 7120P machine model.
//!
//! The physical Phi is discontinued and this container exposes a single
//! host core, so the paper's *wall-clock* experiments (Figs 5–9, Tables
//! 5–6) cannot be re-measured directly. Per the substitution rule
//! (DESIGN.md §2), this module stands in for the testbed: it executes the
//! CHAOS schedule — dynamic image picking, per-layer delayed publication
//! under per-layer locks, no barriers — against the machine model the
//! paper itself validates (Table 3 operation counts, the 1/1/1.5/2 CPI
//! schedule, Table 4 memory contention), at event granularity.
//!
//! The analytic model ([`crate::perfmodel`]) is the closed-form
//! counterpart; Figs 11–13 compare the two, exactly as the paper compares
//! its model against measurements.

#[allow(clippy::module_inception)]
mod sim;
mod hetero;
mod speedup;

pub use sim::{
    core_i5_seq_secs, phi_total_secs, simulate, xeon_e5_seq_secs, LayerBusy, LayerClassSecs,
    SimConfig, SimResult, WRITE_SECS_PER_WEIGHT,
};
pub use hetero::{simulate_hetero, HeteroConfig, HeteroResult, PCIE_PUBLISH_SECS};
pub use speedup::{speedup_table, SpeedupRow, PAPER_THREAD_COUNTS};
