//! Discrete-event simulation of CHAOS training on the Xeon Phi machine
//! model.
//!
//! Workers are simulated timelines drawing images from a shared pool
//! (exactly the coordinator's sampling discipline). Per image, a worker
//! advances through the architecture's layers; per-layer compute times come
//! from the paper's Table-3 operation counts distributed over layers by
//! MAC-derived fractions, scaled by the CPI schedule for the configured
//! occupancy. Two contention mechanisms make parallel efficiency
//! sub-linear, as on the real machine:
//!
//! * **memory contention** (Table 4): extra seconds per training image,
//!   charged during the backward pass of parameterized layers (weight I/O),
//!   proportionally to each layer's weight count;
//! * **publication serialization**: the CHAOS per-layer lock — each
//!   backward publication holds its layer's lock for
//!   `weights × WRITE_SECS_PER_WEIGHT`, so hot layers queue when many
//!   workers publish at once (this is why the paper's backward-conv
//!   speedups in Table 6 trail the forward-conv ones).

use crate::config::ArchSpec;
use crate::nn::{compute_dims, LayerDims};
use crate::perfmodel::{
    arch_constants, ContentionModel, LayerCosts, CLOCK_HZ, CORE_I5_SPEED_VS_PHI1T,
    OPERATION_FACTOR, XEON_E5_SPEED_VS_PHI1T,
};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Cost of publishing one weight to the shared store (lock-held time per
/// element, seconds). Calibrated so the large network's backward-conv
/// speedup at 244 threads lands near the paper's ~103× (Table 6) without
/// saturating the per-layer locks.
pub const WRITE_SECS_PER_WEIGHT: f64 = 5e-9;

/// Effective CPI used by the *simulator* (measured-side stand-in). The
/// paper's Table-3 schedule (1/1/1.5/2) is the "best theoretical" bound
/// its analytic model uses; the measured runs beat it at 3–4 threads/core
/// because multithreading hides the in-order core's stalls (the paper
/// observes exactly this divergence between 120 and 240 threads in Figs
/// 12–13). 1/1/1.4/1.75 reproduces the measured 120→240 gains.
fn sim_cpi(p: usize) -> f64 {
    match crate::perfmodel::threads_per_core(p) {
        0 | 1 | 2 => 1.0,
        3 => 1.4,
        _ => 1.75,
    }
}

/// Simulation scenario.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub arch: String,
    pub threads: usize,
    /// Training (= validation) images.
    pub images: usize,
    pub test_images: usize,
    pub epochs: usize,
    /// Images actually event-simulated per phase; the makespan is scaled
    /// by `images / sample_images`. 2 048 keeps runs instant while giving
    /// every worker hundreds of samples.
    pub sample_images: usize,
}

impl SimConfig {
    /// The paper's MNIST scenario for an architecture.
    pub fn paper(arch: &str, threads: usize) -> SimConfig {
        let epochs = arch_constants(arch).map(|c| c.epochs).unwrap_or(10);
        SimConfig {
            arch: arch.to_string(),
            threads,
            images: 60_000,
            test_images: 10_000,
            epochs,
            sample_images: 2_048,
        }
    }
}

/// Per-layer simulated busy seconds (per network instance, per epoch).
#[derive(Debug, Clone, Default)]
pub struct LayerBusy {
    pub forward: f64,
    pub backward: f64,
    /// Time spent waiting for / holding the publication lock (subset of
    /// neither forward nor backward compute; reported separately).
    pub publish: f64,
}

/// Result of one simulated run.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub cfg_threads: usize,
    /// Wall seconds of one training phase (per epoch).
    pub train_epoch_secs: f64,
    /// Wall seconds of one validation phase (per epoch).
    pub val_epoch_secs: f64,
    /// Wall seconds of one test phase (per epoch).
    pub test_epoch_secs: f64,
    /// Preparation time (Prep ops, sequential).
    pub prep_secs: f64,
    /// Per-layer busy time, per instance per epoch (training phase).
    pub layers: Vec<LayerBusy>,
    /// Layer table of the architecture (parallel to `layers`).
    pub dims: Vec<LayerDims>,
    /// Epochs of the scenario.
    pub epochs: usize,
}

impl SimResult {
    /// Total wall-clock seconds for the full run (all epochs + prep).
    pub fn total_secs(&self) -> f64 {
        self.prep_secs
            + self.epochs as f64
                * (self.train_epoch_secs + self.val_epoch_secs + self.test_epoch_secs)
    }
    /// Aggregate busy seconds over layer classes, per instance per epoch —
    /// the rows of paper Table 5 (BPF, BPC, FPC, FPF).
    pub fn layer_class_secs(&self) -> LayerClassSecs {
        use crate::config::LayerSpec;
        let mut out = LayerClassSecs::default();
        for (d, b) in self.dims.iter().zip(&self.layers) {
            match &d.spec {
                LayerSpec::Conv { .. } => {
                    out.fpc += b.forward;
                    out.bpc += b.backward + b.publish;
                }
                LayerSpec::FullyConnected { .. } | LayerSpec::Output { .. } => {
                    out.fpf += b.forward;
                    out.bpf += b.backward + b.publish;
                }
                // Dropout is a parameter-free elementwise pass; fold it
                // into the pool bucket (absent from paper archs).
                LayerSpec::MaxPool { .. }
                | LayerSpec::AvgPool { .. }
                | LayerSpec::Dropout { .. } => {
                    out.pool_fwd += b.forward;
                    out.pool_bwd += b.backward;
                }
                // Custom kinds may own parameters, so their CHAOS
                // publication time must stay in the totals.
                LayerSpec::Custom { .. } => {
                    out.pool_fwd += b.forward;
                    out.pool_bwd += b.backward + b.publish;
                }
                LayerSpec::Input { .. } => {}
            }
        }
        out
    }
}

/// Paper Table 5 row: seconds per layer class (per instance per epoch).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LayerClassSecs {
    pub bpf: f64,
    pub bpc: f64,
    pub fpc: f64,
    pub fpf: f64,
    pub pool_fwd: f64,
    pub pool_bwd: f64,
}

impl LayerClassSecs {
    pub fn total(&self) -> f64 {
        self.bpf + self.bpc + self.fpc + self.fpf + self.pool_fwd + self.pool_bwd
    }
}

/// f64 min-heap key.
#[derive(PartialEq)]
struct Clock(f64);
impl Eq for Clock {}
impl PartialOrd for Clock {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Clock {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Run the simulation.
pub fn simulate(cfg: &SimConfig) -> anyhow::Result<SimResult> {
    let arch = ArchSpec::by_name(&cfg.arch)
        .ok_or_else(|| anyhow::anyhow!("unknown arch '{}'", cfg.arch))?;
    let consts = arch_constants(&cfg.arch)
        .ok_or_else(|| anyhow::anyhow!("no Table-3 constants for '{}'", cfg.arch))?;
    let contention = ContentionModel::for_arch(&cfg.arch)
        .ok_or_else(|| anyhow::anyhow!("no Table-4 contention for '{}'", cfg.arch))?;
    anyhow::ensure!(cfg.threads >= 1, "threads must be >= 1");

    let dims = compute_dims(&arch);
    let costs = LayerCosts::of(&arch);
    let p = cfg.threads;
    let slowdown = sim_cpi(p) * OPERATION_FACTOR / CLOCK_HZ; // seconds per op

    // Per-layer per-image compute seconds at this occupancy.
    let n_layers = dims.len();
    let fwd_secs: Vec<f64> = (0..n_layers)
        .map(|l| consts.fprop_ops * costs.forward_fraction(l) * slowdown)
        .collect();
    let bwd_secs: Vec<f64> = (0..n_layers)
        .map(|l| consts.bprop_ops * costs.backward_fraction(l) * slowdown)
        .collect();

    // Memory contention per training image, split across parameterized
    // layers by weight share.
    let mc = contention.contention(p);
    let total_weights: f64 = dims.iter().map(|d| d.param_count() as f64).sum();
    let mc_share: Vec<f64> = dims
        .iter()
        .map(|d| mc * d.param_count() as f64 / total_weights)
        .collect();

    // Publication lock hold per layer.
    let hold: Vec<f64> = dims
        .iter()
        .map(|d| d.param_count() as f64 * WRITE_SECS_PER_WEIGHT * sim_cpi(p))
        .collect();

    // ---- training phase --------------------------------------------------
    let n_sim = cfg.sample_images.min(cfg.images).max(p);
    let scale = cfg.images as f64 / n_sim as f64;
    let mut heap: BinaryHeap<Reverse<(Clock, usize)>> = (0..p)
        .map(|w| Reverse((Clock(0.0), w)))
        .collect();
    let mut lock_free = vec![0.0f64; n_layers];
    let mut busy = vec![LayerBusy::default(); n_layers];

    for _ in 0..n_sim {
        let Reverse((Clock(mut t), w)) = heap.pop().unwrap();
        // forward
        for l in 1..n_layers {
            t += fwd_secs[l];
            busy[l].forward += fwd_secs[l];
        }
        // backward (output → first hidden layer)
        for l in (1..n_layers).rev() {
            t += bwd_secs[l] + mc_share[l];
            busy[l].backward += bwd_secs[l] + mc_share[l];
            if dims[l].param_count() > 0 {
                // CHAOS publication: serialized per layer, arbitrary order.
                let start = lock_free[l].max(t);
                let wait = start - t;
                lock_free[l] = start + hold[l];
                t = start + hold[l];
                busy[l].publish += wait + hold[l];
            }
        }
        heap.push(Reverse((Clock(t), w)));
    }
    let train_makespan = heap
        .iter()
        .map(|Reverse((Clock(t), _))| *t)
        .fold(0.0, f64::max);
    let train_epoch_secs = train_makespan * scale;

    // Per-instance per-epoch layer times (all instances do n_sim/p images
    // in the sample; scale to images/p each).
    let per_instance_scale = scale / p as f64;
    for b in busy.iter_mut() {
        b.forward *= per_instance_scale;
        b.backward *= per_instance_scale;
        b.publish *= per_instance_scale;
    }

    // ---- evaluation phases (forward only, no contention charges) ---------
    let fwd_image_secs: f64 = fwd_secs.iter().sum();
    let eval_secs = |count: usize| -> f64 {
        // forward-only work divides cleanly over workers.
        fwd_image_secs * (count as f64 / p as f64)
    };
    let val_epoch_secs = eval_secs(cfg.images);
    let test_epoch_secs = eval_secs(cfg.test_images);
    // Table 5 counts forward time of validation/testing too: every image
    // evaluated adds its per-layer forward cost to each instance's tally.
    let eval_images_per_instance = (cfg.images + cfg.test_images) as f64 / p as f64;
    for (l, b) in busy.iter_mut().enumerate() {
        b.forward += fwd_secs[l] * eval_images_per_instance;
    }

    // Preparation is sequential: one thread, full-speed CPI.
    let prep_secs = consts.prep_ops * OPERATION_FACTOR / CLOCK_HZ;

    Ok(SimResult {
        cfg_threads: p,
        train_epoch_secs,
        val_epoch_secs,
        test_epoch_secs,
        prep_secs,
        layers: busy,
        dims,
        epochs: cfg.epochs,
    })
}

/// Total Phi wall-clock for the paper scenario at `threads`.
pub fn phi_total_secs(arch: &str, threads: usize) -> anyhow::Result<f64> {
    Ok(simulate(&SimConfig::paper(arch, threads))?.total_secs())
}

/// Modeled sequential total on the Intel Xeon E5 (derived host speed —
/// DESIGN.md §2).
pub fn xeon_e5_seq_secs(arch: &str) -> anyhow::Result<f64> {
    Ok(phi_total_secs(arch, 1)? / XEON_E5_SPEED_VS_PHI1T)
}

/// Modeled sequential total on the Intel Core i5.
pub fn core_i5_seq_secs(arch: &str) -> anyhow::Result<f64> {
    Ok(phi_total_secs(arch, 1)? / CORE_I5_SPEED_VS_PHI1T)
}
