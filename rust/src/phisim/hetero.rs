//! Heterogeneous CHAOS — the paper's stated future work (§6: "Future work
//! will extend CHAOS to enable the use of all cores of host CPUs and the
//! co-processor(s)"), modeled on the same machine substrate.
//!
//! Workers now live on two device classes: host CPU cores (faster serial
//! clock, few threads) and Phi threads (slow clock, many threads). The
//! shared weight vector lives in host memory; Phi publications cross PCIe,
//! which we model as a fixed per-publication latency added to the lock
//! hold. Because CHAOS workers *pick* images dynamically, load balancing
//! across the asymmetric devices is automatic — no static split needed,
//! which is exactly why the scheme extends naturally (the point the paper
//! gestures at).

use super::sim::WRITE_SECS_PER_WEIGHT;
use crate::config::ArchSpec;
use crate::nn::compute_dims;
use crate::perfmodel::{
    arch_constants, ContentionModel, LayerCosts, CLOCK_HZ, OPERATION_FACTOR,
    XEON_E5_SPEED_VS_PHI1T,
};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// One-way PCIe latency charged per cross-device publication (seconds).
/// ~1 µs is a typical small-transfer PCIe3 latency.
pub const PCIE_PUBLISH_SECS: f64 = 1.5e-6;

/// Heterogeneous scenario: host workers + Phi workers.
#[derive(Debug, Clone)]
pub struct HeteroConfig {
    pub arch: String,
    /// Host CPU worker threads (Xeon E5-class cores).
    pub host_threads: usize,
    /// Xeon Phi worker threads.
    pub phi_threads: usize,
    pub images: usize,
    pub epochs: usize,
    pub sample_images: usize,
}

impl HeteroConfig {
    pub fn paper(arch: &str, host_threads: usize, phi_threads: usize) -> HeteroConfig {
        let epochs = arch_constants(arch).map(|c| c.epochs).unwrap_or(10);
        HeteroConfig {
            arch: arch.to_string(),
            host_threads,
            phi_threads,
            images: 60_000,
            epochs,
            sample_images: 2_048,
        }
    }
}

/// Result of a heterogeneous simulation.
#[derive(Debug, Clone)]
pub struct HeteroResult {
    /// Wall seconds of one training epoch.
    pub train_epoch_secs: f64,
    /// Total seconds (epochs, no prep — both devices are warm).
    pub total_secs: f64,
    /// Images processed by host workers (of the sampled pool, scaled).
    pub host_images: f64,
    /// Images processed by Phi workers.
    pub phi_images: f64,
}

impl HeteroResult {
    /// Fraction of work the host absorbed.
    pub fn host_share(&self) -> f64 {
        self.host_images / (self.host_images + self.phi_images)
    }
}

/// Effective CPI on the Phi for a given worker count (same schedule as the
/// homogeneous simulator).
fn phi_cpi(phi_threads: usize) -> f64 {
    match crate::perfmodel::threads_per_core(phi_threads.max(1)) {
        0 | 1 | 2 => 1.0,
        3 => 1.4,
        _ => 1.75,
    }
}

/// Simulate heterogeneous CHAOS training.
pub fn simulate_hetero(cfg: &HeteroConfig) -> anyhow::Result<HeteroResult> {
    let arch = ArchSpec::by_name(&cfg.arch)
        .ok_or_else(|| anyhow::anyhow!("unknown arch '{}'", cfg.arch))?;
    let consts = arch_constants(&cfg.arch)
        .ok_or_else(|| anyhow::anyhow!("no constants for '{}'", cfg.arch))?;
    let contention = ContentionModel::for_arch(&cfg.arch)
        .ok_or_else(|| anyhow::anyhow!("no contention for '{}'", cfg.arch))?;
    let total_workers = cfg.host_threads + cfg.phi_threads;
    anyhow::ensure!(total_workers >= 1, "need at least one worker");

    let dims = compute_dims(&arch);
    let costs = LayerCosts::of(&arch);
    let n_layers = dims.len();

    // Per-image seconds per device class (whole fwd+bwd; layer split only
    // matters for lock holds here).
    let ops = consts.fprop_ops + consts.bprop_ops;
    let phi_img_secs = ops / CLOCK_HZ * OPERATION_FACTOR * phi_cpi(cfg.phi_threads);
    // Host core ≈ the paper's Xeon E5 serial speed relative to a Phi thread.
    let host_img_secs = ops / CLOCK_HZ * OPERATION_FACTOR / XEON_E5_SPEED_VS_PHI1T;

    // Memory contention is driven by total concurrent publishers.
    let mc = contention.contention(total_workers.min(3840));

    // Per-layer lock holds (host writes locally; Phi pays PCIe).
    let hold_base: Vec<f64> =
        dims.iter().map(|d| d.param_count() as f64 * WRITE_SECS_PER_WEIGHT).collect();

    let n_sim = cfg.sample_images.min(cfg.images).max(total_workers);
    let scale = cfg.images as f64 / n_sim as f64;

    // Publication costs per image. With asymmetric worker speeds a global
    // lock-counter simulation breaks causality under image-granular greedy
    // processing (fast workers would queue behind publications that happen
    // *later* in simulated time), so lock queueing is modeled as an M/D/1
    // wait per layer instead: wait = hold·ρ/(2(1−ρ)), ρ = λ·hold, with the
    // arrival rate λ found by a two-round fixed point over the resulting
    // image rates.
    let param_layers: Vec<usize> =
        (1..n_layers).filter(|&l| dims[l].param_count() > 0).collect();
    let pub_secs = |is_host: bool, waits: &[f64]| -> f64 {
        param_layers
            .iter()
            .enumerate()
            .map(|(i, &l)| {
                hold_base[l] + waits[i] + if is_host { 0.0 } else { PCIE_PUBLISH_SECS }
            })
            .sum()
    };
    let mut waits = vec![0.0f64; param_layers.len()];
    for _ in 0..2 {
        let host_total = host_img_secs + pub_secs(true, &waits);
        let phi_total = phi_img_secs + mc + pub_secs(false, &waits);
        let lambda = cfg.host_threads as f64 / host_total + cfg.phi_threads as f64 / phi_total;
        for (i, &l) in param_layers.iter().enumerate() {
            let rho = (lambda * hold_base[l]).min(0.95);
            waits[i] = hold_base[l] * rho / (2.0 * (1.0 - rho));
        }
    }
    let host_total = host_img_secs + pub_secs(true, &waits);
    let phi_total = phi_img_secs + mc + pub_secs(false, &waits);

    // Greedy dynamic assignment over per-worker clocks (the CHAOS sampler).
    #[derive(PartialEq)]
    struct Clock(f64);
    impl Eq for Clock {}
    impl PartialOrd for Clock {
        fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(other))
        }
    }
    impl Ord for Clock {
        fn cmp(&self, other: &Self) -> std::cmp::Ordering {
            self.0.total_cmp(&other.0)
        }
    }

    let mut heap: BinaryHeap<Reverse<(Clock, usize)>> =
        (0..total_workers).map(|w| Reverse((Clock(0.0), w))).collect();
    let mut host_images = 0usize;
    let mut phi_images = 0usize;

    for _ in 0..n_sim {
        let Reverse((Clock(mut t), w)) = heap.pop().unwrap();
        let is_host = w < cfg.host_threads;
        if is_host {
            host_images += 1;
            t += host_total;
        } else {
            phi_images += 1;
            t += phi_total;
        }
        heap.push(Reverse((Clock(t), w)));
    }
    let makespan = heap.iter().map(|Reverse((Clock(t), _))| *t).fold(0.0, f64::max);
    let train_epoch_secs = makespan * scale;

    Ok(HeteroResult {
        train_epoch_secs,
        total_secs: train_epoch_secs * cfg.epochs as f64,
        host_images: host_images as f64 * scale,
        phi_images: phi_images as f64 * scale,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn epoch(arch: &str, host: usize, phi: usize) -> f64 {
        simulate_hetero(&HeteroConfig::paper(arch, host, phi)).unwrap().train_epoch_secs
    }

    #[test]
    fn adding_host_cores_to_full_phi_helps() {
        // The future-work claim: host cores add throughput on top of the
        // fully-loaded co-processor.
        let phi_only = epoch("medium", 0, 244);
        let plus_host = epoch("medium", 12, 244);
        assert!(
            plus_host < phi_only * 0.95,
            "12 host cores should help: {plus_host} vs {phi_only}"
        );
    }

    #[test]
    fn host_only_matches_e5_scaling() {
        // One host worker ≈ the paper's sequential E5 training phase.
        let r = simulate_hetero(&HeteroConfig::paper("small", 1, 0)).unwrap();
        let per_image = (58_000.0 + 524_000.0) / CLOCK_HZ * OPERATION_FACTOR
            / XEON_E5_SPEED_VS_PHI1T;
        let expect = per_image * 60_000.0;
        assert!(
            (r.train_epoch_secs - expect).abs() / expect < 0.05,
            "{} vs {}",
            r.train_epoch_secs,
            expect
        );
    }

    #[test]
    fn dynamic_picking_balances_load() {
        // Host cores are ~7× faster per worker: their image share must be
        // ≈ host_speed·n_host / (host_speed·n_host + phi_speed·n_phi),
        // emerging purely from the greedy sampler — no static split.
        let r = simulate_hetero(&HeteroConfig::paper("medium", 8, 61)).unwrap();
        let host_rate = 8.0 * XEON_E5_SPEED_VS_PHI1T;
        let phi_rate = 61.0; // CPI 1 at 1 thread/core
        let expect = host_rate / (host_rate + phi_rate);
        let got = r.host_share();
        assert!(
            (got - expect).abs() < 0.08,
            "host share {got:.3} vs expected {expect:.3}"
        );
    }

    #[test]
    fn degenerate_configs() {
        assert!(simulate_hetero(&HeteroConfig::paper("small", 0, 0)).is_err());
        // Phi-only hetero ≈ homogeneous simulator's training phase regime.
        let hetero = epoch("large", 0, 244);
        let homo = crate::phisim::simulate(&crate::phisim::SimConfig::paper("large", 244))
            .unwrap()
            .train_epoch_secs;
        let ratio = hetero / homo;
        assert!(
            (0.7..1.4).contains(&ratio),
            "phi-only hetero {hetero} vs homogeneous {homo}"
        );
    }

    #[test]
    fn combined_beats_either_alone() {
        let both = epoch("large", 16, 244);
        let phi_only = epoch("large", 0, 244);
        let host_only = epoch("large", 16, 0);
        assert!(both < phi_only && both < host_only);
    }
}
