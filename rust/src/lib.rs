//! # chaos-phi
//!
//! A reproduction of **CHAOS: A Parallelization Scheme for Training
//! Convolutional Neural Networks on Intel Xeon Phi** (Viebke, Memeti,
//! Pllana, Abraham — Journal of Supercomputing, 2017) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! ## Training: the `Trainer` builder
//!
//! The public entry point is [`chaos::Trainer`] — configure a network, the
//! hyper-parameters, an update policy and optional observers, then run:
//!
//! ```ignore
//! use chaos_phi::chaos::{ChaosPolicy, EarlyStop, Trainer};
//! use chaos_phi::config::ArchSpec;
//!
//! let run = Trainer::new()
//!     .arch(ArchSpec::small())
//!     .epochs(10)
//!     .threads(4)
//!     .eta(0.001, 0.9)
//!     .policy(ChaosPolicy)                       // or .policy_name("averaged:64")?
//!     .observer(EarlyStop::at_test_error(0.02))  // stop criteria, live checkpoints…
//!     .run(&train_set, &test_set)?;
//! ```
//!
//! The update scheme — the paper's *interchangeable* part (§4.1) — is the
//! open [`chaos::UpdatePolicy`] trait. The five paper strategies ship as
//! impls (sequential baseline, averaged SGD, delayed round-robin,
//! HogWild!, and CHAOS itself), all resolvable by name through the
//! [`chaos::policy`] registry; custom schemes plug in via
//! `chaos::policy::register` and are then selectable from the CLI and
//! benchmarked automatically. In-flight runs can be watched (and stopped,
//! or checkpointed live via [`chaos::Checkpoint`]) through
//! [`chaos::EpochObserver`].
//!
//! ## The open layer API
//!
//! The model side is open in the same way: [`config::ArchSpec`] is a stack
//! of [`config::LayerSpec`] *data*, and all behaviour lives with the layer
//! **kind** registered in [`nn::layer`] — JSON parse/serialize, geometry
//! validation, parameter layout, and compilation into the executable
//! [`nn::LayerOp`] pipeline that [`nn::Network`] drives. Built-in kinds
//! cover the paper's vocabulary plus zero-padded/strided convolution,
//! selectable per-layer activations (`"act": "relu"`), average pooling and
//! dropout; architectures load from JSON:
//!
//! ```ignore
//! let arch = chaos_phi::config::ArchSpec::from_json(&Json::parse(r#"{
//!   "name": "custom", "epochs": 5, "layers": [
//!     {"input": 29},
//!     {"conv": {"maps": 8, "kernel": 5, "stride": 2, "pad": 2, "act": "relu"}},
//!     {"avgpool": 3}, {"dropout": 0.25},
//!     {"fc": {"neurons": 64, "act": "relu"}},
//!     {"output": 10}
//! ]}"#)?)?;
//! ```
//!
//! A kind registered at runtime (`nn::layer::register(Arc::new(MyKind))`)
//! is immediately loadable from JSON, validated like a built-in, and
//! trains end-to-end through [`chaos::Trainer`] under every update policy
//! — the orchestrator never matches on layer types. See
//! `examples/quickstart.rs` for a complete custom-kind walkthrough, and
//! `chaos arch validate <file.json>` to check architecture files from the
//! CLI.
//!
//! ## Layers (system stack)
//!
//! - **L3 (this crate)** — the CHAOS coordinator: shared-weight store with
//!   controlled-Hogwild delayed updates, worker pool, epoch driver, the
//!   paper's strategy baselines, the analytic performance model, and a
//!   discrete-event Intel Xeon Phi simulator standing in for the
//!   discontinued hardware (DESIGN.md §2).
//! - **L2/L1 (python/, build time only)** — JAX model + Pallas kernels,
//!   AOT-lowered to HLO text, loaded and executed here through
//!   [`runtime`] via the PJRT CPU client (behind the `xla-runtime`
//!   feature; the default build substitutes a stub). Python is never on
//!   the request path.
//!
//! ## Batched execution and serving
//!
//! Forward-only consumers run whole batches through [`nn::BatchPlan`]:
//! every layer's parameters load **once per batch** into weight-stationary
//! kernels ([`nn::LayerOp::forward_batch`]), bit-identical to per-sample
//! forwards. The trainer's validation/testing phases evaluate in batched
//! chunks, and [`serve::Server`] serves predictions from any compiled
//! network + weight snapshot on the native engine
//! ([`serve::Engine::Native`], no artifacts required) or from the AOT
//! PJRT artifact ([`serve::Engine::Pjrt`]).
//!
//! ## Static & dynamic analysis
//!
//! [`chaos::analysis`] verifies the invariants the parallel scheme rests
//! on: a static span verifier proves every compiled network's parameter
//! spans are in-bounds, disjoint and covering (run in debug builds at
//! `Network::new`, and from the CLI as `chaos analyze`); a race /
//! lock-discipline checker (cargo feature `race-check`) records every
//! store event against the policy's declared [`chaos::SyncContract`]; and
//! a deterministic interleaving harness replays cross-thread orderings
//! under a seeded or scripted schedule.
//!
//! [`nn::audit`] extends the static side from parameter spans to the
//! batched execution engine: a dataflow/aliasing verifier (shape chain
//! coherent end-to-end, `BatchScratch` arenas exactly sized and
//! non-overlapping, dropout PRNG streams distinct — run in debug builds
//! at `Network::compile`), a kernel-dispatch classifier (every
//! [`nn::LayerOp`] names its forward/backward kernel path; runtime-
//! registered kinds inherit a conservative per-sample default), and a
//! static per-op FLOPs/bytes cost model that [`perfmodel`] derives its
//! operation ratios from (`PerfModel::for_network`). `chaos analyze
//! --cost` prints the dispatch + roofline tables and exits nonzero on
//! any dataflow defect; all analyze JSON reports carry a
//! `schema` version field.
//!
//! Start with [`config::ArchSpec`] (the paper's Table 2 networks),
//! [`chaos::Trainer`] (the parallel trainer), and [`harness`] (regenerates
//! every table and figure of the paper's evaluation).

#![deny(unsafe_op_in_unsafe_fn)]

pub mod bench;
pub mod chaos;
pub mod config;
pub mod data;
pub mod harness;
pub mod nn;
pub mod perfmodel;
pub mod phisim;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod util;
