//! # chaos-phi
//!
//! A reproduction of **CHAOS: A Parallelization Scheme for Training
//! Convolutional Neural Networks on Intel Xeon Phi** (Viebke, Memeti,
//! Pllana, Abraham — Journal of Supercomputing, 2017) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! Layers:
//! - **L3 (this crate)** — the CHAOS coordinator: shared-weight store with
//!   controlled-Hogwild delayed updates, worker pool, epoch driver, the
//!   paper's strategy baselines, the analytic performance model, and a
//!   discrete-event Intel Xeon Phi simulator standing in for the
//!   discontinued hardware (DESIGN.md §2).
//! - **L2/L1 (python/, build time only)** — JAX model + Pallas kernels,
//!   AOT-lowered to HLO text, loaded and executed here through
//!   [`runtime`] via the PJRT CPU client. Python is never on the
//!   request path.
//!
//! Start with [`config::ArchSpec`] (the paper's Table 2 networks),
//! [`chaos::train`] (the parallel trainer), and [`harness`] (regenerates
//! every table and figure of the paper's evaluation).

pub mod bench;
pub mod chaos;
pub mod config;
pub mod data;
pub mod harness;
pub mod nn;
pub mod perfmodel;
pub mod phisim;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod util;
