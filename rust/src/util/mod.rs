//! Foundation utilities: deterministic PRNG, JSON, CLI parsing, statistics,
//! timing, and a lightweight property-testing harness.
//!
//! These replace crates (`rand`, `serde_json`, `clap`, `criterion`,
//! `proptest`) that are absent from the offline vendored registry — see
//! DESIGN.md §10.

pub mod cli;
pub mod json;
pub mod prng;
pub mod proptest;
pub mod stats;
pub mod timer;

pub use json::Json;
pub use prng::Pcg32;
pub use timer::{LayerClass, LayerTimes, Stopwatch};
