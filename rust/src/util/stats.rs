//! Small statistics helpers used by the bench harness, the reporter and the
//! performance model calibration (mean/σ over repeated runs — the paper
//! repeats every parallel configuration three times, §5.1).

/// Online mean/variance accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator); 0 for fewer than 2 samples.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Median (copies + sorts). NaN samples sort last (IEEE total order), so a
/// poisoned timing stream degrades the answer instead of panicking mid-report.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Percentile via linear interpolation, p in [0,100]. NaN samples sort last,
/// same as [`median`].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Least-squares fit of `y = a * x^b` through log-log linear regression.
/// Used to extrapolate the memory-contention table (Table 4) beyond the
/// measured thread counts, which is how the paper produces its starred rows.
pub fn fit_power_law(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let lx: Vec<f64> = xs.iter().map(|x| x.ln()).collect();
    let ly: Vec<f64> = ys.iter().map(|y| y.ln()).collect();
    let n = lx.len() as f64;
    let sx: f64 = lx.iter().sum();
    let sy: f64 = ly.iter().sum();
    let sxx: f64 = lx.iter().map(|x| x * x).sum();
    let sxy: f64 = lx.iter().zip(&ly).map(|(x, y)| x * y).sum();
    let b = (n * sxy - sx * sy) / (n * sxx - sx * sx);
    let a = ((sy - b * sx) / n).exp();
    (a, b)
}

/// Relative deviation |m - p| / p — the paper's prediction-error metric
/// (§5.3 Result 5).
pub fn relative_deviation(measured: f64, predicted: f64) -> f64 {
    (measured - predicted).abs() / predicted
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.add(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        // sample variance of this classic dataset is 4.571428...
        assert!((w.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(w.min(), 2.0);
        assert_eq!(w.max(), 9.0);
        assert_eq!(w.count(), 8);
    }

    #[test]
    fn median_and_percentile() {
        let xs = [1.0, 3.0, 2.0, 4.0];
        assert_eq!(median(&xs), 2.5);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
        assert_eq!(median(&[5.0]), 5.0);
        assert_eq!(median(&[]), 0.0);
    }

    #[test]
    fn nan_samples_do_not_panic_and_sort_last() {
        // `partial_cmp(..).unwrap()` used to panic here; total_cmp puts NaN
        // after every finite value instead.
        let xs = [f64::NAN, 1.0, 3.0];
        assert_eq!(median(&xs), 3.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert!(percentile(&xs, 100.0).is_nan());
        assert!(median(&[f64::NAN]).is_nan());
    }

    #[test]
    fn power_law_recovers_exponent() {
        // y = 3 x^0.8
        let xs: Vec<f64> = (1..=10).map(|i| i as f64 * 10.0).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x.powf(0.8)).collect();
        let (a, b) = fit_power_law(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9, "a={a}");
        assert!((b - 0.8).abs() < 1e-12, "b={b}");
    }

    #[test]
    fn relative_deviation_basic() {
        assert!((relative_deviation(110.0, 100.0) - 0.1).abs() < 1e-12);
        assert!((relative_deviation(90.0, 100.0) - 0.1).abs() < 1e-12);
    }
}
