//! Tiny command-line argument parser (the vendored registry has no `clap`).
//!
//! Supports the subcommand + `--flag value` / `--flag=value` / boolean
//! `--flag` style the `chaos` binary uses. Unknown flags are an error, so
//! typos fail loudly.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    /// Positional arguments (after the subcommand).
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
    /// Flags the command declares; used for unknown-flag detection.
    known: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    UnknownFlag(String),
    MissingValue(String),
    BadValue(String, String, &'static str),
    MissingFlag(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::UnknownFlag(name) => write!(f, "unknown flag --{name}"),
            CliError::MissingValue(name) => write!(f, "flag --{name} expects a value"),
            CliError::BadValue(name, value, ty) => {
                write!(f, "flag --{name}: cannot parse '{value}' as {ty}")
            }
            CliError::MissingFlag(name) => write!(f, "missing required flag --{name}"),
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse raw args (without argv[0]/subcommand). `known_flags` lists the
    /// accepted flag names; names ending in `!` are boolean flags.
    pub fn parse(raw: &[String], known_flags: &[&str]) -> Result<Args, CliError> {
        let mut positional = Vec::new();
        let mut flags = BTreeMap::new();
        let mut bools = Vec::new();
        let boolean: Vec<&str> = known_flags
            .iter()
            .filter(|f| f.ends_with('!'))
            .map(|f| f.trim_end_matches('!'))
            .collect();
        let valued: Vec<&str> = known_flags
            .iter()
            .filter(|f| !f.ends_with('!'))
            .map(|f| *f)
            .collect();

        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(name) = a.strip_prefix("--") {
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                if boolean.contains(&name) {
                    bools.push(name.to_string());
                } else if valued.contains(&name) {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            raw.get(i)
                                .cloned()
                                .ok_or_else(|| CliError::MissingValue(name.to_string()))?
                        }
                    };
                    flags.insert(name.to_string(), v);
                } else {
                    return Err(CliError::UnknownFlag(name.to_string()));
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Args {
            positional,
            flags,
            bools,
            known: known_flags.iter().map(|s| s.to_string()).collect(),
        })
    }

    pub fn has(&self, name: &str) -> bool {
        debug_assert!(
            self.known.iter().any(|k| k.trim_end_matches('!') == name),
            "querying undeclared flag --{name}"
        );
        self.bools.iter().any(|b| b == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn require(&self, name: &str) -> Result<&str, CliError> {
        self.get(name).ok_or_else(|| CliError::MissingFlag(name.to_string()))
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::BadValue(name.into(), v.into(), "usize")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::BadValue(name.into(), v.into(), "f64")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::BadValue(name.into(), v.into(), "u64")),
        }
    }

    /// Comma-separated usize list, e.g. `--threads 1,15,30`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>, CliError> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| CliError::BadValue(name.into(), v.into(), "usize list"))
                })
                .collect(),
        }
    }

    /// Comma-separated f64 list, e.g. `--weights 1.0,2,0.5`.
    pub fn get_f64_list(&self, name: &str, default: &[f64]) -> Result<Vec<f64>, CliError> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| CliError::BadValue(name.into(), v.into(), "f64 list"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn raw(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed_styles() {
        let a = Args::parse(
            &raw(&["small", "--threads=8", "--eta", "0.001", "--verbose"]),
            &["threads", "eta", "verbose!"],
        )
        .unwrap();
        assert_eq!(a.positional, vec!["small"]);
        assert_eq!(a.get_usize("threads", 1).unwrap(), 8);
        assert!((a.get_f64("eta", 0.0).unwrap() - 0.001).abs() < 1e-12);
        assert!(a.has("verbose"));
    }

    #[test]
    fn unknown_flag_rejected() {
        let e = Args::parse(&raw(&["--bogus", "1"]), &["threads"]).unwrap_err();
        assert!(matches!(e, CliError::UnknownFlag(_)));
    }

    #[test]
    fn missing_value_rejected() {
        let e = Args::parse(&raw(&["--threads"]), &["threads"]).unwrap_err();
        assert!(matches!(e, CliError::MissingValue(_)));
    }

    #[test]
    fn bad_value_type() {
        let a = Args::parse(&raw(&["--threads", "abc"]), &["threads"]).unwrap();
        assert!(a.get_usize("threads", 1).is_err());
    }

    #[test]
    fn list_flag() {
        let a = Args::parse(&raw(&["--threads", "1, 15,30"]), &["threads"]).unwrap();
        assert_eq!(a.get_usize_list("threads", &[]).unwrap(), vec![1, 15, 30]);
        let b = Args::parse(&raw(&[]), &["threads"]).unwrap();
        assert_eq!(b.get_usize_list("threads", &[2, 4]).unwrap(), vec![2, 4]);
    }

    #[test]
    fn f64_list_flag() {
        let a = Args::parse(&raw(&["--weights", "1.0, 2,0.5"]), &["weights"]).unwrap();
        assert_eq!(a.get_f64_list("weights", &[]).unwrap(), vec![1.0, 2.0, 0.5]);
        let b = Args::parse(&raw(&[]), &["weights"]).unwrap();
        assert_eq!(b.get_f64_list("weights", &[1.0]).unwrap(), vec![1.0]);
        let c = Args::parse(&raw(&["--weights", "1,abc"]), &["weights"]).unwrap();
        assert!(c.get_f64_list("weights", &[]).is_err());
    }

    #[test]
    fn defaults_and_required() {
        let a = Args::parse(&raw(&[]), &["out"]).unwrap();
        assert_eq!(a.get_str("out", "x.md"), "x.md");
        assert!(a.require("out").is_err());
    }
}
