//! Minimal JSON parser and emitter.
//!
//! The vendored registry carries neither `serde` nor `serde_json`; the AOT
//! pipeline writes an `artifacts/manifest.json` describing parameter shapes
//! and artifact paths, and the harness emits machine-readable experiment
//! records. This module implements the subset of JSON we need (objects,
//! arrays, strings, f64 numbers, bools, null) with precise error positions.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are stored as f64 (all our payloads are shapes,
/// counts and measurements; 2^53 integer precision is ample).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ----- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= usize::MAX as f64 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// `get` that errors with the key name — for manifest parsing.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key '{key}'"))
    }

    /// Vec<usize> from a numeric array (shape lists).
    pub fn usize_vec(&self) -> anyhow::Result<Vec<usize>> {
        let arr = self
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("expected array"))?;
        arr.iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("expected unsigned int")))
            .collect()
    }

    // ----- builders ---------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    pub fn usizes(items: &[usize]) -> Json {
        Json::Arr(items.iter().map(|&u| Json::Num(u as f64)).collect())
    }

    /// Pretty-print with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

impl Json {
    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !items.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    Json::Str(k.clone()).write(out, indent + 1, pretty);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !map.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a json value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self
                                .bump()
                                .and_then(|c| (c as char).to_digit(16))
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16 + d;
                        }
                        // Surrogate pairs are not expected in our payloads;
                        // map lone surrogates to the replacement character.
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8: find the full char at pos-1.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = match c {
                            0xc0..=0xdf => 2,
                            0xe0..=0xef => 3,
                            _ => 4,
                        };
                        let end = (start + len).min(self.bytes.len());
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"shape": [2, 3, 4], "name": "w", "ok": true}"#).unwrap();
        assert_eq!(v.get("shape").unwrap().usize_vec().unwrap(), vec![2, 3, 4]);
        assert_eq!(v.get("name").unwrap().as_str(), Some("w"));
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert!(v.get("missing").is_none());
        assert!(v.req("missing").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("0").unwrap().as_f64(), Some(0.0));
        assert_eq!(Json::parse("-17").unwrap().as_f64(), Some(-17.0));
        assert_eq!(Json::parse("3.5e-2").unwrap().as_f64(), Some(0.035));
        assert_eq!(Json::parse("42").unwrap().as_usize(), Some(42));
        assert_eq!(Json::parse("-1").unwrap().as_usize(), None);
        assert_eq!(Json::parse("1.5").unwrap().as_usize(), None);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""héllo A ✓""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo A ✓"));
        // Round-trip through the emitter.
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::obj(vec![
            ("arch", Json::str("small")),
            ("shapes", Json::arr(vec![Json::usizes(&[5, 1, 4, 4]), Json::usizes(&[5])])),
        ]);
        let p = v.pretty();
        assert!(p.contains('\n'));
        assert_eq!(Json::parse(&p).unwrap(), v);
    }

    #[test]
    fn integer_emission_has_no_fraction() {
        assert_eq!(Json::num(3.0).to_string(), "3");
        assert_eq!(Json::num(3.25).to_string(), "3.25");
    }
}
