//! Deterministic pseudo-random number generation.
//!
//! The vendored registry has no `rand`, so we ship a small, well-tested
//! PCG-XSH-RR 64/32 generator (O'Neill, 2014). Determinism matters here:
//! the paper's accuracy-parity experiments (Table 7) compare parallel runs
//! against a sequential baseline, which is only meaningful when both start
//! from identical weight initializations and dataset shuffles.

/// PCG-XSH-RR 64/32: 64-bit LCG state, 32-bit output with a random rotation.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and a stream id. Different streams
    /// with the same seed are statistically independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience constructor on stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Stream id this generator was created with. Two generators sharing a
    /// stream id walk identical sequences for the same seed, so the
    /// dataflow auditor treats duplicate streams as an aliasing defect.
    #[inline]
    pub fn stream(&self) -> u64 {
        self.inc >> 1
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64-bit output (two draws).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        // 24 bits of mantissa.
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Unbiased integer in [0, bound) via Lemire rejection.
    #[inline]
    pub fn below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (bound as u64);
        let mut l = m as u32;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (bound as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Integer in [lo, hi) (usize convenience).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u32) as usize
    }

    /// Standard normal via Box–Muller (one value per call; the spare is
    /// discarded to keep the generator state simple to reason about).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f32();
            if u1 > 1e-7 {
                let u2 = self.next_f32();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_ms(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.below((i + 1) as u32) as usize;
            slice.swap(i, j);
        }
    }

    /// Fill a slice with uniform values in [lo, hi).
    pub fn fill_uniform(&mut self, slice: &mut [f32], lo: f32, hi: f32) {
        for v in slice.iter_mut() {
            *v = self.uniform(lo, hi);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg32::new(42, 7);
        let mut b = Pcg32::new(42, 7);
        for _ in 0..1000 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn stream_id_round_trips() {
        assert_eq!(Pcg32::new(1, 7).stream(), 7);
        assert_eq!(Pcg32::seeded(99).stream(), 0);
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 3, "streams should be independent, {same} collisions");
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg32::seeded(1);
        for _ in 0..10_000 {
            let v = r.next_f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Pcg32::seeded(3);
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            let expect = n / 10;
            assert!(
                (c as i64 - expect as i64).unsigned_abs() < (expect / 10) as u64,
                "bucket count {c} too far from {expect}"
            );
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(9);
        let n = 200_000;
        let mut sum = 0.0f64;
        let mut sq = 0.0f64;
        for _ in 0..n {
            let v = r.normal() as f64;
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffle left input in order");
    }

    #[test]
    fn range_bounds() {
        let mut r = Pcg32::seeded(8);
        for _ in 0..1000 {
            let v = r.range(5, 15);
            assert!((5..15).contains(&v));
        }
    }
}
