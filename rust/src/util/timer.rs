//! Wall-clock timing and the per-layer time accounting the paper's
//! evaluation is built on (Tables 1 and 5 report seconds per layer class;
//! Table 6 reports per-layer speedups).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Simple scope timer returning elapsed seconds.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_nanos(&self) -> u64 {
        self.start.elapsed().as_nanos() as u64
    }
}

/// Per-op-kind time classes. The first eight are the classes the paper's
/// evaluation reports (Table 5 splits forward/backward into convolutional
/// and fully-connected; pooling is folded into its adjacent class there —
/// we track it separately and let the harness aggregate). Max- and
/// average-pooling share the pool classes; dropout/identity ops get their
/// own pair; layer kinds registered from user code default to the `Other`
/// pair unless their ops override [`crate::nn::LayerOp::class`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerClass {
    ConvForward,
    ConvBackward,
    PoolForward,
    PoolBackward,
    FcForward,
    FcBackward,
    OutputForward,
    OutputBackward,
    DropoutForward,
    DropoutBackward,
    OtherForward,
    OtherBackward,
}

pub const LAYER_CLASSES: [LayerClass; 12] = [
    LayerClass::ConvForward,
    LayerClass::ConvBackward,
    LayerClass::PoolForward,
    LayerClass::PoolBackward,
    LayerClass::FcForward,
    LayerClass::FcBackward,
    LayerClass::OutputForward,
    LayerClass::OutputBackward,
    LayerClass::DropoutForward,
    LayerClass::DropoutBackward,
    LayerClass::OtherForward,
    LayerClass::OtherBackward,
];

impl LayerClass {
    pub fn index(self) -> usize {
        match self {
            LayerClass::ConvForward => 0,
            LayerClass::ConvBackward => 1,
            LayerClass::PoolForward => 2,
            LayerClass::PoolBackward => 3,
            LayerClass::FcForward => 4,
            LayerClass::FcBackward => 5,
            LayerClass::OutputForward => 6,
            LayerClass::OutputBackward => 7,
            LayerClass::DropoutForward => 8,
            LayerClass::DropoutBackward => 9,
            LayerClass::OtherForward => 10,
            LayerClass::OtherBackward => 11,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            LayerClass::ConvForward => "conv/fwd",
            LayerClass::ConvBackward => "conv/bwd",
            LayerClass::PoolForward => "pool/fwd",
            LayerClass::PoolBackward => "pool/bwd",
            LayerClass::FcForward => "fc/fwd",
            LayerClass::FcBackward => "fc/bwd",
            LayerClass::OutputForward => "out/fwd",
            LayerClass::OutputBackward => "out/bwd",
            LayerClass::DropoutForward => "drop/fwd",
            LayerClass::DropoutBackward => "drop/bwd",
            LayerClass::OtherForward => "other/fwd",
            LayerClass::OtherBackward => "other/bwd",
        }
    }
}

/// Thread-safe accumulator of nanoseconds per layer class. Shared by all
/// workers (relaxed atomics: we only need sum integrity, not ordering).
#[derive(Debug, Default)]
pub struct LayerTimes {
    nanos: [AtomicU64; 12],
}

impl LayerTimes {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add(&self, class: LayerClass, nanos: u64) {
        self.nanos[class.index()].fetch_add(nanos, Ordering::Relaxed);
    }

    pub fn get_secs(&self, class: LayerClass) -> f64 {
        self.nanos[class.index()].load(Ordering::Relaxed) as f64 * 1e-9
    }

    pub fn total_secs(&self) -> f64 {
        LAYER_CLASSES.iter().map(|&c| self.get_secs(c)).sum()
    }

    /// Snapshot as (class, seconds) pairs.
    pub fn snapshot(&self) -> Vec<(LayerClass, f64)> {
        LAYER_CLASSES.iter().map(|&c| (c, self.get_secs(c))).collect()
    }

    pub fn reset(&self) {
        for a in &self.nanos {
            a.store(0, Ordering::Relaxed);
        }
    }
}

/// Format seconds compactly for table output.
pub fn fmt_secs(s: f64) -> String {
    if s >= 3600.0 {
        format!("{:.1} h", s / 3600.0)
    } else if s >= 60.0 {
        format!("{:.1} min", s / 60.0)
    } else if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.2} µs", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_times_accumulate() {
        let t = LayerTimes::new();
        t.add(LayerClass::ConvForward, 1_000_000_000);
        t.add(LayerClass::ConvForward, 500_000_000);
        t.add(LayerClass::FcBackward, 250_000_000);
        assert!((t.get_secs(LayerClass::ConvForward) - 1.5).abs() < 1e-9);
        assert!((t.get_secs(LayerClass::FcBackward) - 0.25).abs() < 1e-9);
        assert!((t.total_secs() - 1.75).abs() < 1e-9);
        t.reset();
        assert_eq!(t.total_secs(), 0.0);
    }

    #[test]
    fn layer_times_threaded_sum() {
        let t = std::sync::Arc::new(LayerTimes::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let t = t.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        t.add(LayerClass::ConvBackward, 1);
                    }
                });
            }
        });
        assert_eq!(
            (t.get_secs(LayerClass::ConvBackward) * 1e9).round() as u64,
            8000
        );
    }

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(sw.elapsed_secs() >= 0.004);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert_eq!(fmt_secs(7200.0), "2.0 h");
        assert_eq!(fmt_secs(90.0), "1.5 min");
        assert_eq!(fmt_secs(2.5), "2.50 s");
        assert_eq!(fmt_secs(0.0025), "2.50 ms");
        assert_eq!(fmt_secs(0.0000025), "2.50 µs");
    }

    #[test]
    fn class_indices_unique() {
        let mut seen = std::collections::HashSet::new();
        for c in LAYER_CLASSES {
            assert!(seen.insert(c.index()));
            assert!(!c.name().is_empty());
        }
    }
}
