//! Lightweight property-based testing (the vendored registry has no
//! `proptest`). A property is run against many PRNG-generated cases; on
//! failure we re-run a deterministic "shrink-lite" pass that retries the
//! failing seed with scaled-down size hints, then report the smallest
//! failing seed so the case can be replayed in a unit test.

use super::prng::Pcg32;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    /// Maximum "size" hint passed to generators (e.g. max dimension).
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, seed: 0xC4A05, max_size: 16 }
    }
}

/// A generated test case: the generator gets a PRNG and a size hint.
pub fn run<G, T, P>(cfg: Config, gen: G, prop: P)
where
    G: Fn(&mut Pcg32, usize) -> T,
    P: Fn(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64);
        let mut rng = Pcg32::new(case_seed, 17);
        // Grow sizes over the run: early cases are small, later ones large.
        let size = 1 + (cfg.max_size.saturating_sub(1)) * case / cfg.cases.max(1);
        let input = gen(&mut rng, size.max(1));
        if let Err(msg) = prop(&input) {
            // Shrink-lite: try the same seed at smaller sizes to find a
            // more readable counterexample.
            let mut smallest: Option<(usize, T)> = None;
            for s in 1..size {
                let mut r2 = Pcg32::new(case_seed, 17);
                let candidate = gen(&mut r2, s);
                if prop(&candidate).is_err() {
                    smallest = Some((s, candidate));
                    break;
                }
            }
            match smallest {
                Some((s, c)) => panic!(
                    "property failed (case {case}, seed {case_seed:#x}, shrunk to size {s}):\n  {msg}\n  input: {c:?}"
                ),
                None => panic!(
                    "property failed (case {case}, seed {case_seed:#x}, size {size}):\n  {msg}\n  input: {input:?}"
                ),
            }
        }
    }
}

/// Assert two f32 slices are elementwise close; returns an Err message
/// suitable for `run` properties.
pub fn check_close(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if !(x - y).abs().le(&tol) {
            return Err(format!(
                "element {i}: {x} vs {y} (|diff|={}, tol={tol})",
                (x - y).abs()
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::Cell::new(0usize);
        run(
            Config { cases: 32, ..Default::default() },
            |rng, size| {
                counter.set(counter.get() + 1);
                (0..size).map(|_| rng.next_f32()).collect::<Vec<f32>>()
            },
            |v| {
                if v.iter().all(|x| (0.0..1.0).contains(x)) {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
        count += counter.get();
        assert_eq!(count, 32);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        run(
            Config { cases: 8, ..Default::default() },
            |rng, _| rng.below(100),
            |&v| if v < 1000 { Err(format!("forced failure on {v}")) } else { Ok(()) },
        );
    }

    #[test]
    fn check_close_reports_index() {
        let err = check_close(&[1.0, 2.0], &[1.0, 2.5], 0.1, 0.0).unwrap_err();
        assert!(err.contains("element 1"), "{err}");
        assert!(check_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-8], 1e-6, 0.0).is_ok());
        assert!(check_close(&[1.0], &[1.0, 2.0], 0.1, 0.0).is_err());
    }
}
