//! CNN architecture specifications — paper Table 2, exactly, plus the open
//! layer vocabulary that grew out of it.
//!
//! All three paper networks take a 29×29 single-channel input. Their
//! convolutions are "valid" with stride 1 and full map-to-map connectivity
//! plus one bias per output map (weights = maps·(prev_maps·k² + 1), matching
//! every weight count in Table 2). Max-pooling uses kernel k with stride k,
//! except the large network's third pooling, where 6×6 is pooled by 2×2 to
//! 3×3 — the only reading consistent with the 135,150 fully-connected
//! weights the paper states (DESIGN.md §5 documents the Table 2
//! inconsistency).
//!
//! [`LayerSpec`] is the *data* of one layer; all behaviour — JSON parsing
//! and serialization, structural validation, geometry/parameter layout and
//! compilation into an executable op — lives with the layer *kind*
//! registered in [`crate::nn::layer`]. [`ArchSpec::from_json`],
//! [`ArchSpec::to_json`] and [`ArchSpec::validate`] all delegate to the
//! registered kinds, so a kind registered at runtime
//! ([`crate::nn::layer::register`]) is immediately loadable from JSON and
//! trainable, with no changes here.

use crate::util::Json;

/// Activation selected per conv / fully-connected layer (JSON `"act"`
/// field; scaled tanh is the paper's default).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Act {
    /// LeCun-scaled tanh `1.7159·tanh(2x/3)` (the Cireşan default).
    #[default]
    ScaledTanh,
    /// Rectified linear unit, `max(0, x)`.
    Relu,
    /// No activation (linear layer).
    Identity,
}

impl Act {
    pub fn name(self) -> &'static str {
        match self {
            Act::ScaledTanh => "tanh",
            Act::Relu => "relu",
            Act::Identity => "identity",
        }
    }

    pub fn parse(text: &str) -> anyhow::Result<Act> {
        Ok(match text {
            "tanh" | "scaled-tanh" => Act::ScaledTanh,
            "relu" => Act::Relu,
            "identity" | "linear" | "none" => Act::Identity,
            other => anyhow::bail!("unknown activation '{other}' (tanh|relu|identity)"),
        })
    }
}

/// One layer of a network specification (pure data — see module docs).
#[derive(Debug, Clone, PartialEq)]
pub enum LayerSpec {
    /// Square single-channel input of side `side`.
    Input { side: usize },
    /// Convolution: `maps` output feature maps, `kernel`×`kernel` receptive
    /// field, zero padding `pad`, stride `stride`, fully connected to all
    /// input maps. The paper's convolutions are `stride: 1, pad: 0` —
    /// construct those with [`LayerSpec::conv`].
    Conv { maps: usize, kernel: usize, stride: usize, pad: usize, act: Act },
    /// Max pooling with `kernel`×`kernel` windows and stride = kernel.
    MaxPool { kernel: usize },
    /// Average pooling with `kernel`×`kernel` windows and stride = kernel.
    AvgPool { kernel: usize },
    /// Fully connected layer with `neurons` outputs.
    FullyConnected { neurons: usize, act: Act },
    /// Inverted dropout: keeps each activation with probability `1 - rate`
    /// (scaled by `1/(1-rate)`); identity at `rate == 0` and during
    /// evaluation. Masks are drawn from the per-worker scratch PRNG.
    Dropout { rate: f32 },
    /// Output layer: fully connected + softmax over `classes`.
    Output { classes: usize },
    /// A layer kind registered at runtime via [`crate::nn::layer::register`]:
    /// the kind name plus its (key, value) arguments.
    Custom { kind: String, args: Vec<(String, f64)> },
}

impl LayerSpec {
    /// Paper-style convolution: valid padding, stride 1, scaled tanh.
    pub fn conv(maps: usize, kernel: usize) -> LayerSpec {
        LayerSpec::Conv { maps, kernel, stride: 1, pad: 0, act: Act::ScaledTanh }
    }

    /// General convolution with explicit stride / zero padding / activation.
    pub fn conv_ex(maps: usize, kernel: usize, stride: usize, pad: usize, act: Act) -> LayerSpec {
        LayerSpec::Conv { maps, kernel, stride, pad, act }
    }

    /// Fully-connected layer with the default scaled-tanh activation.
    pub fn fc(neurons: usize) -> LayerSpec {
        LayerSpec::FullyConnected { neurons, act: Act::ScaledTanh }
    }

    /// Fully-connected layer with an explicit activation.
    pub fn fc_act(neurons: usize, act: Act) -> LayerSpec {
        LayerSpec::FullyConnected { neurons, act }
    }

    /// A runtime-registered custom layer kind.
    pub fn custom(kind: impl Into<String>, args: Vec<(String, f64)>) -> LayerSpec {
        LayerSpec::Custom { kind: kind.into(), args }
    }
}

/// A named architecture (an ordered stack of layers).
#[derive(Debug, Clone, PartialEq)]
pub struct ArchSpec {
    pub name: String,
    pub layers: Vec<LayerSpec>,
    /// Epoch count the paper trains this network for.
    pub paper_epochs: usize,
}

/// Names of the three paper architectures, in Table 2 order of appearance.
pub const PAPER_ARCHS: [&str; 3] = ["small", "medium", "large"];

impl ArchSpec {
    /// Table 2 "small": 29² → C(5,4×4) → P2 → C(10,5×5) → P3 → FC50 → 10.
    pub fn small() -> ArchSpec {
        ArchSpec {
            name: "small".into(),
            layers: vec![
                LayerSpec::Input { side: 29 },
                LayerSpec::conv(5, 4),
                LayerSpec::MaxPool { kernel: 2 },
                LayerSpec::conv(10, 5),
                LayerSpec::MaxPool { kernel: 3 },
                LayerSpec::fc(50),
                LayerSpec::Output { classes: 10 },
            ],
            paper_epochs: 70,
        }
    }

    /// Table 2 "medium": 29² → C(20,4×4) → P2 → C(40,5×5) → P3 → FC150 → 10.
    pub fn medium() -> ArchSpec {
        ArchSpec {
            name: "medium".into(),
            layers: vec![
                LayerSpec::Input { side: 29 },
                LayerSpec::conv(20, 4),
                LayerSpec::MaxPool { kernel: 2 },
                LayerSpec::conv(40, 5),
                LayerSpec::MaxPool { kernel: 3 },
                LayerSpec::fc(150),
                LayerSpec::Output { classes: 10 },
            ],
            paper_epochs: 70,
        }
    }

    /// Table 2 "large": 29² → C(20,4×4) → P1 → C(60,5×5) → P2 → C(100,6×6)
    /// → P2 → FC150 → 10. (Third pooling is 2×2: see module docs. The P1
    /// identity pool is faithful to the paper and is the one architecture
    /// the validator's identity-pool rejection carves out.)
    pub fn large() -> ArchSpec {
        ArchSpec {
            name: "large".into(),
            layers: vec![
                LayerSpec::Input { side: 29 },
                LayerSpec::conv(20, 4),
                LayerSpec::MaxPool { kernel: 1 },
                LayerSpec::conv(60, 5),
                LayerSpec::MaxPool { kernel: 2 },
                LayerSpec::conv(100, 6),
                LayerSpec::MaxPool { kernel: 2 },
                LayerSpec::fc(150),
                LayerSpec::Output { classes: 10 },
            ],
            paper_epochs: 15,
        }
    }

    /// A miniature but structurally complete network (conv/pool/conv/pool/
    /// fc/output on a 13×13 input). Not from the paper — used by tests,
    /// benches and examples where wall-clock budget matters.
    pub fn tiny() -> ArchSpec {
        ArchSpec {
            name: "tiny".into(),
            layers: vec![
                LayerSpec::Input { side: 13 },
                LayerSpec::conv(3, 4),            // 10x10
                LayerSpec::MaxPool { kernel: 2 }, // 5x5
                LayerSpec::conv(4, 2),            // 4x4
                LayerSpec::MaxPool { kernel: 2 }, // 2x2
                LayerSpec::fc(8),
                LayerSpec::Output { classes: 10 },
            ],
            paper_epochs: 1,
        }
    }

    /// Look up a paper architecture by name ("tiny" is also accepted for
    /// the test network).
    pub fn by_name(name: &str) -> Option<ArchSpec> {
        match name {
            "small" => Some(Self::small()),
            "medium" => Some(Self::medium()),
            "large" => Some(Self::large()),
            "tiny" => Some(Self::tiny()),
            _ => None,
        }
    }

    /// Side of the square input layer. Panics on an arch without a leading
    /// input layer (which [`Self::validate`] rejects).
    pub fn input_side(&self) -> usize {
        match self.layers.first() {
            Some(LayerSpec::Input { side }) => *side,
            _ => panic!("architecture '{}' has no input layer", self.name),
        }
    }

    /// Parse an architecture from a JSON description, e.g.
    /// `{"name":"custom","epochs":10,"layers":[{"input":29},
    /// {"conv":{"maps":5,"kernel":4,"act":"relu"}},{"pool":2},{"avgpool":2},
    /// {"dropout":0.25},{"fc":50},{"output":10}]}`.
    ///
    /// Each layer object's single key selects the registered kind
    /// ([`crate::nn::layer`]); the value is handed to that kind's parser,
    /// so runtime-registered kinds are loadable with no changes here.
    pub fn from_json(j: &Json) -> anyhow::Result<ArchSpec> {
        let name = j.req("name")?.as_str().ok_or_else(|| anyhow::anyhow!("name must be string"))?;
        let epochs = j.get("epochs").and_then(|e| e.as_usize()).unwrap_or(10);
        let layers_json = j
            .req("layers")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("layers must be an array"))?;
        let mut layers = Vec::new();
        for l in layers_json {
            let obj = l.as_obj().ok_or_else(|| anyhow::anyhow!("layer must be an object"))?;
            let (key, val) = obj.iter().next().ok_or_else(|| anyhow::anyhow!("empty layer"))?;
            anyhow::ensure!(
                obj.len() == 1,
                "layer object must have exactly one key (the kind), got {:?}",
                obj.keys().collect::<Vec<_>>()
            );
            layers.push(crate::nn::layer::from_json(key, val)?);
        }
        let spec = ArchSpec { name: name.to_string(), layers, paper_epochs: epochs };
        spec.validate()?;
        Ok(spec)
    }

    /// Load an architecture from a JSON file.
    pub fn from_file(path: &str) -> anyhow::Result<ArchSpec> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text)?;
        Self::from_json(&j)
    }

    /// Serialize to JSON (inverse of [`Self::from_json`]); each layer's
    /// body is produced by its registered kind.
    pub fn to_json(&self) -> Json {
        let layers: Vec<Json> = self
            .layers
            .iter()
            .map(|l| {
                let body = match crate::nn::layer::kind_for(l) {
                    Ok(kind) => kind.to_json(l),
                    // A Custom spec whose kind is not (or no longer)
                    // registered still serializes faithfully from its own
                    // data; built-in kinds are always registered.
                    Err(_) => match l {
                        LayerSpec::Custom { args, .. } => crate::nn::layer::args_to_json(args),
                        _ => unreachable!("builtin layer kinds are always registered"),
                    },
                };
                Json::obj(vec![(crate::nn::layer::kind_of(l), body)])
            })
            .collect();
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("epochs", Json::num(self.paper_epochs as f64)),
            ("layers", Json::arr(layers)),
        ])
    }

    /// Structural validation: starts with input, ends with output, every
    /// layer's geometry folds cleanly through its registered kind (pooling
    /// divides evenly, convolutions fit, no feature-map layers after the
    /// flatten, no identity pools outside the paper's "large" network…).
    pub fn validate(&self) -> anyhow::Result<()> {
        crate::nn::dims::try_compute_dims(self).map(|_| ())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_archs_validate() {
        for name in PAPER_ARCHS {
            ArchSpec::by_name(name).unwrap().validate().unwrap();
        }
        ArchSpec::by_name("tiny").unwrap().validate().unwrap();
        assert!(ArchSpec::by_name("giant").is_none());
    }

    #[test]
    fn json_roundtrip() {
        for name in PAPER_ARCHS {
            let a = ArchSpec::by_name(name).unwrap();
            let j = a.to_json();
            let b = ArchSpec::from_json(&j).unwrap();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn json_roundtrip_new_layer_kinds() {
        let a = ArchSpec {
            name: "zoo".into(),
            layers: vec![
                LayerSpec::Input { side: 29 },
                LayerSpec::conv_ex(8, 5, 2, 2, Act::Relu),
                LayerSpec::AvgPool { kernel: 3 },
                LayerSpec::conv(12, 2),
                LayerSpec::MaxPool { kernel: 2 },
                LayerSpec::Dropout { rate: 0.25 },
                LayerSpec::fc_act(64, Act::Relu),
                LayerSpec::Output { classes: 10 },
            ],
            paper_epochs: 3,
        };
        a.validate().unwrap();
        let b = ArchSpec::from_json(&a.to_json()).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn fc_json_accepts_both_shorthand_and_object() {
        let j = Json::parse(
            r#"{"name":"x","layers":[{"input":8},{"fc":5},
                {"fc":{"neurons":4,"act":"relu"}},{"output":10}]}"#,
        )
        .unwrap();
        let a = ArchSpec::from_json(&j).unwrap();
        assert_eq!(a.layers[1], LayerSpec::fc(5));
        assert_eq!(a.layers[2], LayerSpec::fc_act(4, Act::Relu));
    }

    #[test]
    fn unknown_layer_kind_lists_registry() {
        let j = Json::parse(r#"{"name":"x","layers":[{"warp":3}]}"#).unwrap();
        let e = ArchSpec::from_json(&j).unwrap_err().to_string();
        assert!(e.contains("unknown layer kind 'warp'") && e.contains("conv"), "{e}");
    }

    #[test]
    fn validate_rejects_bad_stacks() {
        let no_input = ArchSpec {
            name: "x".into(),
            layers: vec![LayerSpec::Output { classes: 10 }],
            paper_epochs: 1,
        };
        assert!(no_input.validate().is_err());

        let pool_too_big = ArchSpec {
            name: "x".into(),
            layers: vec![
                LayerSpec::Input { side: 5 },
                LayerSpec::MaxPool { kernel: 7 },
                LayerSpec::Output { classes: 10 },
            ],
            paper_epochs: 1,
        };
        assert!(pool_too_big.validate().is_err());

        let uneven_pool = ArchSpec {
            name: "x".into(),
            layers: vec![
                LayerSpec::Input { side: 9 },
                LayerSpec::MaxPool { kernel: 2 },
                LayerSpec::Output { classes: 10 },
            ],
            paper_epochs: 1,
        };
        assert!(uneven_pool.validate().is_err());

        let conv_after_fc = ArchSpec {
            name: "x".into(),
            layers: vec![
                LayerSpec::Input { side: 9 },
                LayerSpec::fc(5),
                LayerSpec::conv(2, 2),
                LayerSpec::Output { classes: 10 },
            ],
            paper_epochs: 1,
        };
        assert!(conv_after_fc.validate().is_err());

        let bad_dropout = ArchSpec {
            name: "x".into(),
            layers: vec![
                LayerSpec::Input { side: 9 },
                LayerSpec::Dropout { rate: 1.0 },
                LayerSpec::Output { classes: 10 },
            ],
            paper_epochs: 1,
        };
        assert!(bad_dropout.validate().is_err());
    }

    #[test]
    fn validate_rejects_identity_pools_except_paper_large() {
        let p1 = |name: &str| ArchSpec {
            name: name.into(),
            layers: vec![
                LayerSpec::Input { side: 9 },
                LayerSpec::MaxPool { kernel: 1 },
                LayerSpec::Output { classes: 10 },
            ],
            paper_epochs: 1,
        };
        let e = p1("user-net").validate().unwrap_err().to_string();
        assert!(e.contains("identity pool"), "{e}");
        // The carve-out keys on the paper's exact layer stack, not the
        // name: naming an unrelated P1 stack "large" does not bypass it…
        assert!(p1("large").validate().is_err());
        // …while the paper stack passes under any name.
        ArchSpec::large().validate().unwrap();
        let renamed = ArchSpec { name: "large-v2".into(), ..ArchSpec::large() };
        renamed.validate().unwrap();
    }

    #[test]
    fn paper_epochs_match() {
        assert_eq!(ArchSpec::small().paper_epochs, 70);
        assert_eq!(ArchSpec::medium().paper_epochs, 70);
        assert_eq!(ArchSpec::large().paper_epochs, 15);
    }

    #[test]
    fn act_parse_roundtrip() {
        for act in [Act::ScaledTanh, Act::Relu, Act::Identity] {
            assert_eq!(Act::parse(act.name()).unwrap(), act);
        }
        assert!(Act::parse("gelu").is_err());
    }
}
