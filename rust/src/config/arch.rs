//! CNN architecture specifications — paper Table 2, exactly.
//!
//! All three networks take a 29×29 single-channel input. Convolutions are
//! "valid" with stride 1 and full map-to-map connectivity plus one bias per
//! output map (weights = maps·(prev_maps·k² + 1), matching every weight
//! count in Table 2). Max-pooling uses kernel k with stride k, except the
//! large network's third pooling, where 6×6 is pooled by 2×2 to 3×3 — the
//! only reading consistent with the 135,150 fully-connected weights the
//! paper states (DESIGN.md §5 documents the Table 2 inconsistency).

use crate::util::Json;

/// One layer of a network specification.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerSpec {
    /// Square single-channel input of side `side`.
    Input { side: usize },
    /// Convolution: `maps` output feature maps, `kernel`×`kernel` receptive
    /// field, valid padding, stride 1, fully connected to all input maps.
    Conv { maps: usize, kernel: usize },
    /// Max pooling with `kernel`×`kernel` windows and stride = kernel.
    MaxPool { kernel: usize },
    /// Fully connected layer with `neurons` outputs.
    FullyConnected { neurons: usize },
    /// Output layer: fully connected + softmax over `classes`.
    Output { classes: usize },
}

/// A named architecture (an ordered stack of layers).
#[derive(Debug, Clone, PartialEq)]
pub struct ArchSpec {
    pub name: String,
    pub layers: Vec<LayerSpec>,
    /// Epoch count the paper trains this network for.
    pub paper_epochs: usize,
}

/// Names of the three paper architectures, in Table 2 order of appearance.
pub const PAPER_ARCHS: [&str; 3] = ["small", "medium", "large"];

impl ArchSpec {
    /// Table 2 "small": 29² → C(5,4×4) → P2 → C(10,5×5) → P3 → FC50 → 10.
    pub fn small() -> ArchSpec {
        ArchSpec {
            name: "small".into(),
            layers: vec![
                LayerSpec::Input { side: 29 },
                LayerSpec::Conv { maps: 5, kernel: 4 },
                LayerSpec::MaxPool { kernel: 2 },
                LayerSpec::Conv { maps: 10, kernel: 5 },
                LayerSpec::MaxPool { kernel: 3 },
                LayerSpec::FullyConnected { neurons: 50 },
                LayerSpec::Output { classes: 10 },
            ],
            paper_epochs: 70,
        }
    }

    /// Table 2 "medium": 29² → C(20,4×4) → P2 → C(40,5×5) → P3 → FC150 → 10.
    pub fn medium() -> ArchSpec {
        ArchSpec {
            name: "medium".into(),
            layers: vec![
                LayerSpec::Input { side: 29 },
                LayerSpec::Conv { maps: 20, kernel: 4 },
                LayerSpec::MaxPool { kernel: 2 },
                LayerSpec::Conv { maps: 40, kernel: 5 },
                LayerSpec::MaxPool { kernel: 3 },
                LayerSpec::FullyConnected { neurons: 150 },
                LayerSpec::Output { classes: 10 },
            ],
            paper_epochs: 70,
        }
    }

    /// Table 2 "large": 29² → C(20,4×4) → P1 → C(60,5×5) → P2 → C(100,6×6)
    /// → P2 → FC150 → 10. (Third pooling is 2×2: see module docs.)
    pub fn large() -> ArchSpec {
        ArchSpec {
            name: "large".into(),
            layers: vec![
                LayerSpec::Input { side: 29 },
                LayerSpec::Conv { maps: 20, kernel: 4 },
                LayerSpec::MaxPool { kernel: 1 },
                LayerSpec::Conv { maps: 60, kernel: 5 },
                LayerSpec::MaxPool { kernel: 2 },
                LayerSpec::Conv { maps: 100, kernel: 6 },
                LayerSpec::MaxPool { kernel: 2 },
                LayerSpec::FullyConnected { neurons: 150 },
                LayerSpec::Output { classes: 10 },
            ],
            paper_epochs: 15,
        }
    }

    /// A miniature but structurally complete network (conv/pool/conv/pool/
    /// fc/output on a 13×13 input). Not from the paper — used by tests,
    /// benches and examples where wall-clock budget matters.
    pub fn tiny() -> ArchSpec {
        ArchSpec {
            name: "tiny".into(),
            layers: vec![
                LayerSpec::Input { side: 13 },
                LayerSpec::Conv { maps: 3, kernel: 4 }, // 10x10
                LayerSpec::MaxPool { kernel: 2 },       // 5x5
                LayerSpec::Conv { maps: 4, kernel: 2 }, // 4x4
                LayerSpec::MaxPool { kernel: 2 },       // 2x2
                LayerSpec::FullyConnected { neurons: 8 },
                LayerSpec::Output { classes: 10 },
            ],
            paper_epochs: 1,
        }
    }

    /// Look up a paper architecture by name ("tiny" is also accepted for
    /// the test network).
    pub fn by_name(name: &str) -> Option<ArchSpec> {
        match name {
            "small" => Some(Self::small()),
            "medium" => Some(Self::medium()),
            "large" => Some(Self::large()),
            "tiny" => Some(Self::tiny()),
            _ => None,
        }
    }

    /// Parse an architecture from a JSON description, e.g.
    /// `{"name":"custom","epochs":10,"layers":[{"input":29},{"conv":{"maps":5,"kernel":4}},
    /// {"pool":2},{"fc":50},{"output":10}]}`.
    pub fn from_json(j: &Json) -> anyhow::Result<ArchSpec> {
        let name = j.req("name")?.as_str().ok_or_else(|| anyhow::anyhow!("name must be string"))?;
        let epochs = j.get("epochs").and_then(|e| e.as_usize()).unwrap_or(10);
        let layers_json = j
            .req("layers")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("layers must be an array"))?;
        let mut layers = Vec::new();
        for l in layers_json {
            let obj = l.as_obj().ok_or_else(|| anyhow::anyhow!("layer must be an object"))?;
            let (key, val) = obj.iter().next().ok_or_else(|| anyhow::anyhow!("empty layer"))?;
            let layer = match key.as_str() {
                "input" => LayerSpec::Input {
                    side: val.as_usize().ok_or_else(|| anyhow::anyhow!("input side"))?,
                },
                "conv" => LayerSpec::Conv {
                    maps: val.req("maps")?.as_usize().ok_or_else(|| anyhow::anyhow!("conv maps"))?,
                    kernel: val
                        .req("kernel")?
                        .as_usize()
                        .ok_or_else(|| anyhow::anyhow!("conv kernel"))?,
                },
                "pool" => LayerSpec::MaxPool {
                    kernel: val.as_usize().ok_or_else(|| anyhow::anyhow!("pool kernel"))?,
                },
                "fc" => LayerSpec::FullyConnected {
                    neurons: val.as_usize().ok_or_else(|| anyhow::anyhow!("fc neurons"))?,
                },
                "output" => LayerSpec::Output {
                    classes: val.as_usize().ok_or_else(|| anyhow::anyhow!("output classes"))?,
                },
                other => anyhow::bail!("unknown layer type '{other}'"),
            };
            layers.push(layer);
        }
        let spec = ArchSpec { name: name.to_string(), layers, paper_epochs: epochs };
        spec.validate()?;
        Ok(spec)
    }

    /// Load an architecture from a JSON file.
    pub fn from_file(path: &str) -> anyhow::Result<ArchSpec> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text)?;
        Self::from_json(&j)
    }

    /// Serialize to JSON (inverse of [`Self::from_json`]).
    pub fn to_json(&self) -> Json {
        let layers: Vec<Json> = self
            .layers
            .iter()
            .map(|l| match *l {
                LayerSpec::Input { side } => Json::obj(vec![("input", Json::num(side as f64))]),
                LayerSpec::Conv { maps, kernel } => Json::obj(vec![(
                    "conv",
                    Json::obj(vec![
                        ("maps", Json::num(maps as f64)),
                        ("kernel", Json::num(kernel as f64)),
                    ]),
                )]),
                LayerSpec::MaxPool { kernel } => Json::obj(vec![("pool", Json::num(kernel as f64))]),
                LayerSpec::FullyConnected { neurons } => {
                    Json::obj(vec![("fc", Json::num(neurons as f64))])
                }
                LayerSpec::Output { classes } => {
                    Json::obj(vec![("output", Json::num(classes as f64))])
                }
            })
            .collect();
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("epochs", Json::num(self.paper_epochs as f64)),
            ("layers", Json::arr(layers)),
        ])
    }

    /// Structural validation: starts with input, ends with output, pooling
    /// divides evenly, convolutions fit.
    pub fn validate(&self) -> anyhow::Result<()> {
        if !matches!(self.layers.first(), Some(LayerSpec::Input { .. })) {
            anyhow::bail!("architecture must start with an input layer");
        }
        if !matches!(self.layers.last(), Some(LayerSpec::Output { .. })) {
            anyhow::bail!("architecture must end with an output layer");
        }
        let mut side = match self.layers[0] {
            LayerSpec::Input { side } => side,
            _ => unreachable!(),
        };
        let mut seen_fc = false;
        for (i, l) in self.layers.iter().enumerate().skip(1) {
            match *l {
                LayerSpec::Input { .. } => anyhow::bail!("layer {i}: input after start"),
                LayerSpec::Conv { maps, kernel } => {
                    if seen_fc {
                        anyhow::bail!("layer {i}: conv after fully-connected");
                    }
                    if kernel == 0 || maps == 0 || kernel > side {
                        anyhow::bail!(
                            "layer {i}: conv kernel {kernel} invalid for side {side}"
                        );
                    }
                    side = side - kernel + 1;
                }
                LayerSpec::MaxPool { kernel } => {
                    if seen_fc {
                        anyhow::bail!("layer {i}: pool after fully-connected");
                    }
                    if kernel == 0 || kernel > side {
                        anyhow::bail!("layer {i}: pool kernel {kernel} invalid for side {side}");
                    }
                    // Stride = kernel; require at least one full window and
                    // allow a truncated tail only when it is empty.
                    if side % kernel != 0 && side >= kernel {
                        // e.g. 6x6 pooled by 2 -> 3 is fine (6%2==0); what we
                        // reject is a remainder, like 9 pooled by 2.
                        anyhow::bail!(
                            "layer {i}: pool kernel {kernel} does not evenly divide side {side}"
                        );
                    }
                    side /= kernel;
                }
                LayerSpec::FullyConnected { neurons } => {
                    if neurons == 0 {
                        anyhow::bail!("layer {i}: fc with zero neurons");
                    }
                    seen_fc = true;
                }
                LayerSpec::Output { classes } => {
                    if classes == 0 {
                        anyhow::bail!("layer {i}: output with zero classes");
                    }
                    if i != self.layers.len() - 1 {
                        anyhow::bail!("layer {i}: output before the end");
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_archs_validate() {
        for name in PAPER_ARCHS {
            ArchSpec::by_name(name).unwrap().validate().unwrap();
        }
        ArchSpec::by_name("tiny").unwrap().validate().unwrap();
        assert!(ArchSpec::by_name("giant").is_none());
    }

    #[test]
    fn json_roundtrip() {
        for name in PAPER_ARCHS {
            let a = ArchSpec::by_name(name).unwrap();
            let j = a.to_json();
            let b = ArchSpec::from_json(&j).unwrap();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn validate_rejects_bad_stacks() {
        let no_input = ArchSpec {
            name: "x".into(),
            layers: vec![LayerSpec::Output { classes: 10 }],
            paper_epochs: 1,
        };
        assert!(no_input.validate().is_err());

        let pool_too_big = ArchSpec {
            name: "x".into(),
            layers: vec![
                LayerSpec::Input { side: 5 },
                LayerSpec::MaxPool { kernel: 7 },
                LayerSpec::Output { classes: 10 },
            ],
            paper_epochs: 1,
        };
        assert!(pool_too_big.validate().is_err());

        let uneven_pool = ArchSpec {
            name: "x".into(),
            layers: vec![
                LayerSpec::Input { side: 9 },
                LayerSpec::MaxPool { kernel: 2 },
                LayerSpec::Output { classes: 10 },
            ],
            paper_epochs: 1,
        };
        assert!(uneven_pool.validate().is_err());

        let conv_after_fc = ArchSpec {
            name: "x".into(),
            layers: vec![
                LayerSpec::Input { side: 9 },
                LayerSpec::FullyConnected { neurons: 5 },
                LayerSpec::Conv { maps: 2, kernel: 2 },
                LayerSpec::Output { classes: 10 },
            ],
            paper_epochs: 1,
        };
        assert!(conv_after_fc.validate().is_err());
    }

    #[test]
    fn paper_epochs_match() {
        assert_eq!(ArchSpec::small().paper_epochs, 70);
        assert_eq!(ArchSpec::medium().paper_epochs, 70);
        assert_eq!(ArchSpec::large().paper_epochs, 15);
    }
}
