//! Training hyper-parameters — paper §5.1: "trained … using a starting
//! decay (eta) of 0.001 and factor of 0.9", per-sample (on-line) SGD.

use crate::nn::MathPolicy;
use crate::util::Json;

/// Hyper-parameters for a training run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Number of epochs.
    pub epochs: usize,
    /// Starting learning rate η₀ (the paper calls it "decay (eta)").
    pub eta0: f64,
    /// Multiplicative per-epoch decay factor.
    pub eta_decay: f64,
    /// Worker/thread count (network instances). 1 = sequential.
    pub threads: usize,
    /// PRNG seed for weight init and the image shuffle.
    pub seed: u64,
    /// Fraction of the training set also used for validation. The paper
    /// validates on the full training set (Table 7's validation column has
    /// 60,000 images); 1.0 reproduces that.
    pub validation_fraction: f64,
    /// Batch size of the evaluation phases (validation/test forward
    /// passes) — how many images each worker pushes through a
    /// [`crate::nn::BatchPlan`] at a time, amortizing the per-layer
    /// parameter load. Must be ≥ 1; purely a throughput knob, results are
    /// bit-identical across values.
    pub eval_batch: usize,
    /// Accumulation policy for the minibatch training kernels (see the
    /// `nn::simd` reassociation contract). `Exact` (the default) keeps
    /// batched training bit-identical to per-sample execution; `Fast`
    /// allows reassociated, cache-blocked kernels. Evaluation phases
    /// always run exact.
    pub math: MathPolicy,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            epochs: 70,
            eta0: 0.001,
            eta_decay: 0.9,
            threads: 1,
            seed: 0xC4A0_5EED,
            validation_fraction: 1.0,
            eval_batch: 32,
            math: MathPolicy::Exact,
        }
    }
}

impl TrainConfig {
    /// The defaults, as a fluent starting point:
    /// `TrainConfig::new().with_epochs(5).with_threads(4)`.
    pub fn new() -> TrainConfig {
        TrainConfig::default()
    }

    pub fn with_epochs(mut self, epochs: usize) -> TrainConfig {
        self.epochs = epochs;
        self
    }

    pub fn with_threads(mut self, threads: usize) -> TrainConfig {
        self.threads = threads;
        self
    }

    /// Learning-rate schedule: η₀ and the per-epoch decay factor.
    pub fn with_eta(mut self, eta0: f64, eta_decay: f64) -> TrainConfig {
        self.eta0 = eta0;
        self.eta_decay = eta_decay;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> TrainConfig {
        self.seed = seed;
        self
    }

    pub fn with_validation_fraction(mut self, fraction: f64) -> TrainConfig {
        self.validation_fraction = fraction;
        self
    }

    pub fn with_eval_batch(mut self, eval_batch: usize) -> TrainConfig {
        self.eval_batch = eval_batch;
        self
    }

    pub fn with_math(mut self, math: MathPolicy) -> TrainConfig {
        self.math = math;
        self
    }

    /// η at the given 0-based epoch: η₀ · decay^epoch.
    pub fn eta_at(&self, epoch: usize) -> f32 {
        (self.eta0 * self.eta_decay.powi(epoch as i32)) as f32
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        if self.epochs == 0 {
            anyhow::bail!("epochs must be > 0");
        }
        if self.threads == 0 {
            anyhow::bail!("threads must be > 0");
        }
        if !(self.eta0 > 0.0) {
            anyhow::bail!("eta0 must be positive");
        }
        if !(0.0 < self.eta_decay && self.eta_decay <= 1.0) {
            anyhow::bail!("eta_decay must be in (0, 1]");
        }
        if !(0.0..=1.0).contains(&self.validation_fraction) {
            anyhow::bail!("validation_fraction must be in [0, 1]");
        }
        if self.eval_batch == 0 {
            anyhow::bail!("eval_batch must be > 0");
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("epochs", Json::num(self.epochs as f64)),
            ("eta0", Json::num(self.eta0)),
            ("eta_decay", Json::num(self.eta_decay)),
            ("threads", Json::num(self.threads as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("validation_fraction", Json::num(self.validation_fraction)),
            ("eval_batch", Json::num(self.eval_batch as f64)),
            ("math", Json::str(self.math.name())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eta_decays() {
        let c = TrainConfig::default();
        assert!((c.eta_at(0) - 0.001).abs() < 1e-9);
        assert!((c.eta_at(1) - 0.0009).abs() < 1e-9);
        assert!(c.eta_at(10) < c.eta_at(9));
    }

    #[test]
    fn validation() {
        assert!(TrainConfig::default().validate().is_ok());
        assert!(TrainConfig { epochs: 0, ..Default::default() }.validate().is_err());
        assert!(TrainConfig { threads: 0, ..Default::default() }.validate().is_err());
        assert!(TrainConfig { eta0: -1.0, ..Default::default() }.validate().is_err());
        assert!(TrainConfig { eta_decay: 1.5, ..Default::default() }.validate().is_err());
        assert!(TrainConfig { eval_batch: 0, ..Default::default() }.validate().is_err());
    }

    #[test]
    fn fluent_setters_compose() {
        let c = TrainConfig::new()
            .with_epochs(5)
            .with_threads(4)
            .with_eta(0.01, 0.8)
            .with_seed(7)
            .with_validation_fraction(0.25)
            .with_eval_batch(16)
            .with_math(MathPolicy::Fast);
        assert_eq!(c.epochs, 5);
        assert_eq!(c.threads, 4);
        assert_eq!(c.eta0, 0.01);
        assert_eq!(c.eta_decay, 0.8);
        assert_eq!(c.seed, 7);
        assert_eq!(c.validation_fraction, 0.25);
        assert_eq!(c.eval_batch, 16);
        assert_eq!(c.math, MathPolicy::Fast);
        c.validate().unwrap();
    }

    #[test]
    fn json_has_all_fields() {
        let j = TrainConfig::default().to_json();
        for k in [
            "epochs",
            "eta0",
            "eta_decay",
            "threads",
            "seed",
            "validation_fraction",
            "eval_batch",
            "math",
        ] {
            assert!(j.get(k).is_some(), "missing {k}");
        }
    }
}
