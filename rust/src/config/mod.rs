//! Configuration: the paper's CNN architectures (Table 2) and training
//! hyper-parameters (§5.1), plus parsing of user-supplied architecture
//! files so downstream users are not locked to the three paper networks.

mod arch;
mod training;

pub use arch::{Act, ArchSpec, LayerSpec, PAPER_ARCHS};
pub use training::TrainConfig;
