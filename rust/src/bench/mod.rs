//! In-crate micro-benchmark harness (criterion is not in the vendored
//! registry). Provides warmup, repeated timed iterations, mean/σ/min
//! statistics and markdown reporting — enough to drive every `cargo bench`
//! target reproducibly.

use crate::util::stats::Welford;
use crate::util::Stopwatch;

/// One benchmark definition.
pub struct Bench {
    name: String,
    warmup_iters: usize,
    measure_iters: usize,
}

/// Measured result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_secs: f64,
    pub stddev_secs: f64,
    pub min_secs: f64,
    pub max_secs: f64,
}

impl BenchResult {
    /// Iterations per second at the mean.
    pub fn throughput(&self) -> f64 {
        if self.mean_secs > 0.0 {
            1.0 / self.mean_secs
        } else {
            f64::INFINITY
        }
    }

    pub fn row(&self) -> String {
        format!(
            "| {} | {} | {} | ±{} | {} |",
            self.name,
            self.iters,
            crate::util::timer::fmt_secs(self.mean_secs),
            crate::util::timer::fmt_secs(self.stddev_secs),
            crate::util::timer::fmt_secs(self.min_secs),
        )
    }
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Bench {
        Bench { name: name.into(), warmup_iters: 3, measure_iters: 10 }
    }

    pub fn warmup(mut self, n: usize) -> Bench {
        self.warmup_iters = n;
        self
    }

    pub fn iters(mut self, n: usize) -> Bench {
        self.measure_iters = n.max(1);
        self
    }

    /// Run the closure `warmup + iters` times, timing the measured ones.
    /// The closure's return value is black-boxed to keep the optimizer
    /// honest.
    pub fn run<T>(self, mut f: impl FnMut() -> T) -> BenchResult {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut w = Welford::new();
        for _ in 0..self.measure_iters {
            let sw = Stopwatch::start();
            std::hint::black_box(f());
            w.add(sw.elapsed_secs());
        }
        BenchResult {
            name: self.name,
            iters: self.measure_iters,
            mean_secs: w.mean(),
            stddev_secs: w.stddev(),
            min_secs: w.min(),
            max_secs: w.max(),
        }
    }
}

/// Collects results and prints a markdown report; used by the bench
/// binaries so `cargo bench` output is paste-ready for EXPERIMENTS.md.
#[derive(Debug, Default)]
pub struct Report {
    title: String,
    results: Vec<BenchResult>,
    notes: Vec<String>,
}

impl Report {
    pub fn new(title: impl Into<String>) -> Report {
        Report { title: title.into(), results: Vec::new(), notes: Vec::new() }
    }

    pub fn add(&mut self, r: BenchResult) {
        println!("  {} -> mean {}", r.name, crate::util::timer::fmt_secs(r.mean_secs));
        self.results.push(r);
    }

    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    pub fn to_markdown(&self) -> String {
        let mut out = format!("## {}\n\n", self.title);
        out.push_str("| bench | iters | mean | σ | min |\n|---|---|---|---|---|\n");
        for r in &self.results {
            out.push_str(&r.row());
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(&format!("\n> {n}\n"));
        }
        out
    }

    pub fn print(&self) {
        println!("\n{}", self.to_markdown());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_work() {
        let r = Bench::new("sleep").warmup(1).iters(5).run(|| {
            std::thread::sleep(std::time::Duration::from_millis(2));
            42
        });
        assert_eq!(r.iters, 5);
        assert!(r.mean_secs >= 0.002, "mean {}", r.mean_secs);
        assert!(r.min_secs <= r.mean_secs && r.mean_secs <= r.max_secs + 1e-12);
        assert!(r.throughput() < 600.0);
    }

    #[test]
    fn report_markdown_contains_rows() {
        let mut rep = Report::new("test suite");
        rep.add(Bench::new("noop").warmup(0).iters(3).run(|| 1 + 1));
        rep.note("a note");
        let md = rep.to_markdown();
        assert!(md.contains("## test suite"));
        assert!(md.contains("| noop | 3 |"));
        assert!(md.contains("> a note"));
    }
}
