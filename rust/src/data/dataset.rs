//! In-memory image dataset with the flat layout the training hot path wants.
//!
//! Images are stored contiguously (one row of `pixels_per_image` floats per
//! image, values normalized to [-1, 1] as in the Cireşan reference
//! implementation) so a worker picking image `i` touches exactly one
//! cache-friendly span — §4.2(1): "images are loaded into a pre-allocated
//! memory instead of allocating new memory when requesting an image".
//!
//! The paper's geometry is 29×29 ([`super::IMAGE_PIXELS`]); the struct
//! itself is geometry-agnostic so tests and custom architectures can use
//! other sizes.

use super::NUM_CLASSES;

/// Which split a dataset represents (drives reporter labels).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Validation,
    Test,
}

impl Split {
    pub fn name(self) -> &'static str {
        match self {
            Split::Train => "train",
            Split::Validation => "validation",
            Split::Test => "test",
        }
    }
}

/// A labelled image dataset in pre-allocated flat storage.
#[derive(Debug, Clone)]
pub struct Dataset {
    pixels: Vec<f32>,
    labels: Vec<u8>,
    pixels_per_image: usize,
    n: usize,
}

impl Dataset {
    /// Build from flat pixels (`labels.len() * pixels_per_image` values).
    pub fn new(pixels: Vec<f32>, labels: Vec<u8>, pixels_per_image: usize) -> Dataset {
        assert!(pixels_per_image > 0);
        assert_eq!(
            pixels.len(),
            labels.len() * pixels_per_image,
            "pixel/label count mismatch"
        );
        assert!(labels.iter().all(|&l| (l as usize) < NUM_CLASSES), "label out of range");
        let n = labels.len();
        Dataset { pixels, labels, pixels_per_image, n }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Pixels per image.
    pub fn image_len(&self) -> usize {
        self.pixels_per_image
    }

    /// The `i`-th image as a flat slice.
    #[inline]
    pub fn image(&self, i: usize) -> &[f32] {
        &self.pixels[i * self.pixels_per_image..(i + 1) * self.pixels_per_image]
    }

    #[inline]
    pub fn label(&self, i: usize) -> usize {
        self.labels[i] as usize
    }

    /// First `n` images as a new dataset (cheap experiment scaling).
    pub fn take(&self, n: usize) -> Dataset {
        let n = n.min(self.n);
        Dataset {
            pixels: self.pixels[..n * self.pixels_per_image].to_vec(),
            labels: self.labels[..n].to_vec(),
            pixels_per_image: self.pixels_per_image,
            n,
        }
    }

    /// Center-crop every image to a `side`×`side` square (both source and
    /// target sides must be square). Used by tests that pair small
    /// architectures with the 29×29 generator output.
    pub fn center_crop(&self, side: usize) -> Dataset {
        let src_side = (self.pixels_per_image as f64).sqrt() as usize;
        assert_eq!(src_side * src_side, self.pixels_per_image, "images not square");
        assert!(side <= src_side);
        let off = (src_side - side) / 2;
        let mut pixels = Vec::with_capacity(self.n * side * side);
        for i in 0..self.n {
            let img = self.image(i);
            for y in 0..side {
                let row = (y + off) * src_side + off;
                pixels.extend_from_slice(&img[row..row + side]);
            }
        }
        Dataset::new(pixels, self.labels.clone(), side * side)
    }

    /// Bilinear-resize every (square) image to `side`×`side`. Used by tests
    /// pairing small architectures with the 29×29 generator output — unlike
    /// a crop, the full glyph stays visible.
    pub fn resize(&self, side: usize) -> Dataset {
        let src_side = (self.pixels_per_image as f64).sqrt() as usize;
        assert_eq!(src_side * src_side, self.pixels_per_image, "images not square");
        assert!(side >= 2);
        let mut pixels = Vec::with_capacity(self.n * side * side);
        let scale = (src_side - 1) as f32 / (side - 1) as f32;
        for i in 0..self.n {
            let img = self.image(i);
            for y in 0..side {
                let fy = y as f32 * scale;
                let y0 = fy.floor() as usize;
                let y1 = (y0 + 1).min(src_side - 1);
                let wy = fy - y0 as f32;
                for x in 0..side {
                    let fx = x as f32 * scale;
                    let x0 = fx.floor() as usize;
                    let x1 = (x0 + 1).min(src_side - 1);
                    let wx = fx - x0 as f32;
                    let v = img[y0 * src_side + x0] * (1.0 - wy) * (1.0 - wx)
                        + img[y0 * src_side + x1] * (1.0 - wy) * wx
                        + img[y1 * src_side + x0] * wy * (1.0 - wx)
                        + img[y1 * src_side + x1] * wy * wx;
                    pixels.push(v);
                }
            }
        }
        Dataset::new(pixels, self.labels.clone(), side * side)
    }

    /// Per-class counts — sanity metric for the synthetic generator.
    pub fn class_histogram(&self) -> [usize; NUM_CLASSES] {
        let mut h = [0usize; NUM_CLASSES];
        for &l in &self.labels {
            h[l as usize] += 1;
        }
        h
    }

    /// Mean pixel value across the dataset (normalization check).
    pub fn pixel_mean(&self) -> f32 {
        if self.pixels.is_empty() {
            return 0.0;
        }
        self.pixels.iter().sum::<f32>() / self.pixels.len() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::IMAGE_PIXELS;

    fn tiny(n: usize) -> Dataset {
        let pixels = vec![0.5; n * IMAGE_PIXELS];
        let labels: Vec<u8> = (0..n).map(|i| (i % NUM_CLASSES) as u8).collect();
        Dataset::new(pixels, labels, IMAGE_PIXELS)
    }

    #[test]
    fn construction_and_access() {
        let d = tiny(20);
        assert_eq!(d.len(), 20);
        assert_eq!(d.image(3).len(), IMAGE_PIXELS);
        assert_eq!(d.label(13), 3);
    }

    #[test]
    fn image_slices_are_disjoint_spans() {
        let mut pixels = vec![0.0; 2 * IMAGE_PIXELS];
        pixels[IMAGE_PIXELS] = 9.0; // first pixel of image 1
        let d = Dataset::new(pixels, vec![0, 1], IMAGE_PIXELS);
        assert_eq!(d.image(0)[0], 0.0);
        assert_eq!(d.image(1)[0], 9.0);
    }

    #[test]
    fn take_truncates() {
        let d = tiny(30).take(7);
        assert_eq!(d.len(), 7);
        assert_eq!(d.class_histogram()[0], 1);
        // take more than available is a no-op
        assert_eq!(tiny(5).take(50).len(), 5);
    }

    #[test]
    fn center_crop_geometry() {
        // 4x4 image with a distinctive center.
        let mut pixels = vec![0.0; 16];
        pixels[5] = 1.0; // (1,1)
        let d = Dataset::new(pixels, vec![2], 16);
        let c = d.center_crop(2);
        assert_eq!(c.image_len(), 4);
        // offset = (4-2)/2 = 1, so crop covers rows/cols 1..3
        assert_eq!(c.image(0), &[1.0, 0.0, 0.0, 0.0]);
        assert_eq!(c.label(0), 2);
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn mismatched_sizes_panic() {
        Dataset::new(vec![0.0; 10], vec![0, 1], IMAGE_PIXELS);
    }

    #[test]
    #[should_panic(expected = "label out of range")]
    fn bad_label_panics() {
        Dataset::new(vec![0.0; IMAGE_PIXELS], vec![10], IMAGE_PIXELS);
    }

    #[test]
    fn histogram_counts() {
        let d = tiny(25);
        let h = d.class_histogram();
        assert_eq!(h.iter().sum::<usize>(), 25);
        assert_eq!(h[0], 3); // 0, 10, 20
        assert_eq!(h[5], 2); // 5, 15
    }
}
