//! Dataset substrate: the MNIST IDX loader (used when the real MNIST files
//! are present) and a deterministic procedural substitute, `synth-mnist`,
//! for offline environments (DESIGN.md §2).
//!
//! The paper trains on MNIST: 70,000 images of handwritten digits, 29×29
//! after padding (the Cireşan reference implementation pads 28×28 MNIST by
//! one row/column), 60,000 for training/validation and 10,000 for testing.

mod augment;
mod dataset;
mod mnist;
mod synthetic;

pub use augment::{distort_dataset, distort_into, AugmentConfig};
pub use dataset::{Dataset, Split};
pub use mnist::{load_mnist, mnist_available, MnistError};
pub use synthetic::{generate_synthetic, SynthConfig};

/// Image side used throughout (29×29 as in the paper).
pub const IMAGE_SIDE: usize = 29;
/// Pixels per image.
pub const IMAGE_PIXELS: usize = IMAGE_SIDE * IMAGE_SIDE;
/// Number of classes (digits 0–9).
pub const NUM_CLASSES: usize = 10;

/// Load the training+test splits: real MNIST when the IDX files exist under
/// `dir`, otherwise the deterministic synthetic substitute scaled to
/// `train_n`/`test_n` images.
pub fn load_or_generate(
    dir: &str,
    train_n: usize,
    test_n: usize,
    seed: u64,
) -> (Dataset, Dataset) {
    if mnist_available(dir) {
        match load_mnist(dir, train_n, test_n) {
            Ok(pair) => return pair,
            Err(e) => eprintln!("warning: MNIST load failed ({e}); falling back to synthetic"),
        }
    }
    let cfg = SynthConfig::default();
    let train = generate_synthetic(train_n, seed, &cfg);
    let test = generate_synthetic(test_n, seed ^ 0x7E57_0000, &cfg);
    (train, test)
}
