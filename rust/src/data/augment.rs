//! Training-time image augmentation: random affine distortions (shift,
//! rotation, scale) applied per epoch.
//!
//! The Cireşan reference implementation the paper builds on owes much of
//! its MNIST accuracy to continuous input distortion; the paper folds this
//! into "preparation of images" (§5.3: "several other factors impact
//! training, including … preparation of images"). The augmenter is
//! deterministic in (seed, epoch, index), so sequential and parallel runs
//! see identical distorted streams — preserving the accuracy-parity
//! methodology.

use super::Dataset;
use crate::util::Pcg32;

/// Distortion ranges (milder than the generator's, since these stack on
/// top of whatever variance the data already has).
#[derive(Debug, Clone)]
pub struct AugmentConfig {
    pub max_rotation: f32,
    pub scale_jitter: f32,
    pub max_shift: f32,
}

impl Default for AugmentConfig {
    fn default() -> Self {
        AugmentConfig { max_rotation: 0.13, scale_jitter: 0.08, max_shift: 1.5 }
    }
}

/// Apply a random affine distortion of `img` (side×side, [-1,1] values)
/// into `out`, deterministic in `(seed, epoch, index)`.
pub fn distort_into(
    img: &[f32],
    side: usize,
    cfg: &AugmentConfig,
    seed: u64,
    epoch: usize,
    index: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(img.len(), side * side);
    debug_assert_eq!(out.len(), side * side);
    let mut rng = Pcg32::new(seed ^ (epoch as u64) << 32, index as u64);
    let theta = rng.uniform(-cfg.max_rotation, cfg.max_rotation);
    let s = 1.0 / rng.uniform(1.0 - cfg.scale_jitter, 1.0 + cfg.scale_jitter);
    let tx = rng.uniform(-cfg.max_shift, cfg.max_shift);
    let ty = rng.uniform(-cfg.max_shift, cfg.max_shift);
    let (sin, cos) = theta.sin_cos();
    let c = (side as f32 - 1.0) / 2.0;

    for y in 0..side {
        for x in 0..side {
            // inverse mapping: output pixel -> source coordinates
            let dx = x as f32 - c - tx;
            let dy = y as f32 - c - ty;
            let sx = (cos * dx + sin * dy) * s + c;
            let sy = (-sin * dx + cos * dy) * s + c;
            out[y * side + x] = bilinear(img, side, sx, sy);
        }
    }
}

/// Bilinear sample with -1 (background) outside the canvas.
fn bilinear(img: &[f32], side: usize, x: f32, y: f32) -> f32 {
    if x < 0.0 || y < 0.0 || x > (side - 1) as f32 || y > (side - 1) as f32 {
        return -1.0;
    }
    let x0 = x.floor() as usize;
    let y0 = y.floor() as usize;
    let x1 = (x0 + 1).min(side - 1);
    let y1 = (y0 + 1).min(side - 1);
    let wx = x - x0 as f32;
    let wy = y - y0 as f32;
    img[y0 * side + x0] * (1.0 - wy) * (1.0 - wx)
        + img[y0 * side + x1] * (1.0 - wy) * wx
        + img[y1 * side + x0] * wy * (1.0 - wx)
        + img[y1 * side + x1] * wy * wx
}

/// Produce a distorted copy of a whole dataset for one epoch (the paper's
/// sequential pipeline distorts up front; workers then pick from the
/// pre-allocated pool, keeping the hot path allocation-free).
pub fn distort_dataset(data: &Dataset, cfg: &AugmentConfig, seed: u64, epoch: usize) -> Dataset {
    let side = (data.image_len() as f64).sqrt() as usize;
    assert_eq!(side * side, data.image_len(), "images must be square");
    let mut pixels = vec![0.0f32; data.len() * data.image_len()];
    let mut labels = Vec::with_capacity(data.len());
    for i in 0..data.len() {
        let out = &mut pixels[i * data.image_len()..(i + 1) * data.image_len()];
        distort_into(data.image(i), side, cfg, seed, epoch, i, out);
        labels.push(data.label(i) as u8);
    }
    Dataset::new(pixels, labels, data.image_len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_synthetic, SynthConfig};

    #[test]
    fn deterministic_per_epoch_and_index() {
        let data = generate_synthetic(8, 3, &SynthConfig::default());
        let a = distort_dataset(&data, &AugmentConfig::default(), 7, 2);
        let b = distort_dataset(&data, &AugmentConfig::default(), 7, 2);
        assert_eq!(a.image(5), b.image(5));
        let c = distort_dataset(&data, &AugmentConfig::default(), 7, 3);
        assert_ne!(a.image(5), c.image(5), "different epoch must differ");
    }

    #[test]
    fn identity_when_ranges_zero() {
        let data = generate_synthetic(4, 1, &SynthConfig::default());
        let cfg = AugmentConfig { max_rotation: 0.0, scale_jitter: 0.0, max_shift: 0.0 };
        let d = distort_dataset(&data, &cfg, 1, 0);
        for i in 0..data.len() {
            for (a, b) in d.image(i).iter().zip(data.image(i)) {
                assert!((a - b).abs() < 1e-5, "zero-distortion must be identity");
            }
        }
    }

    #[test]
    fn values_stay_in_range_and_labels_preserved() {
        let data = generate_synthetic(16, 9, &SynthConfig::default());
        let d = distort_dataset(&data, &AugmentConfig::default(), 11, 1);
        assert_eq!(d.len(), data.len());
        for i in 0..d.len() {
            assert_eq!(d.label(i), data.label(i));
            for &p in d.image(i) {
                assert!((-1.001..=1.001).contains(&p), "pixel {p} out of range");
            }
        }
    }

    #[test]
    fn distortion_preserves_enough_signal() {
        // A distorted image must stay closer to its source than to a
        // different digit's image (mild ranges keep the class readable).
        let clean = SynthConfig { noise: 0.0, ..SynthConfig::default() };
        let data = generate_synthetic(40, 5, &clean);
        let d = distort_dataset(&data, &AugmentConfig::default(), 3, 0);
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
        };
        let mut wins = 0;
        let n = data.len();
        for i in 0..n {
            let to_self = dist(d.image(i), data.image(i));
            let j = (i + 1) % n;
            let to_other = dist(d.image(i), data.image(j));
            if to_self < to_other || data.label(i) == data.label(j) {
                wins += 1;
            }
        }
        assert!(wins * 10 >= n * 8, "only {wins}/{n} distorted images nearest their source");
    }
}
