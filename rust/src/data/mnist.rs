//! MNIST IDX file loader.
//!
//! Reads the classic LeCun IDX format (`train-images-idx3-ubyte`,
//! `train-labels-idx1-ubyte`, `t10k-…`), optionally gzip-compressed.
//! 28×28 images are zero-padded to 29×29 — the input geometry the paper
//! inherits from the Cireşan reference code (Table 2: input 29×29) — and
//! pixel values are normalized from [0, 255] to [-1, 1].

use super::{Dataset, IMAGE_PIXELS, IMAGE_SIDE};
use std::io::Read;
use std::path::{Path, PathBuf};

#[derive(Debug)]
pub enum MnistError {
    Io { path: String, source: std::io::Error },
    BadMagic { path: String, found: u32, expected: u32 },
    BadSize { path: String, rows: u32, cols: u32 },
    Truncated { path: String },
    CountMismatch { images: usize, labels: usize },
    Missing(String),
}

impl std::fmt::Display for MnistError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MnistError::Io { path, source } => write!(f, "io error reading {path}: {source}"),
            MnistError::BadMagic { path, found, expected } => {
                write!(f, "{path}: bad magic {found:#x}, expected {expected:#x}")
            }
            MnistError::BadSize { path, rows, cols } => {
                write!(f, "{path}: unsupported image size {rows}x{cols} (expected 28x28)")
            }
            MnistError::Truncated { path } => write!(f, "{path}: truncated file"),
            MnistError::CountMismatch { images, labels } => {
                write!(f, "image/label count mismatch: {images} images vs {labels} labels")
            }
            MnistError::Missing(path) => write!(f, "missing file: {path} (nor {path}.gz)"),
        }
    }
}

impl std::error::Error for MnistError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            MnistError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

const IMAGE_MAGIC: u32 = 0x0000_0803;
const LABEL_MAGIC: u32 = 0x0000_0801;
const MNIST_SIDE: usize = 28;

/// True when all four IDX files (possibly .gz) exist under `dir`.
pub fn mnist_available(dir: &str) -> bool {
    ["train-images-idx3-ubyte", "train-labels-idx1-ubyte", "t10k-images-idx3-ubyte", "t10k-labels-idx1-ubyte"]
        .iter()
        .all(|f| resolve(dir, f).is_some())
}

fn resolve(dir: &str, name: &str) -> Option<PathBuf> {
    let plain = Path::new(dir).join(name);
    if plain.exists() {
        return Some(plain);
    }
    let gz = Path::new(dir).join(format!("{name}.gz"));
    if gz.exists() {
        return Some(gz);
    }
    None
}

fn read_file(dir: &str, name: &str) -> Result<Vec<u8>, MnistError> {
    let path = resolve(dir, name).ok_or_else(|| MnistError::Missing(format!("{dir}/{name}")))?;
    let display = path.display().to_string();
    let raw = std::fs::read(&path).map_err(|source| MnistError::Io { path: display.clone(), source })?;
    if path.extension().is_some_and(|e| e == "gz") {
        let mut out = Vec::new();
        flate2::read::GzDecoder::new(&raw[..])
            .read_to_end(&mut out)
            .map_err(|source| MnistError::Io { path: display, source })?;
        Ok(out)
    } else {
        Ok(raw)
    }
}

fn be_u32(bytes: &[u8], off: usize, path: &str) -> Result<u32, MnistError> {
    bytes
        .get(off..off + 4)
        .map(|b| u32::from_be_bytes([b[0], b[1], b[2], b[3]]))
        .ok_or_else(|| MnistError::Truncated { path: path.to_string() })
}

/// Parse an IDX3 image file into padded, normalized flat pixels.
fn parse_images(bytes: &[u8], path: &str, limit: usize) -> Result<Vec<f32>, MnistError> {
    let magic = be_u32(bytes, 0, path)?;
    if magic != IMAGE_MAGIC {
        return Err(MnistError::BadMagic { path: path.into(), found: magic, expected: IMAGE_MAGIC });
    }
    let count = be_u32(bytes, 4, path)? as usize;
    let rows = be_u32(bytes, 8, path)?;
    let cols = be_u32(bytes, 12, path)?;
    if rows as usize != MNIST_SIDE || cols as usize != MNIST_SIDE {
        return Err(MnistError::BadSize { path: path.into(), rows, cols });
    }
    let n = count.min(limit);
    let need = 16 + count * MNIST_SIDE * MNIST_SIDE;
    if bytes.len() < need {
        return Err(MnistError::Truncated { path: path.into() });
    }
    let mut pixels = vec![-1.0f32; n * IMAGE_PIXELS];
    for i in 0..n {
        let src = &bytes[16 + i * MNIST_SIDE * MNIST_SIDE..];
        let dst = &mut pixels[i * IMAGE_PIXELS..(i + 1) * IMAGE_PIXELS];
        // Pad by one row on top and one column on the left (28 -> 29);
        // normalize 0..255 -> -1..1.
        for r in 0..MNIST_SIDE {
            for c in 0..MNIST_SIDE {
                let v = src[r * MNIST_SIDE + c] as f32;
                dst[(r + 1) * IMAGE_SIDE + (c + 1)] = v / 127.5 - 1.0;
            }
        }
    }
    Ok(pixels)
}

/// Parse an IDX1 label file.
fn parse_labels(bytes: &[u8], path: &str, limit: usize) -> Result<Vec<u8>, MnistError> {
    let magic = be_u32(bytes, 0, path)?;
    if magic != LABEL_MAGIC {
        return Err(MnistError::BadMagic { path: path.into(), found: magic, expected: LABEL_MAGIC });
    }
    let count = be_u32(bytes, 4, path)? as usize;
    let n = count.min(limit);
    if bytes.len() < 8 + count {
        return Err(MnistError::Truncated { path: path.into() });
    }
    Ok(bytes[8..8 + n].to_vec())
}

/// Load (train, test) datasets from IDX files under `dir`, truncated to
/// `train_n` / `test_n` images.
pub fn load_mnist(dir: &str, train_n: usize, test_n: usize) -> Result<(Dataset, Dataset), MnistError> {
    let load_split = |img_name: &str, lbl_name: &str, limit: usize| -> Result<Dataset, MnistError> {
        let img_bytes = read_file(dir, img_name)?;
        let lbl_bytes = read_file(dir, lbl_name)?;
        let pixels = parse_images(&img_bytes, img_name, limit)?;
        let labels = parse_labels(&lbl_bytes, lbl_name, limit)?;
        if pixels.len() != labels.len() * IMAGE_PIXELS {
            return Err(MnistError::CountMismatch {
                images: pixels.len() / IMAGE_PIXELS,
                labels: labels.len(),
            });
        }
        Ok(Dataset::new(pixels, labels, IMAGE_PIXELS))
    };
    let train = load_split("train-images-idx3-ubyte", "train-labels-idx1-ubyte", train_n)?;
    let test = load_split("t10k-images-idx3-ubyte", "t10k-labels-idx1-ubyte", test_n)?;
    Ok((train, test))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a tiny in-memory IDX image file.
    fn fake_idx3(n: usize) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(&IMAGE_MAGIC.to_be_bytes());
        b.extend_from_slice(&(n as u32).to_be_bytes());
        b.extend_from_slice(&28u32.to_be_bytes());
        b.extend_from_slice(&28u32.to_be_bytes());
        for i in 0..n {
            // image i: all pixels = i*20 (so images are distinguishable)
            b.extend(std::iter::repeat((i * 20) as u8).take(784));
        }
        b
    }

    fn fake_idx1(labels: &[u8]) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(&LABEL_MAGIC.to_be_bytes());
        b.extend_from_slice(&(labels.len() as u32).to_be_bytes());
        b.extend_from_slice(labels);
        b
    }

    #[test]
    fn parse_images_pads_and_normalizes() {
        let bytes = fake_idx3(2);
        let px = parse_images(&bytes, "t", 2).unwrap();
        assert_eq!(px.len(), 2 * IMAGE_PIXELS);
        // Padding row/column stays at -1.
        assert_eq!(px[0], -1.0); // top-left of image 0
        // Interior pixel of image 1: value 20 -> 20/127.5-1
        let inner = IMAGE_PIXELS + IMAGE_SIDE + 1;
        assert!((px[inner] - (20.0 / 127.5 - 1.0)).abs() < 1e-6);
    }

    #[test]
    fn parse_images_respects_limit() {
        let bytes = fake_idx3(5);
        let px = parse_images(&bytes, "t", 2).unwrap();
        assert_eq!(px.len(), 2 * IMAGE_PIXELS);
    }

    #[test]
    fn parse_labels_roundtrip() {
        let bytes = fake_idx1(&[3, 1, 4, 1, 5]);
        assert_eq!(parse_labels(&bytes, "t", 10).unwrap(), vec![3, 1, 4, 1, 5]);
        assert_eq!(parse_labels(&bytes, "t", 3).unwrap(), vec![3, 1, 4]);
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = fake_idx3(1);
        bytes[3] = 0x42;
        assert!(matches!(
            parse_images(&bytes, "t", 1),
            Err(MnistError::BadMagic { .. })
        ));
    }

    #[test]
    fn truncated_rejected() {
        let bytes = fake_idx3(3);
        assert!(matches!(
            parse_images(&bytes[..100], "t", 3),
            Err(MnistError::Truncated { .. })
        ));
    }

    #[test]
    fn missing_dir_not_available() {
        assert!(!mnist_available("/nonexistent/mnist"));
    }

    #[test]
    fn gz_roundtrip() {
        use std::io::Write;
        let dir = std::env::temp_dir().join(format!("mnist_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let write_gz = |name: &str, data: &[u8]| {
            let f = std::fs::File::create(dir.join(format!("{name}.gz"))).unwrap();
            let mut enc = flate2::write::GzEncoder::new(f, flate2::Compression::fast());
            enc.write_all(data).unwrap();
            enc.finish().unwrap();
        };
        write_gz("train-images-idx3-ubyte", &fake_idx3(4));
        write_gz("train-labels-idx1-ubyte", &fake_idx1(&[0, 1, 2, 3]));
        write_gz("t10k-images-idx3-ubyte", &fake_idx3(2));
        write_gz("t10k-labels-idx1-ubyte", &fake_idx1(&[4, 5]));
        let dirs = dir.to_str().unwrap();
        assert!(mnist_available(dirs));
        let (train, test) = load_mnist(dirs, 100, 100).unwrap();
        assert_eq!(train.len(), 4);
        assert_eq!(test.len(), 2);
        assert_eq!(test.label(1), 5);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
