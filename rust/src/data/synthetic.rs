//! `synth-mnist`: a deterministic procedural stand-in for MNIST.
//!
//! The container is offline, so the real IDX files may be absent. This
//! generator renders digit glyphs (5×7 stroke bitmaps) through a random
//! affine transform — translation, rotation, anisotropic scale, shear —
//! with stroke-thickness variation and pixel noise, onto the same 29×29
//! canvas with the same [-1, 1] normalization. The result is a 10-class
//! image problem with substantial intra-class variance: sequential SGD on
//! the small architecture reaches a low single-digit error rate in a few
//! epochs, which is what the accuracy-parity experiments (paper Table 7,
//! Fig 10) need from the data. See DESIGN.md §2 for the substitution
//! rationale.
//!
//! Every image is generated from `Pcg32::new(seed, index)`, so datasets are
//! reproducible element-wise regardless of generation order or thread count.

use super::{Dataset, IMAGE_PIXELS, IMAGE_SIDE, NUM_CLASSES};
use crate::util::Pcg32;

/// 5×7 digit glyphs; row-major, one bit per pixel (LSB = leftmost column).
const GLYPHS: [[u8; 7]; 10] = [
    // 0
    [0b01110, 0b10001, 0b10011, 0b10101, 0b11001, 0b10001, 0b01110],
    // 1
    [0b00100, 0b01100, 0b00100, 0b00100, 0b00100, 0b00100, 0b01110],
    // 2
    [0b01110, 0b10001, 0b00001, 0b00010, 0b00100, 0b01000, 0b11111],
    // 3
    [0b11111, 0b00010, 0b00100, 0b00010, 0b00001, 0b10001, 0b01110],
    // 4
    [0b00010, 0b00110, 0b01010, 0b10010, 0b11111, 0b00010, 0b00010],
    // 5
    [0b11111, 0b10000, 0b11110, 0b00001, 0b00001, 0b10001, 0b01110],
    // 6
    [0b00110, 0b01000, 0b10000, 0b11110, 0b10001, 0b10001, 0b01110],
    // 7
    [0b11111, 0b00001, 0b00010, 0b00100, 0b01000, 0b01000, 0b01000],
    // 8
    [0b01110, 0b10001, 0b10001, 0b01110, 0b10001, 0b10001, 0b01110],
    // 9
    [0b01110, 0b10001, 0b10001, 0b01111, 0b00001, 0b00010, 0b01100],
];

const GLYPH_W: f32 = 5.0;
const GLYPH_H: f32 = 7.0;

/// Distortion ranges for the generator.
#[derive(Debug, Clone)]
pub struct SynthConfig {
    /// Max |rotation| in radians.
    pub max_rotation: f32,
    /// Scale drawn from [1-s, 1+s] per axis.
    pub scale_jitter: f32,
    /// Max |shear|.
    pub max_shear: f32,
    /// Max |translation| in pixels.
    pub max_shift: f32,
    /// Stroke half-width in glyph units, drawn from [min, max].
    pub stroke_min: f32,
    pub stroke_max: f32,
    /// Additive pixel noise amplitude (in normalized units).
    pub noise: f32,
}

impl Default for SynthConfig {
    fn default() -> Self {
        SynthConfig {
            max_rotation: 0.26, // ~15 degrees
            scale_jitter: 0.18,
            max_shear: 0.15,
            max_shift: 2.5,
            stroke_min: 0.32,
            stroke_max: 0.55,
            noise: 0.08,
        }
    }
}

/// Bilinear-interpolated glyph intensity at continuous glyph coordinates,
/// with a soft stroke profile of half-width `stroke`.
fn glyph_intensity(digit: usize, gx: f32, gy: f32, stroke: f32) -> f32 {
    // Distance-based soft sampling: check the 3x3 neighbourhood of set
    // pixels and take the max of a triangular falloff.
    let mut best = 0.0f32;
    let x0 = (gx - 1.5).floor().max(0.0) as usize;
    let y0 = (gy - 1.5).floor().max(0.0) as usize;
    for py in y0..(y0 + 3).min(7) {
        let row = GLYPHS[digit][py];
        for px in x0..(x0 + 3).min(5) {
            if row >> (4 - px) & 1 == 1 {
                let dx = gx - px as f32;
                let dy = gy - py as f32;
                let d = (dx * dx + dy * dy).sqrt();
                let v = 1.0 - (d - stroke).max(0.0) / 0.75;
                if v > best {
                    best = v;
                }
            }
        }
    }
    best.clamp(0.0, 1.0)
}

/// Render one digit image into `out` (length 841), normalized to [-1, 1].
pub fn render_digit(digit: usize, rng: &mut Pcg32, cfg: &SynthConfig, out: &mut [f32]) {
    assert_eq!(out.len(), IMAGE_PIXELS);
    assert!(digit < NUM_CLASSES);

    let theta = rng.uniform(-cfg.max_rotation, cfg.max_rotation);
    let sx = rng.uniform(1.0 - cfg.scale_jitter, 1.0 + cfg.scale_jitter);
    let sy = rng.uniform(1.0 - cfg.scale_jitter, 1.0 + cfg.scale_jitter);
    let shear = rng.uniform(-cfg.max_shear, cfg.max_shear);
    let tx = rng.uniform(-cfg.max_shift, cfg.max_shift);
    let ty = rng.uniform(-cfg.max_shift, cfg.max_shift);
    let stroke = rng.uniform(cfg.stroke_min, cfg.stroke_max);
    let intensity = rng.uniform(0.8, 1.0);

    // Canvas-to-glyph inverse mapping. The glyph box (5x7) is scaled to
    // roughly 16x22 canvas pixels, centered.
    let base_sx = 16.0 / GLYPH_W * sx;
    let base_sy = 22.0 / GLYPH_H * sy;
    let (sin, cos) = theta.sin_cos();
    let cx = IMAGE_SIDE as f32 / 2.0 + tx;
    let cy = IMAGE_SIDE as f32 / 2.0 + ty;

    for y in 0..IMAGE_SIDE {
        for x in 0..IMAGE_SIDE {
            // canvas coords relative to center
            let dx = x as f32 - cx;
            let dy = y as f32 - cy;
            // inverse rotation
            let rx = cos * dx + sin * dy;
            let ry = -sin * dx + cos * dy;
            // inverse shear (x sheared by y)
            let ux = rx - shear * ry;
            let uy = ry;
            // inverse scale, then shift into glyph coordinates
            let gx = ux / base_sx + (GLYPH_W - 1.0) / 2.0;
            let gy = uy / base_sy + (GLYPH_H - 1.0) / 2.0;
            let mut v = if gx < -1.0 || gy < -1.0 || gx > GLYPH_W || gy > GLYPH_H {
                0.0
            } else {
                glyph_intensity(digit, gx, gy, stroke) * intensity
            };
            if cfg.noise > 0.0 {
                v += rng.uniform(-cfg.noise, cfg.noise);
            }
            out[y * IMAGE_SIDE + x] = (v.clamp(0.0, 1.0)) * 2.0 - 1.0;
        }
    }
}

/// Generate `n` images with balanced round-robin labels. Image `i` depends
/// only on `(seed, i)`.
pub fn generate_synthetic(n: usize, seed: u64, cfg: &SynthConfig) -> Dataset {
    let mut pixels = vec![0.0f32; n * IMAGE_PIXELS];
    let mut labels = vec![0u8; n];
    for i in 0..n {
        // Stream = image index: element-wise reproducibility.
        let mut rng = Pcg32::new(seed, i as u64);
        let digit = (rng.below(NUM_CLASSES as u32)) as usize;
        labels[i] = digit as u8;
        render_digit(digit, &mut rng, cfg, &mut pixels[i * IMAGE_PIXELS..(i + 1) * IMAGE_PIXELS]);
    }
    Dataset::new(pixels, labels, IMAGE_PIXELS)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = generate_synthetic(16, 7, &SynthConfig::default());
        let b = generate_synthetic(16, 7, &SynthConfig::default());
        assert_eq!(a.image(5), b.image(5));
        assert_eq!(a.label(5), b.label(5));
    }

    #[test]
    fn prefix_stable() {
        // Image i must not depend on n.
        let a = generate_synthetic(8, 3, &SynthConfig::default());
        let b = generate_synthetic(32, 3, &SynthConfig::default());
        for i in 0..8 {
            assert_eq!(a.image(i), b.image(i), "image {i} differs with n");
        }
    }

    #[test]
    fn values_in_range() {
        let d = generate_synthetic(64, 1, &SynthConfig::default());
        for i in 0..d.len() {
            for &p in d.image(i) {
                assert!((-1.0..=1.0).contains(&p));
            }
        }
    }

    #[test]
    fn classes_roughly_balanced() {
        let d = generate_synthetic(2000, 11, &SynthConfig::default());
        let h = d.class_histogram();
        for (c, &count) in h.iter().enumerate() {
            assert!(count > 120 && count < 280, "class {c}: {count}");
        }
    }

    #[test]
    fn digits_are_distinguishable() {
        // Nearest-centroid classification on clean renders must beat chance
        // by a wide margin — guards against glyphs collapsing.
        let clean = SynthConfig { noise: 0.0, ..SynthConfig::default() };
        let train = generate_synthetic(500, 21, &clean);
        let test = generate_synthetic(200, 99, &clean);
        let mut centroids = vec![vec![0.0f64; IMAGE_PIXELS]; NUM_CLASSES];
        let mut counts = [0usize; NUM_CLASSES];
        for i in 0..train.len() {
            let l = train.label(i);
            counts[l] += 1;
            for (c, &p) in centroids[l].iter_mut().zip(train.image(i)) {
                *c += p as f64;
            }
        }
        for (c, n) in centroids.iter_mut().zip(counts) {
            for v in c.iter_mut() {
                *v /= n.max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in 0..test.len() {
            let img = test.image(i);
            let mut best = 0;
            let mut bd = f64::INFINITY;
            for (c, cent) in centroids.iter().enumerate() {
                let d: f64 = img
                    .iter()
                    .zip(cent)
                    .map(|(&p, &q)| (p as f64 - q) * (p as f64 - q))
                    .sum();
                if d < bd {
                    bd = d;
                    best = c;
                }
            }
            if best == test.label(i) {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.len() as f64;
        assert!(acc > 0.5, "nearest-centroid accuracy only {acc}");
    }

    #[test]
    fn glyph_intensity_peaks_on_stroke() {
        // Center column of digit 1 is set on row 3.
        let on = glyph_intensity(1, 2.0, 3.0, 0.4);
        let off = glyph_intensity(1, 0.0, 3.0, 0.4);
        assert!(on > 0.9, "on-stroke {on}");
        assert!(off < on, "off-stroke {off} vs {on}");
    }
}
