//! `chaos` — the CHAOS coordinator CLI (leader entrypoint).
//!
//! Subcommands:
//!   train      run the CHAOS trainer (or any strategy baseline)
//!   table N    regenerate paper Table N (1–9)
//!   fig N      regenerate paper Figure N (5–13)
//!   report     regenerate every table and figure into one markdown file
//!   predict    analytic performance model (Listing 2)
//!   simulate   Xeon Phi discrete-event simulator
//!   serve      batched-inference serving demo (native engine or AOT artifacts)
//!   analyze    static analysis over compiled networks (spans, dataflow,
//!              kernel dispatch, cost model) + policy contracts
//!   info       architecture/manifest inventory

use chaos_phi::chaos::{self, policy};
use chaos_phi::config::{ArchSpec, TrainConfig};
use chaos_phi::data;
use chaos_phi::harness::{self, RealRunScale};
use chaos_phi::nn::Network;
use chaos_phi::perfmodel::{PerfModel, Scenario};
use chaos_phi::phisim::{simulate, SimConfig};
use chaos_phi::serve::{Engine, ServeError, Server, ServerConfig};
use chaos_phi::util::cli::Args;
use chaos_phi::util::Stopwatch;

const USAGE: &str = "\
chaos — CHAOS parallel CNN training (Viebke et al. 2017 reproduction)

USAGE: chaos <command> [flags]

  train     --arch small|medium|large|tiny --threads N
            --strategy chaos|sequential|hogwild|delayed-rr|averaged[:n]|minibatch[:B]|hogwild-batch[:B]
            --epochs E --train-n N --test-n N --eta F --seed S --data-dir DIR
            --out FILE.json --weights-out FILE.ckpt
            --eval-batch B   (evaluation batch size, default 32)
            --math exact|fast   (minibatch kernel accumulation, default exact;
             fast allows reassociated cache-blocked kernels, see README)
            --stop-at-test-error R   (early-stop once test error rate <= R)
            (--strategy also accepts any policy registered via chaos::policy;
             minibatch:B trains on B-sample chunks with averaged gradients)
  table N   [--quick|--full] [--threads 2,4,8] [--arch small]    (N in 1..9)
  fig N     [--quick|--full] [--threads 2,4,8] [--arch small]    (N in 5..13)
  report    --out FILE.md [--quick]
  predict   --arch A --threads 1,15,30,...  [--images N --test-n N --epochs E]
  simulate  --arch A --threads 1,15,30,...
  serve     --arch tiny --requests N --clients C --engine native|pjrt --batch B
            --workers W --queue-depth Q --delay-us D
            --deadline-us T   (per-request deadline; expired/overloaded
             requests are shed with typed errors instead of blocking)
            --artifacts DIR --weights FILE.ckpt   (pjrt needs `make artifacts`)
  analyze   [NAME|FILE.json ...] [--cost] [--shards N] [--weights a,b,..] [--json]
            (static analysis of each compiled network: span verification —
             in-bounds, disjoint, exact cover, op/dims agreement — plus the
             dataflow/aliasing audit over the shape chain and batch arenas;
             --cost adds the kernel-dispatch classifier and the static cost
             model's per-layer FLOPs/bytes/intensity roofline tables;
             --shards N plans a hybrid-parallel partition over N shards
             (fc spans split on output units, conv/pool replicated),
             verifies it, and prices per-shard load + boundary traffic;
             --weights gives heterogeneous shard capacity factors (implies
             --shards weights.len() when --shards is omitted);
             defaults to every built-in arch and also prints each policy's
             sync contract; exits nonzero if any defect is found)
  arch      validate FILE.json...   (parse + structurally validate + compile)
            show NAME [--out FILE.json]   (export a built-in arch as JSON)
            kinds   (list registered layer kinds)
  info      [--artifacts DIR]
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" || argv[0] == "help" {
        print!("{USAGE}");
        return;
    }
    let cmd = argv[0].clone();
    let rest = &argv[1..];
    let result = match cmd.as_str() {
        "train" => cmd_train(rest),
        "table" => cmd_table(rest),
        "fig" => cmd_fig(rest),
        "report" => cmd_report(rest),
        "predict" => cmd_predict(rest),
        "simulate" => cmd_simulate(rest),
        "serve" => cmd_serve(rest),
        "analyze" => cmd_analyze(rest),
        "arch" => cmd_arch(rest),
        "info" => cmd_info(rest),
        other => {
            eprintln!("unknown command '{other}'\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_train(raw: &[String]) -> anyhow::Result<()> {
    let a = Args::parse(
        raw,
        &[
            "arch",
            "threads",
            "strategy",
            "epochs",
            "train-n",
            "test-n",
            "eta",
            "seed",
            "data-dir",
            "out",
            "weights-out",
            "validation-fraction",
            "eval-batch",
            "math",
            "stop-at-test-error",
        ],
    )?;
    let arch_name = a.get_str("arch", "small");
    let arch = ArchSpec::by_name(&arch_name)
        .ok_or_else(|| anyhow::anyhow!("unknown arch '{arch_name}'"))?;
    let net = Network::new(arch.clone());
    let update_policy = policy::from_name(&a.get_str("strategy", "chaos"))?;
    let policy_name = update_policy.name();
    let cfg = TrainConfig {
        epochs: a.get_usize("epochs", arch.paper_epochs)?,
        threads: a.get_usize("threads", 4)?,
        eta0: a.get_f64("eta", 0.001)?,
        eta_decay: 0.9,
        seed: a.get_u64("seed", 0xC4A05)?,
        validation_fraction: a.get_f64("validation-fraction", 0.25)?,
        eval_batch: a.get_usize("eval-batch", 32)?,
        math: chaos_phi::nn::MathPolicy::parse(&a.get_str("math", "exact"))?,
    };
    cfg.validate()?;
    let train_n = a.get_usize("train-n", 2_000)?;
    let test_n = a.get_usize("test-n", 1_000)?;
    let data_dir = a.get_str("data-dir", "data/mnist");
    let (mut train_set, mut test_set) = data::load_or_generate(&data_dir, train_n, test_n, cfg.seed);
    // Match the network's input geometry (e.g. the 13x13 tiny arch).
    let side = arch.input_side();
    if train_set.image_len() != side * side {
        train_set = train_set.resize(side);
        test_set = test_set.resize(side);
    }
    println!(
        "training {arch_name} with {policy_name} ({} threads) on {} train / {} test images, {} epochs",
        cfg.threads,
        train_set.len(),
        test_set.len(),
        cfg.epochs
    );
    let mut trainer = chaos::Trainer::new()
        .network(net)
        .config(cfg.clone())
        .policy_boxed(update_policy);
    if a.get("stop-at-test-error").is_some() {
        let rate = a.get_f64("stop-at-test-error", 0.0)?;
        trainer = trainer.observer(chaos::EarlyStop::at_test_error(rate));
    }
    let sw = Stopwatch::start();
    let run = trainer.run(&train_set, &test_set)?;
    for e in &run.epochs {
        println!(
            "epoch {:>3}  eta {:.5}  train loss {:>10.2}  train err {:>6}  val err-rate {:>6.3}%  test err-rate {:>6.3}%  ({:.1}s)",
            e.epoch,
            e.eta,
            e.train.loss,
            e.train.errors,
            e.validation.error_rate() * 100.0,
            e.test.error_rate() * 100.0,
            e.total_secs,
        );
    }
    println!(
        "done in {:.1}s; publications={}  final test errors {}/{}{}",
        sw.elapsed_secs(),
        run.publications,
        run.final_epoch().test.errors,
        run.final_epoch().test.images,
        if run.stopped_early { "  (stopped early)" } else { "" }
    );
    if let Some(out) = a.get("out") {
        run.save(out)?;
        println!("wrote {out}");
    }
    if let Some(w) = a.get("weights-out") {
        chaos_phi::chaos::Checkpoint::new(arch_name.clone(), run.final_params.clone()).save(w)?;
        println!("wrote weights checkpoint {w}");
    }
    Ok(())
}

fn scale_from(a: &Args) -> RealRunScale {
    if a.has("full") {
        RealRunScale::full()
    } else {
        RealRunScale::quick()
    }
}

fn cmd_table(raw: &[String]) -> anyhow::Result<()> {
    anyhow::ensure!(!raw.is_empty(), "usage: chaos table <1..9> [flags]");
    let a = Args::parse(&raw[1..], &["quick!", "full!", "threads", "arch"])?;
    let n: usize = raw[0].parse().map_err(|_| anyhow::anyhow!("table number expected"))?;
    let threads = a.get_usize_list("threads", &[2, 4, 8])?;
    let arch = a.get_str("arch", "small");
    let table = match n {
        1 => harness::table1(scale_from(&a))?,
        2 => harness::table2(),
        3 => harness::table3(),
        4 => harness::table4(),
        5 => harness::table5()?,
        6 => harness::table6()?,
        7 => harness::table7(&arch, &threads, scale_from(&a))?,
        8 => harness::table8()?,
        9 => harness::table9()?,
        _ => anyhow::bail!("tables 1..9 exist"),
    };
    println!("{}", table.to_markdown());
    Ok(())
}

fn cmd_fig(raw: &[String]) -> anyhow::Result<()> {
    anyhow::ensure!(!raw.is_empty(), "usage: chaos fig <5..13> [flags]");
    let a = Args::parse(&raw[1..], &["quick!", "full!", "threads", "arch"])?;
    let n: usize = raw[0].parse().map_err(|_| anyhow::anyhow!("figure number expected"))?;
    let threads = a.get_usize_list("threads", &[2, 4, 8])?;
    let arch = a.get_str("arch", "small");
    let table = match n {
        5 => harness::fig5()?,
        6 => harness::fig6()?,
        7 | 8 | 9 => harness::fig_speedups(n as u8)?,
        10 => harness::fig10(&arch, &threads, scale_from(&a))?,
        11 => harness::fig_pred_vs_measured("small")?,
        12 => harness::fig_pred_vs_measured("medium")?,
        13 => harness::fig_pred_vs_measured("large")?,
        _ => anyhow::bail!("figures 5..13 exist"),
    };
    println!("{}", table.to_markdown());
    Ok(())
}

fn cmd_report(raw: &[String]) -> anyhow::Result<()> {
    let a = Args::parse(raw, &["out", "quick!", "full!", "threads"])?;
    let out = a.get_str("out", "report.md");
    let scale = scale_from(&a);
    let threads = a.get_usize_list("threads", &[2, 4, 8])?;
    let mut md = String::from("# CHAOS reproduction — regenerated tables & figures\n\n");
    let sw = Stopwatch::start();
    eprintln!("tables 2,3,4,8,9 (instant) …");
    md.push_str(&harness::table2().to_markdown());
    md.push_str(&harness::table3().to_markdown());
    md.push_str(&harness::table4().to_markdown());
    md.push_str(&harness::table8()?.to_markdown());
    md.push_str(&harness::table9()?.to_markdown());
    eprintln!("phisim tables/figures (5,6; figs 5-9, 11-13) …");
    md.push_str(&harness::table5()?.to_markdown());
    md.push_str(&harness::table6()?.to_markdown());
    md.push_str(&harness::fig5()?.to_markdown());
    md.push_str(&harness::fig6()?.to_markdown());
    for f in [7u8, 8, 9] {
        md.push_str(&harness::fig_speedups(f)?.to_markdown());
    }
    for arch in ["small", "medium", "large"] {
        md.push_str(&harness::fig_pred_vs_measured(arch)?.to_markdown());
    }
    eprintln!("real-training tables (1, 7, fig 10) — this trains networks …");
    md.push_str(&harness::table1(scale)?.to_markdown());
    md.push_str(&harness::table7("small", &threads, scale)?.to_markdown());
    md.push_str(&harness::fig10("small", &threads, scale)?.to_markdown());
    md.push_str(&format!("\n_Total regeneration time: {:.1}s_\n", sw.elapsed_secs()));
    std::fs::write(&out, &md)?;
    println!("wrote {out} ({} bytes)", md.len());
    Ok(())
}

fn cmd_predict(raw: &[String]) -> anyhow::Result<()> {
    let a = Args::parse(raw, &["arch", "threads", "images", "test-n", "epochs"])?;
    let arch = a.get_str("arch", "small");
    let model = PerfModel::for_arch(&arch)?;
    let threads = a.get_usize_list("threads", &[1, 15, 30, 60, 120, 180, 240, 244, 480, 960])?;
    println!("| threads | predicted | breakdown (seq/train/val/test/mem, s) |");
    println!("|---|---|---|");
    for p in threads {
        let mut sc = Scenario::paper_default(&arch, p);
        sc.images = a.get_usize("images", sc.images)?;
        sc.test_images = a.get_usize("test-n", sc.test_images)?;
        sc.epochs = a.get_usize("epochs", sc.epochs)?;
        let b = model.predict_breakdown(&sc);
        println!(
            "| {p} | {} | {:.0}/{:.0}/{:.0}/{:.0}/{:.0} |",
            chaos_phi::util::timer::fmt_secs(b.total()),
            b.sequential,
            b.training,
            b.validation,
            b.testing,
            b.memory
        );
    }
    Ok(())
}

fn cmd_simulate(raw: &[String]) -> anyhow::Result<()> {
    let a = Args::parse(raw, &["arch", "threads"])?;
    let arch = a.get_str("arch", "large");
    let threads = a.get_usize_list("threads", &[1, 15, 30, 60, 120, 180, 240, 244])?;
    println!("| threads | total | train/epoch | BPC% | FPC% |");
    println!("|---|---|---|---|---|");
    for p in threads {
        let r = simulate(&SimConfig::paper(&arch, p))?;
        let c = r.layer_class_secs();
        println!(
            "| {p} | {} | {} | {:.1}% | {:.1}% |",
            chaos_phi::util::timer::fmt_secs(r.total_secs()),
            chaos_phi::util::timer::fmt_secs(r.train_epoch_secs),
            100.0 * c.bpc / c.total(),
            100.0 * c.fpc / c.total(),
        );
    }
    Ok(())
}

fn cmd_serve(raw: &[String]) -> anyhow::Result<()> {
    let a = Args::parse(
        raw,
        &[
            "arch",
            "requests",
            "clients",
            "artifacts",
            "delay-us",
            "deadline-us",
            "weights",
            "engine",
            "batch",
            "workers",
            "queue-depth",
        ],
    )?;
    let arch = a.get_str("arch", "tiny");
    let requests = a.get_usize("requests", 256)?;
    let clients = a.get_usize("clients", 4)?;
    let artifacts = a.get_str("artifacts", chaos_phi::runtime::ARTIFACT_DIR);
    let delay_us = a.get_u64("delay-us", 2000)?;
    let engine_name = a.get_str("engine", "native");
    let batch = a.get_usize("batch", 8)?;
    let defaults = ServerConfig::default();
    let workers = a.get_usize("workers", 2)?;
    let queue_depth = a.get_usize("queue-depth", defaults.queue_depth)?;
    let deadline = match a.get("deadline-us") {
        Some(_) => Some(std::time::Duration::from_micros(a.get_u64("deadline-us", 0)?)),
        None => None,
    };

    let net = Network::from_name(&arch)?;
    let params = match a.get("weights") {
        Some(path) => chaos_phi::chaos::Checkpoint::load_for(path, &net)?,
        None => net.init_params(1),
    };
    let cfg = ServerConfig {
        max_delay: std::time::Duration::from_micros(delay_us),
        queue_depth,
        workers,
    };
    let engine = match engine_name.as_str() {
        "native" => Engine::Native { net: net.clone(), params, batch },
        "pjrt" => Engine::Pjrt { artifact_dir: artifacts, arch: arch.clone(), params },
        other => anyhow::bail!("unknown engine '{other}' (native|pjrt)"),
    };
    let server = Server::spawn(engine, cfg)?;
    let side = net.arch.input_side();
    let images = data::generate_synthetic(requests, 5, &data::SynthConfig::default()).resize(side);

    let sw = Stopwatch::start();
    std::thread::scope(|s| {
        for c in 0..clients {
            let handle = server.handle();
            let images = &images;
            s.spawn(move || {
                let mut i = c;
                while i < requests {
                    match deadline {
                        None => {
                            let probs = handle.predict(images.image(i)).expect("predict");
                            assert_eq!(probs.len(), 10);
                        }
                        // Deadline mode: shed expired/overloaded requests
                        // like a real client under SLO, count nothing here
                        // — the server's metrics do.
                        Some(budget) => match handle.predict_deadline(images.image(i), budget) {
                            Ok(probs) => assert_eq!(probs.len(), 10),
                            Err(ServeError::Expired | ServeError::Overloaded) => {}
                            Err(e) => panic!("predict: {e}"),
                        },
                    }
                    i += clients;
                }
            });
        }
    });
    let secs = sw.elapsed_secs();
    let m = server.handle().metrics.snapshot();
    println!(
        "served {} of {requests} requests from {clients} clients in {secs:.2}s ({:.0} req/s) on {} worker(s)",
        m.requests,
        m.requests as f64 / secs,
        m.workers
    );
    println!(
        "latency p50 {:.0}µs  p99 {:.0}µs  max {:.0}µs; {} batches, mean fill {:.2}",
        m.p50_us, m.p99_us, m.max_us, m.batches, m.mean_batch_fill
    );
    println!(
        "exec/batch p50 {:.0}µs  p99 {:.0}µs  mean {:.0}µs; expired {}  overloaded {}  exec failures {}",
        m.exec_p50_us, m.exec_p99_us, m.exec_mean_us, m.expired, m.overloaded, m.exec_failures
    );
    Ok(())
}

fn cmd_analyze(raw: &[String]) -> anyhow::Result<()> {
    use chaos_phi::chaos::analysis::{shard, verify_network};
    use chaos_phi::nn::audit;
    use chaos_phi::util::json::Json;

    // Positional targets (arch names or .json files) come first, flags after
    // — same convention as `table`/`fig`.
    let split = raw.iter().position(|s| s.starts_with("--")).unwrap_or(raw.len());
    let (targets, flags) = raw.split_at(split);
    let a = Args::parse(flags, &["json!", "cost!", "shards", "weights"])?;
    let weight_list = a.get_f64_list("weights", &[])?;
    let shards = a.get_usize("shards", weight_list.len())?;
    anyhow::ensure!(
        weight_list.is_empty() || weight_list.len() == shards,
        "--weights lists {} factor(s) but --shards asks for {shards}",
        weight_list.len()
    );
    let default_targets: Vec<String>;
    let targets: &[String] = if targets.is_empty() {
        default_targets = chaos_phi::config::PAPER_ARCHS
            .iter()
            .map(|s| s.to_string())
            .chain(std::iter::once("tiny".to_string()))
            .collect();
        &default_targets
    } else {
        targets
    };

    // Batch size the cost model amortizes parameter loads over (the
    // trainer's evaluation default).
    const COST_BATCH: usize = 32;
    let mut span_reports = Vec::new();
    let mut flow_reports = Vec::new();
    let mut cost_views = Vec::new();
    let mut shard_reports = Vec::new();
    for t in targets {
        let arch = if t.ends_with(".json") {
            ArchSpec::from_file(t).map_err(|e| anyhow::anyhow!("{t}: {e:#}"))?
        } else {
            ArchSpec::by_name(t).ok_or_else(|| {
                anyhow::anyhow!("unknown arch '{t}' (expected a built-in name or a .json file)")
            })?
        };
        // Note: debug builds also verify at compile and turn defects into a
        // compile error; release builds reach the verifiers below.
        let net = Network::compile(arch).map_err(|e| anyhow::anyhow!("{t}: compile: {e:#}"))?;
        span_reports.push(verify_network(&net));
        flow_reports.push(audit::audit_dataflow(&net));
        if a.has("cost") {
            cost_views.push((audit::audit_dispatch(&net), audit::audit_cost(&net, COST_BATCH)));
        }
        if shards > 0 {
            let plan = if weight_list.is_empty() {
                shard::plan_shards(&net, shards)
            } else {
                shard::plan_shards_weighted(&net, &weight_list)
                    .map_err(|e| anyhow::anyhow!("{t}: {e:#}"))?
            };
            shard_reports.push(shard::verify_shards(&net, &plan));
        }
    }
    let span_defects: usize = span_reports.iter().map(|r| r.defects.len()).sum();
    let flow_defects: usize = flow_reports.iter().map(|r| r.defects.len()).sum();
    let shard_defects: usize = shard_reports.iter().map(|r| r.defects.len()).sum();

    if a.has("json") {
        let mut items = Vec::new();
        for (i, (s, f)) in span_reports.iter().zip(&flow_reports).enumerate() {
            let mut fields = vec![("spans", s.to_json()), ("dataflow", f.to_json())];
            if let Some((k, c)) = cost_views.get(i) {
                fields.push(("kernels", k.to_json()));
                fields.push(("cost", c.to_json()));
            }
            if let Some(r) = shard_reports.get(i) {
                fields.push(("shard", r.to_json()));
            }
            items.push(Json::obj(fields));
        }
        println!("{}", Json::arr(items).pretty());
    } else {
        for (i, (s, f)) in span_reports.iter().zip(&flow_reports).enumerate() {
            println!("{}", s.to_text());
            println!("{}", f.to_text());
            if let Some((k, c)) = cost_views.get(i) {
                println!("{}", k.to_text());
                println!("{}", c.to_text());
            }
            if let Some(r) = shard_reports.get(i) {
                println!("{}", r.to_text());
            }
        }
        println!("\nupdate-policy sync contracts:");
        let mut names = policy::names();
        names.sort();
        for name in names {
            let p = policy::from_name(&name)?;
            println!("  {name:16} {}", p.sync_contract().as_str());
        }
    }
    anyhow::ensure!(span_defects == 0, "{span_defects} span defect(s) found");
    anyhow::ensure!(flow_defects == 0, "{flow_defects} dataflow defect(s) found");
    anyhow::ensure!(shard_defects == 0, "{shard_defects} shard defect(s) found");
    Ok(())
}

fn cmd_arch(raw: &[String]) -> anyhow::Result<()> {
    anyhow::ensure!(
        !raw.is_empty(),
        "usage: chaos arch validate FILE.json... | show NAME [--out FILE.json] | kinds"
    );
    match raw[0].as_str() {
        "validate" => {
            anyhow::ensure!(raw.len() > 1, "usage: chaos arch validate FILE.json...");
            for path in &raw[1..] {
                let arch = ArchSpec::from_file(path)
                    .map_err(|e| anyhow::anyhow!("{path}: {e:#}"))?;
                let net = Network::compile(arch)
                    .map_err(|e| anyhow::anyhow!("{path}: compile: {e:#}"))?;
                let kinds: Vec<&str> = net.ops.iter().map(|op| op.kind()).collect();
                println!(
                    "{path}: ok — '{}', {} layers ({}), {} parameters, input {}x{}",
                    net.arch.name,
                    net.dims.len(),
                    kinds.join(">"),
                    net.total_params,
                    net.arch.input_side(),
                    net.arch.input_side(),
                );
            }
            Ok(())
        }
        "show" => {
            anyhow::ensure!(raw.len() > 1, "usage: chaos arch show NAME [--out FILE.json]");
            let a = Args::parse(&raw[2..], &["out"])?;
            let name = &raw[1];
            let arch = ArchSpec::by_name(name)
                .ok_or_else(|| anyhow::anyhow!("unknown arch '{name}'"))?;
            let text = arch.to_json().pretty();
            match a.get("out") {
                Some(path) => {
                    std::fs::write(path, &text)?;
                    println!("wrote {path}");
                }
                None => println!("{text}"),
            }
            Ok(())
        }
        "kinds" => {
            println!("registered layer kinds: {}", chaos_phi::nn::layer::names().join(", "));
            Ok(())
        }
        other => anyhow::bail!("unknown arch subcommand '{other}' (validate|show|kinds)"),
    }
}

fn cmd_info(raw: &[String]) -> anyhow::Result<()> {
    let a = Args::parse(raw, &["artifacts"])?;
    println!("paper architectures:");
    for name in chaos_phi::config::PAPER_ARCHS {
        let net = Network::from_name(name)?;
        println!(
            "  {name:8} {} layers, {} parameters, {} paper epochs",
            net.dims.len(),
            net.total_params,
            net.arch.paper_epochs
        );
    }
    let dir = a.get_str("artifacts", chaos_phi::runtime::ARTIFACT_DIR);
    if chaos_phi::runtime::artifacts_available(&dir) {
        let manifest = chaos_phi::runtime::Manifest::load(&dir)?;
        println!("artifacts in {dir}:");
        for (name, am) in &manifest.archs {
            println!(
                "  {name:8} side {}, batch {}, artifacts: {}",
                am.input_side,
                am.batch,
                am.artifacts.keys().cloned().collect::<Vec<_>>().join(", ")
            );
        }
    } else {
        println!("artifacts not built (run `make artifacts`)");
    }
    Ok(())
}
