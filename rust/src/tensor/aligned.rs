//! 64-byte-aligned f32 buffer — the Rust analogue of the paper's
//! `_mm_malloc(size, 64)` allocations (§4.2: "Data was allocated using
//! `_mm_malloc()` with 64 byte alignment increasing the accuracy of memory
//! requests"). Alignment to the cache-line/vector-register width lets the
//! auto-vectorizer emit aligned loads for the conv inner loops.

use std::alloc::{alloc_zeroed, dealloc, handle_alloc_error, Layout};
use std::ops::{Deref, DerefMut};

pub const ALIGN: usize = 64;

/// A heap-allocated, zero-initialized `[f32]` with 64-byte alignment.
pub struct AlignedBuf {
    ptr: *mut f32,
    len: usize,
}

// SAFETY: AlignedBuf owns its allocation exclusively (the raw pointer is
// never shared out), all access goes through &self / &mut self borrows of
// the owner, and f32 is Send + Sync, so moving the buffer or sharing
// references across threads is sound.
unsafe impl Send for AlignedBuf {}
// SAFETY: as above — &AlignedBuf only permits reads of plain f32 data.
unsafe impl Sync for AlignedBuf {}

impl AlignedBuf {
    pub fn zeroed(len: usize) -> AlignedBuf {
        if len == 0 {
            return AlignedBuf { ptr: std::ptr::NonNull::<f32>::dangling().as_ptr(), len: 0 };
        }
        let layout = Self::layout(len);
        // SAFETY: len > 0 so the layout has non-zero size, satisfying
        // alloc_zeroed's only precondition. The all-zero bit pattern is a
        // valid f32 (0.0), so the buffer is initialized for type f32.
        let ptr = unsafe { alloc_zeroed(layout) } as *mut f32;
        if ptr.is_null() {
            handle_alloc_error(layout);
        }
        AlignedBuf { ptr, len }
    }

    pub fn from_slice(src: &[f32]) -> AlignedBuf {
        let mut buf = Self::zeroed(src.len());
        buf.copy_from_slice(src);
        buf
    }

    fn layout(len: usize) -> Layout {
        // Layout::array checks the size computation for overflow (unlike a
        // bare `len * size_of::<f32>()`), and align_to can only raise the
        // alignment, which for a power of two never fails.
        Layout::array::<f32>(len)
            .and_then(|l| l.align_to(ALIGN))
            .expect("aligned buffer layout overflows isize")
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Verify the guaranteed alignment (used by tests and debug asserts).
    pub fn is_aligned(&self) -> bool {
        self.len == 0 || (self.ptr as usize) % ALIGN == 0
    }

    pub fn fill(&mut self, v: f32) {
        self.deref_mut().fill(v);
    }
}

impl Deref for AlignedBuf {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        // SAFETY: ptr/len describe our exclusive, zero-initialized
        // allocation (or a dangling-but-well-aligned pointer with len 0,
        // which from_raw_parts permits). The borrow of self keeps the
        // allocation alive and prevents a concurrent &mut slice.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }
}

impl DerefMut for AlignedBuf {
    fn deref_mut(&mut self) -> &mut [f32] {
        // SAFETY: as in Deref, plus &mut self guarantees this is the only
        // live reference into the allocation.
        unsafe { std::slice::from_raw_parts_mut(self.ptr, self.len) }
    }
}

impl Drop for AlignedBuf {
    fn drop(&mut self) {
        if self.len > 0 {
            // SAFETY: len > 0 means ptr came from alloc_zeroed with exactly
            // this layout (len is immutable after construction), has not
            // been freed before (drop runs once), and ownership is
            // exclusive.
            unsafe { dealloc(self.ptr as *mut u8, Self::layout(self.len)) };
        }
    }
}

impl Clone for AlignedBuf {
    fn clone(&self) -> Self {
        AlignedBuf::from_slice(self)
    }
}

impl std::fmt::Debug for AlignedBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "AlignedBuf(len={}, align={})", self.len, ALIGN)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeroed_and_aligned() {
        let b = AlignedBuf::zeroed(1000);
        assert!(b.is_aligned());
        assert_eq!(b.len(), 1000);
        assert!(b.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn write_read_roundtrip() {
        let mut b = AlignedBuf::zeroed(16);
        for (i, v) in b.iter_mut().enumerate() {
            *v = i as f32;
        }
        assert_eq!(b[7], 7.0);
        let c = b.clone();
        assert_eq!(&*c, &*b);
    }

    #[test]
    fn from_slice_copies() {
        let b = AlignedBuf::from_slice(&[1.0, 2.0, 3.0]);
        assert_eq!(&*b, &[1.0, 2.0, 3.0]);
        assert!(b.is_aligned());
    }

    #[test]
    fn empty_buffer_ok() {
        let b = AlignedBuf::zeroed(0);
        assert!(b.is_empty());
        assert_eq!(&*b, &[] as &[f32]);
    }

    #[test]
    fn many_allocations_stay_aligned() {
        for len in [1, 3, 17, 63, 64, 65, 4096] {
            let b = AlignedBuf::zeroed(len);
            assert!(b.is_aligned(), "len={len}");
        }
    }

    /// The layout computation must reject a length whose byte size
    /// overflows isize instead of wrapping into a tiny allocation.
    #[test]
    #[should_panic(expected = "aligned buffer layout")]
    #[cfg(target_pointer_width = "64")]
    fn oversized_layout_panics_cleanly() {
        // isize::MAX / 4 + 1 elements of f32 overflow the isize byte limit;
        // the panic fires in layout(), before any allocation is attempted.
        let _ = AlignedBuf::zeroed(isize::MAX as usize / 4 + 1);
    }
}
