//! Minimal dense f32 tensor used at module boundaries (dataset images,
//! PJRT literals, cross-validation against the AOT artifacts).
//!
//! The training hot path in [`crate::nn`] works on flat `&[f32]` slices with
//! explicit dims — mirroring the paper's C++ implementation, where
//! `_mm_malloc(…, 64)`-aligned flat arrays are what the Phi's VPU wants.
//! [`AlignedBuf`] reproduces that 64-byte alignment guarantee.

mod aligned;

pub use aligned::AlignedBuf;

/// A dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Build from existing data; panics if the element count mismatches.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} incompatible with {} elements",
            shape,
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Row-major linear offset of a multi-index.
    pub fn offset(&self, idx: &[usize]) -> usize {
        assert_eq!(idx.len(), self.shape.len(), "rank mismatch");
        let mut off = 0;
        for (i, (&ix, &dim)) in idx.iter().zip(&self.shape).enumerate() {
            assert!(ix < dim, "index {ix} out of bounds for axis {i} (dim {dim})");
            off = off * dim + ix;
        }
        off
    }

    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[self.offset(idx)]
    }

    pub fn set(&mut self, idx: &[usize], v: f32) {
        let off = self.offset(idx);
        self.data[off] = v;
    }

    /// Reshape without copying; panics if element counts differ.
    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.data.len(),
            "cannot reshape {:?} -> {:?}",
            self.shape,
            shape
        );
        self.shape = shape.to_vec();
        self
    }

    /// Index of the maximum element (prediction argmax).
    pub fn argmax(&self) -> usize {
        let mut best = 0;
        let mut bv = f32::NEG_INFINITY;
        for (i, &v) in self.data.iter().enumerate() {
            if v > bv {
                bv = v;
                best = i;
            }
        }
        best
    }

    /// Maximum absolute difference against another tensor of the same shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// Argmax over a plain slice (used on logits in the hot path).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_indexing() {
        let mut t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.len(), 24);
        t.set(&[1, 2, 3], 5.0);
        assert_eq!(t.at(&[1, 2, 3]), 5.0);
        assert_eq!(t.offset(&[1, 2, 3]), 23);
        assert_eq!(t.offset(&[0, 0, 1]), 1);
        assert_eq!(t.offset(&[0, 1, 0]), 4);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn oob_panics() {
        let t = Tensor::zeros(&[2, 2]);
        t.at(&[2, 0]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|i| i as f32).collect());
        let r = t.clone().reshape(&[3, 2]);
        assert_eq!(r.data(), t.data());
        assert_eq!(r.shape(), &[3, 2]);
    }

    #[test]
    #[should_panic(expected = "cannot reshape")]
    fn reshape_wrong_count_panics() {
        Tensor::zeros(&[2, 2]).reshape(&[5]);
    }

    #[test]
    fn argmax_works() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[-5.0, -2.0, -3.0]), 1);
        let t = Tensor::from_vec(&[4], vec![1.0, 7.0, 7.0, 2.0]);
        assert_eq!(t.argmax(), 1, "first max wins");
    }

    #[test]
    fn max_abs_diff() {
        let a = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::from_vec(&[3], vec![1.0, 2.5, 2.0]);
        assert_eq!(a.max_abs_diff(&b), 1.0);
    }
}
