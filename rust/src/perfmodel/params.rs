//! Performance-model constants — paper Table 3, verbatim.
//!
//! Hardware-dependent constants describe the Intel Xeon Phi 7120P (61
//! cores, 1.238 GHz, 4 hardware threads per core with the round-robin CPI
//! schedule 1/1/1.5/2) and the two host CPUs the paper compares against.
//! Hardware-independent constants are the per-architecture operation
//! counts the authors derived for FProp/BProp/Prep.

use crate::config::{ArchSpec, LayerSpec};
use crate::nn::{compute_dims, Network};

/// Xeon Phi core count (7120P).
pub const PHI_CORES: usize = 61;
/// Clock of one processing unit, Hz (Table 3: s = 1.238 GHz).
pub const CLOCK_HZ: f64 = 1.238e9;
/// Table 3: OperationFactor = 15 ("adjusted to closely match the measured
/// value for 15 threads … at the same time account for vectorization").
pub const OPERATION_FACTOR: f64 = 15.0;

/// Relative sequential speed of the comparison hosts versus one Phi
/// thread, derived from the paper's own speedup triple (103× vs Phi 1T,
/// 14× vs Xeon E5, 58× vs Core i5 ⇒ E5 ≈ 103/14, i5 ≈ 103/58).
pub const XEON_E5_SPEED_VS_PHI1T: f64 = 103.0 / 14.0;
pub const CORE_I5_SPEED_VS_PHI1T: f64 = 103.0 / 58.0;

/// Best theoretical CPI per thread for a given threads-per-core occupancy
/// (Table 3: 1–2 threads → 1, 3 threads → 1.5, 4 threads → 2).
pub fn cpi_for_threads_per_core(tpc: usize) -> f64 {
    match tpc {
        0 | 1 | 2 => 1.0,
        3 => 1.5,
        _ => 2.0,
    }
}

/// Threads-per-core occupancy for `p` threads. Up to 244 threads this is
/// the real 61-core Phi. Beyond that the paper models future parts; its
/// Table-8 numbers are reproduced best by a 3-way-occupancy CPI (1.5) —
/// full 4-way (CPI 2) overshoots the large net by >30% while CPI 1
/// undershoots small/medium. We use 3 (CPI 1.5) and record the residual
/// deviation in EXPERIMENTS.md.
pub fn threads_per_core(p: usize) -> usize {
    if p == 0 {
        1
    } else if p <= 4 * PHI_CORES {
        p.div_ceil(PHI_CORES)
    } else {
        3
    }
}

/// CPI for a thread count (convenience composition).
pub fn cpi(p: usize) -> f64 {
    cpi_for_threads_per_core(threads_per_core(p))
}

/// Per-architecture model constants (Table 3).
#[derive(Debug, Clone, Copy)]
pub struct ArchConstants {
    /// # FProp operations / image.
    pub fprop_ops: f64,
    /// # BProp operations / image.
    pub bprop_ops: f64,
    /// # operations for preparations.
    pub prep_ops: f64,
    /// Measured forward time / image on one Phi thread (ms) — prediction b.
    pub t_fprop_ms: f64,
    /// Measured backward time / image on one Phi thread (ms).
    pub t_bprop_ms: f64,
    /// Epochs the paper trains this architecture.
    pub epochs: usize,
}

/// Table 3 constants by architecture name.
pub fn arch_constants(arch: &str) -> Option<ArchConstants> {
    match arch {
        "small" => Some(ArchConstants {
            fprop_ops: 58_000.0,
            bprop_ops: 524_000.0,
            prep_ops: 1e9,
            t_fprop_ms: 1.45,
            t_bprop_ms: 5.3,
            epochs: 70,
        }),
        "medium" => Some(ArchConstants {
            fprop_ops: 559_000.0,
            bprop_ops: 6_119_000.0,
            prep_ops: 1e10,
            t_fprop_ms: 12.55,
            t_bprop_ms: 69.73,
            epochs: 70,
        }),
        "large" => Some(ArchConstants {
            fprop_ops: 5_349_000.0,
            bprop_ops: 73_178_000.0,
            prep_ops: 1e11,
            t_fprop_ms: 148.88,
            t_bprop_ms: 859.19,
            epochs: 15,
        }),
        _ => None,
    }
}

/// Total (forward, backward) FLOPs per image derived from the static cost
/// model ([`crate::nn::audit`]): the sum of every compiled op's
/// [`crate::nn::LayerOp::cost`]. Unlike the Table-3 counts these are not
/// hand-fit — they fall out of the kernel arithmetic, and `chaos analyze
/// --cost` prints the per-layer breakdown they sum over.
pub fn derived_ops(net: &Network) -> (f64, f64) {
    net.ops.iter().map(|op| op.cost()).fold((0.0, 0.0), |(f, b), c| {
        (f + c.fwd_flops, b + c.bwd_flops)
    })
}

impl ArchConstants {
    /// Replace the hand-fit BProp operation count with a statically
    /// *derived* one: keep the forward count as the single measured-scale
    /// anchor and set `bprop_ops = fprop_ops · (derived bwd / derived fwd)`.
    /// The backward cost then comes out of the cost model's kernel
    /// arithmetic instead of Table 3, so the analytic model consumes
    /// derived relative costs — cross-check the absolute scale against
    /// `BENCH_train.json` / `BENCH_eval.json`.
    pub fn with_derived_ops(self, net: &Network) -> ArchConstants {
        let (f, b) = derived_ops(net);
        if f <= 0.0 {
            return self;
        }
        ArchConstants { bprop_ops: self.fprop_ops * (b / f), ..self }
    }
}

/// Per-layer cost weights (MAC-style operation counts) computed from the
/// architecture geometry. The analytic model uses the paper's aggregate
/// constants; the simulator distributes them over layers proportionally to
/// these weights to regenerate the per-layer tables (Table 5/6).
#[derive(Debug, Clone)]
pub struct LayerCosts {
    /// Parallel to the arch's layers: (forward_ops, backward_ops).
    pub per_layer: Vec<(f64, f64)>,
}

impl LayerCosts {
    /// Per-layer (forward, backward) FLOPs from the static cost model —
    /// every compiled op's [`crate::nn::LayerOp::cost`], including
    /// runtime-registered kinds (which answer through the conservative
    /// trait default). Prefer this over [`LayerCosts::of`] when a compiled
    /// [`Network`] is at hand: the spec-level MAC proxy below cannot see
    /// op-level detail like activation arithmetic or custom kernels.
    pub fn derived(net: &Network) -> LayerCosts {
        let per_layer =
            net.ops.iter().map(|op| { let c = op.cost(); (c.fwd_flops, c.bwd_flops) }).collect();
        LayerCosts { per_layer }
    }

    pub fn of(arch: &ArchSpec) -> LayerCosts {
        let dims = compute_dims(arch);
        let per_layer = dims
            .iter()
            .map(|d| match &d.spec {
                LayerSpec::Input { .. } => (0.0, 0.0),
                LayerSpec::Conv { maps, kernel, .. } => {
                    let macs =
                        (maps * d.out_side * d.out_side * d.in_maps * kernel * kernel) as f64;
                    // backward = weight grads + input deltas ≈ 2× forward
                    (macs, 2.0 * macs)
                }
                LayerSpec::MaxPool { kernel } | LayerSpec::AvgPool { kernel } => {
                    let cmp = (d.out_len() * kernel * kernel) as f64;
                    (cmp, d.out_len() as f64)
                }
                LayerSpec::FullyConnected { .. } | LayerSpec::Output { .. } => {
                    let macs = (d.in_maps * d.out_maps) as f64;
                    (macs, 2.0 * macs)
                }
                // Elementwise pass over the outputs.
                LayerSpec::Dropout { .. } => (d.out_len() as f64, d.out_len() as f64),
                // No structural knowledge: weight count (if any) or an
                // elementwise pass is the best generic MAC proxy.
                LayerSpec::Custom { .. } => {
                    let ops = d.weights.max(d.out_len()) as f64;
                    (ops, 2.0 * ops)
                }
            })
            .collect();
        LayerCosts { per_layer }
    }

    pub fn total_forward(&self) -> f64 {
        self.per_layer.iter().map(|(f, _)| f).sum()
    }

    pub fn total_backward(&self) -> f64 {
        self.per_layer.iter().map(|(_, b)| b).sum()
    }

    /// Fraction of forward cost in layer `l`.
    pub fn forward_fraction(&self, l: usize) -> f64 {
        self.per_layer[l].0 / self.total_forward()
    }

    pub fn backward_fraction(&self, l: usize) -> f64 {
        self.per_layer[l].1 / self.total_backward()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchSpec;

    #[test]
    fn cpi_schedule_matches_table3() {
        assert_eq!(cpi(1), 1.0);
        assert_eq!(cpi(61), 1.0);
        assert_eq!(cpi(122), 1.0); // 2 threads/core
        assert_eq!(cpi(180), 1.5); // 3 threads/core
        assert_eq!(cpi(240), 2.0);
        assert_eq!(cpi(244), 2.0);
        assert_eq!(cpi(480), 1.5); // future parts: see threads_per_core docs
        assert_eq!(cpi(3840), 1.5);
    }

    #[test]
    fn threads_per_core_boundaries() {
        assert_eq!(threads_per_core(61), 1);
        assert_eq!(threads_per_core(62), 2);
        assert_eq!(threads_per_core(122), 2);
        assert_eq!(threads_per_core(123), 3);
        assert_eq!(threads_per_core(244), 4);
        assert_eq!(threads_per_core(960), 3);
    }

    #[test]
    fn table3_constants_present() {
        for (name, f, b) in [
            ("small", 58_000.0, 524_000.0),
            ("medium", 559_000.0, 6_119_000.0),
            ("large", 5_349_000.0, 73_178_000.0),
        ] {
            let c = arch_constants(name).unwrap();
            assert_eq!(c.fprop_ops, f);
            assert_eq!(c.bprop_ops, b);
        }
        assert!(arch_constants("tiny").is_none());
    }

    #[test]
    fn layer_costs_dominated_by_conv() {
        // Paper Table 1/5: convolution dominates. Our computed
        // distribution must reflect that for all paper archs.
        for name in crate::config::PAPER_ARCHS {
            let arch = ArchSpec::by_name(name).unwrap();
            let costs = LayerCosts::of(&arch);
            let dims = crate::nn::compute_dims(&arch);
            let conv_b: f64 = dims
                .iter()
                .zip(&costs.per_layer)
                .filter(|(d, _)| matches!(d.spec, LayerSpec::Conv { .. }))
                .map(|(_, (_, b))| b)
                .sum();
            let frac = conv_b / costs.total_backward();
            assert!(frac > 0.85, "{name}: conv backward fraction {frac}");
        }
    }

    #[test]
    fn derived_costs_are_structural() {
        for name in crate::config::PAPER_ARCHS {
            let net = Network::from_name(name).unwrap();
            let costs = LayerCosts::derived(&net);
            assert_eq!(costs.per_layer.len(), net.ops.len(), "{name}");
            // Input layer is free; every driven layer costs something.
            assert_eq!(costs.per_layer[0], (0.0, 0.0), "{name}");
            for (l, (f, b)) in costs.per_layer.iter().enumerate().skip(1) {
                assert!(*f > 0.0 && *b > 0.0, "{name} layer {l}: ({f}, {b})");
            }
            // Backward does strictly more arithmetic than forward, and
            // convolution dominates (paper Table 1/5).
            assert!(costs.total_backward() > costs.total_forward(), "{name}");
            let conv_b: f64 = net
                .ops
                .iter()
                .zip(&costs.per_layer)
                .filter(|(op, _)| op.kind() == "conv")
                .map(|(_, (_, b))| b)
                .sum();
            assert!(conv_b / costs.total_backward() > 0.8, "{name}: conv fraction");
        }
    }

    #[test]
    fn derived_ops_scale_with_arch_size() {
        let (fs, bs) = derived_ops(&Network::from_name("small").unwrap());
        let (fm, bm) = derived_ops(&Network::from_name("medium").unwrap());
        assert!(fm > fs && bm > bs, "medium ({fm}, {bm}) must exceed small ({fs}, {bs})");
        // with_derived_ops keeps the forward anchor, derives backward.
        let c = arch_constants("small").unwrap();
        let d = c.with_derived_ops(&Network::from_name("small").unwrap());
        assert_eq!(d.fprop_ops, c.fprop_ops);
        assert!((d.bprop_ops / d.fprop_ops - bs / fs).abs() < 1e-9);
    }

    #[test]
    fn fractions_sum_to_one() {
        let arch = ArchSpec::medium();
        let costs = LayerCosts::of(&arch);
        let f: f64 = (0..costs.per_layer.len()).map(|l| costs.forward_fraction(l)).sum();
        let b: f64 = (0..costs.per_layer.len()).map(|l| costs.backward_fraction(l)).sum();
        assert!((f - 1.0).abs() < 1e-9);
        assert!((b - 1.0).abs() < 1e-9);
    }

    #[test]
    fn paper_op_ratio_consistency() {
        // Table 3's BProp/FProp ratios (≈9–13.7×) should be in the same
        // regime as our MAC-derived ratios (≈2–3×, since the paper counts
        // more than MACs in backward). Sanity: both grow with arch size.
        let small = arch_constants("small").unwrap();
        let large = arch_constants("large").unwrap();
        assert!(large.fprop_ops / small.fprop_ops > 50.0);
        assert!(large.bprop_ops / small.bprop_ops > 100.0);
    }
}
