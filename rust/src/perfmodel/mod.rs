//! The paper's analytic performance model (§5.2, Listing 2, Tables 3/4)
//! and its constants. Regenerates Figs 11–13 and Tables 8/9, and predicts
//! execution times for thread counts beyond the 7120P's 244 hardware
//! threads.
//!
//! # Derived vs. measured parameters
//!
//! The model's parameters come in two flavours:
//!
//! - **Measured** — Table-3 constants fit by the paper's authors against
//!   the 7120P (per-image FProp/BProp operation counts and millisecond
//!   timings, the `OperationFactor` calibration, the Table-4 memory
//!   contention fits). [`PerfModel::for_arch`] uses these verbatim.
//! - **Derived** — per-op FLOP/byte counts computed statically from the
//!   compiled kernels by the cost model in [`crate::nn::audit`]
//!   ([`LayerCosts::derived`], [`derived_ops`]). No fitting involved: they
//!   fall out of the kernel arithmetic, and `chaos analyze --cost` prints
//!   the per-layer breakdown. [`PerfModel::for_network`] swaps the
//!   hand-fit backward count for the derived backward/forward ratio while
//!   keeping the measured forward anchor.
//!
//! The derived side is cross-checkable against measurements: the
//! `layer_ops` bench and the harness's `BENCH_train.json` /
//! `BENCH_eval.json` outputs record measured per-phase times, so a derived
//! per-layer cost share that disagrees badly with the measured per-layer
//! timer shares (`chaos train`'s layer table) indicates a cost-model bug —
//! the static table is the prediction, the bench JSON is the experiment.

mod contention;
mod model;
mod params;
mod shard;

pub use contention::{
    measured as contention_measured, paper_predicted, ContentionModel, MEASURED_THREADS,
};
pub use model::{Breakdown, PerfModel, Scenario};
pub use params::{
    arch_constants, cpi, cpi_for_threads_per_core, derived_ops, threads_per_core, ArchConstants,
    LayerCosts, CLOCK_HZ, CORE_I5_SPEED_VS_PHI1T, OPERATION_FACTOR, PHI_CORES,
    XEON_E5_SPEED_VS_PHI1T,
};
pub use shard::{
    rank_plans, score_plan, BoundaryCost, ShardCost, ShardScore, SHARD_LINK_BYTES_PER_SEC,
};
