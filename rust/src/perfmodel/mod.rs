//! The paper's analytic performance model (§5.2, Listing 2, Tables 3/4)
//! and its constants. Regenerates Figs 11–13 and Tables 8/9, and predicts
//! execution times for thread counts beyond the 7120P's 244 hardware
//! threads.

mod contention;
mod model;
mod params;

pub use contention::{
    measured as contention_measured, paper_predicted, ContentionModel, MEASURED_THREADS,
};
pub use model::{Breakdown, PerfModel, Scenario};
pub use params::{
    arch_constants, cpi, cpi_for_threads_per_core, threads_per_core, ArchConstants, LayerCosts,
    CLOCK_HZ, CORE_I5_SPEED_VS_PHI1T, OPERATION_FACTOR, PHI_CORES, XEON_E5_SPEED_VS_PHI1T,
};
