//! The analytic performance model — paper Listing 2.
//!
//! ```text
//! T(i, it, ep, p, s) = Tcomp + Tmem
//! Tcomp = [ (Prep + 4i + 2it + 10ep)/s          (sequential work)
//!         + ((FProp + BProp)/s) · (i/p) · ep    (training)
//!         + (FProp/s) · (i/p) · ep              (validation)
//!         + (FProp/s) · (it/p) · ep             (testing)
//!         ] · CPI · OperationFactor
//! Tmem  = MemoryContention(p) · ep · i / p
//! ```
//!
//! All constants are Table 3 / Table 4 verbatim (see [`super::params`] and
//! [`super::contention`]). The model regenerates Figs 11–13 (predicted vs
//! measured), Table 8 (480–3840 threads) and Table 9 (image/epoch scaling).

use super::contention::ContentionModel;
use super::params::{arch_constants, cpi, ArchConstants, CLOCK_HZ, OPERATION_FACTOR};

/// Scenario parameters (defaults = the paper's MNIST setup).
#[derive(Debug, Clone, Copy)]
pub struct Scenario {
    /// Training/validation images (i).
    pub images: usize,
    /// Test images (it).
    pub test_images: usize,
    /// Epochs (ep).
    pub epochs: usize,
    /// Threads (p).
    pub threads: usize,
}

impl Scenario {
    pub fn paper_default(arch: &str, threads: usize) -> Scenario {
        let ep = arch_constants(arch).map(|c| c.epochs).unwrap_or(10);
        Scenario { images: 60_000, test_images: 10_000, epochs: ep, threads }
    }
}

/// The assembled model for one architecture.
#[derive(Debug, Clone)]
pub struct PerfModel {
    pub arch: String,
    consts: ArchConstants,
    contention: ContentionModel,
}

/// Per-term breakdown of a prediction (seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Breakdown {
    pub sequential: f64,
    pub training: f64,
    pub validation: f64,
    pub testing: f64,
    pub memory: f64,
}

impl Breakdown {
    pub fn total(&self) -> f64 {
        self.sequential + self.training + self.validation + self.testing + self.memory
    }
}

impl PerfModel {
    pub fn for_arch(arch: &str) -> anyhow::Result<PerfModel> {
        let consts = arch_constants(arch)
            .ok_or_else(|| anyhow::anyhow!("no Table-3 constants for arch '{arch}'"))?;
        let contention = ContentionModel::for_arch(arch)
            .ok_or_else(|| anyhow::anyhow!("no Table-4 contention for arch '{arch}'"))?;
        Ok(PerfModel { arch: arch.to_string(), consts, contention })
    }

    /// Like [`PerfModel::for_arch`], but with the backward operation count
    /// derived from the static cost model ([`crate::nn::audit`]) instead of
    /// the hand-fit Table-3 value — see
    /// [`ArchConstants::with_derived_ops`]. The forward count and the
    /// contention fit remain the measured anchors.
    pub fn for_network(net: &crate::nn::Network) -> anyhow::Result<PerfModel> {
        let name = net.arch.name.as_str();
        let consts = arch_constants(name)
            .ok_or_else(|| anyhow::anyhow!("no Table-3 constants for arch '{name}'"))?
            .with_derived_ops(net);
        let contention = ContentionModel::for_arch(name)
            .ok_or_else(|| anyhow::anyhow!("no Table-4 contention for arch '{name}'"))?;
        Ok(PerfModel { arch: name.to_string(), consts, contention })
    }

    /// Listing-2 prediction with per-term breakdown.
    pub fn predict_breakdown(&self, sc: &Scenario) -> Breakdown {
        let p = sc.threads.max(1) as f64;
        let i = sc.images as f64;
        let it = sc.test_images as f64;
        let ep = sc.epochs as f64;
        let s = CLOCK_HZ;
        let factor = cpi(sc.threads) * OPERATION_FACTOR;
        let c = &self.consts;

        let sequential = (c.prep_ops + 4.0 * i + 2.0 * it + 10.0 * ep) / s * factor;
        let training = (c.fprop_ops + c.bprop_ops) / s * (i / p) * ep * factor;
        let validation = c.fprop_ops / s * (i / p) * ep * factor;
        let testing = c.fprop_ops / s * (it / p) * ep * factor;
        let memory = self.contention.contention(sc.threads) * ep * i / p;
        Breakdown { sequential, training, validation, testing, memory }
    }

    /// Total predicted seconds.
    pub fn predict_secs(&self, sc: &Scenario) -> f64 {
        self.predict_breakdown(sc).total()
    }

    /// Predicted minutes (the unit of Tables 8 and 9).
    pub fn predict_minutes(&self, sc: &Scenario) -> f64 {
        self.predict_secs(sc) / 60.0
    }

    /// "Prediction b" from Table 3: sequential one-thread execution time
    /// from the *measured* per-image fprop/bprop milliseconds rather than
    /// operation counts. Used as the measured-side anchor of Figs 11–13.
    pub fn measured_phi_1t_secs(&self, sc: &Scenario) -> f64 {
        let c = &self.consts;
        let per_image_train = (c.t_fprop_ms + c.t_bprop_ms) * 1e-3;
        let per_image_fwd = c.t_fprop_ms * 1e-3;
        let i = sc.images as f64;
        let it = sc.test_images as f64;
        let ep = sc.epochs as f64;
        per_image_train * i * ep + per_image_fwd * (i + it) * ep
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn minutes(arch: &str, threads: usize) -> f64 {
        let m = PerfModel::for_arch(arch).unwrap();
        m.predict_minutes(&Scenario::paper_default(arch, threads))
    }

    /// Paper Table 8: predicted minutes at 480–3840 threads.
    #[test]
    fn table8_predictions_within_tolerance() {
        let expected = [
            ("small", [(480, 6.6), (960, 5.4), (1920, 4.9), (3840, 4.6)]),
            ("medium", [(480, 36.8), (960, 23.9), (1920, 17.4), (3840, 14.2)]),
            ("large", [(480, 92.9), (960, 60.8), (1920, 44.8), (3840, 36.8)]),
        ];
        for (arch, rows) in expected {
            for (p, paper_min) in rows {
                let got = minutes(arch, p);
                let rel = (got - paper_min).abs() / paper_min;
                assert!(
                    rel < 0.30,
                    "{arch}@{p}: model {got:.1} min vs paper {paper_min} min ({:.0}% off)",
                    rel * 100.0
                );
            }
        }
    }

    /// Paper Table 9 anchor: small CNN at 240 threads, 70 epochs, 60k/10k
    /// images → 8.9 minutes.
    #[test]
    fn table9_small_anchor() {
        let got = minutes("small", 240);
        assert!((got - 8.9).abs() / 8.9 < 0.15, "got {got:.2} min, paper 8.9");
    }

    /// Table 9 structure: doubling images or epochs ≈ doubles time;
    /// doubling threads does NOT halve it (the paper's Result 6).
    #[test]
    fn table9_scaling_shape() {
        let m = PerfModel::for_arch("small").unwrap();
        let base = Scenario { images: 60_000, test_images: 10_000, epochs: 70, threads: 240 };
        let t_base = m.predict_secs(&base);
        let t_2ep = m.predict_secs(&Scenario { epochs: 140, ..base });
        let t_2img =
            m.predict_secs(&Scenario { images: 120_000, test_images: 20_000, ..base });
        let t_2thr = m.predict_secs(&Scenario { threads: 480, ..base });
        assert!((t_2ep / t_base - 2.0).abs() < 0.1, "epochs ratio {}", t_2ep / t_base);
        assert!((t_2img / t_base - 2.0).abs() < 0.1, "images ratio {}", t_2img / t_base);
        assert!(
            t_2thr > t_base * 0.55 && t_2thr < t_base,
            "threads don't halve time: {} vs {}",
            t_2thr,
            t_base
        );
    }

    /// Fig 5 anchor: the large net on one Phi thread takes ~295.5 hours.
    #[test]
    fn large_one_thread_matches_measured_hours() {
        let m = PerfModel::for_arch("large").unwrap();
        let sc = Scenario::paper_default("large", 1);
        let measured_hours = m.measured_phi_1t_secs(&sc) / 3600.0;
        assert!(
            (measured_hours - 295.5).abs() / 295.5 < 0.15,
            "measured-anchor {measured_hours:.1} h vs paper 295.5 h"
        );
        // The op-count prediction lands in the same regime.
        let predicted_hours = m.predict_secs(&sc) / 3600.0;
        assert!(
            (predicted_hours - 295.5).abs() / 295.5 < 0.35,
            "prediction {predicted_hours:.1} h vs paper 295.5 h"
        );
    }

    #[test]
    fn breakdown_sums_to_total() {
        let m = PerfModel::for_arch("medium").unwrap();
        let sc = Scenario::paper_default("medium", 120);
        let b = m.predict_breakdown(&sc);
        assert!((b.total() - m.predict_secs(&sc)).abs() < 1e-9);
        assert!(b.training > b.validation, "training dominates validation");
        assert!(b.memory > 0.0);
    }

    #[test]
    fn more_threads_never_slower_in_model() {
        let m = PerfModel::for_arch("large").unwrap();
        let mut last = f64::INFINITY;
        for p in [1, 15, 30, 60, 120, 240, 480, 960] {
            let t = m.predict_secs(&Scenario::paper_default("large", p));
            assert!(t <= last * 1.35, "unexpected blow-up at p={p}");
            last = t;
        }
    }

    #[test]
    fn unknown_arch_rejected() {
        assert!(PerfModel::for_arch("tiny").is_err());
    }

    #[test]
    fn derived_model_is_structurally_sane() {
        // The derived-constants variant must stay a well-formed model:
        // finite positive predictions, training dominating validation (its
        // backward/forward ratio is > 1 by kernel arithmetic), and the
        // same measured anchors as the Table-3 model.
        let net = crate::nn::Network::from_name("small").unwrap();
        let m = PerfModel::for_network(&net).unwrap();
        let sc = Scenario::paper_default("small", 240);
        let b = m.predict_breakdown(&sc);
        assert!(b.total().is_finite() && b.total() > 0.0);
        assert!(b.training > b.validation);
        let table3 = PerfModel::for_arch("small").unwrap();
        assert_eq!(m.measured_phi_1t_secs(&sc), table3.measured_phi_1t_secs(&sc));
        // Non-paper archs have no measured anchors to derive around.
        assert!(PerfModel::for_network(&crate::nn::Network::from_name("tiny").unwrap()).is_err());
    }
}
