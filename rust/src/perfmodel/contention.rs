//! Memory-contention model — paper Table 4.
//!
//! The paper measures, per architecture, the extra seconds per image that
//! `p` threads "fighting for the I/O weights concurrently" cost, for
//! p ∈ {1, 15, 30, 60, 120, 180, 240}, and extrapolates the starred rows
//! (480…3840) for the prediction experiments. We carry the measured values
//! verbatim and reproduce the extrapolation with a log-log power-law fit
//! over the p ≥ 15 points (the single-thread point is off-trend, as in the
//! paper, where 15→240 grows almost exactly linearly).

use crate::util::stats::fit_power_law;

/// Measured thread counts of Table 4.
pub const MEASURED_THREADS: [usize; 7] = [1, 15, 30, 60, 120, 180, 240];

/// Table 4 measured contention (seconds/image) per architecture.
pub fn measured(arch: &str) -> Option<&'static [f64; 7]> {
    match arch {
        "small" => Some(&[7.10e-6, 6.40e-4, 1.36e-3, 3.07e-3, 6.76e-3, 9.95e-3, 1.40e-2]),
        "medium" => Some(&[1.56e-4, 2.00e-3, 3.97e-3, 8.03e-3, 1.65e-2, 2.50e-2, 3.83e-2]),
        "large" => Some(&[8.83e-4, 8.75e-3, 1.67e-2, 3.22e-2, 6.74e-2, 1.00e-1, 1.38e-1]),
        _ => None,
    }
}

/// Table 4 predicted (starred) rows, for regression against our fit.
pub fn paper_predicted(arch: &str) -> Option<[(usize, f64); 4]> {
    match arch {
        "small" => Some([(480, 2.78e-2), (960, 5.60e-2), (1920, 1.12e-1), (3840, 2.25e-1)]),
        "medium" => Some([(480, 7.31e-2), (960, 1.47e-1), (1920, 2.95e-1), (3840, 5.91e-1)]),
        "large" => Some([(480, 2.73e-1), (960, 5.46e-1), (1920, 1.09), (3840, 2.19)]),
        _ => None,
    }
}

/// The contention model: measured values verbatim, interpolation between
/// measured points, power-law extrapolation beyond 240 threads.
#[derive(Debug, Clone)]
pub struct ContentionModel {
    /// Power-law coefficients y = a·p^b fit on the p ≥ 15 measurements.
    a: f64,
    b: f64,
    measured: &'static [f64; 7],
}

impl ContentionModel {
    pub fn for_arch(arch: &str) -> Option<ContentionModel> {
        let m = measured(arch)?;
        let xs: Vec<f64> = MEASURED_THREADS[1..].iter().map(|&p| p as f64).collect();
        let ys: Vec<f64> = m[1..].to_vec();
        let (a, b) = fit_power_law(&xs, &ys);
        Some(ContentionModel { a, b, measured: m })
    }

    /// Seconds of memory contention per image at `p` threads.
    pub fn contention(&self, p: usize) -> f64 {
        if p == 0 {
            return 0.0;
        }
        // Exact measured point?
        if let Some(i) = MEASURED_THREADS.iter().position(|&t| t == p) {
            return self.measured[i];
        }
        if p > 240 {
            // The paper's starred rows double with p: linear extrapolation
            // anchored at the last measured point (the power-law fit is
            // kept for the exponent diagnostic only).
            return self.measured[6] * p as f64 / 240.0;
        }
        // Log-log interpolation between neighbouring measured points.
        let hi = MEASURED_THREADS.iter().position(|&t| t > p).unwrap_or(6);
        let lo = hi - 1;
        let (p0, p1) = (MEASURED_THREADS[lo] as f64, MEASURED_THREADS[hi] as f64);
        let (y0, y1) = (self.measured[lo], self.measured[hi]);
        let t = ((p as f64).ln() - p0.ln()) / (p1.ln() - p0.ln());
        (y0.ln() + t * (y1.ln() - y0.ln())).exp()
    }

    /// The fitted power law y = a·p^b (diagnostic; extrapolation itself is
    /// the linear-anchor rule above).
    pub fn fit(&self) -> (f64, f64) {
        (self.a, self.b)
    }

    /// The fitted exponent (≈1: contention grows linearly with threads).
    pub fn exponent(&self) -> f64 {
        self.b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measured_points_exact() {
        for arch in ["small", "medium", "large"] {
            let m = ContentionModel::for_arch(arch).unwrap();
            let tbl = measured(arch).unwrap();
            for (i, &p) in MEASURED_THREADS.iter().enumerate() {
                assert_eq!(m.contention(p), tbl[i], "{arch} p={p}");
            }
        }
    }

    #[test]
    fn extrapolation_matches_paper_predictions() {
        // Our power-law fit must land within 15% of the paper's starred
        // Table-4 rows for every architecture.
        for arch in ["small", "medium", "large"] {
            let m = ContentionModel::for_arch(arch).unwrap();
            for (p, expected) in paper_predicted(arch).unwrap() {
                let got = m.contention(p);
                let rel = (got - expected).abs() / expected;
                assert!(
                    rel < 0.15,
                    "{arch} p={p}: fit {got:.3e} vs paper {expected:.3e} ({:.1}% off)",
                    rel * 100.0
                );
            }
        }
    }

    #[test]
    fn contention_monotone_in_threads() {
        let m = ContentionModel::for_arch("medium").unwrap();
        let mut last = 0.0;
        for p in [1, 8, 15, 40, 60, 100, 180, 240, 480, 1000, 3840] {
            let c = m.contention(p);
            assert!(c >= last, "contention must not decrease: p={p}");
            last = c;
        }
    }

    #[test]
    fn exponent_near_linear() {
        for arch in ["small", "medium", "large"] {
            let m = ContentionModel::for_arch(arch).unwrap();
            let b = m.exponent();
            assert!((0.8..1.2).contains(&b), "{arch}: exponent {b}");
        }
    }

    #[test]
    fn unknown_arch_none() {
        assert!(ContentionModel::for_arch("tiny").is_none());
        assert!(measured("nope").is_none());
    }
}
