//! Pricing shard plans before any sharded runtime exists: per-shard
//! FLOP/param totals, per-boundary activation traffic, predicted
//! imbalance, and a proxy seconds-per-sample that ranks candidate plans.
//!
//! The model deliberately reuses the same primitives as the rest of
//! `perfmodel`: per-layer FLOPs come from the static kernel cost model
//! ([`LayerOp::cost`](crate::nn::LayerOp::cost), the derived side of the
//! paper's Table 3), the op rate is the calibrated
//! [`CLOCK_HZ`](super::CLOCK_HZ)/[`OPERATION_FACTOR`](super::OPERATION_FACTOR)
//! pair, and boundary tensors are the audited activation chain
//! ([`crate::nn::audit::boundary_act_elems`]). Absolute seconds are a
//! proxy — the point is *ranking*: two plans are compared under identical
//! constants, so the ordering is insensitive to calibration error.
//!
//! ## The traffic model
//!
//! * A boundary where neither side is split is **local**: in pure data
//!   parallelism each sample's activations stay on its home shard.
//! * A boundary touching a split layer costs one allgather of the
//!   boundary activation among the `n` participating shards —
//!   `4·act·(n−1)` bytes forward (every non-home participant needs the
//!   full input, or produces a slice every consumer needs), and the same
//!   backward for the returning deltas.
//!
//! ## The balance model
//!
//! Shard `s` has capacity share `w_s` (the plan's normalized weights).
//! Per global sample it performs `w_s`·flops on every replicated layer
//! (it sees `w_s` of the samples) and `frac_s`·flops on every split
//! layer (its owned fraction of the span, every sample). Predicted
//! compute time is `max_s load_s / rate_s`; imbalance is that maximum
//! over the perfectly-balanced time, so 1.0 is ideal and the planner's
//! weighted apportionment should keep it close.

use super::params::{CLOCK_HZ, OPERATION_FACTOR};
use crate::chaos::analysis::shard::{LayerAssignment, ShardPlan};
use crate::nn::{audit, Network};

/// Planning constant for cross-shard activation traffic, a NUMA/QPI-class
/// link (bytes/sec). All plans are priced under the same constant, so
/// rankings do not depend on its exact value.
pub const SHARD_LINK_BYTES_PER_SEC: f64 = 10.0e9;

/// One shard's predicted totals, per global sample.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardCost {
    pub shard: usize,
    /// Normalized capacity share.
    pub weight: f64,
    /// Parameters resident on this shard (replicated spans count fully).
    pub params: usize,
    pub fwd_flops: f64,
    pub bwd_flops: f64,
}

/// Predicted traffic across one layer boundary, per global sample.
#[derive(Debug, Clone, PartialEq)]
pub struct BoundaryCost {
    /// Downstream layer index (the boundary sits between `layer - 1` and
    /// `layer`).
    pub layer: usize,
    /// Elements of the activation tensor crossing here (from the audited
    /// dims chain).
    pub act_elems: usize,
    /// `"local"` (no shard crossing) or `"allgather"`.
    pub kind: &'static str,
    pub fwd_bytes: f64,
    pub bwd_bytes: f64,
}

/// The priced view of one clean plan.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardScore {
    pub shards: Vec<ShardCost>,
    pub boundaries: Vec<BoundaryCost>,
    /// Total cross-shard bytes per global sample (forward + backward).
    pub comm_bytes: f64,
    /// Max over shards of normalized load over the perfectly-balanced
    /// load; ≥ 1.0, with 1.0 meaning every shard finishes together.
    pub imbalance: f64,
    /// Predicted compute seconds per global sample (slowest shard).
    pub compute_secs: f64,
    /// Predicted communication seconds per global sample.
    pub comm_secs: f64,
}

impl ShardScore {
    /// Whole-fleet forward FLOPs per sample (sums to the unsharded
    /// [`audit_cost`](crate::nn::audit::audit_cost) total — sharding moves
    /// work, it does not create any).
    pub fn total_fwd_flops(&self) -> f64 {
        self.shards.iter().map(|s| s.fwd_flops).sum()
    }

    pub fn total_bwd_flops(&self) -> f64 {
        self.shards.iter().map(|s| s.bwd_flops).sum()
    }

    /// The ranking key: predicted compute + communication seconds per
    /// global sample.
    pub fn proxy_secs(&self) -> f64 {
        self.compute_secs + self.comm_secs
    }
}

/// Number of shards actually computing a layer under `assignment`:
/// replicated layers run on every shard (each over its own samples),
/// split layers on the shards owning a non-empty piece.
fn participants(plan: &ShardPlan, assignment: &LayerAssignment) -> usize {
    match assignment {
        LayerAssignment::Replicated | LayerAssignment::Copies(_) => plan.shards,
        LayerAssignment::Split { pieces } => {
            pieces.iter().filter(|rs| rs.iter().any(|r| !r.is_empty())).count()
        }
    }
}

/// Price a plan against its network. Assumes a structurally valid plan
/// (same layer count, verified by
/// [`verify_shards`](crate::chaos::analysis::shard::verify_shards), which
/// calls this for clean plans).
pub fn score_plan(net: &Network, plan: &ShardPlan) -> ShardScore {
    let n = plan.shards;
    let mut shards: Vec<ShardCost> = (0..n)
        .map(|s| ShardCost {
            shard: s,
            weight: plan.weights.get(s).copied().unwrap_or(0.0),
            params: 0,
            fwd_flops: 0.0,
            bwd_flops: 0.0,
        })
        .collect();

    for (layer, (op, d)) in net.ops.iter().zip(&net.dims).enumerate() {
        let cost = op.cost();
        match &plan.layers[layer] {
            LayerAssignment::Replicated | LayerAssignment::Copies(_) => {
                for sc in shards.iter_mut() {
                    sc.params += d.params.len();
                    sc.fwd_flops += cost.fwd_flops * sc.weight;
                    sc.bwd_flops += cost.bwd_flops * sc.weight;
                }
            }
            LayerAssignment::Split { .. } => {
                let span_len = d.params.len().max(1) as f64;
                for sc in shards.iter_mut() {
                    let owned = plan.owned_len(net, sc.shard, layer);
                    let frac = owned as f64 / span_len;
                    sc.params += owned;
                    sc.fwd_flops += cost.fwd_flops * frac;
                    sc.bwd_flops += cost.bwd_flops * frac;
                }
            }
        }
    }

    let acts = audit::boundary_act_elems(net);
    let mut boundaries = Vec::with_capacity(net.dims.len().saturating_sub(1));
    let mut comm_bytes = 0.0;
    for layer in 1..net.dims.len() {
        let up = participants(plan, &plan.layers[layer - 1]);
        let down = participants(plan, &plan.layers[layer]);
        let split_side = |a: &LayerAssignment| matches!(a, LayerAssignment::Split { .. });
        let crossing = usize::max(
            if split_side(&plan.layers[layer - 1]) { up } else { 1 },
            if split_side(&plan.layers[layer]) { down } else { 1 },
        );
        let (kind, bytes) = if crossing >= 2 {
            ("allgather", 4.0 * acts[layer] as f64 * (crossing - 1) as f64)
        } else {
            ("local", 0.0)
        };
        comm_bytes += 2.0 * bytes; // forward activations + backward deltas
        boundaries.push(BoundaryCost {
            layer,
            act_elems: acts[layer],
            kind,
            fwd_bytes: bytes,
            bwd_bytes: bytes,
        });
    }

    // rate_s = capacity share × fleet op rate; the fleet is n Phi-class
    // units at the calibrated sustained op rate.
    let fleet_rate = n as f64 * CLOCK_HZ / OPERATION_FACTOR;
    let mut compute_secs = 0.0f64;
    let mut total_load = 0.0f64;
    for sc in &shards {
        let load = sc.fwd_flops + sc.bwd_flops;
        total_load += load;
        let rate = (sc.weight * fleet_rate).max(f64::MIN_POSITIVE);
        compute_secs = compute_secs.max(load / rate);
    }
    let ideal_secs = total_load / fleet_rate;
    let imbalance = if ideal_secs > 0.0 { compute_secs / ideal_secs } else { 1.0 };
    let comm_secs = comm_bytes / SHARD_LINK_BYTES_PER_SEC;

    ShardScore { shards, boundaries, comm_bytes, imbalance, compute_secs, comm_secs }
}

/// Rank candidate plans for one network by predicted
/// [`proxy_secs`](ShardScore::proxy_secs), ascending (stable on ties).
/// Returns `(index into plans, score)` pairs.
pub fn rank_plans(net: &Network, plans: &[ShardPlan]) -> Vec<(usize, ShardScore)> {
    let mut ranked: Vec<(usize, ShardScore)> =
        plans.iter().enumerate().map(|(i, p)| (i, score_plan(net, p))).collect();
    ranked.sort_by(|a, b| a.1.proxy_secs().total_cmp(&b.1.proxy_secs()).then(a.0.cmp(&b.0)));
    ranked
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chaos::analysis::shard::plan_shards;
    use crate::nn::audit::audit_cost;

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0)
    }

    #[test]
    fn per_shard_totals_cross_check_against_audit_cost() {
        for arch in ["tiny", "small"] {
            let net = Network::from_name(arch).unwrap();
            let report = audit_cost(&net, 1);
            for n in 1..=4 {
                let score = score_plan(&net, &plan_shards(&net, n));
                assert!(
                    close(score.total_fwd_flops(), report.total_fwd_flops()),
                    "{arch}/{n}: {} vs {}",
                    score.total_fwd_flops(),
                    report.total_fwd_flops()
                );
                assert!(
                    close(score.total_bwd_flops(), report.total_bwd_flops()),
                    "{arch}/{n}: {} vs {}",
                    score.total_bwd_flops(),
                    report.total_bwd_flops()
                );
            }
        }
    }

    #[test]
    fn split_boundaries_price_traffic_and_single_shard_is_free() {
        let net = Network::from_name("tiny").unwrap();
        let one = score_plan(&net, &plan_shards(&net, 1));
        assert_eq!(one.comm_bytes, 0.0);
        assert!(one.boundaries.iter().all(|b| b.kind == "local"));

        let two = score_plan(&net, &plan_shards(&net, 2));
        assert!(two.comm_bytes > 0.0);
        let gathered: Vec<_> =
            two.boundaries.iter().filter(|b| b.kind == "allgather").collect();
        assert!(!gathered.is_empty());
        for b in &gathered {
            assert!(close(b.fwd_bytes, 4.0 * b.act_elems as f64));
            assert!(close(b.bwd_bytes, b.fwd_bytes));
        }
        assert!(two.imbalance >= 1.0 - 1e-12);
    }

    #[test]
    fn rank_plans_orders_by_proxy_and_keeps_indices() {
        let net = Network::from_name("small").unwrap();
        let plans = [plan_shards(&net, 1), plan_shards(&net, 2), plan_shards(&net, 4)];
        let ranked = rank_plans(&net, &plans);
        assert_eq!(ranked.len(), 3);
        for pair in ranked.windows(2) {
            assert!(pair[0].1.proxy_secs() <= pair[1].1.proxy_secs());
        }
        let mut seen: Vec<usize> = ranked.iter().map(|(i, _)| *i).collect();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2]);
    }
}
