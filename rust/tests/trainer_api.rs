//! Integration coverage for the redesigned coordinator API: the `Trainer`
//! builder, the open `UpdatePolicy` trait + registry, and the observer
//! callbacks.
//!
//! The toy-policy test is the acceptance check for the open API: a policy
//! defined *outside* the crate, registered by name, and selected through
//! the same path the CLI uses — without touching `trainer.rs`.

use chaos_phi::chaos::{
    observer_fn, policy, ChaosPolicy, EpochCtx, EpochState, SequentialPolicy, Strategy,
    TrainControl, Trainer, UpdatePolicy, WorkerHooks,
};
use chaos_phi::config::{ArchSpec, TrainConfig};
use chaos_phi::data::{generate_synthetic, Dataset, SynthConfig};
use chaos_phi::nn::LayerDims;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

fn tiny_data(n: usize, seed: u64) -> Dataset {
    generate_synthetic(n, seed, &SynthConfig::default()).resize(13)
}

fn tiny_trainer(threads: usize, epochs: usize) -> Trainer {
    Trainer::new().arch(ArchSpec::tiny()).config(TrainConfig {
        epochs,
        threads,
        eta0: 0.05,
        eta_decay: 0.95,
        seed: 42,
        validation_fraction: 0.25,
        eval_batch: 32,
        ..TrainConfig::default()
    })
}

#[test]
fn builder_validates_before_running() {
    let d = tiny_data(10, 1);
    // Missing architecture fails fast.
    assert!(Trainer::new().run(&d, &d).is_err());
    // Config errors surface through validate() without training.
    assert!(tiny_trainer(0, 1).validate().is_err());
    assert!(tiny_trainer(1, 0).validate().is_err());
    assert!(tiny_trainer(1, 1).eta(0.0, 0.9).validate().is_err());
    // Policy parameterization errors too.
    assert!(tiny_trainer(2, 1).policy_name("averaged:0").is_err());
    assert!(tiny_trainer(2, 1).policy_name("nope").is_err());
    // And a complete build passes.
    tiny_trainer(2, 1).policy(ChaosPolicy).validate().unwrap();
}

#[test]
fn quickstart_parity_through_trainer() {
    // The quickstart's headline assertion, as a test: sequential and
    // 4-thread CHAOS from the same seed reach comparable accuracy.
    // Unlike the unit-level parity test this goes through the *registry*
    // selection path (the CLI's route), at a smaller scale.
    let train_set = tiny_data(240, 3);
    let test_set = tiny_data(90, 4);
    let seq = tiny_trainer(1, 3)
        .policy_name("sequential")
        .unwrap()
        .run(&train_set, &test_set)
        .unwrap();
    let par = tiny_trainer(4, 3)
        .policy_name("chaos")
        .unwrap()
        .run(&train_set, &test_set)
        .unwrap();
    let gap = (seq.final_epoch().test.error_rate() - par.final_epoch().test.error_rate()).abs();
    assert!(gap < 0.2, "parity violated: gap {gap}");
    assert!(par.publications > 0);
    assert_eq!(seq.strategy, "sequential");
    assert_eq!(par.strategy, "chaos");
}

#[test]
fn observers_count_and_stop() {
    let train_set = tiny_data(80, 5);
    let test_set = tiny_data(30, 6);
    let calls = Arc::new(AtomicUsize::new(0));
    let c = calls.clone();
    // Stop after the second epoch of five.
    let r = tiny_trainer(1, 5)
        .policy(SequentialPolicy)
        .observer(observer_fn(move |rec, _run| {
            c.fetch_add(1, Ordering::Relaxed);
            if rec.epoch >= 1 {
                TrainControl::Stop
            } else {
                TrainControl::Continue
            }
        }))
        .run(&train_set, &test_set)
        .unwrap();
    assert_eq!(calls.load(Ordering::Relaxed), 2, "observer fires once per completed epoch");
    assert_eq!(r.epochs.len(), 2);
    assert!(r.stopped_early);
}

// ---------------------------------------------------------------------------
// The open-API acceptance check: a toy policy, defined here, registered by
// name, selected through the registry — trainer.rs untouched.
// ---------------------------------------------------------------------------

/// Publishes locked like CHAOS but at a scaled-down learning rate, and
/// counts every publication it routes.
struct TimidPolicy {
    scale: f32,
    published: Arc<AtomicUsize>,
}

struct TimidState {
    scale: f32,
    published: Arc<AtomicUsize>,
}

struct TimidHooks<'a> {
    state: &'a TimidState,
}

impl UpdatePolicy for TimidPolicy {
    fn name(&self) -> String {
        "timid".to_string()
    }

    fn epoch_state(&self, _ctx: &EpochCtx<'_>) -> Box<dyn EpochState> {
        Box::new(TimidState { scale: self.scale, published: self.published.clone() })
    }
}

impl EpochState for TimidState {
    fn worker(&self, _ctx: &EpochCtx<'_>, _worker_id: usize) -> Box<dyn WorkerHooks + '_> {
        Box::new(TimidHooks { state: self })
    }
}

impl WorkerHooks for TimidHooks<'_> {
    fn publish(&mut self, ctx: &EpochCtx<'_>, layer: usize, dims: &LayerDims, grads: &[f32]) {
        self.state.published.fetch_add(1, Ordering::Relaxed);
        ctx.store.publish_scaled(layer, dims.params.clone(), grads, -ctx.eta * self.state.scale);
    }
}

#[test]
fn custom_policy_registers_and_runs_by_name() {
    let published = Arc::new(AtomicUsize::new(0));
    let p = published.clone();
    policy::register("timid", move |arg| {
        let scale: f32 = match arg {
            None => 0.5,
            Some(a) => a
                .parse()
                .map_err(|_| anyhow::anyhow!("timid:<scale> — bad float '{a}'"))?,
        };
        Ok(Box::new(TimidPolicy { scale, published: p.clone() }))
    })
    .unwrap();

    // Registered policies are listed next to the built-ins…
    assert!(policy::names().iter().any(|n| n == "timid"));
    // …and rejected on duplicate registration.
    assert!(policy::register("timid", |_| Ok(Box::new(ChaosPolicy))).is_err());

    // Select it exactly like the CLI does, argument included.
    let train_set = tiny_data(90, 7);
    let test_set = tiny_data(30, 8);
    let r = tiny_trainer(3, 1)
        .policy_name("timid:0.25")
        .unwrap()
        .run(&train_set, &test_set)
        .unwrap();
    assert_eq!(r.strategy, "timid");
    assert_eq!(r.epochs[0].train.images, 90);
    assert!(r.publications > 0);
    assert_eq!(
        published.load(Ordering::Relaxed) as u64,
        r.publications,
        "every publication went through the custom hooks"
    );
    // Factory argument errors propagate.
    assert!(tiny_trainer(2, 1).policy_name("timid:zap").is_err());
}

#[test]
fn strategy_enum_still_selects_policies_through_the_builder() {
    // Migrated from the removed `chaos::train` shim: `Strategy` remains a
    // parseable front-end, but every run goes through the Trainer builder.
    let net = chaos_phi::nn::Network::new(ArchSpec::tiny());
    let train_set = tiny_data(60, 9);
    let test_set = tiny_data(20, 10);
    let cfg = TrainConfig {
        epochs: 1,
        threads: 2,
        eta0: 0.05,
        eta_decay: 0.95,
        seed: 1,
        validation_fraction: 0.0,
        eval_batch: 32,
        ..TrainConfig::default()
    };
    let run = Trainer::new()
        .network(net)
        .config(cfg)
        .policy_boxed(Strategy::Chaos.into_policy())
        .run(&train_set, &test_set)
        .unwrap();
    assert_eq!(run.strategy, "chaos");
    assert_eq!(run.epochs.len(), 1);
    assert!(run.publications > 0);
}
