//! Bit-identity of the batched forward path.
//!
//! The contract behind every batched consumer (native serving engine,
//! batched evaluation phases): `BatchPlan::forward` over `n` images
//! produces, bit for bit, the probabilities of `n` successive per-sample
//! `Network::forward` calls — across **every registered layer kind**,
//! including the padded/strided conv fast-path split, eval-mode dropout,
//! and train-mode dropout when the per-sample baseline shares the same
//! PRNG stream.

use chaos_phi::config::{Act, ArchSpec, LayerSpec};
use chaos_phi::nn::{layer, MathPolicy, Network};
use chaos_phi::util::{proptest, Pcg32};

fn rand_images(rng: &mut Pcg32, n: usize, len: usize) -> Vec<f32> {
    (0..n * len).map(|_| rng.uniform(-1.0, 1.0)).collect()
}

/// Every kind the test architectures below exercise; the coverage test
/// asserts this set matches the registry, so a newly registered built-in
/// kind fails loudly until it is covered here.
const COVERED_KINDS: &[&str] = &["input", "conv", "pool", "avgpool", "fc", "dropout", "output"];

/// An architecture touching every built-in kind, including the general
/// (padded + strided) conv path and both activations.
fn zoo_arch() -> ArchSpec {
    ArchSpec {
        name: "batch-zoo".into(),
        layers: vec![
            LayerSpec::Input { side: 13 },
            LayerSpec::conv_ex(4, 4, 1, 1, Act::Relu), // padded: 12x12
            LayerSpec::MaxPool { kernel: 2 },          // 6x6
            LayerSpec::conv_ex(6, 2, 2, 0, Act::ScaledTanh), // strided: 3x3
            LayerSpec::AvgPool { kernel: 3 },          // 1x1
            LayerSpec::Dropout { rate: 0.4 },
            LayerSpec::fc_act(17, Act::Relu),
            LayerSpec::Output { classes: 10 },
        ],
        paper_epochs: 1,
    }
}

/// Forward `n` samples one by one and return the concatenated probability
/// rows, using a scratch seeded like the batched one.
fn per_sample_probs(
    net: &Network,
    params: &[f32],
    images: &[f32],
    n: usize,
    train: bool,
    seed: u64,
) -> Vec<f32> {
    let il = net.dims[0].out_len();
    let classes = net.num_classes();
    let mut scratch = net.scratch_seeded(seed);
    scratch.train_mode = train;
    let mut out = Vec::with_capacity(n * classes);
    for i in 0..n {
        let probs = net.forward(&params, &images[i * il..(i + 1) * il], &mut scratch, None);
        out.extend_from_slice(probs);
    }
    out
}

fn batched_probs(
    net: &Network,
    params: &[f32],
    images: &[f32],
    n: usize,
    cap: usize,
    train: bool,
    seed: u64,
) -> Vec<f32> {
    let plan = net.batch_plan(cap).unwrap();
    let mut scratch = plan.scratch_seeded(seed);
    scratch.train_mode = train;
    let il = net.dims[0].out_len();
    let mut out = Vec::new();
    let mut idx = 0;
    while idx < n {
        let b = cap.min(n - idx);
        let probs =
            plan.forward(&params, &images[idx * il..(idx + b) * il], b, &mut scratch, None);
        out.extend_from_slice(probs);
        idx += b;
    }
    out
}

#[test]
fn covered_kinds_match_registry() {
    let mut covered: Vec<String> = COVERED_KINDS.iter().map(|s| s.to_string()).collect();
    covered.sort();
    let registered = layer::names();
    assert_eq!(
        registered, covered,
        "a registered kind is missing from the batch bit-identity coverage"
    );
    // And the zoo arch really instantiates every non-input covered kind.
    let net = Network::new(zoo_arch());
    for kind in COVERED_KINDS.iter().filter(|k| **k != "input") {
        assert!(
            net.ops.iter().any(|op| op.kind() == *kind),
            "zoo arch does not instantiate kind '{kind}'"
        );
    }
}

#[test]
fn batched_forward_bit_identical_across_kinds_eval_mode() {
    // Property: for random images, batch sizes and capacities, the batched
    // probabilities equal the per-sample ones bitwise (eval mode: dropout
    // is identity, so the baseline needs no PRNG coordination).
    for arch in [ArchSpec::tiny(), ArchSpec::small(), zoo_arch()] {
        let net = Network::new(arch);
        let params = net.init_params(42);
        let il = net.dims[0].out_len();
        proptest::run(
            proptest::Config { cases: 12, max_size: 9, ..Default::default() },
            |rng, size| {
                let n = 1 + rng.range(0, size.max(1) + 1);
                let cap = 1 + rng.range(0, size.max(1) + 1);
                let images = rand_images(rng, n, il);
                (n, cap, images)
            },
            |(n, cap, images)| {
                let single = per_sample_probs(&net, &params, images, *n, false, 0);
                let batched = batched_probs(&net, &params, images, *n, *cap, false, 0);
                if single != batched {
                    return Err(format!(
                        "{}: batched probs diverge (n={n}, cap={cap})",
                        net.arch.name
                    ));
                }
                Ok(())
            },
        );
    }
}

#[test]
fn batched_forward_bit_identical_with_train_mode_dropout() {
    // Train mode: dropout draws masks. The per-sample baseline shares its
    // PRNG stream across successive calls exactly like forward_batch's
    // contract, so from the same seed both paths draw identical masks —
    // the batch must match bitwise *only* when chunking matches (cap ≥ n,
    // one chunk), because a second chunk reuses the same scratch stream.
    let net = Network::new(zoo_arch());
    let params = net.init_params(7);
    let il = net.dims[0].out_len();
    let mut rng = Pcg32::seeded(3);
    for n in [1usize, 2, 5, 8] {
        let images = rand_images(&mut rng, n, il);
        let single = per_sample_probs(&net, &params, &images, n, true, 0xD0);
        let batched = batched_probs(&net, &params, &images, n, n, true, 0xD0);
        assert_eq!(single, batched, "train-mode dropout diverged at n={n}");
    }
}

#[test]
fn fast_math_forward_within_tolerance_of_exact() {
    // Property: `MathPolicy::Fast` may reassociate (im2col conv, blocked
    // fc GEMM) but must stay numerically close to the exact order — every
    // probability within a small relative error of its exact twin. The zoo
    // arch routes through both reassociating kernels (general conv →
    // im2col, fc → blocked GEMM).
    for arch in [ArchSpec::tiny(), zoo_arch()] {
        let net = Network::new(arch);
        let params = net.init_params(11);
        let il = net.dims[0].out_len();
        let classes = net.num_classes();
        proptest::run(
            proptest::Config { cases: 10, max_size: 8, ..Default::default() },
            |rng, size| {
                let n = 1 + rng.range(0, size.max(1) + 1);
                rand_images(rng, n, il)
            },
            |images| {
                let n = images.len() / il;
                let exact = batched_probs(&net, &params, images, n, n, false, 0);
                let plan = net.batch_plan(n).unwrap().with_math(MathPolicy::Fast);
                let mut scratch = plan.scratch_seeded(0);
                let fast = plan.forward(&params, images, n, &mut scratch, None).to_vec();
                assert_eq!(exact.len(), n * classes);
                for (i, (&e, &f)) in exact.iter().zip(&fast).enumerate() {
                    let tol = 1e-5f32 * e.abs().max(f.abs()).max(1e-3);
                    if (e - f).abs() > tol {
                        return Err(format!(
                            "{}: fast prob {i} drifted: exact={e} fast={f}",
                            net.arch.name
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}

#[test]
fn exact_policy_is_the_default_and_fast_must_be_requested() {
    // The plan's default policy is Exact; bit-identity tests above rely on
    // it. `with_math` is the only way to opt in to reassociation.
    let net = Network::new(zoo_arch());
    let plan = net.batch_plan(4).unwrap();
    assert_eq!(plan.math(), MathPolicy::Exact);
    assert_eq!(plan.with_math(MathPolicy::Fast).math(), MathPolicy::Fast);
}

#[test]
fn batched_forward_matches_paper_archs() {
    // The paper networks end to end (29×29 inputs, conv/pool/fc/output).
    let mut rng = Pcg32::seeded(9);
    for name in ["small", "medium"] {
        let net = Network::from_name(name).unwrap();
        let params = net.init_params(5);
        let il = net.dims[0].out_len();
        let n = 5;
        let images = rand_images(&mut rng, n, il);
        let single = per_sample_probs(&net, &params, &images, n, false, 0);
        let batched = batched_probs(&net, &params, &images, n, 3, false, 0);
        assert_eq!(single, batched, "{name}: batched ≠ per-sample");
    }
}
