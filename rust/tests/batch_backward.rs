//! Bit-identity of the batched backward path.
//!
//! The contract behind minibatch training (`minibatch:B` /
//! `hogwild-batch:B` update policies): `BatchPlan::backward` over `n`
//! samples emits, per parameterized layer, exactly the bits of `n`
//! successive per-sample `Network::backward` calls accumulated in sample
//! order — across **every registered layer kind**, including the
//! padded/strided conv fast-path split and train-mode dropout with fixed
//! masks. A second, op-level harness checks the per-op kernels directly so
//! the **input deltas** (which the network-level API never exposes) are
//! covered too.

use chaos_phi::config::{Act, ArchSpec, LayerSpec};
use chaos_phi::nn::{layer, Acts, BatchActs, MathPolicy, Network, OpScratch};
use chaos_phi::util::{proptest, Pcg32};

fn rand_vec(rng: &mut Pcg32, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect()
}

/// Every kind the test architectures below exercise; the coverage test
/// asserts this set matches the registry, so a newly registered built-in
/// kind fails loudly until it is covered here.
const COVERED_KINDS: &[&str] = &["input", "conv", "pool", "avgpool", "fc", "dropout", "output"];

/// An architecture touching every built-in kind, including the general
/// (padded + strided) conv path and both activations (mirrors
/// `batch_forward.rs`).
fn zoo_arch() -> ArchSpec {
    ArchSpec {
        name: "batch-zoo".into(),
        layers: vec![
            LayerSpec::Input { side: 13 },
            LayerSpec::conv_ex(4, 4, 1, 1, Act::Relu), // padded: 12x12
            LayerSpec::MaxPool { kernel: 2 },          // 6x6
            LayerSpec::conv_ex(6, 2, 2, 0, Act::ScaledTanh), // strided: 3x3
            LayerSpec::AvgPool { kernel: 3 },          // 1x1
            LayerSpec::Dropout { rate: 0.4 },
            LayerSpec::fc_act(17, Act::Relu),
            LayerSpec::Output { classes: 10 },
        ],
        paper_epochs: 1,
    }
}

#[test]
fn covered_kinds_match_registry() {
    let mut covered: Vec<String> = COVERED_KINDS.iter().map(|s| s.to_string()).collect();
    covered.sort();
    let registered = layer::names();
    assert_eq!(
        registered, covered,
        "a registered kind is missing from the batch backward bit-identity coverage"
    );
    // And the zoo arch really instantiates every non-input covered kind.
    let net = Network::new(zoo_arch());
    for kind in COVERED_KINDS.iter().filter(|k| **k != "input") {
        assert!(
            net.ops.iter().any(|op| op.kind() == *kind),
            "zoo arch does not instantiate kind '{kind}'"
        );
    }
}

/// Per-sample baseline: forward + backward each sample with a scratch
/// seeded like the batched one, accumulating per-layer gradients (in
/// sample order) into a full-length vector.
fn per_sample_grads(
    net: &Network,
    params: &[f32],
    images: &[f32],
    labels: &[usize],
    n: usize,
    train: bool,
    seed: u64,
) -> Vec<f32> {
    let il = net.dims[0].out_len();
    let mut scratch = net.scratch_seeded(seed);
    scratch.train_mode = train;
    let mut acc = vec![0.0f32; net.total_params];
    for i in 0..n {
        net.forward(&params, &images[i * il..(i + 1) * il], &mut scratch, None);
        net.backward(&params, labels[i], &mut scratch, None, |_, d, g| {
            for (a, &v) in acc[d.params.clone()].iter_mut().zip(g) {
                *a += v;
            }
        });
    }
    acc
}

/// Batched path: one forward + one backward over the whole chunk (the
/// per-sample baseline shares the PRNG streams, so train-mode dropout
/// draws identical masks — single chunk, like the forward test).
fn batched_grads(
    net: &Network,
    params: &[f32],
    images: &[f32],
    labels: &[usize],
    n: usize,
    train: bool,
    seed: u64,
) -> Vec<f32> {
    let plan = net.batch_plan(n).unwrap();
    let mut scratch = plan.scratch_seeded(seed);
    scratch.train_mode = train;
    plan.forward(&params, images, n, &mut scratch, None);
    let mut acc = vec![0.0f32; net.total_params];
    let mut emitted = Vec::new();
    plan.backward(&params, labels, n, &mut scratch, None, |l, d, g| {
        emitted.push(l);
        acc[d.params.clone()].copy_from_slice(g);
    });
    // Back-to-front emission over exactly the parameterized layers.
    let expect: Vec<usize> = (1..net.dims.len())
        .rev()
        .filter(|&l| net.dims[l].param_count() > 0)
        .collect();
    assert_eq!(emitted, expect, "{}: per-layer emission order", net.arch.name);
    acc
}

#[test]
fn batched_backward_bit_identical_across_kinds() {
    // Property: for random images, labels and batch sizes, the batch-summed
    // gradients equal the per-sample accumulation bitwise. Train mode (the
    // trainer's setting): dropout draws masks shared with the baseline via
    // the common PRNG stream; eval mode covered for the dropout-free archs.
    for (arch, train) in
        [(ArchSpec::tiny(), false), (ArchSpec::tiny(), true), (zoo_arch(), true)]
    {
        let net = Network::new(arch);
        let params = net.init_params(42);
        let il = net.dims[0].out_len();
        let classes = net.num_classes();
        proptest::run(
            proptest::Config { cases: 10, max_size: 7, ..Default::default() },
            |rng, size| {
                let n = 1 + rng.range(0, size.max(1) + 1);
                let images = rand_vec(rng, n * il);
                let labels: Vec<usize> = (0..n).map(|_| rng.range(0, classes)).collect();
                (n, images, labels)
            },
            |(n, images, labels)| {
                let single = per_sample_grads(&net, &params, images, labels, *n, train, 0xD1);
                let batched = batched_grads(&net, &params, images, labels, *n, train, 0xD1);
                if single != batched {
                    return Err(format!(
                        "{} (train={train}): batched grads diverge at n={n}",
                        net.arch.name
                    ));
                }
                Ok(())
            },
        );
    }
}

#[test]
fn batched_backward_matches_paper_archs() {
    // The paper networks end to end (29×29 inputs, conv/pool/fc/output).
    let mut rng = Pcg32::seeded(9);
    for name in ["small", "medium"] {
        let net = Network::from_name(name).unwrap();
        let params = net.init_params(5);
        let il = net.dims[0].out_len();
        let classes = net.num_classes();
        let n = 4;
        let images = rand_vec(&mut rng, n * il);
        let labels: Vec<usize> = (0..n).map(|_| rng.range(0, classes)).collect();
        let single = per_sample_grads(&net, &params, &images, &labels, n, false, 0);
        let batched = batched_grads(&net, &params, &images, &labels, n, false, 0);
        assert_eq!(single, batched, "{name}: batched backward ≠ per-sample");
    }
}

#[test]
fn op_backward_batch_bit_identical_per_kind() {
    // Op-level harness: drive every compiled op of the zoo net directly so
    // input deltas — invisible through the network API — are compared too.
    // Both paths share one PRNG stream per op (forward first, to populate
    // pool switches / dropout masks in the aux words).
    let net = Network::new(zoo_arch());
    let mut rng = Pcg32::seeded(31);
    for l in 1..net.ops.len() {
        let op = net.ops[l].as_ref();
        let d = &net.dims[l];
        let il = d.in_len();
        let ol = d.out_len();
        let al = op.aux_len();
        let pc = d.param_count();
        for batch in [1usize, 3, 5] {
            let params = rand_vec(&mut rng, pc);
            let inputs = rand_vec(&mut rng, batch * il);
            let deltas0 = rand_vec(&mut rng, batch * ol);

            // Per-sample path.
            let mut rng_a = Pcg32::new(0xBEEF, l as u64);
            let mut aux_a = vec![0u32; batch * al];
            let mut outs_a = vec![0.0f32; batch * ol];
            for b in 0..batch {
                let mut per = OpScratch {
                    aux: &mut aux_a[b * al..(b + 1) * al],
                    rng: &mut rng_a,
                    train: true,
                    math: MathPolicy::Exact,
                    col: &mut [],
                };
                op.forward(
                    &params,
                    &inputs[b * il..(b + 1) * il],
                    &mut outs_a[b * ol..(b + 1) * ol],
                    &mut per,
                );
            }
            let mut deltas_a = deltas0.clone();
            let mut din_a = vec![0.0f32; batch * il];
            let mut grads_a = vec![0.0f32; pc];
            for b in 0..batch {
                let mut per = OpScratch {
                    aux: &mut aux_a[b * al..(b + 1) * al],
                    rng: &mut rng_a,
                    train: true,
                    math: MathPolicy::Exact,
                    col: &mut [],
                };
                op.backward(
                    &params,
                    Acts {
                        input: &inputs[b * il..(b + 1) * il],
                        output: &outs_a[b * ol..(b + 1) * ol],
                    },
                    &mut deltas_a[b * ol..(b + 1) * ol],
                    &mut din_a[b * il..(b + 1) * il],
                    &mut grads_a,
                    &mut per,
                );
            }

            // Batched path, same seed → same masks.
            let mut rng_b = Pcg32::new(0xBEEF, l as u64);
            let mut aux_b = vec![0u32; batch * al];
            let mut outs_b = vec![0.0f32; batch * ol];
            {
                let mut per = OpScratch {
                    aux: &mut aux_b,
                    rng: &mut rng_b,
                    train: true,
                    math: MathPolicy::Exact,
                    col: &mut [],
                };
                op.forward_batch(&params, &inputs, &mut outs_b, batch, &mut per);
            }
            let mut deltas_b = deltas0.clone();
            let mut din_b = vec![0.0f32; batch * il];
            let mut grads_b = vec![0.0f32; pc];
            {
                let mut per = OpScratch {
                    aux: &mut aux_b,
                    rng: &mut rng_b,
                    train: true,
                    math: MathPolicy::Exact,
                    col: &mut [],
                };
                op.backward_batch(
                    &params,
                    BatchActs { inputs: &inputs, outputs: &outs_b },
                    &mut deltas_b,
                    &mut din_b,
                    &mut grads_b,
                    batch,
                    &mut per,
                );
            }

            let kind = op.kind();
            assert_eq!(outs_a, outs_b, "{kind} B={batch}: forward outputs");
            assert_eq!(deltas_a, deltas_b, "{kind} B={batch}: pre-activation deltas");
            assert_eq!(din_a, din_b, "{kind} B={batch}: input deltas");
            assert_eq!(grads_a, grads_b, "{kind} B={batch}: batch-summed gradients");

            // Empty input-delta path (layer above the input): gradients
            // must be unaffected by skipping the delta computation.
            let mut deltas_c = deltas0.clone();
            let mut grads_c = vec![0.0f32; pc];
            {
                let mut per = OpScratch {
                    aux: &mut aux_b,
                    rng: &mut rng_b,
                    train: true,
                    math: MathPolicy::Exact,
                    col: &mut [],
                };
                op.backward_batch(
                    &params,
                    BatchActs { inputs: &inputs, outputs: &outs_b },
                    &mut deltas_c,
                    &mut [],
                    &mut grads_c,
                    batch,
                    &mut per,
                );
            }
            assert_eq!(grads_c, grads_b, "{kind} B={batch}: grads with empty input deltas");
        }
    }
}
