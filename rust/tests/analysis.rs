//! Integration coverage for `chaos::analysis` — the span verifier over
//! real compiled networks, the deterministic interleaver through the
//! public API, and (behind `--features race-check`) the race /
//! lock-discipline checker driven end-to-end through [`SharedParams`]
//! and full training runs.
//!
//! The negative tests here are the acceptance checks of the analysis
//! subsystem: every seeded defect class — overlapping spans,
//! out-of-bounds span, wrong-lock publish, unlocked overlapping write
//! under a `Controlled` contract — must be detected, and the shipped
//! paper architectures and registered policies must come back clean.

use chaos_phi::chaos::analysis::{verify_network, verify_spans, Interleaver, Schedule};
use chaos_phi::config::ArchSpec;
use chaos_phi::nn::{compute_dims, total_params, Network};
use chaos_phi::util::Json;

// ---------------------------------------------------------------------
// Level 1: static span verification
// ---------------------------------------------------------------------

#[test]
fn shipped_architectures_are_span_clean() {
    for name in ["small", "medium", "large", "tiny"] {
        let net = Network::from_name(name).unwrap();
        let report = verify_network(&net);
        assert!(report.is_clean(), "{name}: {}", report.to_text());
        assert_eq!(report.arch, name);
        assert_eq!(report.total_params, net.total_params);
        // The JSON view agrees and round-trips through the parser.
        let json = Json::parse(&report.to_json().pretty()).unwrap();
        assert_eq!(json.get("clean").and_then(Json::as_bool), Some(true));
    }
}

fn classes(dims: &[chaos_phi::nn::LayerDims], total: usize) -> Vec<&'static str> {
    verify_spans(dims, total).iter().map(|d| d.class()).collect()
}

/// Each seeded layout-defect class is detected by the verifier. (The
/// spans unit tests pin exact defect fields; this exercises the same
/// checks through the crate's public API on a real layer table.)
#[test]
fn seeded_layout_defects_are_detected() {
    let clean = compute_dims(&ArchSpec::tiny());
    let total = total_params(&clean);
    assert!(classes(&clean, total).is_empty());

    // Overlap: slide layer 3's span down into layer 1's tail.
    let mut dims = clean.clone();
    dims[3].params = dims[3].params.start - 2..dims[3].params.end - 2;
    assert!(classes(&dims, total).contains(&"overlap"), "{:?}", verify_spans(&dims, total));

    // Out of bounds: the last span runs past the store.
    let mut dims = clean.clone();
    let last = dims.len() - 1;
    dims[last].params = dims[last].params.start..total + 7;
    assert!(classes(&dims, total).contains(&"out-of-bounds"));

    // Gap: layer 1 gives up its last 3 parameters and nobody claims them.
    let mut dims = clean.clone();
    dims[1].params = dims[1].params.start..dims[1].params.end - 3;
    dims[1].weights -= 3;
    assert!(classes(&dims, total).contains(&"gap"));

    // Length mismatch: the span disagrees with the declared param count.
    let mut dims = clean.clone();
    dims[1].weights += 5;
    assert!(classes(&dims, total).contains(&"length-mismatch"));

    // Inverted: end before start.
    let mut dims = clean.clone();
    dims[1].params = dims[1].params.end..dims[1].params.start;
    assert!(classes(&dims, total).contains(&"inverted"));
}

// ---------------------------------------------------------------------
// Level 3: the deterministic interleaver through the public API
// ---------------------------------------------------------------------

#[test]
fn interleaver_replays_a_scripted_order_exactly() {
    use chaos_phi::chaos::analysis::yield_point;
    use std::sync::Mutex;

    let log = Mutex::new(Vec::new());
    let run = |schedule| {
        log.lock().unwrap().clear();
        let mk = |id: usize| {
            let log = &log;
            Box::new(move || {
                log.lock().unwrap().push(id);
                yield_point("step");
                log.lock().unwrap().push(id);
            }) as Box<dyn FnOnce() + Send>
        };
        let trace = Interleaver::run(schedule, vec![mk(0), mk(1)]);
        (log.lock().unwrap().clone(), trace)
    };
    let (order, trace) = run(Schedule::Script(vec![1, 0, 1, 0]));
    assert_eq!(order, vec![1, 0, 1, 0]);
    // start1, start0, resume1, exit1, resume0, exit0.
    assert_eq!(trace.order(), vec![1, 0, 1, 1, 0, 0]);
    // A seeded schedule replays identically for the same seed.
    assert_eq!(run(Schedule::Seeded(9)), run(Schedule::Seeded(9)));
}

// ---------------------------------------------------------------------
// Level 2: the race checker, end-to-end through SharedParams
// ---------------------------------------------------------------------

#[cfg(feature = "race-check")]
mod race_check {
    use super::*;
    use chaos_phi::chaos::analysis::{yield_point, RaceDefect, SyncContract};
    use chaos_phi::chaos::{policy, SharedParams, Trainer};
    use chaos_phi::config::TrainConfig;
    use chaos_phi::data::{generate_synthetic, Dataset, SynthConfig};
    use std::ops::Range;

    fn tiny_store() -> (SharedParams, Vec<Range<usize>>) {
        let dims = compute_dims(&ArchSpec::tiny());
        let total = total_params(&dims);
        let spans: Vec<Range<usize>> = dims.iter().map(|d| d.params.clone()).collect();
        (SharedParams::new(&vec![0.0; total], &dims), spans)
    }

    /// Wrong-lock publish is a hard error under the feature: the store
    /// rejects the (layer, range) mismatch before touching any weight.
    #[test]
    #[should_panic(expected = "not owned by layer")]
    fn wrong_lock_publish_is_a_hard_error() {
        let (store, spans) = tiny_store();
        let range = spans[3].clone();
        store.publish_scaled(1, range.clone(), &vec![0.0; range.len()], 1.0);
    }

    /// The headline negative test: two workers publish the same span
    /// unlocked, and the interleaver forces the exact read-modify-write
    /// overlap in which HogWild! loses an update. Under the default
    /// `Controlled` contract the checker reports the overlap; under
    /// `HogwildTolerated` the identical schedule is clean — but the
    /// update is still deterministically lost either way.
    #[test]
    fn scripted_unlocked_overlap_loses_an_update_and_is_flagged() {
        for (contract, expect_defect) in
            [(SyncContract::Controlled, true), (SyncContract::HogwildTolerated, false)]
        {
            let (store, spans) = tiny_store();
            store.set_sync_contract(contract);
            let range = spans[1].clone();
            let grads = vec![1.0f32; range.len()];
            let worker = || {
                store.publish_scaled_unlocked(range.clone(), &grads, 1.0);
            };
            // [0,1,0,1]: worker 0 reads element 0, parks inside its RMW;
            // worker 1 reads the same stale 0.0 and parks; worker 0 writes
            // 1.0 and finishes; worker 1 overwrites with its own 1.0 —
            // worker 0's update to element 0 is lost.
            let trace = Interleaver::run(
                Schedule::Script(vec![0, 1, 0, 1]),
                vec![Box::new(worker), Box::new(worker)],
            );
            // start0, start1, resume0 (inside its split RMW), exit0,
            // resume1, exit1.
            assert_eq!(trace.order(), vec![0, 1, 0, 0, 1, 1], "contract {contract:?}");
            assert_eq!(store.get(range.start), 1.0, "element 0 must lose one update");
            for i in range.start + 1..range.end {
                assert_eq!(store.get(i), 2.0, "element {i} sees both updates");
            }
            let defects = store.race_defects();
            if expect_defect {
                assert!(
                    defects.iter().any(|d| matches!(d, RaceDefect::UnlockedOverlap { .. })),
                    "overlap not flagged under Controlled: {defects:?}"
                );
            } else {
                assert!(defects.is_empty(), "HogwildTolerated must accept: {defects:?}");
            }
        }
    }

    /// Locked publications under the same scripted schedule lose nothing
    /// and stay clean: the publish yield point sits *before* the lock, so
    /// the interleaver can reorder lock acquisition but never split the
    /// locked read-modify-write.
    #[test]
    fn scripted_locked_publishes_lose_nothing() {
        let (store, spans) = tiny_store();
        let range = spans[1].clone();
        let grads = vec![1.0f32; range.len()];
        let worker = || {
            store.publish_scaled(1, range.clone(), &grads, 1.0);
        };
        Interleaver::run(
            Schedule::Script(vec![0, 1, 0, 1]),
            vec![Box::new(worker), Box::new(worker)],
        );
        for i in range.clone() {
            assert_eq!(store.get(i), 2.0, "locked update lost at {i}");
        }
        assert!(store.race_is_clean(), "{:?}", store.race_defects());
    }

    /// A publish landing in no declared span is recorded as a defect even
    /// when it races nobody.
    #[test]
    fn outside_span_publish_is_recorded() {
        let (store, spans) = tiny_store();
        // Straddles the layer-1 / layer-3 boundary (layer 2 is a pool).
        let straddle = spans[1].end - 1..spans[3].start + 1;
        store.publish_scaled_unlocked(straddle, &[0.0; 2], 1.0);
        let defects = store.race_defects();
        assert!(
            defects.iter().any(|d| matches!(d, RaceDefect::OutsideSpan { .. })),
            "{defects:?}"
        );
    }

    /// Outside an interleaved run the store's yield points are no-ops.
    #[test]
    fn instrumented_store_works_without_an_interleaver() {
        let (store, spans) = tiny_store();
        let range = spans[1].clone();
        store.publish_scaled(1, range.clone(), &vec![1.0; range.len()], 1.0);
        yield_point("free");
        assert_eq!(store.get(range.start), 1.0);
        assert!(store.race_is_clean());
    }

    /// Cross-shard enforcement, replayed deterministically: a verified
    /// plan's ownership table is installed on the store, worker 0 declares
    /// shard 0 but publishes shard 1's fc piece, worker 1 publishes the
    /// same piece legally. Exactly the illegal publish is recorded —
    /// locked, in-span, and still a defect, because the shard contract is
    /// an ownership claim on top of the lock discipline.
    #[test]
    fn scripted_cross_shard_publish_is_recorded() {
        use chaos_phi::chaos::analysis::{plan_shards, set_worker_shard, verify_shards};

        let net = Network::from_name("tiny").unwrap();
        let plan = plan_shards(&net, 2);
        assert!(verify_shards(&net, &plan).is_clean());
        let (store, _) = tiny_store();
        store.set_shard_ownership(plan.ownership());

        let fc = net.ops.iter().position(|op| op.kind() == "fc").unwrap();
        // Shard 1's weight-row block of the fc span.
        let piece = plan.owned_ranges(&net, 1, fc)[0].clone();
        let grads = vec![1.0f32; piece.len()];
        let worker = |shard: usize| {
            let (store, piece, grads) = (&store, piece.clone(), &grads);
            Box::new(move || {
                set_worker_shard(Some(shard));
                store.publish_scaled(fc, piece.clone(), grads, 1.0);
                set_worker_shard(None);
            }) as Box<dyn FnOnce() + Send>
        };
        Interleaver::run(Schedule::Script(vec![0, 1, 0, 1]), vec![worker(0), worker(1)]);

        // Both publishes landed (the checker observes, it does not block)…
        assert_eq!(store.get(piece.start), 2.0);
        // …but only worker 0's is a defect, attributed to the right piece.
        let defects = store.race_defects();
        assert_eq!(defects.len(), 1, "{defects:?}");
        match &defects[0] {
            RaceDefect::CrossShardPublish { owner, shard, piece: p, .. } => {
                assert_eq!(*owner, 1);
                assert_eq!(*shard, Some(0));
                assert_eq!(*p, piece);
            }
            other => panic!("expected CrossShardPublish, got {other:?}"),
        }
    }

    fn tiny_data(n: usize, seed: u64) -> Dataset {
        generate_synthetic(n, seed, &SynthConfig::default()).resize(13)
    }

    /// Every registered paper policy trains clean under its declared
    /// contract: the trainer itself asserts a defect-free store at the
    /// end of each parallel run, so reaching the assertions below means
    /// the whole run produced zero findings.
    #[test]
    fn registered_policies_train_clean_under_race_check() {
        let train = tiny_data(96, 1);
        let test = tiny_data(32, 2);
        for name in ["chaos", "hogwild", "delayed-rr", "minibatch:8", "averaged:4"] {
            let run = Trainer::new()
                .arch(ArchSpec::tiny())
                .config(TrainConfig {
                    epochs: 1,
                    threads: 3,
                    eta0: 0.05,
                    eta_decay: 0.95,
                    seed: 7,
                    validation_fraction: 0.25,
                    eval_batch: 32,
                    ..TrainConfig::default()
                })
                .policy_boxed(policy::from_name(name).unwrap())
                .run(&train, &test)
                .unwrap_or_else(|e| panic!("{name}: {e:#}"));
            assert_eq!(run.epochs.len(), 1, "{name}");
        }
    }
}
